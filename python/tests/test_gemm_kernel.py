"""L1 GEMM Pallas kernel vs pure-jnp oracle (the core correctness signal)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import gemm as gemm_k
from compile.kernels import ref


def _rand(shape, seed, dtype=jnp.float32):
    key = jax.random.PRNGKey(seed)
    return jax.random.normal(key, shape, dtype=jnp.float32).astype(dtype)


@pytest.mark.parametrize(
    "m,k,n",
    [
        (8, 128, 128),
        (16, 128, 128),
        (32, 128, 128),
        (64, 128, 128),
        (128, 128, 128),
        (128, 256, 128),
        (256, 128, 256),
    ],
)
def test_gemm_matches_ref_canonical(m, k, n):
    a, b = _rand((m, k), 0), _rand((k, n), 1)
    got = gemm_k.gemm(a, b)
    want = ref.gemm(a, b)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("bm,bn,bk", [(8, 16, 32), (16, 16, 16), (32, 64, 128)])
def test_gemm_block_override(bm, bn, bk):
    """All legal block decompositions produce identical results."""
    a, b = _rand((64, 128), 2), _rand((128, 64), 3)
    got = gemm_k.gemm(a, b, block_m=bm, block_n=bn, block_k=bk)
    want = ref.gemm(a, b)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(
    mi=st.integers(1, 8),
    ki=st.integers(1, 8),
    ni=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_gemm_shape_sweep(mi, ki, ni, seed):
    """Hypothesis sweep over block-multiple shapes."""
    m, k, n = 8 * mi, 8 * ki, 8 * ni
    a, b = _rand((m, k), seed), _rand((k, n), seed + 1)
    got = gemm_k.gemm(a, b, block_m=8, block_n=8, block_k=8)
    want = ref.gemm(a, b)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_gemm_bf16_inputs(seed):
    """bf16 inputs accumulate in f32 (MXU semantics)."""
    a = _rand((32, 64), seed, jnp.bfloat16)
    b = _rand((64, 32), seed + 1, jnp.bfloat16)
    got = gemm_k.gemm(a, b)
    want = ref.gemm(a, b)
    assert got.dtype == jnp.float32
    np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)


def test_gemm_rejects_mismatched_contraction():
    a, b = _rand((16, 32), 0), _rand((64, 16), 1)
    with pytest.raises(AssertionError):
        gemm_k.gemm(a, b)


def test_gemm_rejects_nondividing_blocks():
    a, b = _rand((24, 24), 0), _rand((24, 24), 1)
    with pytest.raises(AssertionError):
        gemm_k.gemm(a, b, block_m=16, block_n=8, block_k=8)


def test_gemm_bias_gelu_matches_ref():
    a, b = _rand((32, 64), 4), _rand((64, 32), 5)
    bias = _rand((32,), 6)
    got = gemm_k.gemm_bias_gelu(a, b, bias)
    want = ref.gemm_bias_gelu(a, b, bias)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_gemm_zero_inputs():
    a = jnp.zeros((16, 16), jnp.float32)
    b = jnp.zeros((16, 16), jnp.float32)
    np.testing.assert_array_equal(gemm_k.gemm(a, b), jnp.zeros((16, 16)))


def test_gemm_identity():
    a = _rand((32, 32), 7)
    eye = jnp.eye(32, dtype=jnp.float32)
    np.testing.assert_allclose(gemm_k.gemm(a, eye), a, rtol=1e-6, atol=1e-6)


def test_pick_block():
    assert gemm_k._pick_block(64, 128) == 64
    assert gemm_k._pick_block(256, 128) == 128
    assert gemm_k._pick_block(192, 128) == 96
    assert gemm_k._pick_block(7, 128) == 7


def test_vmem_budget():
    """Canonical 128^3 f32 block set fits well under the 16 MiB VMEM budget."""
    vb = gemm_k.vmem_bytes(128, 128, 128)
    assert vb == 2 * (2 * 128 * 128 * 4) + 128 * 128 * 4
    assert vb < 16 * 1024 * 1024


def test_mxu_estimate_monotone():
    full = gemm_k.mxu_utilization_estimate(128, 128, 128)
    half = gemm_k.mxu_utilization_estimate(64, 128, 128)
    tiny = gemm_k.mxu_utilization_estimate(8, 8, 8)
    assert full == 1.0
    assert tiny < half < full
