"""L1 attention kernels vs oracle: block step, ring composition, finalize."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention as attn_k
from compile.kernels import ref

SCALE = 0.125


def _rand(shape, seed):
    return jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)


def test_attn_step_matches_ref():
    q, k, v = _rand((64, 64), 0), _rand((32, 64), 1), _rand((32, 64), 2)
    acc, m, l = attn_k.init_state(64, 64)
    got = attn_k.attn_step(q, k, v, acc, m, l, scale=SCALE)
    want = ref.attn_step(q, k, v, acc, m, l, SCALE)
    for g, w in zip(got, want):
        np.testing.assert_allclose(g, w, rtol=1e-5, atol=1e-5)


def test_attn_step_from_nonzero_state():
    """A step from mid-ring state matches the oracle (rescaling path)."""
    q, k1, v1 = _rand((64, 64), 3), _rand((64, 64), 4), _rand((64, 64), 5)
    k2, v2 = _rand((64, 64), 6), _rand((64, 64), 7)
    st_p = attn_k.attn_step(q, k1, v1, *attn_k.init_state(64, 64), scale=SCALE)
    got = attn_k.attn_step(q, k2, v2, *st_p, scale=SCALE)
    want = ref.attn_step(q, k2, v2, *st_p, SCALE)
    for g, w in zip(got, want):
        np.testing.assert_allclose(g, w, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("chunks", [1, 2, 4, 8])
def test_ring_composition_equals_full_attention(chunks):
    """Folding K/V chunk-by-chunk == full softmax attention (any split)."""
    sq, sk, d = 64, 128, 64
    q = _rand((sq, d), 10)
    k = _rand((sk, d), 11)
    v = _rand((sk, d), 12)
    state = attn_k.init_state(sq, d)
    step = sk // chunks
    for c in range(chunks):
        kc = k[c * step:(c + 1) * step]
        vc = v[c * step:(c + 1) * step]
        state = attn_k.attn_step(q, kc, vc, *state, scale=SCALE)
    got = attn_k.attn_finalize(state[0], state[2])
    want = ref.attention(q, k, v, SCALE)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_chunk_order_invariance():
    """Online softmax is order-invariant: ring order must not change o."""
    sq, d = 64, 64
    q = _rand((sq, d), 20)
    chunks = [( _rand((32, d), 30 + i), _rand((32, d), 40 + i)) for i in range(4)]

    def run(order):
        state = attn_k.init_state(sq, d)
        for i in order:
            state = attn_k.attn_step(q, chunks[i][0], chunks[i][1], *state, scale=SCALE)
        return attn_k.attn_finalize(state[0], state[2])

    a = run([0, 1, 2, 3])
    b = run([3, 1, 0, 2])
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(
    sqi=st.integers(1, 3),
    ski=st.integers(1, 4),
    seed=st.integers(0, 2**31 - 1),
)
def test_attn_shape_sweep(sqi, ski, seed):
    """Hypothesis sweep over Q/K shard lengths (multiples of the block)."""
    sq, sk, d = 64 * sqi, 32 * ski, 64
    q, k, v = _rand((sq, d), seed), _rand((sk, d), seed + 1), _rand((sk, d), seed + 2)
    state = attn_k.attn_step(q, k, v, *attn_k.init_state(sq, d), scale=SCALE)
    got = attn_k.attn_finalize(state[0], state[2])
    want = ref.attention(q, k, v, SCALE)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_finalize_matches_ref():
    acc, l = _rand((64, 64), 50), jnp.abs(_rand((64,), 51)) + 1.0
    np.testing.assert_allclose(
        attn_k.attn_finalize(acc, l), ref.attn_finalize(acc, l), rtol=1e-6
    )


def test_numerical_stability_large_logits():
    """Online softmax must survive large score magnitudes without inf/nan."""
    q = 30.0 * jnp.ones((64, 64), jnp.float32)
    k = 30.0 * jnp.ones((64, 64), jnp.float32)
    v = _rand((64, 64), 60)
    state = attn_k.attn_step(q, k, v, *attn_k.init_state(64, 64), scale=1.0)
    out = attn_k.attn_finalize(state[0], state[2])
    assert bool(jnp.all(jnp.isfinite(out)))
    # uniform scores -> output is the mean of v rows
    np.testing.assert_allclose(out, jnp.broadcast_to(v.mean(0), (64, 64)), rtol=1e-4, atol=1e-4)


def test_init_state_identity_element():
    """init_state is the monoid identity for the online-softmax fold."""
    q, k, v = _rand((64, 64), 70), _rand((64, 64), 71), _rand((64, 64), 72)
    one = attn_k.attn_step(q, k, v, *attn_k.init_state(64, 64), scale=SCALE)
    # folding the same chunk after an init produces the direct oracle step
    want = ref.attn_step(q, k, v, *attn_k.init_state(64, 64), SCALE)
    for g, w in zip(one, want):
        np.testing.assert_allclose(g, w, rtol=1e-5, atol=1e-5)


def test_vmem_estimate_within_budget():
    assert attn_k.vmem_bytes(64, 64, 64) < 16 * 1024 * 1024
    assert attn_k.vmem_bytes(128, 128, 128) > attn_k.vmem_bytes(64, 64, 64)
