"""L2 model graphs + AOT lowering: shapes, numerics, HLO-text validity."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.kernels import ref


def _rand(shape, seed):
    return jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)


def test_entry_points_cover_all_split_variants():
    eps = model.entry_points()
    for tm in model.GEMM_TMS:
        assert f"gemm_{tm}x{model.GEMM_K}x{model.GEMM_N}" in eps
    for sk in model.ATTN_SKS:
        assert f"attn_step_q{model.ATTN_SQ}d{model.ATTN_D}k{sk}" in eps
    assert any(k.startswith("ffn_shard_") for k in eps)
    assert any(k.startswith("attn_finalize_") for k in eps)
    assert sum(k.startswith("add_") for k in eps) == 3


def test_entry_point_shapes_consistent():
    """eval_shape of each entry matches its declared example args."""
    for name, (fn, args) in model.entry_points().items():
        outs = jax.eval_shape(fn, *args)
        assert isinstance(outs, tuple) and len(outs) >= 1, name
        for o in outs:
            assert all(d > 0 for d in o.shape), name


def test_ffn_shard_matches_ref():
    x, w1 = _rand((model.FFN_M, model.FFN_D), 0), _rand((model.FFN_D, model.FFN_F), 1)
    b1, w2 = _rand((model.FFN_F,), 2), _rand((model.FFN_F, model.FFN_D), 3)
    (got,) = model.ffn_shard(x, w1, b1, w2)
    want = ref.ffn_shard(x, w1, b1, w2)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_tensor_parallel_ffn_composition():
    """Sum of per-rank FFN shards == unsharded FFN (the GEMM-AR identity)."""
    world, f_total = 4, 4 * model.FFN_F
    x = _rand((model.FFN_M, model.FFN_D), 10)
    w1 = _rand((model.FFN_D, f_total), 11)
    b1 = _rand((f_total,), 12)
    w2 = _rand((f_total, model.FFN_D), 13)
    want = ref.ffn_shard(x, w1, b1, w2)

    acc = jnp.zeros((model.FFN_M, model.FFN_D), jnp.float32)
    for r in range(world):
        sl = slice(r * model.FFN_F, (r + 1) * model.FFN_F)
        (part,) = model.ffn_shard(x, w1[:, sl], b1[sl], w2[sl, :])
        acc = acc + part
    np.testing.assert_allclose(acc, want, rtol=1e-4, atol=1e-4)


def test_gemm_chunk_row_decomposition():
    """Concatenated chunk GEMMs == full GEMM (AG-GEMM chunk identity)."""
    a = _rand((128, model.GEMM_K), 20)
    b = _rand((model.GEMM_K, model.GEMM_N), 21)
    want = ref.gemm(a, b)
    rows = []
    for c in range(4):
        (y,) = model.gemm_chunk(a[c * 32:(c + 1) * 32], b)
        rows.append(y)
    np.testing.assert_allclose(jnp.concatenate(rows, 0), want, rtol=1e-5, atol=1e-5)


def test_hlo_text_lowering_valid():
    """Every entry lowers to HLO text with an ENTRY computation."""
    eps = model.entry_points()
    # lowering all 13 is slow; spot-check one of each family
    picks = [
        f"gemm_{model.GEMM_TMS[0]}x{model.GEMM_K}x{model.GEMM_N}",
        f"attn_step_q{model.ATTN_SQ}d{model.ATTN_D}k{model.ATTN_SKS[0]}",
        f"add_{model.ATTN_SQ}x{model.ATTN_D}",
    ]
    for name in picks:
        fn, args = eps[name]
        text = aot.to_hlo_text(jax.jit(fn).lower(*args))
        assert "ENTRY" in text and "HloModule" in text, name


def test_artifacts_manifest_consistent():
    """If `make artifacts` has run, manifest must match entry_points()."""
    mpath = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts", "manifest.json")
    if not os.path.exists(mpath):
        pytest.skip("artifacts not built")
    with open(mpath) as f:
        manifest = json.load(f)
    eps = model.entry_points()
    assert set(manifest["entries"]) == set(eps)
    for name, ent in manifest["entries"].items():
        hlo = os.path.join(os.path.dirname(mpath), ent["file"])
        assert os.path.exists(hlo), name
        _, args = eps[name]
        assert [list(a.shape) for a in args] == [e["shape"] for e in ent["inputs"]]


def test_add_combiner_is_reduction():
    x, y = _rand((64, 64), 30), _rand((64, 64), 31)
    (z,) = model.add(x, y)
    np.testing.assert_allclose(z, x + y)
