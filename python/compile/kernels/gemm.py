"""L1 Pallas GEMM kernel — the compute hot-spot of every GEMM-family operator.

This is the TPU adaptation of the paper's Listing-1 persistent Triton GEMM:

  * threadblock tiles            -> Pallas grid blocks (BlockSpec)
  * shared-memory staging        -> VMEM blocks (BlockSpec index maps)
  * tensor-core `tl.dot`         -> MXU `jnp.dot` (blocks are multiples of
                                    the 128x128 systolic array where shapes
                                    allow; small test shapes use 16+)
  * persistent `tile_id` loop    -> the (m, n, k) grid; Syncopate's L3
                                    tile-scheduler swizzle permutes the
                                    traversal of this grid.

The `@sy.*` comments below follow the paper's structured directive format
(Listing 1). They carry no Python semantics, but the Rust frontend
(`rust/src/kernel/annotations.rs`) parses this very file to recover the tile
structure, so keep them in sync with the BlockSpecs.

Run with interpret=True only: real TPU lowering emits a Mosaic custom call
that the CPU PJRT plugin cannot execute.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default MXU-aligned block sizes for "paper scale" shapes; the AOT entry
# points for the small real-numerics shapes override these.
BLOCK_M = 128
BLOCK_N = 128
BLOCK_K = 128


def _gemm_kernel(a_ref, b_ref, o_ref):
    """One (m, n, k) grid step: o[m, n] += a[m, k] @ b[k, n].

    # @sy.axis_count M block=BLOCK_M
    # @sy.axis_count N block=BLOCK_N
    # @sy.axis_count K block=BLOCK_K
    # @sy.tile_id grid
    # @sy.dispatch begin
    # @sy.pid_map M=0 N=1 K=2
    # @sy.dispatch end
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # MXU path: accumulate in f32 regardless of input dtype.
    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k"))
def gemm(a, b, *, block_m=None, block_n=None, block_k=None):
    """Tiled Pallas GEMM: (M, K) @ (K, N) -> (M, N).

    Blocks default to the largest of {BLOCK_*, dim} that divides the dim, so
    small test shapes stay valid while big shapes hit MXU-aligned 128s.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"contraction mismatch: {a.shape} @ {b.shape}"

    bm = block_m or _pick_block(m, BLOCK_M)
    bn = block_n or _pick_block(n, BLOCK_N)
    bk = block_k or _pick_block(k, BLOCK_K)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (
        f"blocks ({bm},{bn},{bk}) must divide shape ({m},{n},{k})"
    )

    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _gemm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(a, b)


def _pick_block(dim: int, pref: int) -> int:
    """Largest block <= pref that divides dim (falls back to dim itself)."""
    if dim <= pref:
        return dim
    for cand in range(pref, 0, -1):
        if dim % cand == 0:
            return cand
    return dim


def gemm_bias_gelu(a, b, bias):
    """Fused GEMM + bias + tanh-GELU epilogue (FFN first projection)."""
    y = gemm(a, b)
    y = y + bias[None, :]
    return _gelu(y)


def _gelu(x):
    # tanh approximation, matches the reference oracle exactly.
    c = jnp.sqrt(2.0 / jnp.pi).astype(x.dtype)
    return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x * x * x)))


# ---------------------------------------------------------------------------
# VMEM / MXU accounting (structure-level; interpret mode has no real timing).
# ---------------------------------------------------------------------------

def vmem_bytes(block_m: int, block_n: int, block_k: int, itemsize: int = 4,
               double_buffered: bool = True) -> int:
    """VMEM footprint of one grid step: A block + B block + O block.

    With the Pallas pipeline's default double buffering the input blocks are
    resident twice. This is the number DESIGN.md §8 reports.
    """
    a = block_m * block_k * itemsize
    b = block_k * block_n * itemsize
    o = block_m * block_n * itemsize
    bufs = 2 if double_buffered else 1
    return bufs * (a + b) + o


def mxu_utilization_estimate(block_m: int, block_n: int, block_k: int) -> float:
    """Fraction of the 128x128 MXU each dot fills (systolic-array occupancy)."""
    fill = (min(block_m, 128) / 128.0) * (min(block_n, 128) / 128.0)
    # K chains shorter than 128 under-utilize the pipeline ramp.
    ramp = min(block_k, 128) / 128.0
    return fill * (0.5 + 0.5 * ramp)
