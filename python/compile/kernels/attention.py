"""L1 Pallas attention kernels: flash-attention block step + finalize.

These implement the online-softmax block update that Ring-Attention [18]
passes around the device ring. Each rank holds a local Q shard and receives
K/V *chunks* from its ring peer; one `attn_step` consumes one K/V chunk and
folds it into the running (acc, m, l) state. `attn_finalize` divides the
accumulator by the softmax denominator.

TPU adaptation notes (DESIGN.md §Hardware-Adaptation):
  * the CUDA warp-level QK^T / PV matmuls map to MXU `jnp.dot`s;
  * one (Bq, d) Q block + (Bk, d) K/V blocks + (Bq, d) acc + (Bq,) m/l all
    live in VMEM for the duration of the step;
  * the grid iterates over Q blocks; K/V-chunk iteration is the *ring*,
    i.e. Syncopate's communication schedule, not the kernel grid.

# @sy.axis_count Q block=BLOCK_Q
# @sy.tile_id grid
# @sy.dispatch begin
# @sy.pid_map Q=0
# @sy.dispatch end
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_Q = 64

NEG_INF = -1e30


def _attn_step_kernel(q_ref, k_ref, v_ref, acc_ref, m_ref, l_ref,
                      acc_out, m_out, l_out, *, scale):
    """Online-softmax update for one K/V chunk against one Q block."""
    q = q_ref[...].astype(jnp.float32)
    k = k_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    acc = acc_ref[...].astype(jnp.float32)
    m_prev = m_ref[...].astype(jnp.float32)
    l_prev = l_ref[...].astype(jnp.float32)

    # MXU: scores[qb, kb] = (Q @ K^T) * scale
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

    m_cur = jnp.max(s, axis=-1)
    m_new = jnp.maximum(m_prev, m_cur)
    # Rescale previous accumulator/denominator to the new max.
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_new = alpha * l_prev + jnp.sum(p, axis=-1)
    acc_new = acc * alpha[:, None] + jnp.dot(
        p, v, preferred_element_type=jnp.float32
    )

    acc_out[...] = acc_new
    m_out[...] = m_new
    l_out[...] = l_new


@functools.partial(jax.jit, static_argnames=("scale",))
def attn_step(q, k, v, acc, m, l, *, scale: float):
    """One ring-attention step: fold K/V chunk (k, v) into (acc, m, l).

    Shapes: q/acc (Sq, d), k/v (Sk, d), m/l (Sq,). Returns (acc', m', l').
    """
    sq, d = q.shape
    bq = min(BLOCK_Q, sq)
    assert sq % bq == 0
    grid = (sq // bq,)
    qspec = pl.BlockSpec((bq, d), lambda i: (i, 0))
    kvspec = pl.BlockSpec(k.shape, lambda i: (0, 0))
    vecspec = pl.BlockSpec((bq,), lambda i: (i,))
    kern = functools.partial(_attn_step_kernel, scale=scale)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[qspec, kvspec, kvspec, qspec, vecspec, vecspec],
        out_specs=[qspec, vecspec, vecspec],
        out_shape=[
            jax.ShapeDtypeStruct((sq, d), jnp.float32),
            jax.ShapeDtypeStruct((sq,), jnp.float32),
            jax.ShapeDtypeStruct((sq,), jnp.float32),
        ],
        interpret=True,
    )(q, k, v, acc, m, l)


def _finalize_kernel(acc_ref, l_ref, o_ref):
    o_ref[...] = acc_ref[...] / l_ref[...][:, None]


@jax.jit
def attn_finalize(acc, l):
    """Divide accumulator by softmax denominator: o = acc / l."""
    sq, d = acc.shape
    bq = min(BLOCK_Q, sq)
    grid = (sq // bq,)
    return pl.pallas_call(
        _finalize_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bq, d), lambda i: (i, 0)),
            pl.BlockSpec((bq,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((bq, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((sq, d), jnp.float32),
        interpret=True,
    )(acc, l)


def init_state(sq: int, d: int):
    """Initial (acc, m, l) online-softmax state for a Q shard."""
    return (
        jnp.zeros((sq, d), jnp.float32),
        jnp.full((sq,), NEG_INF, jnp.float32),
        jnp.zeros((sq,), jnp.float32),
    )


def vmem_bytes(block_q: int, block_k: int, d: int, itemsize: int = 4) -> int:
    """VMEM per attn_step grid step: Q, K, V, acc blocks + m/l vectors."""
    mats = (block_q * d) * 2 + (block_k * d) * 2  # q, acc, k, v
    vecs = block_q * 4  # m, l in and out
    scores = block_q * block_k  # s / p intermediate
    return (mats + vecs + scores) * itemsize
