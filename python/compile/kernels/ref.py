"""Pure-jnp oracles for every L1 kernel. No Pallas here by construction —
this file is the correctness ground truth the pytest suite compares against.
"""

from __future__ import annotations

import jax.numpy as jnp


def gemm(a, b):
    """(M, K) @ (K, N) in f32."""
    return jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32))


def gelu(x):
    c = jnp.sqrt(2.0 / jnp.pi).astype(x.dtype)
    return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x * x * x)))


def gemm_bias_gelu(a, b, bias):
    return gelu(gemm(a, b) + bias[None, :])


def attention(q, k, v, scale: float):
    """Full (unchunked) softmax attention — oracle for the ring composition."""
    s = jnp.dot(q.astype(jnp.float32), k.astype(jnp.float32).T) * scale
    p = jnp.exp(s - jnp.max(s, axis=-1, keepdims=True))
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    return jnp.dot(p, v.astype(jnp.float32))


def attn_step(q, k, v, acc, m, l, scale: float):
    """Online-softmax block update, identical math to the Pallas kernel."""
    s = jnp.dot(q.astype(jnp.float32), k.astype(jnp.float32).T) * scale
    m_cur = jnp.max(s, axis=-1)
    m_new = jnp.maximum(m, m_cur)
    alpha = jnp.exp(m - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_new = alpha * l + jnp.sum(p, axis=-1)
    acc_new = acc * alpha[:, None] + jnp.dot(p, v.astype(jnp.float32))
    return acc_new, m_new, l_new


def attn_finalize(acc, l):
    return acc / l[:, None]


def ffn_shard(x, w1, b1, w2):
    """Per-rank FFN shard: gelu(x @ w1 + b1) @ w2 (partial sum over shards)."""
    return gemm(gemm_bias_gelu(x, w1, b1), w2)
