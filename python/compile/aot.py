"""AOT bridge: lower every L2 entry point to HLO *text* + a manifest.

HLO text (not `.serialize()`d protos) is the interchange format: jax >= 0.5
emits HloModuleProto with 64-bit instruction ids, which the xla_extension
0.5.1 the Rust `xla` crate links against rejects (`proto.id() <= INT_MAX`).
The text parser reassigns ids, so text round-trips cleanly. See
/opt/xla-example/README.md.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts
"""

from __future__ import annotations

import argparse
import json
import os

import jax
from jax._src.lib import xla_client as xc

from compile import model


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec_desc(s) -> dict:
    return {"shape": list(s.shape), "dtype": str(s.dtype)}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {"format": "hlo-text", "return_tuple": True, "entries": {}}
    for name, (fn, example_args) in model.entry_points().items():
        lowered = jax.jit(fn).lower(*example_args)
        text = to_hlo_text(lowered)
        path = f"{name}.hlo.txt"
        with open(os.path.join(args.out_dir, path), "w") as f:
            f.write(text)
        out_specs = jax.eval_shape(fn, *example_args)
        manifest["entries"][name] = {
            "file": path,
            "inputs": [_spec_desc(s) for s in example_args],
            "outputs": [_spec_desc(s) for s in out_specs],
        }
        print(f"  aot: {name} -> {path} ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    # TSV twin of the manifest for the Rust runtime (offline build has no
    # JSON dependency): name \t file \t in specs \t out specs, where a spec
    # list is `;`-joined `dimxdim,dtype` entries.
    def _tsv_specs(specs):
        return ";".join(
            "x".join(str(d) for d in e["shape"]) + "," + e["dtype"] for e in specs
        )

    with open(os.path.join(args.out_dir, "manifest.tsv"), "w") as f:
        for name, ent in manifest["entries"].items():
            f.write(
                f"{name}\t{ent['file']}\t{_tsv_specs(ent['inputs'])}\t"
                f"{_tsv_specs(ent['outputs'])}\n"
            )
    print(f"  aot: manifest.json + manifest.tsv ({len(manifest['entries'])} entries)")


if __name__ == "__main__":
    main()
