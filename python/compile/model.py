"""L2: per-rank JAX compute graphs for Syncopate's distributed operators.

Each entry point here is the *local* compute a rank performs between chunk
arrivals; the L3 Rust coordinator sequences these (per its compiled
ExecutablePlan) and moves the chunks. All entry points call the L1 Pallas
kernels, so the AOT artifacts exercise the full three-layer stack.

Entry points are pure functions over fixed shapes; `aot.py` lowers each to
one HLO-text artifact. The canonical real-numerics shapes are small (CPU
interpret mode); paper-scale shapes are handled analytically by `sim::`.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from compile.kernels import attention as attn_k
from compile.kernels import gemm as gemm_k

# Canonical real-numerics shapes (see DESIGN.md §6).
GEMM_K = 128          # contraction dim of the GEMM family
GEMM_N = 128          # output columns (per-rank weight shard width)
GEMM_TMS = (8, 16, 32, 64, 128)  # chunk row-counts (split-factor variants)

ATTN_SQ = 64          # per-rank query shard length
ATTN_D = 64           # head dim
ATTN_SKS = (16, 32, 64)  # K/V chunk lengths (split-factor variants)
ATTN_SCALE = 1.0 / (ATTN_D ** 0.5)

FFN_M, FFN_D, FFN_F = 64, 128, 64  # per-rank FFN shard shapes


def gemm_chunk(a, b):
    """Chunk-granular GEMM: one communicated chunk of rows x local weights.

    This is what a rank runs each time an AG-GEMM / A2A-GEMM input chunk
    lands, and each time GEMM-RS / GEMM-AR produces an output chunk.
    """
    return (gemm_k.gemm(a, b),)


def attn_ring_step(q, k, v, acc, m, l):
    """One Ring-Attention step: fold the K/V chunk from the ring peer."""
    acc2, m2, l2 = attn_k.attn_step(q, k, v, acc, m, l, scale=ATTN_SCALE)
    return (acc2, m2, l2)


def attn_finalize(acc, l):
    """Final o = acc / l once all ring chunks are folded."""
    return (attn_k.attn_finalize(acc, l),)


def ffn_shard(x, w1, b1, w2):
    """Tensor-parallel FFN shard: gelu(x @ w1 + b1) @ w2 (partial sum)."""
    h = gemm_k.gemm_bias_gelu(x, w1, b1)
    return (gemm_k.gemm(h, w2),)


def add(x, y):
    """Reduction combiner (the switch/fibre accumulate of Fig. 4d)."""
    return (x + y,)


def _f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def entry_points():
    """name -> (fn, example_args). One AOT artifact per entry."""
    eps = {}
    for tm in GEMM_TMS:
        eps[f"gemm_{tm}x{GEMM_K}x{GEMM_N}"] = (
            gemm_chunk,
            (_f32(tm, GEMM_K), _f32(GEMM_K, GEMM_N)),
        )
    for sk in ATTN_SKS:
        eps[f"attn_step_q{ATTN_SQ}d{ATTN_D}k{sk}"] = (
            attn_ring_step,
            (
                _f32(ATTN_SQ, ATTN_D),
                _f32(sk, ATTN_D),
                _f32(sk, ATTN_D),
                _f32(ATTN_SQ, ATTN_D),
                _f32(ATTN_SQ),
                _f32(ATTN_SQ),
            ),
        )
    eps[f"attn_finalize_q{ATTN_SQ}d{ATTN_D}"] = (
        attn_finalize,
        (_f32(ATTN_SQ, ATTN_D), _f32(ATTN_SQ)),
    )
    eps[f"ffn_shard_{FFN_M}x{FFN_D}x{FFN_F}"] = (
        ffn_shard,
        (_f32(FFN_M, FFN_D), _f32(FFN_D, FFN_F), _f32(FFN_F), _f32(FFN_F, FFN_D)),
    )
    eps[f"add_{ATTN_SQ}x{ATTN_D}"] = (add, (_f32(ATTN_SQ, ATTN_D), _f32(ATTN_SQ, ATTN_D)))
    eps[f"add_{FFN_M}x{FFN_D}"] = (add, (_f32(FFN_M, FFN_D), _f32(FFN_M, FFN_D)))
    eps[f"add_{GEMM_TMS[-1]}x{GEMM_N}"] = (
        add,
        (_f32(GEMM_TMS[-1], GEMM_N), _f32(GEMM_TMS[-1], GEMM_N)),
    )
    return eps
