//! Critical-path extraction over captured traces.
//!
//! The dependency DAG is rebuilt *structurally* from timestamp-free event
//! identity: every non-kernel event is a node (kernel spans nest inside
//! their compute segment and would double-bill), consecutive plan ops on
//! one rank are program-order edges, and the transfer that raises a
//! signal precedes every wait on that signal. Node weights for the
//! longest-path extraction come from event CONTENT only — the reference
//! [`crate::backend::curve`] for transfers, a nominal compute rate for
//! segments, zero for waits — so both exec engines extract the *same*
//! critical op sequence from their traces of one prepared plan. Measured
//! timestamps of a path chosen from measured timestamps could never be
//! engine-stable: the sequential engine serializes everything, so its
//! measured critical path is the whole program.
//!
//! Blame then projects THIS run's measured timestamps onto the structural
//! path with a cursor sweep: walking the path in order, time between the
//! cursor and a node's start is a *scheduling gap*, the node's span beyond
//! the cursor is *work* blamed to its kind (compute / comm backend /
//! wait), and the tail after the last node is scheduling again. The three
//! buckets plus gaps sum to the wall makespan exactly (up to f64
//! rounding) — sequential traces honestly show most of the makespan as
//! scheduling gap, because nothing in a serialized run is on the modeled
//! dependency-critical chain for its full duration.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap};

use crate::backend::{self, BackendKind};
use crate::error::{Error, Result};
use crate::metrics::Table;
use crate::topo::Topology;
use crate::trace::{Trace, TraceKind};
use crate::util::json_escape as esc;

/// Nominal device compute rate (TFLOPS) for the model weights. Only
/// *relative* weights matter for path extraction; this constant just puts
/// compute on the same µs axis as the reference transfer curves.
pub const NOMINAL_TFLOPS: f64 = 100.0;

/// What a critical node's measured span is blamed on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BlameKind {
    Compute,
    Comm,
    Wait,
}

impl BlameKind {
    pub fn name(self) -> &'static str {
        match self {
            BlameKind::Compute => "compute",
            BlameKind::Comm => "comm",
            BlameKind::Wait => "wait",
        }
    }
}

/// One node of the extracted critical path, in path order.
#[derive(Debug, Clone)]
pub struct CriticalNode {
    /// Index into the source trace's `events`.
    pub event: usize,
    /// Timestamp-free identity ([`crate::trace::TraceEvent::key`]) — the
    /// engine-stable sequence tests compare, and the overlay export's
    /// highlight set.
    pub key: String,
    pub rank: usize,
    pub op: usize,
    pub kind: BlameKind,
    /// Comm backend for transfer nodes.
    pub backend: Option<BackendKind>,
    /// Model weight used for extraction (µs, deterministic).
    pub weight_us: f64,
    /// Measured span (µs, this trace).
    pub start_us: f64,
    pub end_us: f64,
    /// Cursor-sweep scheduling gap blamed immediately before this node.
    pub sched_us: f64,
    /// Cursor-sweep span blamed to the node itself.
    pub work_us: f64,
}

/// Blame decomposition of the wall makespan (all µs; sums to the
/// makespan by construction).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Blame {
    pub compute_us: f64,
    pub comm_us: f64,
    pub wait_us: f64,
    /// Scheduling gaps: makespan time when the modeled critical chain was
    /// not running (engine noise, serialization, off-path stragglers).
    pub sched_us: f64,
    /// `comm_us` split by backend, in [`BackendKind::index`] order.
    pub per_backend: Vec<(BackendKind, f64)>,
}

impl Blame {
    pub fn total_us(&self) -> f64 {
        self.compute_us + self.comm_us + self.wait_us + self.sched_us
    }
}

/// A completed critical-path extraction.
#[derive(Debug, Clone)]
pub struct CriticalPath {
    /// Measured wall makespan (latest end − earliest start), the quantity
    /// blame decomposes.
    pub wall_makespan_us: f64,
    /// Total model weight of the extracted path (µs on the model axis —
    /// comparable between traces of one plan, not to the measured wall).
    pub model_path_us: f64,
    pub nodes: Vec<CriticalNode>,
    pub blame: Blame,
}

/// Deterministic model weight (µs) for one event — content only, no
/// timestamps (see module doc).
fn model_weight(kind: &TraceKind) -> f64 {
    match kind {
        TraceKind::Transfer { bytes, pieces, backend, comm_sms, .. } => {
            let c = backend::curve(*backend);
            let host = backend::caps(*backend).host_launched;
            let launches = if host { (*pieces).max(1) } else { 1 } as f64;
            let x = (*bytes as f64 / launches).max(1.0);
            let r = if c.sms_for_peak == 0 {
                1.0
            } else {
                (*comm_sms as f64 / c.sms_for_peak as f64).clamp(1e-3, 1.0)
            };
            // unclamped reference curve: no link is available (or needed —
            // only relative weights steer the extraction)
            let bw = c.peak_gbps * (x / (x + c.half_size)) * r;
            launches * c.issue_us + *bytes as f64 / (bw * 1e3)
        }
        TraceKind::Compute { flops, .. } => flops / (NOMINAL_TFLOPS * 1e6),
        TraceKind::Wait { .. } | TraceKind::Kernel { .. } => 0.0,
    }
}

/// Extract the critical path of a captured trace (see module doc).
///
/// Errors only when the reconstructed dependency graph has a cycle — a
/// trace no execution could have produced.
pub fn critical_path(trace: &Trace) -> Result<CriticalPath> {
    // -- nodes: non-kernel events, keyed by (rank, plan-op index) --------
    let mut ev_idx: Vec<usize> = Vec::new();
    let mut order: Vec<(usize, usize)> = Vec::new();
    for (i, ev) in trace.events.iter().enumerate() {
        let (rank, op) = match &ev.kind {
            TraceKind::Kernel { .. } => continue,
            TraceKind::Transfer { src, op, .. } => (*src, *op),
            TraceKind::Wait { rank, op, .. } => (*rank, *op),
            TraceKind::Compute { rank, op, .. } => (*rank, *op),
        };
        ev_idx.push(i);
        order.push((rank, op));
    }
    let n = ev_idx.len();

    // -- edges -----------------------------------------------------------
    let mut preds: Vec<Vec<usize>> = vec![Vec::new(); n];
    let mut by_rank: HashMap<usize, Vec<usize>> = HashMap::new();
    for v in 0..n {
        by_rank.entry(order[v].0).or_default().push(v);
    }
    for chain in by_rank.values_mut() {
        chain.sort_by_key(|&v| (order[v].1, ev_idx[v]));
        for w in chain.windows(2) {
            preds[w[1]].push(w[0]);
        }
    }
    let mut producer: HashMap<usize, usize> = HashMap::new();
    for v in 0..n {
        if let TraceKind::Transfer { signal, .. } = &trace.events[ev_idx[v]].kind {
            producer.insert(*signal, v);
        }
    }
    for v in 0..n {
        if let TraceKind::Wait { signal, .. } = &trace.events[ev_idx[v]].kind {
            // waits on internal call signals have no transfer producer —
            // those are gated by program order alone
            if let Some(&p) = producer.get(signal) {
                if p != v {
                    preds[v].push(p);
                }
            }
        }
    }

    // -- deterministic topological order (Kahn, min-(rank,op) heap) ------
    let mut indeg = vec![0usize; n];
    let mut succs: Vec<Vec<usize>> = vec![Vec::new(); n];
    for v in 0..n {
        for &p in &preds[v] {
            succs[p].push(v);
            indeg[v] += 1;
        }
    }
    let mut heap = BinaryHeap::new();
    for v in 0..n {
        if indeg[v] == 0 {
            heap.push(Reverse((order[v], v)));
        }
    }
    let mut topo_order = Vec::with_capacity(n);
    while let Some(Reverse((_, v))) = heap.pop() {
        topo_order.push(v);
        for &s in &succs[v] {
            indeg[s] -= 1;
            if indeg[s] == 0 {
                heap.push(Reverse((order[s], s)));
            }
        }
    }
    if topo_order.len() < n {
        return Err(Error::Trace(format!(
            "trace dependency graph has a cycle ({} of {n} events orderable) — \
             no execution can have produced this trace",
            topo_order.len()
        )));
    }

    // -- longest model-weighted path (deterministic tie-breaks) ----------
    let weight: Vec<f64> = ev_idx.iter().map(|&i| model_weight(&trace.events[i].kind)).collect();
    let mut best = vec![0.0f64; n];
    let mut choice: Vec<Option<usize>> = vec![None; n];
    for &v in &topo_order {
        let mut c: Option<usize> = None;
        for &p in &preds[v] {
            let replace = match c {
                None => true,
                Some(cur) => {
                    best[p] > best[cur]
                        || (best[p] == best[cur] && (order[p], p) < (order[cur], cur))
                }
            };
            if replace {
                c = Some(p);
            }
        }
        best[v] = weight[v] + c.map_or(0.0, |p| best[p]);
        choice[v] = c;
    }
    let end = (0..n).max_by(|&a, &b| {
        best[a]
            .total_cmp(&best[b])
            // ties: prefer the smaller (rank, op) — Reverse flips it so
            // max_by still lands there
            .then_with(|| (Reverse(order[a]), Reverse(a)).cmp(&(Reverse(order[b]), Reverse(b))))
    });
    let mut path = Vec::new();
    let mut cur = end;
    while let Some(v) = cur {
        path.push(v);
        cur = choice[v];
    }
    path.reverse();

    // -- blame: project measured time onto the structural path -----------
    let (t0, t_end) = if trace.events.is_empty() {
        (0.0, 0.0)
    } else {
        (
            trace.events.iter().map(|e| e.start_us).fold(f64::INFINITY, f64::min),
            trace.events.iter().map(|e| e.end_us).fold(f64::NEG_INFINITY, f64::max),
        )
    };
    let wall = (t_end - t0).max(0.0);
    let mut cursor = t0;
    let mut blame = Blame::default();
    let mut nodes = Vec::with_capacity(path.len());
    for &v in &path {
        let ev = &trace.events[ev_idx[v]];
        let gap = (ev.start_us - cursor).max(0.0);
        let work = (ev.end_us - ev.start_us.max(cursor)).max(0.0);
        cursor = cursor.max(ev.end_us);
        blame.sched_us += gap;
        let (kind, backend) = match &ev.kind {
            TraceKind::Transfer { backend, .. } => (BlameKind::Comm, Some(*backend)),
            TraceKind::Wait { .. } => (BlameKind::Wait, None),
            TraceKind::Compute { .. } => (BlameKind::Compute, None),
            TraceKind::Kernel { .. } => unreachable!("kernels are not DAG nodes"),
        };
        match kind {
            BlameKind::Compute => blame.compute_us += work,
            BlameKind::Wait => blame.wait_us += work,
            BlameKind::Comm => {
                blame.comm_us += work;
                let b = backend.expect("comm nodes carry a backend");
                match blame.per_backend.iter_mut().find(|(k, _)| *k == b) {
                    Some((_, t)) => *t += work,
                    None => blame.per_backend.push((b, work)),
                }
            }
        }
        nodes.push(CriticalNode {
            event: ev_idx[v],
            key: ev.key(),
            rank: order[v].0,
            op: order[v].1,
            kind,
            backend,
            weight_us: weight[v],
            start_us: ev.start_us,
            end_us: ev.end_us,
            sched_us: gap,
            work_us: work,
        });
    }
    blame.sched_us += (t_end - cursor).max(0.0);
    blame.per_backend.sort_by_key(|(b, _)| b.index());

    Ok(CriticalPath {
        wall_makespan_us: wall,
        model_path_us: end.map_or(0.0, |v| best[v]),
        nodes,
        blame,
    })
}

/// A what-if verdict: the bound on makespan if every critical comm edge
/// ran under a hypothetical curve (the measured analogue of `analysis`
/// rule SY-W203). An *upper* bound on achievable speedup — a different
/// path may become critical once these edges shrink.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WhatIf {
    pub makespan_us: f64,
    /// Lower bound on the hypothetical makespan.
    pub bound_us: f64,
    pub saved_us: f64,
    /// `makespan / bound` (∞ when comm was the entire makespan).
    pub speedup_bound: f64,
}

impl CriticalPath {
    /// Timestamp-free keys of the path nodes, in path order — the
    /// engine-stable critical op sequence.
    pub fn keys(&self) -> Vec<String> {
        self.nodes.iter().map(|n| n.key.clone()).collect()
    }

    /// Blame summary table (paper-style).
    pub fn table(&self) -> Table {
        let mut t = Table::new(
            "Critical path: blame decomposition (sums to wall makespan)",
            &["blame us", "share %"],
            "us | %",
        );
        let wall = self.wall_makespan_us.max(f64::MIN_POSITIVE);
        for (label, v) in [
            ("compute", self.blame.compute_us),
            ("comm", self.blame.comm_us),
            ("wait", self.blame.wait_us),
            ("sched gap", self.blame.sched_us),
        ] {
            t.push_row(label, vec![v, 100.0 * v / wall]);
        }
        for (b, v) in &self.blame.per_backend {
            t.push_row(&format!("comm[{}]", b.name()), vec![*v, 100.0 * *v / wall]);
        }
        t.push_row(
            "wall makespan",
            vec![self.wall_makespan_us, 100.0 * self.blame.total_us() / wall],
        );
        t
    }

    /// `syncopate.critical.v1` JSON: the blame decomposition plus the full
    /// path with per-node measured spans and blame.
    pub fn to_json(&self) -> String {
        let mut out = String::from("{\n  \"schema\": \"syncopate.critical.v1\",\n");
        out.push_str(&format!("  \"wall_makespan_us\": {},\n", self.wall_makespan_us));
        out.push_str(&format!("  \"model_path_us\": {},\n", self.model_path_us));
        out.push_str(&format!(
            "  \"blame\": {{\"compute_us\": {}, \"comm_us\": {}, \"wait_us\": {}, \
             \"sched_us\": {}, \"per_backend\": {{",
            self.blame.compute_us, self.blame.comm_us, self.blame.wait_us, self.blame.sched_us
        ));
        for (i, (b, v)) in self.blame.per_backend.iter().enumerate() {
            if i > 0 {
                out.push_str(", ");
            }
            out.push_str(&format!("\"{}\": {v}", b.name()));
        }
        out.push_str("}},\n  \"path\": [\n");
        let rows: Vec<String> = self
            .nodes
            .iter()
            .map(|nd| {
                format!(
                    "    {{\"key\": \"{}\", \"kind\": \"{}\", \"rank\": {}, \"op\": {}, \
                     \"start_us\": {}, \"end_us\": {}, \"weight_us\": {}, \"sched_us\": {}, \
                     \"work_us\": {}}}",
                    esc(&nd.key),
                    nd.kind.name(),
                    nd.rank,
                    nd.op,
                    nd.start_us,
                    nd.end_us,
                    nd.weight_us,
                    nd.sched_us,
                    nd.work_us
                )
            })
            .collect();
        out.push_str(&rows.join(",\n"));
        out.push_str("\n  ]\n}\n");
        out
    }

    /// What-if under a concrete topology: every critical transfer is
    /// re-priced by the target arch's curve over the actual link, and the
    /// saving against its measured blame (never negative — a slower
    /// hypothesis cannot stretch a bound) is credited to the makespan.
    pub fn what_if_topo(&self, trace: &Trace, topo: &Topology) -> Result<WhatIf> {
        let mut saved = 0.0;
        for nd in &self.nodes {
            let TraceKind::Transfer { src, dst, bytes, pieces, backend, comm_sms, .. } =
                &trace.events[nd.event].kind
            else {
                continue;
            };
            let link = topo.link(*src, *dst)?;
            let c = topo.arch.curve(*backend);
            let caps = topo.arch.caps(*backend);
            let h = backend::transfer_time_with(
                c,
                caps.host_launched,
                *bytes,
                *pieces,
                *comm_sms,
                link,
            );
            saved += (nd.work_us - h).max(0.0);
        }
        Ok(self.bound(saved))
    }

    /// What-if under a uniform comm scale factor (`0.5` = "comm twice as
    /// fast").
    pub fn what_if_scale(&self, comm_scale: f64) -> WhatIf {
        let scale = comm_scale.max(0.0);
        let saved = self
            .nodes
            .iter()
            .filter(|nd| nd.kind == BlameKind::Comm)
            .map(|nd| (nd.work_us - nd.work_us * scale).max(0.0))
            .sum();
        self.bound(saved)
    }

    fn bound(&self, saved_us: f64) -> WhatIf {
        let saved = saved_us.min(self.wall_makespan_us);
        let bound = (self.wall_makespan_us - saved).max(0.0);
        WhatIf {
            makespan_us: self.wall_makespan_us,
            bound_us: bound,
            saved_us: saved,
            speedup_bound: if bound > 0.0 { self.wall_makespan_us / bound } else { f64::INFINITY },
        }
    }
}

/// Export the blame decomposition as process gauges
/// (`perf.critical_{compute,comm,wait,sched}_us`) — the serving tier
/// feeds these from sampled traced requests.
pub fn record_gauges(path: &CriticalPath) {
    crate::obs::gauge("perf.critical_compute_us").set(path.blame.compute_us);
    crate::obs::gauge("perf.critical_comm_us").set(path.blame.comm_us);
    crate::obs::gauge("perf.critical_wait_us").set(path.blame.wait_us);
    crate::obs::gauge("perf.critical_sched_us").set(path.blame.sched_us);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::TraceEvent;

    fn seg(rank: usize, op: usize, flops: f64, start: f64, end: f64) -> TraceEvent {
        TraceEvent {
            start_us: start,
            end_us: end,
            kind: TraceKind::Compute { rank, op, calls: 1, tiles: 1, flops, quantized: false },
        }
    }

    fn xfer(src: usize, dst: usize, op: usize, signal: usize, bytes: usize, s: f64, e: f64) -> TraceEvent {
        TraceEvent {
            start_us: s,
            end_us: e,
            kind: TraceKind::Transfer {
                src,
                dst,
                op,
                bytes,
                pieces: 1,
                backend: BackendKind::CopyEngine,
                comm_sms: 0,
                reduce: false,
                signal,
            },
        }
    }

    fn wait(rank: usize, op: usize, signal: usize, s: f64, e: f64) -> TraceEvent {
        TraceEvent { start_us: s, end_us: e, kind: TraceKind::Wait { rank, op, signal } }
    }

    fn trace(world: usize, events: Vec<TraceEvent>) -> Trace {
        Trace { world, fingerprint: String::new(), meta: vec![], events }
    }

    // rank 0: big compute (op 0), transfer sig0 (op 1);
    // rank 1: wait sig0 (op 0), small compute (op 1)
    fn cross_rank() -> Trace {
        trace(
            2,
            vec![
                seg(0, 0, 1e9, 0.0, 10.0),
                xfer(0, 1, 1, 0, 1 << 20, 10.0, 14.0),
                wait(1, 0, 0, 0.0, 14.0),
                seg(1, 1, 1e6, 14.0, 15.0),
            ],
        )
    }

    #[test]
    fn path_follows_the_dependency_chain() {
        let p = critical_path(&cross_rank()).unwrap();
        let keys = p.keys();
        assert_eq!(keys.len(), 4, "{keys:?}");
        assert!(keys[0].starts_with("seg r0"), "{keys:?}");
        assert!(keys[1].starts_with("xfer sig0"), "{keys:?}");
        assert!(keys[2].starts_with("wait r1"), "{keys:?}");
        assert!(keys[3].starts_with("seg r1"), "{keys:?}");
        assert!(p.model_path_us > 0.0);
    }

    #[test]
    fn blame_sums_to_wall_makespan() {
        let p = critical_path(&cross_rank()).unwrap();
        assert_eq!(p.wall_makespan_us, 15.0);
        assert!((p.blame.total_us() - 15.0).abs() < 1e-9, "{:?}", p.blame);
        // big segment 10, transfer 4, small segment 1; wait fully
        // overlapped by upstream work -> zero wait blame
        assert!((p.blame.compute_us - 11.0).abs() < 1e-9, "{:?}", p.blame);
        assert!((p.blame.comm_us - 4.0).abs() < 1e-9, "{:?}", p.blame);
        assert_eq!(p.blame.wait_us, 0.0);
        assert_eq!(p.blame.per_backend.len(), 1);
        assert_eq!(p.blame.per_backend[0].0, BackendKind::CopyEngine);
    }

    #[test]
    fn extraction_ignores_timestamps() {
        // same structure, wildly different (serialized) timestamps:
        // identical key sequence
        let a = critical_path(&cross_rank()).unwrap();
        let serialized = trace(
            2,
            vec![
                seg(0, 0, 1e9, 0.0, 10.0),
                xfer(0, 1, 1, 0, 1 << 20, 10.0, 14.0),
                wait(1, 0, 0, 14.0, 14.5),
                seg(1, 1, 1e6, 20.0, 21.0),
            ],
        );
        let b = critical_path(&serialized).unwrap();
        assert_eq!(a.keys(), b.keys());
        // the late straggler start shows up as scheduling gap, and blame
        // still sums to the (longer) wall
        assert!(b.blame.sched_us > 0.0);
        assert!((b.blame.total_us() - b.wall_makespan_us).abs() < 1e-9);
    }

    #[test]
    fn heavier_branch_wins() {
        // two independent chains on one rank pair; the heavier-flops chain
        // must be chosen even though the light one runs longer (measured)
        let t = trace(
            2,
            vec![
                seg(0, 0, 1e9, 0.0, 2.0),
                seg(1, 0, 1e3, 0.0, 50.0),
            ],
        );
        let p = critical_path(&t).unwrap();
        assert_eq!(p.nodes.len(), 1);
        assert_eq!(p.nodes[0].rank, 0, "model weight, not measured span, picks the path");
        // ...while blame still accounts for the full wall (rank 1's slow
        // span off the path lands in sched)
        assert!((p.blame.total_us() - 50.0).abs() < 1e-9);
        assert!(p.blame.sched_us > 0.0);
    }

    #[test]
    fn cycle_is_an_error_and_empty_trace_is_not() {
        // rank 0 waits on a signal its OWN later op produces
        let t = trace(
            1,
            vec![wait(0, 0, 7, 0.0, 1.0), xfer(0, 0, 1, 7, 64, 1.0, 2.0)],
        );
        // wait(op 0) precedes issue(op 1) in program order, but the signal
        // edge points issue -> wait: a cycle
        let e = critical_path(&t).unwrap_err();
        assert!(e.to_string().contains("cycle"), "{e}");

        let p = critical_path(&trace(2, vec![])).unwrap();
        assert_eq!(p.wall_makespan_us, 0.0);
        assert!(p.nodes.is_empty());
        assert_eq!(p.blame.total_us(), 0.0);
    }

    #[test]
    fn what_if_scale_bounds_speedup() {
        let p = critical_path(&cross_rank()).unwrap();
        let w = p.what_if_scale(0.5);
        // 4us comm blame, half saved -> bound 13us
        assert!((w.saved_us - 2.0).abs() < 1e-9, "{w:?}");
        assert!((w.bound_us - 13.0).abs() < 1e-9);
        assert!((w.speedup_bound - 15.0 / 13.0).abs() < 1e-9);
        // free comm cannot save more than the comm blame
        let all = p.what_if_scale(0.0);
        assert!((all.saved_us - 4.0).abs() < 1e-9);
        // slower comm saves nothing
        let none = p.what_if_scale(2.0);
        assert_eq!(none.saved_us, 0.0);
        assert_eq!(none.speedup_bound, 1.0);
    }

    #[test]
    fn what_if_topo_prices_critical_transfers() {
        let t = cross_rank();
        let p = critical_path(&t).unwrap();
        let topo = crate::hw::catalog::topology("h100_node", 2).unwrap();
        let w = p.what_if_topo(&t, &topo).unwrap();
        // saving is clamped to [0, comm blame] whatever the target curve
        // prices the critical transfer at
        assert!(w.saved_us >= 0.0 && w.saved_us <= p.blame.comm_us + 1e-9, "{w:?}");
        assert!(w.bound_us <= w.makespan_us);
    }

    #[test]
    fn json_and_table_render() {
        let p = critical_path(&cross_rank()).unwrap();
        let j = p.to_json();
        assert!(j.contains("syncopate.critical.v1"), "{j}");
        assert!(j.contains("\"path\": ["));
        assert!(j.contains("copy-engine"));
        // machine-parseable (hand-rolled JSON stays valid)
        crate::trace::check_chrome_header(&j).unwrap_err(); // not a chrome trace...
        let t = p.table().render();
        assert!(t.contains("sched gap"), "{t}");
        assert!(t.contains("wall makespan"));
        record_gauges(&p);
        let snap = crate::obs::registry().snapshot();
        assert_eq!(snap.gauge("perf.critical_comm_us", &[]), Some(p.blame.comm_us));
    }
}
