//! Critical-path profiling + continuous perf regression tracking
//! (DESIGN.md §19).
//!
//! Everything before this module measured *aggregates*: per-rank
//! comm/wait/busy totals ([`crate::trace::analyze`]), serving histograms
//! ([`crate::obs`]). None of it answers the two questions a perf
//! investigation actually starts with:
//!
//! * **Which chain of chunks set the makespan?** — [`critical`]
//!   reconstructs the dependency DAG from a captured [`crate::trace::Trace`]
//!   (per-rank program order + transfer→wait signal edges), extracts the
//!   longest model-weighted path, and projects the run's measured
//!   timestamps onto it so every microsecond of the wall makespan is
//!   blamed on compute, a comm backend, an exposed wait, or a scheduling
//!   gap. Blame sums to the makespan by construction; the extraction
//!   itself is engine-stable because the path is chosen on weights
//!   derived from event *content*, never timestamps.
//! * **Did this change regress?** — [`baseline`] holds noise-aware
//!   baselines (median + MAD per case/world/engine, keyed by
//!   [`crate::hw::fingerprint`]), the `perf diff`/`perf gate` significance
//!   rule, and the append-only `BENCH_results.json` trajectory every
//!   `perf record`, `exec --repeat --bench`, and hotpath bench run feeds.

pub mod baseline;
pub mod critical;

pub use baseline::{
    append_bench_row, bench_row, diff, diff_table, median_mad, regressions, Baseline, DiffRow,
    PerfCase, BENCH_SCHEMA, PERF_SCHEMA,
};
pub use critical::{
    critical_path, record_gauges, Blame, BlameKind, CriticalNode, CriticalPath, WhatIf,
};
