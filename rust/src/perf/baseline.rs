//! Noise-aware perf baselines and the regression gate.
//!
//! `perf record` runs every registry case N times per engine through the
//! reusable hot path and summarizes each (case, world, engine) cell as
//! **median + MAD** — robust statistics, because wall-clock samples on a
//! shared machine are contaminated by one-sided outliers that would drag
//! a mean/stddev summary around. Baselines are `syncopate.perf.v1` JSON
//! keyed by [`crate::hw::fingerprint`].
//!
//! The gate rule (`perf diff` / `perf gate`) flags a cell as a regression
//! only when ALL of:
//! 1. the hardware fingerprints match (comparing across machines is a
//!    topology question, not a regression),
//! 2. the relative slowdown exceeds the threshold (`--max-regress`), and
//! 3. the absolute delta clears the noise band `3·(MAD_base + MAD_new)` —
//!    a change smaller than the run-to-run scatter is not evidence.
//!
//! Every recording also appends one row to the repo-root
//! `BENCH_results.json` trajectory (`syncopate.bench.v1`, append-only):
//! the long-term history CI artifacts accumulate, with `perf record`,
//! `exec --repeat --bench`, and the hotpath bench all feeding the same
//! file through [`append_bench_row`].

use crate::error::{Error, Result};
use crate::metrics::Table;
use crate::trace::json::{self, Json};
use crate::util::json_escape as esc;

pub const PERF_SCHEMA: &str = "syncopate.perf.v1";
pub const BENCH_SCHEMA: &str = "syncopate.bench.v1";

/// One baseline cell: robust summary of N samples of one case on one
/// engine at one world size.
#[derive(Debug, Clone, PartialEq)]
pub struct PerfCase {
    pub case: String,
    pub world: usize,
    pub engine: String,
    /// [`crate::hw::fingerprint`] of the topology the samples ran on.
    pub fingerprint: String,
    pub samples: usize,
    pub median_us: f64,
    pub mad_us: f64,
}

/// A recorded baseline: one cell per (case, world, engine).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Baseline {
    pub cases: Vec<PerfCase>,
}

/// Median and median-absolute-deviation of a sample set (`(0, 0)` for an
/// empty set).
pub fn median_mad(samples: &[f64]) -> (f64, f64) {
    if samples.is_empty() {
        return (0.0, 0.0);
    }
    let med = median_of(samples.to_vec());
    let dev: Vec<f64> = samples.iter().map(|s| (s - med).abs()).collect();
    (med, median_of(dev))
}

fn median_of(mut v: Vec<f64>) -> f64 {
    v.sort_by(f64::total_cmp);
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

impl Baseline {
    /// Insert a cell, replacing any existing (case, world, engine) entry;
    /// kept sorted so serialized baselines diff cleanly.
    pub fn insert(&mut self, c: PerfCase) {
        match self
            .cases
            .iter_mut()
            .find(|e| e.case == c.case && e.world == c.world && e.engine == c.engine)
        {
            Some(e) => *e = c,
            None => self.cases.push(c),
        }
        self.cases
            .sort_by(|a, b| (&a.case, a.world, &a.engine).cmp(&(&b.case, b.world, &b.engine)));
    }

    pub fn find(&self, case: &str, world: usize, engine: &str) -> Option<&PerfCase> {
        self.cases
            .iter()
            .find(|e| e.case == case && e.world == world && e.engine == engine)
    }

    pub fn to_json(&self) -> String {
        let mut out = format!("{{\n  \"schema\": \"{PERF_SCHEMA}\",\n  \"cases\": [\n");
        let rows: Vec<String> = self
            .cases
            .iter()
            .map(|c| {
                format!(
                    "    {{\"case\": \"{}\", \"world\": {}, \"engine\": \"{}\", \
                     \"fingerprint\": \"{}\", \"samples\": {}, \"median_us\": {}, \
                     \"mad_us\": {}}}",
                    esc(&c.case),
                    c.world,
                    esc(&c.engine),
                    esc(&c.fingerprint),
                    c.samples,
                    c.median_us,
                    c.mad_us
                )
            })
            .collect();
        out.push_str(&rows.join(",\n"));
        out.push_str("\n  ]\n}\n");
        out
    }

    pub fn from_json(text: &str) -> Result<Baseline> {
        let doc = json::parse(text)?;
        let schema = doc.get("schema").and_then(Json::as_str).unwrap_or("");
        if schema != PERF_SCHEMA {
            return Err(Error::Trace(format!(
                "not a {PERF_SCHEMA} baseline (schema `{schema}`)"
            )));
        }
        let cells = doc
            .get("cases")
            .and_then(Json::as_arr)
            .ok_or_else(|| Error::Trace("baseline: missing `cases` array".into()))?;
        let mut out = Baseline::default();
        for (i, c) in cells.iter().enumerate() {
            let field = |k: &str| {
                c.get(k)
                    .ok_or_else(|| Error::Trace(format!("baseline case {i}: missing `{k}`")))
            };
            out.insert(PerfCase {
                case: field("case")?
                    .as_str()
                    .ok_or_else(|| Error::Trace(format!("baseline case {i}: bad `case`")))?
                    .to_string(),
                world: field("world")?
                    .as_usize()
                    .ok_or_else(|| Error::Trace(format!("baseline case {i}: bad `world`")))?,
                engine: field("engine")?
                    .as_str()
                    .ok_or_else(|| Error::Trace(format!("baseline case {i}: bad `engine`")))?
                    .to_string(),
                fingerprint: field("fingerprint")?
                    .as_str()
                    .ok_or_else(|| Error::Trace(format!("baseline case {i}: bad `fingerprint`")))?
                    .to_string(),
                samples: field("samples")?
                    .as_usize()
                    .ok_or_else(|| Error::Trace(format!("baseline case {i}: bad `samples`")))?,
                median_us: field("median_us")?
                    .as_f64()
                    .ok_or_else(|| Error::Trace(format!("baseline case {i}: bad `median_us`")))?,
                mad_us: field("mad_us")?
                    .as_f64()
                    .ok_or_else(|| Error::Trace(format!("baseline case {i}: bad `mad_us`")))?,
            });
        }
        Ok(out)
    }
}

/// One compared cell of `perf diff`.
#[derive(Debug, Clone, PartialEq)]
pub struct DiffRow {
    pub case: String,
    pub world: usize,
    pub engine: String,
    pub base_us: f64,
    pub new_us: f64,
    /// Relative change in percent (positive = slower).
    pub delta_pct: f64,
    /// Noise band `3·(MAD_base + MAD_new)` in µs.
    pub noise_us: f64,
    pub fingerprint_match: bool,
    pub significant: bool,
}

/// Compare baseline `b` (new) against `a` (base); cells present in only
/// one baseline are skipped (nothing to compare).
pub fn diff(a: &Baseline, b: &Baseline, max_regress_pct: f64) -> Vec<DiffRow> {
    let mut rows = Vec::new();
    for n in &b.cases {
        let Some(base) = a.find(&n.case, n.world, &n.engine) else {
            continue;
        };
        let delta = n.median_us - base.median_us;
        let delta_pct = if base.median_us > 0.0 { 100.0 * delta / base.median_us } else { 0.0 };
        let noise_us = 3.0 * (base.mad_us + n.mad_us);
        let fingerprint_match = base.fingerprint == n.fingerprint;
        rows.push(DiffRow {
            case: n.case.clone(),
            world: n.world,
            engine: n.engine.clone(),
            base_us: base.median_us,
            new_us: n.median_us,
            delta_pct,
            noise_us,
            fingerprint_match,
            significant: fingerprint_match && delta_pct > max_regress_pct && delta > noise_us,
        });
    }
    rows
}

/// Number of significant regressions — the gate's exit code driver.
pub fn regressions(rows: &[DiffRow]) -> usize {
    rows.iter().filter(|r| r.significant).count()
}

/// Render a diff as a table (`regress` column: 1 = significant).
pub fn diff_table(rows: &[DiffRow]) -> Table {
    let mut t = Table::new(
        "Perf diff (median us; noise band = 3*(MAD_a + MAD_b))",
        &["base us", "new us", "delta %", "noise us", "regress"],
        "us | %",
    );
    for r in rows {
        t.push_row(
            &format!("{} w{} [{}]", r.case, r.world, r.engine),
            vec![r.base_us, r.new_us, r.delta_pct, r.noise_us, r.significant as usize as f64],
        );
    }
    t
}

/// Render one `BENCH_results.json` row: a flat object of the tool name,
/// string labels, and numeric fields (non-finite values become `null`).
pub fn bench_row(tool: &str, labels: &[(&str, &str)], fields: &[(&str, f64)]) -> String {
    let mut parts = vec![format!("\"tool\": \"{}\"", esc(tool))];
    for (k, v) in labels {
        parts.push(format!("\"{}\": \"{}\"", esc(k), esc(v)));
    }
    for (k, v) in fields {
        if v.is_finite() {
            parts.push(format!("\"{}\": {v}", esc(k)));
        } else {
            parts.push(format!("\"{}\": null", esc(k)));
        }
    }
    format!("{{{}}}", parts.join(", "))
}

/// Append one row to the `syncopate.bench.v1` trajectory at `path`,
/// creating the file when missing. A file in any other format (including
/// the pre-v1 overwrite-style hotpath dump) is replaced by a fresh
/// trajectory — the old content was a snapshot, not a history.
pub fn append_bench_row(path: &str, row: &str) -> Result<()> {
    let fresh = |row: &str| {
        format!(
            "{{\n  \"bench\": \"syncopate\",\n  \"schema\": \"{BENCH_SCHEMA}\",\n  \"runs\": [\n    {row}\n  ]\n}}\n"
        )
    };
    let spliced = match std::fs::read_to_string(path) {
        Ok(old) if old.contains(BENCH_SCHEMA) => match old.rfind("\n  ]\n}") {
            Some(at) => {
                let mut text = old;
                text.insert_str(at, &format!(",\n    {row}"));
                // a malformed hand-edited file must not poison the splice
                if json::parse(&text).is_ok() {
                    text
                } else {
                    fresh(row)
                }
            }
            None => fresh(row),
        },
        _ => fresh(row),
    };
    if let Err(e) = json::parse(&spliced) {
        return Err(Error::Io(format!("bench row is not valid JSON: {e} — row: {row}")));
    }
    std::fs::write(path, &spliced).map_err(|e| Error::Io(format!("write {path}: {e}")))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cell(case: &str, median: f64, mad: f64) -> PerfCase {
        PerfCase {
            case: case.into(),
            world: 4,
            engine: "parallel".into(),
            fingerprint: "fp0".into(),
            samples: 9,
            median_us: median,
            mad_us: mad,
        }
    }

    #[test]
    fn median_and_mad() {
        assert_eq!(median_mad(&[]), (0.0, 0.0));
        assert_eq!(median_mad(&[5.0]), (5.0, 0.0));
        // odd: median 3; deviations [2,1,0,1,2] -> MAD 1
        assert_eq!(median_mad(&[1.0, 2.0, 3.0, 4.0, 5.0]), (3.0, 1.0));
        // even: median 2.5; deviations [1.5,0.5,0.5,1.5] -> MAD 1
        assert_eq!(median_mad(&[4.0, 1.0, 3.0, 2.0]), (2.5, 1.0));
        // a single huge outlier barely moves either statistic
        let (m, d) = median_mad(&[10.0, 10.0, 10.0, 10.0, 1e6]);
        assert_eq!(m, 10.0);
        assert_eq!(d, 0.0);
    }

    #[test]
    fn baseline_round_trips_and_replaces() {
        let mut b = Baseline::default();
        b.insert(cell("tp-block", 100.0, 2.0));
        b.insert(cell("ag-gemm", 50.0, 1.0));
        b.insert(cell("tp-block", 90.0, 2.0)); // replaces, not duplicates
        assert_eq!(b.cases.len(), 2);
        assert_eq!(b.cases[0].case, "ag-gemm", "kept sorted");
        assert_eq!(b.find("tp-block", 4, "parallel").unwrap().median_us, 90.0);
        assert!(b.find("tp-block", 8, "parallel").is_none());

        let back = Baseline::from_json(&b.to_json()).unwrap();
        assert_eq!(back, b);
        assert!(Baseline::from_json("{\"schema\": \"bogus\", \"cases\": []}").is_err());
        assert!(Baseline::from_json("not json").is_err());
    }

    #[test]
    fn doubled_median_is_flagged() {
        let mut a = Baseline::default();
        a.insert(cell("tp-block", 100.0, 2.0));
        let mut b = Baseline::default();
        b.insert(cell("tp-block", 200.0, 2.0)); // injected 2x slowdown
        let rows = diff(&a, &b, 10.0);
        assert_eq!(rows.len(), 1);
        assert!(rows[0].significant, "{rows:?}");
        assert_eq!(rows[0].delta_pct, 100.0);
        assert_eq!(regressions(&rows), 1);
        let t = diff_table(&rows).render();
        assert!(t.contains("tp-block w4 [parallel]"), "{t}");
    }

    #[test]
    fn identical_baselines_report_nothing() {
        let mut a = Baseline::default();
        a.insert(cell("tp-block", 100.0, 2.0));
        a.insert(cell("ag-gemm", 50.0, 1.0));
        let rows = diff(&a, &a.clone(), 5.0);
        assert_eq!(regressions(&rows), 0, "{rows:?}");
    }

    #[test]
    fn noise_band_and_fingerprint_guard() {
        let mut a = Baseline::default();
        a.insert(cell("tp-block", 100.0, 10.0));
        // +20% but within 3*(10+10)=60us of noise: not significant
        let mut b = Baseline::default();
        b.insert(cell("tp-block", 120.0, 10.0));
        assert_eq!(regressions(&diff(&a, &b, 5.0)), 0);
        // same delta with tight MADs: significant
        let mut a2 = Baseline::default();
        a2.insert(cell("tp-block", 100.0, 1.0));
        let mut b2 = Baseline::default();
        b2.insert(cell("tp-block", 120.0, 1.0));
        assert_eq!(regressions(&diff(&a2, &b2, 5.0)), 1);
        // different machine: never significant
        let mut b3 = Baseline::default();
        let mut moved = cell("tp-block", 300.0, 1.0);
        moved.fingerprint = "fp-other".into();
        b3.insert(moved);
        let rows = diff(&a2, &b3, 5.0);
        assert!(!rows[0].fingerprint_match);
        assert_eq!(regressions(&rows), 0);
        // faster is never a regression
        let mut b4 = Baseline::default();
        b4.insert(cell("tp-block", 50.0, 1.0));
        assert_eq!(regressions(&diff(&a2, &b4, 5.0)), 0);
    }

    #[test]
    fn bench_rows_append_and_survive_garbage() {
        let path = std::env::temp_dir().join(format!("syncopate-bench-{}.json", std::process::id()));
        let path = path.to_str().unwrap();
        let _ = std::fs::remove_file(path);

        let row1 = bench_row("perf-record", &[("case", "tp-block")], &[("median_us", 12.5)]);
        append_bench_row(path, &row1).unwrap();
        let row2 = bench_row("exec-repeat", &[("case", "ag-gemm")], &[("p99_us", f64::NAN)]);
        append_bench_row(path, &row2).unwrap();

        let doc = json::parse(&std::fs::read_to_string(path).unwrap()).unwrap();
        assert_eq!(doc.get("schema").and_then(Json::as_str), Some(BENCH_SCHEMA));
        let runs = doc.get("runs").and_then(Json::as_arr).unwrap();
        assert_eq!(runs.len(), 2);
        assert_eq!(runs[0].get("tool").and_then(Json::as_str), Some("perf-record"));
        assert_eq!(runs[0].get("median_us").and_then(Json::as_f64), Some(12.5));
        assert_eq!(runs[1].get("p99_us"), Some(&Json::Null), "non-finite -> null");

        // a legacy overwrite-format file is replaced, not corrupted
        std::fs::write(path, "{\"bench\": \"hotpath\", \"results\": []}").unwrap();
        append_bench_row(path, &row1).unwrap();
        let doc = json::parse(&std::fs::read_to_string(path).unwrap()).unwrap();
        assert_eq!(doc.get("runs").and_then(Json::as_arr).unwrap().len(), 1);
        let _ = std::fs::remove_file(path);
    }
}
