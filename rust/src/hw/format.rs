//! The line-oriented `.topo` hardware-description format.
//!
//! Mirrors the `.sched` discipline of `plan_io` (PR 2): a hand-rolled,
//! dependency-free parser accepting a superset (flexible whitespace, `#`
//! comments, sections in any order), a canonical printer whose output is
//! byte-stable, errors carrying 1-based `line L, col C:` positions, and the
//! round-trip guarantee `parse(print(t)) == t`.
//!
//! Canonical form:
//!
//! ```text
//! topo v1 h100_node
//! nodes 1
//! device sms 132 copy-engines 3 sm-tflops 7.5 switch-reduce
//! link local bw 2000 lat 0.2
//! link intra bw 400 lat 1.5
//! link inter bw 50 lat 5
//! backend copy-engine peak 400 half 4194304 issue 2.5 sms 0 caps contig,host
//! backend ldst-specialized peak 280 half 131072 issue 0.3 sms 32 caps reduce,inter,dedicated
//! ```
//!
//! Semantics: `nodes` is the node count (ranks split evenly at
//! instantiation); `link` rows give per-level unidirectional bandwidth
//! (GB/s) and base latency (µs); `backend` rows are capability-matrix rows —
//! `peak` GB/s, `half` the transfer size in bytes reaching half of peak,
//! `issue` the per-launch (per-piece if `host`) overhead in µs, `sms` the
//! SM count needed for peak (0 = no SM involvement). `caps` flags:
//! `contig` (contiguous-only), `reduce`, `inter` (crosses nodes),
//! `dedicated` (statically reserves SMs), `host` (host-launched); `-` for
//! none. A mechanism with NO row does not exist on the arch and is
//! infeasible everywhere ([`crate::hw::Arch::check_feasible`]).

use crate::backend::{BackendKind, Caps, Curve};
use crate::error::{Error, Result};
use crate::hw::arch::{Arch, BackendEntry};
use crate::hw::desc::TopoDesc;
use crate::topo::{LinkLevel, LinkSpec};

/// `.topo` format version tag.
pub const FORMAT_VERSION: &str = "v1";

/// File extension for topology descriptions.
pub const FILE_EXT: &str = ".topo";

/// Capability flags in canonical order: (token, accessor).
const CAP_FLAGS: [(&str, fn(&Caps) -> bool); 5] = [
    ("contig", |c| c.contiguous_only),
    ("reduce", |c| c.supports_reduce),
    ("inter", |c| c.inter_node),
    ("dedicated", |c| c.dedicated_sms),
    ("host", |c| c.host_launched),
];

/// Valid topology name: `[A-Za-z_][A-Za-z0-9_-]*`.
pub fn is_valid_topo_name(s: &str) -> bool {
    let mut chars = s.chars();
    match chars.next() {
        Some(c) if c.is_ascii_alphabetic() || c == '_' => {}
        _ => return false,
    }
    chars.all(|c| c.is_ascii_alphanumeric() || c == '_' || c == '-')
}

/// Canonical `device ...` line (no newline). Shared by [`print_desc`] and
/// the fingerprint preimage ([`super::desc::describe`]) so the hash covers
/// exactly what the format expresses — same rule as [`backend_line`].
pub fn device_line(sms: usize, copy_engines: usize, sm_tflops: f64, switch_reduce: bool) -> String {
    format!(
        "device sms {sms} copy-engines {copy_engines} sm-tflops {sm_tflops}{}",
        if switch_reduce { " switch-reduce" } else { "" }
    )
}

/// Canonical `link ...` line (no newline); shared like [`device_line`].
pub fn link_line(tag: &str, l: LinkSpec) -> String {
    format!("link {tag} bw {} lat {}", l.bw_gbps, l.lat_us)
}

/// One backend row in canonical line form (no newline). Shared with the
/// fingerprint preimage ([`super::desc::describe`]) so the hash covers
/// exactly what the format expresses.
pub fn backend_line(kind: BackendKind, e: &BackendEntry) -> String {
    let flags: Vec<&str> = CAP_FLAGS
        .iter()
        .filter(|(_, get)| get(&e.caps))
        .map(|(tok, _)| *tok)
        .collect();
    format!(
        "backend {} peak {} half {} issue {} sms {} caps {}",
        kind.name(),
        e.curve.peak_gbps,
        e.curve.half_size,
        e.curve.issue_us,
        e.curve.sms_for_peak,
        if flags.is_empty() { "-".to_string() } else { flags.join(",") }
    )
}

/// Render a description in canonical `.topo` text.
pub fn print_desc(d: &TopoDesc) -> String {
    let mut out = String::new();
    out.push_str(&format!("topo {FORMAT_VERSION} {}\n", d.name));
    out.push_str(&format!("nodes {}\n", d.nodes));
    out.push_str(&device_line(
        d.sms_per_device,
        d.copy_engines_per_device,
        d.sm_tflops,
        d.switch_reduce,
    ));
    out.push('\n');
    for (tag, l) in [("local", d.local), ("intra", d.intra), ("inter", d.inter)] {
        out.push_str(&link_line(tag, l));
        out.push('\n');
    }
    for kind in BackendKind::ALL {
        if let Some(e) = d.arch.entry(kind) {
            out.push_str(&backend_line(kind, &e));
            out.push('\n');
        }
    }
    out
}

/// Parse `.topo` text into a description. Every error carries a
/// `line L, col C:` prefix.
pub fn parse_desc(text: &str) -> Result<TopoDesc> {
    let mut name: Option<String> = None;
    let mut nodes: Option<usize> = None;
    let mut device: Option<(usize, usize, f64, bool)> = None;
    let mut links: [Option<LinkSpec>; 3] = [None, None, None]; // local/intra/inter
    let mut arch: Option<Arch> = None;
    let mut any_backend = false;

    for (i, raw) in text.lines().enumerate() {
        let mut cur = Cur::new(raw, i + 1);
        cur.skip_ws();
        if cur.done() {
            continue; // blank or comment-only line
        }
        let kw_col = cur.col();
        let kw = cur.ident()?;
        if name.is_none() && kw != "topo" {
            return Err(cur.err_at(
                kw_col,
                &format!("expected `topo {FORMAT_VERSION} NAME` header, found `{kw}`"),
            ));
        }
        match kw.as_str() {
            "topo" => {
                if name.is_some() {
                    return Err(cur.err_at(kw_col, "duplicate `topo` header"));
                }
                let ver = cur.ident()?;
                if ver != FORMAT_VERSION {
                    return Err(cur.err_at(
                        kw_col,
                        &format!("unsupported topo version `{ver}` (expected {FORMAT_VERSION})"),
                    ));
                }
                let n_col = cur.col_after_ws();
                let n = cur.ident()?;
                if !is_valid_topo_name(&n) {
                    return Err(cur.err_at(
                        n_col,
                        &format!("invalid topology name `{n}` (want [A-Za-z_][A-Za-z0-9_-]*)"),
                    ));
                }
                cur.end_of_line()?;
                arch = Some(Arch::new(&n));
                name = Some(n);
            }
            "nodes" => {
                if nodes.is_some() {
                    return Err(cur.err_at(kw_col, "duplicate `nodes` line"));
                }
                let n_col = cur.col_after_ws();
                let n = cur.number()?;
                if n == 0 {
                    return Err(cur.err_at(n_col, "nodes must be >= 1"));
                }
                cur.end_of_line()?;
                nodes = Some(n);
            }
            "device" => {
                if device.is_some() {
                    return Err(cur.err_at(kw_col, "duplicate `device` line"));
                }
                cur.keyword("sms")?;
                let s_col = cur.col_after_ws();
                let sms = cur.number()?;
                if sms == 0 {
                    return Err(cur.err_at(s_col, "device sms must be >= 1"));
                }
                cur.keyword("copy-engines")?;
                let c_col = cur.col_after_ws();
                let ce = cur.number()?;
                if ce == 0 {
                    return Err(cur.err_at(c_col, "copy-engines must be >= 1"));
                }
                cur.keyword("sm-tflops")?;
                let t_col = cur.col_after_ws();
                let tf = cur.float()?; // float() guarantees finite
                if tf <= 0.0 {
                    return Err(cur.err_at(t_col, "sm-tflops must be > 0"));
                }
                let sw = cur.opt_keyword("switch-reduce");
                cur.end_of_line()?;
                device = Some((sms, ce, tf, sw));
            }
            "link" => {
                let lv_col = cur.col_after_ws();
                let lv = cur.ident()?;
                let (slot, level) = match lv.as_str() {
                    "local" => (0, LinkLevel::Local),
                    "intra" => (1, LinkLevel::IntraNode),
                    "inter" => (2, LinkLevel::InterNode),
                    other => {
                        return Err(cur.err_at(
                            lv_col,
                            &format!("unknown link level `{other}` (local|intra|inter)"),
                        ))
                    }
                };
                if links[slot].is_some() {
                    return Err(cur.err_at(lv_col, &format!("duplicate `link {lv}` line")));
                }
                cur.keyword("bw")?;
                let b_col = cur.col_after_ws();
                let bw = cur.float()?;
                if bw <= 0.0 {
                    return Err(cur.err_at(b_col, "link bandwidth must be > 0"));
                }
                cur.keyword("lat")?;
                let l_col = cur.col_after_ws();
                let lat = cur.float()?;
                if lat < 0.0 {
                    return Err(cur.err_at(l_col, "link latency must be >= 0"));
                }
                cur.end_of_line()?;
                links[slot] = Some(LinkSpec { level, bw_gbps: bw, lat_us: lat });
            }
            "backend" => {
                let b_col = cur.col_after_ws();
                let bname = cur.ident()?;
                let Some(kind) = BackendKind::by_name(&bname) else {
                    let known: Vec<&str> =
                        BackendKind::ALL.iter().map(|b| b.name()).collect();
                    return Err(cur.err_at(
                        b_col,
                        &format!("unknown backend `{bname}` (known: {})", known.join("|")),
                    ));
                };
                let a = arch.as_mut().expect("header parsed before any backend line");
                if a.available(kind) {
                    return Err(cur.err_at(b_col, &format!("duplicate `backend {bname}` line")));
                }
                cur.keyword("peak")?;
                let p_col = cur.col_after_ws();
                let peak = cur.float()?;
                if peak <= 0.0 {
                    return Err(cur.err_at(p_col, "peak bandwidth must be > 0"));
                }
                cur.keyword("half")?;
                let h_col = cur.col_after_ws();
                let half = cur.float()?;
                if half < 0.0 {
                    return Err(cur.err_at(h_col, "half-saturation size must be >= 0"));
                }
                cur.keyword("issue")?;
                let i_col = cur.col_after_ws();
                let issue = cur.float()?;
                if issue < 0.0 {
                    return Err(cur.err_at(i_col, "issue overhead must be >= 0"));
                }
                cur.keyword("sms")?;
                let sms = cur.number()?;
                cur.keyword("caps")?;
                let caps = cur.cap_flags()?;
                cur.end_of_line()?;
                a.set(
                    kind,
                    caps,
                    Curve { peak_gbps: peak, half_size: half, issue_us: issue, sms_for_peak: sms },
                );
                any_backend = true;
            }
            other => {
                return Err(cur.err_at(
                    kw_col,
                    &format!("unknown directive `{other}` (topo|nodes|device|link|backend)"),
                ));
            }
        }
    }

    let Some(name) = name else {
        return Err(Error::Hw(format!(
            "line 1, col 1: empty input (expected `topo {FORMAT_VERSION} NAME` header)"
        )));
    };
    let missing = |what: &str| Error::Hw(format!("topology `{name}`: missing `{what}` line"));
    let nodes = nodes.ok_or_else(|| missing("nodes"))?;
    let (sms, ce, tf, sw) = device.ok_or_else(|| missing("device"))?;
    let local = links[0].ok_or_else(|| missing("link local"))?;
    let intra = links[1].ok_or_else(|| missing("link intra"))?;
    let inter = links[2].ok_or_else(|| missing("link inter"))?;
    if !any_backend {
        return Err(missing("backend"));
    }
    Ok(TopoDesc {
        name,
        nodes,
        local,
        intra,
        inter,
        sms_per_device: sms,
        copy_engines_per_device: ce,
        sm_tflops: tf,
        switch_reduce: sw,
        arch: arch.expect("set with the header"),
    })
}

/// Single-line cursor with 1-based line/col error positions (the
/// `plan_io::parse` discipline, specialized to the `.topo` token set).
struct Cur<'a> {
    chars: Vec<char>,
    pos: usize,
    line_no: usize,
    raw: &'a str,
}

impl<'a> Cur<'a> {
    fn new(raw: &'a str, line_no: usize) -> Self {
        // strip trailing comment (no string literals in the grammar)
        let body = match raw.find('#') {
            Some(i) => &raw[..i],
            None => raw,
        };
        Cur { chars: body.chars().collect(), pos: 0, line_no, raw }
    }

    fn done(&self) -> bool {
        self.pos >= self.chars.len()
    }

    fn peek(&self) -> Option<char> {
        self.chars.get(self.pos).copied()
    }

    fn col(&self) -> usize {
        self.pos + 1
    }

    /// Column of the next non-whitespace char (consumes the whitespace).
    fn col_after_ws(&mut self) -> usize {
        self.skip_ws();
        self.col()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(c) if c.is_whitespace()) {
            self.pos += 1;
        }
    }

    fn err_here(&self, msg: &str) -> Error {
        self.err_at(self.col(), msg)
    }

    fn err_at(&self, col: usize, msg: &str) -> Error {
        Error::Hw(format!(
            "line {}, col {col}: {msg} (in `{}`)",
            self.line_no,
            self.raw.trim_end()
        ))
    }

    fn end_of_line(&mut self) -> Result<()> {
        self.skip_ws();
        if self.done() {
            return Ok(());
        }
        let rest: String = self.chars[self.pos..].iter().collect();
        Err(self.err_here(&format!("unexpected trailing `{}`", rest.trim_end())))
    }

    /// Identifier: `[A-Za-z0-9_-]+` (backend names embed `-`).
    fn ident(&mut self) -> Result<String> {
        self.skip_ws();
        let start = self.pos;
        while matches!(self.peek(), Some(c) if c.is_ascii_alphanumeric() || c == '_' || c == '-')
        {
            self.pos += 1;
        }
        if self.pos == start {
            return Err(self.err_here("expected a word"));
        }
        Ok(self.chars[start..self.pos].iter().collect())
    }

    /// Consume the exact keyword `kw` or error.
    fn keyword(&mut self, kw: &str) -> Result<()> {
        let col = self.col_after_ws();
        let w = self.ident().map_err(|_| self.err_at(col, &format!("expected `{kw}`")))?;
        if w == kw {
            Ok(())
        } else {
            Err(self.err_at(col, &format!("expected `{kw}`, found `{w}`")))
        }
    }

    /// Consume the keyword if present (returns whether it was).
    fn opt_keyword(&mut self, kw: &str) -> bool {
        let save = self.pos;
        self.skip_ws();
        let start = self.pos;
        while matches!(self.peek(), Some(c) if c.is_ascii_alphanumeric() || c == '_' || c == '-')
        {
            self.pos += 1;
        }
        let w: String = self.chars[start..self.pos].iter().collect();
        if w == kw {
            true
        } else {
            self.pos = save;
            false
        }
    }

    fn number(&mut self) -> Result<usize> {
        self.skip_ws();
        let col = self.col();
        let start = self.pos;
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.pos == start {
            return Err(self.err_at(col, "expected an unsigned integer"));
        }
        let s: String = self.chars[start..self.pos].iter().collect();
        s.parse().map_err(|_| self.err_at(col, "integer out of range"))
    }

    /// Non-negative decimal float (canonical `{}` prints of f64 round-trip;
    /// scientific notation is accepted for hand-written files).
    fn float(&mut self) -> Result<f64> {
        self.skip_ws();
        let col = self.col();
        let start = self.pos;
        while matches!(
            self.peek(),
            Some(c) if c.is_ascii_digit() || matches!(c, '.' | 'e' | 'E' | '+' | '-')
        ) {
            self.pos += 1;
        }
        if self.pos == start {
            return Err(self.err_at(col, "expected a number"));
        }
        let s: String = self.chars[start..self.pos].iter().collect();
        s.parse::<f64>()
            .map_err(|_| self.err_at(col, &format!("invalid number `{s}`")))
            .and_then(|v| {
                if v.is_finite() {
                    Ok(v)
                } else {
                    Err(self.err_at(col, &format!("non-finite number `{s}`")))
                }
            })
    }

    /// `caps` flag list: `-` or comma-joined tokens from [`CAP_FLAGS`].
    fn cap_flags(&mut self) -> Result<Caps> {
        self.skip_ws();
        let col = self.col();
        let start = self.pos;
        while matches!(self.peek(), Some(c) if c.is_ascii_alphanumeric() || c == ',' || c == '-')
        {
            self.pos += 1;
        }
        if self.pos == start {
            return Err(self.err_at(col, "expected capability flags (or `-` for none)"));
        }
        let s: String = self.chars[start..self.pos].iter().collect();
        let mut caps = Caps {
            contiguous_only: false,
            supports_reduce: false,
            inter_node: false,
            dedicated_sms: false,
            host_launched: false,
        };
        if s == "-" {
            return Ok(caps);
        }
        for tok in s.split(',') {
            match tok {
                "contig" => caps.contiguous_only = true,
                "reduce" => caps.supports_reduce = true,
                "inter" => caps.inter_node = true,
                "dedicated" => caps.dedicated_sms = true,
                "host" => caps.host_launched = true,
                other => {
                    let known: Vec<&str> = CAP_FLAGS.iter().map(|(t, _)| *t).collect();
                    return Err(self.err_at(
                        col,
                        &format!("unknown capability flag `{other}` (known: {})", known.join(",")),
                    ));
                }
            }
        }
        Ok(caps)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::catalog;

    #[test]
    fn catalog_round_trips_bit_stably() {
        for name in catalog::names() {
            let d = catalog::desc(name).unwrap();
            let text = print_desc(&d);
            let parsed = parse_desc(&text).unwrap_or_else(|e| panic!("{name}: {e}"));
            assert_eq!(parsed, d, "{name}: parse(print(t)) != t");
            assert_eq!(print_desc(&parsed), text, "{name}: reprint not byte-stable");
        }
    }

    #[test]
    fn tolerates_messy_whitespace_comments_and_order() {
        let messy = "\
# hand-written description
topo   v1   tiny_box   # header comment
link inter bw 25 lat 8
device sms 4 copy-engines 1 sm-tflops 0.5
nodes 2
link   local  bw 100   lat 0.1
link intra bw 50 lat 1.5
backend copy-engine peak 40 half 65536 issue 2.5 sms 0 caps contig,host
backend ldst-specialized peak 30 half 8192 issue 0.3 sms 8 caps reduce,inter,dedicated
";
        let d = parse_desc(messy).unwrap();
        assert_eq!(d.name, "tiny_box");
        assert_eq!(d.nodes, 2);
        assert_eq!(d.sms_per_device, 4);
        assert_eq!(d.inter.bw_gbps, 25.0);
        assert!(d.arch.available(BackendKind::CopyEngine));
        assert!(!d.arch.available(BackendKind::TmaSpecialized));
        assert!(d.arch.caps(BackendKind::LdStSpecialized).supports_reduce);
        // re-print is canonical and round-trips
        let canon = print_desc(&d);
        assert_eq!(parse_desc(&canon).unwrap(), d);
        let t = d.instantiate(4).unwrap();
        assert_eq!(t.ranks_per_node, 2);
    }

    fn err_of(text: &str) -> String {
        parse_desc(text).unwrap_err().to_string()
    }

    #[test]
    fn errors_carry_line_and_col() {
        // bad version
        let e = err_of("topo v9 x\n");
        assert!(e.contains("line 1, col 1") && e.contains("v9"), "{e}");
        // missing header
        let e = err_of("nodes 1\n");
        assert!(e.contains("line 1") && e.contains("header"), "{e}");
        // empty input
        let e = err_of("");
        assert!(e.contains("line 1, col 1") && e.contains("empty"), "{e}");
        // bad name
        let e = err_of("topo v1 9lives\n");
        assert!(e.contains("line 1, col 9") && e.contains("invalid topology name"), "{e}");
        // unknown directive
        let e = err_of("topo v1 x\nflux-capacitor 88\n");
        assert!(e.contains("line 2, col 1") && e.contains("unknown directive"), "{e}");
        // unknown backend: col of the name (after `backend `)
        let e = err_of("topo v1 x\nbackend warp-drive peak 1 half 1 issue 1 sms 0 caps -\n");
        assert!(e.contains("line 2, col 9") && e.contains("unknown backend"), "{e}");
        // unknown flag
        let e = err_of("topo v1 x\nbackend copy-engine peak 1 half 1 issue 1 sms 0 caps warp\n");
        assert!(e.contains("line 2") && e.contains("unknown capability flag"), "{e}");
        // duplicate sections
        let e = err_of("topo v1 x\nnodes 1\nnodes 2\n");
        assert!(e.contains("line 3") && e.contains("duplicate"), "{e}");
        let e = err_of("topo v1 x\nlink intra bw 1 lat 1\nlink intra bw 2 lat 2\n");
        assert!(e.contains("line 3") && e.contains("duplicate `link intra`"), "{e}");
        // zero nodes / zero bandwidth
        let e = err_of("topo v1 x\nnodes 0\n");
        assert!(e.contains("line 2") && e.contains("nodes must be >= 1"), "{e}");
        let e = err_of("topo v1 x\nlink intra bw 0 lat 1\n");
        assert!(e.contains("line 2") && e.contains("bandwidth must be > 0"), "{e}");
        // trailing junk
        let e = err_of("topo v1 x extra\n");
        assert!(e.contains("line 1") && e.contains("trailing"), "{e}");
        // missing required sections are named
        let e = err_of("topo v1 x\nnodes 1\n");
        assert!(e.contains("missing `device`"), "{e}");
    }

    #[test]
    fn caps_flags_round_trip_every_subset() {
        // drive each flag through a synthetic entry
        let base = catalog::desc("h100_node").unwrap();
        for bits in 0..32u32 {
            let caps = Caps {
                contiguous_only: bits & 1 != 0,
                supports_reduce: bits & 2 != 0,
                inter_node: bits & 4 != 0,
                dedicated_sms: bits & 8 != 0,
                host_launched: bits & 16 != 0,
            };
            let mut d = base.clone();
            d.arch.set(
                BackendKind::CopyEngine,
                caps,
                Curve { peak_gbps: 1.0, half_size: 2.0, issue_us: 0.5, sms_for_peak: 3 },
            );
            let parsed = parse_desc(&print_desc(&d)).unwrap();
            assert_eq!(parsed.arch.caps(BackendKind::CopyEngine), caps, "bits {bits}");
        }
    }
}
