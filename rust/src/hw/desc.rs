//! Serializable topology descriptions and their instantiation.
//!
//! A [`TopoDesc`] is the machine-shape half of the hardware model as DATA:
//! node structure, per-level link specs, device compute parameters, and the
//! arch backend matrix. It is what a `.topo` file parses into
//! ([`super::format`]), what the built-in catalog ships
//! ([`super::catalog`]), and what [`TopoDesc::instantiate`] turns into the
//! [`Topology`] every subsystem consumes.
//!
//! Fingerprint rule (used by `TuneCache` so tuned knobs never leak across
//! machine shapes): [`fingerprint`] hashes the *instantiated* structure —
//! world, ranks-per-node, links, device parameters, and every backend row —
//! but NOT the name. Two descriptions of identical hardware share tuning;
//! any structural difference (including world size) does not.

use crate::backend::BackendKind;
use crate::error::{Error, Result};
use crate::hw::arch::Arch;
use crate::topo::{LinkSpec, Topology};

/// A machine-shape description: everything needed to instantiate a
/// [`Topology`] at a given world size.
#[derive(Debug, Clone, PartialEq)]
pub struct TopoDesc {
    /// Description name (catalog key / `.topo` header), e.g. `h100_node`.
    pub name: String,
    /// Number of nodes the mesh spans; ranks split evenly across nodes at
    /// instantiation (`world % nodes == 0`). `1` = single node.
    pub nodes: usize,
    pub local: LinkSpec,
    pub intra: LinkSpec,
    pub inter: LinkSpec,
    pub sms_per_device: usize,
    pub copy_engines_per_device: usize,
    pub sm_tflops: f64,
    pub switch_reduce: bool,
    pub arch: Arch,
}

impl TopoDesc {
    /// Instantiate at `world` ranks. The description fixes the node COUNT;
    /// the per-node rank count scales with the request, mirroring how the
    /// same cluster shape is used at different job sizes.
    pub fn instantiate(&self, world: usize) -> Result<Topology> {
        if world == 0 {
            return Err(Error::Hw(format!(
                "topology `{}`: world must be > 0",
                self.name
            )));
        }
        if world % self.nodes != 0 {
            return Err(Error::Hw(format!(
                "topology `{}`: world {world} not divisible across {} nodes",
                self.name, self.nodes
            )));
        }
        Ok(Topology {
            world,
            ranks_per_node: world / self.nodes,
            local: self.local,
            intra: self.intra,
            inter: self.inter,
            sms_per_device: self.sms_per_device,
            copy_engines_per_device: self.copy_engines_per_device,
            sm_tflops: self.sm_tflops,
            switch_reduce: self.switch_reduce,
            arch: self.arch.clone(),
        })
    }

    /// Same description over a different node count (e.g. the CLI's
    /// `--nodes` override on a multinode run).
    pub fn with_nodes(mut self, nodes: usize) -> Result<Self> {
        if nodes == 0 {
            return Err(Error::Hw(format!(
                "topology `{}`: nodes must be >= 1",
                self.name
            )));
        }
        self.nodes = nodes;
        Ok(self)
    }
}

/// Canonical structural description of an instantiated topology — the
/// fingerprint preimage. Name-free by design (see the module doc).
pub fn describe(topo: &Topology) -> String {
    let mut s = format!("world {} ranks-per-node {}\n", topo.world, topo.ranks_per_node);
    // every line shares its formatter with format::print_desc, so the
    // fingerprint preimage cannot drift from what the format expresses
    s.push_str(&super::format::device_line(
        topo.sms_per_device,
        topo.copy_engines_per_device,
        topo.sm_tflops,
        topo.switch_reduce,
    ));
    s.push('\n');
    for (tag, l) in [("local", topo.local), ("intra", topo.intra), ("inter", topo.inter)] {
        s.push_str(&super::format::link_line(tag, l));
        s.push('\n');
    }
    for kind in BackendKind::ALL {
        if let Some(e) = topo.arch.entry(kind) {
            s.push_str(&super::format::backend_line(kind, &e));
            s.push('\n');
        }
    }
    s
}

/// Structural fingerprint of a topology (FNV-1a over [`describe`]) — the
/// `TuneCache` key component that pins tuned knobs to one machine shape.
pub fn fingerprint(topo: &Topology) -> String {
    crate::plan_io::content_hash(&describe(topo))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::catalog;

    #[test]
    fn instantiate_divides_ranks_across_nodes() {
        let d = catalog::desc("h100_multinode").unwrap();
        assert_eq!(d.nodes, 2);
        let t = d.instantiate(8).unwrap();
        assert_eq!((t.world, t.ranks_per_node), (8, 4));
        // world 2 on 2 nodes: one rank per node, all traffic inter-node
        let t = d.instantiate(2).unwrap();
        assert_eq!(t.ranks_per_node, 1);
        // named errors on degenerate worlds
        let e = d.instantiate(0).unwrap_err();
        assert!(e.to_string().contains("world must be > 0"), "{e}");
        let e = d.instantiate(5).unwrap_err();
        assert!(e.to_string().contains("not divisible"), "{e}");
        assert!(d.clone().with_nodes(0).is_err());
        let t = d.with_nodes(4).unwrap().instantiate(8).unwrap();
        assert_eq!(t.ranks_per_node, 2);
    }

    #[test]
    fn fingerprint_is_structural_and_name_free() {
        let a = catalog::topology("h100_node", 4).unwrap();
        let b = catalog::topology("h100_node", 4).unwrap();
        assert_eq!(fingerprint(&a), fingerprint(&b), "same shape must fingerprint equal");
        // a renamed but structurally identical description shares the print
        let mut renamed = catalog::desc("h100_node").unwrap();
        renamed.name = "my_cluster".into();
        assert_eq!(fingerprint(&renamed.instantiate(4).unwrap()), fingerprint(&a));
        // world and arch changes do not
        assert_ne!(fingerprint(&a), fingerprint(&catalog::topology("h100_node", 8).unwrap()));
        assert_ne!(fingerprint(&a), fingerprint(&catalog::topology("a100_node", 4).unwrap()));
    }
}
