//! Arch-parameterized capability matrix + bandwidth-curve store.
//!
//! [`Arch`] is the queryable, per-machine-generation replacement for the
//! hardcoded H100 tables in `backend.rs`: one optional
//! ([`Caps`], [`Curve`]) row per [`BackendKind`]. A missing row means the
//! mechanism does not exist on that generation at all (e.g. TMA predates
//! Hopper, so `a100_node` ships no `tma-*` rows) — [`Arch::check_feasible`]
//! rejects it, which is how the autotuner and codegen prune arch-impossible
//! realizations without any backend-specific code.
//!
//! The timing/feasibility MATH lives in `backend.rs` (`bandwidth_with`,
//! `transfer_time_with`, `check_feasible_with`); this type only supplies
//! the per-arch constants, so the reference H100 path and the data-driven
//! path can never diverge in shape.

use crate::backend::{self, BackendKind, Caps, Curve};
use crate::error::{Error, Result};
use crate::topo::{LinkLevel, LinkSpec};

/// Number of rows in the matrix (one per [`BackendKind::ALL`] entry).
pub const NUM_BACKENDS: usize = BackendKind::ALL.len();

/// One capability-matrix row: what a mechanism can express ([`Caps`]) and
/// how fast it goes ([`Curve`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BackendEntry {
    pub caps: Caps,
    pub curve: Curve,
}

/// Per-generation backend matrix: caps + curves for every available
/// chunk-transfer mechanism.
#[derive(Debug, Clone, PartialEq)]
pub struct Arch {
    name: String,
    entries: [Option<BackendEntry>; NUM_BACKENDS],
}

impl Arch {
    /// An empty matrix (no mechanism available) — the parser's starting
    /// point; every described backend is [`Arch::set`] onto it.
    pub fn new(name: &str) -> Self {
        Arch { name: name.to_string(), entries: [None; NUM_BACKENDS] }
    }

    /// A matrix filled with the H100/NVLink reference rows — exactly the
    /// `backend::caps` / `backend::curve` tables, row by row — under a
    /// caller-chosen name (catalog entries reuse the rows but keep their
    /// own names for errors and round-tripping).
    pub fn reference(name: &str) -> Self {
        let mut a = Arch::new(name);
        for kind in BackendKind::ALL {
            a.set(kind, backend::caps(kind), backend::curve(kind));
        }
        a
    }

    /// The H100/NVLink reference matrix.
    pub fn h100() -> Self {
        Self::reference("h100")
    }

    /// Arch name (e.g. `h100`, `a100`); carried into error messages.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Install (or replace) one backend row.
    pub fn set(&mut self, kind: BackendKind, caps: Caps, curve: Curve) {
        self.entries[kind.index()] = Some(BackendEntry { caps, curve });
    }

    /// Whether the mechanism exists on this generation.
    pub fn available(&self, kind: BackendKind) -> bool {
        self.entries[kind.index()].is_some()
    }

    /// The raw matrix row, if available.
    pub fn entry(&self, kind: BackendKind) -> Option<BackendEntry> {
        self.entries[kind.index()]
    }

    /// Every available mechanism, in [`BackendKind::ALL`] order.
    pub fn available_kinds(&self) -> Vec<BackendKind> {
        BackendKind::ALL.into_iter().filter(|k| self.available(*k)).collect()
    }

    /// Capability row. Falls back to the H100 reference for unavailable
    /// mechanisms so "what would it be" queries (reports, SM-choice
    /// heuristics) stay infallible; actual USE is gated by
    /// [`Arch::check_feasible`], which rejects unavailable kinds.
    pub fn caps(&self, kind: BackendKind) -> Caps {
        self.entry(kind).map(|e| e.caps).unwrap_or_else(|| backend::caps(kind))
    }

    /// Curve row; same fallback rule as [`Arch::caps`].
    pub fn curve(&self, kind: BackendKind) -> Curve {
        self.entry(kind).map(|e| e.curve).unwrap_or_else(|| backend::curve(kind))
    }

    /// Effective bandwidth (GB/s) under this arch's curve for `kind`.
    pub fn effective_bandwidth_gbps(
        &self,
        kind: BackendKind,
        bytes: usize,
        comm_sms: usize,
        link: LinkSpec,
    ) -> f64 {
        backend::bandwidth_with(self.curve(kind), bytes, comm_sms, link)
    }

    /// Wall-clock for one logical chunk transfer, microseconds, under this
    /// arch's tables (the simulator's per-transfer cost query).
    pub fn transfer_time_us(
        &self,
        kind: BackendKind,
        bytes: usize,
        pieces: usize,
        comm_sms: usize,
        link: LinkSpec,
    ) -> f64 {
        backend::transfer_time_with(
            self.curve(kind),
            self.caps(kind).host_launched,
            bytes,
            pieces,
            comm_sms,
            link,
        )
    }

    /// Validate a backend choice against this arch and the needs of a
    /// specific transfer: existence on the arch first, then the shared
    /// capability rules.
    pub fn check_feasible(
        &self,
        kind: BackendKind,
        needs_reduce: bool,
        link_level: LinkLevel,
        comm_sms: usize,
    ) -> Result<()> {
        if !self.available(kind) {
            return Err(Error::Backend(format!(
                "{} is not available on arch `{}`",
                kind.name(),
                self.name
            )));
        }
        backend::check_feasible_with(
            kind,
            self.caps(kind),
            self.curve(kind).sms_for_peak > 0,
            needs_reduce,
            link_level,
            comm_sms,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn nvlink() -> LinkSpec {
        LinkSpec { level: LinkLevel::IntraNode, bw_gbps: 400.0, lat_us: 1.5 }
    }

    #[test]
    fn h100_arch_matches_reference_tables() {
        let a = Arch::h100();
        assert_eq!(a.name(), "h100");
        for kind in BackendKind::ALL {
            assert!(a.available(kind), "{}", kind.name());
            assert_eq!(a.caps(kind), backend::caps(kind));
            assert_eq!(a.curve(kind), backend::curve(kind));
            // arch-routed queries agree with the reference wrappers
            let l = nvlink();
            assert_eq!(
                a.effective_bandwidth_gbps(kind, 8 << 20, 32, l),
                backend::effective_bandwidth_gbps(kind, 8 << 20, 32, l)
            );
            assert_eq!(
                a.transfer_time_us(kind, 8 << 20, 4, 32, l),
                backend::transfer_time_us(kind, 8 << 20, 4, 32, l)
            );
        }
        assert_eq!(a.available_kinds().len(), NUM_BACKENDS);
    }

    #[test]
    fn missing_row_is_infeasible_but_queryable() {
        let mut a = Arch::new("no-tma");
        for kind in [BackendKind::CopyEngine, BackendKind::LdStSpecialized] {
            a.set(kind, backend::caps(kind), backend::curve(kind));
        }
        assert!(!a.available(BackendKind::TmaSpecialized));
        let e = a
            .check_feasible(BackendKind::TmaSpecialized, false, LinkLevel::IntraNode, 16)
            .unwrap_err();
        assert!(e.to_string().contains("not available on arch `no-tma`"), "{e}");
        // fallback keeps "what would it be" queries alive
        assert_eq!(a.curve(BackendKind::TmaSpecialized), backend::curve(BackendKind::TmaSpecialized));
        // available rows pass the shared rules
        a.check_feasible(BackendKind::CopyEngine, false, LinkLevel::IntraNode, 0).unwrap();
        assert!(a.check_feasible(BackendKind::CopyEngine, true, LinkLevel::IntraNode, 0).is_err());
        assert_eq!(a.available_kinds(), vec![BackendKind::CopyEngine, BackendKind::LdStSpecialized]);
    }

    #[test]
    fn overridden_curve_changes_the_model() {
        let mut a = Arch::h100();
        let mut c = backend::curve(BackendKind::CopyEngine);
        c.peak_gbps = 100.0;
        a.set(BackendKind::CopyEngine, backend::caps(BackendKind::CopyEngine), c);
        let l = nvlink();
        let slow = a.effective_bandwidth_gbps(BackendKind::CopyEngine, 256 << 20, 0, l);
        assert!(slow <= 100.0, "{slow}");
        assert!(slow < backend::effective_bandwidth_gbps(BackendKind::CopyEngine, 256 << 20, 0, l));
    }
}
