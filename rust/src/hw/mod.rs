//! Data-driven hardware model: queryable capability matrix + topology
//! catalog.
//!
//! Syncopate's chunk abstraction decouples *plans* from *backend
//! mechanisms*; this subsystem decouples both from the *machine*. The
//! hardware side of the model is a serializable artifact, not code:
//!
//! * [`arch`] — the per-generation backend matrix ([`Arch`]): one
//!   capability row + bandwidth-curve row per [`crate::backend::BackendKind`],
//!   with absence meaning "mechanism does not exist on this arch" (A100
//!   has no TMA). Every [`crate::topo::Topology`] carries one; sim,
//!   codegen, and the autotuner query it instead of the hardcoded H100
//!   tables.
//! * [`desc`] — [`TopoDesc`], the machine-shape description (nodes, link
//!   specs per level, device parameters, arch), instantiated to a
//!   `Topology` at any world size; plus the structural [`fingerprint`]
//!   that keys tuned knobs to one machine shape (`TuneCache`).
//! * [`format`] — the line-oriented `.topo` text format: hand-rolled
//!   parser with `line L, col C:` errors, canonical printer,
//!   `parse(print(t)) == t` (the `.sched` discipline of `plan_io`).
//! * [`catalog`] — five built-in shapes (`h100_node`, `h100_multinode`,
//!   `a100_node`, `b200_node`, `mixed_multinode`), shipped as
//!   `examples/topos/*.topo`, and name-or-file resolution for every
//!   `--topo` flag.
//!
//! Everything downstream (exec cases, reports, `plan run`, autotune,
//! `report arch-sweep`) reaches hardware exclusively through this module —
//! there are no `h100_*` constructors anywhere else.

pub mod arch;
pub mod catalog;
pub mod desc;
pub mod format;

pub use arch::{Arch, BackendEntry, NUM_BACKENDS};
pub use desc::{describe, fingerprint, TopoDesc};
pub use format::{parse_desc, print_desc};
