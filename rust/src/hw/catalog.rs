//! Built-in topology catalog + name/file resolution.
//!
//! Five shipped machine shapes (mirrored as `examples/topos/*.topo`, kept
//! in sync by `tests/integration_hw.rs`):
//!
//! | name               | shape                | arch notes                           |
//! |--------------------|----------------------|--------------------------------------|
//! | `h100_node`        | 1 node               | the paper's testbed; reference tables|
//! | `h100_multinode`   | 2 nodes, IB inter    | same device, NVLink + IB             |
//! | `a100_node`        | 1 node               | 108 SMs, NVLink3, **no TMA**, no NVLS|
//! | `b200_node`        | 1 node               | 148 SMs, NVLink5, everything faster  |
//! | `mixed_multinode`  | 2 nodes, RoCE inter  | NVLink intra + slow lossy Ethernet   |
//!
//! Numbers are analytic calibrations in the same spirit as the H100 tables
//! of `backend.rs` (§2.3): peaks from the link generation, half-saturation
//! sizes scaling with link speed, launch costs per mechanism family. They
//! are DATA — any of them can be overridden by pointing `--topo` at a
//! `.topo` file instead of a catalog name.

use crate::backend::{self, BackendKind, Curve};
use crate::error::{Error, Result};
use crate::hw::arch::Arch;
use crate::hw::desc::TopoDesc;
use crate::hw::format;
use crate::topo::{LinkLevel, LinkSpec, Topology};

/// The default machine shape (the paper's testbed).
pub const DEFAULT: &str = "h100_node";

/// One catalog entry.
pub struct CatalogEntry {
    pub name: &'static str,
    pub about: &'static str,
    build: fn() -> TopoDesc,
}

/// The catalog, in listing order.
pub static CATALOG: &[CatalogEntry] = &[
    CatalogEntry {
        name: "h100_node",
        about: "single NVLink node of H100s (the paper's testbed)",
        build: h100_node,
    },
    CatalogEntry {
        name: "h100_multinode",
        about: "2 nodes of H100s, NVLink intra + InfiniBand inter",
        build: h100_multinode,
    },
    CatalogEntry {
        name: "a100_node",
        about: "single NVLink3 node of A100s (no TMA, no switch reduce)",
        build: a100_node,
    },
    CatalogEntry {
        name: "b200_node",
        about: "single NVLink5 node of B200s",
        build: b200_node,
    },
    CatalogEntry {
        name: "mixed_multinode",
        about: "2 nodes, NVLink intra + RoCE inter (mixed fabric)",
        build: mixed_multinode,
    },
];

/// Catalog names, in listing order.
pub fn names() -> Vec<&'static str> {
    CATALOG.iter().map(|e| e.name).collect()
}

/// Built-in description by name; unknown names list the catalog.
pub fn desc(name: &str) -> Result<TopoDesc> {
    CATALOG
        .iter()
        .find(|e| e.name == name)
        .map(|e| (e.build)())
        .ok_or_else(|| {
            Error::Hw(format!(
                "unknown topology `{name}` (catalog: {}; or a path to a .topo file)",
                names().join(", ")
            ))
        })
}

/// Load a description from a catalog name OR a `.topo` file path.
pub fn load_desc(spec: &str) -> Result<TopoDesc> {
    if CATALOG.iter().any(|e| e.name == spec) {
        return desc(spec);
    }
    let p = std::path::Path::new(spec);
    if spec.ends_with(format::FILE_EXT) || p.exists() {
        let text = std::fs::read_to_string(p)
            .map_err(|e| Error::Hw(format!("{spec}: {e}")))?;
        return format::parse_desc(&text).map_err(|e| Error::Hw(format!("{spec}: {e}")));
    }
    desc(spec) // unreachable-name path: reuse the catalog-listing error
}

/// Resolve a name-or-path and instantiate at `world`.
pub fn resolve(spec: &str, world: usize) -> Result<(TopoDesc, Topology)> {
    let d = load_desc(spec)?;
    let t = d.instantiate(world)?;
    Ok((d, t))
}

/// Instantiate a catalog topology at `world` ranks.
pub fn topology(name: &str, world: usize) -> Result<Topology> {
    desc(name)?.instantiate(world)
}

/// Instantiate a catalog topology with an explicit node count (the old
/// `h100_multinode(nodes, rpn)` shape: `world = nodes * rpn`).
pub fn topology_nodes(name: &str, nodes: usize, world: usize) -> Result<Topology> {
    desc(name)?.with_nodes(nodes)?.instantiate(world)
}

// ---------------------------------------------------------------------------
// Built-in descriptions.
// ---------------------------------------------------------------------------

fn link(level: LinkLevel, bw_gbps: f64, lat_us: f64) -> LinkSpec {
    LinkSpec { level, bw_gbps, lat_us }
}

fn h100_node() -> TopoDesc {
    TopoDesc {
        name: "h100_node".into(),
        nodes: 1,
        // 900 GB/s aggregate bidirectional -> 450 GB/s per direction; a
        // single P2P stream peaks near 400 GB/s on the copy engine (§2.3),
        // the remainder is protocol overhead.
        local: link(LinkLevel::Local, 2000.0, 0.2),
        intra: link(LinkLevel::IntraNode, 400.0, 1.5),
        inter: link(LinkLevel::InterNode, 50.0, 5.0),
        sms_per_device: 132,
        copy_engines_per_device: 3,
        sm_tflops: 7.5,
        switch_reduce: true,
        arch: Arch::reference("h100_node"),
    }
}

fn h100_multinode() -> TopoDesc {
    let mut d = h100_node();
    d.name = "h100_multinode".into();
    d.nodes = 2;
    d.arch = Arch::reference("h100_multinode");
    d
}

/// A100 SXM: 108 SMs, ~312 TFLOPS bf16 dense, NVLink3 (600 GB/s aggregate
/// -> ~250 GB/s single stream). No TMA (a Hopper feature): the `tma-*`
/// rows simply do not exist, and the autotuner prunes them through the
/// capability matrix. No NVSwitch in-network reduction either.
fn a100_node() -> TopoDesc {
    let mut a = Arch::new("a100_node");
    a.set(
        BackendKind::CopyEngine,
        backend::caps(BackendKind::CopyEngine),
        Curve { peak_gbps: 250.0, half_size: 4.0 * 1024.0 * 1024.0, issue_us: 2.5, sms_for_peak: 0 },
    );
    a.set(
        BackendKind::LdStSpecialized,
        backend::caps(BackendKind::LdStSpecialized),
        Curve { peak_gbps: 180.0, half_size: 128.0 * 1024.0, issue_us: 0.35, sms_for_peak: 32 },
    );
    a.set(
        BackendKind::LdStColocated,
        backend::caps(BackendKind::LdStColocated),
        Curve { peak_gbps: 150.0, half_size: 128.0 * 1024.0, issue_us: 0.35, sms_for_peak: 32 },
    );
    a.set(
        BackendKind::NcclBulk,
        backend::caps(BackendKind::NcclBulk),
        Curve { peak_gbps: 200.0, half_size: 8.0 * 1024.0 * 1024.0, issue_us: 9.0, sms_for_peak: 20 },
    );
    TopoDesc {
        name: "a100_node".into(),
        nodes: 1,
        local: link(LinkLevel::Local, 1300.0, 0.25),
        intra: link(LinkLevel::IntraNode, 250.0, 2.0),
        inter: link(LinkLevel::InterNode, 25.0, 6.0),
        sms_per_device: 108,
        copy_engines_per_device: 2,
        sm_tflops: 2.9,
        switch_reduce: false,
        arch: a,
    }
}

/// B200: 148 SMs, ~2250 TFLOPS bf16 dense, NVLink5 (1.8 TB/s aggregate ->
/// ~750 GB/s single stream). Same mechanism set as Hopper; faster links
/// shift every half-saturation size up (bigger messages needed to fill the
/// pipe).
fn b200_node() -> TopoDesc {
    let mut a = Arch::new("b200_node");
    a.set(
        BackendKind::CopyEngine,
        backend::caps(BackendKind::CopyEngine),
        Curve { peak_gbps: 750.0, half_size: 8.0 * 1024.0 * 1024.0, issue_us: 2.0, sms_for_peak: 0 },
    );
    a.set(
        BackendKind::TmaSpecialized,
        backend::caps(BackendKind::TmaSpecialized),
        Curve { peak_gbps: 600.0, half_size: 1024.0 * 1024.0, issue_us: 0.4, sms_for_peak: 16 },
    );
    a.set(
        BackendKind::TmaColocated,
        backend::caps(BackendKind::TmaColocated),
        Curve { peak_gbps: 600.0, half_size: 1024.0 * 1024.0, issue_us: 0.4, sms_for_peak: 16 },
    );
    a.set(
        BackendKind::LdStSpecialized,
        backend::caps(BackendKind::LdStSpecialized),
        Curve { peak_gbps: 520.0, half_size: 256.0 * 1024.0, issue_us: 0.25, sms_for_peak: 32 },
    );
    a.set(
        BackendKind::LdStColocated,
        backend::caps(BackendKind::LdStColocated),
        Curve { peak_gbps: 450.0, half_size: 256.0 * 1024.0, issue_us: 0.25, sms_for_peak: 32 },
    );
    a.set(
        BackendKind::NcclBulk,
        backend::caps(BackendKind::NcclBulk),
        Curve { peak_gbps: 600.0, half_size: 16.0 * 1024.0 * 1024.0, issue_us: 7.0, sms_for_peak: 24 },
    );
    TopoDesc {
        name: "b200_node".into(),
        nodes: 1,
        local: link(LinkLevel::Local, 4000.0, 0.15),
        intra: link(LinkLevel::IntraNode, 750.0, 1.2),
        inter: link(LinkLevel::InterNode, 100.0, 4.0),
        sms_per_device: 148,
        copy_engines_per_device: 4,
        sm_tflops: 15.2,
        switch_reduce: true,
        arch: a,
    }
}

/// Mixed fabric: H100 devices, NVLink inside each node, but commodity RoCE
/// between nodes (25 GB/s, high base latency) — the shape where level-aware
/// hierarchical schedules (Fig. 4e) matter most.
fn mixed_multinode() -> TopoDesc {
    let mut d = h100_node();
    d.name = "mixed_multinode".into();
    d.nodes = 2;
    d.inter = link(LinkLevel::InterNode, 25.0, 10.0);
    d.arch = Arch::reference("mixed_multinode");
    d
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn catalog_lists_and_builds_every_entry() {
        let n = names();
        assert_eq!(n.len(), 5);
        assert!(n.contains(&DEFAULT));
        for name in n {
            let d = desc(name).unwrap();
            assert_eq!(d.name, name);
            assert_eq!(d.arch.name(), name);
            assert!(!d.arch.available_kinds().is_empty(), "{name}");
            // every entry instantiates at the sweep worlds
            for world in [2usize, 4, 8] {
                let t = d.instantiate(world).unwrap();
                assert_eq!(t.world, world);
                assert_eq!(t.world % t.ranks_per_node, 0);
            }
        }
    }

    #[test]
    fn unknown_name_lists_catalog() {
        let e = desc("dgx-9000").unwrap_err().to_string();
        assert!(e.contains("unknown topology `dgx-9000`"), "{e}");
        assert!(e.contains("h100_node") && e.contains("mixed_multinode"), "{e}");
        assert!(e.contains(".topo"), "{e}");
    }

    #[test]
    fn h100_node_matches_the_reference_tables() {
        let t = topology("h100_node", 8).unwrap();
        assert_eq!(t.sms_per_device, 132);
        assert_eq!(t.intra.bw_gbps, 400.0);
        for kind in BackendKind::ALL {
            assert_eq!(t.arch.caps(kind), backend::caps(kind), "{}", kind.name());
            assert_eq!(t.arch.curve(kind), backend::curve(kind), "{}", kind.name());
        }
    }

    #[test]
    fn a100_lacks_tma_and_b200_outruns_h100() {
        let a100 = topology("a100_node", 4).unwrap();
        assert!(!a100.arch.available(BackendKind::TmaSpecialized));
        assert!(!a100.arch.available(BackendKind::TmaColocated));
        assert!(a100.arch.available(BackendKind::LdStSpecialized));
        assert!(!a100.switch_reduce);
        let h100 = topology("h100_node", 4).unwrap();
        let b200 = topology("b200_node", 4).unwrap();
        assert!(a100.device_tflops() < h100.device_tflops());
        assert!(h100.device_tflops() < b200.device_tflops());
        assert!(a100.intra.bw_gbps < h100.intra.bw_gbps);
        assert!(h100.intra.bw_gbps < b200.intra.bw_gbps);
    }

    #[test]
    fn mixed_fabric_is_slow_across_nodes_only() {
        let t = topology("mixed_multinode", 4).unwrap();
        assert_eq!(t.ranks_per_node, 2);
        assert_eq!(t.link(0, 1).unwrap().bw_gbps, 400.0);
        assert_eq!(t.link(0, 2).unwrap().bw_gbps, 25.0);
        assert!(t.link(0, 2).unwrap().lat_us > t.link(0, 1).unwrap().lat_us);
    }

    #[test]
    fn resolve_accepts_files_and_rejects_nonsense() {
        // write a catalog entry out and resolve it back by path
        let d = desc("a100_node").unwrap();
        let path = std::env::temp_dir().join("syncopate_catalog_test.topo");
        std::fs::write(&path, format::print_desc(&d)).unwrap();
        let (d2, t) = resolve(path.to_str().unwrap(), 4).unwrap();
        assert_eq!(d2, d);
        assert_eq!(t.world, 4);
        let _ = std::fs::remove_file(&path);
        // missing file with the extension reports the io error, not the
        // catalog listing
        let e = resolve("/nonexistent/box.topo", 4).unwrap_err().to_string();
        assert!(e.contains("box.topo"), "{e}");
        assert!(resolve("warp-box", 4).is_err());
    }
}
