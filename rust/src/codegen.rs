//! Chunk-based code generation (paper §5.2): lower a communication schedule
//! plus per-rank tile schedules and sync plans into per-rank *executable
//! plans* — the fused-kernel analogue.
//!
//! A [`RankProgram`] is the straight-line body of the fused kernel on one
//! rank: compute segments (runs of swizzled tiles) interleaved with
//! asynchronous transfer issues and signal waits, exactly as the generated
//! Triton kernel of Fig. 5 would interleave them. Both execution paths
//! interpret the same plan:
//!
//! * `sim::` scores it on the calibrated multi-GPU model (paper-scale), and
//! * `exec::` runs it with real numerics via PJRT (validation-scale).

use std::collections::HashMap;

use crate::backend::BackendKind;
use crate::chunk::Chunk;
use crate::depgraph::RankSync;
use crate::error::{Error, Result};
use crate::kernel::grid::{TileGrid, TileId};
use crate::kernel::scheduler::TileScheduler;
use crate::schedule::{CommOp, CommSchedule, OpRef};
use crate::topo::{Rank, Topology};

/// Global signal index: one signal per comm op, set when its transfer lands.
pub type SignalId = usize;

/// What a tile actually computes on the real-numerics path. `Sim`-only plans
/// leave calls empty. The artifact names refer to `artifacts/manifest.json`
/// entries; tensor names refer to the exec engine's buffer store.
#[derive(Debug, Clone, PartialEq)]
pub enum CallSpec {
    /// `out[rows] = a[rows] @ b` via a GEMM artifact. With `accumulate`,
    /// the result adds into `out` instead of overwriting — used when the
    /// destination region also receives reduce transfers (GEMM-RS/AR), so
    /// every contribution commutes and no ordering race exists.
    GemmRows {
        artifact: String,
        a: String,
        b: String,
        out: String,
        /// Row range [start, end) of `a` and `out`.
        rows: (usize, usize),
        accumulate: bool,
    },
    /// One ring-attention step folding a K/V chunk into the running state.
    AttnStep {
        artifact: String,
        q: String,
        k: String,
        v: String,
        /// K/V row range [start, end).
        kv_rows: (usize, usize),
        /// State tensors (acc, m, l), updated in place.
        acc: String,
        m: String,
        l: String,
    },
    /// `out = acc / l` (ring-attention finalize).
    AttnFinalize { artifact: String, acc: String, l: String, out: String },
    /// `out[rows] += x[rows]` (host-side combine for partial sums).
    AddRows { x: String, out: String, rows: (usize, usize) },
    /// Tensor-parallel FFN shard: `out (+)= gelu(x @ w1 + b1) @ w2` via the
    /// fused L2 artifact (partial sum when `accumulate`).
    FfnShard {
        artifact: String,
        x: String,
        w1: String,
        b1: String,
        w2: String,
        out: String,
        accumulate: bool,
    },
}

/// One transfer as realized by a concrete backend.
#[derive(Debug, Clone, PartialEq)]
pub struct TransferDesc {
    /// Signal set when the data has fully landed at `dst_rank`.
    pub signal: SignalId,
    /// Schedule op this realizes (provenance, exec data movement).
    pub op: OpRef,
    pub src_rank: Rank,
    pub dst_rank: Rank,
    /// Region moved (same logical region on both buffers for our templates).
    pub src_chunk: Chunk,
    pub dst_chunk: Chunk,
    pub bytes: usize,
    /// Contiguous pieces the region decomposes into (copy-engine launches).
    pub pieces: usize,
    pub backend: BackendKind,
    pub comm_sms: usize,
    pub reduce: bool,
    /// Signals that must be set before the transfer may start (the
    /// schedule's `(rank, index)` deps, translated).
    pub dep_signals: Vec<SignalId>,
}

/// One straight-line instruction of a rank's fused-kernel body.
#[derive(Debug, Clone, PartialEq)]
pub enum PlanOp {
    /// Run a segment of tiles in the given (already swizzled) order.
    Compute(ComputeSeg),
    /// Asynchronously start a transfer (returns immediately).
    Issue(TransferDesc),
    /// Block until a signal is set.
    Wait(SignalId),
    /// Fixed overhead (kernel launches, reorder passes — baselines).
    Overhead { us: f64, label: &'static str },
}

/// A run of tiles executed back-to-back on the compute SMs.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ComputeSeg {
    /// Tiles in visit order (a contiguous slice of the swizzled schedule).
    pub tiles: Vec<TileId>,
    /// FLOPs per tile (uniform within the segment is typical; per-tile
    /// values support edge tiles).
    pub flops: Vec<f64>,
    /// Real-numerics calls, one per tile position (may be empty for sim).
    pub calls: Vec<CallSpec>,
    /// Wave-quantized execution: true for separate kernel launches
    /// (baselines), false for segments of a persistent fused kernel, whose
    /// tiles stream continuously across wait boundaries (§3, Insight 1).
    pub quantized: bool,
}

impl ComputeSeg {
    pub fn total_flops(&self) -> f64 {
        self.flops.iter().sum()
    }
}

impl CallSpec {
    /// Artifact label of this call ("add_rows" for the artifact-free
    /// host-side combine) — the kernel-span name in execution traces.
    pub fn artifact_name(&self) -> &str {
        match self {
            CallSpec::GemmRows { artifact, .. }
            | CallSpec::AttnStep { artifact, .. }
            | CallSpec::AttnFinalize { artifact, .. }
            | CallSpec::FfnShard { artifact, .. } => artifact,
            CallSpec::AddRows { .. } => "add_rows",
        }
    }
}

impl PlanOp {
    /// One-line human form for stuck-op reports (the full `Debug` form
    /// dumps whole chunk regions — far too loud for an error message).
    pub fn brief(&self) -> String {
        match self {
            PlanOp::Compute(seg) => {
                format!("Compute({} tiles, {} calls)", seg.tiles.len(), seg.calls.len())
            }
            PlanOp::Issue(d) => {
                format!(
                    "Issue(sig {}, {}->{}, deps {:?})",
                    d.signal, d.src_rank, d.dst_rank, d.dep_signals
                )
            }
            PlanOp::Wait(sig) => format!("Wait(sig {sig})"),
            PlanOp::Overhead { label, .. } => format!("Overhead({label})"),
        }
    }
}

/// A rank's complete fused-kernel body.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct RankProgram {
    pub ops: Vec<PlanOp>,
}

impl RankProgram {
    pub fn num_tiles(&self) -> usize {
        self.ops
            .iter()
            .map(|o| match o {
                PlanOp::Compute(c) => c.tiles.len(),
                _ => 0,
            })
            .sum()
    }
    pub fn num_transfers(&self) -> usize {
        self.ops.iter().filter(|o| matches!(o, PlanOp::Issue(_))).count()
    }
    pub fn num_waits(&self) -> usize {
        self.ops.iter().filter(|o| matches!(o, PlanOp::Wait(_))).count()
    }
}

/// The compiled distributed operator: one program per rank + signal count.
#[derive(Debug, Clone, PartialEq)]
pub struct ExecutablePlan {
    pub world: usize,
    pub per_rank: Vec<RankProgram>,
    pub num_signals: usize,
    /// SMs statically reserved for communication per device (0 for
    /// copy-engine / co-located realizations).
    pub reserved_comm_sms: usize,
}

impl ExecutablePlan {
    pub fn total_flops(&self) -> f64 {
        self.per_rank
            .iter()
            .flat_map(|p| &p.ops)
            .map(|o| match o {
                PlanOp::Compute(c) => c.total_flops(),
                _ => 0.0,
            })
            .sum()
    }
    pub fn total_transfers(&self) -> usize {
        self.per_rank.iter().map(|p| p.num_transfers()).sum()
    }
}

impl ExecutablePlan {
    /// Structural validation: every signal index in range, transfer ranks
    /// inside the world, waits matched by a producing transfer. Plans built
    /// by [`compile`] satisfy this by construction; hand-built plans (tests,
    /// external tools) are checked by the simulator and executor on entry.
    pub fn validate(&self) -> Result<()> {
        if self.per_rank.len() != self.world {
            return Err(Error::Codegen(format!(
                "plan has {} rank programs for world {}",
                self.per_rank.len(),
                self.world
            )));
        }
        let mut produced = vec![false; self.num_signals];
        for (rank, prog) in self.per_rank.iter().enumerate() {
            for (i, op) in prog.ops.iter().enumerate() {
                let at = || format!("rank {rank} op {i}");
                match op {
                    PlanOp::Wait(s) => {
                        if *s >= self.num_signals {
                            return Err(Error::Codegen(format!(
                                "{}: wait on signal {s} >= {}",
                                at(),
                                self.num_signals
                            )));
                        }
                    }
                    PlanOp::Issue(d) => {
                        if d.signal >= self.num_signals {
                            return Err(Error::Codegen(format!(
                                "{}: transfer signal {} out of range",
                                at(),
                                d.signal
                            )));
                        }
                        if d.src_rank >= self.world || d.dst_rank >= self.world {
                            return Err(Error::Codegen(format!(
                                "{}: transfer ranks {}->{} outside world {}",
                                at(),
                                d.src_rank,
                                d.dst_rank,
                                self.world
                            )));
                        }
                        if d.dep_signals.iter().any(|&s| s >= self.num_signals) {
                            return Err(Error::Codegen(format!(
                                "{}: dep signal out of range",
                                at()
                            )));
                        }
                        produced[d.signal] = true;
                    }
                    PlanOp::Compute(seg) => {
                        if seg.flops.len() != seg.tiles.len() {
                            return Err(Error::Codegen(format!(
                                "{}: {} flops entries for {} tiles",
                                at(),
                                seg.flops.len(),
                                seg.tiles.len()
                            )));
                        }
                    }
                    PlanOp::Overhead { us, .. } => {
                        if !us.is_finite() || *us < 0.0 {
                            return Err(Error::Codegen(format!(
                                "{}: bad overhead {us}",
                                at()
                            )));
                        }
                    }
                }
            }
        }
        // a wait on a signal no transfer ever sets = guaranteed deadlock
        for (rank, prog) in self.per_rank.iter().enumerate() {
            for op in &prog.ops {
                if let PlanOp::Wait(s) = op {
                    if !produced[*s] {
                        return Err(Error::Codegen(format!(
                            "rank {rank} waits on signal {s} that no transfer \
                             produces (deadlock)"
                        )));
                    }
                }
            }
        }
        Ok(())
    }
}

/// Stable global signal numbering for a schedule's ops.
///
/// Rank-major and dense: rank `r` owns the contiguous id block returned by
/// [`signal_ranges`], and ascending-id order within one rank is schedule
/// order. `exec::plan_prep` leans on the stability of this numbering when
/// it serializes intersecting reduce transfers in ascending signal order;
/// [`signal_ranges`] itself is an introspection helper (CLI/debugging),
/// not consulted by the engines.
pub fn signal_ids(sched: &CommSchedule) -> (HashMap<OpRef, SignalId>, usize) {
    let mut map = HashMap::new();
    let mut next = 0usize;
    for (rank, ops) in sched.per_rank.iter().enumerate() {
        for index in 0..ops.len() {
            map.insert(OpRef { rank, index }, next);
            next += 1;
        }
    }
    (map, next)
}

/// Per-rank signal id ranges under the [`signal_ids`] numbering: rank `r`
/// owns signals `[ranges[r].0, ranges[r].1)`.
pub fn signal_ranges(sched: &CommSchedule) -> Vec<(SignalId, SignalId)> {
    let mut out = Vec::with_capacity(sched.world);
    let mut next = 0usize;
    for ops in &sched.per_rank {
        out.push((next, next + ops.len()));
        next += ops.len();
    }
    out
}

/// Per-rank compute-side inputs to codegen.
#[derive(Debug, Clone)]
pub struct RankComputeInput {
    pub grid: TileGrid,
    /// Swizzled visiting order (must be a permutation of the grid).
    pub order: TileScheduler,
    /// Minimal (or barrier) sync plan for this rank.
    pub sync: RankSync,
    /// FLOPs per tile id (len == grid.num_tiles()).
    pub tile_flops: Vec<f64>,
    /// Real-numerics calls per tile id (empty map = sim-only plan). A tile
    /// may carry several calls (e.g. the last ring-attention step plus the
    /// finalize), executed in order.
    pub tile_calls: HashMap<TileId, Vec<CallSpec>>,
}

/// Backend realization choice for the plan (one knob set of the autotuner).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Realization {
    pub backend: BackendKind,
    /// SMs driving communication (must satisfy backend feasibility).
    pub comm_sms: usize,
}

impl Realization {
    pub fn new(backend: BackendKind, comm_sms: usize) -> Self {
        Realization { backend, comm_sms }
    }
}

/// Compile a communication schedule + per-rank compute inputs into an
/// executable plan under one backend realization.
///
/// Interleaving rule (the tile-scheduler alignment of §5.2): walking the
/// swizzled tile order, at each position emit first the waits registered
/// *before* that tile, then the tile; transfer issues triggered *after* a
/// tile are emitted right behind it. Triggers with no producing tiles issue
/// up front, before any compute.
pub fn compile(
    sched: &CommSchedule,
    inputs: &[RankComputeInput],
    real: Realization,
    topo: &Topology,
) -> Result<ExecutablePlan> {
    if inputs.len() != sched.world {
        return Err(Error::Codegen(format!(
            "{} rank inputs for world {}",
            inputs.len(),
            sched.world
        )));
    }
    let (sig, num_signals) = signal_ids(sched);
    let mut per_rank = Vec::with_capacity(sched.world);
    for (rank, input) in inputs.iter().enumerate() {
        per_rank.push(compile_rank(rank, sched, input, real, topo, &sig)?);
    }
    let reserved = if topo.arch.caps(real.backend).dedicated_sms { real.comm_sms } else { 0 };
    Ok(ExecutablePlan { world: sched.world, per_rank, num_signals, reserved_comm_sms: reserved })
}

/// Compile a schedule with NO attached compute: a trivial 1-tile,
/// zero-FLOP grid per rank, every transfer issued up front, ordering left
/// entirely to the schedule's own dependency signals.
///
/// This is how comm-only artifacts run: `reports::comm_only_latency_us`
/// scores lowering paths on it, and the user-plan serving path
/// (`coordinator::service`, `plan run`) executes parsed `.sched` files
/// through it — both engines drain all transfers before returning, so no
/// trailing waits are needed for completeness.
pub fn compile_comm_only(
    sched: &CommSchedule,
    real: Realization,
    topo: &Topology,
) -> Result<ExecutablePlan> {
    let grid = TileGrid::gemm(1, 1, 1, 1)?;
    let inputs: Vec<RankComputeInput> = (0..sched.world)
        .map(|rank| RankComputeInput {
            grid: grid.clone(),
            order: TileScheduler::row_major(&grid),
            sync: crate::depgraph::RankSync {
                waits: vec![],
                triggers: (0..sched.per_rank[rank].len())
                    .map(|op_index| crate::depgraph::Trigger { after_pos: None, op_index })
                    .collect(),
            },
            tile_flops: vec![0.0; 1],
            tile_calls: HashMap::new(),
        })
        .collect();
    compile(sched, &inputs, real, topo)
}

fn make_transfer(
    owner: Rank,
    opref: OpRef,
    op: &CommOp,
    sched: &CommSchedule,
    real: Realization,
    topo: &Topology,
    sig: &HashMap<OpRef, SignalId>,
) -> Result<TransferDesc> {
    let (src_chunk, dst_chunk, reduce) = match op {
        CommOp::P2p { src, dst, reduce, .. } => (src.clone(), dst.clone(), *reduce),
        CommOp::LocalCopy { src, dst, .. } => (src.clone(), dst.clone(), false),
        CommOp::Collective { .. } => {
            return Err(Error::Codegen(
                "collective ops must be lowered to P2P before codegen \
                 (see lowering::collective) or realized via baselines::nccl"
                    .into(),
            ))
        }
    };
    let src_rank = op.src_rank(owner);
    let dst_rank = op.dst_rank(owner);
    let link = topo.link(src_rank, dst_rank)?;
    topo.arch.check_feasible(real.backend, reduce, link.level, real.comm_sms)?;
    let bytes = src_chunk.bytes(&sched.tensors)?;
    let shape = sched.tensors.get(src_chunk.tensor)?.shape.clone();
    let pieces = src_chunk.region.contiguous_pieces(&shape);
    let dep_signals = op
        .deps()
        .iter()
        .map(|d| {
            sig.get(&OpRef { rank: d.rank, index: d.index })
                .copied()
                .ok_or_else(|| Error::Codegen(format!("unmapped dep {d:?}")))
        })
        .collect::<Result<Vec<_>>>()?;
    Ok(TransferDesc {
        signal: sig[&opref],
        op: opref,
        src_rank,
        dst_rank,
        src_chunk,
        dst_chunk,
        bytes,
        pieces,
        backend: real.backend,
        comm_sms: real.comm_sms,
        reduce,
        dep_signals,
    })
}

fn compile_rank(
    rank: Rank,
    sched: &CommSchedule,
    input: &RankComputeInput,
    real: Realization,
    topo: &Topology,
    sig: &HashMap<OpRef, SignalId>,
) -> Result<RankProgram> {
    let n = input.grid.num_tiles();
    if !input.order.is_permutation(n) {
        return Err(Error::Codegen(format!(
            "rank {rank}: tile order is not a permutation of {n} tiles"
        )));
    }
    if input.tile_flops.len() != n {
        return Err(Error::Codegen(format!(
            "rank {rank}: tile_flops has {} entries for {n} tiles",
            input.tile_flops.len()
        )));
    }
    // Waits/triggers grouped by position — position-indexed vectors, not
    // hash maps: this loop runs once per tile and dominated the compile
    // profile under SipHash (perf pass, EXPERIMENTS §Perf).
    let mut waits_at: Vec<Vec<SignalId>> = vec![Vec::new(); n];
    for w in &input.sync.waits {
        if w.before_pos >= n && n > 0 {
            return Err(Error::Codegen(format!(
                "rank {rank}: wait position {} out of {n} tiles",
                w.before_pos
            )));
        }
        let s = *sig
            .get(&w.op)
            .ok_or_else(|| Error::Codegen(format!("rank {rank}: unmapped wait op {:?}", w.op)))?;
        waits_at[w.before_pos.min(n.saturating_sub(1))].push(s);
    }
    let mut issue_immediate: Vec<usize> = Vec::new();
    let mut issue_at: Vec<Vec<usize>> = vec![Vec::new(); n];
    for t in &input.sync.triggers {
        if t.op_index >= sched.per_rank[rank].len() {
            return Err(Error::Codegen(format!(
                "rank {rank}: trigger references op {} of {}",
                t.op_index,
                sched.per_rank[rank].len()
            )));
        }
        match t.after_pos {
            None => issue_immediate.push(t.op_index),
            Some(p) => {
                if p >= n {
                    return Err(Error::Codegen(format!(
                        "rank {rank}: trigger position {p} out of {n} tiles"
                    )));
                }
                issue_at[p].push(t.op_index);
            }
        }
    }

    let mut ops: Vec<PlanOp> = Vec::new();
    let emit_issues = |ops: &mut Vec<PlanOp>, idxs: &[usize]| -> Result<()> {
        for &op_index in idxs {
            let opref = OpRef { rank, index: op_index };
            let op = &sched.per_rank[rank][op_index];
            ops.push(PlanOp::Issue(make_transfer(rank, opref, op, sched, real, topo, sig)?));
        }
        Ok(())
    };
    emit_issues(&mut ops, &issue_immediate)?;

    let mut seg =
        ComputeSeg { tiles: Vec::new(), flops: Vec::new(), calls: Vec::new(), quantized: false };
    let flush = |ops: &mut Vec<PlanOp>, seg: &mut ComputeSeg| {
        if !seg.tiles.is_empty() {
            ops.push(PlanOp::Compute(std::mem::take(seg)));
        }
    };
    let has_calls = !input.tile_calls.is_empty();
    for (pos, &tile) in input.order.order.iter().enumerate() {
        if !waits_at[pos].is_empty() {
            flush(&mut ops, &mut seg);
            for &s in &waits_at[pos] {
                ops.push(PlanOp::Wait(s));
            }
        }
        seg.tiles.push(tile);
        seg.flops.push(input.tile_flops[tile]);
        if has_calls {
            if let Some(calls) = input.tile_calls.get(&tile) {
                seg.calls.extend(calls.iter().cloned());
            }
        }
        if !issue_at[pos].is_empty() {
            flush(&mut ops, &mut seg);
            let idxs = std::mem::take(&mut issue_at[pos]);
            emit_issues(&mut ops, &idxs)?;
        }
    }
    flush(&mut ops, &mut seg);
    Ok(RankProgram { ops })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chunk::{DType, Region, TensorTable};
    use crate::depgraph::{Trigger, Wait};
    use crate::schedule::{Dep, TransferKind};

    /// 2 ranks, rank1 pushes 2 chunks to rank0 (second dep on first);
    /// rank0's grid: 4 M-tiles; tiles 2,3 consume the chunks.
    fn setup() -> (CommSchedule, Vec<RankComputeInput>, Topology) {
        let mut t = TensorTable::new();
        let x = t.declare("x", &[8, 16], DType::F32).unwrap();
        let mut s = CommSchedule::new(2, t);
        for (i, r0) in [(0usize, 0usize), (1, 2)] {
            let c = Chunk::new(x, Region::rows(r0, 2, 16));
            let deps = if i == 0 { vec![] } else { vec![Dep::on(1, 0)] };
            s.add_op(
                1,
                CommOp::P2p {
                    kind: TransferKind::Push,
                    peer: 0,
                    src: c.clone(),
                    dst: c,
                    reduce: false,
                    deps,
                },
            )
            .unwrap();
        }
        let grid = TileGrid::gemm(8, 16, 2, 16).unwrap();
        let mk_input = |sync: RankSync| RankComputeInput {
            grid: grid.clone(),
            order: TileScheduler::row_major(&grid),
            sync,
            tile_flops: vec![100.0; 4],
            tile_calls: HashMap::new(),
        };
        let sync0 = RankSync {
            waits: vec![
                Wait { before_pos: 2, op: OpRef { rank: 1, index: 0 } },
                Wait { before_pos: 3, op: OpRef { rank: 1, index: 1 } },
            ],
            triggers: vec![],
        };
        let sync1 = RankSync {
            waits: vec![],
            triggers: vec![
                Trigger { after_pos: None, op_index: 0 },
                Trigger { after_pos: Some(1), op_index: 1 },
            ],
        };
        let topo = crate::hw::catalog::topology("h100_node", 2).unwrap();
        (s, vec![mk_input(sync0), mk_input(sync1)], topo)
    }

    #[test]
    fn compiles_interleaved_program() {
        let (s, inputs, topo) = setup();
        let plan =
            compile(&s, &inputs, Realization::new(BackendKind::CopyEngine, 0), &topo).unwrap();
        assert_eq!(plan.world, 2);
        assert_eq!(plan.num_signals, 2);
        assert_eq!(plan.reserved_comm_sms, 0);
        // rank0: compute [t0,t1], wait s0, compute [t2], wait s1, compute [t3]
        let r0 = &plan.per_rank[0];
        assert_eq!(r0.num_tiles(), 4);
        assert_eq!(r0.num_waits(), 2);
        match &r0.ops[0] {
            PlanOp::Compute(c) => assert_eq!(c.tiles, vec![0, 1]),
            o => panic!("expected compute, got {o:?}"),
        }
        assert!(matches!(r0.ops[1], PlanOp::Wait(0)));
        // rank1: issue s0 up front; compute t0,t1; issue s1; compute t2,t3
        let r1 = &plan.per_rank[1];
        assert_eq!(r1.num_transfers(), 2);
        assert!(matches!(&r1.ops[0], PlanOp::Issue(d) if d.signal == 0));
        match &r1.ops[1] {
            PlanOp::Compute(c) => assert_eq!(c.tiles, vec![0, 1]),
            o => panic!("{o:?}"),
        }
        assert!(matches!(&r1.ops[2], PlanOp::Issue(d) if d.signal == 1));
    }

    #[test]
    fn transfer_desc_fields() {
        let (s, inputs, topo) = setup();
        let plan =
            compile(&s, &inputs, Realization::new(BackendKind::CopyEngine, 0), &topo).unwrap();
        let PlanOp::Issue(d) = &plan.per_rank[1].ops[0] else { panic!() };
        assert_eq!(d.src_rank, 1);
        assert_eq!(d.dst_rank, 0);
        assert_eq!(d.bytes, 2 * 16 * 4);
        assert_eq!(d.pieces, 1); // full rows are contiguous
        assert!(d.dep_signals.is_empty());
        let PlanOp::Issue(d2) = &plan.per_rank[1].ops[2] else { panic!() };
        assert_eq!(d2.dep_signals, vec![0]); // dep on first push
    }

    #[test]
    fn dedicated_backend_reserves_sms() {
        let (s, inputs, topo) = setup();
        let plan = compile(
            &s,
            &inputs,
            Realization::new(BackendKind::TmaSpecialized, 16),
            &topo,
        )
        .unwrap();
        assert_eq!(plan.reserved_comm_sms, 16);
        let plan2 = compile(
            &s,
            &inputs,
            Realization::new(BackendKind::TmaColocated, 16),
            &topo,
        )
        .unwrap();
        assert_eq!(plan2.reserved_comm_sms, 0); // borrowed, not reserved
    }

    #[test]
    fn infeasible_backend_rejected() {
        let (mut s, inputs, topo) = setup();
        // add a reduce op: TMA must be rejected
        let x = s.tensors.lookup("x").unwrap();
        let c = Chunk::new(x, Region::rows(4, 2, 16));
        s.add_op(
            1,
            CommOp::P2p {
                kind: TransferKind::Push,
                peer: 0,
                src: c.clone(),
                dst: c,
                reduce: true,
                deps: vec![],
            },
        )
        .unwrap();
        let mut inputs = inputs;
        inputs[1].sync.triggers.push(Trigger { after_pos: None, op_index: 2 });
        let r = compile(&s, &inputs, Realization::new(BackendKind::TmaSpecialized, 16), &topo);
        assert!(r.is_err());
        let ok = compile(&s, &inputs, Realization::new(BackendKind::LdStSpecialized, 16), &topo);
        assert!(ok.is_ok());
    }

    #[test]
    fn bad_inputs_rejected() {
        let (s, mut inputs, topo) = setup();
        // non-permutation order
        inputs[0].order = TileScheduler { order: vec![0, 0, 1, 2] };
        assert!(compile(&s, &inputs, Realization::new(BackendKind::CopyEngine, 0), &topo)
            .is_err());
        let (s, mut inputs, topo) = setup();
        inputs[0].tile_flops = vec![1.0; 2];
        assert!(compile(&s, &inputs, Realization::new(BackendKind::CopyEngine, 0), &topo)
            .is_err());
        let (s, inputs, topo) = setup();
        assert!(compile(&s, &inputs[..1], Realization::new(BackendKind::CopyEngine, 0), &topo)
            .is_err());
    }

    #[test]
    fn collective_must_be_lowered_first() {
        let (mut s, mut inputs, topo) = setup();
        let x = s.tensors.lookup("x").unwrap();
        let full = Chunk::new(x, Region::full(&[8, 16]));
        s.add_op(
            0,
            CommOp::Collective {
                kind: crate::schedule::CollectiveKind::AllGather,
                src: full.clone(),
                dst: full,
                ranks: vec![0, 1],
                deps: vec![],
            },
        )
        .unwrap();
        inputs[0].sync.triggers.push(Trigger { after_pos: None, op_index: 0 });
        let e = compile(&s, &inputs, Realization::new(BackendKind::CopyEngine, 0), &topo)
            .unwrap_err();
        assert!(e.to_string().contains("lowered"));
    }

    #[test]
    fn plan_stats() {
        let (s, inputs, topo) = setup();
        let plan =
            compile(&s, &inputs, Realization::new(BackendKind::CopyEngine, 0), &topo).unwrap();
        assert_eq!(plan.total_transfers(), 2);
        assert_eq!(plan.total_flops(), 8.0 * 100.0);
    }

    #[test]
    fn plan_validation_catches_corruption() {
        let (s, inputs, topo) = setup();
        let mut plan =
            compile(&s, &inputs, Realization::new(BackendKind::CopyEngine, 0), &topo).unwrap();
        plan.validate().unwrap();
        // wait on out-of-range signal
        let mut bad = plan.clone();
        bad.per_rank[0].ops.push(PlanOp::Wait(99));
        assert!(bad.validate().is_err());
        // wait on a signal no transfer produces
        let mut bad2 = plan.clone();
        bad2.num_signals = 3;
        bad2.per_rank[0].ops.push(PlanOp::Wait(2));
        let e = bad2.validate().unwrap_err();
        assert!(e.to_string().contains("deadlock"), "{e}");
        // negative overhead
        let mut bad3 = plan.clone();
        bad3.per_rank[0].ops.push(PlanOp::Overhead { us: -1.0, label: "x" });
        assert!(bad3.validate().is_err());
        // transfer rank out of world
        if let Some(PlanOp::Issue(d)) =
            plan.per_rank[1].ops.iter_mut().find(|o| matches!(o, PlanOp::Issue(_)))
        {
            d.dst_rank = 9;
        }
        assert!(plan.validate().is_err());
    }

    #[test]
    fn signal_ids_stable_and_dense() {
        let (s, _, _) = setup();
        let (map, n) = signal_ids(&s);
        assert_eq!(n, 2);
        let mut vals: Vec<_> = map.values().copied().collect();
        vals.sort_unstable();
        assert_eq!(vals, vec![0, 1]);
    }

    #[test]
    fn signal_ranges_partition_the_id_space() {
        let (s, _, _) = setup();
        let ranges = signal_ranges(&s);
        assert_eq!(ranges.len(), 2);
        assert_eq!(ranges[0], (0, 0)); // rank0 owns no ops in the setup
        assert_eq!(ranges[1], (0, 2));
        let (map, n) = signal_ids(&s);
        for (op, sig) in &map {
            let (lo, hi) = ranges[op.rank];
            assert!(*sig >= lo && *sig < hi, "signal {sig} outside rank {} range", op.rank);
        }
        assert_eq!(ranges.last().unwrap().1, n);
    }
}
