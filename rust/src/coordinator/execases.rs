//! Validation-scale operator cases: real buffers, real AOT kernels, host
//! oracles (DESIGN.md §6).
//!
//! Each builder constructs the complete pipeline for one distributed
//! operator at the canonical small shapes baked into the artifacts
//! (python/compile/model.py): schedule template → chunk split → tile grid →
//! chunk-major swizzle → minimal sync → codegen → [`ExecCase`] with
//! deterministic input data and expected outputs. `run_and_verify` executes
//! the plan through `exec::` and asserts numerics against the oracle.

use std::collections::HashMap;

use crate::backend::BackendKind;
use crate::chunk::TensorTable;
use crate::codegen::{compile, CallSpec, ExecutablePlan, RankComputeInput, Realization};
use crate::depgraph::{plan_rank_sync, ChunkTileMap};
use crate::error::{Error, Result};
use crate::exec::verify::{
    assert_allclose, assert_bit_identical, host_attention, host_gemm, host_sum,
};
use crate::exec::{run_with, BufferStore, ExecOptions, ExecStats};
use crate::kernel::grid::{Axis, TileGrid};
use crate::kernel::scheduler::TileScheduler;
use crate::pipeline::{self, Stage};
use crate::runtime::Runtime;
use crate::schedule::{templates, CommSchedule, OpRef};
use crate::topo::Topology;
use crate::util::Rng;

/// Canonical exec shapes — single-sourced from [`crate::runtime::canonical`]
/// (the Rust mirror of python/compile/model.py).
pub use crate::runtime::canonical::{ATTN_D, ATTN_SQ, GEMM_K, GEMM_N};

/// One expected-value check after execution.
#[derive(Debug, Clone)]
pub struct Check {
    pub rank: usize,
    pub tensor: String,
    pub expected: Vec<f32>,
    pub what: String,
}

/// A fully-built validation case.
pub struct ExecCase {
    pub name: String,
    pub sched: CommSchedule,
    pub plan: ExecutablePlan,
    pub store: BufferStore,
    pub checks: Vec<Check>,
    /// The topology the case was compiled for (simulation runs against
    /// this, e.g. `report arch-sweep`).
    pub topo: Topology,
}

/// Execute a case and verify every check (consumes the case's store).
/// Runs the sequential reference engine; see [`run_and_verify_with`].
pub fn run_and_verify(case: ExecCase, runtime: &Runtime) -> Result<ExecStats> {
    run_and_verify_with(case, runtime, &ExecOptions::sequential())
}

/// Execute a case under an explicit [`ExecOptions`] and verify every check.
pub fn run_and_verify_with(
    case: ExecCase,
    runtime: &Runtime,
    opts: &ExecOptions,
) -> Result<ExecStats> {
    let stats = run_with(&case.plan, &case.sched.tensors, &case.store, runtime, opts)?;
    verify_checks(&case.name, "", &case.store, &case.checks)?;
    Ok(stats)
}

/// [`run_and_verify_with`] + chunk-level tracing. The returned
/// [`crate::trace::Trace`] is stamped with the case topology's
/// [`crate::hw::fingerprint`] (calibration's cross-machine guard) and the
/// case name/world — everything `calibrate --from` needs to rebuild and
/// re-simulate the traced plan.
pub fn run_and_verify_traced(
    case: ExecCase,
    runtime: &Runtime,
    opts: &ExecOptions,
) -> Result<(ExecStats, crate::trace::Trace)> {
    let (stats, mut trace) =
        crate::exec::run_with_traced(&case.plan, &case.sched.tensors, &case.store, runtime, opts)?;
    verify_checks(&case.name, "", &case.store, &case.checks)?;
    trace.fingerprint = crate::hw::fingerprint(&case.topo);
    trace.set_meta("case", &case.name);
    trace.set_meta("world", &case.topo.world.to_string());
    trace.set_meta("engine", &format!("{:?}", opts.mode));
    Ok((stats, trace))
}

/// Assert every expected-value check against the post-run store; `tag`
/// distinguishes which engine produced the state in error messages.
fn verify_checks(name: &str, tag: &str, store: &BufferStore, checks: &[Check]) -> Result<()> {
    for c in checks {
        let got = store.get(c.rank, &c.tensor)?;
        let what = format!("{name}{tag}: {}", c.what);
        assert_allclose(&got, &c.expected, 5e-4, 5e-4, &what)?;
    }
    Ok(())
}

/// Run one case under BOTH engines and require bit-identical f32 state.
///
/// `build` must return the same deterministic case on every call (same
/// seed); the first instance runs sequentially, the second in parallel, and
/// every declared tensor on every rank is compared bitwise afterwards —
/// the DESIGN.md §6 cross-mode equivalence check. Oracle checks run on both
/// instances too, so a template that is wrong in *both* engines still fails.
pub fn verify_modes_bit_identical(
    build: &dyn Fn() -> Result<ExecCase>,
    runtime: &Runtime,
) -> Result<(ExecStats, ExecStats)> {
    let seq_case = build()?;
    let name = seq_case.name.clone();
    let tensors: Vec<String> =
        seq_case.store.names().into_iter().map(|s| s.to_string()).collect();
    let world = seq_case.store.world();

    let par_case = build()?;
    // sanity: the builder must be deterministic for the comparison to mean
    // anything — inputs must already match bitwise
    for t in &tensors {
        for r in 0..world {
            assert_bit_identical(
                &par_case.store.get(r, t)?,
                &seq_case.store.get(r, t)?,
                &format!("{name}: builder not deterministic for `{t}`@rank{r}"),
            )?;
        }
    }

    let seq_stats = run_with(
        &seq_case.plan,
        &seq_case.sched.tensors,
        &seq_case.store,
        runtime,
        &ExecOptions::sequential(),
    )?;
    verify_checks(&name, " (seq)", &seq_case.store, &seq_case.checks)?;
    let par_stats = run_and_verify_stats(&par_case, runtime)?;

    for t in &tensors {
        for r in 0..world {
            assert_bit_identical(
                &par_case.store.get(r, t)?,
                &seq_case.store.get(r, t)?,
                &format!("{name}: parallel vs sequential `{t}`@rank{r}"),
            )?;
        }
    }
    if seq_stats.transfers != par_stats.transfers
        || seq_stats.bytes_moved != par_stats.bytes_moved
        || seq_stats.compute_calls != par_stats.compute_calls
    {
        return Err(Error::Exec(format!(
            "{name}: stats diverge between modes: seq {seq_stats:?} vs par {par_stats:?}"
        )));
    }
    Ok((seq_stats, par_stats))
}

/// [`verify_modes_bit_identical`] extended across synchronization cores:
/// runs the case sequentially, parallel/atomic, and parallel/condvar, and
/// requires bit-identical f32 state and identical stats from all three —
/// the safety net for the lock-free hot path (DESIGN.md §15).
pub fn verify_sync_strategies_bit_identical(
    build: &dyn Fn() -> Result<ExecCase>,
    runtime: &Runtime,
) -> Result<()> {
    let engines: [(&str, ExecOptions); 3] = [
        ("sequential", ExecOptions::sequential()),
        ("parallel/atomic", ExecOptions::parallel()),
        (
            "parallel/condvar",
            ExecOptions {
                sync: crate::exec::SyncStrategy::Condvar,
                ..ExecOptions::parallel()
            },
        ),
    ];
    let mut reference: Option<(String, Vec<String>, usize, ExecCase, ExecStats)> = None;
    for (tag, opts) in engines {
        let case = build()?;
        let stats = run_with(&case.plan, &case.sched.tensors, &case.store, runtime, &opts)?;
        verify_checks(&case.name, &format!(" ({tag})"), &case.store, &case.checks)?;
        match &reference {
            None => {
                let name = case.name.clone();
                let tensors: Vec<String> =
                    case.store.names().into_iter().map(|s| s.to_string()).collect();
                let world = case.store.world();
                reference = Some((name, tensors, world, case, stats));
            }
            Some((name, tensors, world, ref_case, ref_stats)) => {
                for t in tensors {
                    for r in 0..*world {
                        assert_bit_identical(
                            &case.store.get(r, t)?,
                            &ref_case.store.get(r, t)?,
                            &format!("{name}: {tag} vs sequential `{t}`@rank{r}"),
                        )?;
                    }
                }
                if stats.transfers != ref_stats.transfers
                    || stats.bytes_moved != ref_stats.bytes_moved
                    || stats.compute_calls != ref_stats.compute_calls
                {
                    return Err(Error::Exec(format!(
                        "{name}: stats diverge: {tag} {stats:?} vs sequential {ref_stats:?}"
                    )));
                }
            }
        }
    }
    Ok(())
}

fn run_and_verify_stats(case: &ExecCase, runtime: &Runtime) -> Result<ExecStats> {
    let stats = run_with(
        &case.plan,
        &case.sched.tensors,
        &case.store,
        runtime,
        &ExecOptions::parallel(),
    )?;
    verify_checks(&case.name, " (par)", &case.store, &case.checks)?;
    Ok(stats)
}

/// Reject degenerate world sizes with a named error instead of letting
/// them panic (or silently no-op) deep inside template construction.
fn check_world(case: &str, world: usize) -> Result<()> {
    if world < 2 {
        return Err(Error::Coordinator(format!(
            "{case}: world must be >= 2 (got {world})"
        )));
    }
    Ok(())
}

/// Reject degenerate split factors with a named error: `split == 0` would
/// otherwise panic on the modulo, and a non-dividing split would surface
/// as an opaque region error.
fn check_split(case: &str, split: usize, shard: usize) -> Result<()> {
    if split == 0 {
        return Err(Error::Coordinator(format!("{case}: split must be >= 1 (got 0)")));
    }
    if shard % split != 0 {
        return Err(Error::Coordinator(format!(
            "{case}: split {split} does not evenly divide the {shard}-row shard"
        )));
    }
    Ok(())
}

/// Default realization for a case on `topo`: the copy engine for plain
/// single-node transfers (the historical default), otherwise the first
/// matrix row that can carry every transfer the case may issue — reduce
/// support when `reduce`, inter-node reach on a multinode mesh, and no
/// contiguous-only restriction (split chunks may stride). Picking through
/// the capability matrix keeps custom `.topo` files without, say, an
/// `ldst-specialized` row runnable instead of failing at codegen.
fn default_real(topo: &Topology, reduce: bool) -> Realization {
    let multi_node = topo.ranks_per_node < topo.world;
    if !reduce && !multi_node && topo.arch.available(BackendKind::CopyEngine) {
        return Realization::new(BackendKind::CopyEngine, 0);
    }
    let pick = BackendKind::ALL.into_iter().find(|&k| {
        topo.arch.available(k) && {
            let c = topo.arch.caps(k);
            (!reduce || c.supports_reduce) && (!multi_node || c.inter_node) && !c.contiguous_only
        }
    });
    match pick {
        Some(k) if topo.arch.curve(k).sms_for_peak == 0 => Realization::new(k, 0),
        Some(k) => Realization::new(k, 16),
        // no feasible row: keep the reference choice so codegen's
        // check_feasible names the real problem
        None => Realization::new(BackendKind::LdStSpecialized, 16),
    }
}

/// Consumers/producers from row intersections (axis 0 of the grid).
fn rows_map(
    sched: &CommSchedule,
    rank: usize,
    grid: &TileGrid,
    consumed_tensor: Option<&str>,
    produced_tensor: Option<&str>,
) -> Result<ChunkTileMap> {
    let mut map = ChunkTileMap::default();
    for (r, ops) in sched.per_rank.iter().enumerate() {
        for (index, op) in ops.iter().enumerate() {
            let opref = OpRef { rank: r, index };
            if let Some(tname) = consumed_tensor {
                if op.dst_rank(r) == rank {
                    let reg = &op.produced_chunk().region;
                    let name = &sched.tensors.get(op.produced_chunk().tensor)?.name;
                    if name == tname {
                        let mut ranges = vec![None; grid.rank()];
                        ranges[0] = Some((reg.offset[0], reg.offset[0] + reg.sizes[0]));
                        map.consumers
                            .entry(opref)
                            .or_default()
                            .extend(grid.tiles_intersecting(&ranges)?);
                    }
                }
            }
            if let Some(tname) = produced_tensor {
                if op.src_rank(r) == rank {
                    let reg = &op.consumed_chunk().region;
                    let name = &sched.tensors.get(op.consumed_chunk().tensor)?.name;
                    if name == tname {
                        let mut ranges = vec![None; grid.rank()];
                        ranges[0] = Some((reg.offset[0], reg.offset[0] + reg.sizes[0]));
                        map.producers
                            .entry(opref)
                            .or_default()
                            .extend(grid.tiles_intersecting(&ranges)?);
                    }
                }
            }
        }
    }
    Ok(map)
}

fn chunk_major_order(grid: &TileGrid, map: &ChunkTileMap, rank: usize) -> Result<TileScheduler> {
    let groups = map.consumer_groups(rank);
    if groups.is_empty() {
        return Ok(TileScheduler::row_major(grid));
    }
    let arrival: Vec<usize> = (0..groups.len()).collect();
    TileScheduler::chunk_major(
        grid,
        &groups,
        &arrival,
        crate::kernel::scheduler::IntraOrder::RowMajor,
    )
}

/// Which AllGather realization an exec-scale AG-GEMM uses (the push/pull
/// equivalence of Fig. 4a/4b plus the ring of Fig. 4c — all must produce
/// identical numerics).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AgVariant {
    /// Pull-based 1-D swizzle (Listing 2) — no deps.
    PullSwizzle,
    /// Push-based ring (Fig. 4c) — forwarding dependency chains: a rank
    /// re-sends data it received, so exec-side dep ordering is load-bearing.
    PushRing,
    /// Push-based direct broadcast of the own shard.
    PushDirect,
    /// Flux-style plan lifted from a stream-level description
    /// (`plan_io::import::flux_ag`) — the "ported from existing distributed
    /// compilers" path executed with real numerics.
    ImportedFlux,
    /// Triton-distributed-style plan lifted from its single ld/st stream
    /// (`plan_io::import::triton_dist_ag`).
    ImportedTritonDist,
}

/// AG-GEMM at validation scale: gather row-sharded X, multiply by each
/// rank's private weight shard, chunk by chunk as shards land.
/// Runs on the default catalog topology; see [`ag_gemm_variant_on`].
pub fn ag_gemm(world: usize, split: usize, seed: u64) -> Result<ExecCase> {
    ag_gemm_variant(world, split, seed, AgVariant::PullSwizzle)
}

/// AG-GEMM with an explicit AllGather realization on the default catalog
/// topology (see [`AgVariant`]).
pub fn ag_gemm_variant(
    world: usize,
    split: usize,
    seed: u64,
    variant: AgVariant,
) -> Result<ExecCase> {
    check_world(ag_case_name(variant), world)?;
    ag_gemm_variant_on(
        &crate::hw::catalog::topology(crate::hw::catalog::DEFAULT, world)?,
        split,
        seed,
        variant,
    )
}

/// Registry case a variant backs (used in error messages).
fn ag_case_name(variant: AgVariant) -> &'static str {
    match variant {
        AgVariant::ImportedFlux => "ag-gemm-flux",
        AgVariant::ImportedTritonDist => "ag-gemm-tdist",
        _ => "ag-gemm",
    }
}

/// AG-GEMM with an explicit AllGather realization on an explicit topology.
pub fn ag_gemm_variant_on(
    topo: &Topology,
    split: usize,
    seed: u64,
    variant: AgVariant,
) -> Result<ExecCase> {
    // error messages name the registry case this variant actually backs
    let case = ag_case_name(variant);
    let world = topo.world;
    check_world(case, world)?;
    let shard = 32usize;
    check_split(case, split, shard)?;
    let bm = shard / split;
    let artifact = format!("gemm_{bm}x{GEMM_K}x{GEMM_N}");
    let m = world * shard;

    let mut table = TensorTable::new();
    let x = table.declare("x", &[m, GEMM_K], crate::chunk::DType::F32)?;
    table.declare("w", &[GEMM_K, GEMM_N], crate::chunk::DType::F32)?;
    table.declare("y", &[m, GEMM_N], crate::chunk::DType::F32)?;
    let base = match variant {
        AgVariant::PullSwizzle => templates::all_gather_swizzle(&table, x, 0, world)?,
        AgVariant::PushRing => templates::all_gather_ring(&table, x, 0, world)?,
        AgVariant::PushDirect => templates::all_gather_direct(&table, x, 0, world)?,
        // imported plans arrive pre-chunked by the foreign system (4
        // tile-pieces per shard for Flux, one chunk per shard for
        // Triton-dist); split_p2p refines them further like any template
        AgVariant::ImportedFlux => crate::plan_io::import::flux_ag(&table, x, 0, world, 4)?,
        AgVariant::ImportedTritonDist => {
            crate::plan_io::import::triton_dist_ag(&table, x, 0, world)?
        }
    };
    let sched = base.split_p2p(0, split)?;

    let grid = TileGrid::new(vec![
        Axis::new("M", m, bm)?,
        Axis::new("N", GEMM_N, GEMM_N)?,
    ])?;
    let mut rng = Rng::new(seed);
    let x_global = rng.vec_f32(m * GEMM_K);
    let ws: Vec<Vec<f32>> = (0..world).map(|_| rng.vec_f32(GEMM_K * GEMM_N)).collect();

    let mut store = BufferStore::new(world);
    store.declare("x", &[m, GEMM_K])?;
    store.declare("w", &[GEMM_K, GEMM_N])?;
    store.declare("y", &[m, GEMM_N])?;
    for r in 0..world {
        // only rank r's shard of x is valid initially
        let mut xr = vec![0.0f32; m * GEMM_K];
        let a = r * shard * GEMM_K;
        xr[a..a + shard * GEMM_K].copy_from_slice(&x_global[a..a + shard * GEMM_K]);
        store.set(r, "x", &xr)?;
        store.set(r, "w", &ws[r])?;
    }

    let mut inputs = Vec::new();
    for rank in 0..world {
        let map = rows_map(&sched, rank, &grid, Some("x"), None)?;
        let order = chunk_major_order(&grid, &map, rank)?;
        let sync = plan_rank_sync(rank, &sched, &order, &map)?;
        let mut tile_calls: HashMap<usize, Vec<CallSpec>> = HashMap::new();
        for t in 0..grid.num_tiles() {
            let c = grid.coords(t)?;
            let (r0, r1) = grid.axis_span(0, c[0]);
            tile_calls.insert(
                t,
                vec![CallSpec::GemmRows {
                    artifact: artifact.clone(),
                    a: "x".into(),
                    b: "w".into(),
                    out: "y".into(),
                    rows: (r0, r1),
                    accumulate: false,
                }],
            );
        }
        inputs.push(RankComputeInput {
            grid: grid.clone(),
            order,
            sync,
            tile_flops: vec![2.0 * bm as f64 * GEMM_N as f64 * GEMM_K as f64; grid.num_tiles()],
            tile_calls,
        });
    }
    let plan = compile(&sched, &inputs, default_real(topo, false), topo)?;
    let checks = (0..world)
        .map(|r| Check {
            rank: r,
            tensor: "y".into(),
            expected: host_gemm(&x_global, &ws[r], m, GEMM_K, GEMM_N),
            what: format!("y@rank{r} == X_full @ W_{r}"),
        })
        .collect();
    Ok(ExecCase {
        name: format!("ag-gemm-w{world}-s{split}-{variant:?}"),
        sched,
        plan,
        store,
        checks,
        topo: topo.clone(),
    })
}

/// GEMM-RS: each rank computes a partial Y from its K-shard, output row
/// shards reduce-scatter to their owners as tiles finish.
pub fn gemm_rs(world: usize, seed: u64) -> Result<ExecCase> {
    check_world("gemm-rs", world)?;
    gemm_rs_on(&crate::hw::catalog::topology(crate::hw::catalog::DEFAULT, world)?, seed)
}

/// GEMM-AR: partition-based AllReduce (Fig. 4d) of the partial Y.
pub fn gemm_ar(world: usize, seed: u64) -> Result<ExecCase> {
    check_world("gemm-ar", world)?;
    gemm_ar_on(&crate::hw::catalog::topology(crate::hw::catalog::DEFAULT, world)?, seed)
}

/// [`gemm_rs`] on an explicit topology.
pub fn gemm_rs_on(topo: &Topology, seed: u64) -> Result<ExecCase> {
    gemm_reduce_case(topo, seed, false)
}

/// [`gemm_ar`] on an explicit topology.
pub fn gemm_ar_on(topo: &Topology, seed: u64) -> Result<ExecCase> {
    gemm_reduce_case(topo, seed, true)
}

fn gemm_reduce_case(topo: &Topology, seed: u64, all_reduce: bool) -> Result<ExecCase> {
    let world = topo.world;
    check_world(if all_reduce { "gemm-ar" } else { "gemm-rs" }, world)?;
    let shard = 16usize;
    let bm = shard; // one tile per output shard
    let artifact = format!("gemm_{bm}x{GEMM_K}x{GEMM_N}");
    let m = world * shard;

    let mut table = TensorTable::new();
    table.declare("x", &[m, GEMM_K], crate::chunk::DType::F32)?;
    table.declare("w", &[GEMM_K, GEMM_N], crate::chunk::DType::F32)?;
    let y = table.declare("y", &[m, GEMM_N], crate::chunk::DType::F32)?;
    let sched = if all_reduce {
        templates::all_reduce_partition(&table, y, 0, world)?
    } else {
        templates::reduce_scatter_direct(&table, y, 0, world)?
    };

    let grid = TileGrid::new(vec![
        Axis::new("M", m, bm)?,
        Axis::new("N", GEMM_N, GEMM_N)?,
    ])?;
    let mut rng = Rng::new(seed);
    let xs: Vec<Vec<f32>> = (0..world).map(|_| rng.vec_f32(m * GEMM_K)).collect();
    let ws: Vec<Vec<f32>> = (0..world).map(|_| rng.vec_f32(GEMM_K * GEMM_N)).collect();

    let mut store = BufferStore::new(world);
    store.declare("x", &[m, GEMM_K])?;
    store.declare("w", &[GEMM_K, GEMM_N])?;
    store.declare("y", &[m, GEMM_N])?;
    for r in 0..world {
        store.set(r, "x", &xs[r])?;
        store.set(r, "w", &ws[r])?;
    }

    let mut inputs = Vec::new();
    for rank in 0..world {
        let map = rows_map(&sched, rank, &grid, None, Some("y"))?;
        let order = TileScheduler::row_major(&grid);
        let sync = plan_rank_sync(rank, &sched, &order, &map)?;
        let mut tile_calls: HashMap<usize, Vec<CallSpec>> = HashMap::new();
        for t in 0..grid.num_tiles() {
            let c = grid.coords(t)?;
            let (r0, r1) = grid.axis_span(0, c[0]);
            tile_calls.insert(
                t,
                vec![CallSpec::GemmRows {
                    artifact: artifact.clone(),
                    a: "x".into(),
                    b: "w".into(),
                    out: "y".into(),
                    rows: (r0, r1),
                    // reduce transfers also add into y: everything commutes
                    accumulate: true,
                }],
            );
        }
        inputs.push(RankComputeInput {
            grid: grid.clone(),
            order,
            sync,
            tile_flops: vec![2.0 * bm as f64 * GEMM_N as f64 * GEMM_K as f64; grid.num_tiles()],
            tile_calls,
        });
    }
    let plan = compile(&sched, &inputs, default_real(topo, true), topo)?;

    // oracle: full reduced Y
    let partials: Vec<Vec<f32>> =
        (0..world).map(|r| host_gemm(&xs[r], &ws[r], m, GEMM_K, GEMM_N)).collect();
    let refs: Vec<&[f32]> = partials.iter().map(|p| p.as_slice()).collect();
    let y_sum = host_sum(&refs);

    let checks = (0..world)
        .map(|r| {
            if all_reduce {
                Check {
                    rank: r,
                    tensor: "y".into(),
                    expected: y_sum.clone(),
                    what: format!("full AR y@rank{r}"),
                }
            } else {
                // RS: only shard r is guaranteed reduced at rank r
                let mut expected = partials[r].clone();
                let a = r * shard * GEMM_N;
                expected[a..a + shard * GEMM_N]
                    .copy_from_slice(&y_sum[a..a + shard * GEMM_N]);
                Check {
                    rank: r,
                    tensor: "y".into(),
                    expected,
                    what: format!("RS shard {r}@rank{r}"),
                }
            }
        })
        .collect();
    let name = if all_reduce { "gemm-ar" } else { "gemm-rs" };
    Ok(ExecCase {
        name: format!("{name}-w{world}"),
        sched,
        plan,
        store,
        checks,
        topo: topo.clone(),
    })
}

/// A2A-GEMM: block exchange then per-block GEMM on received tokens.
/// Runs on the default catalog topology; see [`a2a_gemm_on`].
pub fn a2a_gemm(world: usize, seed: u64) -> Result<ExecCase> {
    check_world("a2a-gemm", world)?;
    a2a_gemm_on(&crate::hw::catalog::topology(crate::hw::catalog::DEFAULT, world)?, seed)
}

/// [`a2a_gemm`] on an explicit topology.
pub fn a2a_gemm_on(topo: &Topology, seed: u64) -> Result<ExecCase> {
    let world = topo.world;
    check_world("a2a-gemm", world)?;
    let blk = 8usize;
    let artifact = format!("gemm_{blk}x{GEMM_K}x{GEMM_N}");
    let m = world * world * blk;

    let mut table = TensorTable::new();
    let x = table.declare("x", &[m, GEMM_K], crate::chunk::DType::F32)?;
    table.declare("w", &[GEMM_K, GEMM_N], crate::chunk::DType::F32)?;
    table.declare("y", &[m, GEMM_N], crate::chunk::DType::F32)?;
    let sched = templates::all_to_all(&table, x, 0, world)?;

    let grid = TileGrid::new(vec![
        Axis::new("M", m, blk)?,
        Axis::new("N", GEMM_N, GEMM_N)?,
    ])?;
    let mut rng = Rng::new(seed);
    let x_global = rng.vec_f32(m * GEMM_K);
    let ws: Vec<Vec<f32>> = (0..world).map(|_| rng.vec_f32(GEMM_K * GEMM_N)).collect();

    let mut store = BufferStore::new(world);
    store.declare("x", &[m, GEMM_K])?;
    store.declare("w", &[GEMM_K, GEMM_N])?;
    store.declare("y", &[m, GEMM_N])?;
    for r in 0..world {
        // rank r owns row blocks (r, *): global rows [r*w*blk, (r+1)*w*blk)
        let mut xr = vec![0.0f32; m * GEMM_K];
        let a = r * world * blk * GEMM_K;
        xr[a..a + world * blk * GEMM_K].copy_from_slice(&x_global[a..a + world * blk * GEMM_K]);
        store.set(r, "x", &xr)?;
        store.set(r, "w", &ws[r])?;
    }

    let mut inputs = Vec::new();
    for rank in 0..world {
        let map = rows_map(&sched, rank, &grid, Some("x"), None)?;
        let order = chunk_major_order(&grid, &map, rank)?;
        let sync = plan_rank_sync(rank, &sched, &order, &map)?;
        // rank j computes blocks (i, j) for all i — global rows (i*w + j)*blk
        let mut tile_calls: HashMap<usize, Vec<CallSpec>> = HashMap::new();
        for i in 0..world {
            let r0 = (i * world + rank) * blk;
            let tile = grid.linear(&[r0 / blk, 0])?;
            tile_calls.insert(
                tile,
                vec![CallSpec::GemmRows {
                    artifact: artifact.clone(),
                    a: "x".into(),
                    b: "w".into(),
                    out: "y".into(),
                    rows: (r0, r0 + blk),
                    accumulate: false,
                }],
            );
        }
        inputs.push(RankComputeInput {
            grid: grid.clone(),
            order,
            sync,
            tile_flops: vec![2.0 * blk as f64 * GEMM_N as f64 * GEMM_K as f64; grid.num_tiles()],
            tile_calls,
        });
    }
    let plan = compile(&sched, &inputs, default_real(topo, false), topo)?;

    let mut checks = Vec::new();
    for j in 0..world {
        let mut expected = vec![0.0f32; m * GEMM_N];
        for i in 0..world {
            let r0 = (i * world + j) * blk;
            let yrows = host_gemm(
                &x_global[r0 * GEMM_K..(r0 + blk) * GEMM_K],
                &ws[j],
                blk,
                GEMM_K,
                GEMM_N,
            );
            expected[r0 * GEMM_N..(r0 + blk) * GEMM_N].copy_from_slice(&yrows);
        }
        checks.push(Check {
            rank: j,
            tensor: "y".into(),
            expected,
            what: format!("column blocks @rank{j}"),
        });
    }
    Ok(ExecCase {
        name: format!("a2a-gemm-w{world}"),
        sched,
        plan,
        store,
        checks,
        topo: topo.clone(),
    })
}

/// RingAttention: rotate K/V shards around the ring, folding each arrival
/// with the online-softmax Pallas step; finalize at the end.
/// Runs on the default catalog topology; see [`ring_attention_on`].
pub fn ring_attention(world: usize, split: usize, seed: u64) -> Result<ExecCase> {
    check_world("ring-attn", world)?;
    ring_attention_on(
        &crate::hw::catalog::topology(crate::hw::catalog::DEFAULT, world)?,
        split,
        seed,
    )
}

/// [`ring_attention`] on an explicit topology.
pub fn ring_attention_on(topo: &Topology, split: usize, seed: u64) -> Result<ExecCase> {
    let world = topo.world;
    check_world("ring-attn", world)?;
    let shard = ATTN_SQ; // K/V rows per rank
    check_split("ring-attn", split, shard)?;
    let ch = shard / split;
    let step_artifact = format!("attn_step_q{ATTN_SQ}d{ATTN_D}k{ch}");
    let fin_artifact = format!("attn_finalize_q{ATTN_SQ}d{ATTN_D}");
    let s_total = world * shard;

    let mut table = TensorTable::new();
    let k = table.declare("k", &[s_total, ATTN_D], crate::chunk::DType::F32)?;
    let v = table.declare("v", &[s_total, ATTN_D], crate::chunk::DType::F32)?;
    for (name, shape) in [
        ("q", vec![ATTN_SQ, ATTN_D]),
        ("acc", vec![ATTN_SQ, ATTN_D]),
        ("m", vec![ATTN_SQ]),
        ("l", vec![ATTN_SQ]),
        ("o", vec![ATTN_SQ, ATTN_D]),
    ] {
        table.declare(name, &shape, crate::chunk::DType::F32)?;
    }
    let mut sched = templates::all_gather_ring(&table, k, 0, world)?;
    let sv = templates::all_gather_ring(&table, v, 0, world)?;
    sched.append(&sv)?;
    let sched = sched.split_p2p(0, split)?;

    // grid: one Q block x one tile per KV chunk
    let grid = TileGrid::new(vec![Axis::new("S", s_total, ch)?])?;

    let mut rng = Rng::new(seed);
    let qs: Vec<Vec<f32>> = (0..world).map(|_| rng.vec_f32(ATTN_SQ * ATTN_D)).collect();
    let k_global = rng.vec_f32(s_total * ATTN_D);
    let v_global = rng.vec_f32(s_total * ATTN_D);

    let mut store = BufferStore::new(world);
    for (name, shape) in [
        ("k", vec![s_total, ATTN_D]),
        ("v", vec![s_total, ATTN_D]),
        ("q", vec![ATTN_SQ, ATTN_D]),
        ("acc", vec![ATTN_SQ, ATTN_D]),
        ("m", vec![ATTN_SQ]),
        ("l", vec![ATTN_SQ]),
        ("o", vec![ATTN_SQ, ATTN_D]),
    ] {
        store.declare(name, &shape)?;
    }
    for r in 0..world {
        let mut kr = vec![0.0f32; s_total * ATTN_D];
        let mut vr = vec![0.0f32; s_total * ATTN_D];
        let a = r * shard * ATTN_D;
        kr[a..a + shard * ATTN_D].copy_from_slice(&k_global[a..a + shard * ATTN_D]);
        vr[a..a + shard * ATTN_D].copy_from_slice(&v_global[a..a + shard * ATTN_D]);
        store.set(r, "k", &kr)?;
        store.set(r, "v", &vr)?;
        store.set(r, "q", &qs[r])?;
        store.set(r, "m", &[-1e30f32; ATTN_SQ])?;
    }

    let mut inputs = Vec::new();
    for rank in 0..world {
        // consumers: arrivals of BOTH k and v chunks feed the S tile of
        // those rows; wait for both before folding.
        let mut map = ChunkTileMap::default();
        for (r, ops) in sched.per_rank.iter().enumerate() {
            for (index, op) in ops.iter().enumerate() {
                if op.dst_rank(r) != rank {
                    continue;
                }
                let reg = &op.produced_chunk().region;
                let tiles = grid
                    .tiles_intersecting(&[Some((reg.offset[0], reg.offset[0] + reg.sizes[0]))])?;
                map.consumers.entry(OpRef { rank: r, index }).or_default().extend(tiles);
            }
        }
        let order = chunk_major_order(&grid, &map, rank)?;
        let sync = plan_rank_sync(rank, &sched, &order, &map)?;
        let mut tile_calls: HashMap<usize, Vec<CallSpec>> = HashMap::new();
        for t in 0..grid.num_tiles() {
            let (k0, k1) = grid.axis_span(0, grid.coords(t)?[0]);
            tile_calls.insert(
                t,
                vec![CallSpec::AttnStep {
                    artifact: step_artifact.clone(),
                    q: "q".into(),
                    k: "k".into(),
                    v: "v".into(),
                    kv_rows: (k0, k1),
                    acc: "acc".into(),
                    m: "m".into(),
                    l: "l".into(),
                }],
            );
        }
        // the LAST tile in visit order also finalizes
        let last = *order.order.last().expect("non-empty grid");
        tile_calls.get_mut(&last).unwrap().push(CallSpec::AttnFinalize {
            artifact: fin_artifact.clone(),
            acc: "acc".into(),
            l: "l".into(),
            out: "o".into(),
        });
        let flops = 4.0 * ATTN_SQ as f64 * ch as f64 * ATTN_D as f64;
        inputs.push(RankComputeInput {
            grid: grid.clone(),
            order,
            sync,
            tile_flops: vec![flops; grid.num_tiles()],
            tile_calls,
        });
    }
    let plan = compile(&sched, &inputs, default_real(topo, false), topo)?;
    let _ = v;

    let scale = 1.0 / (ATTN_D as f32).sqrt();
    let checks = (0..world)
        .map(|r| Check {
            rank: r,
            tensor: "o".into(),
            expected: host_attention(&qs[r], &k_global, &v_global, ATTN_SQ, s_total, ATTN_D, scale),
            what: format!("ring attention output @rank{r}"),
        })
        .collect();
    Ok(ExecCase {
        name: format!("ring-attn-w{world}-s{split}"),
        sched,
        plan,
        store,
        checks,
        topo: topo.clone(),
    })
}

/// AG-GEMM over a TWO-LEVEL mesh using the heterogeneous hierarchical
/// swizzle of Fig. 4(e): intra-node ring, cross-node mirror exchange, and
/// pipelined intra-node redistribution — executed with REAL numerics.
/// `nodes * rpn` ranks; validates that the multi-level schedule's deps
/// deliver every shard exactly once and the chunked GEMM still matches.
pub fn ag_gemm_hierarchical(nodes: usize, rpn: usize, seed: u64) -> Result<ExecCase> {
    if nodes == 0 || rpn == 0 {
        return Err(Error::Coordinator(format!(
            "ag-gemm-hier: need nodes >= 1 and ranks-per-node >= 1 (got {nodes}x{rpn})"
        )));
    }
    let world = nodes * rpn;
    check_world("ag-gemm-hier", world)?;
    ag_gemm_hierarchical_on(
        &crate::hw::catalog::topology_nodes("h100_multinode", nodes, world)?,
        seed,
    )
}

/// [`ag_gemm_hierarchical`] on an explicit topology; node structure (and
/// hence the schedule's level split) comes from the topology itself. On a
/// single-node topology the hierarchical template degenerates to the
/// intra-node ring.
pub fn ag_gemm_hierarchical_on(topo: &Topology, seed: u64) -> Result<ExecCase> {
    let world = topo.world;
    check_world("ag-gemm-hier", world)?;
    let (rpn, nodes) = (topo.ranks_per_node, world / topo.ranks_per_node);
    let shard = 16usize;
    let artifact = format!("gemm_{shard}x{GEMM_K}x{GEMM_N}");
    let m = world * shard;

    let mut table = TensorTable::new();
    let x = table.declare("x", &[m, GEMM_K], crate::chunk::DType::F32)?;
    table.declare("w", &[GEMM_K, GEMM_N], crate::chunk::DType::F32)?;
    table.declare("y", &[m, GEMM_N], crate::chunk::DType::F32)?;
    let sched = templates::all_gather_hierarchical(&table, x, 0, topo)?;

    let grid = TileGrid::new(vec![
        Axis::new("M", m, shard)?,
        Axis::new("N", GEMM_N, GEMM_N)?,
    ])?;
    let mut rng = Rng::new(seed);
    let x_global = rng.vec_f32(m * GEMM_K);
    let ws: Vec<Vec<f32>> = (0..world).map(|_| rng.vec_f32(GEMM_K * GEMM_N)).collect();

    let mut store = BufferStore::new(world);
    store.declare("x", &[m, GEMM_K])?;
    store.declare("w", &[GEMM_K, GEMM_N])?;
    store.declare("y", &[m, GEMM_N])?;
    for r in 0..world {
        let mut xr = vec![0.0f32; m * GEMM_K];
        let a = r * shard * GEMM_K;
        xr[a..a + shard * GEMM_K].copy_from_slice(&x_global[a..a + shard * GEMM_K]);
        store.set(r, "x", &xr)?;
        store.set(r, "w", &ws[r])?;
    }

    let mut inputs = Vec::new();
    for rank in 0..world {
        let map = rows_map(&sched, rank, &grid, Some("x"), None)?;
        let order = chunk_major_order(&grid, &map, rank)?;
        let sync = plan_rank_sync(rank, &sched, &order, &map)?;
        let mut tile_calls: HashMap<usize, Vec<CallSpec>> = HashMap::new();
        for t in 0..grid.num_tiles() {
            let (r0, r1) = grid.axis_span(0, grid.coords(t)?[0]);
            tile_calls.insert(
                t,
                vec![CallSpec::GemmRows {
                    artifact: artifact.clone(),
                    a: "x".into(),
                    b: "w".into(),
                    out: "y".into(),
                    rows: (r0, r1),
                    accumulate: false,
                }],
            );
        }
        inputs.push(RankComputeInput {
            grid: grid.clone(),
            order,
            sync,
            tile_flops: vec![2.0 * shard as f64 * GEMM_N as f64 * GEMM_K as f64; grid.num_tiles()],
            tile_calls,
        });
    }
    // arch-aware default: inter-node-capable on a multinode mesh (ld/st on
    // the catalog arches — TMA / copy engine cannot cross nodes)
    let plan = compile(&sched, &inputs, default_real(topo, false), topo)?;
    let checks = (0..world)
        .map(|r| Check {
            rank: r,
            tensor: "y".into(),
            expected: host_gemm(&x_global, &ws[r], m, GEMM_K, GEMM_N),
            what: format!("hierarchical AG y@rank{r}"),
        })
        .collect();
    Ok(ExecCase {
        name: format!("ag-gemm-hier-{nodes}x{rpn}"),
        sched,
        plan,
        store,
        checks,
        topo: topo.clone(),
    })
}

/// Sequence-parallel attention at validation scale: gather K/V shards with
/// the direct pull swizzle (no ring deps), fold each arrival blockwise —
/// the AttnSp pattern of Fig. 9 with real numerics.
pub fn attn_sp(world: usize, seed: u64) -> Result<ExecCase> {
    check_world("attn-sp", world)?;
    attn_sp_on(&crate::hw::catalog::topology(crate::hw::catalog::DEFAULT, world)?, seed)
}

/// [`attn_sp`] on an explicit topology.
pub fn attn_sp_on(topo: &Topology, seed: u64) -> Result<ExecCase> {
    let world = topo.world;
    check_world("attn-sp", world)?;
    let shard = ATTN_SQ;
    let step_artifact = format!("attn_step_q{ATTN_SQ}d{ATTN_D}k{shard}");
    let fin_artifact = format!("attn_finalize_q{ATTN_SQ}d{ATTN_D}");
    let s_total = world * shard;

    let mut table = TensorTable::new();
    let k = table.declare("k", &[s_total, ATTN_D], crate::chunk::DType::F32)?;
    let v = table.declare("v", &[s_total, ATTN_D], crate::chunk::DType::F32)?;
    for (name, shape) in [
        ("q", vec![ATTN_SQ, ATTN_D]),
        ("acc", vec![ATTN_SQ, ATTN_D]),
        ("m", vec![ATTN_SQ]),
        ("l", vec![ATTN_SQ]),
        ("o", vec![ATTN_SQ, ATTN_D]),
    ] {
        table.declare(name, &shape, crate::chunk::DType::F32)?;
    }
    let mut sched = templates::all_gather_swizzle(&table, k, 0, world)?;
    sched.append(&templates::all_gather_swizzle(&table, v, 0, world)?)?;

    let grid = TileGrid::new(vec![Axis::new("S", s_total, shard)?])?;
    let mut rng = Rng::new(seed);
    let qs: Vec<Vec<f32>> = (0..world).map(|_| rng.vec_f32(ATTN_SQ * ATTN_D)).collect();
    let k_global = rng.vec_f32(s_total * ATTN_D);
    let v_global = rng.vec_f32(s_total * ATTN_D);

    let mut store = BufferStore::new(world);
    for (name, shape) in [
        ("k", vec![s_total, ATTN_D]),
        ("v", vec![s_total, ATTN_D]),
        ("q", vec![ATTN_SQ, ATTN_D]),
        ("acc", vec![ATTN_SQ, ATTN_D]),
        ("m", vec![ATTN_SQ]),
        ("l", vec![ATTN_SQ]),
        ("o", vec![ATTN_SQ, ATTN_D]),
    ] {
        store.declare(name, &shape)?;
    }
    for r in 0..world {
        let mut kr = vec![0.0f32; s_total * ATTN_D];
        let mut vr = vec![0.0f32; s_total * ATTN_D];
        let a = r * shard * ATTN_D;
        kr[a..a + shard * ATTN_D].copy_from_slice(&k_global[a..a + shard * ATTN_D]);
        vr[a..a + shard * ATTN_D].copy_from_slice(&v_global[a..a + shard * ATTN_D]);
        store.set(r, "k", &kr)?;
        store.set(r, "v", &vr)?;
        store.set(r, "q", &qs[r])?;
        store.set(r, "m", &[-1e30f32; ATTN_SQ])?;
    }

    let mut inputs = Vec::new();
    for rank in 0..world {
        let mut map = ChunkTileMap::default();
        for (r, ops) in sched.per_rank.iter().enumerate() {
            for (index, op) in ops.iter().enumerate() {
                if op.dst_rank(r) != rank {
                    continue;
                }
                let reg = &op.produced_chunk().region;
                let tiles = grid
                    .tiles_intersecting(&[Some((reg.offset[0], reg.offset[0] + reg.sizes[0]))])?;
                map.consumers.entry(OpRef { rank: r, index }).or_default().extend(tiles);
            }
        }
        let order = chunk_major_order(&grid, &map, rank)?;
        let sync = plan_rank_sync(rank, &sched, &order, &map)?;
        let mut tile_calls: HashMap<usize, Vec<CallSpec>> = HashMap::new();
        for t in 0..grid.num_tiles() {
            let (k0, k1) = grid.axis_span(0, grid.coords(t)?[0]);
            tile_calls.insert(
                t,
                vec![CallSpec::AttnStep {
                    artifact: step_artifact.clone(),
                    q: "q".into(),
                    k: "k".into(),
                    v: "v".into(),
                    kv_rows: (k0, k1),
                    acc: "acc".into(),
                    m: "m".into(),
                    l: "l".into(),
                }],
            );
        }
        let last = *order.order.last().expect("non-empty grid");
        tile_calls.get_mut(&last).unwrap().push(CallSpec::AttnFinalize {
            artifact: fin_artifact.clone(),
            acc: "acc".into(),
            l: "l".into(),
            out: "o".into(),
        });
        inputs.push(RankComputeInput {
            grid: grid.clone(),
            order,
            sync,
            tile_flops: vec![4.0 * ATTN_SQ as f64 * shard as f64 * ATTN_D as f64; grid.num_tiles()],
            tile_calls,
        });
    }
    let plan = compile(&sched, &inputs, default_real(topo, false), topo)?;
    let _ = v;

    let scale = 1.0 / (ATTN_D as f32).sqrt();
    let checks = (0..world)
        .map(|r| Check {
            rank: r,
            tensor: "o".into(),
            expected: host_attention(&qs[r], &k_global, &v_global, ATTN_SQ, s_total, ATTN_D, scale),
            what: format!("SP attention output @rank{r}"),
        })
        .collect();
    Ok(ExecCase {
        name: format!("attn-sp-w{world}"),
        sched,
        plan,
        store,
        checks,
        topo: topo.clone(),
    })
}

// ---------------------------------------------------------------------------
// Fused cross-operator pipelines (`crate::pipeline`): multiple operators'
// chunk schedules composed into ONE barrier-free plan. These are the
// repro's demonstration of the paper's kernel-boundary-sync claim: every
// other case overlaps comm and compute *within* one operator; these two
// overlap *across* the operator seam.
// ---------------------------------------------------------------------------

/// Fused tensor-parallel MLP block: AG-GEMM → GEMM-RS with no barrier at
/// the operator boundary.
///
/// Stage 1 gathers row-sharded `x` and computes the rank-private hidden
/// `h = X_full @ w1_r`; stage 2 computes the partial output
/// `y_r = h @ w2_r` and ReduceScatters it so rank `j` ends owning the
/// fully-reduced row shard `j` of `Y = Σ_r X·w1_r·w2_r` — the exact
/// math of a TP MLP block. The combined tile grid interleaves each stage-2
/// tile right behind the stage-1 tile producing its input, so the reduce
/// push of output shard `j` issues the moment rows `j` of `h·w2` exist,
/// while later `x` chunks are still in flight.
pub fn tp_block(world: usize, split: usize, seed: u64) -> Result<ExecCase> {
    check_world("tp-block", world)?;
    tp_block_on(
        &crate::hw::catalog::topology(crate::hw::catalog::DEFAULT, world)?,
        split,
        seed,
    )
}

/// [`tp_block`] on an explicit topology.
pub fn tp_block_on(topo: &Topology, split: usize, seed: u64) -> Result<ExecCase> {
    let world = topo.world;
    check_world("tp-block", world)?;
    let shard = 16usize;
    check_split("tp-block", split, shard)?;
    let bm = shard / split;
    // stage 1 contracts over GEMM_K (x @ w1), stage 2 over GEMM_N
    // (h @ w2) — equal at the canonical shapes, but kept distinct so the
    // artifacts/flops stay right if the canon ever diverges
    let artifact1 = format!("gemm_{bm}x{GEMM_K}x{GEMM_N}");
    let artifact2 = format!("gemm_{bm}x{GEMM_N}x{GEMM_N}");
    let m = world * shard;

    // Stage schedules over their own tensor tables; pipeline::fuse merges
    // the namespaces and validates the fused plan. The split knob then
    // refines BOTH stages' transfers, like any single-operator schedule.
    let mut t1 = TensorTable::new();
    let x = t1.declare("x", &[m, GEMM_K], crate::chunk::DType::F32)?;
    let mut t2 = TensorTable::new();
    let y = t2.declare("y", &[m, GEMM_N], crate::chunk::DType::F32)?;
    let fused = pipeline::fuse(&[
        Stage::new("ag", templates::all_gather_swizzle(&t1, x, 0, world)?),
        Stage::new("rs", templates::reduce_scatter_direct(&t2, y, 0, world)?),
    ])?;
    let sched = fused.sched.split_p2p(0, split)?;
    let y_id = sched.tensors.lookup("y").expect("fused table keeps y");

    // Combined grid: tiles [0, m/bm) are the stage-1 h tiles, tiles
    // [m/bm, 2m/bm) the stage-2 y tiles over the same rows.
    let half = m / bm;
    let grid = TileGrid::new(vec![Axis::new("P", 2 * m, bm)?])?;

    let mut rng = Rng::new(seed);
    let x_global = rng.vec_f32(m * GEMM_K);
    let w1s: Vec<Vec<f32>> = (0..world).map(|_| rng.vec_f32(GEMM_K * GEMM_N)).collect();
    let w2s: Vec<Vec<f32>> = (0..world).map(|_| rng.vec_f32(GEMM_N * GEMM_N)).collect();

    let mut store = BufferStore::new(world);
    store.declare("x", &[m, GEMM_K])?;
    store.declare("w1", &[GEMM_K, GEMM_N])?;
    store.declare("h", &[m, GEMM_N])?;
    store.declare("w2", &[GEMM_N, GEMM_N])?;
    store.declare("y", &[m, GEMM_N])?;
    for r in 0..world {
        let mut xr = vec![0.0f32; m * GEMM_K];
        let a = r * shard * GEMM_K;
        xr[a..a + shard * GEMM_K].copy_from_slice(&x_global[a..a + shard * GEMM_K]);
        store.set(r, "x", &xr)?;
        store.set(r, "w1", &w1s[r])?;
        store.set(r, "w2", &w2s[r])?;
    }

    let mut inputs = Vec::new();
    for rank in 0..world {
        // Chunk↔tile containment over the COMBINED grid: incoming x chunks
        // feed the h tiles of their rows (rows_map, identity coordinates);
        // outgoing y reduce pushes are fed by the y tiles of theirs, whose
        // combined-grid coordinates sit at +m. This is the fine-grained
        // boundary sync: no op anywhere waits for "stage 1 done".
        let mut map = rows_map(&sched, rank, &grid, Some("x"), None)?;
        for (index, op) in sched.per_rank[rank].iter().enumerate() {
            if op.consumed_chunk().tensor == y_id {
                let reg = &op.consumed_chunk().region;
                map.producers.entry(OpRef { rank, index }).or_default().extend(
                    grid.tiles_intersecting(&[Some((
                        m + reg.offset[0],
                        m + reg.offset[0] + reg.sizes[0],
                    ))])?,
                );
            }
        }
        // Visiting order: local row blocks first, then x-chunk arrival
        // order — each h tile immediately followed by the y tile it feeds.
        let groups = map.consumer_groups(rank);
        let mut covered = vec![false; half];
        for tiles in groups.values() {
            for &t in tiles {
                covered[t] = true; // consumer tiles are h tiles (< half)
            }
        }
        let mut order = Vec::with_capacity(2 * half);
        for (t, seen) in covered.iter().enumerate() {
            if !seen {
                order.push(t);
                order.push(t + half);
            }
        }
        for k in 0..groups.len() {
            for &t in &groups[&k] {
                order.push(t);
                order.push(t + half);
            }
        }
        let order = TileScheduler { order };
        let sync = plan_rank_sync(rank, &sched, &order, &map)?;
        let mut tile_calls: HashMap<usize, Vec<CallSpec>> = HashMap::new();
        for t in 0..half {
            let rows = (t * bm, (t + 1) * bm);
            tile_calls.insert(
                t,
                vec![CallSpec::GemmRows {
                    artifact: artifact1.clone(),
                    a: "x".into(),
                    b: "w1".into(),
                    out: "h".into(),
                    rows,
                    accumulate: false,
                }],
            );
            tile_calls.insert(
                t + half,
                vec![CallSpec::GemmRows {
                    artifact: artifact2.clone(),
                    a: "h".into(),
                    b: "w2".into(),
                    out: "y".into(),
                    rows,
                    // y also receives reduce transfers: all contributions
                    // commute, plan_prep serializes them canonically
                    accumulate: true,
                }],
            );
        }
        let mut tile_flops = vec![2.0 * bm as f64 * GEMM_N as f64 * GEMM_K as f64; half];
        tile_flops.extend(vec![2.0 * bm as f64 * GEMM_N as f64 * GEMM_N as f64; half]);
        inputs.push(RankComputeInput {
            grid: grid.clone(),
            order,
            sync,
            tile_flops,
            tile_calls,
        });
    }
    let plan = compile(&sched, &inputs, default_real(topo, true), topo)?;

    // oracle: h_r = X @ W1_r; Y = Σ_r h_r @ W2_r; rank r owns shard r of Y
    let hs: Vec<Vec<f32>> =
        (0..world).map(|r| host_gemm(&x_global, &w1s[r], m, GEMM_K, GEMM_N)).collect();
    let partials: Vec<Vec<f32>> =
        (0..world).map(|r| host_gemm(&hs[r], &w2s[r], m, GEMM_N, GEMM_N)).collect();
    let refs: Vec<&[f32]> = partials.iter().map(|p| p.as_slice()).collect();
    let y_sum = host_sum(&refs);
    let mut checks = Vec::new();
    for r in 0..world {
        checks.push(Check {
            rank: r,
            tensor: "h".into(),
            expected: hs[r].clone(),
            what: format!("fused TP block: h@rank{r} == X_full @ W1_{r}"),
        });
        let mut expected = partials[r].clone();
        let a = r * shard * GEMM_N;
        expected[a..a + shard * GEMM_N].copy_from_slice(&y_sum[a..a + shard * GEMM_N]);
        checks.push(Check {
            rank: r,
            tensor: "y".into(),
            expected,
            what: format!("fused TP block: reduced shard {r}@rank{r}"),
        });
    }
    Ok(ExecCase {
        name: format!("tp-block-w{world}-s{split}"),
        sched,
        plan,
        store,
        checks,
        topo: topo.clone(),
    })
}

/// Per-stage plans of the tp-block pipeline (same shapes, flops and
/// realization as [`tp_block`], no attached numerics). The
/// barrier-at-boundary baseline runs stage N+1 only after stage N's plan
/// fully completes device-wide, so its makespan is the SUM of these plans'
/// simulated makespans — each stage keeps its *internal* overlap, exactly
/// like per-operator overlapped kernels that still sync at the seam
/// (DESIGN.md §12). `reports::pipeline` scores fused vs. this.
pub fn tp_block_stage_plans(world: usize, split: usize) -> Result<Vec<ExecutablePlan>> {
    check_world("tp-block", world)?;
    tp_block_stage_plans_on(
        &crate::hw::catalog::topology(crate::hw::catalog::DEFAULT, world)?,
        split,
    )
}

/// [`tp_block_stage_plans`] on an explicit topology.
pub fn tp_block_stage_plans_on(topo: &Topology, split: usize) -> Result<Vec<ExecutablePlan>> {
    let world = topo.world;
    check_world("tp-block", world)?;
    let shard = 16usize;
    check_split("tp-block", split, shard)?;
    let bm = shard / split;
    let m = world * shard;
    // stage-specific contraction depths, as in tp_block
    let flops1 = 2.0 * bm as f64 * GEMM_N as f64 * GEMM_K as f64;
    let flops2 = 2.0 * bm as f64 * GEMM_N as f64 * GEMM_N as f64;
    let grid = TileGrid::new(vec![Axis::new("M", m, bm)?])?;

    // stage 1: AllGather(x) overlapped with the h tiles
    let mut t1 = TensorTable::new();
    let x = t1.declare("x", &[m, GEMM_K], crate::chunk::DType::F32)?;
    let s1 = templates::all_gather_swizzle(&t1, x, 0, world)?.split_p2p(0, split)?;
    let mut inputs = Vec::new();
    for rank in 0..world {
        let map = rows_map(&s1, rank, &grid, Some("x"), None)?;
        let order = chunk_major_order(&grid, &map, rank)?;
        let sync = plan_rank_sync(rank, &s1, &order, &map)?;
        inputs.push(RankComputeInput {
            grid: grid.clone(),
            order,
            sync,
            tile_flops: vec![flops1; grid.num_tiles()],
            tile_calls: HashMap::new(),
        });
    }
    let p1 = compile(&s1, &inputs, default_real(topo, true), topo)?;

    // stage 2: the y tiles overlapped with the ReduceScatter of their shards
    let mut t2 = TensorTable::new();
    let y = t2.declare("y", &[m, GEMM_N], crate::chunk::DType::F32)?;
    let s2 = templates::reduce_scatter_direct(&t2, y, 0, world)?.split_p2p(0, split)?;
    let mut inputs = Vec::new();
    for rank in 0..world {
        let map = rows_map(&s2, rank, &grid, None, Some("y"))?;
        let order = TileScheduler::row_major(&grid);
        let sync = plan_rank_sync(rank, &s2, &order, &map)?;
        inputs.push(RankComputeInput {
            grid: grid.clone(),
            order,
            sync,
            tile_flops: vec![flops2; grid.num_tiles()],
            tile_calls: HashMap::new(),
        });
    }
    let p2 = compile(&s2, &inputs, default_real(topo, true), topo)?;
    Ok(vec![p1, p2])
}

/// Fused MoE block: AllToAll dispatch → per-rank expert GEMMs → AllToAll
/// combine, as ONE barrier-free plan.
///
/// Token block `(i, j)` (row owner `i`, expert `j`) is dispatched to rank
/// `j`, transformed by expert `j`'s weight the moment it lands, and the
/// result pushed straight back to row owner `i` the moment the expert tile
/// finishes — dispatch, expert compute, and combine are all in flight at
/// once instead of three device-wide phases.
pub fn moe_a2a(world: usize, seed: u64) -> Result<ExecCase> {
    check_world("moe-a2a", world)?;
    moe_a2a_on(&crate::hw::catalog::topology(crate::hw::catalog::DEFAULT, world)?, seed)
}

/// [`moe_a2a`] on an explicit topology.
pub fn moe_a2a_on(topo: &Topology, seed: u64) -> Result<ExecCase> {
    let world = topo.world;
    check_world("moe-a2a", world)?;
    let blk = 8usize;
    let artifact = format!("gemm_{blk}x{GEMM_K}x{GEMM_N}");
    let m = world * world * blk;

    let mut t1 = TensorTable::new();
    let x = t1.declare("x", &[m, GEMM_K], crate::chunk::DType::F32)?;
    let mut t2 = TensorTable::new();
    let y = t2.declare("y", &[m, GEMM_N], crate::chunk::DType::F32)?;
    let fused = pipeline::fuse(&[
        Stage::new("dispatch", templates::all_to_all(&t1, x, 0, world)?),
        Stage::new("combine", templates::all_to_all_transpose(&t2, y, 0, world)?),
    ])?;
    let sched = fused.sched;

    let grid = TileGrid::new(vec![Axis::new("M", m, blk)?])?;
    let mut rng = Rng::new(seed);
    let x_global = rng.vec_f32(m * GEMM_K);
    let ws: Vec<Vec<f32>> = (0..world).map(|_| rng.vec_f32(GEMM_K * GEMM_N)).collect();

    let mut store = BufferStore::new(world);
    store.declare("x", &[m, GEMM_K])?;
    store.declare("w", &[GEMM_K, GEMM_N])?;
    store.declare("y", &[m, GEMM_N])?;
    for r in 0..world {
        // rank r owns token block row r: global rows [r·w·blk, (r+1)·w·blk)
        let mut xr = vec![0.0f32; m * GEMM_K];
        let a = r * world * blk * GEMM_K;
        xr[a..a + world * blk * GEMM_K]
            .copy_from_slice(&x_global[a..a + world * blk * GEMM_K]);
        store.set(r, "x", &xr)?;
        store.set(r, "w", &ws[r])?;
    }

    let flops = 2.0 * blk as f64 * GEMM_N as f64 * GEMM_K as f64;
    let mut inputs = Vec::new();
    for rank in 0..world {
        // incoming x blocks feed the expert tiles of their rows; outgoing
        // y combine pushes are fed by the tiles that computed their blocks
        let map = rows_map(&sched, rank, &grid, Some("x"), Some("y"))?;
        let order = chunk_major_order(&grid, &map, rank)?;
        let sync = plan_rank_sync(rank, &sched, &order, &map)?;
        // expert `rank` computes blocks (i, rank): global rows (i·w+rank)·blk
        let mut tile_calls: HashMap<usize, Vec<CallSpec>> = HashMap::new();
        let mut tile_flops = vec![0.0f64; grid.num_tiles()];
        for i in 0..world {
            let r0 = (i * world + rank) * blk;
            let tile = r0 / blk;
            tile_flops[tile] = flops;
            tile_calls.insert(
                tile,
                vec![CallSpec::GemmRows {
                    artifact: artifact.clone(),
                    a: "x".into(),
                    b: "w".into(),
                    out: "y".into(),
                    rows: (r0, r0 + blk),
                    accumulate: false,
                }],
            );
        }
        inputs.push(RankComputeInput { grid: grid.clone(), order, sync, tile_flops, tile_calls });
    }
    let plan = compile(&sched, &inputs, default_real(topo, false), topo)?;

    // oracle: rank r ends with its combined row blocks (r, *) plus the
    // expert outputs it computed locally, blocks (*, r); the rest stays 0
    let mut checks = Vec::new();
    for r in 0..world {
        let mut expected = vec![0.0f32; m * GEMM_N];
        {
            let mut put = |i: usize, j: usize| {
                let r0 = (i * world + j) * blk;
                let yrows = host_gemm(
                    &x_global[r0 * GEMM_K..(r0 + blk) * GEMM_K],
                    &ws[j],
                    blk,
                    GEMM_K,
                    GEMM_N,
                );
                expected[r0 * GEMM_N..(r0 + blk) * GEMM_N].copy_from_slice(&yrows);
            };
            for j in 0..world {
                put(r, j); // combined row blocks (r, *)
            }
            for i in 0..world {
                put(i, r); // locally computed expert outputs (*, r)
            }
        }
        checks.push(Check {
            rank: r,
            tensor: "y".into(),
            expected,
            what: format!("fused MoE: combined rows + expert outputs @rank{r}"),
        });
    }
    Ok(ExecCase {
        name: format!("moe-a2a-w{world}"),
        sched,
        plan,
        store,
        checks,
        topo: topo.clone(),
    })
}

/// Per-stage plans of the MoE pipeline for the barrier-at-boundary
/// baseline: dispatch AllToAll, then the expert GEMMs, then the combine
/// AllToAll, each as its own device-wide-synced plan (see
/// [`tp_block_stage_plans`]).
pub fn moe_a2a_stage_plans(world: usize) -> Result<Vec<ExecutablePlan>> {
    check_world("moe-a2a", world)?;
    moe_a2a_stage_plans_on(&crate::hw::catalog::topology(crate::hw::catalog::DEFAULT, world)?)
}

/// [`moe_a2a_stage_plans`] on an explicit topology.
pub fn moe_a2a_stage_plans_on(topo: &Topology) -> Result<Vec<ExecutablePlan>> {
    let world = topo.world;
    check_world("moe-a2a", world)?;
    let blk = 8usize;
    let m = world * world * blk;
    let real = default_real(topo, false);

    let mut t1 = TensorTable::new();
    let x = t1.declare("x", &[m, GEMM_K], crate::chunk::DType::F32)?;
    let p1 =
        crate::codegen::compile_comm_only(&templates::all_to_all(&t1, x, 0, world)?, real, topo)?;

    // stage 2: the expert GEMMs alone (no communication)
    let grid = TileGrid::new(vec![Axis::new("M", m, blk)?])?;
    let flops = 2.0 * blk as f64 * GEMM_N as f64 * GEMM_K as f64;
    let empty = CommSchedule::new(world, TensorTable::new());
    let mut inputs = Vec::new();
    for rank in 0..world {
        let mut tile_flops = vec![0.0f64; grid.num_tiles()];
        for i in 0..world {
            tile_flops[i * world + rank] = flops;
        }
        inputs.push(RankComputeInput {
            grid: grid.clone(),
            order: TileScheduler::row_major(&grid),
            sync: crate::depgraph::RankSync::default(),
            tile_flops,
            tile_calls: HashMap::new(),
        });
    }
    let p2 = compile(&empty, &inputs, real, topo)?;

    let mut t3 = TensorTable::new();
    let y = t3.declare("y", &[m, GEMM_N], crate::chunk::DType::F32)?;
    let p3 = crate::codegen::compile_comm_only(
        &templates::all_to_all_transpose(&t3, y, 0, world)?,
        real,
        topo,
    )?;
    Ok(vec![p1, p2, p3])
}

// ---------------------------------------------------------------------------
// Case registry: the single source of truth for named exec cases, shared by
// the CLI (`exec --case NAME`, `exec --case list`) and tests. Adding a case
// here makes it reachable everywhere; unknown-case errors list the registry.
// ---------------------------------------------------------------------------

/// Parameters a registry case may consume (unused fields are ignored by
/// cases that don't take them).
#[derive(Debug, Clone)]
pub struct CaseParams {
    pub world: usize,
    pub split: usize,
    pub seed: u64,
    /// Node count for hierarchical cases (`world` must divide evenly).
    pub nodes: usize,
    /// Topology: a catalog name (`hw::catalog`) or a `.topo` file path.
    pub topo: String,
}

impl Default for CaseParams {
    fn default() -> Self {
        CaseParams {
            world: 4,
            split: 1,
            seed: 42,
            nodes: 2,
            topo: crate::hw::catalog::DEFAULT.to_string(),
        }
    }
}

impl CaseParams {
    /// Range checks every case shares, run before any builder: degenerate
    /// values fail with a named [`Error::Coordinator`] message instead of
    /// panicking deep inside template/grid construction. Builders add
    /// case-specific checks (split divisibility, node factorization) on
    /// top.
    pub fn check(&self, case: &str) -> Result<()> {
        check_world(case, self.world)?;
        if self.split == 0 {
            return Err(Error::Coordinator(format!("{case}: split must be >= 1 (got 0)")));
        }
        if self.nodes == 0 {
            return Err(Error::Coordinator(format!("{case}: nodes must be >= 1 (got 0)")));
        }
        Ok(())
    }

    /// Resolve the requested topology (catalog name or `.topo` file) at
    /// this world size.
    pub fn topology(&self) -> Result<Topology> {
        Ok(crate::hw::catalog::resolve(&self.topo, self.world)?.1)
    }

    /// Topology for the hierarchical case. A multinode description's own
    /// node structure wins; for single-node descriptions the `--nodes`
    /// knob splits the same device/link description across `nodes` (so the
    /// default `h100_node` keeps the case's historical 2-node H100 shape —
    /// structurally identical to `h100_multinode`).
    pub fn hier_topology(&self) -> Result<Topology> {
        let desc = crate::hw::catalog::load_desc(&self.topo)
            .map_err(|e| Error::Coordinator(format!("ag-gemm-hier: {e}")))?;
        if desc.nodes > 1 {
            return desc
                .instantiate(self.world)
                .map_err(|e| Error::Coordinator(format!("ag-gemm-hier: {e}")));
        }
        if self.nodes == 0 {
            return Err(Error::Coordinator(
                "ag-gemm-hier: nodes must be >= 1 (got 0)".into(),
            ));
        }
        if self.world % self.nodes != 0 {
            return Err(Error::Coordinator(format!(
                "ag-gemm-hier: world {} not divisible by nodes {}",
                self.world, self.nodes
            )));
        }
        desc.with_nodes(self.nodes)?
            .instantiate(self.world)
            .map_err(|e| Error::Coordinator(format!("ag-gemm-hier: {e}")))
    }
}

/// One registered validation case.
pub struct CaseSpec {
    pub name: &'static str,
    pub about: &'static str,
    build: fn(&CaseParams) -> Result<ExecCase>,
}

impl CaseSpec {
    pub fn build(&self, p: &CaseParams) -> Result<ExecCase> {
        p.check(self.name)?;
        (self.build)(p)
    }
}

/// The registry, in listing order. Every builder takes its topology from
/// the catalog/file resolution of `p.topo` — no case hardwires a machine.
pub const CASES: &[CaseSpec] = &[
    CaseSpec {
        name: "ag-gemm",
        about: "AllGather (pull swizzle) overlapped with row-sharded GEMM",
        build: |p| ag_gemm_variant_on(&p.topology()?, p.split, p.seed, AgVariant::PullSwizzle),
    },
    CaseSpec {
        name: "gemm-rs",
        about: "GEMM with direct ReduceScatter of output shards",
        build: |p| gemm_rs_on(&p.topology()?, p.seed),
    },
    CaseSpec {
        name: "gemm-ar",
        about: "GEMM with partition-based AllReduce (Fig. 4d)",
        build: |p| gemm_ar_on(&p.topology()?, p.seed),
    },
    CaseSpec {
        name: "a2a-gemm",
        about: "AllToAll block exchange feeding per-block GEMMs",
        build: |p| a2a_gemm_on(&p.topology()?, p.seed),
    },
    CaseSpec {
        name: "ring-attn",
        about: "RingAttention: rotate K/V, fold with online softmax",
        build: |p| ring_attention_on(&p.topology()?, p.split, p.seed),
    },
    CaseSpec {
        name: "attn-sp",
        about: "sequence-parallel attention over a pull-swizzle K/V gather",
        build: |p| attn_sp_on(&p.topology()?, p.seed),
    },
    CaseSpec {
        name: "ag-gemm-hier",
        about: "AG-GEMM on a two-level mesh (Fig. 4e heterogeneous swizzle)",
        build: |p| ag_gemm_hierarchical_on(&p.hier_topology()?, p.seed),
    },
    CaseSpec {
        name: "tp-block",
        about: "fused TP MLP block: AG-GEMM -> GEMM-RS, no boundary barrier",
        build: |p| tp_block_on(&p.topology()?, p.split, p.seed),
    },
    CaseSpec {
        name: "moe-a2a",
        about: "fused MoE block: A2A dispatch -> expert GEMMs -> A2A combine",
        build: |p| moe_a2a_on(&p.topology()?, p.seed),
    },
    CaseSpec {
        name: "ag-gemm-flux",
        about: "AG-GEMM over a Flux-style plan imported via plan_io",
        build: |p| ag_gemm_variant_on(&p.topology()?, p.split, p.seed, AgVariant::ImportedFlux),
    },
    CaseSpec {
        name: "ag-gemm-tdist",
        about: "AG-GEMM over a Triton-distributed-style imported plan",
        build: |p| {
            ag_gemm_variant_on(&p.topology()?, p.split, p.seed, AgVariant::ImportedTritonDist)
        },
    },
];

/// Registered case names, in listing order.
pub fn case_names() -> Vec<&'static str> {
    CASES.iter().map(|c| c.name).collect()
}

/// Build a registered case by name; unknown names list the registry.
pub fn build_case(name: &str, params: &CaseParams) -> Result<ExecCase> {
    let Some(spec) = CASES.iter().find(|c| c.name == name) else {
        return Err(Error::Coordinator(format!(
            "unknown exec case `{name}` (registry: {})",
            case_names().join(", ")
        )));
    };
    spec.build(params)
}

/// A deliberately deadlocking plan — NOT in the [`CASES`] registry (the
/// static-analysis sweep asserts every registered case is clean). Rank 0
/// waits on a signal that only its *own later* transfer would set; every
/// other rank has an empty program. All three engines report a runtime
/// deadlock verdict. Used by `flight dump --deadlock-demo`, the CI flight
/// smoke, and the deadlock-accounting regression tests: a known-bad plan
/// to exercise post-mortem capture without a hand-written `.sched` file.
pub fn deadlock_demo(world: usize) -> Result<ExecCase> {
    check_world("deadlock-demo", world)?;
    let mut table = TensorTable::new();
    let x = table.declare("x", &[4, 4], crate::chunk::DType::F32)?;
    let mut store = BufferStore::new(world);
    store.declare("x", &[4, 4])?;

    let mut per_rank = vec![crate::codegen::RankProgram::default(); world];
    per_rank[0].ops = vec![
        crate::codegen::PlanOp::Wait(0),
        crate::codegen::PlanOp::Issue(crate::testutil::transfer_desc(
            x,
            crate::chunk::Region::rows(0, 2, 4),
            0,
            0,
            1,
            vec![],
            false,
        )),
    ];
    let plan = ExecutablePlan { world, per_rank, num_signals: 1, reserved_comm_sms: 0 };
    let topo = crate::hw::catalog::topology(crate::hw::catalog::DEFAULT, world)?;
    Ok(ExecCase {
        name: format!("deadlock-demo-w{world}"),
        sched: CommSchedule::new(world, table),
        plan,
        store,
        checks: Vec::new(), // it never runs to completion
        topo,
    })
}

#[cfg(test)]
mod tests {
    // These builders are exercised with the real PJRT runtime in
    // rust/tests/integration_exec.rs. Here: structural checks only.
    use super::*;

    #[test]
    fn ag_gemm_structure() {
        let case = ag_gemm(4, 2, 7).unwrap();
        assert_eq!(case.plan.world, 4);
        // 4 ranks x 3 pulls x split 2
        assert_eq!(case.plan.total_transfers(), 4 * 3 * 2);
        assert_eq!(case.checks.len(), 4);
        // every rank waits for 6 incoming chunks
        assert!(case.plan.per_rank.iter().all(|p| p.num_waits() == 6));
    }

    #[test]
    fn invalid_split_rejected() {
        assert!(ag_gemm(2, 5, 0).is_err());
        assert!(ring_attention(2, 5, 0).is_err());
    }

    #[test]
    fn deadlock_demo_reports_verdict_with_flight_context() {
        let case = deadlock_demo(2).unwrap();
        assert!(case.name.starts_with("deadlock-demo"));
        // not in the registry: the analysis sweep must stay clean
        assert!(!case_names().contains(&"deadlock-demo"));
        let rt = Runtime::host_reference();
        let e = run_with(&case.plan, &case.sched.tensors, &case.store, &rt, &ExecOptions::sequential())
            .unwrap_err();
        let msg = e.to_string();
        assert!(msg.contains("deadlock"), "{msg}");
        assert!(msg.contains("rank 0"), "{msg}");
        // post-mortem context: the stuck rank's recent flight events ride
        // along on the verdict (rank 0 recorded at least its blocked wait)
        #[cfg(not(feature = "no-obs"))]
        {
            assert!(msg.contains("recent flight events"), "{msg}");
            assert!(msg.contains("sig-wait"), "{msg}");
        }
    }

    #[test]
    fn gemm_rs_triggers_follow_tiles() {
        let case = gemm_rs(4, 9).unwrap();
        // each rank issues w-1 reduce pushes, none before its producing tile
        for prog in &case.plan.per_rank {
            assert_eq!(prog.num_transfers(), 3);
            // first op must be compute, not a transfer (triggers gated)
            assert!(matches!(prog.ops[0], crate::codegen::PlanOp::Compute(_)));
        }
    }

    #[test]
    fn ring_attention_structure() {
        let case = ring_attention(4, 1, 3).unwrap();
        // k and v rings: 2 tensors x 3 steps per rank
        assert_eq!(case.plan.total_transfers(), 4 * 6);
        // each rank folds 4 chunks: 4 attn steps + 1 finalize call
        let calls: usize = case.plan.per_rank[0]
            .ops
            .iter()
            .map(|o| match o {
                crate::codegen::PlanOp::Compute(c) => c.calls.len(),
                _ => 0,
            })
            .sum();
        assert_eq!(calls, 5);
    }

    #[test]
    fn a2a_structure() {
        let case = a2a_gemm(2, 5).unwrap();
        assert_eq!(case.plan.total_transfers(), 2);
        assert_eq!(case.checks.len(), 2);
    }

    #[test]
    fn registry_builds_every_case() {
        let p = CaseParams::default();
        for spec in CASES {
            let case = spec.build(&p).unwrap_or_else(|e| panic!("{}: {e}", spec.name));
            assert_eq!(case.plan.world, p.world, "{}", spec.name);
            assert!(!case.checks.is_empty(), "{}", spec.name);
        }
    }

    #[test]
    fn tp_block_structure() {
        let case = tp_block(4, 1, 7).unwrap();
        // AG pulls + RS reduce pushes: (w-1) of each per rank
        assert_eq!(case.plan.total_transfers(), 2 * 4 * 3);
        // one wait per incoming x chunk; no rank waits for "stage 1 done"
        assert!(case.plan.per_rank.iter().all(|p| p.num_waits() == 3));
        // every rank runs both stages' tiles: 2 per row block
        assert_eq!(case.plan.per_rank[0].num_tiles(), 2 * 4);
        // h and y checked on every rank
        assert_eq!(case.checks.len(), 8);
        // split refines both stages
        let split = tp_block(4, 2, 7).unwrap();
        assert_eq!(split.plan.total_transfers(), 2 * 4 * 3 * 2);
    }

    #[test]
    fn moe_a2a_structure() {
        let case = moe_a2a(4, 5).unwrap();
        // dispatch + combine: w(w-1) pushes each
        assert_eq!(case.plan.total_transfers(), 2 * 4 * 3);
        assert_eq!(case.checks.len(), 4);
        // each rank waits once per incoming token block
        assert!(case.plan.per_rank.iter().all(|p| p.num_waits() == 3));
    }

    #[test]
    fn pipeline_stage_plans_cover_every_stage() {
        let stages = tp_block_stage_plans(4, 1).unwrap();
        assert_eq!(stages.len(), 2);
        assert_eq!(stages[0].total_transfers(), 4 * 3);
        assert_eq!(stages[1].total_transfers(), 4 * 3);
        let stages = moe_a2a_stage_plans(2).unwrap();
        assert_eq!(stages.len(), 3);
        assert_eq!(stages[0].total_transfers(), 2);
        assert_eq!(stages[1].total_transfers(), 0);
        assert!(stages[1].total_flops() > 0.0);
        assert_eq!(stages[2].total_transfers(), 2);
    }

    #[test]
    fn degenerate_params_error_instead_of_panicking() {
        // ISSUE 3 satellite: a registry-wide sweep over edge values —
        // every builder must return, never panic, and the universally
        // invalid values must carry named Coordinator errors.
        let degenerate = [
            CaseParams { world: 0, ..Default::default() },
            CaseParams { world: 1, ..Default::default() },
            CaseParams { split: 0, ..Default::default() },
            CaseParams { split: 5, ..Default::default() },
            CaseParams { split: 1 << 20, ..Default::default() },
            CaseParams { nodes: 0, ..Default::default() },
            CaseParams { world: 4, nodes: 3, ..Default::default() },
        ];
        for spec in CASES {
            for p in &degenerate {
                // Ok or Err both fine here; a panic fails the test
                let _ = spec.build(p);
            }
            for p in &degenerate[..2] {
                let e = spec.build(p).unwrap_err();
                assert!(matches!(e, Error::Coordinator(_)), "{}: {e:?}", spec.name);
                assert!(e.to_string().contains("world"), "{}: {e}", spec.name);
            }
            let e = spec.build(&degenerate[2]).unwrap_err();
            assert!(e.to_string().contains("split"), "{}: {e}", spec.name);
            let e = spec.build(&degenerate[5]).unwrap_err();
            assert!(e.to_string().contains("nodes"), "{}: {e}", spec.name);
        }
        // direct-call paths are guarded too, not just the registry
        assert!(tp_block(1, 1, 0).is_err());
        assert!(tp_block(4, 0, 0).is_err());
        assert!(moe_a2a(0, 0).is_err());
        assert!(ag_gemm_hierarchical(0, 4, 0).is_err());
        assert!(gemm_rs(1, 0).is_err());
        assert!(a2a_gemm(1, 0).is_err());
        assert!(attn_sp(0, 0).is_err());
    }

    #[test]
    fn registry_rejects_unknown_case_naming_the_registry() {
        let e = build_case("warp-speed", &CaseParams::default()).unwrap_err().to_string();
        assert!(e.contains("unknown exec case `warp-speed`"), "{e}");
        assert!(e.contains("ag-gemm") && e.contains("ring-attn") && e.contains("ag-gemm-flux"), "{e}");
    }

    #[test]
    fn imported_variant_structure() {
        // Flux: 4 pieces per remote shard, pulls only
        let case = ag_gemm_variant(2, 1, 3, AgVariant::ImportedFlux).unwrap();
        assert_eq!(case.plan.total_transfers(), 2 * 1 * 4);
        // Triton-dist: one push per peer
        let case = ag_gemm_variant(4, 1, 3, AgVariant::ImportedTritonDist).unwrap();
        assert_eq!(case.plan.total_transfers(), 4 * 3);
        assert!(case.name.contains("ImportedTritonDist"), "{}", case.name);
    }
}
