//! Sharded read-mostly plan cache for the coordinator worker pool.
//!
//! The pool used to share ONE `RwLock<HashMap>`: every cache hit still
//! bounced the same lock word between worker cores, and any insert blocked
//! every concurrent hit. [`ShardedCache`] splits the map N ways by key
//! hash (FNV-1a, shard = `hash & (shards - 1)`), so lookups of different
//! keys take different locks and writers only stall readers of their own
//! shard. Shard count is rounded up to a power of two to keep the index a
//! mask instead of a modulo.
//!
//! Semantics match the single-lock original: [`ShardedCache::insert_if_absent`]
//! is first-writer-wins (racing workers compiled the same deterministic
//! bits, so whichever insert lands first is canonical and the caller's
//! value is dropped on the floor for later arrivals).

use std::collections::HashMap;
use std::sync::RwLock;

use crate::obs;

/// FNV-1a 64-bit: tiny, allocation-free, good dispersion on short
/// `label|config` style keys. (std's `DefaultHasher` works too; FNV keeps
/// the shard choice stable across Rust releases, which makes shard-balance
/// tests deterministic.)
fn fnv1a(s: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in s.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Per-shard telemetry handles. Counters live in the global [`obs`]
/// registry under `plan_cache.{hits,misses,insert_races}{shard=i}` — the
/// only production [`ShardedCache`] is the coordinator's plan cache, so
/// the name is fixed rather than threaded through the generic. Handles
/// are `&'static`, so cloning a cache instance (or building one in a
/// test) shares the same counters; assertions on them must be
/// delta-based.
#[derive(Debug)]
struct ShardStats {
    hits: &'static obs::Counter,
    misses: &'static obs::Counter,
    races: &'static obs::Counter,
}

impl ShardStats {
    fn for_shard(i: usize) -> Self {
        let shard = i.to_string();
        let labels: &[(&str, &str)] = &[("shard", shard.as_str())];
        ShardStats {
            hits: obs::counter_with("plan_cache.hits", labels),
            misses: obs::counter_with("plan_cache.misses", labels),
            races: obs::counter_with("plan_cache.insert_races", labels),
        }
    }
}

/// A string-keyed concurrent cache, sharded by key hash.
#[derive(Debug)]
pub struct ShardedCache<V> {
    shards: Vec<RwLock<HashMap<String, V>>>,
    stats: Vec<ShardStats>,
    mask: u64,
}

impl<V: Clone> ShardedCache<V> {
    /// Build with at least `shards` shards (rounded up to a power of two,
    /// minimum 1).
    pub fn new(shards: usize) -> Self {
        let n = shards.max(1).next_power_of_two();
        ShardedCache {
            shards: (0..n).map(|_| RwLock::new(HashMap::new())).collect(),
            stats: (0..n).map(ShardStats::for_shard).collect(),
            mask: (n - 1) as u64,
        }
    }

    fn index(&self, key: &str) -> usize {
        (fnv1a(key) & self.mask) as usize
    }

    fn shard(&self, key: &str) -> &RwLock<HashMap<String, V>> {
        &self.shards[self.index(key)]
    }

    /// Clone the cached value for `key`, if present.
    pub fn get(&self, key: &str) -> Option<V> {
        let i = self.index(key);
        let v = self.shards[i].read().unwrap().get(key).cloned();
        match v {
            Some(_) => self.stats[i].hits.inc(),
            None => self.stats[i].misses.inc(),
        }
        v
    }

    /// Insert unless the key is already present (first writer wins).
    /// Returns true if this call inserted. A losing insert (the key
    /// appeared between the caller's miss and this write) counts as an
    /// insert race.
    pub fn insert_if_absent(&self, key: &str, value: V) -> bool {
        let i = self.index(key);
        let mut shard = self.shards[i].write().unwrap();
        if shard.contains_key(key) {
            self.stats[i].races.inc();
            return false;
        }
        shard.insert(key.to_string(), value);
        true
    }

    /// Total entries across all shards. Takes the shard read locks one at
    /// a time; exact only when writers are quiescent (tests, stats).
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.read().unwrap().len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn shard_count_rounds_up_to_power_of_two() {
        assert_eq!(ShardedCache::<u32>::new(0).num_shards(), 1);
        assert_eq!(ShardedCache::<u32>::new(1).num_shards(), 1);
        assert_eq!(ShardedCache::<u32>::new(5).num_shards(), 8);
        assert_eq!(ShardedCache::<u32>::new(16).num_shards(), 16);
    }

    #[test]
    fn get_insert_roundtrip_and_first_writer_wins() {
        let c = ShardedCache::new(4);
        assert!(c.is_empty());
        assert!(c.get("a").is_none());
        assert!(c.insert_if_absent("a", 1));
        assert!(!c.insert_if_absent("a", 2), "second writer must lose");
        assert_eq!(c.get("a"), Some(1));
        assert_eq!(c.len(), 1);
    }

    #[test]
    fn keys_disperse_across_shards() {
        let c = ShardedCache::new(8);
        for i in 0..64 {
            c.insert_if_absent(&format!("user-plan|{i:016x}"), i);
        }
        assert_eq!(c.len(), 64);
        let occupied =
            c.shards.iter().filter(|s| !s.read().unwrap().is_empty()).count();
        assert!(occupied >= 4, "64 keys landed in only {occupied}/8 shards");
    }

    #[test]
    fn concurrent_workers_agree_on_hits_and_misses() {
        // the satellite's stress shape: 8 workers x 50 requests over 10
        // keys; hits + misses must account for every request, exactly 10
        // entries exist afterwards, and at most workers*keys inserts can
        // have raced in.
        const WORKERS: usize = 8;
        const REQS: usize = 50;
        const KEYS: usize = 10;
        let c = ShardedCache::new(16);
        let hits = AtomicUsize::new(0);
        let misses = AtomicUsize::new(0);
        std::thread::scope(|s| {
            for w in 0..WORKERS {
                let (c, hits, misses) = (&c, &hits, &misses);
                s.spawn(move || {
                    for r in 0..REQS {
                        let key = format!("k{}", (w + r) % KEYS);
                        if let Some(v) = c.get(&key) {
                            assert_eq!(v, (w + r) % KEYS, "value for {key} corrupted");
                            hits.fetch_add(1, Ordering::Relaxed);
                        } else {
                            misses.fetch_add(1, Ordering::Relaxed);
                            c.insert_if_absent(&key, (w + r) % KEYS);
                        }
                    }
                });
            }
        });
        let (h, m) = (hits.load(Ordering::Relaxed), misses.load(Ordering::Relaxed));
        assert_eq!(h + m, WORKERS * REQS, "every request is a hit or a miss");
        assert_eq!(c.len(), KEYS);
        assert!(m >= KEYS, "each key misses at least once");
        assert!(m <= WORKERS * KEYS, "misses bounded by worst-case racing");
    }

    #[test]
    fn cache_traffic_lands_in_obs_counters() {
        // counters are process-global and shared by every cache instance,
        // so assert deltas, not absolutes (other tests run concurrently)
        let c = ShardedCache::new(1); // one shard: all traffic hits shard=0
        let hits = crate::obs::counter_with("plan_cache.hits", &[("shard", "0")]);
        let misses = crate::obs::counter_with("plan_cache.misses", &[("shard", "0")]);
        let races = crate::obs::counter_with("plan_cache.insert_races", &[("shard", "0")]);
        let (h0, m0, r0) = (hits.get(), misses.get(), races.get());
        assert!(c.get("k").is_none());
        assert!(c.insert_if_absent("k", 1));
        assert!(!c.insert_if_absent("k", 2), "losing insert must count as a race");
        assert_eq!(c.get("k"), Some(1));
        assert!(hits.get() >= h0 + 1);
        assert!(misses.get() >= m0 + 1);
        assert!(races.get() >= r0 + 1);
    }
}
