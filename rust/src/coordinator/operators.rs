//! Paper-scale operator compilation: workload instance + tuning config →
//! executable plan for the performance model.
//!
//! For each operator kind this module picks the schedule template, derives
//! the per-rank tile grid (blocks come from the annotated L1 kernel source
//! unless the config overrides them), maps chunks to tiles, applies the
//! scheduler swizzle, inserts minimal sync, and hands everything to
//! [`crate::codegen::compile`].

use std::collections::HashMap;

use crate::chunk::TensorTable;
use crate::codegen::{compile, ExecutablePlan, RankComputeInput};
use crate::coordinator::TuneConfig;
use crate::depgraph::{plan_rank_sync, plan_rank_sync_barrier, ChunkTileMap};
use crate::error::{Error, Result};
use crate::kernel::grid::{Axis, TileGrid};
use crate::kernel::scheduler::{SwizzlePolicy, TileScheduler};
use crate::schedule::{templates, CommSchedule, OpRef};
use crate::sim::engine::SimParams;
use crate::sim::waves;
use crate::topo::{Rank, Topology};
use crate::workload::{OpKind, OperatorInstance};

/// How an operator's chunks relate to its tiles.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum ChunkRole {
    /// Incoming chunks are read by tiles (AG-style inputs).
    ConsumedByTiles,
    /// Outgoing chunks are written by tiles (RS/AR-style outputs).
    ProducedByTiles,
}

/// Compile a paper-scale operator under one tuning configuration.
pub fn compile_operator(
    op: &OperatorInstance,
    cfg: &TuneConfig,
    topo: &Topology,
) -> Result<(ExecutablePlan, SimParams)> {
    compile_operator_inner(op, cfg, topo, false)
}

/// Same, but with conservative barrier sync (the `ablation_sync` study).
pub fn compile_operator_barrier_sync(
    op: &OperatorInstance,
    cfg: &TuneConfig,
    topo: &Topology,
) -> Result<(ExecutablePlan, SimParams)> {
    compile_operator_inner(op, cfg, topo, true)
}

fn compile_operator_inner(
    op: &OperatorInstance,
    cfg: &TuneConfig,
    topo: &Topology,
    barrier: bool,
) -> Result<(ExecutablePlan, SimParams)> {
    if op.world != topo.world {
        return Err(Error::Coordinator(format!(
            "operator world {} != topology {}",
            op.world, topo.world
        )));
    }
    let (sched, grid, role, row_map) = build_schedule_and_grid(op, cfg, topo)?;
    let flops_per_rank = op.flops() / op.world as f64;
    let n_tiles = grid.num_tiles();
    let tile_flops = vec![flops_per_rank / n_tiles as f64; n_tiles];

    let mut inputs = Vec::with_capacity(op.world);
    for rank in 0..op.world {
        let map = chunk_tile_map(&sched, rank, &grid, role, &row_map)?;
        let order = match (&cfg.swizzle, role) {
            (SwizzlePolicy::ChunkMajor { .. }, ChunkRole::ConsumedByTiles) => {
                let groups = map.consumer_groups(rank);
                let arrival: Vec<usize> = (0..groups.len()).collect();
                if groups.is_empty() {
                    TileScheduler::row_major(&grid)
                } else {
                    TileScheduler::from_policy(&grid, &cfg.swizzle, Some((&groups, &arrival)))?
                }
            }
            (SwizzlePolicy::ChunkMajor { .. }, ChunkRole::ProducedByTiles) => {
                // producer side: visit tiles in the order their chunks must
                // depart (issue order of this rank's ops)
                producer_order(&sched, rank, &grid, &map)?
            }
            (policy, _) => TileScheduler::from_policy(&grid, policy, None)?,
        };
        let sync = if barrier {
            plan_rank_sync_barrier(rank, &sched, &map, grid.num_tiles())?
        } else {
            plan_rank_sync(rank, &sched, &order, &map)?
        };
        inputs.push(RankComputeInput {
            grid: grid.clone(),
            order,
            sync,
            tile_flops: tile_flops.clone(),
            tile_calls: HashMap::new(),
        });
    }
    let plan = compile(&sched, &inputs, cfg.real, topo)?;
    // Achieved efficiency = MXU fill for the tile shape × a cache-locality
    // term from the visiting order (Fig. 11d: tile order changes operand
    // reuse in L2/VMEM; orders that revisit operands back-to-back run
    // closer to peak). Calibrated small: order explains ~10%, shape the rest.
    let locality = match inputs.first() {
        Some(i) => i.order.locality_score(&i.grid)?,
        None => 1.0,
    };
    let params = SimParams {
        mxu_eff: waves::mxu_efficiency(cfg.block_m, cfg.block_n, cfg.block_k)
            * (0.90 + 0.10 * locality),
    };
    Ok((plan, params))
}

/// Row-range mapping from a chunk's global rows to grid rows (identity for
/// most operators; A2A maps global block positions to local token rows).
type RowMap = fn(world: usize, m_global: usize, row: usize, rank: Rank) -> usize;

fn identity_rows(_w: usize, _m: usize, row: usize, _r: Rank) -> usize {
    row
}

/// A2A: global row of block (i, j) maps to local row i*blk + offset on rank j.
fn a2a_rows(w: usize, m_global: usize, row: usize, _r: Rank) -> usize {
    let blk = m_global / (w * w);
    let i = row / (w * blk);
    let a = row % blk;
    i * blk + a
}

fn build_schedule_and_grid(
    op: &OperatorInstance,
    cfg: &TuneConfig,
    topo: &Topology,
) -> Result<(CommSchedule, TileGrid, ChunkRole, RowMap)> {
    let w = op.world;
    let mut table = TensorTable::new();
    let (sched, grid, role, rmap): (CommSchedule, TileGrid, ChunkRole, RowMap) = match op.kind {
        OpKind::AgGemm => {
            let x = table.declare("x", &[op.m, op.k], op.dtype)?;
            let s = if topo.ranks_per_node < w {
                templates::all_gather_hierarchical(&table, x, 0, topo)?
            } else {
                templates::all_gather_swizzle(&table, x, 0, w)?
            };
            let grid = TileGrid::gemm(op.m, op.n, cfg.block_m, cfg.block_n)?;
            (s, grid, ChunkRole::ConsumedByTiles, identity_rows as RowMap)
        }
        OpKind::GemmRs => {
            let y = table.declare("y", &[op.m, op.n], op.dtype)?;
            let s = templates::reduce_scatter_direct(&table, y, 0, w)?;
            let grid = TileGrid::gemm(op.m, op.n, cfg.block_m, cfg.block_n)?;
            (s, grid, ChunkRole::ProducedByTiles, identity_rows as RowMap)
        }
        OpKind::GemmAr => {
            let y = table.declare("y", &[op.m, op.n], op.dtype)?;
            let s = templates::all_reduce_partition(&table, y, 0, w)?;
            let grid = TileGrid::gemm(op.m, op.n, cfg.block_m, cfg.block_n)?;
            (s, grid, ChunkRole::ProducedByTiles, identity_rows as RowMap)
        }
        OpKind::A2aGemm => {
            let rows = op.m - op.m % (w * w); // align to w^2 blocks
            let x = table.declare("x", &[rows, op.k], op.dtype)?;
            let s = templates::all_to_all(&table, x, 0, w)?;
            let grid = TileGrid::gemm(rows / w, op.n, cfg.block_m, cfg.block_n)?;
            (s, grid, ChunkRole::ConsumedByTiles, a2a_rows as RowMap)
        }
        OpKind::RingAttn | OpKind::AttnSp => {
            // K and V move; grid is Q-blocks x KV-rows.
            let cols = op.n * op.k; // heads * head_dim
            let k = table.declare("k", &[op.m, cols], op.dtype)?;
            let v = table.declare("v", &[op.m, cols], op.dtype)?;
            let (mut s, s2) = if op.kind == OpKind::RingAttn {
                (
                    templates::all_gather_ring(&table, k, 0, w)?,
                    templates::all_gather_ring(&table, v, 0, w)?,
                )
            } else {
                (
                    templates::all_gather_swizzle(&table, k, 0, w)?,
                    templates::all_gather_swizzle(&table, v, 0, w)?,
                )
            };
            s.append(&s2)?;
            let grid = TileGrid::new(vec![
                Axis::new("Q", op.m / w, cfg.block_m)?,
                Axis::new("S", op.m, op.m / w)?, // one S-tile per KV shard
            ])?;
            (s, grid, ChunkRole::ConsumedByTiles, identity_rows as RowMap)
        }
        OpKind::AttnHp => {
            // Ulysses: A2A(qkv) in, A2A(out) back; local full attention.
            let cols = op.n * op.k;
            let rows = op.m - op.m % (w * w);
            let qkv = table.declare("qkv", &[rows, 3 * cols], op.dtype)?;
            let out = table.declare("out", &[rows, cols], op.dtype)?;
            let mut s = templates::all_to_all(&table, qkv, 0, w)?;
            let s2 = templates::all_to_all(&table, out, 0, w)?;
            s.append(&s2)?;
            let grid = TileGrid::new(vec![
                Axis::new("Q", rows / w, cfg.block_m)?,
                Axis::new("S", rows, rows / w)?,
            ])?;
            // chunks of qkv are consumed; chunks of out are produced — we
            // approximate with the dominant (consumed) role and let the out
            // A2A trail the kernel (its producers are mapped below).
            (s, grid, ChunkRole::ConsumedByTiles, a2a_rows as RowMap)
        }
    };
    let sched = sched.split_p2p(0, cfg.split).map_err(|e| {
        Error::Coordinator(format!("split {} infeasible for {}: {e}", cfg.split, op.label()))
    })?;
    Ok((sched, grid, role, rmap))
}

/// Build the chunk↔tile map for one rank by intersecting each op's region
/// rows with the grid's row axis.
fn chunk_tile_map(
    sched: &CommSchedule,
    rank: Rank,
    grid: &TileGrid,
    role: ChunkRole,
    row_map: &RowMap,
) -> Result<ChunkTileMap> {
    let mut map = ChunkTileMap::default();
    let m_local = grid.axes[0].size;
    let free_axes = grid.rank() - 1;
    for (r, ops) in sched.per_rank.iter().enumerate() {
        for (index, op) in ops.iter().enumerate() {
            let opref = OpRef { rank: r, index };
            match role {
                ChunkRole::ConsumedByTiles => {
                    if op.dst_rank(r) != rank {
                        continue;
                    }
                    let reg = &op.produced_chunk().region;
                    let m_glob = sched.tensors.get(op.produced_chunk().tensor)?.shape[0];
                    let a = row_map(sched.world, m_glob, reg.offset[0], rank);
                    let b = a + reg.sizes[0];
                    // grid axis 0 may be the KV axis (attention) or local
                    // token rows; clamp to grid size
                    let (axis_idx, span) = if grid.axes.len() > 1 && grid.axes[1].name == "S" {
                        (1usize, (reg.offset[0], reg.offset[0] + reg.sizes[0]))
                    } else {
                        (0usize, (a, b.min(m_local)))
                    };
                    if span.0 >= span.1 {
                        continue;
                    }
                    let mut ranges: Vec<Option<(usize, usize)>> = vec![None; grid.rank()];
                    ranges[axis_idx] = Some(span);
                    let tiles = grid.tiles_intersecting(&ranges)?;
                    map.consumers.entry(opref).or_default().extend(tiles);
                }
                ChunkRole::ProducedByTiles => {
                    if op.src_rank(r) != rank {
                        continue;
                    }
                    let reg = &op.consumed_chunk().region;
                    let span = (reg.offset[0], reg.offset[0] + reg.sizes[0]);
                    let mut ranges: Vec<Option<(usize, usize)>> = vec![None; grid.rank()];
                    ranges[0] = Some(span);
                    let _ = free_axes;
                    let tiles = grid.tiles_intersecting(&ranges)?;
                    map.producers.entry(opref).or_default().extend(tiles);
                }
            }
        }
    }
    Ok(map)
}

/// Producer-side swizzle: visit tiles so that chunks depart in this rank's
/// op issue order — tiles feeding op 0 first, then op 1, remainder last.
fn producer_order(
    sched: &CommSchedule,
    rank: Rank,
    grid: &TileGrid,
    map: &ChunkTileMap,
) -> Result<TileScheduler> {
    let n = grid.num_tiles();
    let mut order = Vec::with_capacity(n);
    let mut placed = vec![false; n];
    for index in 0..sched.per_rank[rank].len() {
        if let Some(tiles) = map.producers.get(&OpRef { rank, index }) {
            let mut ts = tiles.clone();
            ts.sort_unstable();
            for t in ts {
                if !placed[t] {
                    placed[t] = true;
                    order.push(t);
                }
            }
        }
    }
    for t in 0..n {
        if !placed[t] {
            order.push(t);
        }
    }
    Ok(TileScheduler { order })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::engine::simulate;
    use crate::workload::{OperatorInstance, LLAMA3_8B};

    fn topo(w: usize) -> Topology {
        crate::hw::catalog::topology("h100_node", w).unwrap()
    }

    #[test]
    fn all_gemm_kinds_compile_and_simulate() {
        for kind in [OpKind::AgGemm, OpKind::GemmRs, OpKind::GemmAr, OpKind::A2aGemm] {
            let op = OperatorInstance::gemm(kind, &LLAMA3_8B, 4096, 4);
            let cfg = TuneConfig::default();
            let cfg = match kind {
                // reduce ops need a reduce-capable backend
                OpKind::GemmRs | OpKind::GemmAr => TuneConfig {
                    real: crate::codegen::Realization::new(
                        crate::backend::BackendKind::LdStSpecialized,
                        16,
                    ),
                    ..cfg
                },
                _ => cfg,
            };
            let (plan, params) = compile_operator(&op, &cfg, &topo(4))
                .unwrap_or_else(|e| panic!("{kind:?}: {e}"));
            assert_eq!(plan.world, 4);
            assert!(plan.total_transfers() > 0, "{kind:?}");
            let r = simulate(&plan, &topo(4), params).unwrap();
            assert!(r.makespan_us > 0.0, "{kind:?}");
            assert!(r.tflops() > 1.0, "{kind:?}: {}", r.tflops());
        }
    }

    #[test]
    fn attention_kinds_compile_and_simulate() {
        for kind in [OpKind::RingAttn, OpKind::AttnSp, OpKind::AttnHp] {
            let op = OperatorInstance::attention(kind, &LLAMA3_8B, 8192, 4);
            let cfg = TuneConfig { split: 1, ..TuneConfig::default() };
            let (plan, params) =
                compile_operator(&op, &cfg, &topo(4)).unwrap_or_else(|e| panic!("{kind:?}: {e}"));
            let r = simulate(&plan, &topo(4), params).unwrap();
            assert!(r.makespan_us > 0.0);
            assert!(r.tflops() > 1.0, "{kind:?}: {}", r.tflops());
        }
    }

    #[test]
    fn split_factor_changes_transfer_count() {
        let op = OperatorInstance::gemm(OpKind::AgGemm, &LLAMA3_8B, 4096, 4);
        let t = topo(4);
        let p1 = compile_operator(&op, &TuneConfig { split: 1, ..Default::default() }, &t)
            .unwrap()
            .0;
        let p4 = compile_operator(&op, &TuneConfig { split: 4, ..Default::default() }, &t)
            .unwrap()
            .0;
        assert_eq!(p4.total_transfers(), 4 * p1.total_transfers());
    }

    #[test]
    fn overlap_beats_barrier_sync() {
        let op = OperatorInstance::gemm(OpKind::AgGemm, &LLAMA3_8B, 8192, 8);
        let t = topo(8);
        let cfg = TuneConfig::default();
        let (p_min, params) = compile_operator(&op, &cfg, &t).unwrap();
        let (p_bar, _) = compile_operator_barrier_sync(&op, &cfg, &t).unwrap();
        let r_min = simulate(&p_min, &t, params).unwrap();
        let r_bar = simulate(&p_bar, &t, params).unwrap();
        assert!(
            r_min.makespan_us <= r_bar.makespan_us * 1.001,
            "minimal sync {} vs barrier {}",
            r_min.makespan_us,
            r_bar.makespan_us
        );
        // fine-grained overlap should hide strictly more communication
        assert!(r_min.exposed_wait_us <= r_bar.exposed_wait_us);
    }

    #[test]
    fn world_mismatch_rejected() {
        let op = OperatorInstance::gemm(OpKind::AgGemm, &LLAMA3_8B, 4096, 4);
        assert!(compile_operator(&op, &TuneConfig::default(), &topo(8)).is_err());
    }

    #[test]
    fn infeasible_split_rejected() {
        let op = OperatorInstance::gemm(OpKind::AgGemm, &LLAMA3_8B, 4096, 4);
        // shard = 1024 rows; split 7 does not divide
        let cfg = TuneConfig { split: 7, ..Default::default() };
        assert!(compile_operator(&op, &cfg, &topo(4)).is_err());
    }

    #[test]
    fn reduce_on_copy_engine_rejected() {
        let op = OperatorInstance::gemm(OpKind::GemmRs, &LLAMA3_8B, 4096, 4);
        // default config uses the copy engine, which cannot reduce
        let e = compile_operator(&op, &TuneConfig::default(), &topo(4)).unwrap_err();
        assert_eq!(e.subsystem(), "backend");
    }

    #[test]
    fn hierarchical_template_on_multinode() {
        let t = crate::hw::catalog::topology_nodes("h100_multinode", 2, 8).unwrap();
        let op = OperatorInstance::gemm(OpKind::AgGemm, &LLAMA3_8B, 4096, 8);
        // TMA can't cross nodes; ldst can
        let cfg = TuneConfig {
            real: crate::codegen::Realization::new(
                crate::backend::BackendKind::LdStSpecialized,
                16,
            ),
            ..Default::default()
        };
        let (plan, params) = compile_operator(&op, &cfg, &t).unwrap();
        let r = simulate(&plan, &t, params).unwrap();
        assert!(r.makespan_us > 0.0);
    }

    #[test]
    fn a2a_row_map() {
        // w=2, m=8: blk=2; block (1,0) starts at global row 4 -> local row 2
        assert_eq!(a2a_rows(2, 8, 4, 0), 2);
        assert_eq!(a2a_rows(2, 8, 5, 0), 3);
        assert_eq!(a2a_rows(2, 8, 0, 0), 0);
    }
}
