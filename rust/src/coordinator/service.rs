//! The serving half of the framework: a multi-worker coordinator that owns
//! the topology, compiles operators on demand (tune-once, cached), and
//! answers simulation/estimation requests.
//!
//! The offline build has no tokio; the service is a configurable pool of
//! std worker threads draining one shared mpsc queue (dequeue serialized
//! behind a mutex, processing fully parallel), which is all the request
//! path needs — requests are CPU-bound compilations/simulations, not I/O.
//! Compiled plans land in a process-wide cache behind an `RwLock`: reads
//! (cache hits) never block each other, and a key is compiled at most a
//! handful of times under race but inserted once (first writer wins, so
//! responses stay deterministic).

use std::collections::HashMap;
use std::sync::mpsc;
use std::sync::{Arc, Mutex, RwLock};
use std::thread;

use crate::coordinator::operators::compile_operator;
use crate::coordinator::TuneConfig;
use crate::error::{Error, Result};
use crate::sim::engine::simulate;
use crate::topo::Topology;
use crate::workload::{OpKind, OperatorInstance};

/// Parse an operator kind by its report name (the CLI's registry).
pub fn opkind_by_name(name: &str) -> Result<OpKind> {
    let all = [
        OpKind::AgGemm,
        OpKind::GemmRs,
        OpKind::GemmAr,
        OpKind::A2aGemm,
        OpKind::AttnHp,
        OpKind::AttnSp,
        OpKind::RingAttn,
    ];
    all.into_iter().find(|k| k.name() == name).ok_or_else(|| {
        Error::Coordinator(format!(
            "unknown operator `{name}` (known: {})",
            all.map(|k| k.name()).join(", ")
        ))
    })
}

/// One request to the coordinator.
#[derive(Debug, Clone)]
pub enum Request {
    /// Compile (cached) and simulate one operator configuration.
    Run { op: OperatorInstance, cfg: TuneConfig },
}

/// Simulation outcome returned to the caller.
#[derive(Debug, Clone)]
pub struct Response {
    pub label: String,
    pub makespan_us: f64,
    pub tflops: f64,
    pub exposed_wait_us: f64,
    /// True when the compiled plan came from the coordinator's cache.
    pub cache_hit: bool,
}

enum Envelope {
    Req(Request, mpsc::Sender<Result<Response>>),
    Shutdown,
}

type PlanCache = HashMap<String, (crate::codegen::ExecutablePlan, crate::sim::SimParams)>;

/// A running coordinator service (worker pool).
pub struct Coordinator {
    tx: mpsc::Sender<Envelope>,
    handles: Vec<thread::JoinHandle<()>>,
}

/// A cloneable submission handle; hand one to each client thread.
#[derive(Clone)]
pub struct CoordinatorClient {
    tx: mpsc::Sender<Envelope>,
}

impl CoordinatorClient {
    /// Submit a request; returns a receiver for the response.
    pub fn submit(&self, req: Request) -> Result<mpsc::Receiver<Result<Response>>> {
        let (rtx, rrx) = mpsc::channel();
        self.tx
            .send(Envelope::Req(req, rtx))
            .map_err(|_| Error::Coordinator("coordinator workers are gone".into()))?;
        Ok(rrx)
    }

    /// Convenience: submit and block for the answer.
    pub fn run(&self, op: OperatorInstance, cfg: TuneConfig) -> Result<Response> {
        self.submit(Request::Run { op, cfg })?
            .recv()
            .map_err(|_| Error::Coordinator("coordinator dropped the request".into()))?
    }
}

impl Coordinator {
    /// Spawn a single-worker coordinator (back-compat entry point).
    pub fn spawn(topo: Topology) -> Self {
        Self::spawn_pool(topo, 1)
    }

    /// Spawn a pool of `workers` threads sharing one request queue and one
    /// plan cache.
    pub fn spawn_pool(topo: Topology, workers: usize) -> Self {
        let workers = workers.max(1);
        let (tx, rx) = mpsc::channel::<Envelope>();
        let rx = Arc::new(Mutex::new(rx));
        let cache: Arc<RwLock<PlanCache>> = Arc::new(RwLock::new(HashMap::new()));
        let topo = Arc::new(topo);
        let handles = (0..workers)
            .map(|_| {
                let rx = rx.clone();
                let cache = cache.clone();
                let topo = topo.clone();
                thread::spawn(move || worker(&topo, &rx, &cache))
            })
            .collect();
        Coordinator { tx, handles }
    }

    /// Number of worker threads in the pool.
    pub fn workers(&self) -> usize {
        self.handles.len()
    }

    /// A cloneable handle for submitting from other threads.
    pub fn client(&self) -> CoordinatorClient {
        CoordinatorClient { tx: self.tx.clone() }
    }

    /// Submit a request; returns a receiver for the response.
    pub fn submit(&self, req: Request) -> Result<mpsc::Receiver<Result<Response>>> {
        self.client().submit(req)
    }

    /// Convenience: submit and block for the answer.
    pub fn run(&self, op: OperatorInstance, cfg: TuneConfig) -> Result<Response> {
        self.client().run(op, cfg)
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        for _ in &self.handles {
            let _ = self.tx.send(Envelope::Shutdown);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker(topo: &Topology, rx: &Mutex<mpsc::Receiver<Envelope>>, cache: &RwLock<PlanCache>) {
    loop {
        // Serialize only the dequeue; processing runs in parallel.
        let env = { rx.lock().unwrap().recv() };
        let Ok(env) = env else { break };
        match env {
            Envelope::Shutdown => break,
            Envelope::Req(Request::Run { op, cfg }, reply) => {
                let key = format!("{}|{}", op.label(), cfg.label());
                let cached = cache.read().unwrap().get(&key).cloned();
                let cache_hit = cached.is_some();
                let compiled = match cached {
                    Some(c) => Ok(c),
                    None => compile_operator(&op, &cfg, topo),
                };
                let resp = compiled.and_then(|(plan, params)| {
                    if !cache_hit {
                        // first writer wins; racing workers agree anyway
                        // (compilation is deterministic)
                        cache
                            .write()
                            .unwrap()
                            .entry(key.clone())
                            .or_insert_with(|| (plan.clone(), params));
                    }
                    let r = simulate(&plan, topo, params)?;
                    Ok(Response {
                        label: key.clone(),
                        makespan_us: r.makespan_us,
                        tflops: r.tflops(),
                        exposed_wait_us: r.exposed_wait_us,
                        cache_hit,
                    })
                });
                let _ = reply.send(resp);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::LLAMA3_8B;

    #[test]
    fn registry_lookup() {
        assert_eq!(opkind_by_name("ag-gemm").unwrap(), OpKind::AgGemm);
        assert_eq!(opkind_by_name("ring-attn").unwrap(), OpKind::RingAttn);
        assert!(opkind_by_name("nope").is_err());
    }

    #[test]
    fn serve_and_cache() {
        let coord = Coordinator::spawn(Topology::h100_node(4).unwrap());
        let op = OperatorInstance::gemm(OpKind::AgGemm, &LLAMA3_8B, 4096, 4);
        let r1 = coord.run(op, TuneConfig::default()).unwrap();
        assert!(r1.tflops > 0.0);
        assert!(!r1.cache_hit);
        let r2 = coord.run(op, TuneConfig::default()).unwrap();
        assert!(r2.cache_hit);
        assert_eq!(r1.makespan_us, r2.makespan_us); // deterministic
    }

    #[test]
    fn errors_propagate() {
        let coord = Coordinator::spawn(Topology::h100_node(4).unwrap());
        // world mismatch: operator says 8, topo is 4
        let op = OperatorInstance::gemm(OpKind::AgGemm, &LLAMA3_8B, 4096, 8);
        assert!(coord.run(op, TuneConfig::default()).is_err());
    }

    #[test]
    fn concurrent_submissions() {
        let coord = Coordinator::spawn(Topology::h100_node(4).unwrap());
        let op = OperatorInstance::gemm(OpKind::GemmRs, &LLAMA3_8B, 4096, 4);
        let cfg = TuneConfig {
            real: crate::codegen::Realization::new(
                crate::backend::BackendKind::LdStSpecialized,
                16,
            ),
            ..Default::default()
        };
        let rxs: Vec<_> =
            (0..4).map(|_| coord.submit(Request::Run { op, cfg: cfg.clone() }).unwrap()).collect();
        let times: Vec<f64> =
            rxs.into_iter().map(|rx| rx.recv().unwrap().unwrap().makespan_us).collect();
        assert!(times.windows(2).all(|w| w[0] == w[1]));
    }

    #[test]
    fn pool_answers_from_multiple_workers() {
        let coord = Coordinator::spawn_pool(Topology::h100_node(4).unwrap(), 4);
        assert_eq!(coord.workers(), 4);
        let op = OperatorInstance::gemm(OpKind::AgGemm, &LLAMA3_8B, 4096, 4);
        let rxs: Vec<_> = (0..8)
            .map(|_| coord.submit(Request::Run { op, cfg: TuneConfig::default() }).unwrap())
            .collect();
        let times: Vec<f64> =
            rxs.into_iter().map(|rx| rx.recv().unwrap().unwrap().makespan_us).collect();
        assert!(times.windows(2).all(|w| w[0] == w[1]), "pool must stay deterministic");
        // warm cache: a fresh request is a hit no matter which worker serves it
        let r = coord.run(op, TuneConfig::default()).unwrap();
        assert!(r.cache_hit);
    }

    #[test]
    fn clients_submit_from_other_threads() {
        let coord = Coordinator::spawn_pool(Topology::h100_node(4).unwrap(), 2);
        let op = OperatorInstance::gemm(OpKind::AgGemm, &LLAMA3_8B, 4096, 4);
        std::thread::scope(|s| {
            for _ in 0..3 {
                let client = coord.client();
                s.spawn(move || {
                    let r = client.run(op, TuneConfig::default()).unwrap();
                    assert!(r.tflops > 0.0);
                });
            }
        });
    }
}
