//! The serving half of the framework: a multi-worker coordinator that owns
//! the topology, compiles operators on demand (tune-once, cached), and
//! answers simulation/estimation requests.
//!
//! The offline build has no tokio; the service is a configurable pool of
//! std worker threads draining one shared mpsc queue (dequeue serialized
//! behind a mutex, processing fully parallel), which is all the request
//! path needs — requests are CPU-bound compilations/simulations, not I/O.
//! Compiled plans land in a process-wide [`ShardedCache`] (key-hash
//! sharded `RwLock` maps): hits on different keys take different locks,
//! and a key is compiled at most a handful of times under race but
//! inserted once (first writer wins, so responses stay deterministic).

use std::sync::atomic::{AtomicU64, Ordering::Relaxed};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::Instant;

use crate::coordinator::cache::ShardedCache;
use crate::coordinator::operators::compile_operator;
use crate::coordinator::TuneConfig;
use crate::error::{Error, Result};
use crate::exec::{BufferStore, ExecOptions, ExecStats};
use crate::obs;
use crate::runtime::Runtime;
use crate::sim::engine::simulate;
use crate::topo::Topology;
use crate::util::Rng;
use crate::workload::{OpKind, OperatorInstance};

/// Parse an operator kind by its report name (the CLI's registry).
pub fn opkind_by_name(name: &str) -> Result<OpKind> {
    let all = [
        OpKind::AgGemm,
        OpKind::GemmRs,
        OpKind::GemmAr,
        OpKind::A2aGemm,
        OpKind::AttnHp,
        OpKind::AttnSp,
        OpKind::RingAttn,
    ];
    all.into_iter().find(|k| k.name() == name).ok_or_else(|| {
        Error::Coordinator(format!(
            "unknown operator `{name}` (known: {})",
            all.map(|k| k.name()).join(", ")
        ))
    })
}

/// One request to the coordinator.
#[derive(Debug, Clone)]
pub enum Request {
    /// Compile (cached) and simulate one operator configuration.
    Run { op: OperatorInstance, cfg: TuneConfig },
}

/// Outcome of serving one user-submitted schedule (see
/// [`CoordinatorClient::run_user_plan`]).
#[derive(Debug, Clone)]
pub struct UserPlanResponse {
    /// Content hash of the plan's canonical printed form — the cache key.
    pub hash: String,
    pub world: usize,
    pub ops: usize,
    /// Winning restricted-autotune realization, e.g. `copy-engine/sm0`.
    pub backend_label: String,
    /// Simulated comm-only makespan under that realization.
    pub sim_makespan_us: f64,
    /// Real-numerics execution statistics.
    pub stats: ExecStats,
    /// True when the compiled plan came from the coordinator's cache.
    pub cache_hit: bool,
    /// Measured per-request overlap summary, when the request asked for a
    /// traced execution ([`CoordinatorClient::run_user_plan_traced`]).
    pub trace: Option<crate::trace::TraceStats>,
}

/// Simulation outcome returned to the caller.
#[derive(Debug, Clone)]
pub struct Response {
    pub label: String,
    pub makespan_us: f64,
    pub tflops: f64,
    pub exposed_wait_us: f64,
    /// True when the compiled plan came from the coordinator's cache.
    pub cache_hit: bool,
}

/// Monotonic request IDs, assigned at submit time (so queue time is part
/// of a request's observable lifetime). Process-global: IDs stay unique
/// across coordinator instances, which keeps flight events unambiguous.
static NEXT_REQUEST_ID: AtomicU64 = AtomicU64::new(1);

fn next_request_id() -> u64 {
    NEXT_REQUEST_ID.fetch_add(1, Relaxed)
}

enum Envelope {
    Req(u64, Request, mpsc::Sender<Result<Response>>),
    /// (request id, plan text, exec options, trace this execution?)
    UserPlan(u64, String, ExecOptions, bool, mpsc::Sender<Result<UserPlanResponse>>),
    Shutdown,
}

/// One cached compiled plan. `user_meta` is populated only for user-plan
/// entries — (simulated comm-only makespan, winning realization label) —
/// so warm requests skip re-simulation entirely.
#[derive(Clone)]
struct CachedPlan {
    plan: crate::codegen::ExecutablePlan,
    params: crate::sim::SimParams,
    user_meta: Option<(f64, String)>,
}

/// 16 shards comfortably exceeds the worker-pool sizes we spawn (≤ 8 in
/// tests), so two workers rarely contend on the same shard lock.
type PlanCache = ShardedCache<CachedPlan>;
const CACHE_SHARDS: usize = 16;

/// A running coordinator service (worker pool).
pub struct Coordinator {
    tx: mpsc::Sender<Envelope>,
    handles: Vec<thread::JoinHandle<()>>,
}

/// A cloneable submission handle; hand one to each client thread.
#[derive(Clone)]
pub struct CoordinatorClient {
    tx: mpsc::Sender<Envelope>,
}

impl CoordinatorClient {
    /// Submit a request; returns a receiver for the response.
    pub fn submit(&self, req: Request) -> Result<mpsc::Receiver<Result<Response>>> {
        let (rtx, rrx) = mpsc::channel();
        self.tx
            .send(Envelope::Req(next_request_id(), req, rtx))
            .map_err(|_| Error::Coordinator("coordinator workers are gone".into()))?;
        obs::gauge("coord.queue_depth").inc();
        Ok(rrx)
    }

    /// Convenience: submit and block for the answer.
    pub fn run(&self, op: OperatorInstance, cfg: TuneConfig) -> Result<Response> {
        self.submit(Request::Run { op, cfg })?
            .recv()
            .map_err(|_| Error::Coordinator("coordinator dropped the request".into()))?
    }

    /// Submit a user-authored `.sched` plan (DSL text); returns a receiver
    /// for the outcome. The plan flows through validate → restricted
    /// autotune → comm-only codegen → real-numerics exec, with the
    /// compiled plan cached under the content hash of its canonical form.
    pub fn submit_user_plan(
        &self,
        text: &str,
        opts: ExecOptions,
    ) -> Result<mpsc::Receiver<Result<UserPlanResponse>>> {
        self.submit_user_plan_opts(text, opts, false)
    }

    /// [`CoordinatorClient::submit_user_plan`] with per-request tracing:
    /// the execution runs over a trace sink and the response carries the
    /// measured overlap summary ([`crate::trace::TraceStats`]).
    pub fn submit_user_plan_traced(
        &self,
        text: &str,
        opts: ExecOptions,
    ) -> Result<mpsc::Receiver<Result<UserPlanResponse>>> {
        self.submit_user_plan_opts(text, opts, true)
    }

    fn submit_user_plan_opts(
        &self,
        text: &str,
        opts: ExecOptions,
        traced: bool,
    ) -> Result<mpsc::Receiver<Result<UserPlanResponse>>> {
        let (rtx, rrx) = mpsc::channel();
        self.tx
            .send(Envelope::UserPlan(next_request_id(), text.to_string(), opts, traced, rtx))
            .map_err(|_| Error::Coordinator("coordinator workers are gone".into()))?;
        obs::gauge("coord.queue_depth").inc();
        Ok(rrx)
    }

    /// Convenience: submit a user plan and block for the outcome.
    pub fn run_user_plan(&self, text: &str, opts: ExecOptions) -> Result<UserPlanResponse> {
        self.submit_user_plan(text, opts)?
            .recv()
            .map_err(|_| Error::Coordinator("coordinator dropped the request".into()))?
    }

    /// Convenience: traced submit + block (see
    /// [`CoordinatorClient::submit_user_plan_traced`]).
    pub fn run_user_plan_traced(&self, text: &str, opts: ExecOptions) -> Result<UserPlanResponse> {
        self.submit_user_plan_traced(text, opts)?
            .recv()
            .map_err(|_| Error::Coordinator("coordinator dropped the request".into()))?
    }
}

impl Coordinator {
    /// Spawn a single-worker coordinator (back-compat entry point).
    pub fn spawn(topo: Topology) -> Self {
        Self::spawn_pool(topo, 1)
    }

    /// Spawn a pool of `workers` threads sharing one request queue and one
    /// plan cache.
    pub fn spawn_pool(topo: Topology, workers: usize) -> Self {
        let workers = workers.max(1);
        let (tx, rx) = mpsc::channel::<Envelope>();
        let rx = Arc::new(Mutex::new(rx));
        let cache: Arc<PlanCache> = Arc::new(ShardedCache::new(CACHE_SHARDS));
        let topo = Arc::new(topo);
        let handles = (0..workers)
            .map(|wi| {
                let rx = rx.clone();
                let cache = cache.clone();
                let topo = topo.clone();
                thread::spawn(move || worker(wi, &topo, &rx, &cache))
            })
            .collect();
        Coordinator { tx, handles }
    }

    /// Number of worker threads in the pool.
    pub fn workers(&self) -> usize {
        self.handles.len()
    }

    /// A cloneable handle for submitting from other threads.
    pub fn client(&self) -> CoordinatorClient {
        CoordinatorClient { tx: self.tx.clone() }
    }

    /// Submit a request; returns a receiver for the response.
    pub fn submit(&self, req: Request) -> Result<mpsc::Receiver<Result<Response>>> {
        self.client().submit(req)
    }

    /// Convenience: submit and block for the answer.
    pub fn run(&self, op: OperatorInstance, cfg: TuneConfig) -> Result<Response> {
        self.client().run(op, cfg)
    }

    /// Serve a user-authored `.sched` plan (see
    /// [`CoordinatorClient::run_user_plan`]).
    pub fn run_user_plan(&self, text: &str, opts: ExecOptions) -> Result<UserPlanResponse> {
        self.client().run_user_plan(text, opts)
    }

    /// Traced serving (see [`CoordinatorClient::run_user_plan_traced`]).
    pub fn run_user_plan_traced(&self, text: &str, opts: ExecOptions) -> Result<UserPlanResponse> {
        self.client().run_user_plan_traced(text, opts)
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        for _ in &self.handles {
            let _ = self.tx.send(Envelope::Shutdown);
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker(wi: usize, topo: &Topology, rx: &Mutex<mpsc::Receiver<Envelope>>, cache: &PlanCache) {
    // Lazily opened on the first user-plan request: operator requests are
    // sim-only and never touch the artifact runtime.
    let mut runtime: Option<Runtime> = None;
    let widx = wi.to_string();
    let busy = obs::gauge_with("coord.worker_busy", &[("worker", widx.as_str())]);
    let served = obs::counter_with("coord.worker_requests", &[("worker", widx.as_str())]);
    let depth = obs::gauge("coord.queue_depth");
    loop {
        // Serialize only the dequeue; processing runs in parallel.
        let env = { rx.lock().unwrap().recv() };
        let Ok(env) = env else { break };
        match env {
            Envelope::Shutdown => break,
            Envelope::UserPlan(id, text, opts, traced, reply) => {
                depth.dec();
                busy.set(1.0);
                served.inc();
                // everything this request touches — serving phases on this
                // thread AND rank threads the engines spawn — records under
                // this request ID (DESIGN.md §18)
                obs::flight::set_request(id);
                obs::flight::req_begin();
                let t0 = Instant::now();
                let resp = serve_user_plan(&text, &opts, traced, topo, cache, &mut runtime)
                    .map_err(|e| e.prefixed(&format!("request {id}")));
                obs::histogram_with("serve.request_us", &[("kind", "user-plan")])
                    .record_us(obs::us_since(t0));
                match &resp {
                    Ok(_) => obs::flight::req_end(),
                    Err(e) => {
                        obs::error_total(e.subsystem());
                        obs::flight::req_error();
                        obs::flight::dump_to_configured("served-error");
                    }
                }
                obs::flight::set_request(0);
                busy.set(0.0);
                let _ = reply.send(resp);
            }
            Envelope::Req(id, Request::Run { op, cfg }, reply) => {
                depth.dec();
                busy.set(1.0);
                served.inc();
                obs::flight::set_request(id);
                obs::flight::req_begin();
                let t0 = Instant::now();
                let key = format!("{}|{}", op.label(), cfg.label());
                let cached = cache.get(&key);
                let cache_hit = cached.is_some();
                let compiled = match cached {
                    Some(c) => Ok((c.plan, c.params)),
                    None => compile_operator(&op, &cfg, topo),
                };
                let resp = compiled.and_then(|(plan, params)| {
                    if !cache_hit {
                        // first writer wins; racing workers agree anyway
                        // (compilation is deterministic)
                        cache.insert_if_absent(
                            &key,
                            CachedPlan { plan: plan.clone(), params, user_meta: None },
                        );
                    }
                    let r = simulate(&plan, topo, params)?;
                    Ok(Response {
                        label: key.clone(),
                        makespan_us: r.makespan_us,
                        tflops: r.tflops(),
                        exposed_wait_us: r.exposed_wait_us,
                        cache_hit,
                    })
                });
                let resp = resp.map_err(|e| e.prefixed(&format!("request {id}")));
                obs::histogram_with("serve.request_us", &[("kind", "operator")])
                    .record_us(obs::us_since(t0));
                match &resp {
                    Ok(_) => obs::flight::req_end(),
                    Err(e) => {
                        obs::error_total(e.subsystem());
                        obs::flight::req_error();
                        obs::flight::dump_to_configured("served-error");
                    }
                }
                obs::flight::set_request(0);
                busy.set(0.0);
                let _ = reply.send(resp);
            }
        }
    }
}

/// The user-plan serving path (DESIGN.md §11): parse → validate →
/// restricted autotune (split fixed by the plan) → comm-only codegen →
/// real-numerics exec, with the tuned compiled plan cached under the
/// content hash of the canonical printed form. Each phase lands its
/// latency in `serve.phase_us{phase=...}` (a phase that errors out
/// records nothing — the failure is counted once in `error_total` by the
/// worker loop); warm cache hits skip tune/compile, so those phases only
/// accumulate cold-path samples.
fn serve_user_plan(
    text: &str,
    opts: &crate::exec::ExecOptions,
    traced: bool,
    topo: &Topology,
    cache: &PlanCache,
    runtime: &mut Option<Runtime>,
) -> Result<UserPlanResponse> {
    let phase = |p: &str| obs::histogram_with("serve.phase_us", &[("phase", p)]);
    // phase spans in the flight recorder carry the worker's current
    // request ID; a phase that errors out leaves its begin unmatched,
    // which Chrome renders as the unfinished span — exactly the story
    let t0 = Instant::now();
    obs::flight::phase_begin("parse");
    let sched = crate::plan_io::parse_schedule(text)?;
    obs::flight::phase_end("parse");
    phase("parse").record_us(obs::us_since(t0));
    let t0 = Instant::now();
    obs::flight::phase_begin("validate");
    if sched.world != topo.world {
        return Err(Error::Coordinator(format!(
            "plan world {} != coordinator world {}",
            sched.world, topo.world
        )));
    }
    crate::schedule::validate::validate(&sched)?;
    // Static analysis (DESIGN.md §17): every finding is counted into the
    // registry; error-severity findings reject the plan with its
    // certificate (defense in depth — validate already rejects races and
    // cycles, but the analyzer's rule set may grow past it).
    let rep = crate::analysis::run(&sched)?;
    for f in &rep.findings {
        obs::counter_with(
            "analysis.findings_total",
            &[("rule", f.rule), ("severity", f.severity.as_str())],
        )
        .inc();
    }
    if let Some(f) =
        rep.findings.iter().find(|f| f.severity == crate::analysis::Severity::Error)
    {
        return Err(Error::Analysis(format!(
            "plan rejected by static analysis: {} {}",
            f.rule, f.message
        )));
    }
    // hash the CANONICAL form: formatting differences between authors of
    // the same plan still hit the same cache entry
    let hash = crate::plan_io::content_hash(&crate::plan_io::print_schedule(&sched)?);
    let key = format!("user-plan|{hash}");
    obs::flight::phase_end("validate");
    phase("validate").record_us(obs::us_since(t0));

    let cached = cache.get(&key);
    let cache_hit = cached.is_some();
    let (plan, sim_makespan_us, backend_label) = match cached {
        Some(CachedPlan { plan, user_meta: Some((makespan, label)), .. }) => {
            (plan, makespan, label)
        }
        Some(CachedPlan { plan, params, user_meta: None }) => {
            // only reachable if an operator entry ever shared a key, which
            // the "user-plan|" prefix prevents; handle it anyway
            let sim = simulate(&plan, topo, params)?;
            let label = realization_label(&plan);
            (plan, sim.makespan_us, label)
        }
        None => {
            let t0 = Instant::now();
            obs::flight::phase_begin("tune");
            let tuned = crate::autotune::tune_user_plan(&sched, topo)?;
            obs::flight::phase_end("tune");
            phase("tune").record_us(obs::us_since(t0));
            let t0 = Instant::now();
            obs::flight::phase_begin("compile");
            let plan = crate::codegen::compile_comm_only(&sched, tuned.real, topo)?;
            let params = crate::sim::SimParams::default();
            let sim = simulate(&plan, topo, params)?;
            let label = realization_label(&plan);
            obs::flight::phase_end("compile");
            phase("compile").record_us(obs::us_since(t0));
            // first writer wins; racing workers compiled the same bits
            cache.insert_if_absent(
                &key,
                CachedPlan {
                    plan: plan.clone(),
                    params,
                    user_meta: Some((sim.makespan_us, label.clone())),
                },
            );
            (plan, sim.makespan_us, label)
        }
    };

    if runtime.is_none() {
        *runtime = Some(Runtime::open_default()?);
    }
    let rt = runtime.as_ref().expect("just initialized");
    let store = seeded_store(&sched)?;
    let t0 = Instant::now();
    obs::flight::phase_begin("exec");
    let (stats, trace_stats) = if traced {
        let (stats, mut trace) =
            crate::exec::run_with_traced(&plan, &sched.tensors, &store, rt, opts)?;
        // the captured trace remembers which request produced it, so a
        // Chrome export of a sampled live trace names its lifecycle
        let req = obs::flight::current_request();
        if req != 0 {
            trace.set_meta("request", &req.to_string());
        }
        let report = crate::trace::analyze(&trace);
        // every traced request feeds the standing sim-vs-trace gauge
        report.record_divergence(sim_makespan_us);
        // ... and the critical-path blame gauges (perf.critical_*_us):
        // a live view of where sampled requests spend their makespan
        if let Ok(path) = crate::perf::critical_path(&trace) {
            crate::perf::record_gauges(&path);
        }
        (stats, Some(report.stats()))
    } else {
        (crate::exec::run_with(&plan, &sched.tensors, &store, rt, opts)?, None)
    };
    obs::flight::phase_end("exec");
    phase("exec").record_us(obs::us_since(t0));
    Ok(UserPlanResponse {
        hash,
        world: sched.world,
        ops: sched.num_ops(),
        backend_label,
        sim_makespan_us,
        stats,
        cache_hit,
        trace: trace_stats,
    })
}

/// Human-readable realization of a compiled plan's transfers (they all
/// share one backend/SM choice by construction).
fn realization_label(plan: &crate::codegen::ExecutablePlan) -> String {
    plan.per_rank
        .iter()
        .flat_map(|p| &p.ops)
        .find_map(|o| match o {
            crate::codegen::PlanOp::Issue(d) => {
                Some(format!("{}/sm{}", d.backend.name(), d.comm_sms))
            }
            _ => None,
        })
        .unwrap_or_else(|| "n/a".into())
}

/// Deterministic per-rank buffer contents for user-plan execution: the
/// same plan always executes over the same bits, so repeated requests (and
/// both exec engines) are comparable.
fn seeded_store(sched: &crate::schedule::CommSchedule) -> Result<BufferStore> {
    let mut store = BufferStore::new(sched.world);
    for (_, decl) in sched.tensors.iter() {
        store.declare(&decl.name, &decl.shape)?;
    }
    for rank in 0..sched.world {
        for (id, decl) in sched.tensors.iter() {
            let seed = 0x9E37_79B9_7F4A_7C15u64 ^ ((rank as u64) << 32) ^ id.0 as u64;
            let data = Rng::new(seed).vec_f32(decl.elems());
            store.set(rank, &decl.name, &data)?;
        }
    }
    Ok(store)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::LLAMA3_8B;

    #[test]
    fn registry_lookup() {
        assert_eq!(opkind_by_name("ag-gemm").unwrap(), OpKind::AgGemm);
        assert_eq!(opkind_by_name("ring-attn").unwrap(), OpKind::RingAttn);
        assert!(opkind_by_name("nope").is_err());
    }

    #[test]
    fn serve_and_cache() {
        let coord = Coordinator::spawn(crate::hw::catalog::topology("h100_node", 4).unwrap());
        let op = OperatorInstance::gemm(OpKind::AgGemm, &LLAMA3_8B, 4096, 4);
        let r1 = coord.run(op, TuneConfig::default()).unwrap();
        assert!(r1.tflops > 0.0);
        assert!(!r1.cache_hit);
        let r2 = coord.run(op, TuneConfig::default()).unwrap();
        assert!(r2.cache_hit);
        assert_eq!(r1.makespan_us, r2.makespan_us); // deterministic
    }

    #[test]
    fn errors_propagate() {
        let coord = Coordinator::spawn(crate::hw::catalog::topology("h100_node", 4).unwrap());
        // world mismatch: operator says 8, topo is 4
        let op = OperatorInstance::gemm(OpKind::AgGemm, &LLAMA3_8B, 4096, 8);
        assert!(coord.run(op, TuneConfig::default()).is_err());
    }

    #[test]
    fn concurrent_submissions() {
        let coord = Coordinator::spawn(crate::hw::catalog::topology("h100_node", 4).unwrap());
        let op = OperatorInstance::gemm(OpKind::GemmRs, &LLAMA3_8B, 4096, 4);
        let cfg = TuneConfig {
            real: crate::codegen::Realization::new(
                crate::backend::BackendKind::LdStSpecialized,
                16,
            ),
            ..Default::default()
        };
        let rxs: Vec<_> =
            (0..4).map(|_| coord.submit(Request::Run { op, cfg: cfg.clone() }).unwrap()).collect();
        let times: Vec<f64> =
            rxs.into_iter().map(|rx| rx.recv().unwrap().unwrap().makespan_us).collect();
        assert!(times.windows(2).all(|w| w[0] == w[1]));
    }

    #[test]
    fn pool_answers_from_multiple_workers() {
        let coord = Coordinator::spawn_pool(crate::hw::catalog::topology("h100_node", 4).unwrap(), 4);
        assert_eq!(coord.workers(), 4);
        let op = OperatorInstance::gemm(OpKind::AgGemm, &LLAMA3_8B, 4096, 4);
        let rxs: Vec<_> = (0..8)
            .map(|_| coord.submit(Request::Run { op, cfg: TuneConfig::default() }).unwrap())
            .collect();
        let times: Vec<f64> =
            rxs.into_iter().map(|rx| rx.recv().unwrap().unwrap().makespan_us).collect();
        assert!(times.windows(2).all(|w| w[0] == w[1]), "pool must stay deterministic");
        // warm cache: a fresh request is a hit no matter which worker serves it
        let r = coord.run(op, TuneConfig::default()).unwrap();
        assert!(r.cache_hit);
    }

    #[test]
    fn sharded_cache_consistent_under_concurrent_pool_load() {
        // 8 workers hammer 3 distinct keys with 24 in-flight requests: every
        // response is either a hit or a miss, results are identical per key,
        // and once the pool drains, every key is warm.
        let coord =
            Coordinator::spawn_pool(crate::hw::catalog::topology("h100_node", 4).unwrap(), 8);
        let op = OperatorInstance::gemm(OpKind::AgGemm, &LLAMA3_8B, 4096, 4);
        let cfgs: Vec<TuneConfig> =
            [1, 2, 4].iter().map(|&s| TuneConfig { split: s, ..Default::default() }).collect();
        let rxs: Vec<_> = (0..24)
            .map(|i| {
                coord.submit(Request::Run { op, cfg: cfgs[i % cfgs.len()].clone() }).unwrap()
            })
            .collect();
        let mut by_key: std::collections::HashMap<String, Vec<(f64, bool)>> = Default::default();
        for rx in rxs {
            let r = rx.recv().unwrap().unwrap();
            by_key.entry(r.label.clone()).or_default().push((r.makespan_us, r.cache_hit));
        }
        assert_eq!(by_key.len(), 3);
        for (key, results) in &by_key {
            assert_eq!(results.len(), 8);
            assert!(
                results.windows(2).all(|w| w[0].0 == w[1].0),
                "nondeterministic makespan for {key}"
            );
            let misses = results.iter().filter(|(_, hit)| !hit).count();
            assert!(misses >= 1, "{key}: first request cannot be a hit");
            assert!(misses <= 8, "{key}: more misses than workers");
        }
        // drained pool: every key is warm now
        for cfg in cfgs {
            assert!(coord.run(op, cfg).unwrap().cache_hit);
        }
    }

    #[test]
    fn user_plans_serve_and_cache_by_content_hash() {
        let coord = Coordinator::spawn_pool(crate::hw::catalog::topology("h100_node", 2).unwrap(), 2);
        let text = "plan v1 world 2\n\
                    tensor x f32 4x16\n\
                    rank 0:\n  push x[0:2, 0:16] -> x[0:2, 0:16] peer 1\n\
                    rank 1:\n  push x[2:4, 0:16] -> x[2:4, 0:16] peer 0\n";
        let opts = ExecOptions::sequential();
        let r1 = coord.run_user_plan(text, opts.clone()).unwrap();
        assert!(!r1.cache_hit);
        assert_eq!(r1.world, 2);
        assert_eq!(r1.ops, 2);
        assert_eq!(r1.stats.transfers, 2);
        assert!(r1.sim_makespan_us > 0.0);
        assert!(r1.backend_label.contains("/sm"), "{}", r1.backend_label);
        // differently formatted text of the SAME plan hits the same entry
        let messy = text.replace("  push", "    push  ");
        let r2 = coord.run_user_plan(&messy, opts.clone()).unwrap();
        assert!(r2.cache_hit, "canonical-form hashing must dedupe formatting");
        assert_eq!(r1.hash, r2.hash);
        assert_eq!(r1.sim_makespan_us, r2.sim_makespan_us);
        // parallel mode serves the same plan too
        let r3 = coord.run_user_plan(text, ExecOptions::parallel()).unwrap();
        assert!(r3.cache_hit);
        assert_eq!(r3.stats.transfers, 2);
        // untraced requests carry no trace summary
        assert!(r3.trace.is_none());
    }

    #[test]
    fn traced_requests_carry_overlap_stats() {
        let coord =
            Coordinator::spawn_pool(crate::hw::catalog::topology("h100_node", 2).unwrap(), 2);
        let text = "plan v1 world 2\n\
                    tensor x f32 4x16\n\
                    rank 0:\n  push x[0:2, 0:16] -> x[0:2, 0:16] peer 1\n\
                    rank 1:\n  push x[2:4, 0:16] -> x[2:4, 0:16] peer 0\n";
        for opts in [ExecOptions::sequential(), ExecOptions::parallel()] {
            let r = coord.run_user_plan_traced(text, opts).unwrap();
            let t = r.trace.expect("traced request must carry stats");
            assert_eq!(t.events, r.stats.transfers, "comm-only plan: one event per transfer");
            assert!(t.comm_us > 0.0);
            assert!(t.busy_makespan_us > 0.0);
        }
    }

    #[test]
    fn bad_user_plans_are_rejected_not_served() {
        let coord = Coordinator::spawn(crate::hw::catalog::topology("h100_node", 2).unwrap());
        let opts = ExecOptions::sequential();
        // parse error (carries line/col)
        let e = coord.run_user_plan("plan v9 world 2\n", opts.clone()).unwrap_err();
        assert!(e.to_string().contains("line 1"), "{e}");
        // world mismatch against the coordinator's topology
        let four = "plan v1 world 4\ntensor x f32 8x16\nrank 0:\n  push x[0:2, 0:16] -> x[0:2, 0:16] peer 1\n";
        let e = coord.run_user_plan(four, opts.clone()).unwrap_err();
        assert!(e.to_string().contains("world"), "{e}");
        // structural failure: dependency cycle
        let cyc = "plan v1 world 2\ntensor x f32 4x16\n\
                   rank 0:\n  push x[0:2, 0:16] -> x[0:2, 0:16] peer 1 deps (1,0)\n\
                   rank 1:\n  push x[2:4, 0:16] -> x[2:4, 0:16] peer 0 deps (0,0)\n";
        let e = coord.run_user_plan(cyc, opts).unwrap_err();
        assert!(e.to_string().contains("cycle"), "{e}");
    }

    #[test]
    fn serving_feeds_the_obs_registry() {
        // metric handles are process-global: assert deltas, not absolutes
        let req = crate::obs::histogram_with("serve.request_us", &[("kind", "user-plan")]);
        let parse = crate::obs::histogram_with("serve.phase_us", &[("phase", "parse")]);
        let exec = crate::obs::histogram_with("serve.phase_us", &[("phase", "exec")]);
        let div_samples = crate::obs::counter("sim.divergence_samples");
        let errs = crate::obs::counter_with("error_total", &[("kind", "coordinator")]);
        let (r0, p0, e0) = (req.snap().count, parse.snap().count, exec.snap().count);
        let (d0, c0) = (div_samples.get(), errs.get());
        let coord =
            Coordinator::spawn_pool(crate::hw::catalog::topology("h100_node", 2).unwrap(), 2);
        let text = "plan v1 world 2\n\
                    tensor x f32 4x16\n\
                    rank 0:\n  push x[0:2, 0:16] -> x[0:2, 0:16] peer 1\n\
                    rank 1:\n  push x[2:4, 0:16] -> x[2:4, 0:16] peer 0\n";
        coord.run_user_plan(text, ExecOptions::sequential()).unwrap();
        coord.run_user_plan_traced(text, ExecOptions::sequential()).unwrap();
        assert!(req.snap().count >= r0 + 2, "both requests must land in serve.request_us");
        assert!(parse.snap().count >= p0 + 2);
        assert!(exec.snap().count >= e0 + 2);
        assert!(div_samples.get() >= d0 + 1, "traced request must feed the divergence gauge");
        // a rejected plan (world mismatch -> coordinator subsystem) counts
        let four = "plan v1 world 4\ntensor x f32 8x16\nrank 0:\n  push x[0:2, 0:16] -> x[0:2, 0:16] peer 1\n";
        assert!(coord.run_user_plan(four, ExecOptions::sequential()).is_err());
        assert!(errs.get() >= c0 + 1, "serve errors must land in error_total{{kind}}");
    }

    #[test]
    fn analysis_findings_feed_obs_and_gate_serving() {
        // metric handles are process-global: assert deltas, not absolutes
        let warns = crate::obs::counter_with(
            "analysis.findings_total",
            &[("rule", crate::analysis::RULE_REDUNDANT_DEP), ("severity", "warn")],
        );
        let w0 = warns.get();
        let coord = Coordinator::spawn(crate::hw::catalog::topology("h100_node", 2).unwrap());
        // dep (1,0) duplicates rank 1's program order: the plan still serves,
        // but the analyzer's SY-W101 finding lands in the registry
        let text = "plan v1 world 2\ntensor x f32 4x8\n\
                    rank 0:\n  push x[0:2, 0:8] -> x[0:2, 0:8] peer 1\n\
                    rank 1:\n  push x[2:4, 0:8] -> x[2:4, 0:8] peer 0\n  \
                    push x[2:4, 0:8] -> x[2:4, 0:8] peer 0 deps (0,0) (1,0)\n";
        coord.run_user_plan(text, ExecOptions::sequential()).unwrap();
        assert!(warns.get() >= w0 + 1, "redundant dep must land in analysis.findings_total");
        // a racy plan never reaches execution: rejected with a race certificate
        let racy = "plan v1 world 2\ntensor x f32 4x8\n\
                    rank 0:\n  push x[0:2, 0:8] -> x[0:2, 0:8] peer 1\n\
                    rank 1:\n  push x[0:2, 0:8] -> x[2:4, 0:8] peer 0\n";
        let e = coord.run_user_plan(racy, ExecOptions::sequential()).unwrap_err();
        assert!(e.to_string().contains("race"), "{e}");
    }

    #[test]
    fn clients_submit_from_other_threads() {
        let coord = Coordinator::spawn_pool(crate::hw::catalog::topology("h100_node", 4).unwrap(), 2);
        let op = OperatorInstance::gemm(OpKind::AgGemm, &LLAMA3_8B, 4096, 4);
        std::thread::scope(|s| {
            for _ in 0..3 {
                let client = coord.client();
                s.spawn(move || {
                    let r = client.run(op, TuneConfig::default()).unwrap();
                    assert!(r.tflops > 0.0);
                });
            }
        });
    }
}
