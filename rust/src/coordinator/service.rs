//! The serving half of the framework: a threaded coordinator that owns the
//! topology, compiles operators on demand (tune-once, cached), and answers
//! simulation/estimation requests.
//!
//! The offline build has no tokio; the loop is a std thread draining an
//! mpsc queue, which is all the request path needs (requests are CPU-bound
//! compilations/simulations, not I/O).

use std::collections::HashMap;
use std::sync::mpsc;
use std::thread;

use crate::coordinator::operators::compile_operator;
use crate::coordinator::TuneConfig;
use crate::error::{Error, Result};
use crate::sim::engine::simulate;
use crate::topo::Topology;
use crate::workload::{OpKind, OperatorInstance};

/// Parse an operator kind by its report name (the CLI's registry).
pub fn opkind_by_name(name: &str) -> Result<OpKind> {
    let all = [
        OpKind::AgGemm,
        OpKind::GemmRs,
        OpKind::GemmAr,
        OpKind::A2aGemm,
        OpKind::AttnHp,
        OpKind::AttnSp,
        OpKind::RingAttn,
    ];
    all.into_iter().find(|k| k.name() == name).ok_or_else(|| {
        Error::Coordinator(format!(
            "unknown operator `{name}` (known: {})",
            all.map(|k| k.name()).join(", ")
        ))
    })
}

/// One request to the coordinator.
#[derive(Debug, Clone)]
pub enum Request {
    /// Compile (cached) and simulate one operator configuration.
    Run { op: OperatorInstance, cfg: TuneConfig },
}

/// Simulation outcome returned to the caller.
#[derive(Debug, Clone)]
pub struct Response {
    pub label: String,
    pub makespan_us: f64,
    pub tflops: f64,
    pub exposed_wait_us: f64,
    /// True when the compiled plan came from the coordinator's cache.
    pub cache_hit: bool,
}

enum Envelope {
    Req(Request, mpsc::Sender<Result<Response>>),
    Shutdown,
}

/// A running coordinator service.
pub struct Coordinator {
    tx: mpsc::Sender<Envelope>,
    handle: Option<thread::JoinHandle<()>>,
}

impl Coordinator {
    /// Spawn the worker thread.
    pub fn spawn(topo: Topology) -> Self {
        let (tx, rx) = mpsc::channel::<Envelope>();
        let handle = thread::spawn(move || worker(topo, rx));
        Coordinator { tx, handle: Some(handle) }
    }

    /// Submit a request; returns a receiver for the response.
    pub fn submit(&self, req: Request) -> Result<mpsc::Receiver<Result<Response>>> {
        let (rtx, rrx) = mpsc::channel();
        self.tx
            .send(Envelope::Req(req, rtx))
            .map_err(|_| Error::Coordinator("coordinator thread is gone".into()))?;
        Ok(rrx)
    }

    /// Convenience: submit and block for the answer.
    pub fn run(&self, op: OperatorInstance, cfg: TuneConfig) -> Result<Response> {
        self.submit(Request::Run { op, cfg })?
            .recv()
            .map_err(|_| Error::Coordinator("coordinator dropped the request".into()))?
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        let _ = self.tx.send(Envelope::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn worker(topo: Topology, rx: mpsc::Receiver<Envelope>) {
    // plan cache: same (operator, config) never recompiles
    let mut cache: HashMap<String, (crate::codegen::ExecutablePlan, crate::sim::SimParams)> =
        HashMap::new();
    while let Ok(env) = rx.recv() {
        match env {
            Envelope::Shutdown => break,
            Envelope::Req(Request::Run { op, cfg }, reply) => {
                let key = format!("{}|{}", op.label(), cfg.label());
                let cache_hit = cache.contains_key(&key);
                let compiled = if cache_hit {
                    Ok(cache[&key].clone())
                } else {
                    compile_operator(&op, &cfg, &topo)
                };
                let resp = compiled.and_then(|(plan, params)| {
                    if !cache_hit {
                        cache.insert(key.clone(), (plan.clone(), params));
                    }
                    let r = simulate(&plan, &topo, params)?;
                    Ok(Response {
                        label: key.clone(),
                        makespan_us: r.makespan_us,
                        tflops: r.tflops(),
                        exposed_wait_us: r.exposed_wait_us,
                        cache_hit,
                    })
                });
                let _ = reply.send(resp);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::LLAMA3_8B;

    #[test]
    fn registry_lookup() {
        assert_eq!(opkind_by_name("ag-gemm").unwrap(), OpKind::AgGemm);
        assert_eq!(opkind_by_name("ring-attn").unwrap(), OpKind::RingAttn);
        assert!(opkind_by_name("nope").is_err());
    }

    #[test]
    fn serve_and_cache() {
        let coord = Coordinator::spawn(Topology::h100_node(4).unwrap());
        let op = OperatorInstance::gemm(OpKind::AgGemm, &LLAMA3_8B, 4096, 4);
        let r1 = coord.run(op, TuneConfig::default()).unwrap();
        assert!(r1.tflops > 0.0);
        assert!(!r1.cache_hit);
        let r2 = coord.run(op, TuneConfig::default()).unwrap();
        assert!(r2.cache_hit);
        assert_eq!(r1.makespan_us, r2.makespan_us); // deterministic
    }

    #[test]
    fn errors_propagate() {
        let coord = Coordinator::spawn(Topology::h100_node(4).unwrap());
        // world mismatch: operator says 8, topo is 4
        let op = OperatorInstance::gemm(OpKind::AgGemm, &LLAMA3_8B, 4096, 8);
        assert!(coord.run(op, TuneConfig::default()).is_err());
    }

    #[test]
    fn concurrent_submissions() {
        let coord = Coordinator::spawn(Topology::h100_node(4).unwrap());
        let op = OperatorInstance::gemm(OpKind::GemmRs, &LLAMA3_8B, 4096, 4);
        let cfg = TuneConfig {
            real: crate::codegen::Realization::new(
                crate::backend::BackendKind::LdStSpecialized,
                16,
            ),
            ..Default::default()
        };
        let rxs: Vec<_> =
            (0..4).map(|_| coord.submit(Request::Run { op, cfg: cfg.clone() }).unwrap()).collect();
        let times: Vec<f64> =
            rxs.into_iter().map(|rx| rx.recv().unwrap().unwrap().makespan_us).collect();
        assert!(times.windows(2).all(|w| w[0] == w[1]));
    }
}
