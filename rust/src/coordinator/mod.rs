//! The Syncopate coordinator: operator registry, compilation entry points,
//! and the request-serving loop.
//!
//! This is L3's integration layer. It owns the two compilation paths:
//!
//! * [`operators`] — paper-scale operator compilation for the performance
//!   model (`sim::`): schedules from templates, grids from the annotated L1
//!   kernel sources, chunk-major swizzles, minimal sync, one plan per
//!   [`crate::workload::OperatorInstance`] × [`TuneConfig`].
//! * [`execases`] — validation-scale cases with real buffers, real kernel
//!   execution (PJRT artifacts or the host-reference backend) and numeric
//!   verification against host oracles (`exec::`).
//! * [`service`] — a multi-worker request pool that serves compiled
//!   operators (tune-once, run-many) from a sharded plan cache
//!   ([`cache::ShardedCache`]), the "runtime" half of the paper's
//!   compiler + runtime framework.

pub mod cache;
pub mod execases;
pub mod operators;
pub mod service;

use crate::backend::BackendKind;
use crate::codegen::Realization;
use crate::kernel::scheduler::{IntraOrder, SwizzlePolicy};

/// One point in the communication-centric tuning space (§5.3):
/// inter-chunk (split factor) + intra-chunk (backend, SM allocation, tile
/// shape, tile order) knobs.
#[derive(Debug, Clone, PartialEq)]
pub struct TuneConfig {
    /// Chunk split factor: each logical transfer splits into this many
    /// sub-chunks (1 = one chunk per shard).
    pub split: usize,
    /// Backend + communication SM allocation.
    pub real: Realization,
    /// Tile visiting order policy.
    pub swizzle: SwizzlePolicy,
    /// Compute tile shape (GEMM blocks; attention uses block_m as Bq).
    pub block_m: usize,
    pub block_n: usize,
    pub block_k: usize,
}

impl Default for TuneConfig {
    fn default() -> Self {
        TuneConfig {
            split: 2,
            real: Realization::new(BackendKind::CopyEngine, 0),
            swizzle: SwizzlePolicy::ChunkMajor { intra: IntraOrder::Snake },
            block_m: 128,
            block_n: 128,
            block_k: 128,
        }
    }
}

impl TuneConfig {
    /// Compact label for reports.
    pub fn label(&self) -> String {
        let sw = match &self.swizzle {
            SwizzlePolicy::RowMajor => "row",
            SwizzlePolicy::ColMajor => "col",
            SwizzlePolicy::ChunkMajor { intra: IntraOrder::RowMajor } => "chunk",
            SwizzlePolicy::ChunkMajor { intra: IntraOrder::Snake } => "chunk-snake",
            SwizzlePolicy::ChunkMajor { intra: IntraOrder::GroupedCols { .. } } => "chunk-group",
        };
        format!(
            "s{}-{}-sm{}-{}x{}x{}-{}",
            self.split,
            self.real.backend.name(),
            self.real.comm_sms,
            self.block_m,
            self.block_n,
            self.block_k,
            sw
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_sane() {
        let c = TuneConfig::default();
        assert!(c.split >= 1);
        assert_eq!(c.block_m, 128);
        assert!(c.label().contains("copy-engine"));
    }

    #[test]
    fn labels_distinguish_configs() {
        let a = TuneConfig::default();
        let mut b = a.clone();
        b.split = 4;
        assert_ne!(a.label(), b.label());
    }
}
