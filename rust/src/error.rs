//! Library-wide error type.
//!
//! Every fallible public API in the crate returns [`Result`]. Variants are
//! grouped by subsystem so callers (and tests) can match on failure class
//! without string-parsing.

use std::fmt;

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// Failure classes across the Syncopate stack.
#[derive(Debug, Clone, PartialEq)]
pub enum Error {
    /// Static analysis could not run (structurally broken schedule, or a
    /// reduction requested on a cyclic plan). Rule findings are *not*
    /// errors of this kind — they are data in the `AnalysisReport`.
    Analysis(String),
    /// Chunk/region arithmetic out of bounds or shape mismatch.
    Region(String),
    /// Communication schedule is malformed (bad deps, uncovered regions, ...).
    Schedule(String),
    /// Kernel annotation parsing / tile-grid construction failure.
    Kernel(String),
    /// Dependence-graph construction found a cycle or unresolved reference.
    DepGraph(String),
    /// Backend capability violation (e.g. collective reduce on TMA).
    Backend(String),
    /// Lowering from a higher-level compiler IR failed.
    Lowering(String),
    /// Code generation could not realize the schedule.
    Codegen(String),
    /// Discrete-event simulation error (resource misuse, deadlock).
    Sim(String),
    /// Real-numerics execution error (missing artifact, deadlock, mismatch).
    Exec(String),
    /// PJRT runtime failure (wraps the `xla` crate error text).
    Runtime(String),
    /// Autotuner found no feasible configuration.
    Autotune(String),
    /// Coordinator / service error.
    Coordinator(String),
    /// Plan interchange failure (DSL parse/print, importer lifting). Parse
    /// errors carry `line L, col C:` prefixes for editor jump-to.
    PlanIo(String),
    /// Hardware-model failure (`.topo` parse/print, catalog lookup,
    /// topology instantiation). Parse errors carry `line L, col C:`
    /// prefixes like [`Error::PlanIo`].
    Hw(String),
    /// Tracing / calibration failure (trace JSON parse, schema violation,
    /// fingerprint mismatch, unfittable samples).
    Trace(String),
    /// I/O error (artifact files, manifests, exports).
    Io(String),
}

impl Error {
    /// Short subsystem tag, used in log lines and test assertions.
    pub fn subsystem(&self) -> &'static str {
        match self {
            Error::Analysis(_) => "analysis",
            Error::Region(_) => "region",
            Error::Schedule(_) => "schedule",
            Error::Kernel(_) => "kernel",
            Error::DepGraph(_) => "depgraph",
            Error::Backend(_) => "backend",
            Error::Lowering(_) => "lowering",
            Error::Codegen(_) => "codegen",
            Error::Sim(_) => "sim",
            Error::Exec(_) => "exec",
            Error::Runtime(_) => "runtime",
            Error::Autotune(_) => "autotune",
            Error::Coordinator(_) => "coordinator",
            Error::PlanIo(_) => "plan-io",
            Error::Hw(_) => "hw",
            Error::Trace(_) => "trace",
            Error::Io(_) => "io",
        }
    }

    /// The same error with `prefix: ` prepended to its message. The
    /// variant (and therefore [`Error::subsystem`]) is preserved, so
    /// `error_total{kind}` still counts the real failure class — the
    /// coordinator uses this to stamp the request ID onto served errors.
    pub fn prefixed(self, prefix: &str) -> Error {
        let wrap = |m: String| format!("{prefix}: {m}");
        match self {
            Error::Analysis(m) => Error::Analysis(wrap(m)),
            Error::Region(m) => Error::Region(wrap(m)),
            Error::Schedule(m) => Error::Schedule(wrap(m)),
            Error::Kernel(m) => Error::Kernel(wrap(m)),
            Error::DepGraph(m) => Error::DepGraph(wrap(m)),
            Error::Backend(m) => Error::Backend(wrap(m)),
            Error::Lowering(m) => Error::Lowering(wrap(m)),
            Error::Codegen(m) => Error::Codegen(wrap(m)),
            Error::Sim(m) => Error::Sim(wrap(m)),
            Error::Exec(m) => Error::Exec(wrap(m)),
            Error::Runtime(m) => Error::Runtime(wrap(m)),
            Error::Autotune(m) => Error::Autotune(wrap(m)),
            Error::Coordinator(m) => Error::Coordinator(wrap(m)),
            Error::PlanIo(m) => Error::PlanIo(wrap(m)),
            Error::Hw(m) => Error::Hw(wrap(m)),
            Error::Trace(m) => Error::Trace(wrap(m)),
            Error::Io(m) => Error::Io(wrap(m)),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let msg = match self {
            Error::Analysis(m)
            | Error::Region(m)
            | Error::Schedule(m)
            | Error::Kernel(m)
            | Error::DepGraph(m)
            | Error::Backend(m)
            | Error::Lowering(m)
            | Error::Codegen(m)
            | Error::Sim(m)
            | Error::Exec(m)
            | Error::Runtime(m)
            | Error::Autotune(m)
            | Error::Coordinator(m)
            | Error::PlanIo(m)
            | Error::Hw(m)
            | Error::Trace(m)
            | Error::Io(m) => m,
        };
        write!(f, "[{}] {}", self.subsystem(), msg)
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e.to_string())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_includes_subsystem_tag() {
        let e = Error::Schedule("bad dep".into());
        assert_eq!(e.to_string(), "[schedule] bad dep");
        assert_eq!(e.subsystem(), "schedule");
    }

    #[test]
    fn prefixed_keeps_subsystem() {
        let e = Error::PlanIo("line 1, col 6: bad token".into()).prefixed("request 42");
        assert_eq!(e.subsystem(), "plan-io");
        assert_eq!(e.to_string(), "[plan-io] request 42: line 1, col 6: bad token");
    }

    #[test]
    fn io_error_converts() {
        let ioe = std::io::Error::new(std::io::ErrorKind::NotFound, "gone");
        let e: Error = ioe.into();
        assert_eq!(e.subsystem(), "io");
    }
}
