//! Measured-curve calibration: fit the `.topo` hardware model to a
//! captured [`Trace`] so the simulator predicts the machine that actually
//! ran, not the hand-written reference.
//!
//! What gets fitted (everything else in the description is preserved):
//!
//! * **Per-backend bandwidth curves** — for every backend with traced
//!   transfer samples, ordinary least squares on the existing curve
//!   parameterization (`backend::transfer_time_with`). The model is linear
//!   once rearranged: with `x` the per-launch ramp bytes and
//!   `y = (t - link_lat) / launches`,
//!   `y = issue + (x + half) / (peak · smramp · 1e3)` — slope gives
//!   `peak`, intercept gives `issue` (with `half` kept from the prior row;
//!   slope and intercept cannot separate `issue` from `half/peak`, and
//!   `half` needs a size sweep far wider than one run provides).
//! * **Device compute rate** (`sm_tflops`) — the simulator's segment
//!   duration is linear in `1/sm_tflops` ([`crate::sim::waves`]), so the
//!   fit is a one-parameter least squares over traced compute segments
//!   (each carries its modeled FLOPs and wave shape).
//! * **Link bandwidth floors** — raised (never lowered) to the best
//!   observed effective bandwidth per level, so the link clamp cannot cap
//!   a fitted curve below what the machine demonstrably did.
//!
//! Fingerprint rule: a trace calibrates ONLY the machine shape it was
//! captured on — [`calibrate`] requires the trace's
//! [`crate::hw::fingerprint`] to equal the fingerprint of the target
//! description instantiated at the trace's world size. The emitted
//! description gets a `-cal` suffix and (being structurally different)
//! its own fingerprint, so `TuneCache` entries tuned on the uncalibrated
//! shape are automatically invalidated.

use crate::backend::{BackendKind, Caps, Curve};
use crate::error::{Error, Result};
use crate::hw::TopoDesc;
use crate::topo::{LinkLevel, Topology};
use crate::trace::{Trace, TraceKind};

/// Achieved MXU fraction assumed when fitting the compute rate — must
/// match the [`crate::sim::SimParams::default`] the exec cases simulate
/// under, or the fitted rate would be silently rescaled.
const FIT_MXU_EFF: f64 = 0.85;

/// Fit outcome for one backend.
#[derive(Debug, Clone, PartialEq)]
pub struct CurveFit {
    pub backend: BackendKind,
    pub samples: usize,
    pub before: Curve,
    pub after: Curve,
}

/// A completed calibration: the updated description plus what changed.
#[derive(Debug, Clone)]
pub struct Calibration {
    /// The calibrated description (print with [`crate::hw::print_desc`]).
    pub desc: TopoDesc,
    /// One entry per backend observed in the trace.
    pub curves: Vec<CurveFit>,
    /// (before, after, samples) for the device compute rate, when compute
    /// segments were traced.
    pub sm_tflops: Option<(f64, f64, usize)>,
    /// Link levels whose bandwidth floor was raised: (level tag, before,
    /// after GB/s).
    pub link_floors: Vec<(&'static str, f64, f64)>,
}

struct XferSample {
    bytes: usize,
    pieces: usize,
    comm_sms: usize,
    dur_us: f64,
    lat_us: f64,
}

/// Least-squares curve fit for one backend's samples (see module doc).
fn fit_curve(prior: Curve, caps: Caps, samples: &[XferSample]) -> Curve {
    let launches = |s: &XferSample| if caps.host_launched { s.pieces.max(1) } else { 1 } as f64;
    let ramp = |s: &XferSample| {
        if prior.sms_for_peak == 0 {
            1.0
        } else {
            (s.comm_sms as f64 / prior.sms_for_peak as f64).clamp(1e-3, 1.0)
        }
    };
    let pts: Vec<(f64, f64, f64)> = samples
        .iter()
        .map(|s| {
            let l = launches(s);
            let x = s.bytes as f64 / l; // per-launch ramp bytes
            let y = ((s.dur_us - s.lat_us) / l).max(0.0);
            (x, y, ramp(s))
        })
        .collect();
    let n = pts.len() as f64;
    if n == 0.0 {
        return prior;
    }
    let s_ramp = pts.iter().map(|(_, _, r)| r).sum::<f64>() / n;
    let mx = pts.iter().map(|(x, ..)| x).sum::<f64>() / n;
    let my = pts.iter().map(|(_, y, _)| y).sum::<f64>() / n;
    let sxx: f64 = pts.iter().map(|(x, ..)| (x - mx) * (x - mx)).sum();
    let sxy: f64 = pts.iter().map(|(x, y, _)| (x - mx) * (y - my)).sum();
    let mut c = prior;
    if sxx > 0.0 {
        let beta = sxy / sxx; // µs per ramp byte = 1/(peak·smramp·1e3)
        if beta.is_finite() && beta > 0.0 {
            c.peak_gbps = (1.0 / (beta * s_ramp * 1e3)).clamp(1e-3, 1e9);
        }
    }
    // intercept -> issue overhead, with the wire term at the mean size
    // removed under the fitted peak (issue floor keeps the curve sane when
    // samples are noise-dominated)
    let wire_at_mean = (mx + c.half_size) / (c.peak_gbps * s_ramp * 1e3);
    c.issue_us = (my - wire_at_mean).max(0.01);
    c
}

/// One microbenchmark point of a `calibrate sweep` run (a transfer of
/// `bytes` in `pieces` spans on `comm_sms` SMs took `dur_us`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SweepSample {
    pub bytes: usize,
    pub pieces: usize,
    pub comm_sms: usize,
    pub dur_us: f64,
}

/// Fit a full curve — including `half_size` — from a dedicated size × SM
/// sweep. Returns the fitted curve and its residual SSE (µs²).
///
/// [`fit_curve`] must keep `half` from the prior because a single run's
/// samples cannot separate it from `issue`: with fixed SM count the model
/// is affine in the sample size, and intercept + slope absorb any `half`
/// hypothesis identically. Varying `comm_sms` breaks the degeneracy for
/// SM-driven backends — `bytes/ramp` and `1/ramp` become independent
/// regressors, and only the true `half` zeroes the residual. So: grid
/// search `half` candidates (√2 steps, 1 KiB → 64 MiB) and solve the
/// remaining 2-parameter least squares `t - lat ≈ issue·launches + w/peak`
/// in closed form per candidate, keeping the minimum-SSE fit.
///
/// The sweep must stay below the link clamp (`bandwidth_with` flattens
/// clamped samples and nothing is identifiable there) — the driver keeps
/// sizes/SM counts in the ramp region. For host-launched backends
/// (`sms_for_peak == 0`) every candidate fits equally well and the prior
/// `half` wins the tie; callers get the same behavior as [`fit_curve`].
pub fn fit_curve_sweep(
    prior: Curve,
    caps: Caps,
    lat_us: f64,
    samples: &[SweepSample],
) -> Result<(Curve, f64)> {
    if samples.len() < 3 {
        return Err(Error::Trace(format!(
            "curve sweep needs at least 3 samples, got {} (sweep a size x sm grid)",
            samples.len()
        )));
    }
    // (launches L, ramp bytes x, sm ramp r, measured wire+issue time t)
    let pts: Vec<(f64, f64, f64, f64)> = samples
        .iter()
        .map(|s| {
            let l = if caps.host_launched { s.pieces.max(1) } else { 1 } as f64;
            let x = if caps.host_launched {
                (s.bytes as f64 / s.pieces.max(1) as f64).max(1.0)
            } else {
                (s.bytes as f64).max(1.0)
            };
            let r = if prior.sms_for_peak == 0 {
                1.0
            } else {
                (s.comm_sms as f64 / prior.sms_for_peak as f64).clamp(1e-3, 1.0)
            };
            (l, x, r, (s.dur_us - lat_us).max(0.0))
        })
        .collect();

    // closed-form LS for (issue, a = 1/peak) on regressors (L, w) at one
    // half candidate; returns (issue, a, sse)
    let fit_at_half = |half: f64| -> Option<(f64, f64, f64)> {
        let (mut s_ll, mut s_lw, mut s_ww, mut s_lt, mut s_wt) = (0.0, 0.0, 0.0, 0.0, 0.0);
        let rows: Vec<(f64, f64, f64)> = pts
            .iter()
            .map(|&(l, x, r, t)| {
                // bytes/(bw·1e3) with bw = peak·x/(x+half)·r, factored so the
                // unknown peak divides out into `a`
                let bytes = x * l;
                (l, bytes * (x + half) / (x * r * 1e3), t)
            })
            .collect();
        for &(l, w, t) in &rows {
            s_ll += l * l;
            s_lw += l * w;
            s_ww += w * w;
            s_lt += l * t;
            s_wt += w * t;
        }
        let det = s_ll * s_ww - s_lw * s_lw;
        if det.abs() < 1e-12 {
            return None;
        }
        let issue = (s_lt * s_ww - s_wt * s_lw) / det;
        let a = (s_ll * s_wt - s_lw * s_lt) / det;
        if !a.is_finite() || a <= 0.0 || !issue.is_finite() {
            return None;
        }
        let issue = issue.max(0.01);
        let sse: f64 = rows.iter().map(|&(l, w, t)| (issue * l + a * w - t).powi(2)).sum();
        Some((issue, a, sse))
    };

    let mut best: Option<(Curve, f64)> = None;
    let mut half = 1024.0;
    while half <= 64.0 * 1024.0 * 1024.0 {
        if let Some((issue, a, sse)) = fit_at_half(half) {
            let c = Curve {
                peak_gbps: (1.0 / a).clamp(1e-3, 1e9),
                half_size: half,
                issue_us: issue,
                sms_for_peak: prior.sms_for_peak,
            };
            if best.as_ref().map_or(true, |(_, b)| sse < *b) {
                best = Some((c, sse));
            }
        }
        half *= std::f64::consts::SQRT_2;
    }
    best.ok_or_else(|| {
        Error::Trace(
            "curve sweep: no half candidate produced a positive-bandwidth fit \
             (are the samples all latency-dominated?)"
                .into(),
        )
    })
}

/// Fit the device compute rate from traced segments: each segment's
/// simulated duration is `K_i / r` with `K_i` the wave-model duration at
/// `sm_tflops = 1` ([`crate::sim::waves`]), so least squares over
/// `dur_i ≈ K_i · (1/r)` has the closed form `1/r = Σ K·d / Σ K²`.
fn fit_sm_tflops(sms: usize, segs: &[(usize, f64, bool, f64)]) -> Option<(f64, usize)> {
    // segs: (tiles, total flops, quantized, measured duration)
    let mut skd = 0.0;
    let mut skk = 0.0;
    let mut n = 0usize;
    for &(tiles, flops, quantized, dur) in segs {
        if tiles == 0 || flops <= 0.0 || dur <= 0.0 {
            continue;
        }
        let mean_tile_us_at_r1 = (flops / tiles as f64) / (1e6 * FIT_MXU_EFF);
        let k = if quantized {
            crate::sim::waves::segment_duration_us(tiles, mean_tile_us_at_r1, sms, 0.0)
        } else {
            crate::sim::waves::streaming_duration_us(tiles, mean_tile_us_at_r1, sms, 0.0)
        };
        skd += k * dur;
        skk += k * k;
        n += 1;
    }
    if n == 0 || skk <= 0.0 || skd <= 0.0 {
        return None;
    }
    Some(((skk / skd).clamp(1e-9, 1e9), n))
}

/// Calibrate `desc` from a trace captured on the same machine shape.
///
/// Errors when the trace carries no fingerprint, the fingerprint does not
/// match `desc` at the trace's world size, or a traced backend has no row
/// on the description's arch (impossible for a genuine same-shape trace).
pub fn calibrate(trace: &Trace, desc: &TopoDesc) -> Result<Calibration> {
    if trace.fingerprint.is_empty() {
        return Err(Error::Trace(
            "trace carries no topology fingerprint; re-capture with `exec --trace` \
             (calibration refuses anonymous traces)"
                .into(),
        ));
    }
    let topo: Topology = desc.instantiate(trace.world)?;
    let fp = crate::hw::fingerprint(&topo);
    if trace.fingerprint != fp {
        return Err(Error::Trace(format!(
            "trace fingerprint {} does not match topology `{}` at world {} ({fp}); \
             calibrations must not cross machine shapes",
            trace.fingerprint, desc.name, trace.world
        )));
    }

    // -- collect samples -------------------------------------------------
    let mut by_backend: Vec<(BackendKind, Vec<XferSample>)> = Vec::new();
    let mut segs: Vec<(usize, f64, bool, f64)> = Vec::new();
    let mut best_eff: [(f64, bool); 3] = [(0.0, false); 3]; // local/intra/inter
    for ev in &trace.events {
        match &ev.kind {
            TraceKind::Transfer { src, dst, bytes, pieces, backend, comm_sms, .. } => {
                let link = topo.link(*src, *dst)?;
                let dur = ev.dur_us();
                if dur > 0.0 && *bytes > 0 {
                    let idx = match link.level {
                        LinkLevel::Local => 0,
                        LinkLevel::IntraNode => 1,
                        LinkLevel::InterNode => 2,
                    };
                    let eff = *bytes as f64 / (dur * 1e3);
                    if eff > best_eff[idx].0 {
                        best_eff[idx] = (eff, true);
                    }
                }
                let sample = XferSample {
                    bytes: *bytes,
                    pieces: *pieces,
                    comm_sms: *comm_sms,
                    dur_us: dur,
                    lat_us: link.lat_us,
                };
                match by_backend.iter_mut().find(|(b, _)| b == backend) {
                    Some((_, v)) => v.push(sample),
                    None => by_backend.push((*backend, vec![sample])),
                }
            }
            TraceKind::Compute { tiles, flops, quantized, .. } => {
                segs.push((*tiles, *flops, *quantized, ev.dur_us()));
            }
            _ => {}
        }
    }
    if by_backend.is_empty() && segs.is_empty() {
        return Err(Error::Trace(
            "trace contains no transfer or compute samples; nothing to calibrate".into(),
        ));
    }
    by_backend.sort_by_key(|(b, _)| b.index());

    // -- fit -------------------------------------------------------------
    let mut out = desc.clone();
    if !out.name.ends_with("-cal") {
        out.name.push_str("-cal");
    }

    let mut curves = Vec::new();
    for (backend, samples) in &by_backend {
        let entry = desc.arch.entry(*backend).ok_or_else(|| {
            Error::Trace(format!(
                "trace used backend {} but arch `{}` has no row for it — \
                 the trace cannot be from this machine shape",
                backend.name(),
                desc.arch.name()
            ))
        })?;
        let after = fit_curve(entry.curve, entry.caps, samples);
        out.arch.set(*backend, entry.caps, after);
        curves.push(CurveFit {
            backend: *backend,
            samples: samples.len(),
            before: entry.curve,
            after,
        });
    }

    // The simulator runs segments on `sms_per_device - reserved_comm_sms`;
    // reconstruct the traced plan's reservation by codegen's own rule
    // (dedicated-SM backends statically reserve their comm SMs) so the
    // compute fit models the pool the segments actually map back onto.
    let reserved = by_backend
        .iter()
        .filter(|(b, _)| desc.arch.caps(*b).dedicated_sms)
        .flat_map(|(_, v)| v.iter().map(|s| s.comm_sms))
        .max()
        .unwrap_or(0);
    let pool = desc.sms_per_device.saturating_sub(reserved).max(1);
    let sm_tflops = fit_sm_tflops(pool, &segs).map(|(r, n)| (desc.sm_tflops, r, n));
    if let Some((_, fitted, _)) = sm_tflops {
        out.sm_tflops = fitted;
    }

    // raise link floors so the clamp never caps a demonstrated rate
    let mut link_floors = Vec::new();
    let links = [
        ("local", &mut out.local),
        ("intra", &mut out.intra),
        ("inter", &mut out.inter),
    ];
    for (i, (tag, link)) in links.into_iter().enumerate() {
        let (eff, seen) = best_eff[i];
        let floor = eff * 1.05;
        if seen && floor > link.bw_gbps {
            link_floors.push((tag, link.bw_gbps, floor));
            link.bw_gbps = floor;
        }
    }

    Ok(Calibration { desc: out, curves, sm_tflops, link_floors })
}

impl Calibration {
    /// Fit summary table ([`crate::metrics::Table`], paper-style).
    pub fn table(&self) -> crate::metrics::Table {
        let mut t = crate::metrics::Table::new(
            "Calibration: fitted curve rows (measured vs prior)",
            &["samples", "peak before", "peak after", "issue before", "issue after"],
            "GB/s | us",
        );
        for f in &self.curves {
            t.push_row(
                f.backend.name(),
                vec![
                    f.samples as f64,
                    f.before.peak_gbps,
                    f.after.peak_gbps,
                    f.before.issue_us,
                    f.after.issue_us,
                ],
            );
        }
        if let Some((before, after, n)) = self.sm_tflops {
            t.push_row("sm-tflops", vec![n as f64, before, after, f64::NAN, f64::NAN]);
        }
        t
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend;
    use crate::trace::TraceEvent;

    fn desc() -> TopoDesc {
        crate::hw::catalog::desc("h100_node").unwrap()
    }

    fn stamped_trace(world: usize, events: Vec<TraceEvent>) -> Trace {
        let topo = desc().instantiate(world).unwrap();
        Trace {
            world,
            fingerprint: crate::hw::fingerprint(&topo),
            meta: vec![],
            events,
        }
    }

    fn xfer(bytes: usize, dur_us: f64) -> TraceEvent {
        TraceEvent {
            start_us: 0.0,
            end_us: dur_us,
            kind: TraceKind::Transfer {
                src: 0,
                dst: 1,
                op: 0,
                bytes,
                pieces: 1,
                backend: BackendKind::CopyEngine,
                comm_sms: 0,
                reduce: false,
                signal: 0,
            },
        }
    }

    #[test]
    fn fingerprint_mismatch_rejected() {
        let mut t = stamped_trace(2, vec![xfer(1024, 5.0)]);
        t.fingerprint = "0000000000000000".into();
        let e = calibrate(&t, &desc()).unwrap_err();
        assert!(e.to_string().contains("must not cross machine shapes"), "{e}");
        t.fingerprint = String::new();
        let e = calibrate(&t, &desc()).unwrap_err();
        assert!(e.to_string().contains("no topology fingerprint"), "{e}");
        // world change is a shape change too: same events, world 4 print
        let mut t4 = stamped_trace(2, vec![xfer(1024, 5.0)]);
        t4.world = 4; // fingerprint still the world-2 one
        assert!(calibrate(&t4, &desc()).is_err());
    }

    #[test]
    fn empty_trace_rejected() {
        let t = stamped_trace(2, vec![]);
        let e = calibrate(&t, &desc()).unwrap_err();
        assert!(e.to_string().contains("nothing to calibrate"), "{e}");
    }

    #[test]
    fn curve_fit_recovers_a_synthetic_machine() {
        // generate samples from a KNOWN curve, fit, and require the model's
        // predictions to match the generator closely
        let truth = Curve {
            peak_gbps: 12.0,
            half_size: backend::curve(BackendKind::CopyEngine).half_size,
            issue_us: 7.0,
            sms_for_peak: 0,
        };
        let caps = backend::caps(BackendKind::CopyEngine);
        let d = desc();
        let topo = d.instantiate(2).unwrap();
        let lat = topo.intra.lat_us;
        let events: Vec<TraceEvent> = [64usize << 10, 256 << 10, 1 << 20, 4 << 20]
            .iter()
            .map(|&bytes| {
                // generator = the model itself, minus the link clamp (the
                // synthetic peak is far below the link, clamp inert)
                let dur = backend::transfer_time_with(truth, caps.host_launched, bytes, 1, 0, topo.intra);
                xfer(bytes, dur)
            })
            .collect();
        let t = stamped_trace(2, events);
        let cal = calibrate(&t, &d).unwrap();
        assert_eq!(cal.curves.len(), 1);
        let fit = &cal.curves[0];
        assert_eq!(fit.backend, BackendKind::CopyEngine);
        assert_eq!(fit.samples, 4);
        assert!(
            (fit.after.peak_gbps - truth.peak_gbps).abs() / truth.peak_gbps < 0.15,
            "peak {} vs {}",
            fit.after.peak_gbps,
            truth.peak_gbps
        );
        assert!(
            (fit.after.issue_us - truth.issue_us).abs() < 1.5,
            "issue {} vs {}",
            fit.after.issue_us,
            truth.issue_us
        );
        // the emitted description carries the fitted row, renamed, and
        // fingerprints differently from the source shape
        assert!(cal.desc.name.ends_with("-cal"), "{}", cal.desc.name);
        let cal_topo = cal.desc.instantiate(2).unwrap();
        assert_ne!(crate::hw::fingerprint(&cal_topo), t.fingerprint);
        assert_eq!(
            cal_topo.arch.curve(BackendKind::CopyEngine).peak_gbps,
            fit.after.peak_gbps
        );
        // untraced backends keep their prior rows
        assert_eq!(
            cal_topo.arch.curve(BackendKind::TmaSpecialized),
            backend::curve(BackendKind::TmaSpecialized)
        );
    }

    #[test]
    fn compute_fit_matches_measured_segments() {
        let d = desc();
        // one-wave segments measured at 100us for 1e6 flops/tile
        let seg = |tiles: usize, dur: f64| TraceEvent {
            start_us: 0.0,
            end_us: dur,
            kind: TraceKind::Compute {
                rank: 0,
                op: 0,
                calls: tiles,
                tiles,
                flops: 1e6 * tiles as f64,
                quantized: false,
            },
        };
        let t = stamped_trace(2, vec![seg(1, 100.0), seg(2, 200.0), seg(4, 400.0)]);
        let cal = calibrate(&t, &d).unwrap();
        let (before, after, n) = cal.sm_tflops.unwrap();
        assert_eq!(n, 3);
        assert_eq!(before, d.sm_tflops);
        // streaming model: dur = flops/(sms·r·1e6·eff)
        // -> r = flops/(sms·dur·1e6·eff) = 1e6/(132·100·1e6·0.85)
        let want = 1e6 / (d.sms_per_device as f64 * 100.0 * 1e6 * FIT_MXU_EFF);
        assert!((after - want).abs() / want < 1e-6, "{after} vs {want}");
        assert_eq!(cal.desc.sm_tflops, after);
        // a lint-style round trip of the emitted text holds
        let text = crate::hw::print_desc(&cal.desc);
        let reparsed = crate::hw::parse_desc(&text).unwrap();
        assert_eq!(reparsed, cal.desc);
    }

    #[test]
    fn compute_fit_honors_dedicated_sm_reservation() {
        // A traced plan whose realization statically reserves comm SMs
        // (dedicated backend) runs its segments on the REDUCED pool in the
        // simulator — the fit must reconstruct that from the transfers, or
        // re-simulating the traced plan would overpredict every segment.
        let d = desc();
        let seg = TraceEvent {
            start_us: 0.0,
            end_us: 100.0,
            kind: TraceKind::Compute {
                rank: 0,
                op: 0,
                calls: 1,
                tiles: 1,
                flops: 1e6,
                quantized: false,
            },
        };
        let ldst = TraceEvent {
            start_us: 0.0,
            end_us: 2.0,
            kind: TraceKind::Transfer {
                src: 0,
                dst: 1,
                op: 1,
                bytes: 4096,
                pieces: 1,
                backend: BackendKind::LdStSpecialized, // dedicated-SM row
                comm_sms: 32,
                reduce: true,
                signal: 0,
            },
        };
        let t = stamped_trace(2, vec![seg, ldst]);
        let cal = calibrate(&t, &d).unwrap();
        let (_, after, _) = cal.sm_tflops.unwrap();
        let pool = (d.sms_per_device - 32) as f64;
        let want = 1e6 / (pool * 100.0 * 1e6 * FIT_MXU_EFF);
        assert!((after - want).abs() / want < 1e-6, "{after} vs {want}");
    }

    #[test]
    fn link_floor_raised_when_measured_faster() {
        let d = desc();
        // 64 MiB in 10us = 6400 GB/s effective, far above the intra link
        let t = stamped_trace(2, vec![xfer(64 << 20, 10.0)]);
        let cal = calibrate(&t, &d).unwrap();
        assert_eq!(cal.link_floors.len(), 1);
        let (tag, before, after) = cal.link_floors[0];
        assert_eq!(tag, "intra");
        assert_eq!(before, d.intra.bw_gbps);
        assert!(after > before);
        assert_eq!(cal.desc.intra.bw_gbps, after);
        // slow transfers never lower a floor
        let t = stamped_trace(2, vec![xfer(1024, 1000.0)]);
        let cal = calibrate(&t, &d).unwrap();
        assert!(cal.link_floors.is_empty());
        assert_eq!(cal.desc.intra.bw_gbps, d.intra.bw_gbps);
    }

    #[test]
    fn sweep_fit_identifies_half_size() {
        // truth: a Tma-like SM-driven curve with half on the sweep's √2
        // grid; samples span sizes AND comm SMs, which is exactly what
        // makes `half` identifiable (see fit_curve_sweep doc)
        let truth = Curve {
            peak_gbps: 300.0,
            half_size: 512.0 * 1024.0,
            issue_us: 0.5,
            sms_for_peak: 16,
        };
        let caps = backend::caps(BackendKind::TmaSpecialized);
        // huge link so the clamp never flattens a sample
        let link = crate::topo::LinkSpec {
            level: crate::topo::LinkLevel::IntraNode,
            bw_gbps: 1e6,
            lat_us: 1.0,
        };
        let mut samples = Vec::new();
        for &bytes in &[64usize << 10, 256 << 10, 1 << 20, 4 << 20] {
            for &sms in &[4usize, 8, 16] {
                samples.push(SweepSample {
                    bytes,
                    pieces: 1,
                    comm_sms: sms,
                    dur_us: backend::transfer_time_with(truth, caps.host_launched, bytes, 1, sms, link),
                });
            }
        }
        let prior = backend::curve(BackendKind::TmaSpecialized);
        let (fit, sse) = fit_curve_sweep(prior, caps, link.lat_us, &samples).unwrap();
        assert!(
            (fit.half_size / truth.half_size).ln().abs() < 0.5f64.ln().abs(),
            "half {} vs {} (sse {sse})",
            fit.half_size,
            truth.half_size
        );
        assert!(
            (fit.peak_gbps - truth.peak_gbps).abs() / truth.peak_gbps < 0.05,
            "peak {} vs {}",
            fit.peak_gbps,
            truth.peak_gbps
        );
        assert!((fit.issue_us - truth.issue_us).abs() < 0.1, "issue {}", fit.issue_us);
        assert_eq!(fit.sms_for_peak, truth.sms_for_peak);
        // near-exact generator recovery: residual is numerically tiny
        assert!(sse < 1e-6, "sse {sse}");

        // degenerate input is refused, not mis-fit
        assert!(fit_curve_sweep(prior, caps, 1.0, &samples[..2]).is_err());
        let flat: Vec<SweepSample> =
            samples.iter().map(|s| SweepSample { dur_us: 0.0, ..*s }).collect();
        assert!(fit_curve_sweep(prior, caps, 1.0, &flat).is_err());
    }

    #[test]
    fn calibration_lowers_model_error_on_synthetic_samples() {
        // end to end at the fit level: generated from a machine 50x slower
        // than the catalog entry, the calibrated curve must predict the
        // samples better than the prior on every sample
        let d = desc();
        let topo = d.instantiate(2).unwrap();
        let caps = backend::caps(BackendKind::CopyEngine);
        let slow = Curve { peak_gbps: 8.0, issue_us: 120.0, ..backend::curve(BackendKind::CopyEngine) };
        let sizes = [32usize << 10, 128 << 10, 512 << 10, 2 << 20];
        let events: Vec<TraceEvent> = sizes
            .iter()
            .map(|&b| {
                xfer(b, backend::transfer_time_with(slow, caps.host_launched, b, 1, 0, topo.intra))
            })
            .collect();
        let t = stamped_trace(2, events);
        let cal = calibrate(&t, &d).unwrap();
        let fitted = cal.curves[0].after;
        let prior = cal.curves[0].before;
        for &b in &sizes {
            let want = backend::transfer_time_with(slow, caps.host_launched, b, 1, 0, topo.intra);
            let got_fit = backend::transfer_time_with(fitted, caps.host_launched, b, 1, 0, topo.intra);
            let got_prior =
                backend::transfer_time_with(prior, caps.host_launched, b, 1, 0, topo.intra);
            assert!(
                (got_fit - want).abs() < (got_prior - want).abs(),
                "{b}B: fit {got_fit} prior {got_prior} want {want}"
            );
        }
        assert!(cal.table().render().contains("copy-engine"));
    }
}
