//! Chunk-level execution tracing + measured-curve calibration: the
//! sim↔execution feedback loop.
//!
//! Everything upstream of this module *predicts*: `sim::` scores plans on
//! the `.topo` curve store, the autotuner ranks candidates on those scores.
//! Nothing measured what the exec engines actually did — so the hardware
//! model stayed a hand-written artifact. This subsystem closes the loop:
//!
//! * **Capture** (this file) — both exec engines emit timestamped
//!   [`TraceEvent`]s (transfer applies with bytes/peer/backend, signal-wait
//!   spans, kernel-call spans, compute-segment spans) into a [`TraceSink`]
//!   with one lock per rank lane, toggled per run: when tracing is off the
//!   engines carry a `None` sink and the hot path is a dead branch.
//! * **Export / analyze** ([`export`], [`analyze`]) — Chrome `trace_event`
//!   JSON (one compute + one comm track per rank, wait spans nested in
//!   their op's track) with a schema-checked importer, plus an overlap
//!   report: comm-hidden fraction, busy-critical-path makespan, per-rank
//!   slack, and the sim-vs-trace divergence row — all rendered through
//!   [`crate::metrics::Table`] so `trace overlap` prints paper-style.
//! * **Calibrate** ([`calibrate`]) — least-squares fits of per-backend
//!   bandwidth [`crate::backend::Curve`] rows (and the device compute
//!   rate) from traced samples, emitted as an updated `.topo` through
//!   `hw::format`'s canonical printer. Calibrations are keyed by
//!   [`crate::hw::fingerprint`]: a trace only calibrates the machine shape
//!   it was captured on.
//!
//! Event identity: both engines interpret the same
//! [`crate::exec::PreparedPlan`], so a traced run produces the same event
//! *set* (kinds, ranks, op indices, signals — [`Trace::event_keys`])
//! under either engine; only timestamps differ. Tests assert this for
//! every registry exec case.

pub mod analyze;
pub mod calibrate;
pub(crate) mod json;
pub mod export;

use std::sync::Mutex;
use std::time::Instant;

use crate::backend::BackendKind;

pub use analyze::{analyze, OverlapReport, TraceStats};
pub use calibrate::{calibrate, fit_curve_sweep, Calibration, SweepSample};
pub use export::{
    check_chrome_header, check_chrome_schema, from_chrome_json, syncopate_header, to_chrome_json,
    to_chrome_json_overlay,
};

/// What one traced span was doing.
#[derive(Debug, Clone, PartialEq)]
pub enum TraceKind {
    /// One applied chunk transfer (attributed to the source rank's comm
    /// lane). `signal` is the plan-unique completion signal — the event's
    /// identity across engines. `op` is the plan op index of the `Issue`
    /// on the source rank, anchoring the transfer into that rank's program
    /// order (how `perf::critical` interleaves it with waits/computes).
    Transfer {
        src: usize,
        dst: usize,
        op: usize,
        bytes: usize,
        pieces: usize,
        backend: BackendKind,
        comm_sms: usize,
        reduce: bool,
        signal: usize,
    },
    /// A rank blocked on (then passed) a dependency signal. `op` is the
    /// plan op index of the `Wait`.
    Wait { rank: usize, op: usize, signal: usize },
    /// One kernel call (`artifact` names the AOT entry, or the built-in
    /// family for artifact-free calls).
    Kernel { rank: usize, op: usize, call: usize, artifact: String },
    /// A whole compute segment (its kernel calls nest inside). `flops` is
    /// the segment's modeled total, carried so calibration can fit the
    /// device compute rate; `quantized` mirrors the plan's wave model.
    Compute { rank: usize, op: usize, calls: usize, tiles: usize, flops: f64, quantized: bool },
}

/// One timestamped span. Times are microseconds from the sink's origin
/// (run start).
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEvent {
    pub start_us: f64,
    pub end_us: f64,
    pub kind: TraceKind,
}

impl TraceEvent {
    pub fn dur_us(&self) -> f64 {
        (self.end_us - self.start_us).max(0.0)
    }

    /// The rank whose lane this event lives on (transfers: the source).
    pub fn rank(&self) -> usize {
        match &self.kind {
            TraceKind::Transfer { src, .. } => *src,
            TraceKind::Wait { rank, .. }
            | TraceKind::Kernel { rank, .. }
            | TraceKind::Compute { rank, .. } => *rank,
        }
    }

    /// Timestamp-free identity, stable across engines: two traced runs of
    /// the same prepared plan produce equal key multisets.
    pub fn key(&self) -> String {
        match &self.kind {
            TraceKind::Transfer { src, dst, bytes, pieces, backend, reduce, signal, .. } => {
                format!(
                    "xfer sig{signal} {src}->{dst} {bytes}B p{pieces} {} r{}",
                    backend.name(),
                    *reduce as u8
                )
            }
            TraceKind::Wait { rank, op, signal } => format!("wait r{rank} op{op} sig{signal}"),
            TraceKind::Kernel { rank, op, call, artifact } => {
                format!("call r{rank} op{op} c{call} {artifact}")
            }
            TraceKind::Compute { rank, op, calls, tiles, .. } => {
                format!("seg r{rank} op{op} t{tiles} c{calls}")
            }
        }
    }
}

/// Lock-cheap event collector the engines write into: one mutexed lane per
/// rank, so rank threads contend only when another rank lands a transfer
/// event on their lane (events are attributed to the SOURCE rank, so a
/// destination draining its parked queue writes to the issuer's lane).
/// Created per traced run; the engines take `Option<&TraceSink>` and skip
/// every clock read when it is `None`.
#[derive(Debug)]
pub struct TraceSink {
    origin: Instant,
    lanes: Vec<Mutex<Vec<TraceEvent>>>,
}

impl TraceSink {
    pub fn new(world: usize) -> Self {
        TraceSink {
            origin: Instant::now(),
            lanes: (0..world.max(1)).map(|_| Mutex::new(Vec::new())).collect(),
        }
    }

    /// Microseconds since the sink was created (the run clock).
    pub fn now_us(&self) -> f64 {
        self.origin.elapsed().as_secs_f64() * 1e6
    }

    /// Record one event on its rank's lane.
    pub fn push(&self, ev: TraceEvent) {
        let lane = ev.rank().min(self.lanes.len() - 1);
        self.lanes[lane].lock().unwrap().push(ev);
    }

    /// Drain into an immutable [`Trace`] (events sorted per rank by start
    /// time; fingerprint/meta left for the caller to stamp).
    pub fn into_trace(self, world: usize) -> Trace {
        let mut events = Vec::new();
        for lane in self.lanes {
            let mut evs = lane.into_inner().unwrap();
            evs.sort_by(|a, b| a.start_us.total_cmp(&b.start_us));
            events.extend(evs);
        }
        Trace { world, fingerprint: String::new(), meta: Vec::new(), events }
    }
}

/// A finished capture: every event of one run, plus the machine-shape
/// fingerprint and free-form provenance metadata (case name, world, seed,
/// ... — whatever the producer knows). The fingerprint is load-bearing:
/// [`calibrate`] refuses traces whose fingerprint does not match the
/// topology being calibrated, so measured curves never leak across
/// machine shapes.
#[derive(Debug, Clone, PartialEq)]
pub struct Trace {
    pub world: usize,
    /// [`crate::hw::fingerprint`] of the topology the run executed on
    /// (empty when unknown — e.g. a hand-built trace).
    pub fingerprint: String,
    /// Sorted (key, value) provenance pairs.
    pub meta: Vec<(String, String)>,
    /// All events, grouped by rank lane, sorted by start within each lane.
    pub events: Vec<TraceEvent>,
}

impl Trace {
    /// Stamp provenance (sorts keys; replaces an existing key).
    pub fn set_meta(&mut self, key: &str, value: &str) {
        self.meta.retain(|(k, _)| k != key);
        self.meta.push((key.to_string(), value.to_string()));
        self.meta.sort();
    }

    /// Look up a provenance value.
    pub fn meta(&self, key: &str) -> Option<&str> {
        self.meta.iter().find(|(k, _)| k == key).map(|(_, v)| v.as_str())
    }

    /// Sorted timestamp-free event keys — the cross-engine identity set.
    pub fn event_keys(&self) -> Vec<String> {
        let mut keys: Vec<String> = self.events.iter().map(TraceEvent::key).collect();
        keys.sort();
        keys
    }

    /// Event count of one kind class: "transfer" | "wait" | "kernel" |
    /// "compute".
    pub fn count(&self, class: &str) -> usize {
        self.events
            .iter()
            .filter(|e| match &e.kind {
                TraceKind::Transfer { .. } => class == "transfer",
                TraceKind::Wait { .. } => class == "wait",
                TraceKind::Kernel { .. } => class == "kernel",
                TraceKind::Compute { .. } => class == "compute",
            })
            .count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn xfer(signal: usize) -> TraceEvent {
        TraceEvent {
            start_us: 1.0,
            end_us: 2.5,
            kind: TraceKind::Transfer {
                src: 0,
                dst: 1,
                op: 0,
                bytes: 4096,
                pieces: 1,
                backend: BackendKind::CopyEngine,
                comm_sms: 0,
                reduce: false,
                signal,
            },
        }
    }

    #[test]
    fn sink_collects_per_rank_sorted() {
        let sink = TraceSink::new(2);
        sink.push(TraceEvent {
            start_us: 5.0,
            end_us: 6.0,
            kind: TraceKind::Wait { rank: 1, op: 0, signal: 0 },
        });
        sink.push(xfer(0));
        sink.push(TraceEvent {
            start_us: 0.5,
            end_us: 0.9,
            kind: TraceKind::Kernel { rank: 0, op: 1, call: 0, artifact: "g".into() },
        });
        let t = sink.into_trace(2);
        assert_eq!(t.world, 2);
        assert_eq!(t.events.len(), 3);
        // rank 0's lane first, sorted by start (kernel before transfer)
        assert!(matches!(t.events[0].kind, TraceKind::Kernel { .. }));
        assert!(matches!(t.events[1].kind, TraceKind::Transfer { .. }));
        assert_eq!(t.events[2].rank(), 1);
        assert_eq!(t.count("transfer"), 1);
        assert_eq!(t.count("wait"), 1);
        assert_eq!(t.count("kernel"), 1);
        assert_eq!(t.count("compute"), 0);
    }

    #[test]
    fn clock_is_monotone() {
        let sink = TraceSink::new(1);
        let a = sink.now_us();
        let b = sink.now_us();
        assert!(b >= a && a >= 0.0);
    }

    #[test]
    fn keys_are_timestamp_free_and_sorted() {
        let mut a = xfer(3);
        let mut b = xfer(3);
        a.start_us = 0.0;
        b.start_us = 99.0;
        assert_eq!(a.key(), b.key());
        let t = Trace {
            world: 2,
            fingerprint: String::new(),
            meta: vec![],
            events: vec![xfer(7), xfer(2)],
        };
        let keys = t.event_keys();
        assert!(keys[0] < keys[1], "{keys:?}");
        assert!(keys[0].contains("sig2"), "{keys:?}");
    }

    #[test]
    fn meta_set_get_replace() {
        let mut t = Trace { world: 2, fingerprint: "fp".into(), meta: vec![], events: vec![] };
        t.set_meta("case", "ag-gemm");
        t.set_meta("world", "4");
        t.set_meta("case", "gemm-rs");
        assert_eq!(t.meta("case"), Some("gemm-rs"));
        assert_eq!(t.meta("world"), Some("4"));
        assert_eq!(t.meta("nope"), None);
        assert_eq!(t.meta.len(), 2);
    }
}
