//! Minimal JSON value parser for the Chrome-trace importer and schema
//! check. The offline build carries no serde; this is the same hand-rolled
//! discipline as the `.sched` / `.topo` parsers — a strict, small reader
//! of the JSON the exporter writes (plus ordinary whitespace), with byte
//! offsets in every error.
//!
//! Scope: full JSON value grammar minus `\uXXXX` escapes beyond BMP
//! shortcuts — the exporter never emits any (labels are ASCII-escaped).

use crate::error::{Error, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub(crate) enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as usize),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
}

/// Parse one complete JSON document (trailing whitespace allowed).
pub(crate) fn parse(text: &str) -> Result<Json> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters after the JSON document"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl Parser<'_> {
    fn err(&self, msg: &str) -> Error {
        Error::Trace(format!("JSON byte {}: {msg}", self.pos))
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected `{}`", b as char)))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected `{word}`")))
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(self.err(&format!("unexpected `{}`", c as char))),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut pairs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(pairs));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            pairs.push((key, self.value()?));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(pairs));
                }
                _ => return Err(self.err("expected `,` or `}` in object")),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected `,` or `]` in array")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let esc = self.peek().ok_or_else(|| self.err("dangling escape"))?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        other => {
                            return Err(
                                self.err(&format!("unsupported escape `\\{}`", other as char))
                            )
                        }
                    }
                }
                Some(c) if c < 0x20 => return Err(self.err("raw control char in string")),
                Some(_) => {
                    // consume one UTF-8 scalar (input is a &str, so bytes
                    // are valid UTF-8; find the char boundary)
                    let start = self.pos;
                    self.pos += 1;
                    while self.pos < self.bytes.len() && (self.bytes[self.pos] & 0xC0) == 0x80 {
                        self.pos += 1;
                    }
                    out.push_str(std::str::from_utf8(&self.bytes[start..self.pos]).unwrap());
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| Error::Trace(format!("JSON byte {start}: bad number `{s}`")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars_arrays_objects() {
        let v = parse(r#"{"a": 1.5, "b": [true, null, "x\ny"], "c": {"d": -2e3}}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_f64(), Some(1.5));
        let arr = v.get("b").unwrap().as_arr().unwrap();
        assert_eq!(arr[0], Json::Bool(true));
        assert_eq!(arr[1], Json::Null);
        assert_eq!(arr[2].as_str(), Some("x\ny"));
        assert_eq!(v.get("c").unwrap().get("d").unwrap().as_f64(), Some(-2000.0));
        assert_eq!(v.get("missing"), None);
    }

    #[test]
    fn as_usize_rejects_fractions_and_negatives() {
        assert_eq!(parse("3").unwrap().as_usize(), Some(3));
        assert_eq!(parse("3.5").unwrap().as_usize(), None);
        assert_eq!(parse("-3").unwrap().as_usize(), None);
    }

    #[test]
    fn errors_carry_byte_offsets() {
        for bad in ["{", "[1,", "\"open", "{\"a\" 1}", "[1 2]", "tru", "{} junk"] {
            let e = parse(bad).unwrap_err();
            assert!(e.to_string().contains("byte"), "{bad}: {e}");
        }
    }

    #[test]
    fn empty_containers_and_unicode() {
        assert_eq!(parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Json::Obj(vec![]));
        assert_eq!(parse("\"héllo→\"").unwrap().as_str(), Some("héllo→"));
    }
}
