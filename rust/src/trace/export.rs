//! Chrome `trace_event` JSON export / import for [`Trace`]s.
//!
//! The exported file opens directly in `chrome://tracing` / Perfetto: one
//! *compute* track and one *comm* track per rank (thread-name metadata
//! events label them), every span a `"ph": "X"` complete event with `ts` /
//! `dur` in microseconds, wait and kernel spans nesting inside their
//! segment's span on the compute track. A `"syncopate"` top-level object
//! (ignored by viewers) carries the world size, the
//! [`crate::hw::fingerprint`] of the machine shape the run executed on,
//! and free-form provenance metadata — everything [`super::calibrate`]
//! needs to refuse cross-machine traces and rebuild the traced case.
//!
//! [`from_chrome_json`] inverts [`to_chrome_json`] exactly (timestamps are
//! printed with `{}`, the shortest f64 round-trip form), and
//! [`check_chrome_schema`] validates the structural contract without
//! building a [`Trace`] — the CI smoke and the corpus test both run it.

use crate::backend::BackendKind;
use crate::error::{Error, Result};
use crate::trace::json::{self, Json};
use crate::trace::{Trace, TraceEvent, TraceKind};
use crate::util::json_escape as esc;

/// Track id: compute/wait/kernel spans of rank `r` on tid `2r`, its
/// outgoing transfers on tid `2r + 1` (transfers overlap compute in the
/// parallel engine; separate tracks keep the viewer's nesting clean).
fn tid(ev: &TraceEvent) -> usize {
    match ev.kind {
        TraceKind::Transfer { .. } => 2 * ev.rank() + 1,
        _ => 2 * ev.rank(),
    }
}

/// Render one span, with an optional critical-path highlight: `cname`
/// paints the span red in the viewer, `args.critical` marks it for
/// downstream tooling (extra args keys are schema-transparent).
fn event_json_with(ev: &TraceEvent, critical: bool) -> String {
    let (name, cat, args) = match &ev.kind {
        TraceKind::Transfer { src, dst, op, bytes, pieces, backend, comm_sms, reduce, signal } => {
            (
                format!("{src}->{dst} {}", backend.name()),
                "transfer",
                format!(
                    "{{\"src\": {src}, \"dst\": {dst}, \"op\": {op}, \"bytes\": {bytes}, \
                     \"pieces\": {pieces}, \"backend\": \"{}\", \"sms\": {comm_sms}, \
                     \"reduce\": {reduce}, \"signal\": {signal}}}",
                    backend.name()
                ),
            )
        }
        TraceKind::Wait { rank, op, signal } => (
            format!("wait sig{signal}"),
            "wait",
            format!("{{\"rank\": {rank}, \"op\": {op}, \"signal\": {signal}}}"),
        ),
        TraceKind::Kernel { rank, op, call, artifact } => (
            esc(artifact),
            "kernel",
            format!("{{\"rank\": {rank}, \"op\": {op}, \"call\": {call}}}"),
        ),
        TraceKind::Compute { rank, op, calls, tiles, flops, quantized } => (
            format!("seg {tiles} tiles"),
            "compute",
            format!(
                "{{\"rank\": {rank}, \"op\": {op}, \"calls\": {calls}, \"tiles\": {tiles}, \
                 \"flops\": {flops}, \"quantized\": {quantized}}}"
            ),
        ),
    };
    let (mark, args) = if critical {
        ("\"cname\": \"terrible\", ", args.replacen('{', "{\"critical\": true, ", 1))
    } else {
        ("", args)
    };
    // `end` is ours, not Chrome's (viewers ignore unknown keys): `ts + dur`
    // does not always reproduce `end_us` bit-exactly in f64, and the
    // importer promises an exact round trip
    format!(
        "    {{\"ph\": \"X\", {mark}\"pid\": 0, \"tid\": {}, \"name\": \"{name}\", \
         \"cat\": \"{cat}\", \"ts\": {}, \"dur\": {}, \"end\": {}, \"args\": {args}}}",
        tid(ev),
        ev.start_us,
        ev.dur_us(),
        ev.end_us
    )
}

/// Render the `"syncopate"` top-level header object every Chrome export
/// in the repo shares (execution trace, flight recorder, sim timeline):
/// schema version, world size, machine fingerprint, sorted provenance
/// meta, plus producer-specific `extra` pairs whose values arrive
/// pre-rendered as JSON (`"true"`, `"\"text\""`, ...). Returns the
/// complete `  "syncopate": {...}` fragment, no trailing comma.
pub fn syncopate_header(
    world: usize,
    fingerprint: &str,
    meta: &[(String, String)],
    extra: &[(&str, String)],
) -> String {
    let mut out = format!(
        "  \"syncopate\": {{\"version\": 1, \"world\": {world}, \"fingerprint\": \"{}\"",
        esc(fingerprint)
    );
    for (k, v) in extra {
        out.push_str(&format!(", \"{k}\": {v}"));
    }
    out.push_str(", \"meta\": {");
    for (i, (k, v)) in meta.iter().enumerate() {
        if i > 0 {
            out.push_str(", ");
        }
        out.push_str(&format!("\"{}\": \"{}\"", esc(k), esc(v)));
    }
    out.push_str("}}");
    out
}

/// Render a trace as Chrome `trace_event` JSON.
pub fn to_chrome_json(trace: &Trace) -> String {
    to_chrome_json_overlay(trace, &[])
}

/// [`to_chrome_json`] with a critical-path overlay: events whose
/// timestamp-free [`TraceEvent::key`] appears in `critical_keys` are
/// painted red (`cname`) and tagged `args.critical` — the rendering of
/// [`crate::perf::critical_path`]'s verdict. An empty slice degenerates
/// to the plain export.
pub fn to_chrome_json_overlay(trace: &Trace, critical_keys: &[String]) -> String {
    let crit: std::collections::HashSet<&str> =
        critical_keys.iter().map(String::as_str).collect();
    let mut out = String::from("{\n  \"displayTimeUnit\": \"ms\",\n");
    out.push_str(&syncopate_header(trace.world, &trace.fingerprint, &trace.meta, &[]));
    out.push_str(",\n  \"traceEvents\": [\n");
    let mut lines = Vec::new();
    // thread-name metadata: label every rank's compute + comm track
    for r in 0..trace.world {
        for (lane, label) in [(2 * r, format!("rank {r}")), (2 * r + 1, format!("rank {r} comm"))]
        {
            lines.push(format!(
                "    {{\"ph\": \"M\", \"pid\": 0, \"tid\": {lane}, \"name\": \"thread_name\", \
                 \"args\": {{\"name\": \"{label}\"}}}}"
            ));
        }
    }
    lines.extend(
        trace.events.iter().map(|ev| event_json_with(ev, crit.contains(ev.key().as_str()))),
    );
    out.push_str(&lines.join(",\n"));
    out.push_str("\n  ]\n}\n");
    out
}

/// Per-category required `args` keys (the schema contract).
const REQUIRED_ARGS: [(&str, &[&str]); 4] = [
    ("transfer", &["src", "dst", "op", "bytes", "pieces", "backend", "sms", "reduce", "signal"]),
    ("wait", &["rank", "op", "signal"]),
    ("kernel", &["rank", "op", "call"]),
    ("compute", &["rank", "op", "calls", "tiles", "flops", "quantized"]),
];

/// Validate the structural contract of an exported trace: a JSON object
/// with a `traceEvents` array whose `"X"` events carry `name`/`cat`/`ts`/
/// `dur`/`tid` and the per-category `args` keys, plus the `syncopate`
/// header with `world` and `fingerprint`. Returns the `"X"` event count.
pub fn check_chrome_schema(text: &str) -> Result<usize> {
    check_parsed(&json::parse(text)?)
}

/// Validate just the shared `syncopate` header contract of any Chrome
/// export in the repo — execution traces, flight-recorder dumps, and sim
/// timelines all carry it, while their *event* schemas differ (only the
/// trace export satisfies [`check_chrome_schema`]'s category table).
/// Returns `(world, fingerprint)`.
pub fn check_chrome_header(text: &str) -> Result<(usize, String)> {
    let doc = json::parse(text)?;
    let out = check_header_parsed(&doc)?;
    doc.get("traceEvents")
        .and_then(Json::as_arr)
        .ok_or_else(|| Error::Trace("missing `traceEvents` array".into()))?;
    Ok(out)
}

fn check_header_parsed(doc: &Json) -> Result<(usize, String)> {
    let sync = doc
        .get("syncopate")
        .ok_or_else(|| Error::Trace("missing `syncopate` header object".into()))?;
    let world = sync
        .get("world")
        .and_then(Json::as_usize)
        .ok_or_else(|| Error::Trace("syncopate.world missing or not an integer".into()))?;
    if world == 0 {
        return Err(Error::Trace("syncopate.world must be >= 1".into()));
    }
    let fp = sync
        .get("fingerprint")
        .and_then(Json::as_str)
        .ok_or_else(|| Error::Trace("syncopate.fingerprint missing or not a string".into()))?;
    Ok((world, fp.to_string()))
}

/// [`check_chrome_schema`] over an already-parsed document, so the
/// importer pays the parse exactly once.
fn check_parsed(doc: &Json) -> Result<usize> {
    check_header_parsed(doc)?;
    let events = doc
        .get("traceEvents")
        .and_then(Json::as_arr)
        .ok_or_else(|| Error::Trace("missing `traceEvents` array".into()))?;
    let mut spans = 0usize;
    for (i, ev) in events.iter().enumerate() {
        let ph = ev
            .get("ph")
            .and_then(Json::as_str)
            .ok_or_else(|| Error::Trace(format!("event {i}: missing `ph`")))?;
        match ph {
            "M" => continue, // metadata (thread names)
            "X" => {}
            other => {
                return Err(Error::Trace(format!(
                    "event {i}: unsupported phase `{other}` (exporter only emits X/M)"
                )))
            }
        }
        for key in ["ts", "dur"] {
            if ev.get(key).and_then(Json::as_f64).is_none() {
                return Err(Error::Trace(format!("event {i}: missing numeric `{key}`")));
            }
        }
        if ev.get("tid").and_then(Json::as_usize).is_none() {
            return Err(Error::Trace(format!("event {i}: missing integer `tid`")));
        }
        if ev.get("name").and_then(Json::as_str).is_none() {
            return Err(Error::Trace(format!("event {i}: missing string `name`")));
        }
        let cat = ev
            .get("cat")
            .and_then(Json::as_str)
            .ok_or_else(|| Error::Trace(format!("event {i}: missing string `cat`")))?;
        let required = REQUIRED_ARGS
            .iter()
            .find(|(c, _)| *c == cat)
            .map(|(_, keys)| *keys)
            .ok_or_else(|| Error::Trace(format!("event {i}: unknown category `{cat}`")))?;
        let args = ev
            .get("args")
            .ok_or_else(|| Error::Trace(format!("event {i}: missing `args` object")))?;
        for key in required {
            if args.get(key).is_none() {
                return Err(Error::Trace(format!(
                    "event {i} ({cat}): args missing `{key}`"
                )));
            }
        }
        spans += 1;
    }
    Ok(spans)
}

fn arg_usize(args: &Json, key: &str, i: usize) -> Result<usize> {
    args.get(key)
        .and_then(Json::as_usize)
        .ok_or_else(|| Error::Trace(format!("event {i}: args.{key} missing or not an integer")))
}

fn arg_f64(args: &Json, key: &str, i: usize) -> Result<f64> {
    args.get(key)
        .and_then(Json::as_f64)
        .ok_or_else(|| Error::Trace(format!("event {i}: args.{key} missing or not a number")))
}

/// Parse an exported trace back into a [`Trace`] (schema-checking as it
/// goes). Inverse of [`to_chrome_json`].
pub fn from_chrome_json(text: &str) -> Result<Trace> {
    let doc = json::parse(text)?;
    check_parsed(&doc)?;
    let sync = doc.get("syncopate").expect("schema-checked");
    let world = sync.get("world").and_then(Json::as_usize).expect("schema-checked");
    let fingerprint = sync
        .get("fingerprint")
        .and_then(Json::as_str)
        .expect("schema-checked")
        .to_string();
    let mut meta = Vec::new();
    if let Some(Json::Obj(pairs)) = sync.get("meta") {
        for (k, v) in pairs {
            let v = v
                .as_str()
                .ok_or_else(|| Error::Trace(format!("syncopate.meta.{k} is not a string")))?;
            meta.push((k.clone(), v.to_string()));
        }
    }
    meta.sort();

    let mut events = Vec::new();
    for (i, ev) in doc.get("traceEvents").and_then(Json::as_arr).expect("schema-checked").iter().enumerate()
    {
        if ev.get("ph").and_then(Json::as_str) != Some("X") {
            continue;
        }
        let args = ev.get("args").expect("schema-checked");
        let kind = match ev.get("cat").and_then(Json::as_str).expect("schema-checked") {
            "transfer" => {
                let b = args.get("backend").and_then(Json::as_str).ok_or_else(|| {
                    Error::Trace(format!("event {i}: args.backend is not a string"))
                })?;
                TraceKind::Transfer {
                    src: arg_usize(args, "src", i)?,
                    dst: arg_usize(args, "dst", i)?,
                    op: arg_usize(args, "op", i)?,
                    bytes: arg_usize(args, "bytes", i)?,
                    pieces: arg_usize(args, "pieces", i)?,
                    backend: BackendKind::by_name(b).ok_or_else(|| {
                        Error::Trace(format!("event {i}: unknown backend `{b}`"))
                    })?,
                    comm_sms: arg_usize(args, "sms", i)?,
                    reduce: matches!(args.get("reduce"), Some(Json::Bool(true))),
                    signal: arg_usize(args, "signal", i)?,
                }
            }
            "wait" => TraceKind::Wait {
                rank: arg_usize(args, "rank", i)?,
                op: arg_usize(args, "op", i)?,
                signal: arg_usize(args, "signal", i)?,
            },
            "kernel" => TraceKind::Kernel {
                rank: arg_usize(args, "rank", i)?,
                op: arg_usize(args, "op", i)?,
                call: arg_usize(args, "call", i)?,
                artifact: ev.get("name").and_then(Json::as_str).expect("schema-checked").into(),
            },
            "compute" => TraceKind::Compute {
                rank: arg_usize(args, "rank", i)?,
                op: arg_usize(args, "op", i)?,
                calls: arg_usize(args, "calls", i)?,
                tiles: arg_usize(args, "tiles", i)?,
                flops: arg_f64(args, "flops", i)?,
                quantized: matches!(args.get("quantized"), Some(Json::Bool(true))),
            },
            _ => unreachable!("schema-checked"),
        };
        let ts = ev.get("ts").and_then(Json::as_f64).expect("schema-checked");
        let dur = ev.get("dur").and_then(Json::as_f64).expect("schema-checked");
        // exporter-written traces carry the exact end; plain Chrome traces
        // reconstruct it from ts + dur
        let end = ev.get("end").and_then(Json::as_f64).unwrap_or(ts + dur);
        let event = TraceEvent { start_us: ts, end_us: end, kind };
        if event.rank() >= world {
            return Err(Error::Trace(format!(
                "event {i}: rank {} out of range for world {world}",
                event.rank()
            )));
        }
        events.push(event);
    }
    // restore the canonical lane grouping (rank-major, start-sorted)
    events.sort_by(|a, b| a.rank().cmp(&b.rank()).then(a.start_us.total_cmp(&b.start_us)));
    Ok(Trace { world, fingerprint, meta, events })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_trace() -> Trace {
        let mut t = Trace {
            world: 2,
            fingerprint: "deadbeefdeadbeef".into(),
            meta: vec![],
            events: vec![
                TraceEvent {
                    start_us: 0.5,
                    end_us: 3.25,
                    kind: TraceKind::Compute {
                        rank: 0,
                        op: 1,
                        calls: 2,
                        tiles: 2,
                        flops: 524288.0,
                        quantized: false,
                    },
                },
                TraceEvent {
                    start_us: 0.6,
                    end_us: 1.5,
                    kind: TraceKind::Kernel {
                        rank: 0,
                        op: 1,
                        call: 0,
                        artifact: "gemm_32x128x128".into(),
                    },
                },
                TraceEvent {
                    start_us: 1.0,
                    end_us: 2.0,
                    kind: TraceKind::Transfer {
                        src: 0,
                        dst: 1,
                        op: 2,
                        bytes: 16384,
                        pieces: 4,
                        backend: BackendKind::LdStSpecialized,
                        comm_sms: 16,
                        reduce: true,
                        signal: 3,
                    },
                },
                TraceEvent {
                    start_us: 0.0,
                    end_us: 2.1,
                    kind: TraceKind::Wait { rank: 1, op: 0, signal: 3 },
                },
            ],
        };
        t.set_meta("case", "unit \"quoted\"");
        t
    }

    #[test]
    fn export_passes_schema_and_counts_spans() {
        let t = sample_trace();
        let txt = to_chrome_json(&t);
        assert_eq!(check_chrome_schema(&txt).unwrap(), t.events.len());
        // viewers need these verbatim
        assert!(txt.contains("\"traceEvents\""), "{txt}");
        assert!(txt.contains("\"ph\": \"X\""));
        assert!(txt.contains("thread_name"));
        assert!(txt.contains("rank 0 comm"));
    }

    #[test]
    fn json_round_trip_is_exact() {
        let t = sample_trace();
        let back = from_chrome_json(&to_chrome_json(&t)).unwrap();
        assert_eq!(back.world, t.world);
        assert_eq!(back.fingerprint, t.fingerprint);
        assert_eq!(back.meta, t.meta);
        // events re-sorted into lane order, contents preserved exactly
        assert_eq!(back.events.len(), t.events.len());
        let mut want = t.events.clone();
        want.sort_by(|a, b| a.rank().cmp(&b.rank()).then(a.start_us.total_cmp(&b.start_us)));
        assert_eq!(back.events, want);
    }

    #[test]
    fn schema_rejects_malformed_traces() {
        // not JSON / missing header / missing args key / unknown category
        assert!(check_chrome_schema("not json").is_err());
        assert!(check_chrome_schema("{\"traceEvents\": []}").is_err());
        let no_world = "{\"syncopate\": {\"fingerprint\": \"f\"}, \"traceEvents\": []}";
        assert!(check_chrome_schema(no_world).unwrap_err().to_string().contains("world"));
        let bad_args = "{\"syncopate\": {\"world\": 2, \"fingerprint\": \"f\"}, \
            \"traceEvents\": [{\"ph\": \"X\", \"tid\": 0, \"name\": \"n\", \"cat\": \"wait\", \
            \"ts\": 0, \"dur\": 1, \"args\": {\"rank\": 0, \"op\": 0}}]}";
        let e = check_chrome_schema(bad_args).unwrap_err();
        assert!(e.to_string().contains("signal"), "{e}");
        let bad_cat = bad_args.replace("\"wait\"", "\"warp\"");
        assert!(check_chrome_schema(&bad_cat).unwrap_err().to_string().contains("warp"));
    }

    #[test]
    fn header_check_accepts_all_exports_and_overlay_marks_critical() {
        let t = sample_trace();
        let txt = to_chrome_json(&t);
        let (world, fp) = check_chrome_header(&txt).unwrap();
        assert_eq!(world, 2);
        assert_eq!(fp, "deadbeefdeadbeef");
        assert!(check_chrome_header("{\"traceEvents\": []}").is_err());

        // overlay: exactly the named keys get painted, schema still holds
        let crit = vec![t.events[2].key()]; // the transfer
        let overlaid = to_chrome_json_overlay(&t, &crit);
        assert_eq!(check_chrome_schema(&overlaid).unwrap(), t.events.len());
        assert_eq!(overlaid.matches("\"cname\": \"terrible\"").count(), 1);
        assert_eq!(overlaid.matches("\"critical\": true").count(), 1);
        // the overlay stays importable and equal to the plain trace
        let back = from_chrome_json(&overlaid).unwrap();
        assert_eq!(back.events.len(), t.events.len());
    }

    #[test]
    fn import_rejects_out_of_range_ranks_and_bad_backends() {
        let t = sample_trace();
        let txt = to_chrome_json(&t);
        let shrunk = txt.replace("\"world\": 2", "\"world\": 1");
        let e = from_chrome_json(&shrunk).unwrap_err();
        assert!(e.to_string().contains("out of range"), "{e}");
        let warped = txt.replace("ldst-specialized", "warp-drive");
        assert!(from_chrome_json(&warped).is_err());
    }

    #[test]
    fn empty_trace_round_trips() {
        let t = Trace { world: 1, fingerprint: "f".into(), meta: vec![], events: vec![] };
        let back = from_chrome_json(&to_chrome_json(&t)).unwrap();
        assert_eq!(back, t);
    }
}
