//! Overlap analysis of a captured [`Trace`]: the measured counterpart of
//! the simulator's `exposed_wait_us`.
//!
//! Definitions (all µs):
//!
//! * **wall makespan** — latest event end minus earliest event start; the
//!   run's wall-clock extent. Includes engine scheduling noise (thread
//!   spawn, lock handoffs), so it varies run to run.
//! * **busy makespan** — max over ranks of that rank's total *working*
//!   time (compute segments + transfers it sourced; waits are idle). This
//!   is the scheduling-noise-free critical-rank work, identical in
//!   expectation across engines — the quantity sim-vs-trace divergence is
//!   measured against.
//! * **comm-hidden fraction** — `1 - wait/comm`: how much of the measured
//!   communication time was NOT exposed as a wait anywhere. The paper's
//!   overlap claim, measured instead of predicted.
//! * **per-rank slack** — wall makespan minus the rank's last event end
//!   (relative to the trace start): how long the rank sat finished while
//!   stragglers ran. Feeds the NUMA-pinning roadmap item.

use crate::metrics::Table;
use crate::trace::{Trace, TraceKind};

/// Per-rank usage totals (µs).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct RankUsage {
    pub compute_us: f64,
    pub comm_us: f64,
    pub wait_us: f64,
    /// compute + comm (working, non-idle time).
    pub busy_us: f64,
    /// Wall time from trace start to this rank's last event end.
    pub end_us: f64,
}

/// The full overlap analysis of one trace.
#[derive(Debug, Clone, PartialEq)]
pub struct OverlapReport {
    pub world: usize,
    pub wall_makespan_us: f64,
    pub busy_makespan_us: f64,
    pub per_rank: Vec<RankUsage>,
    pub comm_total_us: f64,
    pub wait_total_us: f64,
    /// `1 - wait/comm`, clamped to `[0, 1]`; NaN when no communication
    /// was traced.
    pub hidden_frac: f64,
    pub events: usize,
}

/// Compact per-request summary for serving responses (`serve-demo`,
/// coordinator user-plan tracing).
#[derive(Debug, Clone, PartialEq)]
pub struct TraceStats {
    pub events: usize,
    pub comm_us: f64,
    pub wait_us: f64,
    pub busy_makespan_us: f64,
    pub hidden_frac: f64,
}

/// Analyze a trace (kernel spans nest inside their compute segment's span,
/// so only segment spans count toward compute time — counting both would
/// double-bill).
pub fn analyze(trace: &Trace) -> OverlapReport {
    let world = trace.world;
    let mut per_rank = vec![RankUsage::default(); world];
    let mut start = f64::INFINITY;
    let mut end = f64::NEG_INFINITY;
    // ranks whose compute ops carry no segment span (comm-only plans still
    // traced their kernel-free programs) fall back to kernel spans — for
    // ordinary plans kernels are nested and must not double-count
    let mut has_seg = vec![false; world];
    for ev in &trace.events {
        if let TraceKind::Compute { rank, .. } = ev.kind {
            has_seg[rank] = true;
        }
    }
    for ev in &trace.events {
        let r = ev.rank().min(world.saturating_sub(1));
        start = start.min(ev.start_us);
        end = end.max(ev.end_us);
        per_rank[r].end_us = per_rank[r].end_us.max(ev.end_us);
        match &ev.kind {
            TraceKind::Transfer { .. } => per_rank[r].comm_us += ev.dur_us(),
            TraceKind::Wait { .. } => per_rank[r].wait_us += ev.dur_us(),
            TraceKind::Compute { .. } => per_rank[r].compute_us += ev.dur_us(),
            TraceKind::Kernel { .. } => {
                if !has_seg[r] {
                    per_rank[r].compute_us += ev.dur_us();
                }
            }
        }
    }
    if trace.events.is_empty() {
        start = 0.0;
        end = 0.0;
    }
    for u in &mut per_rank {
        u.busy_us = u.compute_us + u.comm_us;
        u.end_us = (u.end_us - start).max(0.0);
    }
    let comm_total_us: f64 = per_rank.iter().map(|u| u.comm_us).sum();
    let wait_total_us: f64 = per_rank.iter().map(|u| u.wait_us).sum();
    let hidden_frac = if comm_total_us > 0.0 {
        (1.0 - wait_total_us / comm_total_us).clamp(0.0, 1.0)
    } else {
        f64::NAN
    };
    OverlapReport {
        world,
        wall_makespan_us: (end - start).max(0.0),
        busy_makespan_us: per_rank.iter().map(|u| u.busy_us).fold(0.0, f64::max),
        per_rank,
        comm_total_us,
        wait_total_us,
        hidden_frac,
        events: trace.events.len(),
    }
}

impl OverlapReport {
    /// Per-rank usage table (paper-style rendering via [`Table`]).
    pub fn table(&self) -> Table {
        let mut t = Table::new(
            "Overlap report: measured per-rank usage",
            &["compute us", "comm us", "wait us", "busy us", "slack us"],
            "us",
        );
        for (r, u) in self.per_rank.iter().enumerate() {
            t.push_row(
                &format!("rank {r}"),
                vec![
                    u.compute_us,
                    u.comm_us,
                    u.wait_us,
                    u.busy_us,
                    (self.wall_makespan_us - u.end_us).max(0.0),
                ],
            );
        }
        t
    }

    /// Sim-vs-trace divergence table: one row comparing a simulated
    /// makespan against this trace's busy makespan (the noise-free side —
    /// see the module doc for why not wall time).
    pub fn divergence_table(&self, label: &str, sim_makespan_us: f64) -> Table {
        let mut t = Table::new(
            "Sim vs trace divergence",
            &["sim us", "trace busy us", "trace wall us", "divergence"],
            "us (divergence: |sim-busy|/busy)",
        );
        t.push_row(
            label,
            vec![
                sim_makespan_us,
                self.busy_makespan_us,
                self.wall_makespan_us,
                self.divergence(sim_makespan_us),
            ],
        );
        t
    }

    /// Relative divergence `|sim - busy| / busy` of a simulated makespan
    /// from the measured busy makespan (NaN for an empty trace).
    pub fn divergence(&self, sim_makespan_us: f64) -> f64 {
        if self.busy_makespan_us <= 0.0 {
            return f64::NAN;
        }
        (sim_makespan_us - self.busy_makespan_us).abs() / self.busy_makespan_us
    }

    /// Feed the standing sim-vs-trace telemetry: sets the `sim.divergence`
    /// gauge to this trace's divergence from `sim_makespan_us` and bumps
    /// `sim.divergence_samples`. A NaN divergence (empty trace) records
    /// nothing — the gauge keeps its last meaningful value.
    pub fn record_divergence(&self, sim_makespan_us: f64) {
        let d = self.divergence(sim_makespan_us);
        if d.is_nan() {
            return;
        }
        crate::obs::gauge("sim.divergence").set(d);
        crate::obs::counter("sim.divergence_samples").inc();
    }

    /// Compare two traced runs of the same plan: per-rank busy deltas
    /// (B − A) plus summary rows for busy/wall makespan and the hidden
    /// fraction. Feeds `trace diff A.json B.json`; callers are expected to
    /// have checked the traces describe the same case first.
    pub fn diff_table(a: &OverlapReport, b: &OverlapReport) -> Table {
        let mut t = Table::new(
            "Trace diff (B - A)",
            &["A us", "B us", "delta us", "delta %"],
            "us",
        );
        let pct = |a: f64, b: f64| if a > 0.0 { (b - a) / a * 100.0 } else { f64::NAN };
        let ranks = a.per_rank.len().max(b.per_rank.len());
        for r in 0..ranks {
            let ab = a.per_rank.get(r).map(|u| u.busy_us).unwrap_or(0.0);
            let bb = b.per_rank.get(r).map(|u| u.busy_us).unwrap_or(0.0);
            t.push_row(&format!("rank {r} busy"), vec![ab, bb, bb - ab, pct(ab, bb)]);
        }
        t.push_row(
            "busy makespan",
            vec![
                a.busy_makespan_us,
                b.busy_makespan_us,
                b.busy_makespan_us - a.busy_makespan_us,
                pct(a.busy_makespan_us, b.busy_makespan_us),
            ],
        );
        t.push_row(
            "wall makespan",
            vec![
                a.wall_makespan_us,
                b.wall_makespan_us,
                b.wall_makespan_us - a.wall_makespan_us,
                pct(a.wall_makespan_us, b.wall_makespan_us),
            ],
        );
        t.push_row(
            "hidden frac",
            vec![
                a.hidden_frac,
                b.hidden_frac,
                b.hidden_frac - a.hidden_frac,
                f64::NAN,
            ],
        );
        t
    }

    /// One-line human summary (`exec --trace` / serve-demo output).
    pub fn summary_line(&self) -> String {
        let hidden = if self.hidden_frac.is_nan() {
            "-".to_string()
        } else {
            format!("{:.0}%", self.hidden_frac * 100.0)
        };
        format!(
            "{} events, wall {}, busy {}, comm {} ({hidden} hidden), waits {}",
            self.events,
            crate::util::fmt_us(self.wall_makespan_us),
            crate::util::fmt_us(self.busy_makespan_us),
            crate::util::fmt_us(self.comm_total_us),
            crate::util::fmt_us(self.wait_total_us),
        )
    }

    /// Compact serving summary.
    pub fn stats(&self) -> TraceStats {
        TraceStats {
            events: self.events,
            comm_us: self.comm_total_us,
            wait_us: self.wait_total_us,
            busy_makespan_us: self.busy_makespan_us,
            hidden_frac: self.hidden_frac,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::BackendKind;
    use crate::trace::TraceEvent;

    fn ev(start: f64, end: f64, kind: TraceKind) -> TraceEvent {
        TraceEvent { start_us: start, end_us: end, kind }
    }

    fn trace() -> Trace {
        Trace {
            world: 2,
            fingerprint: String::new(),
            meta: vec![],
            events: vec![
                // rank 0: a 10us segment with a nested 8us kernel call,
                // then a 4us transfer it sources
                ev(
                    0.0,
                    10.0,
                    TraceKind::Compute {
                        rank: 0,
                        op: 0,
                        calls: 1,
                        tiles: 1,
                        flops: 1e6,
                        quantized: false,
                    },
                ),
                ev(1.0, 9.0, TraceKind::Kernel { rank: 0, op: 0, call: 0, artifact: "g".into() }),
                ev(
                    10.0,
                    14.0,
                    TraceKind::Transfer {
                        src: 0,
                        dst: 1,
                        op: 1,
                        bytes: 1024,
                        pieces: 1,
                        backend: BackendKind::CopyEngine,
                        comm_sms: 0,
                        reduce: false,
                        signal: 0,
                    },
                ),
                // rank 1: waits 14us then nothing else
                ev(0.0, 14.0, TraceKind::Wait { rank: 1, op: 0, signal: 0 }),
            ],
        }
    }

    #[test]
    fn usage_totals_and_makespans() {
        let r = analyze(&trace());
        assert_eq!(r.world, 2);
        assert_eq!(r.events, 4);
        // kernel nested in a segment: compute counted once (10us, not 18)
        assert_eq!(r.per_rank[0].compute_us, 10.0);
        assert_eq!(r.per_rank[0].comm_us, 4.0);
        assert_eq!(r.per_rank[0].busy_us, 14.0);
        assert_eq!(r.per_rank[1].wait_us, 14.0);
        assert_eq!(r.per_rank[1].busy_us, 0.0);
        assert_eq!(r.wall_makespan_us, 14.0);
        assert_eq!(r.busy_makespan_us, 14.0);
        // 4us comm, 14us waits -> nothing hidden (clamped at 0)
        assert_eq!(r.hidden_frac, 0.0);
    }

    #[test]
    fn kernels_count_when_no_segment_span_exists() {
        let mut t = trace();
        t.events.retain(|e| !matches!(e.kind, TraceKind::Compute { .. }));
        let r = analyze(&t);
        assert_eq!(r.per_rank[0].compute_us, 8.0);
    }

    #[test]
    fn divergence_and_tables() {
        let r = analyze(&trace());
        assert!((r.divergence(7.0) - 0.5).abs() < 1e-12);
        assert_eq!(r.divergence(14.0), 0.0);
        let t = r.table();
        assert_eq!(t.rows.len(), 2);
        // rank 0 slack: wall 14 - end 14 = 0; rank 1 identical here
        assert_eq!(t.rows[0].1[4], 0.0);
        let d = r.divergence_table("case", 7.0);
        assert_eq!(d.rows[0].1[0], 7.0);
        assert!(d.render().contains("divergence"));
        assert!(r.summary_line().contains("4 events"), "{}", r.summary_line());
        let s = r.stats();
        assert_eq!(s.events, 4);
        assert_eq!(s.busy_makespan_us, 14.0);
    }

    #[test]
    fn record_divergence_sets_gauge_and_counter() {
        // the gauge/counter are process-global and other tests feed them
        // too: assert deltas only
        let samples = crate::obs::counter("sim.divergence_samples");
        let s0 = samples.get();
        let r = analyze(&trace());
        r.record_divergence(7.0);
        assert!(samples.get() >= s0 + 1);
        // NaN (empty trace) must take the early-return path, not panic
        let empty =
            analyze(&Trace { world: 2, fingerprint: String::new(), meta: vec![], events: vec![] });
        assert!(empty.divergence(1.0).is_nan());
        empty.record_divergence(1.0);
    }

    #[test]
    fn diff_table_reports_per_rank_and_summary_deltas() {
        let a = analyze(&trace());
        let mut t2 = trace();
        for ev in &mut t2.events {
            ev.end_us *= 2.0; // B is uniformly slower
            ev.start_us *= 2.0;
        }
        let b = analyze(&t2);
        let d = OverlapReport::diff_table(&a, &b);
        // 2 ranks + busy/wall makespan + hidden frac
        assert_eq!(d.rows.len(), 5);
        let busy = d.rows.iter().find(|(l, _)| l == "busy makespan").unwrap();
        assert_eq!(busy.1[0], 14.0);
        assert_eq!(busy.1[1], 28.0);
        assert_eq!(busy.1[2], 14.0);
        assert!((busy.1[3] - 100.0).abs() < 1e-9);
        assert!(d.render().contains("rank 0 busy"));
    }

    #[test]
    fn empty_trace_is_safe() {
        let t = Trace { world: 2, fingerprint: String::new(), meta: vec![], events: vec![] };
        let r = analyze(&t);
        assert_eq!(r.wall_makespan_us, 0.0);
        assert_eq!(r.busy_makespan_us, 0.0);
        assert!(r.hidden_frac.is_nan());
        assert!(r.divergence(1.0).is_nan());
    }

    #[test]
    fn full_overlap_hides_everything() {
        // comm with zero wait time -> hidden fraction 1
        let t = Trace {
            world: 2,
            fingerprint: String::new(),
            meta: vec![],
            events: vec![ev(
                0.0,
                5.0,
                TraceKind::Transfer {
                    src: 0,
                    dst: 1,
                    op: 0,
                    bytes: 64,
                    pieces: 1,
                    backend: BackendKind::CopyEngine,
                    comm_sms: 0,
                    reduce: false,
                    signal: 0,
                },
            )],
        };
        assert_eq!(analyze(&t).hidden_frac, 1.0);
    }
}
