//! Metrics accounting and report rendering.
//!
//! The report binaries print paper-style tables: one row per configuration,
//! one column per system, TFLOPS or latency. This module owns the shared
//! formatting, speedup math, and CSV/markdown export so every bench renders
//! identically.

use std::fmt::Write as _;

use crate::util::geomean;

/// Achieved TFLOP/s from total FLOPs and wall-clock microseconds.
pub fn tflops(flops: f64, us: f64) -> f64 {
    if us <= 0.0 {
        return 0.0;
    }
    flops / (us * 1e6)
}

/// Speedup of `ours` over `baseline` (latencies, lower is better).
pub fn speedup(baseline_us: f64, ours_us: f64) -> f64 {
    if ours_us <= 0.0 {
        return 0.0;
    }
    baseline_us / ours_us
}

/// One rendered comparison table (a paper figure's data).
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    /// Column headers (systems).
    pub columns: Vec<String>,
    /// (row label, value per column). NaN renders as "-" (unsupported combo,
    /// e.g. ThunderKittens on 4 GPUs in Fig. 8).
    pub rows: Vec<(String, Vec<f64>)>,
    /// Unit label for values.
    pub unit: &'static str,
}

impl Table {
    pub fn new(title: &str, columns: &[&str], unit: &'static str) -> Self {
        Table {
            title: title.into(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            unit,
        }
    }

    pub fn push_row(&mut self, label: &str, values: Vec<f64>) {
        assert_eq!(values.len(), self.columns.len(), "row width mismatch");
        self.rows.push((label.into(), values));
    }

    /// Column index by name.
    pub fn col(&self, name: &str) -> Option<usize> {
        self.columns.iter().position(|c| c == name)
    }

    /// Geomean ratio of column `a` over column `b` across rows where both
    /// are finite (the "average speedup" headline).
    pub fn geomean_ratio(&self, a: &str, b: &str) -> Option<f64> {
        let (ia, ib) = (self.col(a)?, self.col(b)?);
        let ratios: Vec<f64> = self
            .rows
            .iter()
            .filter_map(|(_, v)| {
                let (x, y) = (v[ia], v[ib]);
                (x.is_finite() && y.is_finite() && y > 0.0).then_some(x / y)
            })
            .collect();
        if ratios.is_empty() {
            None
        } else {
            Some(geomean(&ratios))
        }
    }

    /// Max ratio of column `a` over `b` (the "up to N×" headline).
    pub fn max_ratio(&self, a: &str, b: &str) -> Option<f64> {
        let (ia, ib) = (self.col(a)?, self.col(b)?);
        self.rows
            .iter()
            .filter_map(|(_, v)| {
                let (x, y) = (v[ia], v[ib]);
                (x.is_finite() && y.is_finite() && y > 0.0).then_some(x / y)
            })
            .fold(None, |m, r| Some(m.map_or(r, |mm: f64| mm.max(r))))
    }

    /// Pretty-print with aligned columns.
    pub fn render(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "## {} [{}]", self.title, self.unit);
        let label_w = self
            .rows
            .iter()
            .map(|(l, _)| l.len())
            .chain([8])
            .max()
            .unwrap();
        let col_w = self.columns.iter().map(|c| c.len().max(9)).collect::<Vec<_>>();
        let _ = write!(out, "{:label_w$}", "");
        for (c, w) in self.columns.iter().zip(&col_w) {
            let _ = write!(out, "  {c:>w$}");
        }
        let _ = writeln!(out);
        for (label, vals) in &self.rows {
            let _ = write!(out, "{label:label_w$}");
            for (v, w) in vals.iter().zip(&col_w) {
                if v.is_finite() {
                    let _ = write!(out, "  {v:>w$.2}");
                } else {
                    let _ = write!(out, "  {:>w$}", "-");
                }
            }
            let _ = writeln!(out);
        }
        out
    }

    /// CSV export (for plotting outside).
    pub fn to_csv(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "label,{}", self.columns.join(","));
        for (label, vals) in &self.rows {
            let cells: Vec<String> = vals
                .iter()
                .map(|v| if v.is_finite() { format!("{v:.4}") } else { String::new() })
                .collect();
            let _ = writeln!(out, "{label},{}", cells.join(","));
        }
        out
    }

    /// JSON export (the `BENCH_results.json` discipline: machine-readable
    /// next to the human table, hand-rolled — the offline build has no
    /// serde). NaN cells (unsupported combos) render as `null`.
    pub fn to_json(&self) -> String {
        let esc = crate::util::json_escape;
        let mut out = String::new();
        let _ = writeln!(out, "{{");
        let _ = writeln!(out, "  \"title\": \"{}\",", esc(&self.title));
        let _ = writeln!(out, "  \"unit\": \"{}\",", esc(self.unit));
        let cols: Vec<String> = self.columns.iter().map(|c| format!("\"{}\"", esc(c))).collect();
        let _ = writeln!(out, "  \"columns\": [{}],", cols.join(", "));
        let _ = writeln!(out, "  \"rows\": [");
        for (i, (label, vals)) in self.rows.iter().enumerate() {
            let cells: Vec<String> = vals
                .iter()
                .map(|v| if v.is_finite() { format!("{v}") } else { "null".to_string() })
                .collect();
            let _ = writeln!(
                out,
                "    {{\"label\": \"{}\", \"values\": [{}]}}{}",
                esc(label),
                cells.join(", "),
                if i + 1 < self.rows.len() { "," } else { "" }
            );
        }
        let _ = writeln!(out, "  ]");
        let _ = writeln!(out, "}}");
        out
    }

    /// Markdown export (for EXPERIMENTS.md).
    pub fn to_markdown(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "| {} | {} |", "config", self.columns.join(" | "));
        let _ = writeln!(out, "|{}|", vec!["---"; self.columns.len() + 1].join("|"));
        for (label, vals) in &self.rows {
            let cells: Vec<String> = vals
                .iter()
                .map(|v| if v.is_finite() { format!("{v:.2}") } else { "-".into() })
                .collect();
            let _ = writeln!(out, "| {label} | {} |", cells.join(" | "));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn table() -> Table {
        let mut t = Table::new("fig", &["ours", "base"], "TFLOPS");
        t.push_row("a", vec![4.0, 2.0]);
        t.push_row("b", vec![9.0, 3.0]);
        t.push_row("c", vec![5.0, f64::NAN]);
        t
    }

    #[test]
    fn tflops_and_speedup() {
        assert!((tflops(1e12, 1e6) - 1.0).abs() < 1e-12);
        assert_eq!(tflops(1.0, 0.0), 0.0);
        assert_eq!(speedup(10.0, 5.0), 2.0);
        assert_eq!(speedup(10.0, 0.0), 0.0);
    }

    #[test]
    fn ratios_skip_nan_rows() {
        let t = table();
        // geomean(2, 3) = sqrt(6)
        assert!((t.geomean_ratio("ours", "base").unwrap() - 6.0f64.sqrt()).abs() < 1e-12);
        assert_eq!(t.max_ratio("ours", "base").unwrap(), 3.0);
        assert!(t.geomean_ratio("nope", "base").is_none());
    }

    #[test]
    fn render_marks_missing() {
        let r = table().render();
        assert!(r.contains("fig"));
        assert!(r.contains('-'), "{r}");
        assert!(r.contains("4.00"));
    }

    #[test]
    fn csv_and_markdown() {
        let c = table().to_csv();
        assert!(c.starts_with("label,ours,base"));
        assert!(c.contains("c,5.0000,\n"), "{c}");
        let m = table().to_markdown();
        assert!(m.contains("| a | 4.00 | 2.00 |"));
        assert!(m.contains("| c | 5.00 | - |"));
    }

    #[test]
    fn json_export_marks_missing_as_null() {
        let j = table().to_json();
        assert!(j.contains("\"title\": \"fig\""), "{j}");
        assert!(j.contains("\"columns\": [\"ours\", \"base\"]"), "{j}");
        assert!(j.contains("{\"label\": \"a\", \"values\": [4, 2]}"), "{j}");
        assert!(j.contains("{\"label\": \"c\", \"values\": [5, null]}"), "{j}");
        // quotes in labels stay valid JSON
        let mut t = Table::new("q\"t", &["c"], "u");
        t.push_row("r\"l", vec![1.0]);
        assert!(t.to_json().contains("q\\\"t"));
        assert!(t.to_json().contains("r\\\"l"));
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn row_width_checked() {
        let mut t = Table::new("x", &["a"], "u");
        t.push_row("r", vec![1.0, 2.0]);
    }

    #[test]
    fn json_export_survives_control_chars_and_non_finite() {
        // stats snapshots pipe Table JSON into files and jq: control
        // characters in titles/labels and non-finite cells must never
        // produce invalid JSON. Validate with the crate's own parser.
        let mut t = Table::new("line1\nline2\ttabbed \"q\"", &["c\\col", "d"], "us");
        t.push_row("row\r\"quoted\"", vec![f64::INFINITY, 1.5]);
        t.push_row("neg", vec![f64::NEG_INFINITY, f64::NAN]);
        let parsed =
            crate::trace::json::parse(&t.to_json()).expect("Table::to_json must emit valid JSON");
        assert_eq!(
            parsed.get("title").and_then(|v| v.as_str()),
            Some("line1\nline2\ttabbed \"q\"")
        );
        let rows = parsed.get("rows").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(
            rows[0].get("label").and_then(|v| v.as_str()),
            Some("row\r\"quoted\"")
        );
        // every non-finite cell (Inf, -Inf, NaN) lands as null
        let vals = |i: usize| rows[i].get("values").unwrap().as_arr().unwrap();
        assert!(matches!(vals(0)[0], crate::trace::json::Json::Null));
        assert_eq!(vals(0)[1].as_f64(), Some(1.5));
        assert!(matches!(vals(1)[0], crate::trace::json::Json::Null));
        assert!(matches!(vals(1)[1], crate::trace::json::Json::Null));
    }
}
