//! Schedule composition: concatenate stage schedules, rewrite tensor
//! namespaces, and replace the inter-stage barrier with fine-grained
//! dependency edges (see the module docs of [`crate::pipeline`]).

use std::collections::HashMap;

use crate::chunk::{Chunk, TensorId};
use crate::error::{Error, Result};
use crate::plan_io::dsl::is_valid_tensor_name;
use crate::schedule::validate as sched_validate;
use crate::schedule::{CommOp, CommSchedule, Dep, OpRef};
use crate::topo::Rank;

/// One pipeline stage: a named operator with its communication schedule.
///
/// The name namespaces tensors on declaration conflicts, so it must itself
/// be a valid tensor-name fragment (`[A-Za-z_][A-Za-z0-9_]*`).
#[derive(Debug, Clone)]
pub struct Stage {
    pub name: String,
    pub sched: CommSchedule,
}

impl Stage {
    pub fn new(name: &str, sched: CommSchedule) -> Self {
        Stage { name: name.to_string(), sched }
    }
}

/// A fused multi-stage pipeline schedule plus provenance metadata.
#[derive(Debug, Clone)]
pub struct FusedPipeline {
    /// The fused schedule over the merged tensor table — a plain
    /// [`CommSchedule`]: it validates, splits, prints, parses, compiles and
    /// executes exactly like a single-operator schedule.
    pub sched: CommSchedule,
    /// Per stage, per rank: the `[start, end)` index range the stage's ops
    /// occupy in the fused per-rank lists.
    pub op_ranges: Vec<Vec<(usize, usize)>>,
    /// Per stage: original [`TensorId`] → fused [`TensorId`].
    pub tensor_maps: Vec<HashMap<TensorId, TensorId>>,
    /// Cross-stage dependency edges added in place of the boundary barrier:
    /// `(later-stage op, earlier-stage op it now depends on)`, both in
    /// fused coordinates.
    pub cross_deps: Vec<(OpRef, OpRef)>,
}

impl FusedPipeline {
    /// Which stage a fused op belongs to.
    pub fn stage_of(&self, op: OpRef) -> Option<usize> {
        self.op_ranges
            .iter()
            .position(|ranges| {
                ranges
                    .get(op.rank)
                    .map(|&(s, e)| op.index >= s && op.index < e)
                    .unwrap_or(false)
            })
    }
}

fn op_deps_mut(op: &mut CommOp) -> &mut Vec<Dep> {
    match op {
        CommOp::P2p { deps, .. }
        | CommOp::Collective { deps, .. }
        | CommOp::LocalCopy { deps, .. } => deps,
    }
}

fn remap_chunk(c: &mut Chunk, map: &HashMap<TensorId, TensorId>) -> Result<()> {
    let new = map
        .get(&c.tensor)
        .ok_or_else(|| Error::Schedule(format!("fuse: unmapped tensor id {:?}", c.tensor)))?;
    c.tensor = *new;
    Ok(())
}

fn remap_op(op: &mut CommOp, map: &HashMap<TensorId, TensorId>) -> Result<()> {
    match op {
        CommOp::P2p { src, dst, .. }
        | CommOp::Collective { src, dst, .. }
        | CommOp::LocalCopy { src, dst, .. } => {
            remap_chunk(src, map)?;
            remap_chunk(dst, map)
        }
    }
}

/// Buffer access of one op: which rank's buffer, which tensor, which region.
/// Only exact for P2P/LocalCopy ops — abstract collectives (which touch
/// every group rank) are rejected by [`fuse`] before this runs.
fn read_access(op: &CommOp, owner: Rank) -> (Rank, &Chunk) {
    (op.src_rank(owner), op.consumed_chunk())
}

fn write_access(op: &CommOp, owner: Rank) -> (Rank, &Chunk) {
    (op.dst_rank(owner), op.produced_chunk())
}

fn accesses_conflict(a: (Rank, &Chunk), b: (Rank, &Chunk)) -> bool {
    a.0 == b.0 && a.1.tensor == b.1.tensor && a.1.region.intersects(&b.1.region)
}

/// Fuse consecutive operator stages into one barrier-free schedule.
///
/// See the module docs for the three composition steps. Errors when the
/// stages disagree on world size, a stage name cannot namespace tensors,
/// conflicting tensor declarations cannot be disambiguated, or the fused
/// schedule fails structural validation.
pub fn fuse(stages: &[Stage]) -> Result<FusedPipeline> {
    let Some(first) = stages.first() else {
        return Err(Error::Schedule("fuse: pipeline has no stages".into()));
    };
    let world = first.sched.world;
    for st in stages {
        if st.sched.world != world {
            return Err(Error::Schedule(format!(
                "fuse: stage `{}` has world {}, expected {world}",
                st.name, st.sched.world
            )));
        }
        if st.sched.per_rank.len() != world {
            return Err(Error::Schedule(format!(
                "fuse: stage `{}` has {} per-rank lists for world {world}",
                st.name,
                st.sched.per_rank.len()
            )));
        }
        if !is_valid_tensor_name(&st.name) {
            return Err(Error::Schedule(format!(
                "fuse: stage name `{}` cannot namespace tensors \
                 (need [A-Za-z_][A-Za-z0-9_]*)",
                st.name
            )));
        }
        // An abstract collective reads/writes buffers on EVERY group rank,
        // but per-op access attribution below sees only its owning rank —
        // cross-stage hazards on the other ranks would be silently missed
        // (and validate's race check is write-write only). Until
        // lowering-aware attribution exists, fusion requires P2P form.
        if st.sched.per_rank.iter().flatten().any(|op| matches!(op, CommOp::Collective { .. }))
        {
            return Err(Error::Schedule(format!(
                "fuse: stage `{}` contains abstract collective ops; lower them \
                 to P2P (lowering::collective) before fusing",
                st.name
            )));
        }
    }

    // 1. Merge tensor tables: identical declarations unify (cross-stage
    //    dataflow), conflicting names are stage-prefixed. Unification is
    //    keyed on the ORIGINAL (name, shape, dtype) — two later stages
    //    re-declaring the same tensor unify with each other even when both
    //    had to be renamed away from an earlier stage's conflicting name
    //    (otherwise their cross-stage dep edges would silently vanish).
    let mut sched = CommSchedule::new(world, crate::chunk::TensorTable::new());
    let mut tensor_maps: Vec<HashMap<TensorId, TensorId>> = Vec::with_capacity(stages.len());
    let mut by_decl: HashMap<(String, Vec<usize>, crate::chunk::DType), TensorId> =
        HashMap::new();
    for st in stages {
        let mut map = HashMap::new();
        for (old_id, decl) in st.sched.tensors.iter() {
            let key = (decl.name.clone(), decl.shape.clone(), decl.dtype);
            let new_id = match by_decl.get(&key) {
                Some(&unified) => unified,
                None => {
                    let id = if sched.tensors.lookup(&decl.name).is_none() {
                        sched.tensors.declare(&decl.name, &decl.shape, decl.dtype)?
                    } else {
                        let renamed = format!("{}__{}", st.name, decl.name);
                        if sched.tensors.lookup(&renamed).is_some() {
                            return Err(Error::Schedule(format!(
                                "fuse: cannot disambiguate tensor `{}` of stage `{}` \
                                 (both `{}` and `{renamed}` are taken)",
                                decl.name, st.name, decl.name
                            )));
                        }
                        sched.tensors.declare(&renamed, &decl.shape, decl.dtype)?
                    };
                    by_decl.insert(key, id);
                    id
                }
            };
            map.insert(old_id, new_id);
        }
        tensor_maps.push(map);
    }

    // 2. Concatenate per-rank op lists in stage order, remapping tensor ids
    //    and shifting intra-stage dep indices past the ops already emitted.
    let mut op_ranges: Vec<Vec<(usize, usize)>> = Vec::with_capacity(stages.len());
    for (si, st) in stages.iter().enumerate() {
        let offsets: Vec<usize> = (0..world).map(|r| sched.per_rank[r].len()).collect();
        for (rank, ops) in st.sched.per_rank.iter().enumerate() {
            for op in ops {
                let mut op = op.clone();
                remap_op(&mut op, &tensor_maps[si])?;
                for d in op_deps_mut(&mut op).iter_mut() {
                    if d.rank >= world {
                        return Err(Error::Schedule(format!(
                            "fuse: stage `{}` dep rank {} out of world {world}",
                            st.name, d.rank
                        )));
                    }
                    d.index += offsets[d.rank];
                }
                sched.per_rank[rank].push(op);
            }
        }
        op_ranges
            .push((0..world).map(|r| (offsets[r], sched.per_rank[r].len())).collect());
    }

    // 3. Replace the boundary barrier with fine-grained dep edges: a
    //    later-stage op waits on exactly the earlier-stage ops whose buffer
    //    accesses conflict with its own (RAW/WAW/WAR on intersecting
    //    regions of the same fused tensor at the same rank). Everything
    //    else stays unordered and overlaps freely.
    let mut cross_deps: Vec<(OpRef, OpRef)> = Vec::new();
    for bi in 1..stages.len() {
        for rank in 0..world {
            let (bstart, bend) = op_ranges[bi][rank];
            for bidx in bstart..bend {
                let mut extra: Vec<Dep> = Vec::new();
                {
                    let b = &sched.per_rank[rank][bidx];
                    let b_read = read_access(b, rank);
                    let b_write = write_access(b, rank);
                    for ranges in op_ranges.iter().take(bi) {
                        for (arank, &(astart, aend)) in ranges.iter().enumerate() {
                            for aidx in astart..aend {
                                let a = &sched.per_rank[arank][aidx];
                                let a_read = read_access(a, arank);
                                let a_write = write_access(a, arank);
                                let conflict = accesses_conflict(b_read, a_write)
                                    || accesses_conflict(b_write, a_write)
                                    || accesses_conflict(b_write, a_read);
                                if conflict {
                                    extra.push(Dep { rank: arank, index: aidx });
                                }
                            }
                        }
                    }
                }
                if !extra.is_empty() {
                    let me = OpRef { rank, index: bidx };
                    let deps = op_deps_mut(&mut sched.per_rank[rank][bidx]);
                    for d in extra {
                        if !deps.contains(&d) {
                            deps.push(d);
                            cross_deps.push((me, OpRef { rank: d.rank, index: d.index }));
                        }
                    }
                }
            }
        }
    }

    // 4. Reduce the derived edges: step 3 adds one dep per conflicting
    //    earlier-stage op, and many of those are already implied by other
    //    deps or by apply-order program edges (e.g. a chain of stages
    //    touching the same region derives a full clique). Drop every
    //    *derived* edge the rest of the graph implies — stage-internal deps
    //    are the stages' own and are left untouched. Removal against the
    //    original closure is sound (DESIGN.md §17.3): each dropped edge
    //    keeps an alternative happens-before path, so the fused plan stays
    //    provably race-free with the minimal boundary ordering.
    // Iterated to a fixpoint: a removal can leave an op dep-free, adding
    // apply-order program edges that expose further redundancy.
    loop {
        let removable = crate::analysis::redundant_dep_edges(&sched)?;
        let mut progressed = false;
        for (op, dep) in &removable {
            let target = OpRef { rank: dep.rank, index: dep.index };
            let Some(pos) = cross_deps.iter().position(|e| *e == (*op, target)) else {
                continue; // stage-internal dep: not ours to remove
            };
            cross_deps.remove(pos);
            let deps = op_deps_mut(&mut sched.per_rank[op.rank][op.index]);
            if let Some(slot) = deps.iter().position(|d| d == dep) {
                deps.remove(slot);
            }
            progressed = true;
        }
        if !progressed {
            break;
        }
    }

    // Every fused pipeline must be executable and deadlock-free.
    sched_validate::validate(&sched)?;
    Ok(FusedPipeline { sched, op_ranges, tensor_maps, cross_deps })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chunk::{DType, Region, TensorTable};
    use crate::schedule::validate::topo_order;
    use crate::schedule::{templates, TransferKind};

    fn ag_stage(name: &str, tensor: &str, world: usize) -> Stage {
        let mut t = TensorTable::new();
        let x = t.declare(tensor, &[world * 4, 16], DType::F32).unwrap();
        Stage::new(name, templates::all_gather_swizzle(&t, x, 0, world).unwrap())
    }

    #[test]
    fn disjoint_stages_concatenate_without_cross_deps() {
        let fp = fuse(&[ag_stage("ag1", "x", 4), ag_stage("ag2", "y", 4)]).unwrap();
        assert_eq!(fp.sched.world, 4);
        assert_eq!(fp.sched.tensors.len(), 2);
        // each stage: (w-1) pulls per rank
        assert_eq!(fp.sched.num_ops(), 2 * 4 * 3);
        assert!(fp.cross_deps.is_empty(), "{:?}", fp.cross_deps);
        for rank in 0..4 {
            assert_eq!(fp.op_ranges[0][rank], (0, 3));
            assert_eq!(fp.op_ranges[1][rank], (3, 6));
        }
        assert_eq!(fp.stage_of(OpRef { rank: 2, index: 1 }), Some(0));
        assert_eq!(fp.stage_of(OpRef { rank: 2, index: 4 }), Some(1));
        assert_eq!(fp.stage_of(OpRef { rank: 2, index: 9 }), None);
    }

    #[test]
    fn identical_declarations_unify_into_one_tensor() {
        // stage 2 re-declares `x` with the same shape/dtype: the fused
        // table must hold ONE `x`, and both stages' ops must reference it.
        let fp = fuse(&[ag_stage("a", "x", 2), {
            let mut t = TensorTable::new();
            let x = t.declare("x", &[8, 16], DType::F32).unwrap();
            let mut s = CommSchedule::new(2, t);
            // forward the gathered half onward: reads what stage 1 wrote
            let c = Chunk::new(x, Region::rows(4, 4, 16));
            s.add_op(
                0,
                CommOp::P2p {
                    kind: TransferKind::Push,
                    peer: 1,
                    src: c.clone(),
                    dst: c,
                    reduce: false,
                    deps: vec![],
                },
            )
            .unwrap();
            Stage::new("b", s)
        }])
        .unwrap();
        assert_eq!(fp.sched.tensors.len(), 1);
        let x = fp.sched.tensors.lookup("x").unwrap();
        assert_eq!(fp.tensor_maps[0].values().copied().collect::<Vec<_>>(), vec![x]);
        assert_eq!(fp.tensor_maps[1].values().copied().collect::<Vec<_>>(), vec![x]);
    }

    #[test]
    fn conflicting_declarations_are_stage_prefixed() {
        let mk = |name: &str, rows: usize| {
            let mut t = TensorTable::new();
            let x = t.declare("x", &[rows, 16], DType::F32).unwrap();
            let mut s = CommSchedule::new(2, t);
            let c = Chunk::new(x, Region::rows(0, 2, 16));
            s.add_op(
                0,
                CommOp::P2p {
                    kind: TransferKind::Push,
                    peer: 1,
                    src: c.clone(),
                    dst: c,
                    reduce: false,
                    deps: vec![],
                },
            )
            .unwrap();
            Stage::new(name, s)
        };
        let fp = fuse(&[mk("up", 8), mk("down", 4)]).unwrap();
        assert_eq!(fp.sched.tensors.len(), 2);
        assert!(fp.sched.tensors.lookup("x").is_some());
        assert!(fp.sched.tensors.lookup("down__x").is_some());
    }

    #[test]
    fn cross_stage_raw_gets_dep_edges_instead_of_barrier() {
        // stage 1: direct AG of x — every rank ends holding all of x.
        // stage 2: rank 0 pushes the region rank 1 delivered (a RAW hazard
        // across the boundary): it must now depend on exactly the stage-1
        // ops that write rows 4..8 of x on rank 0, and on nothing else.
        let world = 2;
        let mut t = TensorTable::new();
        let x = t.declare("x", &[8, 16], DType::F32).unwrap();
        let s1 = templates::all_gather_direct(&t, x, 0, world).unwrap();

        let mut t2 = TensorTable::new();
        let x2 = t2.declare("x", &[8, 16], DType::F32).unwrap();
        let mut s2 = CommSchedule::new(world, t2);
        let c = Chunk::new(x2, Region::rows(4, 4, 16));
        s2.add_op(
            0,
            CommOp::P2p {
                kind: TransferKind::Push,
                peer: 1,
                src: c.clone(),
                dst: c,
                reduce: false,
                deps: vec![],
            },
        )
        .unwrap();

        let fp = fuse(&[Stage::new("gather", s1), Stage::new("forward", s2)]).unwrap();
        // rank 1's stage-1 push wrote x[4:8] into rank 0 (RAW with the
        // stage-2 read) and also reads x[4:8] on rank 1 where the stage-2
        // push writes (WAR): one deduplicated edge onto exactly that op.
        let consumer = OpRef { rank: 0, index: 1 };
        assert!(
            fp.cross_deps.contains(&(consumer, OpRef { rank: 1, index: 0 })),
            "{:?}",
            fp.cross_deps
        );
        let deps = fp.sched.per_rank[0][1].deps();
        assert!(deps.contains(&Dep::on(1, 0)), "{deps:?}");
        // the fused schedule stays acyclic and totally orderable
        let order = topo_order(&fp.sched).unwrap();
        assert_eq!(order.len(), fp.sched.num_ops());
    }

    #[test]
    fn fused_tp_block_shape_validates_and_splits(){
        // AG(x) then RS(y): the canonical tensor-parallel block at schedule
        // level. No region conflicts -> no cross deps; the fused plan still
        // validates, and the split knob composes with it.
        let world = 4;
        let mut t1 = TensorTable::new();
        let x = t1.declare("x", &[world * 4, 16], DType::F32).unwrap();
        let mut t2 = TensorTable::new();
        let y = t2.declare("y", &[world * 4, 16], DType::F32).unwrap();
        let fp = fuse(&[
            Stage::new("ag", templates::all_gather_swizzle(&t1, x, 0, world).unwrap()),
            Stage::new("rs", templates::reduce_scatter_direct(&t2, y, 0, world).unwrap()),
        ])
        .unwrap();
        assert!(fp.cross_deps.is_empty());
        assert_eq!(fp.sched.num_ops(), 2 * world * (world - 1));
        let split = fp.sched.split_p2p(0, 2).unwrap();
        crate::schedule::validate::validate(&split).unwrap();
        assert_eq!(split.num_ops(), 2 * fp.sched.num_ops());
    }

    #[test]
    fn renamed_tensors_still_unify_across_later_stages() {
        // regression: stages B and C both declare x[16,16] (conflicting
        // with stage A's x[8,16]); the identical declarations must unify
        // into ONE renamed fused tensor so the C-reads-what-B-wrote dep
        // edge is still derived — not split into b__x / c__x with the
        // boundary ordering silently dropped.
        let mk = |name: &str, rows: usize, src_row: usize| {
            let mut t = TensorTable::new();
            let x = t.declare("x", &[rows, 16], DType::F32).unwrap();
            let mut s = CommSchedule::new(2, t);
            let c = Chunk::new(x, Region::rows(src_row, 2, 16));
            s.add_op(
                0,
                CommOp::P2p {
                    kind: TransferKind::Push,
                    peer: 1,
                    src: c.clone(),
                    dst: c,
                    reduce: false,
                    deps: vec![],
                },
            )
            .unwrap();
            Stage::new(name, s)
        };
        // B pushes x[0:2] into rank 1; C pushes the SAME region onward —
        // a cross-stage WAW/RAW that only exists if b/c share one tensor
        let fp = fuse(&[mk("a", 8, 0), mk("b", 16, 0), mk("c", 16, 0)]).unwrap();
        assert_eq!(fp.sched.tensors.len(), 2, "a's x + ONE unified renamed x");
        assert!(fp.sched.tensors.lookup("b__x").is_some());
        assert!(fp.sched.tensors.lookup("c__x").is_none());
        let b_id = fp.tensor_maps[1][&crate::chunk::TensorId(0)];
        let c_id = fp.tensor_maps[2][&crate::chunk::TensorId(0)];
        assert_eq!(b_id, c_id, "identical later-stage declarations must unify");
        // The boundary ordering exists but the explicit edge does not: B's
        // op is dep-free, so apply-order program order already runs it
        // before C's — the derived dep is redundant and step 4 drops it.
        assert!(
            !fp.cross_deps.contains(&(
                OpRef { rank: 0, index: 2 },
                OpRef { rank: 0, index: 1 }
            )),
            "redundant derived edge must be reduced away: {:?}",
            fp.cross_deps
        );
        let g = crate::analysis::hb::OpGraph::apply_order(&fp.sched);
        let order = g.topo().unwrap();
        let reach = crate::analysis::hb::Reach::build(&g, &order);
        assert!(
            reach.reaches(
                g.id(OpRef { rank: 0, index: 1 }),
                g.id(OpRef { rank: 0, index: 2 })
            ),
            "ordering must survive the reduction via program order"
        );
    }

    #[test]
    fn fused_output_is_race_free_and_reduced() {
        // Chain three stages over one tensor: step 3 derives a dep clique
        // at each boundary; step 4 must thin it to the transitive reduction
        // while the analyzer still certifies the result race-free.
        let mk = |name: &str| {
            let mut t = TensorTable::new();
            let x = t.declare("x", &[8, 16], DType::F32).unwrap();
            let mut s = CommSchedule::new(2, t);
            let c = Chunk::new(x, Region::rows(0, 4, 16));
            s.add_op(
                0,
                CommOp::P2p {
                    kind: TransferKind::Push,
                    peer: 1,
                    src: c.clone(),
                    dst: c,
                    reduce: false,
                    deps: vec![],
                },
            )
            .unwrap();
            Stage::new(name, s)
        };
        let fp = fuse(&[mk("s1"), mk("s2"), mk("s3")]).unwrap();
        let rep = crate::analysis::run(&fp.sched).unwrap();
        assert!(!rep.has_errors(), "{:#?}", rep.findings);
        // no derived edge left over that the rest of the graph implies
        let leftover: Vec<_> = crate::analysis::redundant_dep_edges(&fp.sched)
            .unwrap()
            .into_iter()
            .filter(|(op, d)| {
                fp.cross_deps.contains(&(*op, OpRef { rank: d.rank, index: d.index }))
            })
            .collect();
        assert!(leftover.is_empty(), "{leftover:?}");
    }

    #[test]
    fn world_mismatch_and_empty_pipeline_rejected() {
        assert!(fuse(&[]).is_err());
        let e = fuse(&[ag_stage("a", "x", 2), ag_stage("b", "y", 4)]).unwrap_err();
        assert!(e.to_string().contains("world"), "{e}");
        let e = fuse(&[Stage::new("bad name", ag_stage("a", "x", 2).sched)]).unwrap_err();
        assert!(e.to_string().contains("stage name"), "{e}");
    }

    #[test]
    fn abstract_collectives_are_rejected() {
        // per-op access attribution cannot see a collective's non-owner
        // ranks; fusing one could silently drop cross-stage hazards
        let mut t = TensorTable::new();
        let x = t.declare("x", &[8, 16], DType::F32).unwrap();
        let mut s = CommSchedule::new(2, t);
        let c = Chunk::new(x, Region::rows(0, 4, 16));
        s.add_op(
            0,
            CommOp::Collective {
                kind: crate::schedule::CollectiveKind::AllGather,
                src: c.clone(),
                dst: c,
                ranks: vec![0, 1],
                deps: vec![],
            },
        )
        .unwrap();
        let e = fuse(&[Stage::new("coll", s)]).unwrap_err();
        assert!(e.to_string().contains("collective"), "{e}");
    }

    #[test]
    fn fused_schedules_are_validated_on_construction() {
        // a stage whose dep references a missing op must be rejected by the
        // final validate pass, not silently emitted
        let mut t = TensorTable::new();
        let x = t.declare("x", &[8, 16], DType::F32).unwrap();
        let mut s = CommSchedule::new(2, t);
        let c = Chunk::new(x, Region::rows(0, 4, 16));
        s.add_op(
            0,
            CommOp::P2p {
                kind: TransferKind::Push,
                peer: 1,
                src: c.clone(),
                dst: c,
                reduce: false,
                deps: vec![Dep::on(1, 5)],
            },
        )
        .unwrap();
        assert!(fuse(&[Stage::new("only", s)]).is_err());
    }
}
