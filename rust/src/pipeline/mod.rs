//! Cross-operator pipeline fusion (paper §2/§3: eliminating the
//! device-wide synchronization at kernel boundaries).
//!
//! Every single-operator schedule in this repo already overlaps its own
//! communication with its own compute — but a *sequence* of operators run
//! as separate plans still pays a full barrier at each seam: operator N+1
//! starts only after operator N's slowest rank has finished both its
//! compute and its last transfer. That boundary sync is exactly what the
//! paper's stream-level-overlap critique targets, and what this subsystem
//! removes.
//!
//! [`fuse`] composes the [`crate::schedule::CommSchedule`]s of consecutive
//! pipeline stages into ONE schedule:
//!
//! 1. **Namespace rewrite** — stage tensor tables are merged. Declarations
//!    that agree on (name, shape, dtype) unify into one fused tensor (the
//!    cross-stage dataflow: stage N's output *is* stage N+1's input);
//!    conflicting declarations are renamed `"{stage}__{tensor}"` so both
//!    survive.
//! 2. **Op concatenation** — per-rank op lists are appended in stage order
//!    with intra-stage dep indices shifted, like
//!    [`crate::schedule::CommSchedule::append`] but across tables.
//! 3. **Cross-stage dependency derivation** — instead of a barrier, each
//!    later-stage op gains explicit `(rank, index)` deps on exactly the
//!    earlier-stage ops whose buffer accesses conflict with its own
//!    (RAW / WAW / WAR on an intersecting region of the same tensor at the
//!    same rank), reusing the region math of [`crate::chunk::Region`] /
//!    `schedule::validate`. Non-conflicting ops stay unordered and free to
//!    overlap.
//!
//! The fused schedule is validated ([`crate::schedule::validate::validate`])
//! before it is returned, so every fused pipeline is executable and
//! deadlock-free by construction. Compute-side fine-grained sync (stage
//! N+1 tiles starting the moment their chunks land) comes from compiling
//! the fused schedule with a *combined* tile grid through the ordinary
//! [`crate::depgraph::plan_rank_sync`] path — see
//! `coordinator::execases::tp_block` / `moe_a2a` for the wired-up cases
//! and `reports::pipeline` for the fused-vs-barrier makespan comparison.
//!
//! The **barrier-at-boundary baseline** this is measured against is the
//! sum of the per-stage plan makespans: each stage keeps its internal
//! overlap, but a device-wide sync separates consecutive stages (DESIGN.md
//! §12). Fused pipelines are plain [`crate::schedule::CommSchedule`]s, so
//! they print/parse through `plan_io` (`plan import --from tp-block`) and
//! serve through the coordinator's content-hash plan cache unchanged.

mod fuse;

pub use fuse::{fuse, FusedPipeline, Stage};
