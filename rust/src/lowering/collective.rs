//! Collective lowering paths (paper Listing 3: "direct" | "template" |
//! "synth").
//!
//! * **direct** — keep the collective's library algorithm: a plain ring with
//!   one full-shard chunk per step (what NCCL would do), no swizzling. The
//!   realization layer typically pairs this with `BackendKind::NcclBulk`.
//! * **template** — instantiate the corresponding Syncopate template
//!   (swizzled AllGather, direct ReduceScatter, partition AllReduce, ...),
//!   which is chunk-splittable and dependency-pipelined.
//! * **synth** — synthesize a schedule from the topology with a TACOS-like
//!   greedy flood: at each synthesis round, every rank forwards a shard it
//!   holds to a peer that lacks it, preferring under-used links; rounds
//!   become dependency stages.

use std::collections::HashSet;

use crate::chunk::{Chunk, TensorId, TensorTable};
use crate::error::{Error, Result};
use crate::schedule::templates::{self, shard_region};
use crate::schedule::{CollectiveKind, CommOp, CommSchedule, Dep, TransferKind};
use crate::topo::Topology;

/// Which lowering path realizes abstract collectives.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LowerPath {
    Direct,
    Template,
    Synth,
}

impl LowerPath {
    pub fn name(self) -> &'static str {
        match self {
            LowerPath::Direct => "direct",
            LowerPath::Template => "template",
            LowerPath::Synth => "synth",
        }
    }
}

/// Lower one collective over a full tensor into a chunk schedule.
pub fn lower_collective(
    kind: CollectiveKind,
    table: &TensorTable,
    tensor: TensorId,
    axis: usize,
    topo: &Topology,
    path: LowerPath,
) -> Result<CommSchedule> {
    let world = topo.world;
    match (path, kind) {
        (LowerPath::Direct, CollectiveKind::AllGather) => {
            templates::all_gather_ring(table, tensor, axis, world)
        }
        (LowerPath::Direct, CollectiveKind::ReduceScatter) => {
            templates::reduce_scatter_ring(table, tensor, axis, world)
        }
        (LowerPath::Direct, CollectiveKind::AllReduce) => {
            templates::all_reduce_rs_ag(table, tensor, axis, world)
        }
        (LowerPath::Direct, CollectiveKind::AllToAll) => {
            templates::all_to_all(table, tensor, axis, world)
        }
        (LowerPath::Template, CollectiveKind::AllGather) => {
            if topo.ranks_per_node < topo.world {
                templates::all_gather_hierarchical(table, tensor, axis, topo)
            } else {
                templates::all_gather_swizzle(table, tensor, axis, world)
            }
        }
        (LowerPath::Template, CollectiveKind::ReduceScatter) => {
            templates::reduce_scatter_direct(table, tensor, axis, world)
        }
        (LowerPath::Template, CollectiveKind::AllReduce) => {
            templates::all_reduce_partition(table, tensor, axis, world)
        }
        (LowerPath::Template, CollectiveKind::AllToAll) => {
            templates::all_to_all(table, tensor, axis, world)
        }
        (LowerPath::Synth, CollectiveKind::AllGather) => {
            synth_all_gather(table, tensor, axis, topo)
        }
        (LowerPath::Synth, CollectiveKind::ReduceScatter) => {
            // synthesis of reductions degenerates to the ring on symmetric
            // topologies; reuse it (TACOS treats RS as time-reversed AG).
            templates::reduce_scatter_ring(table, tensor, axis, world)
        }
        (LowerPath::Synth, CollectiveKind::AllReduce) => {
            templates::all_reduce_rs_ag(table, tensor, axis, world)
        }
        (LowerPath::Synth, CollectiveKind::AllToAll) => {
            templates::all_to_all(table, tensor, axis, world)
        }
        (_, CollectiveKind::Broadcast) => {
            broadcast_from_zero(table, tensor, topo)
        }
    }
}

/// Broadcast rank 0's full tensor via a binomial tree (log rounds).
fn broadcast_from_zero(
    table: &TensorTable,
    tensor: TensorId,
    topo: &Topology,
) -> Result<CommSchedule> {
    let world = topo.world;
    let shape = table.get(tensor)?.shape.clone();
    let full = Chunk::new(tensor, crate::chunk::Region::full(&shape));
    let mut sched = CommSchedule::new(world, table.clone());
    // holders after round k: ranks < 2^k. In round k, rank r (< 2^k) sends
    // to r + 2^k. Dep: the op that delivered the data to r (if any).
    let mut delivered_by: Vec<Option<Dep>> = vec![None; world];
    let mut span = 1usize;
    while span < world {
        for r in 0..span.min(world) {
            let peer = r + span;
            if peer >= world {
                continue;
            }
            let deps = delivered_by[r].into_iter().collect();
            let idx = sched.add_op(
                r,
                CommOp::P2p {
                    kind: TransferKind::Push,
                    peer,
                    src: full.clone(),
                    dst: full.clone(),
                    reduce: false,
                    deps,
                },
            )?;
            delivered_by[peer] = Some(Dep::on(r, idx));
        }
        span *= 2;
    }
    Ok(sched)
}

/// TACOS-like greedy AllGather synthesis.
///
/// Time-stepped flood: each round, every rank may send ONE shard it holds to
/// ONE peer that lacks it. Pairings greedily prefer (a) peers on the same
/// node over cross-node links, (b) shards the receiver will wait longest
/// for. Rounds become dependency stages: a forwarded shard's op depends on
/// the op that delivered it.
pub fn synth_all_gather(
    table: &TensorTable,
    tensor: TensorId,
    axis: usize,
    topo: &Topology,
) -> Result<CommSchedule> {
    let world = topo.world;
    let shape = table.get(tensor)?.shape.clone();
    let mut sched = CommSchedule::new(world, table.clone());
    // holds[r] = shards present; how[r][s] = op that delivered shard s to r
    let mut holds: Vec<HashSet<usize>> = (0..world).map(|r| HashSet::from([r])).collect();
    let mut how: Vec<Vec<Option<Dep>>> = vec![vec![None; world]; world];
    let mut rounds = 0usize;
    while holds.iter().any(|h| h.len() < world) {
        rounds += 1;
        if rounds > 4 * world {
            return Err(Error::Lowering("synth AG failed to converge".into()));
        }
        // plan this round: busy senders/receivers, chosen (sender, shard, recv)
        let mut sender_busy = vec![false; world];
        let mut recv_busy = vec![false; world];
        let mut moves: Vec<(usize, usize, usize)> = Vec::new();
        // shards already en route to a node THIS round (avoid duplicate
        // cross-node imports of the same shard by sibling ranks)
        let mut arriving: HashSet<(usize, usize)> = HashSet::new();
        // receivers with the most missing shards pick first
        let mut recv_order: Vec<usize> = (0..world).collect();
        recv_order.sort_by_key(|&r| world - holds[r].len());
        recv_order.reverse();
        for &r in &recv_order {
            if recv_busy[r] || holds[r].len() == world {
                continue;
            }
            // candidate (sender, shard): sender holds shard, r lacks it
            let mut best: Option<(usize, usize, usize)> = None; // (cost, sender, shard)
            for s in 0..world {
                if sender_busy[s] || s == r {
                    continue;
                }
                for &shard in &holds[s] {
                    if holds[r].contains(&shard) {
                        continue;
                    }
                    // cost 0: intra-node forward; cost 1: cross-node import
                    // of a shard this node does not have yet; cost 2:
                    // redundant cross-node import (another rank in the node
                    // already holds it -> prefer waiting for local forward).
                    let node_r = topo.node_of(r);
                    let cost = if topo.node_of(s) == node_r {
                        0
                    } else if arriving.contains(&(node_r, shard))
                        || (0..world)
                            .filter(|&x| topo.node_of(x) == node_r)
                            .any(|x| holds[x].contains(&shard))
                    {
                        2
                    } else {
                        1
                    };
                    let cand = (cost, s, shard);
                    if best.map(|b| cand < b).unwrap_or(true) {
                        best = Some(cand);
                    }
                }
            }
            if let Some((_, s, shard)) = best {
                sender_busy[s] = true;
                recv_busy[r] = true;
                arriving.insert((topo.node_of(r), shard));
                moves.push((s, shard, r));
            }
        }
        if moves.is_empty() {
            return Err(Error::Lowering("synth AG stalled (no feasible move)".into()));
        }
        for (s, shard, r) in moves {
            let c = Chunk::new(tensor, shard_region(&shape, axis, world, shard)?);
            let deps = how[s][shard].into_iter().collect();
            let idx = sched.add_op(
                s,
                CommOp::P2p {
                    kind: TransferKind::Push,
                    peer: r,
                    src: c.clone(),
                    dst: c,
                    reduce: false,
                    deps,
                },
            )?;
            holds[r].insert(shard);
            how[r][shard] = Some(Dep::on(s, idx));
        }
    }
    Ok(sched)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chunk::DType;
    use crate::schedule::validate::validate;

    fn table(rows: usize) -> (TensorTable, TensorId) {
        let mut t = TensorTable::new();
        let x = t.declare("x", &[rows, 8], DType::F32).unwrap();
        (t, x)
    }

    fn replay_ag_complete(s: &CommSchedule, world: usize) {
        // all-pairs completion check via the schedule's produced chunks:
        // every rank must receive every other rank's shard exactly once.
        let mut got = vec![HashSet::new(); world];
        for r in 0..world {
            got[r].insert(r);
        }
        for (rank, ops) in s.per_rank.iter().enumerate() {
            for op in ops {
                let dst = op.dst_rank(rank);
                let shard = op.produced_chunk().region.offset[0]
                    / (s.tensors.get(op.produced_chunk().tensor).unwrap().shape[0] / world);
                got[dst].insert(shard);
            }
        }
        for (r, g) in got.iter().enumerate() {
            assert_eq!(g.len(), world, "rank {r} missing shards");
        }
    }

    #[test]
    fn all_paths_produce_valid_ag() {
        let topo = crate::hw::catalog::topology("h100_node", 4).unwrap();
        let (t, x) = table(8);
        for path in [LowerPath::Direct, LowerPath::Template, LowerPath::Synth] {
            let s = lower_collective(CollectiveKind::AllGather, &t, x, 0, &topo, path)
                .unwrap_or_else(|e| panic!("{path:?}: {e}"));
            validate(&s).unwrap_or_else(|e| panic!("{path:?}: {e}"));
            replay_ag_complete(&s, 4);
        }
    }

    #[test]
    fn paths_differ_structurally() {
        let topo = crate::hw::catalog::topology("h100_node", 4).unwrap();
        let (t, x) = table(8);
        let d = lower_collective(CollectiveKind::AllGather, &t, x, 0, &topo, LowerPath::Direct)
            .unwrap();
        let tpl =
            lower_collective(CollectiveKind::AllGather, &t, x, 0, &topo, LowerPath::Template)
                .unwrap();
        assert_ne!(d, tpl, "direct (ring) and template (swizzle) must differ");
        // ring has chained deps; swizzle has none
        assert!(d.per_rank.iter().flatten().any(|o| !o.deps().is_empty()));
        assert!(tpl.per_rank.iter().flatten().all(|o| o.deps().is_empty()));
    }

    #[test]
    fn template_ag_goes_hierarchical_on_multinode() {
        let topo = crate::hw::catalog::topology_nodes("h100_multinode", 2, 4).unwrap();
        let (t, x) = table(8);
        let s = lower_collective(CollectiveKind::AllGather, &t, x, 0, &topo, LowerPath::Template)
            .unwrap();
        validate(&s).unwrap();
        replay_ag_complete(&s, 4);
    }

    #[test]
    fn synth_ag_converges_all_worlds() {
        for world in [2usize, 3, 4, 8] {
            let topo = crate::hw::catalog::topology("h100_node", world).unwrap();
            let (t, x) = table(world * 2);
            let s = synth_all_gather(&t, x, 0, &topo).unwrap();
            validate(&s).unwrap();
            replay_ag_complete(&s, world);
        }
    }

    #[test]
    fn synth_ag_prefers_intra_node() {
        let topo = crate::hw::catalog::topology_nodes("h100_multinode", 2, 8).unwrap();
        let (t, x) = table(16);
        let s = synth_all_gather(&t, x, 0, &topo).unwrap();
        validate(&s).unwrap();
        replay_ag_complete(&s, 8);
        // cross-node transfers should be well below all-pairs count
        let cross = s
            .per_rank
            .iter()
            .enumerate()
            .flat_map(|(r, ops)| ops.iter().map(move |o| (r, o.dst_rank(r))))
            .filter(|(a, b)| topo.node_of(*a) != topo.node_of(*b))
            .count();
        assert!(cross < 8 * 7 / 2, "cross-node transfers: {cross}");
    }

    #[test]
    fn rs_and_ar_paths_valid() {
        let topo = crate::hw::catalog::topology("h100_node", 4).unwrap();
        let (t, x) = table(8);
        for path in [LowerPath::Direct, LowerPath::Template, LowerPath::Synth] {
            for kind in [CollectiveKind::ReduceScatter, CollectiveKind::AllReduce] {
                let s = lower_collective(kind, &t, x, 0, &topo, path).unwrap();
                validate(&s).unwrap();
                assert!(s.num_ops() > 0);
            }
        }
    }

    #[test]
    fn broadcast_tree_log_depth() {
        let topo = crate::hw::catalog::topology("h100_node", 8).unwrap();
        let (t, x) = table(8);
        let s = lower_collective(CollectiveKind::Broadcast, &t, x, 0, &topo, LowerPath::Template)
            .unwrap();
        validate(&s).unwrap();
        // binomial tree: 7 sends total for 8 ranks
        assert_eq!(s.num_ops(), 7);
        // rank 0 sends ceil(log2(8)) = 3 times
        assert_eq!(s.per_rank[0].len(), 3);
    }

    #[test]
    fn a2a_same_under_all_paths() {
        let topo = crate::hw::catalog::topology("h100_node", 4).unwrap();
        let (t, x) = table(32);
        let a = lower_collective(CollectiveKind::AllToAll, &t, x, 0, &topo, LowerPath::Direct)
            .unwrap();
        let b = lower_collective(CollectiveKind::AllToAll, &t, x, 0, &topo, LowerPath::Synth)
            .unwrap();
        assert_eq!(a, b);
    }
}
