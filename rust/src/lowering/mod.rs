//! Frontends from higher-level distributed-compiler IRs (paper §5.1,
//! Listing 3, Fig. 10).
//!
//! Two IR families are supported, mirroring the systems integrated in the
//! paper's evaluation:
//!
//! * [`partition`] — partition-based IRs (Domino-, Alpa-style): tensors carry
//!   source/destination placements; the implied resharding collectives are
//!   inferred and lowered onto chunk schedules.
//! * [`loops`] — loop-based IRs (Mercury-style): explicit ring/step loops
//!   with per-step send/recv intents, grouped into chunks.
//!
//! Both funnel through [`collective`], which realizes abstract collectives
//! via one of three paths: `direct` (library-style bulk ring), `template`
//! (this crate's swizzle templates), or `synth` (TACOS-like greedy
//! synthesis over the topology).

pub mod collective;
pub mod loops;
pub mod partition;

pub use collective::LowerPath;
