//! Partition-based IR frontend (Domino / Alpa style; paper Listing 3,
//! `lower_partition_ir`).
//!
//! A partition IR describes tensors by their *placements* before and after
//! an operator: replicated, sharded along an axis, or partial (pending
//! reduction). The resharding collective between two placements is a pure
//! function of the pair; we infer it, then lower each collective through the
//! chosen [`LowerPath`], merging everything into one chunk schedule.

use crate::chunk::{DType, TensorTable};
use crate::error::{Error, Result};
use crate::lowering::collective::{lower_collective, LowerPath};
use crate::schedule::{CollectiveKind, CommSchedule};
use crate::topo::Topology;

/// Tensor placement over the mesh (Alpa/GSPMD-style, 1-D mesh).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Placement {
    /// Full copy on every rank.
    Replicated,
    /// Equal slabs along `axis`, shard `r` on rank `r`.
    Sharded { axis: usize },
    /// Every rank holds an unreduced partial of the full tensor.
    Partial,
}

/// One tensor in the partition IR, with its placement transition.
#[derive(Debug, Clone, PartialEq)]
pub struct PTensor {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
    pub src: Placement,
    pub dst: Placement,
}

/// A partition-based compiler's view of one operator's communication.
#[derive(Debug, Clone, PartialEq)]
pub struct PartitionIR {
    pub world: usize,
    pub tensors: Vec<PTensor>,
}

/// The collective implied by a placement transition
/// (`parse_partition_to_steps` in the paper's Listing 3).
pub fn implied_collective(src: Placement, dst: Placement) -> Result<Option<CollectiveKind>> {
    use Placement::*;
    Ok(match (src, dst) {
        (a, b) if a == b => None,
        (Sharded { .. }, Replicated) => Some(CollectiveKind::AllGather),
        (Partial, Sharded { .. }) => Some(CollectiveKind::ReduceScatter),
        (Partial, Replicated) => Some(CollectiveKind::AllReduce),
        (Sharded { axis: a }, Sharded { axis: b }) if a != b => Some(CollectiveKind::AllToAll),
        // slicing a replica is rank-local, no communication
        (Replicated, Sharded { .. }) => None,
        (Replicated, Partial) | (Sharded { .. }, Partial) => {
            return Err(Error::Lowering(format!(
                "no collective reshards {src:?} -> {dst:?} (partial is a \
                 producer-side state)"
            )))
        }
        _ => None,
    })
}

/// Lower a whole partition IR into one merged chunk schedule.
///
/// Tensors are processed in order; each tensor's ops are appended to the
/// shared per-rank lists, so later tensors' ops sit after earlier ones in
/// program order (matching how a partition-based compiler sequences its
/// collectives).
pub fn lower_partition_ir(
    ir: &PartitionIR,
    topo: &Topology,
    path: LowerPath,
) -> Result<CommSchedule> {
    if ir.world != topo.world {
        return Err(Error::Lowering(format!(
            "IR world {} != topology world {}",
            ir.world, topo.world
        )));
    }
    // Declare all tensors up front in one shared table.
    let mut table = TensorTable::new();
    for t in &ir.tensors {
        table.declare(&t.name, &t.shape, t.dtype)?;
    }
    let mut merged = CommSchedule::new(ir.world, table.clone());
    for t in &ir.tensors {
        let Some(kind) = implied_collective(t.src, t.dst)? else { continue };
        let axis = match kind {
            CollectiveKind::AllGather | CollectiveKind::AllToAll => match t.src {
                Placement::Sharded { axis } => axis,
                _ => 0,
            },
            CollectiveKind::ReduceScatter => match t.dst {
                Placement::Sharded { axis } => axis,
                _ => 0,
            },
            _ => 0,
        };
        let id = table.lookup(&t.name).expect("declared above");
        let sub = lower_collective(kind, &table, id, axis, topo, path)?;
        // merge: append sub's ops with dep indices shifted per rank
        let offsets: Vec<usize> = (0..ir.world).map(|r| merged.per_rank[r].len()).collect();
        for (rank, ops) in sub.per_rank.into_iter().enumerate() {
            for mut op in ops {
                remap_deps(&mut op, &offsets);
                merged.per_rank[rank].push(op);
            }
        }
    }
    Ok(merged)
}

fn remap_deps(op: &mut crate::schedule::CommOp, offsets: &[usize]) {
    use crate::schedule::CommOp::*;
    let deps = match op {
        P2p { deps, .. } | Collective { deps, .. } | LocalCopy { deps, .. } => deps,
    };
    for d in deps.iter_mut() {
        d.index += offsets[d.rank];
    }
}

/// Representative partition IRs for the Fig. 10 integration study.
pub mod presets {
    use super::*;

    /// Domino-style tensor-parallel FFN: AG(X) then AR(Y-partial).
    pub fn domino_ffn(world: usize, m: usize, k: usize, n: usize) -> PartitionIR {
        PartitionIR {
            world,
            tensors: vec![
                PTensor {
                    name: "x".into(),
                    shape: vec![m, k],
                    dtype: DType::BF16,
                    src: Placement::Sharded { axis: 0 },
                    dst: Placement::Replicated,
                },
                PTensor {
                    name: "y".into(),
                    shape: vec![m, n],
                    dtype: DType::BF16,
                    src: Placement::Partial,
                    dst: Placement::Replicated,
                },
            ],
        }
    }

    /// Alpa-style megatron FFN: AG(X) then RS(Y) (sequence parallel).
    pub fn alpa_ffn(world: usize, m: usize, k: usize, n: usize) -> PartitionIR {
        PartitionIR {
            world,
            tensors: vec![
                PTensor {
                    name: "x".into(),
                    shape: vec![m, k],
                    dtype: DType::BF16,
                    src: Placement::Sharded { axis: 0 },
                    dst: Placement::Replicated,
                },
                PTensor {
                    name: "y".into(),
                    shape: vec![m, n],
                    dtype: DType::BF16,
                    src: Placement::Partial,
                    dst: Placement::Sharded { axis: 0 },
                },
            ],
        }
    }

    /// MoE dispatch: tokens resharded across experts (A2A both ways).
    pub fn moe_a2a(world: usize, tokens: usize, hidden: usize) -> PartitionIR {
        PartitionIR {
            world,
            tensors: vec![
                PTensor {
                    name: "dispatch".into(),
                    shape: vec![tokens, hidden],
                    dtype: DType::BF16,
                    src: Placement::Sharded { axis: 0 },
                    dst: Placement::Sharded { axis: 1 },
                },
                PTensor {
                    name: "combine".into(),
                    shape: vec![tokens, hidden],
                    dtype: DType::BF16,
                    src: Placement::Sharded { axis: 1 },
                    dst: Placement::Sharded { axis: 0 },
                },
            ],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::validate::validate;

    #[test]
    fn implied_collectives_table() {
        use CollectiveKind::*;
        use Placement::*;
        assert_eq!(implied_collective(Sharded { axis: 0 }, Replicated).unwrap(), Some(AllGather));
        assert_eq!(
            implied_collective(Partial, Sharded { axis: 0 }).unwrap(),
            Some(ReduceScatter)
        );
        assert_eq!(implied_collective(Partial, Replicated).unwrap(), Some(AllReduce));
        assert_eq!(
            implied_collective(Sharded { axis: 0 }, Sharded { axis: 1 }).unwrap(),
            Some(AllToAll)
        );
        assert_eq!(implied_collective(Replicated, Replicated).unwrap(), None);
        assert_eq!(implied_collective(Replicated, Sharded { axis: 0 }).unwrap(), None);
        assert_eq!(
            implied_collective(Sharded { axis: 1 }, Sharded { axis: 1 }).unwrap(),
            None
        );
        assert!(implied_collective(Replicated, Partial).is_err());
        assert!(implied_collective(Sharded { axis: 0 }, Partial).is_err());
    }

    #[test]
    fn domino_ffn_lowers_and_validates() {
        let topo = crate::hw::catalog::topology("h100_node", 4).unwrap();
        let ir = presets::domino_ffn(4, 64, 32, 32);
        for path in [LowerPath::Direct, LowerPath::Template, LowerPath::Synth] {
            let s = lower_partition_ir(&ir, &topo, path).unwrap();
            validate(&s).unwrap_or_else(|e| panic!("{path:?}: {e}"));
            assert!(s.num_ops() > 0);
            assert_eq!(s.tensors.len(), 2);
        }
    }

    #[test]
    fn alpa_ffn_has_ag_and_rs_phases() {
        let topo = crate::hw::catalog::topology("h100_node", 4).unwrap();
        let ir = presets::alpa_ffn(4, 64, 32, 32);
        let s = lower_partition_ir(&ir, &topo, LowerPath::Template).unwrap();
        validate(&s).unwrap();
        // RS ops reduce, AG ops don't: both kinds present
        let reduces = s.per_rank.iter().flatten().filter(|o| o.reduces()).count();
        let plain = s.per_rank.iter().flatten().filter(|o| !o.reduces()).count();
        assert!(reduces > 0 && plain > 0);
    }

    #[test]
    fn merged_deps_remapped_past_earlier_tensor_ops() {
        // Direct path: AG ring (with deps) then AR rs+ag (with deps); the
        // second tensor's dep indices must be shifted by the first's op count.
        let topo = crate::hw::catalog::topology("h100_node", 4).unwrap();
        let ir = presets::domino_ffn(4, 64, 32, 32);
        let s = lower_partition_ir(&ir, &topo, LowerPath::Direct).unwrap();
        validate(&s).unwrap(); // would fail on bad dep indices / cycles
        let ag_ops = 4 - 1; // ring AG ops per rank for tensor "x"
        // at least one dep in the AR phase points past the AG phase
        let mut found = false;
        for ops in &s.per_rank {
            for op in &ops[ag_ops..] {
                if op.deps().iter().any(|d| d.index >= ag_ops) {
                    found = true;
                }
            }
        }
        assert!(found, "AR deps were not remapped");
    }

    #[test]
    fn moe_a2a_round_trip() {
        let topo = crate::hw::catalog::topology("h100_node", 4).unwrap();
        let ir = presets::moe_a2a(4, 64, 32);
        let s = lower_partition_ir(&ir, &topo, LowerPath::Template).unwrap();
        validate(&s).unwrap();
        // two A2As, each w*(w-1) pushes total
        assert_eq!(s.num_ops(), 2 * 4 * 3);
    }

    #[test]
    fn world_mismatch_rejected() {
        let topo = crate::hw::catalog::topology("h100_node", 2).unwrap();
        let ir = presets::domino_ffn(4, 64, 32, 32);
        assert!(lower_partition_ir(&ir, &topo, LowerPath::Template).is_err());
    }

    #[test]
    fn a2a_needs_divisible_blocks() {
        // tokens not divisible by world^2 on the A2A axis -> schedule error
        let topo = crate::hw::catalog::topology("h100_node", 4).unwrap();
        let ir = presets::moe_a2a(4, 20, 32);
        assert!(lower_partition_ir(&ir, &topo, LowerPath::Template).is_err());
    }
}
