//! Loop-based IR frontend (Mercury style; paper Listing 3, `lower_loop_ir`).
//!
//! Mercury-like compilers express ring and double-ring attention as loop
//! nests whose bodies contain communication intents (rotate the K/V shard to
//! the ring successor) and compute statements. We walk the loop nest,
//! collect the per-step send/recv intents (`parse_comm_intents`), group the
//! communicated regions into chunks at the chosen granularity, and emit a
//! dependency-chained chunk schedule.

use crate::chunk::{Chunk, DType, TensorTable};
use crate::error::{Error, Result};
use crate::schedule::templates::shard_region;
use crate::schedule::{CommOp, CommSchedule, Dep, TransferKind};
use crate::topo::Topology;

/// A communication intent inside a loop body.
#[derive(Debug, Clone, PartialEq)]
pub enum LoopNode {
    /// `for step in 0..steps { body }` — the ring loop.
    ForStep { steps: usize, body: Vec<LoopNode> },
    /// Rotate `tensor`'s current shard to the ring successor each step.
    RotateShard { tensor: String, axis: usize },
    /// Compute statement (opaque to the comm plan; marks granularity).
    Compute { label: String },
}

/// A loop-based compiler's view of one operator.
#[derive(Debug, Clone, PartialEq)]
pub struct LoopIR {
    pub world: usize,
    /// (name, global shape, dtype) of tensors referenced by the loop.
    pub tensors: Vec<(String, Vec<usize>, DType)>,
    pub nodes: Vec<LoopNode>,
}

/// Walk the loop nest and collect (tensor, axis, steps) rotation intents
/// (the `parse_comm_intents` of Listing 3).
pub fn parse_comm_intents(ir: &LoopIR) -> Vec<(String, usize, usize)> {
    fn walk(nodes: &[LoopNode], steps: usize, out: &mut Vec<(String, usize, usize)>) {
        for n in nodes {
            match n {
                LoopNode::ForStep { steps: s, body } => walk(body, *s, out),
                LoopNode::RotateShard { tensor, axis } => {
                    out.push((tensor.clone(), *axis, steps))
                }
                LoopNode::Compute { .. } => {}
            }
        }
    }
    let mut out = Vec::new();
    walk(&ir.nodes, 1, &mut out);
    out
}

/// Lower a loop IR to a chunk schedule.
///
/// Each `RotateShard` inside a `steps`-iteration loop becomes a pipelined
/// ring: at step `s`, rank `r` pushes the shard it currently holds —
/// `(r - s) mod w` — to its successor, depending on the predecessor's
/// previous-step push (the shard has to arrive before it can be forwarded).
pub fn lower_loop_ir(ir: &LoopIR, topo: &Topology) -> Result<CommSchedule> {
    if ir.world != topo.world {
        return Err(Error::Lowering(format!(
            "IR world {} != topology world {}",
            ir.world, topo.world
        )));
    }
    let world = ir.world;
    let mut table = TensorTable::new();
    for (name, shape, dtype) in &ir.tensors {
        table.declare(name, shape, *dtype)?;
    }
    let intents = parse_comm_intents(ir);
    if intents.is_empty() {
        return Ok(CommSchedule::new(world, table));
    }
    let mut sched = CommSchedule::new(world, table.clone());
    for (tensor, axis, steps) in intents {
        let id = table
            .lookup(&tensor)
            .ok_or_else(|| Error::Lowering(format!("loop rotates undeclared tensor `{tensor}`")))?;
        if steps > world {
            return Err(Error::Lowering(format!(
                "ring loop of {steps} steps exceeds world {world}"
            )));
        }
        let shape = table.get(id)?.shape.clone();
        let base: Vec<usize> = (0..world).map(|r| sched.per_rank[r].len()).collect();
        for r in 0..world {
            for s in 0..steps.saturating_sub(1) {
                let shard = (r + world - s) % world;
                let c = Chunk::new(id, shard_region(&shape, axis, world, shard)?);
                let deps = if s == 0 {
                    vec![]
                } else {
                    vec![Dep::on((r + world - 1) % world, base[(r + world - 1) % world] + s - 1)]
                };
                sched.add_op(
                    r,
                    CommOp::P2p {
                        kind: TransferKind::Push,
                        peer: (r + 1) % world,
                        src: c.clone(),
                        dst: c,
                        reduce: false,
                        deps,
                    },
                )?;
            }
        }
    }
    Ok(sched)
}

/// Representative loop IRs for the Fig. 10 integration study.
pub mod presets {
    use super::*;

    /// Mercury-style RingAttention: rotate K and V around the full ring,
    /// computing one block-attention step per arrival.
    pub fn mercury_ring_attention(
        world: usize,
        seq: usize,
        heads_dim: usize,
    ) -> LoopIR {
        LoopIR {
            world,
            tensors: vec![
                ("k".into(), vec![seq, heads_dim], DType::BF16),
                ("v".into(), vec![seq, heads_dim], DType::BF16),
            ],
            nodes: vec![LoopNode::ForStep {
                steps: world,
                body: vec![
                    LoopNode::RotateShard { tensor: "k".into(), axis: 0 },
                    LoopNode::RotateShard { tensor: "v".into(), axis: 0 },
                    LoopNode::Compute { label: "attn_step".into() },
                ],
            }],
        }
    }

    /// Double-ring (LoongTrain-style): outer ring over node groups, inner
    /// ring within — expressed as two nested rotate loops.
    pub fn mercury_double_ring(world: usize, seq: usize, heads_dim: usize) -> LoopIR {
        let inner = world / 2;
        LoopIR {
            world,
            tensors: vec![
                ("k".into(), vec![seq, heads_dim], DType::BF16),
                ("v".into(), vec![seq, heads_dim], DType::BF16),
            ],
            nodes: vec![LoopNode::ForStep {
                steps: 2,
                body: vec![LoopNode::ForStep {
                    steps: inner,
                    body: vec![
                        LoopNode::RotateShard { tensor: "k".into(), axis: 0 },
                        LoopNode::RotateShard { tensor: "v".into(), axis: 0 },
                        LoopNode::Compute { label: "attn_step".into() },
                    ],
                }],
            }],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::validate::validate;

    #[test]
    fn parse_intents_nested() {
        let ir = presets::mercury_ring_attention(4, 64, 32);
        let intents = parse_comm_intents(&ir);
        assert_eq!(intents.len(), 2);
        assert_eq!(intents[0], ("k".to_string(), 0, 4));
    }

    #[test]
    fn ring_attention_lowers_and_validates() {
        let topo = crate::hw::catalog::topology("h100_node", 4).unwrap();
        let ir = presets::mercury_ring_attention(4, 64, 32);
        let s = lower_loop_ir(&ir, &topo).unwrap();
        validate(&s).unwrap();
        // two tensors x (world-1) pushes per rank
        assert_eq!(s.num_ops(), 2 * 4 * 3);
        // pipelined: later steps carry deps
        assert!(s.per_rank.iter().flatten().any(|o| !o.deps().is_empty()));
    }

    #[test]
    fn double_ring_lowers() {
        let topo = crate::hw::catalog::topology("h100_node", 4).unwrap();
        let ir = presets::mercury_double_ring(4, 64, 32);
        let s = lower_loop_ir(&ir, &topo).unwrap();
        validate(&s).unwrap();
        // inner ring of 2 steps -> 1 push per tensor per rank per outer iter
        assert!(s.num_ops() > 0);
    }

    #[test]
    fn empty_loop_ir_is_empty_schedule() {
        let topo = crate::hw::catalog::topology("h100_node", 2).unwrap();
        let ir = LoopIR { world: 2, tensors: vec![], nodes: vec![] };
        let s = lower_loop_ir(&ir, &topo).unwrap();
        assert_eq!(s.num_ops(), 0);
    }

    #[test]
    fn error_cases() {
        let topo = crate::hw::catalog::topology("h100_node", 4).unwrap();
        // undeclared tensor
        let ir = LoopIR {
            world: 4,
            tensors: vec![],
            nodes: vec![LoopNode::ForStep {
                steps: 4,
                body: vec![LoopNode::RotateShard { tensor: "ghost".into(), axis: 0 }],
            }],
        };
        assert!(lower_loop_ir(&ir, &topo).is_err());
        // world mismatch
        let ir2 = presets::mercury_ring_attention(8, 64, 32);
        assert!(lower_loop_ir(&ir2, &topo).is_err());
        // steps exceed world
        let ir3 = LoopIR {
            world: 4,
            tensors: vec![("k".into(), vec![64, 32], DType::BF16)],
            nodes: vec![LoopNode::ForStep {
                steps: 9,
                body: vec![LoopNode::RotateShard { tensor: "k".into(), axis: 0 }],
            }],
        };
        assert!(lower_loop_ir(&ir3, &topo).is_err());
    }

    #[test]
    fn shard_rotation_covers_all_shards_at_each_rank() {
        // after the ring completes, every rank has pushed/received w-1
        // distinct shards of each tensor
        let topo = crate::hw::catalog::topology("h100_node", 4).unwrap();
        let ir = presets::mercury_ring_attention(4, 64, 32);
        let s = lower_loop_ir(&ir, &topo).unwrap();
        for r in 0..4 {
            let mut shards: Vec<usize> = s.per_rank[r]
                .iter()
                .filter(|o| {
                    s.tensors.get(o.produced_chunk().tensor).unwrap().name == "k"
                })
                .map(|o| o.produced_chunk().region.offset[0] / 16)
                .collect();
            shards.sort_unstable();
            shards.dedup();
            assert_eq!(shards.len(), 3, "rank {r} pushes 3 distinct k shards");
        }
    }
}
