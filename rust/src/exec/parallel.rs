//! The parallel per-rank execution engine (atomic synchronization core).
//!
//! One worker thread per rank interprets that rank's [`PlanOp`] stream
//! directly — `Wait`s block on the shared atomic [`SignalBoard`]
//! (targeted parking, no condvar broadcast), and transfers with unmet
//! dependencies are parked in the **destination rank's own queue**
//! ([`PlanArena`]) instead of a global pending pool. The destination
//! thread drains its queue opportunistically at every op boundary, inside
//! its own blocked `Wait`s, and in a final drain phase after its program
//! ends — so the O(ranks × pending) full-pool rescans of the old
//! dedicated servicer loop (see [`crate::exec::parallel_condvar`], the
//! retained baseline) are gone, and the thread that owns the destination
//! buffers is the one that writes them. This mirrors the signal-based
//! per-rank progress model of Triton-distributed / ParallelKittens:
//! chunks land while compute proceeds, with no global step barrier.
//!
//! All run-loop state (signal words, queue storage, drain scratch, copy
//! staging) is preallocated in the [`PlanArena`], so once the threads are
//! up the interpretation loop performs no heap allocation; rank threads
//! layer a [`SeenSignals`] cache over the board so re-checks of
//! already-observed signals stay thread-local. With
//! [`ExecOptions::pin_cores`] set, each rank thread pins itself
//! (best-effort) to a core before interpreting.
//!
//! Determinism: the plan arrives pre-augmented by
//! [`super::plan_prep::prepare`], which serializes every accumulating
//! writer into a contested region through dependency signals — so despite
//! true concurrency, f32 outputs are bit-identical to the sequential
//! reference engine (and to the condvar baseline).
//!
//! Deadlock policy: every blocking wait is bounded. A waiter errors only
//! after [`ExecOptions::wait_timeout`] elapses with *no board activity at
//! all* (signals set, queue pushes, rank completions) *and* no thread
//! mid-kernel-call or mid-transfer-apply — the busy counter and the
//! epoch heartbeat cooperate through the ordering contract documented on
//! [`SignalBoard::busy_end`]. Verdicts name the stuck ranks and every
//! parked transfer's unmet dependency signals, exactly as the baseline
//! engine's did.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::codegen::{PlanOp, TransferDesc};
use crate::error::{Error, Result};
use crate::exec::arena::{PlanArena, QueuedTransfer, RankLocal};
use crate::exec::buffers::BufferStore;
use crate::exec::engine::{apply_transfer_scratch_sunk, exec_call_sunk, push_seg_event, ExecStats};
use crate::exec::plan_prep::PreparedPlan;
use crate::exec::signals::{Interest, SignalBoard};
use crate::exec::ExecOptions;
use crate::runtime::Runtime;
use crate::trace::{TraceEvent, TraceKind, TraceSink};

/// `rank_pc` value meaning "this rank's program completed".
const RANK_DONE: usize = usize::MAX;

struct Shared<'p> {
    prep: &'p PreparedPlan,
    arena: &'p PlanArena,
    /// Each rank's current op index ([`RANK_DONE`] once its program
    /// finished). Per-op stores are Relaxed (only deadlock verdicts read
    /// them, and stale-by-one is fine there); the RANK_DONE store is
    /// Release and [`Shared::all_programs_done`] loads Acquire, so a
    /// drainer that observes "all done" also observes every queue push
    /// those programs made — the final-drain exit check cannot miss a
    /// transfer.
    rank_pc: Vec<AtomicUsize>,
    stats: Mutex<ExecStats>,
    fail: Mutex<Option<Error>>,
    /// Event sink when the run is traced; `None` leaves the hot path with
    /// a dead branch per op.
    sink: Option<&'p TraceSink>,
}

impl Shared<'_> {
    fn board(&self) -> &SignalBoard {
        &self.arena.board
    }

    /// Apply a transfer with the board's busy marker held, so bounded
    /// waiters elsewhere treat a long region copy as progress, not
    /// deadlock (see [`SignalBoard::busy_end`] for the ordering that
    /// closes the misdiagnosis window).
    fn apply_busy(
        &self,
        d: &TransferDesc,
        store: &BufferStore,
        scratch: &mut Vec<f32>,
    ) -> Result<usize> {
        self.board().busy_begin();
        let r = apply_transfer_scratch_sunk(self.prep, d, store, scratch, self.sink);
        self.board().busy_end();
        r
    }

    /// The plan's `Issue` op at queue coordinates `it`.
    fn queued_desc(&self, it: QueuedTransfer) -> Result<&TransferDesc> {
        match self.prep.plan.per_rank[it.rank as usize].ops.get(it.op as usize) {
            Some(PlanOp::Issue(d)) => Ok(d),
            _ => Err(Error::Exec(format!(
                "internal: parked queue entry (rank {}, op {}) is not an Issue",
                it.rank, it.op
            ))),
        }
    }

    /// True once every rank stored [`RANK_DONE`] (Acquire — see `rank_pc`).
    fn all_programs_done(&self) -> bool {
        self.rank_pc.iter().all(|pc| pc.load(Ordering::Acquire) == RANK_DONE)
    }

    /// Where every unfinished rank is stuck, for deadlock verdicts.
    fn stuck_ranks(&self) -> Vec<String> {
        (0..self.prep.plan.world)
            .filter_map(|r| {
                let pc = self.rank_pc[r].load(Ordering::Relaxed);
                if pc == RANK_DONE {
                    return None;
                }
                let op = self.prep.plan.per_rank[r]
                    .ops
                    .get(pc)
                    .map(|o| o.brief())
                    .unwrap_or_else(|| "<end>".into());
                Some(format!("rank {r} at op {pc} ({op})"))
            })
            .collect()
    }

    /// The bounded-wait deadlock verdict, enriched with WHO is stuck
    /// WHERE — each unfinished rank's current op, and each parked
    /// transfer's unmet dependency signals — instead of a bare timeout.
    /// Same shape as the baseline engine's verdict (pinned by tests).
    fn deadlock_verdict(&self, timeout: std::time::Duration, what: &str) -> Error {
        let mut parked: Vec<String> = Vec::new();
        for q in &self.arena.queues {
            for it in q.items.lock().unwrap().iter() {
                if let Ok(d) = self.queued_desc(*it) {
                    parked.push(format!(
                        "sig {} ({}->{}) missing deps {:?}",
                        d.signal,
                        d.src_rank,
                        d.dst_rank,
                        self.board().unmet(&d.dep_signals)
                    ));
                }
            }
        }
        // error_total{kind=deadlock} and the flight dump happen once on the
        // shared verdict path in engine::note_deadlock, not per call site
        let stuck = self.stuck_ranks();
        let stuck_idx: Vec<usize> = (0..self.prep.plan.world)
            .filter(|&r| self.rank_pc[r].load(Ordering::Relaxed) != RANK_DONE)
            .collect();
        // when every program completed (a final-drain verdict), the whole
        // world's recent events are the useful context
        let ctx_ranks: Vec<usize> = if stuck_idx.is_empty() {
            (0..self.prep.plan.world).collect()
        } else {
            stuck_idx
        };
        let ctx = crate::obs::flight::verdict_context(&ctx_ranks, 8);
        let stuck = if stuck.is_empty() {
            "none (all rank programs completed)".to_string()
        } else {
            stuck.join("; ")
        };
        Error::Exec(format!(
            "deadlock: bounded wait ({timeout:?}) expired with no progress; {what}; \
             stuck ranks: {stuck}; parked transfers: [{}]{ctx}",
            parked.join(", ")
        ))
    }

    /// Record the first failure and wake every waiter.
    fn record_fail(&self, e: Error) {
        {
            let mut f = self.fail.lock().unwrap();
            if f.is_none() {
                *f = Some(e);
            }
        }
        self.board().abort();
    }
}

/// Run the atomic parallel engine with a freshly built arena.
pub(crate) fn run_parallel(
    prep: &PreparedPlan,
    store: &BufferStore,
    runtime: &Runtime,
    opts: &ExecOptions,
    sink: Option<&TraceSink>,
) -> Result<ExecStats> {
    let mut arena = PlanArena::new(prep);
    run_parallel_in(prep, &mut arena, store, runtime, opts, sink)
}

/// Run the atomic parallel engine inside a caller-owned [`PlanArena`]
/// (reset on entry), so repeated runs of one plan reuse every capacity.
pub(crate) fn run_parallel_in(
    prep: &PreparedPlan,
    arena: &mut PlanArena,
    store: &BufferStore,
    runtime: &Runtime,
    opts: &ExecOptions,
    sink: Option<&TraceSink>,
) -> Result<ExecStats> {
    if !arena.fits(prep) {
        return Err(Error::Exec(format!(
            "arena built for world {} does not fit plan world {}",
            arena.world(),
            prep.plan.world
        )));
    }
    arena.reset();
    let world = prep.plan.world;
    let shared = Shared {
        prep,
        arena: &*arena,
        rank_pc: (0..world).map(|_| AtomicUsize::new(0)).collect(),
        stats: Mutex::new(ExecStats::default()),
        fail: Mutex::new(None),
        sink,
    };

    // rank threads inherit the spawning thread's request scope so their
    // flight events carry the request ID being served
    let req = crate::obs::flight::current_request();
    std::thread::scope(|scope| {
        for rank in 0..world {
            let shared = &shared;
            scope.spawn(move || {
                crate::obs::flight::set_request(req);
                crate::obs::flight::enter_rank(rank);
                // register the handle FIRST: producers unpark us directly
                // after pushing into our queue, and a push that lands
                // before registration is caught by our first drain pass
                // (we have not parked yet)
                *shared.arena.threads[rank].lock().unwrap() =
                    Some(std::thread::current());
                if let Some(cores) = opts.pin_cores.as_deref() {
                    if !cores.is_empty() {
                        // best-effort: an unpinnable target just runs unpinned
                        let _ = super::pin::pin_current_thread(cores[rank % cores.len()]);
                    }
                }
                let mut local = shared.arena.rank_local[rank].lock().unwrap();
                match rank_body(shared, rank, store, runtime, opts, &mut local) {
                    Ok(stats) => shared.stats.lock().unwrap().merge(&stats),
                    Err(e) => shared.record_fail(e),
                }
                drop(local);
                // completion is activity: wake any-interest drainers so
                // they re-evaluate their exit condition
                shared.board().touch();
            });
        }
    });

    if let Some(e) = shared.fail.lock().unwrap().take() {
        return Err(e);
    }
    Ok(shared.stats.into_inner().unwrap())
}

/// Interpret one rank's program on its own thread, then drain the rank's
/// inbound queue until every program has finished and the queue is empty.
fn rank_body(
    shared: &Shared<'_>,
    rank: usize,
    store: &BufferStore,
    runtime: &Runtime,
    opts: &ExecOptions,
    local: &mut RankLocal,
) -> Result<ExecStats> {
    let prog = &shared.prep.plan.per_rank[rank];
    let mut stats = ExecStats::default();
    for (op_index, op) in prog.ops.iter().enumerate() {
        shared.rank_pc[rank].store(op_index, Ordering::Relaxed);
        if shared.board().aborted() {
            // another thread already recorded the real error
            return Err(Error::Exec(format!("rank {rank}: run aborted")));
        }
        // opportunistic drain: inbound transfers whose deps have landed
        // apply here, at op granularity, instead of waiting on a servicer
        drain_ready(shared, rank, store, local, &mut stats)?;
        match op {
            PlanOp::Overhead { .. } => {}
            PlanOp::Wait(sig) => {
                crate::obs::flight::signal_wait(rank, op_index, *sig);
                let t0 = shared.sink.map(|s| s.now_us());
                wait_and_drain(shared, rank, op_index, *sig, store, opts, local, &mut stats)?;
                if let (Some(s), Some(t0)) = (shared.sink, t0) {
                    s.push(TraceEvent {
                        start_us: t0,
                        end_us: s.now_us(),
                        kind: TraceKind::Wait { rank, op: op_index, signal: *sig },
                    });
                }
                stats.waits_hit += 1;
            }
            PlanOp::Issue(d) => {
                crate::obs::flight::op_issue(rank, op_index);
                if local.seen.all_set(shared.board(), &d.dep_signals) {
                    let bytes = shared.apply_busy(d, store, &mut local.copy)?;
                    stats.transfers += 1;
                    stats.bytes_moved += bytes;
                    shared.board().set(d.signal);
                    local.seen.mark(d.signal);
                    crate::obs::flight::op_apply(rank, op_index, d.signal);
                } else {
                    // asynchronous issue: park it in the DESTINATION
                    // rank's queue and move on
                    push_parked(shared, rank, op_index, d.dst_rank);
                }
            }
            PlanOp::Compute(seg) => {
                let seg_start = shared.sink.map(|s| s.now_us());
                for (ci, call) in seg.calls.iter().enumerate() {
                    // mark the call busy so bounded waiters elsewhere
                    // treat this rank as live, however long the kernel runs
                    shared.board().busy_begin();
                    let result =
                        exec_call_sunk(call, rank, op_index, ci, store, runtime, shared.sink);
                    shared.board().busy_end();
                    result?;
                    stats.compute_calls += 1;
                    if let Some(&ps) = shared.prep.call_signals.get(&(rank, op_index, ci)) {
                        shared.board().set(ps);
                        local.seen.mark(ps);
                    }
                }
                if let (Some(s), Some(t0)) = (shared.sink, seg_start) {
                    if !seg.calls.is_empty() {
                        push_seg_event(s, rank, op_index, seg, t0, s.now_us());
                    }
                }
            }
        }
    }
    // Release store: pairs with all_programs_done's Acquire loads, making
    // every queue push above visible to whichever drainer sees "all done"
    shared.rank_pc[rank].store(RANK_DONE, Ordering::Release);
    shared.board().touch();
    final_drain(shared, rank, store, opts, local, &mut stats)?;
    Ok(stats)
}

/// Park an `Issue` with unmet deps in the destination rank's queue, then
/// poke the destination: the epoch bump keeps bounded waits live, and the
/// direct unpark covers a destination that parked with narrow
/// ([`Interest::Signal`]) interest while its queue was empty.
fn push_parked(shared: &Shared<'_>, rank: usize, op_index: usize, dst: usize) {
    {
        let mut q = shared.arena.queues[dst].items.lock().unwrap();
        q.push(QueuedTransfer { rank: rank as u32, op: op_index as u32 });
    }
    shared.board().touch();
    if let Some(t) = shared.arena.threads[dst].lock().unwrap().as_ref() {
        t.unpark();
    }
}

/// One drain pass over `rank`'s own queue: apply every parked transfer
/// whose deps are met (in queue order — dep-chained entries stay ordered
/// because a not-yet-ready successor is simply retained for the next
/// pass). Returns how many were applied.
fn drain_ready(
    shared: &Shared<'_>,
    rank: usize,
    store: &BufferStore,
    local: &mut RankLocal,
    stats: &mut ExecStats,
) -> Result<usize> {
    let RankLocal { seen, ready, copy } = local;
    debug_assert!(ready.is_empty());
    {
        let mut q = shared.arena.queues[rank].items.lock().unwrap();
        if q.is_empty() {
            return Ok(0);
        }
        let board = shared.board();
        q.retain(|it| {
            let deps = match shared.queued_desc(*it) {
                Ok(d) => &d.dep_signals,
                Err(_) => return true, // impossible by construction; keep for the verdict
            };
            if seen.all_set(board, deps) {
                ready.push(*it);
                false
            } else {
                true
            }
        });
    }
    let n = ready.len();
    crate::obs::hot::queue_drained(n);
    crate::obs::flight::queue_drain(rank, n);
    for it in ready.drain(..) {
        let d = shared.queued_desc(it)?;
        let bytes = shared.apply_busy(d, store, copy)?;
        stats.transfers += 1;
        stats.bytes_moved += bytes;
        shared.board().set(d.signal);
        seen.mark(d.signal);
        crate::obs::flight::op_apply(it.rank as usize, it.op as usize, d.signal);
    }
    Ok(n)
}

/// Block at a `Wait` op until `sig` lands, draining the rank's own queue
/// whenever there is activity, with the bounded-wait deadlock verdict.
#[allow(clippy::too_many_arguments)]
fn wait_and_drain(
    shared: &Shared<'_>,
    rank: usize,
    op_index: usize,
    sig: usize,
    store: &BufferStore,
    opts: &ExecOptions,
    local: &mut RankLocal,
    stats: &mut ExecStats,
) -> Result<()> {
    let timeout = opts.wait_timeout;
    let board = shared.board();
    let mut bound_epoch = board.epoch();
    let mut deadline = Instant::now() + timeout;
    loop {
        if board.aborted() {
            return Err(Error::Exec(format!(
                "aborted while waiting: rank {rank} at op {op_index} (Wait(sig {sig}))"
            )));
        }
        drain_ready(shared, rank, store, local, stats)?;
        if local.seen.is_set(board, sig) {
            return Ok(());
        }
        // any epoch movement (including our own drain's sets) restarts
        // the bound: the run is live
        let e = board.epoch();
        if e != bound_epoch {
            bound_epoch = e;
            deadline = Instant::now() + timeout;
        }
        // narrow interest only when our queue is empty: with parked
        // inbound transfers, ANY signal could be one of their deps, so we
        // must wake on every set to re-run the drain
        let interest = if shared.arena.queues[rank].items.lock().unwrap().is_empty() {
            Interest::Signal(sig)
        } else {
            Interest::Any
        };
        board.park_unless(interest, deadline, || board.aborted() || board.epoch() != e);
        if Instant::now() >= deadline {
            // busy BEFORE epoch: see SignalBoard::busy_end
            let busy = board.busy();
            let e2 = board.epoch();
            if busy == 0 && e2 == bound_epoch {
                return Err(shared.deadlock_verdict(
                    timeout,
                    &format!(
                        "rank {rank} at op {op_index} (Wait(sig {sig})) \
                         still waiting on signals [{sig}]"
                    ),
                ));
            }
            if busy > 0 {
                deadline = Instant::now() + timeout;
            }
        }
    }
}

/// After the rank's program ends: keep draining the rank's queue until it
/// is empty AND every program has finished (a running producer could
/// still push to us), with the same bounded-wait verdict.
fn final_drain(
    shared: &Shared<'_>,
    rank: usize,
    store: &BufferStore,
    opts: &ExecOptions,
    local: &mut RankLocal,
    stats: &mut ExecStats,
) -> Result<()> {
    let timeout = opts.wait_timeout;
    let board = shared.board();
    let mut bound_epoch = board.epoch();
    let mut deadline = Instant::now() + timeout;
    loop {
        if board.aborted() {
            return Err(Error::Exec(format!("rank {rank}: run aborted")));
        }
        drain_ready(shared, rank, store, local, stats)?;
        if shared.all_programs_done() {
            // the Acquire/Release pairing on rank_pc makes every push by
            // the now-finished programs visible to this drain pass
            drain_ready(shared, rank, store, local, stats)?;
            if shared.arena.queues[rank].items.lock().unwrap().is_empty() {
                return Ok(());
            }
        }
        let e = board.epoch();
        if e != bound_epoch {
            bound_epoch = e;
            deadline = Instant::now() + timeout;
        }
        board.park_unless(Interest::Any, deadline, || board.aborted() || board.epoch() != e);
        if Instant::now() >= deadline {
            let busy = board.busy();
            let e2 = board.epoch();
            if busy == 0 && e2 == bound_epoch {
                let remaining = shared.arena.queues[rank].items.lock().unwrap().len();
                return Err(shared.deadlock_verdict(
                    timeout,
                    &format!("rank {rank} draining {remaining} parked inbound transfers"),
                ));
            }
            if busy > 0 {
                deadline = Instant::now() + timeout;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    // Plan-level parallel behavior is covered in exec::engine::tests (both
    // modes) and rust/tests/integration_parallel.rs (full operators,
    // cross-mode bit-equality, cyclic deadlocks). Here: queue mechanics of
    // the atomic engine — the same scenarios the condvar baseline pins in
    // exec::parallel_condvar::tests.
    use super::*;
    use crate::chunk::{DType, Region, TensorTable};
    use crate::codegen::{ExecutablePlan, RankProgram};
    use crate::exec::plan_prep::prepare;
    use crate::testutil::transfer_desc;
    use std::time::Duration;

    fn opts(timeout: Duration) -> ExecOptions {
        ExecOptions {
            mode: crate::exec::ExecMode::Parallel,
            wait_timeout: timeout,
            ..ExecOptions::parallel()
        }
    }

    #[test]
    fn forwarding_chain_completes_across_threads() {
        // rank0 -> rank1 -> rank2 forwarding chain: rank1's send depends on
        // rank0's arrival, so it parks in rank2's queue and rank2's own
        // drain must fire it once signal 0 lands.
        let mut t = TensorTable::new();
        let x = t.declare("x", &[4, 4], DType::F32).unwrap();
        let mut store = BufferStore::new(3);
        store.declare("x", &[4, 4]).unwrap();
        store.set(0, "x", &[5.0; 16]).unwrap();
        let mk = |signal: usize, src: usize, dst: usize, deps: Vec<usize>| {
            transfer_desc(x, Region::rows(0, 2, 4), signal, src, dst, deps, false)
        };
        let plan = ExecutablePlan {
            world: 3,
            per_rank: vec![
                RankProgram { ops: vec![PlanOp::Issue(mk(0, 0, 1, vec![]))] },
                // issued before its dep is met -> parked in rank2's queue
                RankProgram { ops: vec![PlanOp::Issue(mk(1, 1, 2, vec![0]))] },
                RankProgram { ops: vec![PlanOp::Wait(1)] },
            ],
            num_signals: 2,
            reserved_comm_sms: 0,
        };
        let prep = prepare(&plan, &t).unwrap();
        let rt = Runtime::host_reference();
        let stats =
            run_parallel(&prep, &store, &rt, &opts(Duration::from_secs(5)), None).unwrap();
        assert_eq!(stats.transfers, 2);
        assert_eq!(stats.waits_hit, 1);
        assert_eq!(&store.get(2, "x").unwrap()[..8], &[5.0; 8]);
    }

    #[test]
    fn deadlock_verdict_names_stuck_rank_and_pending_signal() {
        // Rank 0 waits forever on signal 1, which only rank 1's parked
        // transfer would set — and that transfer's dep (signal 0) is never
        // set either. Whichever bounded wait fires first (rank 0's Wait or
        // rank 1's final drain), the error must name WHO is stuck on WHAT:
        // a rank + op + signal, not a bare timeout.
        let mut t = TensorTable::new();
        let x = t.declare("x", &[4, 4], crate::chunk::DType::F32).unwrap();
        let mut store = BufferStore::new(2);
        store.declare("x", &[4, 4]).unwrap();
        let plan = ExecutablePlan {
            world: 2,
            per_rank: vec![
                RankProgram { ops: vec![PlanOp::Wait(1)] },
                RankProgram {
                    ops: vec![PlanOp::Issue(transfer_desc(
                        x,
                        Region::rows(0, 2, 4),
                        1,
                        1,
                        0,
                        vec![0],
                        false,
                    ))],
                },
            ],
            num_signals: 2,
            reserved_comm_sms: 0,
        };
        let prep = prepare(&plan, &t).unwrap();
        let rt = Runtime::host_reference();
        let e = run_parallel(&prep, &store, &rt, &opts(Duration::from_millis(100)), None)
            .unwrap_err()
            .to_string();
        assert!(e.contains("deadlock"), "{e}");
        assert!(e.contains("rank 0") || e.contains("sig 1"), "{e}");
        // the signal id of the blocking wait (or the parked transfer) is named
        assert!(e.contains('1'), "{e}");
    }

    #[test]
    fn servicer_verdict_lists_parked_transfers_with_unmet_deps() {
        // No rank ever blocks in a Wait: rank 0 parks a transfer whose dep
        // (signal 1) nobody sets and finishes its program. Only rank 1's
        // final drain is left holding the bag, so ITS verdict fires — and
        // must list the parked transfer's signal and its unmet dependency.
        let mut t = TensorTable::new();
        let x = t.declare("x", &[4, 4], crate::chunk::DType::F32).unwrap();
        let mut store = BufferStore::new(2);
        store.declare("x", &[4, 4]).unwrap();
        let plan = ExecutablePlan {
            world: 2,
            per_rank: vec![
                RankProgram {
                    ops: vec![PlanOp::Issue(transfer_desc(
                        x,
                        Region::rows(0, 2, 4),
                        0,
                        0,
                        1,
                        vec![1],
                        false,
                    ))],
                },
                RankProgram::default(),
            ],
            num_signals: 2,
            reserved_comm_sms: 0,
        };
        let prep = prepare(&plan, &t).unwrap();
        let rt = Runtime::host_reference();
        let e = run_parallel(&prep, &store, &rt, &opts(Duration::from_millis(100)), None)
            .unwrap_err()
            .to_string();
        assert!(e.contains("deadlock"), "{e}");
        assert!(e.contains("parked transfers"), "{e}");
        assert!(e.contains("sig 0"), "missing parked signal: {e}");
        assert!(e.contains("missing deps [1]"), "missing unmet dep list: {e}");
        assert!(e.contains("all rank programs completed"), "{e}");
    }

    #[test]
    fn arena_reuse_runs_back_to_back() {
        // the same arena drives several runs of one prepared plan; results
        // and stats must match a fresh-arena run every time
        let mut t = TensorTable::new();
        let x = t.declare("x", &[4, 4], DType::F32).unwrap();
        let mut store = BufferStore::new(2);
        store.declare("x", &[4, 4]).unwrap();
        store.set(0, "x", &[2.0; 16]).unwrap();
        let plan = ExecutablePlan {
            world: 2,
            per_rank: vec![
                RankProgram {
                    ops: vec![PlanOp::Issue(transfer_desc(
                        x,
                        Region::rows(0, 2, 4),
                        0,
                        0,
                        1,
                        vec![],
                        false,
                    ))],
                },
                RankProgram { ops: vec![PlanOp::Wait(0)] },
            ],
            num_signals: 1,
            reserved_comm_sms: 0,
        };
        let prep = prepare(&plan, &t).unwrap();
        let rt = Runtime::host_reference();
        let mut arena = PlanArena::new(&prep);
        for _ in 0..3 {
            let run_store = store.clone();
            let stats = run_parallel_in(
                &prep,
                &mut arena,
                &run_store,
                &rt,
                &opts(Duration::from_secs(5)),
                None,
            )
            .unwrap();
            assert_eq!(stats.transfers, 1);
            assert_eq!(&run_store.get(1, "x").unwrap()[..8], &[2.0; 8]);
        }
    }

    #[test]
    fn arena_world_mismatch_rejected() {
        let mut t = TensorTable::new();
        t.declare("x", &[4, 4], DType::F32).unwrap();
        let plan = ExecutablePlan {
            world: 2,
            per_rank: vec![RankProgram::default(), RankProgram::default()],
            num_signals: 0,
            reserved_comm_sms: 0,
        };
        let plan3 = ExecutablePlan {
            world: 3,
            per_rank: vec![
                RankProgram::default(),
                RankProgram::default(),
                RankProgram::default(),
            ],
            num_signals: 0,
            reserved_comm_sms: 0,
        };
        let prep2 = prepare(&plan, &t).unwrap();
        let prep3 = prepare(&plan3, &t).unwrap();
        let mut arena = PlanArena::new(&prep2);
        let mut store = BufferStore::new(3);
        store.declare("x", &[4, 4]).unwrap();
        let rt = Runtime::host_reference();
        let e = run_parallel_in(
            &prep3,
            &mut arena,
            &store,
            &rt,
            &opts(Duration::from_secs(1)),
            None,
        )
        .unwrap_err();
        assert!(e.to_string().contains("arena"), "{e}");
    }
}
