//! Host-side numeric oracles and comparison helpers.
//!
//! Pure-Rust reference math (f64 accumulation) used to verify the
//! distributed execution engines against single-device ground truth. These
//! mirror `python/compile/kernels/ref.py`; the blockwise online-softmax
//! step/finalize pair additionally mirrors the L1 Pallas kernels
//! (`python/compile/kernels/attention.py`) so the host-reference runtime
//! backend can stand in for the AOT artifacts on a bare checkout.
//!
//! Two comparison regimes:
//! * [`assert_allclose`] — tolerance-based, for checking either engine
//!   against an oracle (kernel vs reference math legitimately differ in
//!   rounding);
//! * [`assert_bit_identical`] — exact f32 bit equality, for cross-checking
//!   `ExecMode::Parallel` against `ExecMode::Sequential`, which must agree
//!   on every bit thanks to the deterministic reduction order
//!   (`exec::plan_prep`).

use crate::error::{Error, Result};

/// `C[m,n] = A[m,k] @ B[k,n]`, f64 accumulation.
pub fn host_gemm(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    assert_eq!(a.len(), m * k, "A size");
    assert_eq!(b.len(), k * n, "B size");
    let mut c = vec![0.0f32; m * n];
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0.0f64;
            for p in 0..k {
                acc += a[i * k + p] as f64 * b[p * n + j] as f64;
            }
            c[i * n + j] = acc as f32;
        }
    }
    c
}

/// tanh-GELU, matching the L1 kernel's approximation.
pub fn host_gelu(x: &mut [f32]) {
    let c = (2.0f64 / std::f64::consts::PI).sqrt();
    for v in x.iter_mut() {
        let xf = *v as f64;
        *v = (0.5 * xf * (1.0 + (c * (xf + 0.044715 * xf * xf * xf)).tanh())) as f32;
    }
}

/// Full softmax attention: `softmax(Q K^T * scale) V`.
/// Q: [sq, d], K/V: [sk, d]; returns [sq, d].
pub fn host_attention(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    sq: usize,
    sk: usize,
    d: usize,
    scale: f32,
) -> Vec<f32> {
    assert_eq!(q.len(), sq * d);
    assert_eq!(k.len(), sk * d);
    assert_eq!(v.len(), sk * d);
    let mut out = vec![0.0f32; sq * d];
    for i in 0..sq {
        // scores
        let mut s = vec![0.0f64; sk];
        let mut mx = f64::NEG_INFINITY;
        for (j, sj) in s.iter_mut().enumerate() {
            let mut acc = 0.0f64;
            for p in 0..d {
                acc += q[i * d + p] as f64 * k[j * d + p] as f64;
            }
            *sj = acc * scale as f64;
            mx = mx.max(*sj);
        }
        let mut denom = 0.0f64;
        for sj in s.iter_mut() {
            *sj = (*sj - mx).exp();
            denom += *sj;
        }
        for p in 0..d {
            let mut acc = 0.0f64;
            for j in 0..sk {
                acc += s[j] * v[j * d + p] as f64;
            }
            out[i * d + p] = (acc / denom) as f32;
        }
    }
    out
}

/// One online-softmax (flash-attention) step folding a K/V chunk into the
/// running `(acc, m, l)` state — the host twin of the Pallas `attn_step`
/// kernel. Q/acc: `[sq, d]`, K/V chunk: `[sk, d]`, m/l: `[sq]`.
/// Returns `(acc', m', l')`.
#[allow(clippy::too_many_arguments)]
pub fn host_attn_step(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    acc: &[f32],
    m: &[f32],
    l: &[f32],
    sq: usize,
    sk: usize,
    d: usize,
    scale: f32,
) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    assert_eq!(q.len(), sq * d);
    assert_eq!(k.len(), sk * d);
    assert_eq!(v.len(), sk * d);
    assert_eq!(acc.len(), sq * d);
    assert_eq!(m.len(), sq);
    assert_eq!(l.len(), sq);
    let mut acc2 = vec![0.0f32; sq * d];
    let mut m2 = vec![0.0f32; sq];
    let mut l2 = vec![0.0f32; sq];
    for i in 0..sq {
        let mut s = vec![0.0f64; sk];
        let mut m_cur = f64::NEG_INFINITY;
        for (j, sj) in s.iter_mut().enumerate() {
            let mut dot = 0.0f64;
            for p in 0..d {
                dot += q[i * d + p] as f64 * k[j * d + p] as f64;
            }
            *sj = dot * scale as f64;
            m_cur = m_cur.max(*sj);
        }
        let m_new = (m[i] as f64).max(m_cur);
        let alpha = (m[i] as f64 - m_new).exp();
        let mut p_sum = 0.0f64;
        for sj in s.iter_mut() {
            *sj = (*sj - m_new).exp();
            p_sum += *sj;
        }
        for pidx in 0..d {
            let mut pv = 0.0f64;
            for j in 0..sk {
                pv += s[j] * v[j * d + pidx] as f64;
            }
            acc2[i * d + pidx] = (acc[i * d + pidx] as f64 * alpha + pv) as f32;
        }
        m2[i] = m_new as f32;
        l2[i] = (l[i] as f64 * alpha + p_sum) as f32;
    }
    (acc2, m2, l2)
}

/// `o = acc / l` rowwise (the Pallas `attn_finalize` twin).
pub fn host_attn_finalize(acc: &[f32], l: &[f32], sq: usize, d: usize) -> Vec<f32> {
    assert_eq!(acc.len(), sq * d);
    assert_eq!(l.len(), sq);
    let mut o = vec![0.0f32; sq * d];
    for i in 0..sq {
        for p in 0..d {
            o[i * d + p] = (acc[i * d + p] as f64 / l[i] as f64) as f32;
        }
    }
    o
}

/// Fused FFN shard: `gelu(x @ w1 + b1) @ w2` (the `ffn_shard` twin).
/// x: `[m, d]`, w1: `[d, f]`, b1: `[f]`, w2: `[f, d]`.
pub fn host_ffn_shard(
    x: &[f32],
    w1: &[f32],
    b1: &[f32],
    w2: &[f32],
    m: usize,
    d: usize,
    f: usize,
) -> Vec<f32> {
    let mut h = host_gemm(x, w1, m, d, f);
    for (i, hv) in h.iter_mut().enumerate() {
        *hv += b1[i % f];
    }
    host_gelu(&mut h);
    host_gemm(&h, w2, m, f, d)
}

/// Elementwise sum of several slices.
pub fn host_sum(parts: &[&[f32]]) -> Vec<f32> {
    assert!(!parts.is_empty());
    let n = parts[0].len();
    let mut out = vec![0.0f32; n];
    for p in parts {
        assert_eq!(p.len(), n);
        for (o, x) in out.iter_mut().zip(p.iter()) {
            *o += x;
        }
    }
    out
}

/// Assert element-wise closeness with combined absolute/relative tolerance.
pub fn assert_allclose(got: &[f32], want: &[f32], rtol: f32, atol: f32, what: &str) -> Result<()> {
    if got.len() != want.len() {
        return Err(Error::Exec(format!(
            "{what}: length mismatch {} vs {}",
            got.len(),
            want.len()
        )));
    }
    let mut worst = 0.0f32;
    let mut worst_i = 0usize;
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        let tol = atol + rtol * w.abs();
        let d = (g - w).abs();
        if d > tol && d > worst {
            worst = d;
            worst_i = i;
        }
    }
    if worst > 0.0 {
        return Err(Error::Exec(format!(
            "{what}: mismatch at [{worst_i}]: got {} want {} (|d|={worst})",
            got[worst_i], want[worst_i]
        )));
    }
    Ok(())
}

/// Assert exact f32 bit equality (NaN-safe: compares bit patterns).
///
/// Used by the cross-mode verifier: `ExecMode::Parallel` must reproduce the
/// sequential reference engine's output *bits*, not just its values.
pub fn assert_bit_identical(got: &[f32], want: &[f32], what: &str) -> Result<()> {
    if got.len() != want.len() {
        return Err(Error::Exec(format!(
            "{what}: length mismatch {} vs {}",
            got.len(),
            want.len()
        )));
    }
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        if g.to_bits() != w.to_bits() {
            return Err(Error::Exec(format!(
                "{what}: bit mismatch at [{i}]: got {g} ({:#010x}) want {w} ({:#010x})",
                g.to_bits(),
                w.to_bits()
            )));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn gemm_identity() {
        let a: Vec<f32> = (0..12).map(|i| i as f32).collect(); // 3x4
        let mut eye = vec![0.0f32; 16];
        for i in 0..4 {
            eye[i * 4 + i] = 1.0;
        }
        let c = host_gemm(&a, &eye, 3, 4, 4);
        assert_eq!(c, a);
    }

    #[test]
    fn gemm_known_values() {
        // [[1,2],[3,4]] @ [[1,1],[1,1]] = [[3,3],[7,7]]
        let c = host_gemm(&[1.0, 2.0, 3.0, 4.0], &[1.0; 4], 2, 2, 2);
        assert_eq!(c, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn gemm_block_additivity() {
        // C = A1@B + A2@B row-wise concatenation (the chunk identity)
        let mut rng = Rng::new(3);
        let a = rng.vec_f32(4 * 6);
        let b = rng.vec_f32(6 * 5);
        let full = host_gemm(&a, &b, 4, 6, 5);
        let top = host_gemm(&a[..2 * 6], &b, 2, 6, 5);
        let bot = host_gemm(&a[2 * 6..], &b, 2, 6, 5);
        let mut cat = top;
        cat.extend(bot);
        assert_allclose(&cat, &full, 1e-6, 1e-6, "cat").unwrap();
    }

    #[test]
    fn attention_uniform_scores_average_v() {
        let sq = 2;
        let sk = 3;
        let d = 2;
        let q = vec![0.0f32; sq * d]; // zero queries -> uniform softmax
        let k = vec![1.0f32; sk * d];
        let v: Vec<f32> = (0..sk * d).map(|i| i as f32).collect();
        let out = host_attention(&q, &k, &v, sq, sk, d, 1.0);
        // mean of v rows: [(0+2+4)/3, (1+3+5)/3] = [2, 3]
        assert_allclose(&out, &[2.0, 3.0, 2.0, 3.0], 1e-6, 1e-6, "attn").unwrap();
    }

    #[test]
    fn attention_large_logits_stable() {
        let q = vec![30.0f32; 4];
        let k = vec![30.0f32; 4];
        let v = vec![1.0f32, 2.0, 3.0, 4.0];
        let out = host_attention(&q, &k, &v, 2, 2, 2, 1.0);
        assert!(out.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn sum_and_gelu() {
        let s = host_sum(&[&[1.0, 2.0], &[3.0, 4.0], &[5.0, 6.0]]);
        assert_eq!(s, vec![9.0, 12.0]);
        let mut x = vec![0.0f32, 100.0, -100.0];
        host_gelu(&mut x);
        assert_eq!(x[0], 0.0);
        assert!((x[1] - 100.0).abs() < 1e-3);
        assert!(x[2].abs() < 1e-3);
    }

    #[test]
    fn attn_step_chain_matches_full_attention() {
        // folding chunk-by-chunk with the online-softmax step and then
        // finalizing must reproduce full softmax attention
        let mut rng = Rng::new(77);
        let (sq, d, chunks, sk) = (4usize, 8usize, 3usize, 4usize);
        let q = rng.vec_f32(sq * d);
        let k = rng.vec_f32(chunks * sk * d);
        let v = rng.vec_f32(chunks * sk * d);
        let scale = 0.5f32;
        let mut acc = vec![0.0f32; sq * d];
        let mut m = vec![-1e30f32; sq];
        let mut l = vec![0.0f32; sq];
        for c in 0..chunks {
            let ks = &k[c * sk * d..(c + 1) * sk * d];
            let vs = &v[c * sk * d..(c + 1) * sk * d];
            let (a2, m2, l2) = host_attn_step(&q, ks, vs, &acc, &m, &l, sq, sk, d, scale);
            acc = a2;
            m = m2;
            l = l2;
        }
        let o = host_attn_finalize(&acc, &l, sq, d);
        let want = host_attention(&q, &k, &v, sq, chunks * sk, d, scale);
        assert_allclose(&o, &want, 1e-5, 1e-5, "chain").unwrap();
    }

    #[test]
    fn ffn_shard_matches_independent_scalar_reference() {
        // independent naive loops (not host_gemm/host_gelu) so composition
        // bugs in host_ffn_shard (bias layout, gelu placement, operand
        // order) cannot cancel out
        let mut rng = Rng::new(88);
        let (m, d, f) = (3usize, 4usize, 5usize);
        let x = rng.vec_f32(m * d);
        let w1 = rng.vec_f32(d * f);
        let b1 = rng.vec_f32(f);
        let w2 = rng.vec_f32(f * d);
        let got = host_ffn_shard(&x, &w1, &b1, &w2, m, d, f);
        let c = (2.0f64 / std::f64::consts::PI).sqrt();
        let mut want = vec![0.0f32; m * d];
        for i in 0..m {
            let mut g = vec![0.0f64; f];
            for (j, gj) in g.iter_mut().enumerate() {
                let mut acc = b1[j] as f64;
                for p in 0..d {
                    acc += x[i * d + p] as f64 * w1[p * f + j] as f64;
                }
                // tanh-GELU, written out once more from the formula
                *gj = 0.5 * acc * (1.0 + (c * (acc + 0.044715 * acc * acc * acc)).tanh());
            }
            for q in 0..d {
                let mut acc = 0.0f64;
                for (j, gj) in g.iter().enumerate() {
                    acc += gj * w2[j * d + q] as f64;
                }
                want[i * d + q] = acc as f32;
            }
        }
        assert_allclose(&got, &want, 1e-5, 1e-5, "ffn vs scalar reference").unwrap();
    }

    #[test]
    fn bit_identical_is_exact() {
        assert!(assert_bit_identical(&[1.0, -0.0], &[1.0, -0.0], "ok").is_ok());
        // -0.0 and 0.0 compare equal numerically but differ bitwise
        let e = assert_bit_identical(&[0.0], &[-0.0], "signed zero").unwrap_err();
        assert!(e.to_string().contains("bit mismatch"), "{e}");
        assert!(assert_bit_identical(&[1.0], &[1.0, 2.0], "len").is_err());
        // NaN equals itself bitwise
        assert!(assert_bit_identical(&[f32::NAN], &[f32::NAN], "nan").is_ok());
    }

    #[test]
    fn allclose_reports_worst() {
        assert!(assert_allclose(&[1.0, 2.0], &[1.0, 2.0], 1e-6, 1e-6, "ok").is_ok());
        let e = assert_allclose(&[1.0, 9.0], &[1.0, 2.0], 1e-3, 1e-3, "bad").unwrap_err();
        assert!(e.to_string().contains("[1]"), "{e}");
        assert!(assert_allclose(&[1.0], &[1.0, 2.0], 1e-3, 1e-3, "len").is_err());
    }
}
