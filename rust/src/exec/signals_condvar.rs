//! The retained `Mutex + Condvar` signal board — the pre-atomic baseline.
//!
//! This is the synchronization core the parallel engine shipped with
//! before the lock-free rework (DESIGN.md §15): every `set`/`wait`/`touch`
//! funnels through one mutex and wakes every waiter via `notify_all`. It
//! is kept compilable and selectable (`--sync condvar`,
//! [`crate::exec::SyncStrategy::Condvar`]) for exactly one reason: the
//! hotpath bench compares the atomic engine against this baseline
//! like-for-like, on the same prepared plans, in the same process. Do not
//! grow it; behavioral fixes land in [`crate::exec::signals`] first and
//! are backported only if the bench comparison would otherwise be unfair.
//!
//! Semantics (shared with the atomic board): signal sets are monotonic
//! within a run, every state change bumps an *epoch* counter, and bounded
//! waits declare deadlock only after `timeout` passes with no epoch
//! movement and no busy work in flight.

use std::sync::{Condvar, Mutex};
use std::time::Duration;

use crate::error::{Error, Result};

#[derive(Debug)]
struct BoardState {
    set: Vec<bool>,
    /// Bumped on every `set`, `touch`, `abort`, or `busy_end`; the
    /// progress heartbeat.
    epoch: u64,
    /// Threads currently inside work the board can't see (kernel calls,
    /// transfer applies). While nonzero, bounded waits never declare
    /// deadlock. Transitions happen under the board lock, so a waiter
    /// evaluating its timeout atomically sees either `busy > 0` or the
    /// epoch bump from `busy_end` — there is no misdiagnosis window.
    busy: usize,
    aborted: bool,
}

/// Condvar-backed monotonic signal table shared by all rank threads.
#[derive(Debug)]
pub struct CondvarSignalBoard {
    state: Mutex<BoardState>,
    cv: Condvar,
}

impl CondvarSignalBoard {
    pub fn new(num_signals: usize) -> Self {
        CondvarSignalBoard {
            state: Mutex::new(BoardState {
                set: vec![false; num_signals],
                epoch: 0,
                busy: 0,
                aborted: false,
            }),
            cv: Condvar::new(),
        }
    }

    pub fn len(&self) -> usize {
        self.state.lock().unwrap().set.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Set a signal and wake all waiters.
    pub fn set(&self, id: usize) {
        let mut st = self.state.lock().unwrap();
        st.set[id] = true;
        st.epoch += 1;
        drop(st);
        self.cv.notify_all();
    }

    /// Record activity without setting a signal (pending-queue pushes, rank
    /// completion) so bounded waits see the run is still live.
    pub fn touch(&self) {
        let mut st = self.state.lock().unwrap();
        st.epoch += 1;
        drop(st);
        self.cv.notify_all();
    }

    /// Mark the start of work the board can't otherwise see (a kernel
    /// call, a transfer apply). Bounded waits defer the deadlock verdict
    /// while any such work is in flight.
    pub fn busy_begin(&self) {
        let mut st = self.state.lock().unwrap();
        st.busy += 1;
    }

    /// End of [`CondvarSignalBoard::busy_begin`]'s work; counts as
    /// activity. An end without a matching begin is a caller bug: loudly
    /// asserted in debug builds, clamped at zero in release (same policy
    /// as the atomic board — see `SignalBoard::busy_end`).
    pub fn busy_end(&self) {
        let mut st = self.state.lock().unwrap();
        debug_assert!(st.busy > 0, "busy_end without matching busy_begin");
        st.busy = st.busy.saturating_sub(1);
        st.epoch += 1;
        drop(st);
        self.cv.notify_all();
    }

    /// Tell every waiter to give up (another thread hit an error).
    pub fn abort(&self) {
        let mut st = self.state.lock().unwrap();
        st.aborted = true;
        st.epoch += 1;
        drop(st);
        self.cv.notify_all();
    }

    pub fn aborted(&self) -> bool {
        self.state.lock().unwrap().aborted
    }

    pub fn is_set(&self, id: usize) -> bool {
        self.state.lock().unwrap().set[id]
    }

    pub fn all_set(&self, ids: &[usize]) -> bool {
        let st = self.state.lock().unwrap();
        ids.iter().all(|&i| st.set[i])
    }

    /// The subset of `ids` not yet set — what a stuck waiter is actually
    /// missing. Deadlock verdicts use this to name the pending signals
    /// instead of reporting a bare timeout.
    pub fn unmet(&self, ids: &[usize]) -> Vec<usize> {
        let st = self.state.lock().unwrap();
        ids.iter().copied().filter(|&i| !st.set[i]).collect()
    }

    /// Current epoch; pair with [`CondvarSignalBoard::wait_activity_since`].
    pub fn epoch(&self) -> u64 {
        self.state.lock().unwrap().epoch
    }

    /// Block until every signal in `ids` is set.
    ///
    /// Errors if the run is aborted, or if `timeout` elapses with no board
    /// activity at all and no busy work in flight (the bounded-wait
    /// deadlock verdict — see [`CondvarSignalBoard::busy_begin`]); slow
    /// kernel calls are never misdiagnosed as deadlocks. `what` labels the
    /// error with the waiter's identity.
    pub fn wait_all(
        &self,
        ids: &[usize],
        timeout: Duration,
        what: impl Fn() -> String,
    ) -> Result<()> {
        let mut st = self.state.lock().unwrap();
        loop {
            if st.aborted {
                return Err(Error::Exec(format!("aborted while waiting: {}", what())));
            }
            if ids.iter().all(|&i| st.set[i]) {
                return Ok(());
            }
            let epoch = st.epoch;
            let (guard, res) = self.cv.wait_timeout(st, timeout).unwrap();
            st = guard;
            if res.timed_out() && st.epoch == epoch && st.busy == 0 {
                let missing: Vec<usize> =
                    ids.iter().copied().filter(|&i| !st.set[i]).collect();
                return Err(Error::Exec(format!(
                    "deadlock: bounded wait ({timeout:?}) expired with no progress; \
                     {} still waiting on signals {missing:?}",
                    what()
                )));
            }
        }
    }

    /// Block until the board's epoch moves past `since` (any activity).
    ///
    /// Returns `Ok(true)` on activity, `Ok(false)` if aborted, and the
    /// deadlock error if `timeout` elapses with the epoch unchanged and
    /// no busy work in flight (see [`CondvarSignalBoard::busy_begin`]).
    pub fn wait_activity_since(
        &self,
        since: u64,
        timeout: Duration,
        what: impl Fn() -> String,
    ) -> Result<bool> {
        let mut st = self.state.lock().unwrap();
        loop {
            if st.aborted {
                return Ok(false);
            }
            if st.epoch != since {
                return Ok(true);
            }
            let (guard, res) = self.cv.wait_timeout(st, timeout).unwrap();
            st = guard;
            if res.timed_out() && st.epoch == since && st.busy == 0 {
                return Err(Error::Exec(format!(
                    "deadlock: bounded wait ({timeout:?}) expired with no progress; {}",
                    what()
                )));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Instant;

    #[test]
    fn set_and_query() {
        let b = CondvarSignalBoard::new(3);
        assert_eq!(b.len(), 3);
        assert!(!b.is_set(0));
        b.set(0);
        b.set(2);
        assert!(b.is_set(0));
        assert!(b.all_set(&[0, 2]));
        assert!(!b.all_set(&[0, 1]));
        assert!(b.all_set(&[]));
        assert_eq!(b.unmet(&[0, 1, 2]), vec![1]);
        assert!(b.unmet(&[]).is_empty());
    }

    #[test]
    fn wait_all_returns_once_set() {
        let b = CondvarSignalBoard::new(2);
        std::thread::scope(|s| {
            s.spawn(|| {
                std::thread::sleep(Duration::from_millis(20));
                b.set(0);
                b.set(1);
            });
            b.wait_all(&[0, 1], Duration::from_secs(5), || "test".into()).unwrap();
        });
        assert!(b.all_set(&[0, 1]));
    }

    #[test]
    fn bounded_wait_reports_deadlock() {
        let b = CondvarSignalBoard::new(2);
        let t0 = Instant::now();
        let e = b
            .wait_all(&[1], Duration::from_millis(50), || "rank 0 at op 3".into())
            .unwrap_err();
        assert!(t0.elapsed() < Duration::from_secs(5));
        assert!(e.to_string().contains("deadlock"), "{e}");
        assert!(e.to_string().contains("rank 0 at op 3"), "{e}");
    }

    #[test]
    fn activity_resets_the_bound() {
        // a live-but-slow producer must not trip the deadlock verdict; the
        // producer-step vs bound ratio is kept wide (5ms vs 500ms) so
        // loaded CI runners cannot misschedule their way into flaking
        let b = CondvarSignalBoard::new(8);
        std::thread::scope(|s| {
            s.spawn(|| {
                for i in 0..8 {
                    std::thread::sleep(Duration::from_millis(5));
                    b.set(i);
                }
            });
            b.wait_all(&[7], Duration::from_millis(500), || "waiter".into()).unwrap();
        });
    }

    #[test]
    fn busy_work_defers_the_verdict() {
        // a waiter whose bound expires while busy work is in flight (a
        // rank inside a long kernel call) must keep waiting, and succeed
        // when the signal finally lands after the "call" finishes
        let b = CondvarSignalBoard::new(1);
        b.busy_begin();
        std::thread::scope(|s| {
            s.spawn(|| {
                // "kernel call" far longer than the 20ms bound
                std::thread::sleep(Duration::from_millis(200));
                b.busy_end();
                b.set(0);
            });
            b.wait_all(&[0], Duration::from_millis(20), || "waiter".into()).unwrap();
        });
    }

    #[test]
    fn abort_wakes_waiters() {
        let b = CondvarSignalBoard::new(1);
        std::thread::scope(|s| {
            s.spawn(|| {
                std::thread::sleep(Duration::from_millis(10));
                b.abort();
            });
            let e = b
                .wait_all(&[0], Duration::from_secs(30), || "waiter".into())
                .unwrap_err();
            assert!(e.to_string().contains("abort"), "{e}");
        });
        assert!(b.aborted());
    }

    #[test]
    fn wait_activity_since_sees_touch() {
        let b = CondvarSignalBoard::new(1);
        let e0 = b.epoch();
        b.touch();
        assert!(b.wait_activity_since(e0, Duration::from_millis(10), || "x".into()).unwrap());
        let e1 = b.epoch();
        let err = b.wait_activity_since(e1, Duration::from_millis(30), || "idle".into());
        assert!(err.is_err());
    }
}
