//! The retained condvar-based parallel engine — the pre-atomic baseline.
//!
//! This is the parallel engine as it shipped before the lock-free rework
//! (DESIGN.md §15): one worker thread per rank over a
//! [`CondvarSignalBoard`], with transfers whose dependencies are unmet
//! parked in a single global pending pool drained by a dedicated
//! transfer-servicer loop on the caller's thread. It is kept selectable
//! ([`crate::exec::SyncStrategy::Condvar`], `--sync condvar`) so the
//! hotpath bench can compare the atomic engine against this baseline
//! like-for-like; see [`crate::exec::parallel`] for the production
//! engine and the rationale for each structural difference (per-rank
//! queues instead of the global pool, targeted parking instead of
//! `notify_all`, arena state instead of per-run allocation).
//!
//! Semantics are identical to the atomic engine: same deterministic
//! reduction order (the plan arrives pre-augmented by
//! [`super::plan_prep::prepare`]), same bounded-wait deadlock policy,
//! same verdict message shapes. Bit-identity across all three engines is
//! asserted per registry case in `tests/integration_parallel.rs`.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::codegen::{PlanOp, TransferDesc};
use crate::error::{Error, Result};
use crate::exec::buffers::BufferStore;
use crate::exec::engine::{apply_transfer_sunk, exec_call_sunk, push_seg_event, ExecStats};
use crate::exec::plan_prep::PreparedPlan;
use crate::exec::signals_condvar::CondvarSignalBoard;
use crate::exec::ExecOptions;
use crate::runtime::Runtime;
use crate::trace::{TraceEvent, TraceKind, TraceSink};

/// `rank_pc` value meaning "this rank's program completed".
const RANK_DONE: usize = usize::MAX;

struct Shared<'p> {
    prep: &'p PreparedPlan,
    board: CondvarSignalBoard,
    /// Issued transfers whose dependency signals were not yet met.
    pending: Mutex<Vec<TransferDesc>>,
    ranks_active: AtomicUsize,
    /// Each rank's current op index ([`RANK_DONE`] once finished) — read
    /// only by the deadlock verdict, so stuck ranks are named with the op
    /// they are parked on. Relaxed stores: a stale-by-one read only makes
    /// an error message stale-by-one.
    rank_pc: Vec<AtomicUsize>,
    stats: Mutex<ExecStats>,
    fail: Mutex<Option<Error>>,
    /// Event sink when the run is traced; `None` leaves the hot path with
    /// a dead branch per op.
    sink: Option<&'p TraceSink>,
}

impl Shared<'_> {
    /// Apply a transfer with the board's busy marker held, so bounded
    /// waiters elsewhere treat a long region copy as progress, not
    /// deadlock (the marker transitions under the board lock — no
    /// misdiagnosis window).
    fn apply_busy(&self, d: &TransferDesc, store: &BufferStore) -> Result<usize> {
        self.board.busy_begin();
        let r = apply_transfer_sunk(self.prep, d, store, self.sink);
        self.board.busy_end();
        r
    }

    /// Where every unfinished rank is stuck, for deadlock verdicts.
    fn stuck_ranks(&self) -> Vec<String> {
        (0..self.prep.plan.world)
            .filter_map(|r| {
                let pc = self.rank_pc[r].load(Ordering::Relaxed);
                if pc == RANK_DONE {
                    return None;
                }
                let op = self.prep.plan.per_rank[r]
                    .ops
                    .get(pc)
                    .map(|o| o.brief())
                    .unwrap_or_else(|| "<end>".into());
                Some(format!("rank {r} at op {pc} ({op})"))
            })
            .collect()
    }

    /// Record the first failure and wake every waiter.
    fn record_fail(&self, e: Error) {
        {
            let mut f = self.fail.lock().unwrap();
            if f.is_none() {
                *f = Some(e);
            }
        }
        self.board.abort();
    }
}

pub(crate) fn run_parallel_condvar(
    prep: &PreparedPlan,
    store: &BufferStore,
    runtime: &Runtime,
    opts: &ExecOptions,
    sink: Option<&TraceSink>,
) -> Result<ExecStats> {
    let world = prep.plan.world;
    let shared = Shared {
        prep,
        board: CondvarSignalBoard::new(prep.plan.num_signals),
        pending: Mutex::new(Vec::new()),
        ranks_active: AtomicUsize::new(world),
        rank_pc: (0..world).map(|_| AtomicUsize::new(0)).collect(),
        stats: Mutex::new(ExecStats::default()),
        fail: Mutex::new(None),
        sink,
    };

    // rank threads inherit the spawning thread's request scope so their
    // flight events carry the request ID being served
    let req = crate::obs::flight::current_request();
    std::thread::scope(|scope| {
        for rank in 0..world {
            let shared = &shared;
            scope.spawn(move || {
                crate::obs::flight::set_request(req);
                crate::obs::flight::enter_rank(rank);
                match rank_body(shared, rank, store, runtime, opts) {
                    Ok(local) => {
                        shared.rank_pc[rank].store(RANK_DONE, Ordering::Relaxed);
                        shared.stats.lock().unwrap().merge(&local);
                    }
                    Err(e) => shared.record_fail(e),
                }
                shared.ranks_active.fetch_sub(1, Ordering::SeqCst);
                shared.board.touch();
            });
        }
        // The caller's thread services parked transfers until all ranks
        // finish and the pool drains (or the run fails).
        servicer(&shared, store, opts);
    });

    if let Some(e) = shared.fail.lock().unwrap().take() {
        return Err(e);
    }
    Ok(shared.stats.into_inner().unwrap())
}

/// Interpret one rank's program on its own thread.
fn rank_body(
    shared: &Shared<'_>,
    rank: usize,
    store: &BufferStore,
    runtime: &Runtime,
    opts: &ExecOptions,
) -> Result<ExecStats> {
    let prog = &shared.prep.plan.per_rank[rank];
    let mut local = ExecStats::default();
    for (op_index, op) in prog.ops.iter().enumerate() {
        shared.rank_pc[rank].store(op_index, Ordering::Relaxed);
        if shared.board.aborted() {
            // another thread already recorded the real error
            return Err(Error::Exec(format!("rank {rank}: run aborted")));
        }
        match op {
            PlanOp::Overhead { .. } => {}
            PlanOp::Wait(sig) => {
                crate::obs::flight::signal_wait(rank, op_index, *sig);
                let t0 = shared.sink.map(|s| s.now_us());
                shared.board.wait_all(&[*sig], opts.wait_timeout, || {
                    format!("rank {rank} at op {op_index} (Wait(sig {sig}))")
                })?;
                if let (Some(s), Some(t0)) = (shared.sink, t0) {
                    s.push(TraceEvent {
                        start_us: t0,
                        end_us: s.now_us(),
                        kind: TraceKind::Wait { rank, op: op_index, signal: *sig },
                    });
                }
                local.waits_hit += 1;
            }
            PlanOp::Issue(d) => {
                crate::obs::flight::op_issue(rank, op_index);
                if shared.board.all_set(&d.dep_signals) {
                    let bytes = shared.apply_busy(d, store)?;
                    local.transfers += 1;
                    local.bytes_moved += bytes;
                    shared.board.set(d.signal);
                    crate::obs::flight::op_apply(rank, op_index, d.signal);
                } else {
                    // asynchronous issue: park it and move on
                    shared.pending.lock().unwrap().push(d.clone());
                    shared.board.touch();
                }
            }
            PlanOp::Compute(seg) => {
                let seg_start = shared.sink.map(|s| s.now_us());
                for (ci, call) in seg.calls.iter().enumerate() {
                    // mark the call busy so bounded waiters elsewhere
                    // treat this rank as live, however long the kernel runs
                    shared.board.busy_begin();
                    let result =
                        exec_call_sunk(call, rank, op_index, ci, store, runtime, shared.sink);
                    shared.board.busy_end();
                    result?;
                    local.compute_calls += 1;
                    if let Some(&ps) = shared.prep.call_signals.get(&(rank, op_index, ci)) {
                        shared.board.set(ps);
                    }
                }
                if let (Some(s), Some(t0)) = (shared.sink, seg_start) {
                    if !seg.calls.is_empty() {
                        push_seg_event(s, rank, op_index, seg, t0, s.now_us());
                    }
                }
            }
        }
    }
    Ok(local)
}

/// Drain parked transfers as their dependencies resolve; detect deadlock.
fn servicer(shared: &Shared<'_>, store: &BufferStore, opts: &ExecOptions) {
    loop {
        if shared.board.aborted() {
            return;
        }
        // Epoch snapshot BEFORE the readiness check: any signal set between
        // the check and the wait bumps the epoch and the wait returns
        // immediately — no lost wakeups.
        let epoch = shared.board.epoch();

        let ready: Vec<TransferDesc> = {
            let mut q = shared.pending.lock().unwrap();
            let mut ready = Vec::new();
            let mut keep = Vec::new();
            for d in q.drain(..) {
                if shared.board.all_set(&d.dep_signals) {
                    ready.push(d);
                } else {
                    keep.push(d);
                }
            }
            *q = keep;
            ready
        };
        let made_progress = !ready.is_empty();
        for d in &ready {
            match shared.apply_busy(d, store) {
                Ok(bytes) => {
                    {
                        let mut st = shared.stats.lock().unwrap();
                        st.transfers += 1;
                        st.bytes_moved += bytes;
                    }
                    shared.board.set(d.signal);
                }
                Err(e) => {
                    shared.record_fail(e);
                    return;
                }
            }
        }

        let ranks_left = shared.ranks_active.load(Ordering::SeqCst);
        let pending_left = shared.pending.lock().unwrap().len();
        if ranks_left == 0 && pending_left == 0 {
            return;
        }
        if made_progress {
            continue; // re-check before sleeping
        }

        let msg = format!(
            "transfer servicer: {pending_left} parked transfers, {ranks_left} ranks active"
        );
        match shared.board.wait_activity_since(epoch, opts.wait_timeout, || msg.clone()) {
            Ok(true) => continue,   // activity — re-scan
            Ok(false) => return,    // aborted elsewhere
            Err(e) => {
                // Bounded wait expired with no progress: deadlock verdict,
                // enriched with WHO is stuck WHERE — each unfinished
                // rank's current op, and each parked transfer's unmet
                // dependency signals — instead of a bare timeout.
                let parked: Vec<String> = shared
                    .pending
                    .lock()
                    .unwrap()
                    .iter()
                    .map(|d| {
                        format!(
                            "sig {} ({}->{}) missing deps {:?}",
                            d.signal,
                            d.src_rank,
                            d.dst_rank,
                            shared.board.unmet(&d.dep_signals)
                        )
                    })
                    .collect();
                let stuck = shared.stuck_ranks();
                let stuck_idx: Vec<usize> = (0..shared.prep.plan.world)
                    .filter(|&r| shared.rank_pc[r].load(Ordering::Relaxed) != RANK_DONE)
                    .collect();
                let ctx_ranks: Vec<usize> = if stuck_idx.is_empty() {
                    (0..shared.prep.plan.world).collect()
                } else {
                    stuck_idx
                };
                // last-K flight events per stuck rank ride on the verdict;
                // error_total{kind=deadlock} is counted once on the shared
                // path in engine::note_deadlock
                let ctx = crate::obs::flight::verdict_context(&ctx_ranks, 8);
                let stuck = if stuck.is_empty() {
                    "none (all rank programs completed)".to_string()
                } else {
                    stuck.join("; ")
                };
                shared.record_fail(Error::Exec(format!(
                    "{e}; stuck ranks: {stuck}; parked transfers: [{}]{ctx}",
                    parked.join(", ")
                )));
                return;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    // Pool mechanics of the retained baseline engine; the same scenarios
    // run against the atomic engine in exec::parallel::tests, and both
    // verdict shapes are re-asserted per sync strategy in
    // tests/integration_parallel.rs.
    use super::*;
    use crate::chunk::{DType, Region, TensorTable};
    use crate::codegen::{ExecutablePlan, RankProgram};
    use crate::exec::plan_prep::prepare;
    use crate::testutil::transfer_desc;
    use std::time::Duration;

    fn opts(timeout: Duration) -> ExecOptions {
        ExecOptions {
            mode: crate::exec::ExecMode::Parallel,
            wait_timeout: timeout,
            sync: crate::exec::SyncStrategy::Condvar,
            ..ExecOptions::parallel()
        }
    }

    #[test]
    fn forwarding_chain_completes_across_threads() {
        // rank0 -> rank1 -> rank2 forwarding chain: rank1's send depends on
        // rank0's arrival, so it parks in the pending pool and the servicer
        // must fire it once signal 0 lands.
        let mut t = TensorTable::new();
        let x = t.declare("x", &[4, 4], DType::F32).unwrap();
        let mut store = BufferStore::new(3);
        store.declare("x", &[4, 4]).unwrap();
        store.set(0, "x", &[5.0; 16]).unwrap();
        let mk = |signal: usize, src: usize, dst: usize, deps: Vec<usize>| {
            transfer_desc(x, Region::rows(0, 2, 4), signal, src, dst, deps, false)
        };
        let plan = ExecutablePlan {
            world: 3,
            per_rank: vec![
                RankProgram { ops: vec![PlanOp::Issue(mk(0, 0, 1, vec![]))] },
                // issued before its dep is met -> parked
                RankProgram { ops: vec![PlanOp::Issue(mk(1, 1, 2, vec![0]))] },
                RankProgram { ops: vec![PlanOp::Wait(1)] },
            ],
            num_signals: 2,
            reserved_comm_sms: 0,
        };
        let prep = prepare(&plan, &t).unwrap();
        let rt = Runtime::host_reference();
        let stats =
            run_parallel_condvar(&prep, &store, &rt, &opts(Duration::from_secs(5)), None)
                .unwrap();
        assert_eq!(stats.transfers, 2);
        assert_eq!(stats.waits_hit, 1);
        assert_eq!(&store.get(2, "x").unwrap()[..8], &[5.0; 8]);
    }

    #[test]
    fn deadlock_verdict_names_stuck_rank_and_pending_signal() {
        // Rank 0 waits forever on signal 1, which only rank 1's parked
        // transfer would set — and that transfer's dep (signal 0) is never
        // set either. Whichever bounded wait fires first (the rank's
        // wait_all or the servicer), the error must name WHO is stuck on
        // WHAT: a rank + op + signal, not a bare timeout.
        let mut t = TensorTable::new();
        let x = t.declare("x", &[4, 4], crate::chunk::DType::F32).unwrap();
        let mut store = BufferStore::new(2);
        store.declare("x", &[4, 4]).unwrap();
        let plan = ExecutablePlan {
            world: 2,
            per_rank: vec![
                RankProgram { ops: vec![PlanOp::Wait(1)] },
                RankProgram {
                    ops: vec![PlanOp::Issue(transfer_desc(
                        x,
                        Region::rows(0, 2, 4),
                        1,
                        1,
                        0,
                        vec![0],
                        false,
                    ))],
                },
            ],
            num_signals: 2,
            reserved_comm_sms: 0,
        };
        let prep = prepare(&plan, &t).unwrap();
        let rt = Runtime::host_reference();
        let e = run_parallel_condvar(&prep, &store, &rt, &opts(Duration::from_millis(100)), None)
            .unwrap_err()
            .to_string();
        assert!(e.contains("deadlock"), "{e}");
        assert!(e.contains("rank 0") || e.contains("sig 1"), "{e}");
        // the signal id of the blocking wait (or the parked transfer) is named
        assert!(e.contains('1'), "{e}");
    }

    #[test]
    fn servicer_verdict_lists_parked_transfers_with_unmet_deps() {
        // No rank ever blocks: rank 0 parks a transfer whose dep (signal
        // 1) nobody sets and finishes its program. Only the servicer is
        // left holding the bag, so ITS verdict fires — and must list the
        // parked transfer's signal and its unmet dependency.
        let mut t = TensorTable::new();
        let x = t.declare("x", &[4, 4], crate::chunk::DType::F32).unwrap();
        let mut store = BufferStore::new(2);
        store.declare("x", &[4, 4]).unwrap();
        let plan = ExecutablePlan {
            world: 2,
            per_rank: vec![
                RankProgram {
                    ops: vec![PlanOp::Issue(transfer_desc(
                        x,
                        Region::rows(0, 2, 4),
                        0,
                        0,
                        1,
                        vec![1],
                        false,
                    ))],
                },
                RankProgram::default(),
            ],
            num_signals: 2,
            reserved_comm_sms: 0,
        };
        let prep = prepare(&plan, &t).unwrap();
        let rt = Runtime::host_reference();
        let e = run_parallel_condvar(&prep, &store, &rt, &opts(Duration::from_millis(100)), None)
            .unwrap_err()
            .to_string();
        assert!(e.contains("deadlock"), "{e}");
        assert!(e.contains("parked transfers"), "{e}");
        assert!(e.contains("sig 0"), "missing parked signal: {e}");
        assert!(e.contains("missing deps [1]"), "missing unmet dep list: {e}");
        assert!(e.contains("all rank programs completed"), "{e}");
    }
}
