//! Lock-free atomic signal table for the parallel per-rank executor.
//!
//! A [`SignalBoard`] is the synchronization core shared by all rank
//! threads: one `AtomicU32` word per signal, an atomic epoch heartbeat,
//! an atomic busy counter, and a small parking lot for blocked threads.
//! Signal sets are monotonic (a signal, once set, never clears within a
//! run), which buys two things the old `Mutex + Condvar` board could not
//! offer (see [`crate::exec::signals_condvar`] for the retained baseline):
//!
//! * **Uncontended reads.** `is_set`/`all_set`/`unmet` are plain atomic
//!   loads — no lock word is touched, and rank threads layer a
//!   [`SeenSignals`] cache on top so re-checks of already-observed signals
//!   never even touch shared cache lines.
//! * **Targeted wakeups.** A blocked thread registers *what* it is waiting
//!   for ([`Interest`]) and parks; `set(id)` unparks only the threads
//!   interested in `id` (plus any-activity waiters) instead of
//!   `notify_all`-ing the world. Producers skip the parking lot entirely
//!   when nobody is parked (a single atomic load).
//!
//! # Memory ordering
//!
//! All hot-path atomics use `SeqCst`. Release/acquire is the *minimum*
//! the design needs — the publishing store in [`SignalBoard::set`] must
//! happen-after the buffer writes it announces, and a reader observing
//! the word must see those writes — but the wakeup protocol additionally
//! needs a store-load fence (Dekker-style): a producer stores the signal
//! word and then loads the parked count, while a waiter registers in the
//! parking lot and then re-checks the signal. `SeqCst` on both sides
//! guarantees at least one of them sees the other — either the producer
//! observes `nparked > 0` and walks the lot, or the waiter's re-check
//! sees the fresh signal and never sleeps. Plain release/acquire permits
//! both loads to miss, i.e. a lost wakeup. The signal words themselves
//! would be correct with `Release`-store/`Acquire`-load; they share the
//! `SeqCst` spelling so every ordering in this file means one thing.
//!
//! # Bounded-wait deadlock detection without a condvar
//!
//! The epoch counter is bumped by every `set`, `touch`, `abort`, and
//! `busy_end`. A bounded waiter snapshots the epoch, parks with a
//! deadline, and on expiry declares deadlock only if the epoch is still
//! at the snapshot *and* the busy counter is zero. `busy_end` bumps the
//! epoch *before* decrementing the counter, and the waiter reads busy
//! *before* epoch, so across any completed busy window the waiter
//! observes either `busy > 0` or a moved epoch — the condvar board got
//! this atomicity from its lock; here it falls out of the two orderings.

use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, AtomicUsize, Ordering::SeqCst};
use std::sync::Mutex;
use std::thread::Thread;
use std::time::{Duration, Instant};

use crate::error::{Error, Result};

/// What a parked thread must be woken for.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Interest {
    /// Wake when this one signal is set (the common Wait-op case).
    Signal(usize),
    /// Wake on any board activity — used by threads whose wake condition
    /// spans many signals (e.g. a rank with parked inbound transfers whose
    /// dep signals can be set by anyone).
    Any,
}

#[derive(Debug)]
struct Parker {
    thread: Thread,
    interest: Interest,
}

/// Atomic monotonic signal table shared by all rank threads.
#[derive(Debug)]
pub struct SignalBoard {
    /// One word per signal: 0 = unset, 1 = set. Monotonic within a run.
    words: Box<[AtomicU32]>,
    /// Bumped on every `set`, `touch`, `abort`, or `busy_end`; the
    /// progress heartbeat bounded waits measure against.
    epoch: AtomicU64,
    /// Threads currently inside work the board can't see (kernel calls,
    /// transfer applies). While nonzero, bounded waits never declare
    /// deadlock.
    busy: AtomicUsize,
    aborted: AtomicBool,
    /// Mirror of `parked.len()`, maintained under the `parked` lock.
    /// Producers load this first and skip the lock when it reads 0 — the
    /// no-waiters fast path. See the module doc for why this load and the
    /// signal store must both be `SeqCst`.
    nparked: AtomicUsize,
    /// The parking lot: registered blocked threads. Only touched on the
    /// slow path (a thread about to sleep, or a producer that saw
    /// `nparked > 0`).
    parked: Mutex<Vec<Parker>>,
}

impl SignalBoard {
    pub fn new(num_signals: usize) -> Self {
        let words: Vec<AtomicU32> = (0..num_signals).map(|_| AtomicU32::new(0)).collect();
        SignalBoard {
            words: words.into_boxed_slice(),
            epoch: AtomicU64::new(0),
            busy: AtomicUsize::new(0),
            aborted: AtomicBool::new(false),
            nparked: AtomicUsize::new(0),
            // worst case every rank thread parks at once; a small
            // preallocation keeps the slow path allocation-free too
            parked: Mutex::new(Vec::with_capacity(16)),
        }
    }

    /// Clear all run state for plan reuse (arena resets between runs).
    /// Takes `&mut self`, so no thread can still be waiting.
    pub fn reset(&mut self) {
        for w in self.words.iter() {
            w.store(0, SeqCst);
        }
        self.epoch.store(0, SeqCst);
        self.busy.store(0, SeqCst);
        self.aborted.store(false, SeqCst);
        self.parked.get_mut().unwrap().clear();
        self.nparked.store(0, SeqCst);
    }

    pub fn len(&self) -> usize {
        self.words.len()
    }

    pub fn is_empty(&self) -> bool {
        self.words.is_empty()
    }

    /// Set a signal and wake the threads waiting for it (targeted — other
    /// parked threads stay parked).
    pub fn set(&self, id: usize) {
        self.words[id].store(1, SeqCst);
        self.epoch.fetch_add(1, SeqCst);
        crate::obs::flight::signal_set(id);
        self.wake(Some(id));
    }

    /// Record activity without setting a signal (queue pushes, rank
    /// completion) so bounded waits see the run is still live. Wakes only
    /// any-activity waiters; signal-targeted parkers have, by definition,
    /// nothing new to look at.
    pub fn touch(&self) {
        self.epoch.fetch_add(1, SeqCst);
        self.wake(None);
    }

    /// Mark the start of work the board can't otherwise see (a kernel
    /// call, a transfer apply). Bounded waits defer the deadlock verdict
    /// while any such work is in flight.
    pub fn busy_begin(&self) {
        self.busy.fetch_add(1, SeqCst);
    }

    /// End of [`SignalBoard::busy_begin`]'s work; counts as activity.
    ///
    /// The epoch bump precedes the decrement on purpose: a bounded waiter
    /// reads busy first, then epoch, so across any completed busy window
    /// it sees either the in-flight count or the bump — never a false
    /// "idle and quiet" verdict. An end without a matching begin is a
    /// caller bug: loudly asserted in debug builds, clamped at zero in
    /// release so a production run degrades to the old masking behavior
    /// instead of wrapping the counter to `usize::MAX` (which would
    /// suppress deadlock detection forever).
    pub fn busy_end(&self) {
        self.epoch.fetch_add(1, SeqCst);
        let prev = self.busy.fetch_sub(1, SeqCst);
        debug_assert!(prev > 0, "busy_end without matching busy_begin");
        if prev == 0 {
            self.busy.store(0, SeqCst);
        }
        self.wake(None);
    }

    /// Tell every waiter to give up (another thread hit an error).
    pub fn abort(&self) {
        self.aborted.store(true, SeqCst);
        self.epoch.fetch_add(1, SeqCst);
        self.wake_all();
    }

    pub fn aborted(&self) -> bool {
        self.aborted.load(SeqCst)
    }

    pub fn is_set(&self, id: usize) -> bool {
        self.words[id].load(SeqCst) != 0
    }

    pub fn all_set(&self, ids: &[usize]) -> bool {
        ids.iter().all(|&i| self.is_set(i))
    }

    /// The subset of `ids` not yet set — what a stuck waiter is actually
    /// missing. Deadlock verdicts use this to name the pending signals
    /// instead of reporting a bare timeout.
    pub fn unmet(&self, ids: &[usize]) -> Vec<usize> {
        ids.iter().copied().filter(|&i| !self.is_set(i)).collect()
    }

    /// Current epoch; pair with [`SignalBoard::wait_activity_since`] or an
    /// engine-side bounded-wait loop.
    pub fn epoch(&self) -> u64 {
        self.epoch.load(SeqCst)
    }

    /// Current busy count (threads inside invisible work).
    pub fn busy(&self) -> usize {
        self.busy.load(SeqCst)
    }

    /// Wake parked threads after a state change. `sig = Some(id)` is a
    /// signal set (wake matching `Interest::Signal` parkers plus all
    /// `Interest::Any` parkers); `None` is bare activity (wake only
    /// `Interest::Any` parkers — the epoch moved, nothing else did).
    fn wake(&self, sig: Option<usize>) {
        if self.nparked.load(SeqCst) == 0 {
            return; // fast path: nobody is parked, skip the lot entirely
        }
        let parked = self.parked.lock().unwrap();
        for p in parked.iter() {
            let hit = match (p.interest, sig) {
                (Interest::Any, _) => true,
                (Interest::Signal(want), Some(id)) => want == id,
                (Interest::Signal(_), None) => false,
            };
            if hit {
                crate::obs::hot::unpark();
                crate::obs::flight::unpark(sig);
                p.thread.unpark();
            }
        }
    }

    /// Unpark everyone regardless of interest (abort).
    fn wake_all(&self) {
        if self.nparked.load(SeqCst) == 0 {
            return;
        }
        let parked = self.parked.lock().unwrap();
        for p in parked.iter() {
            p.thread.unpark();
        }
    }

    /// Park the current thread until a matching wakeup, `deadline`, or a
    /// spurious unpark — whichever comes first. Returns after at most one
    /// sleep; callers loop and re-evaluate their own condition.
    ///
    /// The lost-wakeup-free protocol: (1) register in the parking lot and
    /// publish the count, (2) re-check `cond`, (3) sleep only if it still
    /// holds nothing. A producer that fires between (2) and the sleep saw
    /// `nparked > 0` (its `SeqCst` store precedes its count load; our
    /// count store precedes our re-check) and left an unpark token, which
    /// makes the `park_timeout` return immediately. Stale tokens from
    /// previous rounds cause at worst one spurious loop iteration.
    pub fn park_unless(&self, interest: Interest, deadline: Instant, cond: impl Fn() -> bool) {
        {
            let mut parked = self.parked.lock().unwrap();
            parked.push(Parker { thread: std::thread::current(), interest });
            self.nparked.store(parked.len(), SeqCst);
        }
        if !cond() {
            let left = deadline.saturating_duration_since(Instant::now());
            if !left.is_zero() {
                crate::obs::hot::park();
                crate::obs::flight::park(match interest {
                    Interest::Signal(id) => Some(id),
                    Interest::Any => None,
                });
                std::thread::park_timeout(left);
            }
        }
        let me = std::thread::current().id();
        let mut parked = self.parked.lock().unwrap();
        if let Some(pos) = parked.iter().position(|p| p.thread.id() == me) {
            parked.swap_remove(pos);
        }
        self.nparked.store(parked.len(), SeqCst);
    }

    /// Block until every signal in `ids` is set.
    ///
    /// Errors if the run is aborted, or if `timeout` elapses with no board
    /// activity at all and no busy work in flight (the bounded-wait
    /// deadlock verdict — see [`SignalBoard::busy_begin`]); slow kernel
    /// calls are never misdiagnosed as deadlocks. `what` labels the error
    /// with the waiter's identity.
    pub fn wait_all(
        &self,
        ids: &[usize],
        timeout: Duration,
        what: impl Fn() -> String,
    ) -> Result<()> {
        let mut bound_epoch = self.epoch();
        let mut deadline = Instant::now() + timeout;
        loop {
            if self.aborted() {
                return Err(Error::Exec(format!("aborted while waiting: {}", what())));
            }
            let Some(first) = ids.iter().copied().find(|&i| !self.is_set(i)) else {
                return Ok(());
            };
            // any activity since the snapshot restarts the bound — the
            // board is live, even if our own signals haven't moved
            let e = self.epoch();
            if e != bound_epoch {
                bound_epoch = e;
                deadline = Instant::now() + timeout;
            }
            self.park_unless(Interest::Signal(first), deadline, || {
                self.aborted() || self.epoch() != e
            });
            if Instant::now() >= deadline {
                // busy BEFORE epoch: see busy_end's ordering contract
                let busy = self.busy();
                let e2 = self.epoch();
                if busy == 0 && e2 == bound_epoch {
                    let missing = self.unmet(ids);
                    return Err(Error::Exec(format!(
                        "deadlock: bounded wait ({timeout:?}) expired with no progress; \
                         {} still waiting on signals {missing:?}",
                        what()
                    )));
                }
                if busy > 0 {
                    // invisible work in flight: extend the bound; its
                    // busy_end will bump the epoch and restart it anyway
                    deadline = Instant::now() + timeout;
                }
            }
        }
    }

    /// Block until the board's epoch moves past `since` (any activity).
    ///
    /// Returns `Ok(true)` on activity, `Ok(false)` if aborted, and the
    /// deadlock error if `timeout` elapses with the epoch unchanged and
    /// no busy work in flight (see [`SignalBoard::busy_begin`]).
    pub fn wait_activity_since(
        &self,
        since: u64,
        timeout: Duration,
        what: impl Fn() -> String,
    ) -> Result<bool> {
        let mut deadline = Instant::now() + timeout;
        loop {
            if self.aborted() {
                return Ok(false);
            }
            if self.epoch() != since {
                return Ok(true);
            }
            self.park_unless(Interest::Any, deadline, || {
                self.aborted() || self.epoch() != since
            });
            if Instant::now() >= deadline {
                let busy = self.busy();
                let e = self.epoch();
                if busy == 0 && e == since {
                    return Err(Error::Exec(format!(
                        "deadlock: bounded wait ({timeout:?}) expired with no progress; {}",
                        what()
                    )));
                }
                deadline = Instant::now() + timeout;
            }
        }
    }
}

/// Per-thread monotonic cache over a board's signals.
///
/// Signals never clear within a run, so once a thread has observed one it
/// can answer every future re-check from thread-local memory — no shared
/// cache line is touched, which is what makes dep-heavy drain loops cheap
/// (the queue retain pass re-checks the same dep sets every round). The
/// cache is sound in one direction only: a `true` is forever, a `false`
/// just means "go ask the board".
#[derive(Debug, Clone)]
pub struct SeenSignals {
    seen: Vec<bool>,
}

impl SeenSignals {
    pub fn new(num_signals: usize) -> Self {
        SeenSignals { seen: vec![false; num_signals] }
    }

    /// Forget everything (arena reuse between runs).
    pub fn reset(&mut self) {
        for s in &mut self.seen {
            *s = false;
        }
    }

    /// Record a signal this thread itself set (skip the board round-trip).
    pub fn mark(&mut self, id: usize) {
        self.seen[id] = true;
    }

    pub fn is_set(&mut self, board: &SignalBoard, id: usize) -> bool {
        if self.seen[id] {
            crate::obs::hot::seen_short_circuit();
            return true;
        }
        if board.is_set(id) {
            self.seen[id] = true;
            return true;
        }
        false
    }

    pub fn all_set(&mut self, board: &SignalBoard, ids: &[usize]) -> bool {
        ids.iter().all(|&i| self.is_set(board, i))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;
    use std::time::Instant;

    #[test]
    fn set_and_query() {
        let b = SignalBoard::new(3);
        assert_eq!(b.len(), 3);
        assert!(!b.is_empty());
        assert!(!b.is_set(0));
        b.set(0);
        b.set(2);
        assert!(b.is_set(0));
        assert!(b.all_set(&[0, 2]));
        assert!(!b.all_set(&[0, 1]));
        assert!(b.all_set(&[]));
        assert_eq!(b.unmet(&[0, 1, 2]), vec![1]);
        assert!(b.unmet(&[]).is_empty());
    }

    #[test]
    fn wait_all_returns_once_set() {
        let b = SignalBoard::new(2);
        std::thread::scope(|s| {
            s.spawn(|| {
                std::thread::sleep(Duration::from_millis(20));
                b.set(0);
                b.set(1);
            });
            b.wait_all(&[0, 1], Duration::from_secs(5), || "test".into()).unwrap();
        });
        assert!(b.all_set(&[0, 1]));
    }

    #[test]
    fn bounded_wait_reports_deadlock() {
        let b = SignalBoard::new(2);
        let t0 = Instant::now();
        let e = b
            .wait_all(&[1], Duration::from_millis(50), || "rank 0 at op 3".into())
            .unwrap_err();
        assert!(t0.elapsed() < Duration::from_secs(5));
        assert!(e.to_string().contains("deadlock"), "{e}");
        assert!(e.to_string().contains("rank 0 at op 3"), "{e}");
    }

    #[test]
    fn activity_resets_the_bound() {
        // a live-but-slow producer must not trip the deadlock verdict; the
        // producer-step vs bound ratio is kept wide (5ms vs 500ms) so
        // loaded CI runners cannot misschedule their way into flaking
        let b = SignalBoard::new(8);
        std::thread::scope(|s| {
            s.spawn(|| {
                for i in 0..8 {
                    std::thread::sleep(Duration::from_millis(5));
                    b.set(i);
                }
            });
            b.wait_all(&[7], Duration::from_millis(500), || "waiter".into()).unwrap();
        });
    }

    #[test]
    fn busy_work_defers_the_verdict() {
        // a waiter whose bound expires while busy work is in flight (a
        // rank inside a long kernel call) must keep waiting, and succeed
        // when the signal finally lands after the "call" finishes
        let b = SignalBoard::new(1);
        b.busy_begin();
        std::thread::scope(|s| {
            s.spawn(|| {
                // "kernel call" far longer than the 20ms bound
                std::thread::sleep(Duration::from_millis(200));
                b.busy_end();
                b.set(0);
            });
            b.wait_all(&[0], Duration::from_millis(20), || "waiter".into()).unwrap();
        });
    }

    #[test]
    fn abort_wakes_waiters() {
        let b = SignalBoard::new(1);
        std::thread::scope(|s| {
            s.spawn(|| {
                std::thread::sleep(Duration::from_millis(10));
                b.abort();
            });
            let e = b
                .wait_all(&[0], Duration::from_secs(30), || "waiter".into())
                .unwrap_err();
            assert!(e.to_string().contains("abort"), "{e}");
        });
        assert!(b.aborted());
    }

    #[test]
    fn wait_activity_since_sees_touch() {
        let b = SignalBoard::new(1);
        let e0 = b.epoch();
        b.touch();
        assert!(b.wait_activity_since(e0, Duration::from_millis(10), || "x".into()).unwrap());
        let e1 = b.epoch();
        let err = b.wait_activity_since(e1, Duration::from_millis(30), || "idle".into());
        assert!(err.is_err());
    }

    #[test]
    #[cfg(debug_assertions)]
    #[should_panic(expected = "busy_end without matching busy_begin")]
    fn unbalanced_busy_end_asserts_in_debug() {
        // the old board silently saturating_sub'd this imbalance away —
        // it now names the bug at the call site
        let b = SignalBoard::new(1);
        b.busy_end();
    }

    #[test]
    fn targeted_wakeup_only_wakes_matching_waiters() {
        // two waiters on different signals: setting one must complete that
        // waiter while the other stays blocked until ITS signal lands
        let b = SignalBoard::new(2);
        let done = AtomicUsize::new(0);
        std::thread::scope(|s| {
            s.spawn(|| {
                b.wait_all(&[0], Duration::from_secs(10), || "w0".into()).unwrap();
                done.fetch_add(1, SeqCst);
            });
            s.spawn(|| {
                b.wait_all(&[1], Duration::from_secs(10), || "w1".into()).unwrap();
                done.fetch_add(1, SeqCst);
            });
            std::thread::sleep(Duration::from_millis(20));
            b.set(0);
            std::thread::sleep(Duration::from_millis(50));
            assert!(done.load(SeqCst) <= 1, "waiter 1 completed without its signal");
            b.set(1);
        });
        assert_eq!(done.load(SeqCst), 2);
    }

    #[test]
    fn seen_cache_is_monotonic_and_marks_local_sets() {
        let b = SignalBoard::new(3);
        let mut cache = SeenSignals::new(3);
        assert!(!cache.is_set(&b, 0));
        b.set(0);
        assert!(cache.is_set(&b, 0));
        assert!(cache.is_set(&b, 0)); // second hit answered from the cache
        cache.mark(2);
        assert!(cache.is_set(&b, 2)); // local set: never asked the board
        assert!(!cache.all_set(&b, &[0, 1, 2]));
        b.set(1);
        assert!(cache.all_set(&b, &[0, 1, 2]));
        cache.reset();
        assert!(cache.is_set(&b, 0)); // board still has it after reset
    }

    #[test]
    fn many_producers_one_waiter_race() {
        // N producers each set one signal with no coordination; a single
        // wait_all on the full set must observe every one exactly once
        let n = 16;
        let b = SignalBoard::new(n);
        let ids: Vec<usize> = (0..n).collect();
        std::thread::scope(|s| {
            for i in 0..n {
                let b = &b;
                s.spawn(move || b.set(i));
            }
            b.wait_all(&ids, Duration::from_secs(10), || "collector".into()).unwrap();
        });
        assert!(b.all_set(&ids));
    }
}
