//! Opt-in core pinning for the parallel engine's rank threads.
//!
//! Pinning is *best-effort everywhere*: [`pin_current_thread`] issues a raw
//! `sched_setaffinity` syscall on Linux (no libc dependency — this crate is
//! std-only) and returns `Err` on any other platform or on kernel refusal.
//! The engine ignores the `Err`: an unpinnable environment (containers with
//! restricted cpusets, non-Linux CI) runs exactly as before.
//!
//! Layouts map ranks to cores. [`identity_layout`] is the obvious
//! `rank % cores` spread; [`layout_from_slack`] orders ranks by measured
//! per-rank slack from a chunk trace (ascending — stragglers first), so the
//! ranks with the least headroom get the lowest-numbered (conventionally
//! least-contended) cores and never migrate mid-run.

use crate::error::{Error, Result};

/// Pin the calling thread to one CPU. Best-effort; see module docs.
#[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
pub fn pin_current_thread(cpu: usize) -> Result<()> {
    // cpu_set_t is 1024 bits = 16 u64 words on Linux.
    const WORDS: usize = 16;
    if cpu >= WORDS * 64 {
        return Err(Error::Exec(format!("cpu {cpu} out of cpu_set_t range")));
    }
    let mut mask = [0u64; WORDS];
    mask[cpu / 64] = 1u64 << (cpu % 64);
    let size = core::mem::size_of_val(&mask);
    let ret: isize;
    // sched_setaffinity(pid=0 /* self */, size, &mask)
    // SAFETY: the syscall only *reads* `size` bytes from `mask`, which is a
    // live stack array for the whole call; the kernel writes no user memory
    // for sched_setaffinity; rcx/r11 are declared clobbered (syscall ABI)
    // and the return flows out through rax. No Rust invariants are touched.
    #[cfg(target_arch = "x86_64")]
    unsafe {
        core::arch::asm!(
            "syscall",
            inlateout("rax") 203isize => ret, // __NR_sched_setaffinity
            in("rdi") 0usize,
            in("rsi") size,
            in("rdx") mask.as_ptr(),
            lateout("rcx") _,
            lateout("r11") _,
            options(nostack),
        );
    }
    // SAFETY: same contract as the x86_64 block — `svc 0` with x8 =
    // __NR_sched_setaffinity reads `size` bytes from the live `mask` array,
    // writes no user memory, and returns through x0.
    #[cfg(target_arch = "aarch64")]
    unsafe {
        core::arch::asm!(
            "svc 0",
            inlateout("x0") 0usize => ret, // pid = self
            in("x1") size,
            in("x2") mask.as_ptr(),
            in("x8") 122usize, // __NR_sched_setaffinity
            options(nostack),
        );
    }
    if ret < 0 {
        return Err(Error::Exec(format!(
            "sched_setaffinity(cpu {cpu}) failed (errno {})",
            -ret
        )));
    }
    Ok(())
}

/// Non-Linux / non-{x86_64,aarch64} fallback: pinning unsupported.
#[cfg(not(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64"))))]
pub fn pin_current_thread(_cpu: usize) -> Result<()> {
    Err(Error::Exec("core pinning unsupported on this platform".into()))
}

/// `rank -> core` layout from traced per-rank slack (µs of idle headroom
/// before the critical path; see `trace::analyze`). Ranks are ordered by
/// ascending slack — stragglers first — and assigned cores round-robin, so
/// with fewer ranks than cores every straggler gets a dedicated core.
///
/// Returns `layout` where rank `r` should pin to `layout[r]`.
pub fn layout_from_slack(slack_us: &[f64], cores: usize) -> Vec<usize> {
    let cores = cores.max(1);
    let mut order: Vec<usize> = (0..slack_us.len()).collect();
    // total_cmp: NaN-safe, deterministic; rank id breaks ties
    order.sort_by(|&a, &b| slack_us[a].total_cmp(&slack_us[b]).then(a.cmp(&b)));
    let mut layout = vec![0usize; slack_us.len()];
    for (pos, &rank) in order.iter().enumerate() {
        layout[rank] = pos % cores;
    }
    layout
}

/// The trivial `rank % cores` layout (no trace needed).
pub fn identity_layout(world: usize, cores: usize) -> Vec<usize> {
    let cores = cores.max(1);
    (0..world).map(|r| r % cores).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slack_layout_gives_stragglers_low_cores() {
        // rank 2 has the least slack -> core 0; rank 0 the most -> core 2
        let layout = layout_from_slack(&[50.0, 20.0, 5.0], 4);
        assert_eq!(layout, vec![2, 1, 0]);
    }

    #[test]
    fn slack_layout_wraps_when_ranks_exceed_cores() {
        let layout = layout_from_slack(&[1.0, 2.0, 3.0, 4.0], 2);
        assert_eq!(layout, vec![0, 1, 0, 1]);
        // zero cores clamps to 1 instead of dividing by zero
        assert_eq!(layout_from_slack(&[1.0, 2.0], 0), vec![0, 0]);
    }

    #[test]
    fn slack_ties_break_by_rank_id() {
        let layout = layout_from_slack(&[7.0, 7.0, 7.0], 8);
        assert_eq!(layout, vec![0, 1, 2]);
    }

    #[test]
    fn identity_layout_spreads_round_robin() {
        assert_eq!(identity_layout(4, 2), vec![0, 1, 0, 1]);
        assert_eq!(identity_layout(2, 8), vec![0, 1]);
        assert_eq!(identity_layout(2, 0), vec![0, 0]);
    }

    #[test]
    fn pin_is_best_effort_smoke() {
        // must not panic or UB regardless of platform/cpuset; Err is fine
        let _ = pin_current_thread(0);
        assert!(pin_current_thread(usize::MAX).is_err());
    }
}
