//! Per-rank tensor buffers with region-level read/write/reduce.
//!
//! Each rank holds a full-size buffer for every declared tensor; schedules
//! determine which regions are valid when. Region copies use row-major
//! linear offsets from [`Region::linear_offsets`] — fine at validation
//! scale (tensors are a few thousand elements).

use std::collections::HashMap;

use crate::chunk::Region;
use crate::error::{Error, Result};
use crate::topo::Rank;

/// Per-rank named tensor buffers.
#[derive(Debug, Clone)]
pub struct BufferStore {
    world: usize,
    shapes: HashMap<String, Vec<usize>>,
    data: Vec<HashMap<String, Vec<f32>>>,
}

impl BufferStore {
    pub fn new(world: usize) -> Self {
        BufferStore { world, shapes: HashMap::new(), data: vec![HashMap::new(); world] }
    }

    pub fn world(&self) -> usize {
        self.world
    }

    /// Declare a tensor on every rank (zero-initialized).
    pub fn declare(&mut self, name: &str, shape: &[usize]) -> Result<()> {
        if self.shapes.contains_key(name) {
            return Err(Error::Exec(format!("buffer `{name}` already declared")));
        }
        let n: usize = shape.iter().product();
        if n == 0 {
            return Err(Error::Exec(format!("buffer `{name}` has empty shape {shape:?}")));
        }
        self.shapes.insert(name.to_string(), shape.to_vec());
        for r in 0..self.world {
            self.data[r].insert(name.to_string(), vec![0.0; n]);
        }
        Ok(())
    }

    pub fn shape(&self, name: &str) -> Result<&[usize]> {
        self.shapes
            .get(name)
            .map(|s| s.as_slice())
            .ok_or_else(|| Error::Exec(format!("unknown buffer `{name}`")))
    }

    fn check(&self, rank: Rank, name: &str) -> Result<()> {
        if rank >= self.world {
            return Err(Error::Exec(format!("rank {rank} out of world {}", self.world)));
        }
        self.shape(name).map(|_| ())
    }

    /// Whole-buffer read.
    pub fn get(&self, rank: Rank, name: &str) -> Result<&[f32]> {
        self.check(rank, name)?;
        Ok(self.data[rank][name].as_slice())
    }

    /// Whole-buffer write (length-checked).
    pub fn set(&mut self, rank: Rank, name: &str, values: &[f32]) -> Result<()> {
        self.check(rank, name)?;
        let buf = self.data[rank].get_mut(name).unwrap();
        if buf.len() != values.len() {
            return Err(Error::Exec(format!(
                "set `{name}`: {} values for buffer of {}",
                values.len(),
                buf.len()
            )));
        }
        buf.copy_from_slice(values);
        Ok(())
    }

    /// Read a region (row-major element order within the region).
    pub fn read_region(&self, rank: Rank, name: &str, region: &Region) -> Result<Vec<f32>> {
        self.check(rank, name)?;
        let shape = &self.shapes[name];
        if !region.fits(shape) {
            return Err(Error::Exec(format!(
                "read `{name}`: region {region:?} does not fit {shape:?}"
            )));
        }
        let buf = &self.data[rank][name];
        Ok(region.linear_offsets(shape).into_iter().map(|o| buf[o]).collect())
    }

    /// Write (or reduce-add into) a region.
    pub fn write_region(
        &mut self,
        rank: Rank,
        name: &str,
        region: &Region,
        values: &[f32],
        reduce: bool,
    ) -> Result<()> {
        self.check(rank, name)?;
        let shape = self.shapes[name].clone();
        if !region.fits(&shape) {
            return Err(Error::Exec(format!(
                "write `{name}`: region {region:?} does not fit {shape:?}"
            )));
        }
        if values.len() != region.elems() {
            return Err(Error::Exec(format!(
                "write `{name}`: {} values for region of {}",
                values.len(),
                region.elems()
            )));
        }
        let buf = self.data[rank].get_mut(name).unwrap();
        for (o, &v) in region.linear_offsets(&shape).into_iter().zip(values) {
            if reduce {
                buf[o] += v;
            } else {
                buf[o] = v;
            }
        }
        Ok(())
    }

    /// Copy a region between ranks/tensors (the chunk-transfer primitive).
    pub fn transfer(
        &mut self,
        src_rank: Rank,
        src_name: &str,
        src_region: &Region,
        dst_rank: Rank,
        dst_name: &str,
        dst_region: &Region,
        reduce: bool,
    ) -> Result<usize> {
        if src_region.elems() != dst_region.elems() {
            return Err(Error::Exec(format!(
                "transfer: src {} elems != dst {} elems",
                src_region.elems(),
                dst_region.elems()
            )));
        }
        let values = self.read_region(src_rank, src_name, src_region)?;
        self.write_region(dst_rank, dst_name, dst_region, &values, reduce)?;
        Ok(values.len() * 4)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn store() -> BufferStore {
        let mut s = BufferStore::new(2);
        s.declare("x", &[4, 4]).unwrap();
        s
    }

    #[test]
    fn declare_and_rw() {
        let mut s = store();
        assert_eq!(s.shape("x").unwrap(), &[4, 4]);
        assert!(s.declare("x", &[2]).is_err());
        assert!(s.declare("bad", &[0]).is_err());
        s.set(0, "x", &[1.0; 16]).unwrap();
        assert_eq!(s.get(0, "x").unwrap()[5], 1.0);
        assert_eq!(s.get(1, "x").unwrap()[5], 0.0); // ranks are independent
        assert!(s.set(0, "x", &[1.0; 3]).is_err());
        assert!(s.get(5, "x").is_err());
        assert!(s.get(0, "nope").is_err());
    }

    #[test]
    fn region_read_write() {
        let mut s = store();
        let vals: Vec<f32> = (0..16).map(|i| i as f32).collect();
        s.set(0, "x", &vals).unwrap();
        let r = Region::rows(1, 2, 4);
        assert_eq!(s.read_region(0, "x", &r).unwrap(), vec![4.0, 5.0, 6.0, 7.0, 8.0, 9.0, 10.0, 11.0]);
        s.write_region(0, "x", &Region::rows(0, 1, 4), &[9.0; 4], false).unwrap();
        assert_eq!(&s.get(0, "x").unwrap()[..4], &[9.0; 4]);
        // reduce accumulates
        s.write_region(0, "x", &Region::rows(0, 1, 4), &[1.0; 4], true).unwrap();
        assert_eq!(&s.get(0, "x").unwrap()[..4], &[10.0; 4]);
        // bounds errors
        assert!(s.read_region(0, "x", &Region::rows(3, 2, 4)).is_err());
        assert!(s
            .write_region(0, "x", &Region::rows(0, 1, 4), &[0.0; 3], false)
            .is_err());
    }

    #[test]
    fn column_region_strided() {
        let mut s = store();
        let vals: Vec<f32> = (0..16).map(|i| i as f32).collect();
        s.set(0, "x", &vals).unwrap();
        let col = Region::cols(1, 1, 4);
        assert_eq!(s.read_region(0, "x", &col).unwrap(), vec![1.0, 5.0, 9.0, 13.0]);
    }

    #[test]
    fn transfer_between_ranks() {
        let mut s = store();
        s.set(0, "x", &[2.0; 16]).unwrap();
        let r = Region::rows(0, 2, 4);
        let bytes = s.transfer(0, "x", &r, 1, "x", &r, false).unwrap();
        assert_eq!(bytes, 8 * 4);
        assert_eq!(&s.get(1, "x").unwrap()[..8], &[2.0; 8]);
        assert_eq!(&s.get(1, "x").unwrap()[8..], &[0.0; 8]);
        // reduce transfer
        s.transfer(0, "x", &r, 1, "x", &r, true).unwrap();
        assert_eq!(&s.get(1, "x").unwrap()[..8], &[4.0; 8]);
        // mismatched sizes
        assert!(s
            .transfer(0, "x", &Region::rows(0, 1, 4), 1, "x", &r, false)
            .is_err());
    }
}
