//! Per-rank tensor buffers with region-level read/write/reduce.
//!
//! Each rank holds a full-size buffer for every declared tensor; schedules
//! determine which regions are valid when. Region copies use row-major
//! linear offsets from [`Region::linear_offsets`] — fine at validation
//! scale (tensors are a few thousand elements).
//!
//! The store is sharded per `(rank, tensor)` behind interior mutability so
//! the parallel executor's rank threads can read/write/transfer without
//! serializing the world: every buffer sits in its own `RwLock` (readers —
//! kernel-call inputs — never block each other), mutating operations take
//! `&self`, and a cross-rank transfer holds at most one buffer lock at a
//! time (read the source region out, release, then lock the destination),
//! so writers never hold-and-wait and the store itself cannot deadlock.
//! Zero-copy kernel input reads go through [`BufferStore::read_guard`].

use std::collections::HashMap;
use std::sync::{RwLock, RwLockReadGuard};

use crate::chunk::Region;
use crate::error::{Error, Result};
use crate::topo::Rank;

/// Per-rank named tensor buffers (sharded, `Send + Sync`).
#[derive(Debug)]
pub struct BufferStore {
    world: usize,
    shapes: HashMap<String, Vec<usize>>,
    data: Vec<HashMap<String, RwLock<Vec<f32>>>>,
}

impl Clone for BufferStore {
    fn clone(&self) -> Self {
        BufferStore {
            world: self.world,
            shapes: self.shapes.clone(),
            data: self
                .data
                .iter()
                .map(|rank| {
                    rank.iter()
                        .map(|(k, v)| (k.clone(), RwLock::new(v.read().unwrap().clone())))
                        .collect()
                })
                .collect(),
        }
    }
}

impl BufferStore {
    pub fn new(world: usize) -> Self {
        let mut data = Vec::with_capacity(world);
        for _ in 0..world {
            data.push(HashMap::new());
        }
        BufferStore { world, shapes: HashMap::new(), data }
    }

    pub fn world(&self) -> usize {
        self.world
    }

    /// Declare a tensor on every rank (zero-initialized). Declaration is a
    /// setup-phase operation and keeps `&mut self`; everything else takes
    /// `&self`.
    pub fn declare(&mut self, name: &str, shape: &[usize]) -> Result<()> {
        if self.shapes.contains_key(name) {
            return Err(Error::Exec(format!("buffer `{name}` already declared")));
        }
        let n: usize = shape.iter().product();
        if n == 0 {
            return Err(Error::Exec(format!("buffer `{name}` has empty shape {shape:?}")));
        }
        self.shapes.insert(name.to_string(), shape.to_vec());
        for r in 0..self.world {
            self.data[r].insert(name.to_string(), RwLock::new(vec![0.0; n]));
        }
        Ok(())
    }

    pub fn shape(&self, name: &str) -> Result<&[usize]> {
        self.shapes
            .get(name)
            .map(|s| s.as_slice())
            .ok_or_else(|| Error::Exec(format!("unknown buffer `{name}`")))
    }

    /// All declared tensor names (sorted, for deterministic iteration).
    pub fn names(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.shapes.keys().map(|s| s.as_str()).collect();
        v.sort_unstable();
        v
    }

    fn buf(&self, rank: Rank, name: &str) -> Result<&RwLock<Vec<f32>>> {
        if rank >= self.world {
            return Err(Error::Exec(format!("rank {rank} out of world {}", self.world)));
        }
        self.data[rank]
            .get(name)
            .ok_or_else(|| Error::Exec(format!("unknown buffer `{name}`")))
    }

    /// Whole-buffer read (snapshot copy). For the engine hot path prefer
    /// [`BufferStore::read_guard`], which copies nothing.
    pub fn get(&self, rank: Rank, name: &str) -> Result<Vec<f32>> {
        Ok(self.buf(rank, name)?.read().unwrap().clone())
    }

    /// Zero-copy whole-buffer read: a shared guard. Hold it only for the
    /// duration of a kernel call, and drop it before writing the same
    /// tensor from the same thread (re-entering the `RwLock` for write
    /// while holding its read guard deadlocks).
    pub fn read_guard(
        &self,
        rank: Rank,
        name: &str,
    ) -> Result<RwLockReadGuard<'_, Vec<f32>>> {
        Ok(self.buf(rank, name)?.read().unwrap())
    }

    /// Whole-buffer write (length-checked).
    pub fn set(&self, rank: Rank, name: &str, values: &[f32]) -> Result<()> {
        let buf = self.buf(rank, name)?;
        let mut buf = buf.write().unwrap();
        if buf.len() != values.len() {
            return Err(Error::Exec(format!(
                "set `{name}`: {} values for buffer of {}",
                values.len(),
                buf.len()
            )));
        }
        buf.copy_from_slice(values);
        Ok(())
    }

    /// Read a region (row-major element order within the region).
    pub fn read_region(&self, rank: Rank, name: &str, region: &Region) -> Result<Vec<f32>> {
        let buf = self.buf(rank, name)?;
        let shape = &self.shapes[name];
        if !region.fits(shape) {
            return Err(Error::Exec(format!(
                "read `{name}`: region {region:?} does not fit {shape:?}"
            )));
        }
        let buf = buf.read().unwrap();
        Ok(region.linear_offsets(shape).into_iter().map(|o| buf[o]).collect())
    }

    /// Write (or reduce-add into) a region.
    pub fn write_region(
        &self,
        rank: Rank,
        name: &str,
        region: &Region,
        values: &[f32],
        reduce: bool,
    ) -> Result<()> {
        let buf = self.buf(rank, name)?;
        let shape = &self.shapes[name];
        if !region.fits(shape) {
            return Err(Error::Exec(format!(
                "write `{name}`: region {region:?} does not fit {shape:?}"
            )));
        }
        if values.len() != region.elems() {
            return Err(Error::Exec(format!(
                "write `{name}`: {} values for region of {}",
                values.len(),
                region.elems()
            )));
        }
        let mut buf = buf.write().unwrap();
        for (o, &v) in region.linear_offsets(shape).into_iter().zip(values) {
            if reduce {
                buf[o] += v;
            } else {
                buf[o] = v;
            }
        }
        Ok(())
    }

    /// Read a region into a caller-provided buffer (cleared first). The
    /// allocation-free twin of [`BufferStore::read_region`]: the parallel
    /// engine threads a per-rank scratch vector through here so steady-state
    /// transfers never touch the heap once the scratch has grown to the
    /// plan's largest region.
    pub fn read_region_into(
        &self,
        rank: Rank,
        name: &str,
        region: &Region,
        out: &mut Vec<f32>,
    ) -> Result<()> {
        let buf = self.buf(rank, name)?;
        let shape = &self.shapes[name];
        if !region.fits(shape) {
            return Err(Error::Exec(format!(
                "read `{name}`: region {region:?} does not fit {shape:?}"
            )));
        }
        out.clear();
        out.reserve(region.elems());
        let buf = buf.read().unwrap();
        region.for_each_offset(shape, |o| out.push(buf[o]));
        Ok(())
    }

    /// Copy a region between ranks/tensors (the chunk-transfer primitive).
    ///
    /// Holds one buffer lock at a time: the source region is snapshotted,
    /// then written under the destination lock.
    #[allow(clippy::too_many_arguments)]
    pub fn transfer(
        &self,
        src_rank: Rank,
        src_name: &str,
        src_region: &Region,
        dst_rank: Rank,
        dst_name: &str,
        dst_region: &Region,
        reduce: bool,
    ) -> Result<usize> {
        if src_region.elems() != dst_region.elems() {
            return Err(Error::Exec(format!(
                "transfer: src {} elems != dst {} elems",
                src_region.elems(),
                dst_region.elems()
            )));
        }
        let values = self.read_region(src_rank, src_name, src_region)?;
        self.write_region(dst_rank, dst_name, dst_region, &values, reduce)?;
        Ok(values.len() * 4)
    }

    /// [`BufferStore::transfer`] staging through a caller-provided scratch
    /// buffer instead of a fresh `Vec` per copy. Same one-lock-at-a-time
    /// discipline, same byte count returned.
    #[allow(clippy::too_many_arguments)]
    pub fn transfer_into(
        &self,
        src_rank: Rank,
        src_name: &str,
        src_region: &Region,
        dst_rank: Rank,
        dst_name: &str,
        dst_region: &Region,
        reduce: bool,
        scratch: &mut Vec<f32>,
    ) -> Result<usize> {
        if src_region.elems() != dst_region.elems() {
            return Err(Error::Exec(format!(
                "transfer: src {} elems != dst {} elems",
                src_region.elems(),
                dst_region.elems()
            )));
        }
        self.read_region_into(src_rank, src_name, src_region, scratch)?;
        self.write_region(dst_rank, dst_name, dst_region, scratch, reduce)?;
        Ok(scratch.len() * 4)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn store() -> BufferStore {
        let mut s = BufferStore::new(2);
        s.declare("x", &[4, 4]).unwrap();
        s
    }

    #[test]
    fn declare_and_rw() {
        let mut s = store();
        assert_eq!(s.shape("x").unwrap(), &[4, 4]);
        assert!(s.declare("x", &[2]).is_err());
        assert!(s.declare("bad", &[0]).is_err());
        s.set(0, "x", &[1.0; 16]).unwrap();
        assert_eq!(s.get(0, "x").unwrap()[5], 1.0);
        assert_eq!(s.get(1, "x").unwrap()[5], 0.0); // ranks are independent
        assert!(s.set(0, "x", &[1.0; 3]).is_err());
        assert!(s.get(5, "x").is_err());
        assert!(s.get(0, "nope").is_err());
    }

    #[test]
    fn region_read_write() {
        let s = store();
        let vals: Vec<f32> = (0..16).map(|i| i as f32).collect();
        s.set(0, "x", &vals).unwrap();
        let r = Region::rows(1, 2, 4);
        let want = vec![4.0, 5.0, 6.0, 7.0, 8.0, 9.0, 10.0, 11.0];
        assert_eq!(s.read_region(0, "x", &r).unwrap(), want);
        s.write_region(0, "x", &Region::rows(0, 1, 4), &[9.0; 4], false).unwrap();
        assert_eq!(&s.get(0, "x").unwrap()[..4], &[9.0; 4]);
        // reduce accumulates
        s.write_region(0, "x", &Region::rows(0, 1, 4), &[1.0; 4], true).unwrap();
        assert_eq!(&s.get(0, "x").unwrap()[..4], &[10.0; 4]);
        // bounds errors
        assert!(s.read_region(0, "x", &Region::rows(3, 2, 4)).is_err());
        assert!(s
            .write_region(0, "x", &Region::rows(0, 1, 4), &[0.0; 3], false)
            .is_err());
    }

    #[test]
    fn column_region_strided() {
        let s = store();
        let vals: Vec<f32> = (0..16).map(|i| i as f32).collect();
        s.set(0, "x", &vals).unwrap();
        let col = Region::cols(1, 1, 4);
        assert_eq!(s.read_region(0, "x", &col).unwrap(), vec![1.0, 5.0, 9.0, 13.0]);
    }

    #[test]
    fn transfer_between_ranks() {
        let s = store();
        s.set(0, "x", &[2.0; 16]).unwrap();
        let r = Region::rows(0, 2, 4);
        let bytes = s.transfer(0, "x", &r, 1, "x", &r, false).unwrap();
        assert_eq!(bytes, 8 * 4);
        assert_eq!(&s.get(1, "x").unwrap()[..8], &[2.0; 8]);
        assert_eq!(&s.get(1, "x").unwrap()[8..], &[0.0; 8]);
        // reduce transfer
        s.transfer(0, "x", &r, 1, "x", &r, true).unwrap();
        assert_eq!(&s.get(1, "x").unwrap()[..8], &[4.0; 8]);
        // mismatched sizes
        assert!(s
            .transfer(0, "x", &Region::rows(0, 1, 4), 1, "x", &r, false)
            .is_err());
    }

    #[test]
    fn self_transfer_within_rank() {
        let s = store();
        let vals: Vec<f32> = (0..16).map(|i| i as f32).collect();
        s.set(0, "x", &vals).unwrap();
        // copy rows 0..2 onto rows 2..4 of the SAME buffer: the one-lock-at-
        // a-time discipline must not self-deadlock
        s.transfer(0, "x", &Region::rows(0, 2, 4), 0, "x", &Region::rows(2, 2, 4), false)
            .unwrap();
        assert_eq!(&s.get(0, "x").unwrap()[8..12], &[0.0, 1.0, 2.0, 3.0]);
    }

    #[test]
    fn concurrent_disjoint_writes() {
        let mut s = BufferStore::new(4);
        s.declare("x", &[8, 8]).unwrap();
        std::thread::scope(|scope| {
            for r in 0..4usize {
                let s = &s;
                scope.spawn(move || {
                    for i in 0..8 {
                        s.write_region(
                            r,
                            "x",
                            &Region::rows(i, 1, 8),
                            &[(r * 10 + i) as f32; 8],
                            false,
                        )
                        .unwrap();
                    }
                });
            }
        });
        for r in 0..4 {
            let v = s.get(r, "x").unwrap();
            for i in 0..8 {
                assert_eq!(v[i * 8], (r * 10 + i) as f32);
            }
        }
    }

    #[test]
    fn read_guard_is_zero_copy_and_shared() {
        let s = store();
        s.set(0, "x", &[6.0; 16]).unwrap();
        let g1 = s.read_guard(0, "x").unwrap();
        let g2 = s.read_guard(0, "x").unwrap(); // readers don't block readers
        assert_eq!(g1[0], 6.0);
        assert_eq!(&g2[..4], &[6.0; 4]);
        drop(g1);
        drop(g2);
        // write proceeds after guards drop
        s.set(0, "x", &[1.0; 16]).unwrap();
        assert!(s.read_guard(0, "nope").is_err());
    }

    #[test]
    fn read_region_into_matches_read_region() {
        let s = store();
        let vals: Vec<f32> = (0..16).map(|i| i as f32).collect();
        s.set(0, "x", &vals).unwrap();
        let mut scratch = Vec::new();
        for r in [Region::rows(1, 2, 4), Region::cols(1, 1, 4), Region::full(&[4, 4])] {
            s.read_region_into(0, "x", &r, &mut scratch).unwrap();
            assert_eq!(scratch, s.read_region(0, "x", &r).unwrap());
        }
        // scratch is cleared, not appended to
        s.read_region_into(0, "x", &Region::rows(0, 1, 4), &mut scratch).unwrap();
        assert_eq!(scratch.len(), 4);
        assert!(s
            .read_region_into(0, "x", &Region::rows(3, 2, 4), &mut scratch)
            .is_err());
    }

    #[test]
    fn transfer_into_matches_transfer_and_reuses_scratch() {
        let s = store();
        s.set(0, "x", &[2.0; 16]).unwrap();
        let r = Region::rows(0, 2, 4);
        let mut scratch = Vec::new();
        let bytes = s.transfer_into(0, "x", &r, 1, "x", &r, false, &mut scratch).unwrap();
        assert_eq!(bytes, 8 * 4);
        assert_eq!(&s.get(1, "x").unwrap()[..8], &[2.0; 8]);
        let cap = scratch.capacity();
        // second transfer reuses the grown scratch without reallocating
        s.transfer_into(0, "x", &r, 1, "x", &r, true, &mut scratch).unwrap();
        assert_eq!(scratch.capacity(), cap);
        assert_eq!(&s.get(1, "x").unwrap()[..8], &[4.0; 8]);
        assert!(s
            .transfer_into(0, "x", &Region::rows(0, 1, 4), 1, "x", &r, false, &mut scratch)
            .is_err());
    }

    #[test]
    fn clone_is_deep() {
        let s = store();
        s.set(0, "x", &[3.0; 16]).unwrap();
        let c = s.clone();
        s.set(0, "x", &[7.0; 16]).unwrap();
        assert_eq!(c.get(0, "x").unwrap()[0], 3.0);
        assert_eq!(s.get(0, "x").unwrap()[0], 7.0);
    }
}
