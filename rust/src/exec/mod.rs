//! Real-numerics distributed execution (validation-scale).
//!
//! Runs the *same* [`crate::codegen::ExecutablePlan`]s the simulator scores,
//! but with real data: every rank holds buffers, chunk transfers copy (or
//! reduce into) buffer regions, signals gate execution, and compute segments
//! call the AOT-compiled Pallas/JAX artifacts through PJRT.
//!
//! The engine is a deterministic single-threaded cooperative interpreter:
//! ranks are stepped round-robin, transfers complete as soon as their
//! dependencies allow. This makes failures reproducible and lets property
//! tests assert that *any* valid schedule/backend/split produces identical
//! numerics (DESIGN.md §6).

pub mod buffers;
pub mod engine;
pub mod verify;

pub use buffers::BufferStore;
pub use engine::{run, ExecStats};
