//! Real-numerics distributed execution (validation-scale).
//!
//! Runs the *same* [`crate::codegen::ExecutablePlan`]s the simulator scores,
//! but with real data: every rank holds buffers, chunk transfers copy (or
//! reduce into) buffer regions, signals gate execution, and compute segments
//! call the AOT-compiled Pallas/JAX artifacts through the runtime.
//!
//! Two engines interpret every plan (selected by [`ExecMode`]):
//!
//! * **Parallel** — the production path: one worker thread per rank over a
//!   shared atomic [`signals::SignalBoard`] and a sharded, interior-mutable
//!   [`buffers::BufferStore`], so chunks genuinely land while other ranks
//!   compute. Bounded waits turn cyclic schedules into errors instead of
//!   hangs. [`SyncStrategy`] selects between the lock-free atomic
//!   synchronization core (default) and the retained condvar baseline
//!   ([`signals_condvar::CondvarSignalBoard`]) kept for benchmarking.
//! * **Sequential** — the deterministic single-threaded cooperative
//!   interpreter kept as the *reference semantics*: ranks step round-robin,
//!   failures are exactly reproducible.
//!
//! [`plan_prep::prepare`] grafts a canonical ordering over all accumulating
//! writers into each plan, so all engines produce bit-identical f32
//! results — property tests assert this for every schedule template and
//! world size (DESIGN.md §6).
//!
//! All engines optionally emit chunk-level [`crate::trace`] events
//! (transfer applies, wait spans, kernel-call spans) through the
//! `*_traced` entry points; an untraced run carries a `None` sink and pays
//! one dead branch per op (DESIGN.md §14).
//!
//! The atomic parallel engine additionally supports arena reuse
//! ([`PlanArena`] + [`run_prepared_reusing`]) for allocation-free repeated
//! runs, and opt-in core pinning via [`ExecOptions::pin_cores`]
//! (DESIGN.md §15).

pub mod arena;
pub mod buffers;
pub mod engine;
pub mod parallel;
pub(crate) mod parallel_condvar;
pub mod pin;
pub mod plan_prep;
pub mod signals;
pub mod signals_condvar;
pub mod verify;

use std::time::Duration;

pub use arena::PlanArena;
pub use buffers::BufferStore;
pub use engine::{
    run, run_prepared, run_prepared_reusing, run_prepared_traced, run_with, run_with_traced,
    ExecStats,
};
pub use plan_prep::{prepare, PreparedPlan};
pub use signals::{SeenSignals, SignalBoard};
pub use signals_condvar::CondvarSignalBoard;

/// Which engine interprets the plan.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecMode {
    /// Deterministic single-threaded round-robin reference interpreter.
    Sequential,
    /// One worker thread per rank; signal-driven, bounded-wait.
    Parallel,
}

impl std::str::FromStr for ExecMode {
    type Err = crate::error::Error;
    fn from_str(s: &str) -> crate::error::Result<Self> {
        match s {
            "sequential" | "seq" => Ok(ExecMode::Sequential),
            "parallel" | "par" => Ok(ExecMode::Parallel),
            other => Err(crate::error::Error::Exec(format!(
                "unknown exec mode `{other}` (expected `sequential` or `parallel`)"
            ))),
        }
    }
}

/// Which synchronization core the parallel engine uses (DESIGN.md §15).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SyncStrategy {
    /// Lock-free hot path: atomic signal words, targeted thread parking,
    /// rank-owned transfer queues, arena-allocated plan state. Default.
    Atomic,
    /// Retained mutex+condvar baseline (`notify_all`, global pending-transfer
    /// servicer). Kept for benchmark comparison; do not grow it.
    Condvar,
}

impl std::str::FromStr for SyncStrategy {
    type Err = crate::error::Error;
    fn from_str(s: &str) -> crate::error::Result<Self> {
        match s {
            "atomic" => Ok(SyncStrategy::Atomic),
            "condvar" => Ok(SyncStrategy::Condvar),
            other => Err(crate::error::Error::Exec(format!(
                "unknown sync strategy `{other}` (expected `atomic` or `condvar`)"
            ))),
        }
    }
}

/// Engine selection + bounded-wait budget for the parallel engine.
#[derive(Debug, Clone)]
pub struct ExecOptions {
    pub mode: ExecMode,
    /// Parallel engine only: a blocking wait errors out as a deadlock after
    /// this long with *no* execution progress anywhere — the bound resets
    /// on every signal set, and a rank inside a kernel call counts as
    /// progress however long the call runs. The sequential engine detects
    /// stalls exactly and ignores this.
    pub wait_timeout: Duration,
    /// Parallel engine only: synchronization core. The sequential engine
    /// ignores this.
    pub sync: SyncStrategy,
    /// Parallel engine only (atomic core): pin rank `r` to core
    /// `pin_cores[r % pin_cores.len()]`. Best-effort — pinning failure is
    /// ignored, unsupported platforms no-op. `None` or empty disables.
    pub pin_cores: Option<Vec<usize>>,
}

impl Default for ExecOptions {
    fn default() -> Self {
        ExecOptions {
            mode: ExecMode::Sequential,
            wait_timeout: Duration::from_secs(10),
            sync: SyncStrategy::Atomic,
            pin_cores: None,
        }
    }
}

impl ExecOptions {
    pub fn sequential() -> Self {
        Self::default()
    }

    pub fn parallel() -> Self {
        ExecOptions { mode: ExecMode::Parallel, ..Self::default() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exec_mode_parses() {
        assert_eq!("sequential".parse::<ExecMode>().unwrap(), ExecMode::Sequential);
        assert_eq!("par".parse::<ExecMode>().unwrap(), ExecMode::Parallel);
        assert!("turbo".parse::<ExecMode>().is_err());
    }

    #[test]
    fn sync_strategy_parses() {
        assert_eq!("atomic".parse::<SyncStrategy>().unwrap(), SyncStrategy::Atomic);
        assert_eq!("condvar".parse::<SyncStrategy>().unwrap(), SyncStrategy::Condvar);
        assert!("spin".parse::<SyncStrategy>().is_err());
    }

    #[test]
    fn default_options_are_sequential_reference() {
        assert_eq!(ExecOptions::default().mode, ExecMode::Sequential);
        assert_eq!(ExecOptions::parallel().mode, ExecMode::Parallel);
        assert_eq!(ExecOptions::default().sync, SyncStrategy::Atomic);
        assert!(ExecOptions::default().pin_cores.is_none());
    }
}
