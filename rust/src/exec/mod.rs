//! Real-numerics distributed execution (validation-scale).
//!
//! Runs the *same* [`crate::codegen::ExecutablePlan`]s the simulator scores,
//! but with real data: every rank holds buffers, chunk transfers copy (or
//! reduce into) buffer regions, signals gate execution, and compute segments
//! call the AOT-compiled Pallas/JAX artifacts through the runtime.
//!
//! Two engines interpret every plan (selected by [`ExecMode`]):
//!
//! * **Parallel** — the production path: one worker thread per rank over a
//!   shared condvar-backed [`signals::SignalBoard`] and a sharded,
//!   interior-mutable [`buffers::BufferStore`], so chunks genuinely land
//!   while other ranks compute. Bounded waits turn cyclic schedules into
//!   errors instead of hangs.
//! * **Sequential** — the deterministic single-threaded cooperative
//!   interpreter kept as the *reference semantics*: ranks step round-robin,
//!   failures are exactly reproducible.
//!
//! [`plan_prep::prepare`] grafts a canonical ordering over all accumulating
//! writers into each plan, so the two modes produce bit-identical f32
//! results — property tests assert this for every schedule template and
//! world size (DESIGN.md §6).
//!
//! Both engines optionally emit chunk-level [`crate::trace`] events
//! (transfer applies, wait spans, kernel-call spans) through the
//! `*_traced` entry points; an untraced run carries a `None` sink and pays
//! one dead branch per op (DESIGN.md §14).

pub mod buffers;
pub mod engine;
pub mod parallel;
pub mod plan_prep;
pub mod signals;
pub mod verify;

use std::time::Duration;

pub use buffers::BufferStore;
pub use engine::{run, run_prepared, run_prepared_traced, run_with, run_with_traced, ExecStats};
pub use plan_prep::{prepare, PreparedPlan};
pub use signals::SignalBoard;

/// Which engine interprets the plan.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecMode {
    /// Deterministic single-threaded round-robin reference interpreter.
    Sequential,
    /// One worker thread per rank; signal-driven, bounded-wait.
    Parallel,
}

impl std::str::FromStr for ExecMode {
    type Err = crate::error::Error;
    fn from_str(s: &str) -> crate::error::Result<Self> {
        match s {
            "sequential" | "seq" => Ok(ExecMode::Sequential),
            "parallel" | "par" => Ok(ExecMode::Parallel),
            other => Err(crate::error::Error::Exec(format!(
                "unknown exec mode `{other}` (expected `sequential` or `parallel`)"
            ))),
        }
    }
}

/// Engine selection + bounded-wait budget for the parallel engine.
#[derive(Debug, Clone)]
pub struct ExecOptions {
    pub mode: ExecMode,
    /// Parallel engine only: a blocking wait errors out as a deadlock after
    /// this long with *no* execution progress anywhere — the bound resets
    /// on every signal set, and a rank inside a kernel call counts as
    /// progress however long the call runs. The sequential engine detects
    /// stalls exactly and ignores this.
    pub wait_timeout: Duration,
}

impl Default for ExecOptions {
    fn default() -> Self {
        ExecOptions { mode: ExecMode::Sequential, wait_timeout: Duration::from_secs(10) }
    }
}

impl ExecOptions {
    pub fn sequential() -> Self {
        Self::default()
    }

    pub fn parallel() -> Self {
        ExecOptions { mode: ExecMode::Parallel, ..Self::default() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exec_mode_parses() {
        assert_eq!("sequential".parse::<ExecMode>().unwrap(), ExecMode::Sequential);
        assert_eq!("par".parse::<ExecMode>().unwrap(), ExecMode::Parallel);
        assert!("turbo".parse::<ExecMode>().is_err());
    }

    #[test]
    fn default_options_are_sequential_reference() {
        assert_eq!(ExecOptions::default().mode, ExecMode::Sequential);
        assert_eq!(ExecOptions::parallel().mode, ExecMode::Parallel);
    }
}
