//! Arena-allocated runtime state for one prepared plan.
//!
//! Everything the parallel engine mutates while interpreting a plan —
//! signal words, parked-transfer queue storage, per-rank scratch — is
//! sized from the [`PreparedPlan`] and allocated up front in a
//! [`PlanArena`], so the run loop itself performs no heap allocation:
//! queue pushes land in preallocated `Vec`s, drain passes reuse a scratch
//! vector, and region copies stage through a buffer sized for the plan's
//! largest transfer. An arena is reusable: [`PlanArena::reset`] clears
//! state but keeps every capacity warm, so repeated runs of the same plan
//! (the bench loop, a serving tier replaying a cached plan) stay
//! allocation-free after the first.
//!
//! Capacities come from two fields `prepare()` computes while it walks
//! the plan anyway: [`PreparedPlan::incoming`] (per-destination-rank
//! Issue counts — a rank's queue can never hold more than every transfer
//! addressed to it) and [`PreparedPlan::max_transfer_elems`] (the copy
//! staging high-water mark).

use std::sync::Mutex;
use std::thread::Thread;

use crate::exec::plan_prep::PreparedPlan;
use crate::exec::signals::{SeenSignals, SignalBoard};

/// A parked transfer, by reference: the (rank, op) coordinates of an
/// `Issue` op in the prepared plan. Queues store these 8-byte handles
/// instead of cloning `TransferDesc`s (dep vectors, chunk refs) into
/// shared state.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) struct QueuedTransfer {
    pub(crate) rank: u32,
    pub(crate) op: u32,
}

/// One destination rank's parked-transfer queue. Pushed by source ranks
/// whose `Issue` found unmet deps; drained exclusively by the destination
/// rank thread. The mutex is per-queue, so contention is pairwise
/// (one producer vs one consumer) instead of global.
#[derive(Debug)]
pub(crate) struct TransferQueue {
    pub(crate) items: Mutex<Vec<QueuedTransfer>>,
}

/// Per-rank-thread mutable state, handed to the rank thread at spawn.
/// Lives in the arena (not on the thread's stack) so capacities survive
/// across runs.
#[derive(Debug)]
pub(crate) struct RankLocal {
    /// Monotonic local signal cache (DESIGN.md §15).
    pub(crate) seen: SeenSignals,
    /// Drain scratch: ready transfers pulled out of the queue per pass.
    pub(crate) ready: Vec<QueuedTransfer>,
    /// Region-copy staging buffer threaded through transfer applies.
    pub(crate) copy: Vec<f32>,
}

/// All mutable engine state for one plan, preallocated.
#[derive(Debug)]
pub struct PlanArena {
    pub(crate) board: SignalBoard,
    pub(crate) queues: Vec<TransferQueue>,
    pub(crate) rank_local: Vec<Mutex<RankLocal>>,
    /// Rank thread handles, registered as each thread's first action so
    /// producers can unpark a destination directly after a queue push.
    pub(crate) threads: Vec<Mutex<Option<Thread>>>,
    num_signals: usize,
    /// Has this arena driven a run before? Flipped by the first
    /// [`reset`](Self::reset); later resets count as warm reuse in the
    /// hot-path telemetry (`hot.arena_reuses`).
    used: bool,
}

impl PlanArena {
    pub fn new(prep: &PreparedPlan) -> Self {
        let world = prep.plan.world;
        let num_signals = prep.plan.num_signals;
        debug_assert_eq!(prep.incoming.len(), world);
        PlanArena {
            board: SignalBoard::new(num_signals),
            queues: (0..world)
                .map(|r| TransferQueue {
                    items: Mutex::new(Vec::with_capacity(
                        prep.incoming.get(r).copied().unwrap_or(0),
                    )),
                })
                .collect(),
            rank_local: (0..world)
                .map(|r| {
                    Mutex::new(RankLocal {
                        seen: SeenSignals::new(num_signals),
                        ready: Vec::with_capacity(prep.incoming.get(r).copied().unwrap_or(0)),
                        copy: Vec::with_capacity(prep.max_transfer_elems),
                    })
                })
                .collect(),
            threads: (0..world).map(|_| Mutex::new(None)).collect(),
            num_signals,
            used: false,
        }
    }

    /// Clear run state, keep capacities. Called by the engine on entry so
    /// a reused arena behaves exactly like a fresh one.
    pub fn reset(&mut self) {
        if self.used {
            crate::obs::hot::arena_reuse();
        }
        self.used = true;
        self.board.reset();
        for q in &mut self.queues {
            q.items.get_mut().unwrap().clear();
        }
        for l in &mut self.rank_local {
            let l = l.get_mut().unwrap();
            l.seen.reset();
            l.ready.clear();
            l.copy.clear();
        }
        for t in &mut self.threads {
            *t.get_mut().unwrap() = None;
        }
    }

    pub fn world(&self) -> usize {
        self.queues.len()
    }

    /// Does this arena fit `prep`? Guards `run_prepared_reusing` against
    /// an arena built for a different plan.
    pub fn fits(&self, prep: &PreparedPlan) -> bool {
        self.world() == prep.plan.world && self.num_signals == prep.plan.num_signals
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chunk::{DType, TensorTable};
    use crate::codegen::{ExecutablePlan, PlanOp, RankProgram};
    use crate::exec::plan_prep::prepare;
    use crate::testutil::transfer_desc;

    fn two_rank_prep() -> PreparedPlan {
        let mut t = TensorTable::new();
        let x = t.declare("x", &[4, 4], DType::F32).unwrap();
        let plan = ExecutablePlan {
            world: 2,
            per_rank: vec![
                RankProgram {
                    ops: vec![PlanOp::Issue(transfer_desc(
                        x,
                        crate::chunk::Region::rows(0, 2, 4),
                        0,
                        0,
                        1,
                        vec![],
                        false,
                    ))],
                },
                RankProgram { ops: vec![PlanOp::Wait(0)] },
            ],
            num_signals: 1,
            reserved_comm_sms: 0,
        };
        prepare(&plan, &t).unwrap()
    }

    #[test]
    fn arena_sizes_from_prepared_plan() {
        let prep = two_rank_prep();
        // one transfer addressed to rank 1, none to rank 0
        assert_eq!(prep.incoming, vec![0, 1]);
        assert_eq!(prep.max_transfer_elems, 8); // 2x4 rows region
        let arena = PlanArena::new(&prep);
        assert_eq!(arena.world(), 2);
        assert!(arena.fits(&prep));
        assert!(arena.queues[1].items.lock().unwrap().capacity() >= 1);
        assert!(arena.rank_local[0].lock().unwrap().copy.capacity() >= 8);
    }

    #[test]
    fn reset_clears_state_but_keeps_capacity() {
        let prep = two_rank_prep();
        let mut arena = PlanArena::new(&prep);
        arena.board.set(0);
        arena.queues[1].items.lock().unwrap().push(QueuedTransfer { rank: 0, op: 0 });
        arena.rank_local[1].lock().unwrap().copy.extend_from_slice(&[1.0; 8]);
        let cap_before = arena.rank_local[1].lock().unwrap().copy.capacity();
        arena.reset();
        assert!(!arena.board.is_set(0));
        assert!(arena.queues[1].items.lock().unwrap().is_empty());
        let local = arena.rank_local[1].lock().unwrap();
        assert!(local.copy.is_empty());
        assert!(local.copy.capacity() >= cap_before);
    }
}
