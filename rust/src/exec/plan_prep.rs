//! Plan preparation shared by both execution engines.
//!
//! Two jobs, done once per `run` instead of per operation:
//!
//! 1. **Name interning** — the engines address [`BufferStore`] tensors by
//!    name; resolving a [`TensorId`] used to clone a `String` per transfer
//!    on the hot path. [`PreparedPlan`] precomputes one `TensorId -> name`
//!    table and threads `&str` through every buffer call.
//!
//! 2. **Deterministic reduction order** — f32 addition is not associative,
//!    so the *apply order* of accumulating writers (reduce transfers and
//!    `accumulate` compute calls) into overlapping regions decides the
//!    output bits. The sequential interpreter orders them by its
//!    round-robin walk; free-running rank threads would order them by the
//!    scheduler's mood. `prepare` therefore augments the plan with a
//!    canonical order — for each destination `(rank, tensor)`: the
//!    destination rank's own accumulating compute calls first (they are
//!    program-ordered on one thread already), then intersecting reduce
//!    transfers along one total order (topological over the orderings the
//!    plan itself already expresses, ascending signal id as tiebreak) —
//!    expressed through the plan's
//!    existing dependency machinery: extra `dep_signals` entries plus
//!    *internal* signals set when a compute call completes
//!    ([`PreparedPlan::call_signals`]). Both engines interpret the same
//!    augmented plan, which is what makes `ExecMode::Parallel` and
//!    `ExecMode::Sequential` produce bit-identical f32 results
//!    (DESIGN.md §6).
//!
//! Plain (non-reduce) writes racing accumulating writers are *not*
//! reordered here: the schedule templates already order them through real
//! dependencies (e.g. the AllReduce broadcast phase depends on every
//! reduce landing). A plan that races plain writes is nondeterministic by
//! construction and will be caught by the cross-mode verifier.
//!
//! Note: `AttnStep` state tensors (acc/m/l) are rank-private in every
//! template — they are never transfer destinations — so they need no
//! ordering and are intentionally not treated as accumulating writers.

use std::collections::BTreeMap;
use std::collections::HashMap;

use crate::chunk::{Region, TensorId, TensorTable};
use crate::codegen::{CallSpec, ExecutablePlan, PlanOp, SignalId};
use crate::error::{Error, Result};

/// Location of one compute call: (rank, op index, call index).
pub type CallLoc = (usize, usize, usize);

/// A plan plus everything the engines derive from it up front.
#[derive(Debug, Clone)]
pub struct PreparedPlan {
    /// The (possibly augmented) plan both engines interpret.
    pub plan: ExecutablePlan,
    /// Signal count of the original plan; ids `>= base_signals` are
    /// engine-internal ordering signals invented by [`prepare`].
    pub base_signals: usize,
    /// Internal signal to set when the call at a [`CallLoc`] completes.
    pub call_signals: HashMap<CallLoc, SignalId>,
    /// Per-destination-rank `Issue` counts: `incoming[r]` is how many
    /// transfers in the whole plan target rank `r`. Sizes the rank-owned
    /// parked-transfer queues in [`crate::exec::PlanArena`] so queue pushes
    /// never reallocate at run time.
    pub incoming: Vec<usize>,
    /// Largest transfer region (in elements) anywhere in the plan: the
    /// high-water mark for the arena's per-rank copy staging buffer.
    pub max_transfer_elems: usize,
    names: Vec<String>,
}

impl PreparedPlan {
    /// Tensor name for a [`TensorId`] (no allocation on the hot path).
    pub fn name(&self, id: TensorId) -> Result<&str> {
        self.names
            .get(id.0 as usize)
            .map(|s| s.as_str())
            .ok_or_else(|| Error::Exec(format!("plan references unknown tensor id {id:?}")))
    }
}

/// One accumulating writer into a destination tensor.
#[derive(Debug)]
enum Writer {
    /// Reduce transfer: (plan location, destination region, its signal).
    Transfer { rank: usize, op_index: usize, region: Region, signal: SignalId },
    /// `accumulate` compute call on the destination rank, in program order.
    Call { loc: CallLoc, region: Region },
}

/// Destination region of an accumulating compute call, if the call
/// accumulates and its output tensor is known to the table. 2-D outputs
/// only — which covers every accumulate-capable [`CallSpec`].
fn accumulate_region(call: &CallSpec, table: &TensorTable) -> Option<(TensorId, Region)> {
    let (out, rows) = match call {
        CallSpec::GemmRows { out, rows, accumulate: true, .. } => (out, Some(*rows)),
        CallSpec::FfnShard { out, accumulate: true, .. } => (out, None),
        CallSpec::AddRows { out, rows, .. } => (out, Some(*rows)),
        _ => return None,
    };
    let id = table.lookup(out)?;
    let shape = &table.get(id).ok()?.shape;
    if shape.len() != 2 {
        return None;
    }
    let region = match rows {
        Some((r0, r1)) => Region::rows(r0, r1 - r0, shape[1]),
        None => Region::full(shape),
    };
    Some((id, region))
}

/// True if the plan itself already orders `signal`'s transfer before the
/// op at `(rank, upto_op)`: either rank `rank` explicitly `Wait`s on
/// `signal` at/before that op, or the op is an `Issue` whose own
/// `dep_signals` (the primary ordering mechanism between transfers)
/// include it. Grafting the reverse edge there would manufacture a
/// dependency cycle, so the graft is skipped — the plan's own edge already
/// makes the apply order deterministic in both engines. (Transitive
/// orderings through third ops are not traced; a plan exotic enough to
/// hit that surfaces as a bounded-wait deadlock `Error`, never a hang.)
fn ordered_before(plan: &ExecutablePlan, rank: usize, upto_op: usize, signal: SignalId) -> bool {
    let ops = &plan.per_rank[rank].ops;
    let waits = ops
        .iter()
        .take(upto_op + 1)
        .any(|op| matches!(op, PlanOp::Wait(s) if *s == signal));
    if waits {
        return true;
    }
    matches!(&ops[upto_op], PlanOp::Issue(d) if d.dep_signals.contains(&signal))
}

/// Build the [`PreparedPlan`] for a validated plan.
pub fn prepare(plan: &ExecutablePlan, table: &TensorTable) -> Result<PreparedPlan> {
    let names: Vec<String> = table.iter().map(|(_, decl)| decl.name.clone()).collect();
    let mut plan = plan.clone();
    let base_signals = plan.num_signals;

    // Accumulating writers grouped by destination (rank, tensor). BTreeMap
    // keeps internal-signal numbering deterministic across calls.
    let mut groups: BTreeMap<(usize, TensorId), Vec<Writer>> = BTreeMap::new();
    for (rank, prog) in plan.per_rank.iter().enumerate() {
        for (op_index, op) in prog.ops.iter().enumerate() {
            match op {
                PlanOp::Issue(d) if d.reduce => {
                    groups.entry((d.dst_rank, d.dst_chunk.tensor)).or_default().push(
                        Writer::Transfer {
                            rank,
                            op_index,
                            region: d.dst_chunk.region.clone(),
                            signal: d.signal,
                        },
                    );
                }
                PlanOp::Compute(seg) => {
                    for (ci, call) in seg.calls.iter().enumerate() {
                        if let Some((id, region)) = accumulate_region(call, table) {
                            groups
                                .entry((rank, id))
                                .or_default()
                                .push(Writer::Call { loc: (rank, op_index, ci), region });
                        }
                    }
                }
                _ => {}
            }
        }
    }

    // Extra deps to graft onto Issue ops, keyed by plan location.
    let mut extra_deps: HashMap<(usize, usize), Vec<SignalId>> = HashMap::new();
    let mut call_signals: HashMap<CallLoc, SignalId> = HashMap::new();

    for writers in groups.values() {
        let mut transfers: Vec<(&Writer, usize, usize, &Region, SignalId)> = writers
            .iter()
            .filter_map(|w| match w {
                Writer::Transfer { rank, op_index, region, signal } => {
                    Some((w, *rank, *op_index, region, *signal))
                }
                Writer::Call { .. } => None,
            })
            .collect();
        if transfers.is_empty() {
            continue; // rank-local accumulation order is program order already
        }
        transfers.sort_by_key(|t| t.4);

        // (a) chain intersecting reduce transfers along ONE canonical total
        // order: topological over the ordering edges the plan itself
        // already expresses (Wait / dep_signals), with ascending-signal
        // tiebreak. Grafting only consistently with a single total order
        // guarantees the grafted edges can never compose with a detected
        // plan edge into a manufactured cycle.
        let n = transfers.len();
        let mut preds: Vec<Vec<usize>> = vec![Vec::new(); n];
        for i in 0..n {
            for j in 0..n {
                if i != j
                    && ordered_before(&plan, transfers[j].1, transfers[j].2, transfers[i].4)
                {
                    preds[j].push(i); // the plan orders transfer i before j
                }
            }
        }
        let mut placed = vec![false; n];
        let mut order: Vec<usize> = Vec::with_capacity(n);
        while order.len() < n {
            let next = (0..n)
                .filter(|&k| !placed[k] && preds[k].iter().all(|&p| placed[p]))
                .min_by_key(|&k| transfers[k].4);
            // a cycle among the plan's OWN edges: leave the group alone —
            // the plan deadlocks with a bounded-wait Error regardless
            let Some(k) = next else { break };
            placed[k] = true;
            order.push(k);
        }
        if order.len() == n {
            for bi in 1..n {
                for ai in 0..bi {
                    let a = order[ai];
                    let b = order[bi];
                    if transfers[a].3.intersects(transfers[b].3)
                        && !ordered_before(
                            &plan,
                            transfers[b].1,
                            transfers[b].2,
                            transfers[a].4,
                        )
                    {
                        let (_, rank, op_index, _, _) = transfers[b];
                        extra_deps.entry((rank, op_index)).or_default().push(transfers[a].4);
                    }
                }
            }
        }

        // (b) every intersecting destination-rank accumulate call that the
        // plan does not already order AFTER the transfer must precede it;
        // it suffices to depend on the LAST such call in program order
        // (same thread runs them in order, and any call the plan orders
        // after the transfer — reduce-then-combine via an explicit Wait —
        // is excluded so the graft cannot invert the plan's own edge into
        // a cycle).
        for &(_, rank, op_index, region, signal) in &transfers {
            let last_unordered_call = writers
                .iter()
                .filter_map(|w| match w {
                    Writer::Call { loc, region: cr } if cr.intersects(region) => Some(*loc),
                    _ => None,
                })
                .filter(|loc| !ordered_before(&plan, loc.0, loc.1, signal))
                .max_by_key(|&(_, op, ci)| (op, ci));
            if let Some(loc) = last_unordered_call {
                let sig = *call_signals.entry(loc).or_insert_with(|| {
                    let s = plan.num_signals;
                    plan.num_signals += 1;
                    s
                });
                extra_deps.entry((rank, op_index)).or_default().push(sig);
            }
        }
    }

    // Graft the extra deps into the plan clone (deduplicated).
    for ((rank, op_index), deps) in extra_deps {
        if let PlanOp::Issue(d) = &mut plan.per_rank[rank].ops[op_index] {
            for s in deps {
                if !d.dep_signals.contains(&s) {
                    d.dep_signals.push(s);
                }
            }
        }
    }

    // Arena sizing: count transfers per destination rank and the largest
    // region, over the final (augmented) plan.
    let mut incoming = vec![0usize; plan.world];
    let mut max_transfer_elems = 0usize;
    for prog in &plan.per_rank {
        for op in &prog.ops {
            if let PlanOp::Issue(d) = op {
                if let Some(slot) = incoming.get_mut(d.dst_rank) {
                    *slot += 1;
                }
                max_transfer_elems = max_transfer_elems.max(d.src_chunk.region.elems());
            }
        }
    }

    Ok(PreparedPlan { plan, base_signals, call_signals, incoming, max_transfer_elems, names })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chunk::DType;
    use crate::codegen::{ComputeSeg, RankProgram, TransferDesc};

    fn table() -> TensorTable {
        let mut t = TensorTable::new();
        t.declare("y", &[8, 4], DType::F32).unwrap();
        t
    }

    fn reduce_xfer(
        t: &TensorTable,
        signal: usize,
        src: usize,
        dst: usize,
        r0: usize,
    ) -> TransferDesc {
        let id = t.lookup("y").unwrap();
        crate::testutil::transfer_desc(id, Region::rows(r0, 2, 4), signal, src, dst, vec![], true)
    }

    fn accumulate_call(rows: (usize, usize)) -> CallSpec {
        CallSpec::GemmRows {
            artifact: "gemm_2x4x4".into(),
            a: "y".into(),
            b: "y".into(),
            out: "y".into(),
            rows,
            accumulate: true,
        }
    }

    #[test]
    fn names_resolve_without_cloning_per_call() {
        let t = table();
        let plan = ExecutablePlan {
            world: 1,
            per_rank: vec![RankProgram::default()],
            num_signals: 0,
            reserved_comm_sms: 0,
        };
        let prep = prepare(&plan, &t).unwrap();
        assert_eq!(prep.name(t.lookup("y").unwrap()).unwrap(), "y");
        assert!(prep.name(crate::chunk::TensorId(9)).is_err());
    }

    #[test]
    fn intersecting_reduces_are_chained_by_signal_order() {
        let t = table();
        // ranks 1 and 2 both reduce into rank 0's rows 0..2 of y
        let plan = ExecutablePlan {
            world: 3,
            per_rank: vec![
                RankProgram::default(),
                RankProgram { ops: vec![PlanOp::Issue(reduce_xfer(&t, 0, 1, 0, 0))] },
                RankProgram { ops: vec![PlanOp::Issue(reduce_xfer(&t, 1, 2, 0, 0))] },
            ],
            num_signals: 2,
            reserved_comm_sms: 0,
        };
        let prep = prepare(&plan, &t).unwrap();
        let PlanOp::Issue(d1) = &prep.plan.per_rank[2].ops[0] else { panic!() };
        assert_eq!(d1.dep_signals, vec![0], "higher signal depends on lower");
        let PlanOp::Issue(d0) = &prep.plan.per_rank[1].ops[0] else { panic!() };
        assert!(d0.dep_signals.is_empty());
        assert_eq!(prep.plan.num_signals, 2); // no compute writers => no internal signals
    }

    #[test]
    fn disjoint_reduces_stay_unordered() {
        let t = table();
        let plan = ExecutablePlan {
            world: 3,
            per_rank: vec![
                RankProgram::default(),
                RankProgram { ops: vec![PlanOp::Issue(reduce_xfer(&t, 0, 1, 0, 0))] },
                RankProgram { ops: vec![PlanOp::Issue(reduce_xfer(&t, 1, 2, 0, 4))] },
            ],
            num_signals: 2,
            reserved_comm_sms: 0,
        };
        let prep = prepare(&plan, &t).unwrap();
        let PlanOp::Issue(d1) = &prep.plan.per_rank[2].ops[0] else { panic!() };
        assert!(d1.dep_signals.is_empty(), "disjoint regions need no ordering");
    }

    #[test]
    fn local_accumulate_precedes_incoming_reduce() {
        let t = table();
        // rank 0 accumulates into y rows 0..2 itself; rank 1 reduce-pushes
        // the same region: the transfer must gain a dep on the internal
        // signal of rank 0's call.
        let seg = ComputeSeg {
            tiles: vec![0],
            flops: vec![1.0],
            calls: vec![accumulate_call((0, 2))],
            quantized: false,
        };
        let plan = ExecutablePlan {
            world: 2,
            per_rank: vec![
                RankProgram { ops: vec![PlanOp::Compute(seg)] },
                RankProgram { ops: vec![PlanOp::Issue(reduce_xfer(&t, 0, 1, 0, 0))] },
            ],
            num_signals: 1,
            reserved_comm_sms: 0,
        };
        let prep = prepare(&plan, &t).unwrap();
        assert_eq!(prep.base_signals, 1);
        assert_eq!(prep.plan.num_signals, 2, "one internal signal allocated");
        assert_eq!(prep.call_signals.get(&(0, 0, 0)), Some(&1));
        let PlanOp::Issue(d) = &prep.plan.per_rank[1].ops[0] else { panic!() };
        assert_eq!(d.dep_signals, vec![1]);
    }

    #[test]
    fn dep_ordered_reduces_are_not_reversed() {
        // the plan orders the SAME-region reduces against ascending signal
        // order via dep_signals (t0 waits for t1): the ascending chain
        // would be a manufactured cycle and must be skipped
        let t = table();
        let mut t0 = reduce_xfer(&t, 0, 1, 0, 0);
        t0.dep_signals = vec![1];
        let plan = ExecutablePlan {
            world: 3,
            per_rank: vec![
                RankProgram::default(),
                RankProgram { ops: vec![PlanOp::Issue(t0)] },
                RankProgram { ops: vec![PlanOp::Issue(reduce_xfer(&t, 1, 2, 0, 0))] },
            ],
            num_signals: 2,
            reserved_comm_sms: 0,
        };
        let prep = prepare(&plan, &t).unwrap();
        let PlanOp::Issue(d1) = &prep.plan.per_rank[2].ops[0] else { panic!() };
        assert!(d1.dep_signals.is_empty(), "no reverse edge grafted: {:?}", d1.dep_signals);
        let PlanOp::Issue(d0) = &prep.plan.per_rank[1].ops[0] else { panic!() };
        assert_eq!(d0.dep_signals, vec![1], "plan's own ordering preserved");
    }

    #[test]
    fn grafts_never_compose_into_a_cycle_with_plan_edges() {
        // three mutually intersecting reduces where the plan orders t2
        // BEFORE t0 via a Wait: naive ascending-signal chaining would
        // graft 0->1 and 1->2, composing with the plan's 2->0 into a
        // cycle. The topological graft must instead produce an acyclic
        // total order (t1, t2, t0).
        let t = table();
        let plan = ExecutablePlan {
            world: 4,
            per_rank: vec![
                RankProgram::default(),
                RankProgram {
                    ops: vec![PlanOp::Wait(2), PlanOp::Issue(reduce_xfer(&t, 0, 1, 0, 0))],
                },
                RankProgram { ops: vec![PlanOp::Issue(reduce_xfer(&t, 1, 2, 0, 0))] },
                RankProgram { ops: vec![PlanOp::Issue(reduce_xfer(&t, 2, 3, 0, 0))] },
            ],
            num_signals: 3,
            reserved_comm_sms: 0,
        };
        let prep = prepare(&plan, &t).unwrap();
        let dep_of = |rank: usize, op: usize| -> Vec<usize> {
            let PlanOp::Issue(d) = &prep.plan.per_rank[rank].ops[op] else { panic!() };
            d.dep_signals.clone()
        };
        assert!(dep_of(2, 0).is_empty(), "t1 runs first");
        assert_eq!(dep_of(3, 0), vec![1], "t2 after t1");
        assert_eq!(dep_of(1, 1), vec![1], "t0 after t1 (plus its plan Wait(2))");
        // acyclic by construction: t1 -> t2 -> (Wait) t0
    }

    #[test]
    fn reduce_then_combine_plans_are_not_inverted() {
        // rank 0 explicitly WAITS for the incoming reduce before its own
        // accumulate (reduce-then-combine): the plan already orders the
        // writers, and grafting call->transfer here would be a cycle.
        let t = table();
        let seg = ComputeSeg {
            tiles: vec![0],
            flops: vec![1.0],
            calls: vec![accumulate_call((0, 2))],
            quantized: false,
        };
        let plan = ExecutablePlan {
            world: 2,
            per_rank: vec![
                RankProgram { ops: vec![PlanOp::Wait(0), PlanOp::Compute(seg)] },
                RankProgram { ops: vec![PlanOp::Issue(reduce_xfer(&t, 0, 1, 0, 0))] },
            ],
            num_signals: 1,
            reserved_comm_sms: 0,
        };
        let prep = prepare(&plan, &t).unwrap();
        assert!(prep.call_signals.is_empty(), "graft must be skipped");
        let PlanOp::Issue(d) = &prep.plan.per_rank[1].ops[0] else { panic!() };
        assert!(d.dep_signals.is_empty());
        assert_eq!(prep.plan.num_signals, 1);
    }

    #[test]
    fn earlier_call_still_ordered_when_last_call_follows_the_transfer() {
        // combine-reduce-combine: A accumulates, the rank Waits for the
        // incoming reduce, then B accumulates. B is plan-ordered after the
        // transfer and must be excluded — but the transfer still has to
        // wait for A, or A races it in parallel mode.
        let t = table();
        let seg = |_tag: usize| ComputeSeg {
            tiles: vec![0],
            flops: vec![1.0],
            calls: vec![accumulate_call((0, 2))],
            quantized: false,
        };
        let plan = ExecutablePlan {
            world: 2,
            per_rank: vec![
                RankProgram {
                    ops: vec![
                        PlanOp::Compute(seg(0)),
                        PlanOp::Wait(0),
                        PlanOp::Compute(seg(1)),
                    ],
                },
                RankProgram { ops: vec![PlanOp::Issue(reduce_xfer(&t, 0, 1, 0, 0))] },
            ],
            num_signals: 1,
            reserved_comm_sms: 0,
        };
        let prep = prepare(&plan, &t).unwrap();
        // A (op 0) gets the internal signal; B (op 2) does not
        assert_eq!(prep.call_signals.get(&(0, 0, 0)), Some(&1));
        assert!(!prep.call_signals.contains_key(&(0, 2, 0)));
        let PlanOp::Issue(d) = &prep.plan.per_rank[1].ops[0] else { panic!() };
        assert_eq!(d.dep_signals, vec![1], "transfer must wait for call A");
    }

    #[test]
    fn arena_sizing_fields_count_the_augmented_plan() {
        let t = table();
        let plan = ExecutablePlan {
            world: 3,
            per_rank: vec![
                RankProgram::default(),
                RankProgram { ops: vec![PlanOp::Issue(reduce_xfer(&t, 0, 1, 0, 0))] },
                RankProgram { ops: vec![PlanOp::Issue(reduce_xfer(&t, 1, 2, 0, 4))] },
            ],
            num_signals: 2,
            reserved_comm_sms: 0,
        };
        let prep = prepare(&plan, &t).unwrap();
        assert_eq!(prep.incoming, vec![2, 0, 0], "both transfers target rank 0");
        assert_eq!(prep.max_transfer_elems, 8, "2x4 rows regions");
    }

    #[test]
    fn non_accumulating_calls_are_ignored() {
        let t = table();
        let seg = ComputeSeg {
            tiles: vec![0],
            flops: vec![1.0],
            calls: vec![CallSpec::GemmRows {
                artifact: "g".into(),
                a: "y".into(),
                b: "y".into(),
                out: "y".into(),
                rows: (0, 2),
                accumulate: false,
            }],
            quantized: false,
        };
        let plan = ExecutablePlan {
            world: 2,
            per_rank: vec![
                RankProgram { ops: vec![PlanOp::Compute(seg)] },
                RankProgram { ops: vec![PlanOp::Issue(reduce_xfer(&t, 0, 1, 0, 0))] },
            ],
            num_signals: 1,
            reserved_comm_sms: 0,
        };
        let prep = prepare(&plan, &t).unwrap();
        assert!(prep.call_signals.is_empty());
        let PlanOp::Issue(d) = &prep.plan.per_rank[1].ops[0] else { panic!() };
        assert!(d.dep_signals.is_empty());
    }
}
