//! Cooperative plan interpreter with real numerics.
//!
//! Semantics match the simulator exactly (same plan, same signal protocol),
//! minus time: transfers complete as soon as their dependency signals are
//! set; compute calls run through the PJRT runtime. Ranks are stepped
//! round-robin; a full pass with no progress is a deadlock (and reported
//! with the stuck op).

use crate::chunk::TensorTable;
use crate::codegen::{CallSpec, ExecutablePlan, PlanOp, TransferDesc};
use crate::error::{Error, Result};
use crate::exec::buffers::BufferStore;
use crate::runtime::Runtime;

/// Execution statistics.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ExecStats {
    pub transfers: usize,
    pub bytes_moved: usize,
    pub compute_calls: usize,
    pub waits_hit: usize,
}

/// Run a plan to completion over real buffers.
pub fn run(
    plan: &ExecutablePlan,
    table: &TensorTable,
    store: &mut BufferStore,
    runtime: &Runtime,
) -> Result<ExecStats> {
    if store.world() != plan.world {
        return Err(Error::Exec(format!(
            "store world {} != plan world {}",
            store.world(),
            plan.world
        )));
    }
    plan.validate().map_err(|e| Error::Exec(format!("invalid plan: {e}")))?;
    let mut stats = ExecStats::default();
    let mut signals = vec![false; plan.num_signals];
    let mut pcs = vec![0usize; plan.world];
    // Transfers issued but blocked on dep signals.
    let mut pending: Vec<TransferDesc> = Vec::new();

    let tensor_name = |id| -> Result<String> { Ok(table.get(id)?.name.clone()) };

    let apply_transfer =
        |d: &TransferDesc, store: &mut BufferStore, stats: &mut ExecStats| -> Result<()> {
            let src_name = tensor_name(d.src_chunk.tensor)?;
            let dst_name = tensor_name(d.dst_chunk.tensor)?;
            let bytes = store.transfer(
                d.src_rank,
                &src_name,
                &d.src_chunk.region,
                d.dst_rank,
                &dst_name,
                &d.dst_chunk.region,
                d.reduce,
            )?;
            stats.transfers += 1;
            stats.bytes_moved += bytes;
            Ok(())
        };

    loop {
        let mut progress = false;

        // 1. retry pending transfers
        let mut still = Vec::new();
        for d in pending.drain(..) {
            if d.dep_signals.iter().all(|&s| signals[s]) {
                apply_transfer(&d, store, &mut stats)?;
                signals[d.signal] = true;
                progress = true;
            } else {
                still.push(d);
            }
        }
        pending = still;

        // 2. step each rank as far as it can go
        for rank in 0..plan.world {
            let prog = &plan.per_rank[rank];
            while pcs[rank] < prog.ops.len() {
                match &prog.ops[pcs[rank]] {
                    PlanOp::Overhead { .. } => {
                        pcs[rank] += 1;
                        progress = true;
                    }
                    PlanOp::Wait(sig) => {
                        if signals[*sig] {
                            stats.waits_hit += 1;
                            pcs[rank] += 1;
                            progress = true;
                        } else {
                            break; // blocked; try other ranks
                        }
                    }
                    PlanOp::Issue(d) => {
                        if d.dep_signals.iter().all(|&s| signals[s]) {
                            apply_transfer(d, store, &mut stats)?;
                            signals[d.signal] = true;
                        } else {
                            pending.push(d.clone());
                        }
                        pcs[rank] += 1;
                        progress = true;
                    }
                    PlanOp::Compute(seg) => {
                        for call in &seg.calls {
                            exec_call(call, rank, store, runtime)?;
                            stats.compute_calls += 1;
                        }
                        pcs[rank] += 1;
                        progress = true;
                    }
                }
            }
        }

        let all_done =
            pending.is_empty() && pcs.iter().enumerate().all(|(r, &pc)| pc >= plan.per_rank[r].ops.len());
        if all_done {
            return Ok(stats);
        }
        if !progress {
            let stuck: Vec<String> = (0..plan.world)
                .filter(|&r| pcs[r] < plan.per_rank[r].ops.len())
                .map(|r| format!("rank {r} at op {} ({:?})", pcs[r], plan.per_rank[r].ops[pcs[r]]))
                .collect();
            return Err(Error::Exec(format!(
                "deadlock: no progress; {} pending transfers; stuck: {}",
                pending.len(),
                stuck.join("; ")
            )));
        }
    }
}

/// Execute one compute call against the buffers.
fn exec_call(call: &CallSpec, rank: usize, store: &mut BufferStore, rt: &Runtime) -> Result<()> {
    use crate::chunk::Region;
    match call {
        CallSpec::GemmRows { artifact, a, b, out, rows, accumulate } => {
            let (r0, r1) = *rows;
            let k = store.shape(a)?[1];
            let n = store.shape(b)?[1];
            let a_rows = store.read_region(rank, a, &Region::rows(r0, r1 - r0, k))?;
            let b_full = store.get(rank, b)?.to_vec();
            let outs = rt.execute(
                artifact,
                &[(&a_rows, &[r1 - r0, k]), (&b_full, &[k, n])],
            )?;
            store.write_region(rank, out, &Region::rows(r0, r1 - r0, n), &outs[0], *accumulate)
        }
        CallSpec::AttnStep { artifact, q, k, v, kv_rows, acc, m, l } => {
            let (k0, k1) = *kv_rows;
            let d = store.shape(q)?[1];
            let sq = store.shape(q)?[0];
            let qv = store.get(rank, q)?.to_vec();
            let kv = store.read_region(rank, k, &Region::rows(k0, k1 - k0, d))?;
            let vv = store.read_region(rank, v, &Region::rows(k0, k1 - k0, d))?;
            let accv = store.get(rank, acc)?.to_vec();
            let mv = store.get(rank, m)?.to_vec();
            let lv = store.get(rank, l)?.to_vec();
            let outs = rt.execute(
                artifact,
                &[
                    (&qv, &[sq, d]),
                    (&kv, &[k1 - k0, d]),
                    (&vv, &[k1 - k0, d]),
                    (&accv, &[sq, d]),
                    (&mv, &[sq]),
                    (&lv, &[sq]),
                ],
            )?;
            store.set(rank, acc, &outs[0])?;
            store.set(rank, m, &outs[1])?;
            store.set(rank, l, &outs[2])
        }
        CallSpec::AttnFinalize { artifact, acc, l, out } => {
            let sq = store.shape(acc)?[0];
            let d = store.shape(acc)?[1];
            let accv = store.get(rank, acc)?.to_vec();
            let lv = store.get(rank, l)?.to_vec();
            let outs = rt.execute(artifact, &[(&accv, &[sq, d]), (&lv, &[sq])])?;
            store.set(rank, out, &outs[0])
        }
        CallSpec::FfnShard { artifact, x, w1, b1, w2, out, accumulate } => {
            let (m, d) = {
                let s = store.shape(x)?;
                (s[0], s[1])
            };
            let f = store.shape(w1)?[1];
            let xv = store.get(rank, x)?.to_vec();
            let w1v = store.get(rank, w1)?.to_vec();
            let b1v = store.get(rank, b1)?.to_vec();
            let w2v = store.get(rank, w2)?.to_vec();
            let outs = rt.execute(
                artifact,
                &[(&xv, &[m, d]), (&w1v, &[d, f]), (&b1v, &[f]), (&w2v, &[f, d])],
            )?;
            store.write_region(
                rank,
                out,
                &Region::rows(0, m, d),
                &outs[0],
                *accumulate,
            )
        }
        CallSpec::AddRows { x, out, rows } => {
            let (r0, r1) = *rows;
            let cols = store.shape(x)?[1];
            let xs = store.read_region(rank, x, &Region::rows(r0, r1 - r0, cols))?;
            store.write_region(rank, out, &Region::rows(r0, r1 - r0, cols), &xs, true)
        }
    }
}

#[cfg(test)]
mod tests {
    // The engine needs real PJRT artifacts; full coverage lives in
    // rust/tests/integration_exec.rs. Here we test the pure parts:
    // deadlock detection and transfer/signal mechanics with call-free plans.
    use super::*;
    use crate::chunk::{Chunk, DType, Region, TensorTable};
    use crate::codegen::{ComputeSeg, RankProgram};
    use crate::schedule::OpRef;

    fn table_and_store() -> (TensorTable, BufferStore) {
        let mut t = TensorTable::new();
        t.declare("x", &[4, 4], DType::F32).unwrap();
        let mut s = BufferStore::new(2);
        s.declare("x", &[4, 4]).unwrap();
        (t, s)
    }

    fn xfer(table: &TensorTable, signal: usize, src: usize, dst: usize, deps: Vec<usize>, reduce: bool) -> TransferDesc {
        let id = table.lookup("x").unwrap();
        let c = Chunk::new(id, Region::rows(0, 2, 4));
        TransferDesc {
            signal,
            op: OpRef { rank: src, index: signal },
            src_rank: src,
            dst_rank: dst,
            src_chunk: c.clone(),
            dst_chunk: c,
            bytes: 32,
            pieces: 1,
            backend: crate::backend::BackendKind::CopyEngine,
            comm_sms: 0,
            reduce,
            dep_signals: deps,
        }
    }

    fn fake_runtime() -> Runtime {
        // a Runtime pointing at an empty temp dir would fail; these tests
        // never exec compute calls, so build one lazily only if artifacts
        // exist. Otherwise skip via the caller.
        Runtime::open_default().expect("run `make artifacts` before cargo test")
    }

    #[test]
    fn transfer_and_signal_flow() {
        let (t, mut store) = table_and_store();
        store.set(0, "x", &[7.0; 16]).unwrap();
        let plan = ExecutablePlan {
            world: 2,
            per_rank: vec![
                RankProgram { ops: vec![PlanOp::Issue(xfer(&t, 0, 0, 1, vec![], false))] },
                RankProgram { ops: vec![PlanOp::Wait(0)] },
            ],
            num_signals: 1,
            reserved_comm_sms: 0,
        };
        let rt = fake_runtime();
        let stats = run(&plan, &t, &mut store, &rt).unwrap();
        assert_eq!(stats.transfers, 1);
        assert_eq!(stats.bytes_moved, 32);
        assert_eq!(stats.waits_hit, 1);
        assert_eq!(&store.get(1, "x").unwrap()[..8], &[7.0; 8]);
    }

    #[test]
    fn dep_signals_order_transfers() {
        let (t, mut store) = table_and_store();
        store.set(0, "x", &[1.0; 16]).unwrap();
        store.set(1, "x", &[1.0; 16]).unwrap();
        // rank0 push (reduce) into rank1 depends on rank1's push into rank0.
        let plan = ExecutablePlan {
            world: 2,
            per_rank: vec![
                RankProgram { ops: vec![PlanOp::Issue(xfer(&t, 0, 0, 1, vec![1], true)), PlanOp::Wait(1)] },
                RankProgram { ops: vec![PlanOp::Issue(xfer(&t, 1, 1, 0, vec![], true)), PlanOp::Wait(0)] },
            ],
            num_signals: 2,
            reserved_comm_sms: 0,
        };
        let rt = fake_runtime();
        let stats = run(&plan, &t, &mut store, &rt).unwrap();
        assert_eq!(stats.transfers, 2);
        // rank0 received 1.0+1.0 = 2.0 in first rows; rank1 then 1+2=3
        assert_eq!(store.get(0, "x").unwrap()[0], 2.0);
        assert_eq!(store.get(1, "x").unwrap()[0], 3.0);
    }

    #[test]
    fn deadlock_reported_with_stuck_rank() {
        let (t, mut store) = table_and_store();
        let plan = ExecutablePlan {
            world: 2,
            per_rank: vec![
                RankProgram { ops: vec![PlanOp::Wait(0)] },
                RankProgram { ops: vec![] },
            ],
            num_signals: 1,
            reserved_comm_sms: 0,
        };
        let rt = fake_runtime();
        let e = run(&plan, &t, &mut store, &rt).unwrap_err();
        assert!(e.to_string().contains("deadlock"), "{e}");
        assert!(e.to_string().contains("rank 0"), "{e}");
    }

    #[test]
    fn world_mismatch_rejected() {
        let (t, mut store) = table_and_store();
        let plan = ExecutablePlan {
            world: 3,
            per_rank: vec![RankProgram::default(); 3],
            num_signals: 0,
            reserved_comm_sms: 0,
        };
        let rt = fake_runtime();
        assert!(run(&plan, &t, &mut store, &rt).is_err());
    }

    #[test]
    fn empty_compute_segments_ok() {
        let (t, mut store) = table_and_store();
        let plan = ExecutablePlan {
            world: 2,
            per_rank: vec![
                RankProgram { ops: vec![PlanOp::Compute(ComputeSeg::default())] },
                RankProgram::default(),
            ],
            num_signals: 0,
            reserved_comm_sms: 0,
        };
        let rt = fake_runtime();
        let stats = run(&plan, &t, &mut store, &rt).unwrap();
        assert_eq!(stats.compute_calls, 0);
    }
}
