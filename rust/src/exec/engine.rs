//! Plan execution with real numerics: mode dispatch, the sequential
//! reference interpreter, and the compute-call evaluator shared with the
//! parallel engine.
//!
//! Two engines interpret the same [`PreparedPlan`]:
//!
//! * [`ExecMode::Sequential`] (this file) — the deterministic cooperative
//!   interpreter: ranks are stepped round-robin on one thread, transfers
//!   complete as soon as their dependency signals allow, and a full pass
//!   with no progress is reported as a deadlock with the stuck ops. This is
//!   the *reference semantics* every other execution strategy is checked
//!   against.
//! * [`ExecMode::Parallel`] — one worker thread per rank with bounded-wait
//!   deadlock detection, in one of two synchronization flavors selected by
//!   [`SyncStrategy`]: [`super::parallel`] (atomic board, rank-owned
//!   transfer queues, arena state — the production engine) or
//!   [`super::parallel_condvar`] (the retained condvar baseline the bench
//!   compares against). Thanks to the deterministic reduction order
//!   grafted in by [`super::plan_prep::prepare`], both produce
//!   bit-identical f32 results to the sequential engine (DESIGN.md §6,
//!   §15).

use crate::chunk::TensorTable;
use crate::codegen::{CallSpec, ExecutablePlan, PlanOp, TransferDesc};
use crate::error::{Error, Result};
use crate::exec::arena::PlanArena;
use crate::exec::buffers::BufferStore;
use crate::exec::plan_prep::{prepare, PreparedPlan};
use crate::exec::{ExecMode, ExecOptions, SyncStrategy};
use crate::runtime::Runtime;
use crate::trace::{Trace, TraceEvent, TraceKind, TraceSink};

/// Execution statistics.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ExecStats {
    pub transfers: usize,
    pub bytes_moved: usize,
    pub compute_calls: usize,
    pub waits_hit: usize,
}

impl ExecStats {
    pub(crate) fn merge(&mut self, other: &ExecStats) {
        self.transfers += other.transfers;
        self.bytes_moved += other.bytes_moved;
        self.compute_calls += other.compute_calls;
        self.waits_hit += other.waits_hit;
    }
}

/// Run a plan to completion over real buffers with the sequential
/// reference engine (back-compat entry point).
pub fn run(
    plan: &ExecutablePlan,
    table: &TensorTable,
    store: &BufferStore,
    runtime: &Runtime,
) -> Result<ExecStats> {
    run_with(plan, table, store, runtime, &ExecOptions::sequential())
}

/// Run a plan under an explicit [`ExecMode`]: validates the plan, builds
/// its [`PreparedPlan`], and executes once. Tune-once-run-many callers
/// should [`prepare`] once and call [`run_prepared`] per execution instead
/// of re-paying validation + plan prep on every run.
pub fn run_with(
    plan: &ExecutablePlan,
    table: &TensorTable,
    store: &BufferStore,
    runtime: &Runtime,
    opts: &ExecOptions,
) -> Result<ExecStats> {
    plan.validate().map_err(|e| Error::Exec(format!("invalid plan: {e}")))?;
    let prep = prepare(plan, table)?;
    run_prepared(&prep, store, runtime, opts)
}

/// Execute an already-prepared plan (see [`prepare`]). The plan inside a
/// [`PreparedPlan`] is assumed structurally valid — [`run_with`] validates
/// before preparing; callers constructing one directly should do the same.
pub fn run_prepared(
    prep: &PreparedPlan,
    store: &BufferStore,
    runtime: &Runtime,
    opts: &ExecOptions,
) -> Result<ExecStats> {
    run_prepared_sunk(prep, store, runtime, opts, None)
}

/// [`run_prepared`] with chunk-level event tracing: runs over a fresh
/// [`TraceSink`] and returns the captured [`Trace`] (fingerprint/meta
/// unstamped — callers who know the topology stamp it). Both engines emit
/// the same event *set* for a given prepared plan; timestamps differ.
pub fn run_prepared_traced(
    prep: &PreparedPlan,
    store: &BufferStore,
    runtime: &Runtime,
    opts: &ExecOptions,
) -> Result<(ExecStats, Trace)> {
    let sink = TraceSink::new(prep.plan.world);
    let stats = run_prepared_sunk(prep, store, runtime, opts, Some(&sink))?;
    Ok((stats, sink.into_trace(prep.plan.world)))
}

/// [`run_with`] + tracing (validate, prepare, execute once, capture).
pub fn run_with_traced(
    plan: &ExecutablePlan,
    table: &TensorTable,
    store: &BufferStore,
    runtime: &Runtime,
    opts: &ExecOptions,
) -> Result<(ExecStats, Trace)> {
    plan.validate().map_err(|e| Error::Exec(format!("invalid plan: {e}")))?;
    let prep = prepare(plan, table)?;
    run_prepared_traced(&prep, store, runtime, opts)
}

fn run_prepared_sunk(
    prep: &PreparedPlan,
    store: &BufferStore,
    runtime: &Runtime,
    opts: &ExecOptions,
    sink: Option<&TraceSink>,
) -> Result<ExecStats> {
    if store.world() != prep.plan.world {
        return Err(Error::Exec(format!(
            "store world {} != plan world {}",
            store.world(),
            prep.plan.world
        )));
    }
    let res = match (opts.mode, opts.sync) {
        (ExecMode::Sequential, _) => run_sequential(prep, store, runtime, sink),
        (ExecMode::Parallel, SyncStrategy::Atomic) => {
            super::parallel::run_parallel(prep, store, runtime, opts, sink)
        }
        (ExecMode::Parallel, SyncStrategy::Condvar) => {
            super::parallel_condvar::run_parallel_condvar(prep, store, runtime, opts, sink)
        }
    };
    // the sequential engine interprets ranks on this thread; return it to
    // the control lane whichever way the run exited
    crate::obs::flight::exit_rank();
    note_deadlock(&res);
    res
}

/// The shared deadlock-verdict path: every engine's verdict funnels
/// through here exactly once, so `error_total{kind=deadlock}` counts each
/// failed run once regardless of mode/sync, and a configured flight dump
/// path captures the post-mortem at the moment of the verdict.
fn note_deadlock<T>(res: &Result<T>) {
    if let Err(e) = res {
        if e.to_string().contains("deadlock") {
            crate::obs::error_total("deadlock");
            crate::obs::flight::dump_to_configured("deadlock");
        }
    }
}

/// Execute a prepared plan on the atomic parallel engine inside a
/// caller-owned [`PlanArena`] (see [`PlanArena::new`]): repeated runs of
/// one plan reuse every preallocated capacity, so the interpretation loop
/// allocates nothing after the first run. `opts.mode`/`opts.sync` are
/// ignored — this entry point IS the atomic parallel engine; only
/// `wait_timeout` and `pin_cores` apply.
pub fn run_prepared_reusing(
    prep: &PreparedPlan,
    arena: &mut PlanArena,
    store: &BufferStore,
    runtime: &Runtime,
    opts: &ExecOptions,
) -> Result<ExecStats> {
    if store.world() != prep.plan.world {
        return Err(Error::Exec(format!(
            "store world {} != plan world {}",
            store.world(),
            prep.plan.world
        )));
    }
    let res = super::parallel::run_parallel_in(prep, arena, store, runtime, opts, None);
    note_deadlock(&res);
    res
}

/// Apply one transfer to the buffers; returns the bytes moved.
pub(crate) fn apply_transfer(
    prep: &PreparedPlan,
    d: &TransferDesc,
    store: &BufferStore,
) -> Result<usize> {
    let src_name = prep.name(d.src_chunk.tensor)?;
    let dst_name = prep.name(d.dst_chunk.tensor)?;
    store.transfer(
        d.src_rank,
        src_name,
        &d.src_chunk.region,
        d.dst_rank,
        dst_name,
        &d.dst_chunk.region,
        d.reduce,
    )
}

/// [`apply_transfer`] staging through a caller-owned scratch buffer (the
/// atomic engine's zero-allocation copy path — the scratch lives in the
/// [`PlanArena`], sized for the plan's largest transfer).
pub(crate) fn apply_transfer_scratch(
    prep: &PreparedPlan,
    d: &TransferDesc,
    store: &BufferStore,
    scratch: &mut Vec<f32>,
) -> Result<usize> {
    let src_name = prep.name(d.src_chunk.tensor)?;
    let dst_name = prep.name(d.dst_chunk.tensor)?;
    store.transfer_into(
        d.src_rank,
        src_name,
        &d.src_chunk.region,
        d.dst_rank,
        dst_name,
        &d.dst_chunk.region,
        d.reduce,
        scratch,
    )
}

/// Plan op index of the `Issue` for `d` on its source rank, resolved by
/// completion signal (plan-unique, so the scan is unambiguous). Anchors
/// the transfer's trace event into the source rank's program order for
/// the critical-path profiler. Only called on the traced path — the
/// untraced hot path never pays the scan.
fn issue_op_index(prep: &PreparedPlan, d: &TransferDesc) -> usize {
    prep.plan.per_rank[d.src_rank]
        .ops
        .iter()
        .position(|op| matches!(op, crate::codegen::PlanOp::Issue(t) if t.signal == d.signal))
        .unwrap_or(usize::MAX)
}

/// [`apply_transfer_scratch`] with the span recorded on the source rank's
/// comm lane (same event shape as [`apply_transfer_sunk`], so traces are
/// engine-agnostic). `sink == None` is the untraced hot path: one dead
/// branch, no clock reads.
pub(crate) fn apply_transfer_scratch_sunk(
    prep: &PreparedPlan,
    d: &TransferDesc,
    store: &BufferStore,
    scratch: &mut Vec<f32>,
    sink: Option<&TraceSink>,
) -> Result<usize> {
    let Some(sink) = sink else {
        return apply_transfer_scratch(prep, d, store, scratch);
    };
    let t0 = sink.now_us();
    let bytes = apply_transfer_scratch(prep, d, store, scratch)?;
    sink.push(TraceEvent {
        start_us: t0,
        end_us: sink.now_us(),
        kind: TraceKind::Transfer {
            src: d.src_rank,
            dst: d.dst_rank,
            op: issue_op_index(prep, d),
            bytes: d.bytes,
            pieces: d.pieces,
            backend: d.backend,
            comm_sms: d.comm_sms,
            reduce: d.reduce,
            signal: d.signal,
        },
    });
    Ok(bytes)
}

/// [`apply_transfer`] with the span recorded on the source rank's comm
/// lane. `sink == None` is the untraced hot path: one dead branch, no
/// clock reads.
pub(crate) fn apply_transfer_sunk(
    prep: &PreparedPlan,
    d: &TransferDesc,
    store: &BufferStore,
    sink: Option<&TraceSink>,
) -> Result<usize> {
    let Some(sink) = sink else {
        return apply_transfer(prep, d, store);
    };
    let t0 = sink.now_us();
    let bytes = apply_transfer(prep, d, store)?;
    sink.push(TraceEvent {
        start_us: t0,
        end_us: sink.now_us(),
        kind: TraceKind::Transfer {
            src: d.src_rank,
            dst: d.dst_rank,
            op: issue_op_index(prep, d),
            bytes: d.bytes,
            pieces: d.pieces,
            backend: d.backend,
            comm_sms: d.comm_sms,
            reduce: d.reduce,
            signal: d.signal,
        },
    });
    Ok(bytes)
}

/// Record a whole compute segment's span (its kernel calls nest inside,
/// pushed individually by the engines). No event for call-free segments —
/// they execute nothing, and both engines apply the same rule so event
/// sets stay identical.
pub(crate) fn push_seg_event(
    sink: &TraceSink,
    rank: usize,
    op_index: usize,
    seg: &crate::codegen::ComputeSeg,
    start_us: f64,
    end_us: f64,
) {
    sink.push(TraceEvent {
        start_us,
        end_us,
        kind: TraceKind::Compute {
            rank,
            op: op_index,
            calls: seg.calls.len(),
            tiles: seg.tiles.len(),
            flops: seg.total_flops(),
            quantized: seg.quantized,
        },
    });
}

/// Run one kernel call with its span recorded.
pub(crate) fn exec_call_sunk(
    call: &CallSpec,
    rank: usize,
    op_index: usize,
    call_index: usize,
    store: &BufferStore,
    rt: &Runtime,
    sink: Option<&TraceSink>,
) -> Result<()> {
    let Some(sink) = sink else {
        return exec_call(call, rank, store, rt);
    };
    let t0 = sink.now_us();
    exec_call(call, rank, store, rt)?;
    sink.push(TraceEvent {
        start_us: t0,
        end_us: sink.now_us(),
        kind: TraceKind::Kernel {
            rank,
            op: op_index,
            call: call_index,
            artifact: call.artifact_name().to_string(),
        },
    });
    Ok(())
}

fn run_sequential(
    prep: &PreparedPlan,
    store: &BufferStore,
    runtime: &Runtime,
    sink: Option<&TraceSink>,
) -> Result<ExecStats> {
    let plan = &prep.plan;
    let mut stats = ExecStats::default();
    let mut signals = vec![false; plan.num_signals];
    let mut pcs = vec![0usize; plan.world];
    // Transfers issued but blocked on dep signals.
    let mut pending: Vec<TransferDesc> = Vec::new();
    // When tracing: the time each rank first blocked at its current Wait,
    // so the wait span covers the whole cooperative stall.
    let mut wait_from: Vec<Option<f64>> = vec![None; plan.world];

    loop {
        let mut progress = false;

        // 1. retry pending transfers
        let mut still = Vec::new();
        for d in pending.drain(..) {
            if d.dep_signals.iter().all(|&s| signals[s]) {
                let bytes = apply_transfer_sunk(prep, &d, store, sink)?;
                stats.transfers += 1;
                stats.bytes_moved += bytes;
                signals[d.signal] = true;
                // deferred apply: op index unknown here, sentinel a=MAX
                crate::obs::flight::op_apply(d.src_rank, usize::MAX, d.signal);
                progress = true;
            } else {
                still.push(d);
            }
        }
        pending = still;

        // 2. step each rank as far as it can go
        for rank in 0..plan.world {
            crate::obs::flight::enter_rank(rank);
            let prog = &plan.per_rank[rank];
            while pcs[rank] < prog.ops.len() {
                let op_index = pcs[rank];
                match &prog.ops[op_index] {
                    PlanOp::Overhead { .. } => {
                        pcs[rank] += 1;
                        progress = true;
                    }
                    PlanOp::Wait(sig) => {
                        if signals[*sig] {
                            if let Some(s) = sink {
                                let now = s.now_us();
                                s.push(TraceEvent {
                                    start_us: wait_from[rank].take().unwrap_or(now),
                                    end_us: now,
                                    kind: TraceKind::Wait { rank, op: op_index, signal: *sig },
                                });
                            }
                            stats.waits_hit += 1;
                            pcs[rank] += 1;
                            progress = true;
                        } else {
                            crate::obs::flight::signal_wait(rank, op_index, *sig);
                            if let Some(s) = sink {
                                if wait_from[rank].is_none() {
                                    wait_from[rank] = Some(s.now_us());
                                }
                            }
                            break; // blocked; try other ranks
                        }
                    }
                    PlanOp::Issue(d) => {
                        crate::obs::flight::op_issue(rank, op_index);
                        if d.dep_signals.iter().all(|&s| signals[s]) {
                            let bytes = apply_transfer_sunk(prep, d, store, sink)?;
                            stats.transfers += 1;
                            stats.bytes_moved += bytes;
                            signals[d.signal] = true;
                            crate::obs::flight::op_apply(rank, op_index, d.signal);
                        } else {
                            pending.push(d.clone());
                        }
                        pcs[rank] += 1;
                        progress = true;
                    }
                    PlanOp::Compute(seg) => {
                        let seg_start = sink.map(|s| s.now_us());
                        for (ci, call) in seg.calls.iter().enumerate() {
                            exec_call_sunk(call, rank, op_index, ci, store, runtime, sink)?;
                            stats.compute_calls += 1;
                            if let Some(&ps) = prep.call_signals.get(&(rank, op_index, ci)) {
                                signals[ps] = true;
                            }
                        }
                        if let (Some(s), Some(t0)) = (sink, seg_start) {
                            if !seg.calls.is_empty() {
                                push_seg_event(s, rank, op_index, seg, t0, s.now_us());
                            }
                        }
                        pcs[rank] += 1;
                        progress = true;
                    }
                }
            }
        }

        let all_done = pending.is_empty()
            && pcs.iter().enumerate().all(|(r, &pc)| pc >= plan.per_rank[r].ops.len());
        if all_done {
            return Ok(stats);
        }
        if !progress {
            let stuck_ranks: Vec<usize> =
                (0..plan.world).filter(|&r| pcs[r] < plan.per_rank[r].ops.len()).collect();
            let stuck: Vec<String> = stuck_ranks
                .iter()
                .map(|&r| {
                    format!("rank {r} at op {} ({})", pcs[r], plan.per_rank[r].ops[pcs[r]].brief())
                })
                .collect();
            // error_total{kind=deadlock} and the post-mortem dump happen on
            // the shared verdict path in run_prepared_sunk, not here
            let ctx = crate::obs::flight::verdict_context(&stuck_ranks, 8);
            return Err(Error::Exec(format!(
                "deadlock: no progress; {} pending transfers; stuck: {}{ctx}",
                pending.len(),
                stuck.join("; ")
            )));
        }
    }
}

/// Execute one compute call against the buffers.
///
/// Whole-buffer kernel inputs are borrowed zero-copy via
/// [`BufferStore::read_guard`]; every guard lives inside the block that
/// computes `outs` and is dropped before any write-back, so a call whose
/// output tensor is also an input cannot self-deadlock on the `RwLock`.
/// Region inputs go through `read_region` (extraction copies regardless).
pub(crate) fn exec_call(
    call: &CallSpec,
    rank: usize,
    store: &BufferStore,
    rt: &Runtime,
) -> Result<()> {
    use crate::chunk::Region;
    match call {
        CallSpec::GemmRows { artifact, a, b, out, rows, accumulate } => {
            let (r0, r1) = *rows;
            let k = store.shape(a)?[1];
            let n = store.shape(b)?[1];
            let a_rows = store.read_region(rank, a, &Region::rows(r0, r1 - r0, k))?;
            let outs = {
                let b_full = store.read_guard(rank, b)?;
                rt.execute(artifact, &[(&a_rows, &[r1 - r0, k]), (&b_full[..], &[k, n])])?
            };
            store.write_region(rank, out, &Region::rows(r0, r1 - r0, n), &outs[0], *accumulate)
        }
        CallSpec::AttnStep { artifact, q, k, v, kv_rows, acc, m, l } => {
            let (k0, k1) = *kv_rows;
            let d = store.shape(q)?[1];
            let sq = store.shape(q)?[0];
            let kv = store.read_region(rank, k, &Region::rows(k0, k1 - k0, d))?;
            let vv = store.read_region(rank, v, &Region::rows(k0, k1 - k0, d))?;
            let outs = {
                let qv = store.read_guard(rank, q)?;
                let accv = store.read_guard(rank, acc)?;
                let mv = store.read_guard(rank, m)?;
                let lv = store.read_guard(rank, l)?;
                rt.execute(
                    artifact,
                    &[
                        (&qv[..], &[sq, d]),
                        (&kv, &[k1 - k0, d]),
                        (&vv, &[k1 - k0, d]),
                        (&accv[..], &[sq, d]),
                        (&mv[..], &[sq]),
                        (&lv[..], &[sq]),
                    ],
                )?
            };
            store.set(rank, acc, &outs[0])?;
            store.set(rank, m, &outs[1])?;
            store.set(rank, l, &outs[2])
        }
        CallSpec::AttnFinalize { artifact, acc, l, out } => {
            let sq = store.shape(acc)?[0];
            let d = store.shape(acc)?[1];
            let outs = {
                let accv = store.read_guard(rank, acc)?;
                let lv = store.read_guard(rank, l)?;
                rt.execute(artifact, &[(&accv[..], &[sq, d]), (&lv[..], &[sq])])?
            };
            store.set(rank, out, &outs[0])
        }
        CallSpec::FfnShard { artifact, x, w1, b1, w2, out, accumulate } => {
            let (m, d) = {
                let s = store.shape(x)?;
                (s[0], s[1])
            };
            let f = store.shape(w1)?[1];
            let outs = {
                let xv = store.read_guard(rank, x)?;
                let w1v = store.read_guard(rank, w1)?;
                let b1v = store.read_guard(rank, b1)?;
                let w2v = store.read_guard(rank, w2)?;
                rt.execute(
                    artifact,
                    &[
                        (&xv[..], &[m, d]),
                        (&w1v[..], &[d, f]),
                        (&b1v[..], &[f]),
                        (&w2v[..], &[f, d]),
                    ],
                )?
            };
            store.write_region(rank, out, &Region::rows(0, m, d), &outs[0], *accumulate)
        }
        CallSpec::AddRows { x, out, rows } => {
            let (r0, r1) = *rows;
            let cols = store.shape(x)?[1];
            let xs = store.read_region(rank, x, &Region::rows(r0, r1 - r0, cols))?;
            store.write_region(rank, out, &Region::rows(r0, r1 - r0, cols), &xs, true)
        }
    }
}

#[cfg(test)]
mod tests {
    // Signal/transfer mechanics with call-free plans, exercised under BOTH
    // engines (the host-reference runtime means no artifacts are needed).
    // Full-stack coverage lives in rust/tests/integration_exec.rs and
    // rust/tests/integration_parallel.rs.
    use super::*;
    use crate::chunk::{DType, Region, TensorTable};
    use crate::codegen::{ComputeSeg, RankProgram};
    use std::time::Duration;

    fn table_and_store() -> (TensorTable, BufferStore) {
        let mut t = TensorTable::new();
        t.declare("x", &[4, 4], DType::F32).unwrap();
        let mut s = BufferStore::new(2);
        s.declare("x", &[4, 4]).unwrap();
        (t, s)
    }

    fn xfer(
        table: &TensorTable,
        signal: usize,
        src: usize,
        dst: usize,
        deps: Vec<usize>,
        reduce: bool,
    ) -> TransferDesc {
        let id = table.lookup("x").unwrap();
        crate::testutil::transfer_desc(id, Region::rows(0, 2, 4), signal, src, dst, deps, reduce)
    }

    fn runtime() -> Runtime {
        Runtime::host_reference()
    }

    fn both_modes() -> [ExecOptions; 3] {
        // "both" engines, with the parallel one in both sync flavors
        [
            ExecOptions::sequential(),
            ExecOptions {
                mode: ExecMode::Parallel,
                wait_timeout: Duration::from_secs(5),
                ..ExecOptions::parallel()
            },
            ExecOptions {
                mode: ExecMode::Parallel,
                wait_timeout: Duration::from_secs(5),
                sync: SyncStrategy::Condvar,
                ..ExecOptions::parallel()
            },
        ]
    }

    #[test]
    fn transfer_and_signal_flow() {
        for opts in both_modes() {
            let (t, mut store) = table_and_store();
            store.set(0, "x", &[7.0; 16]).unwrap();
            let plan = ExecutablePlan {
                world: 2,
                per_rank: vec![
                    RankProgram { ops: vec![PlanOp::Issue(xfer(&t, 0, 0, 1, vec![], false))] },
                    RankProgram { ops: vec![PlanOp::Wait(0)] },
                ],
                num_signals: 1,
                reserved_comm_sms: 0,
            };
            let rt = runtime();
            let stats = run_with(&plan, &t, &mut store, &rt, &opts).unwrap();
            assert_eq!(stats.transfers, 1);
            assert_eq!(stats.bytes_moved, 32);
            assert_eq!(stats.waits_hit, 1);
            assert_eq!(&store.get(1, "x").unwrap()[..8], &[7.0; 8]);
        }
    }

    #[test]
    fn dep_signals_order_transfers() {
        for opts in both_modes() {
            let (t, mut store) = table_and_store();
            store.set(0, "x", &[1.0; 16]).unwrap();
            store.set(1, "x", &[1.0; 16]).unwrap();
            // rank0 push (reduce) into rank1 depends on rank1's push into rank0.
            let plan = ExecutablePlan {
                world: 2,
                per_rank: vec![
                    RankProgram {
                        ops: vec![
                            PlanOp::Issue(xfer(&t, 0, 0, 1, vec![1], true)),
                            PlanOp::Wait(1),
                        ],
                    },
                    RankProgram {
                        ops: vec![
                            PlanOp::Issue(xfer(&t, 1, 1, 0, vec![], true)),
                            PlanOp::Wait(0),
                        ],
                    },
                ],
                num_signals: 2,
                reserved_comm_sms: 0,
            };
            let rt = runtime();
            let stats = run_with(&plan, &t, &mut store, &rt, &opts).unwrap();
            assert_eq!(stats.transfers, 2);
            // rank0 received 1.0+1.0 = 2.0 in first rows; rank1 then 1+2=3
            assert_eq!(store.get(0, "x").unwrap()[0], 2.0);
            assert_eq!(store.get(1, "x").unwrap()[0], 3.0);
        }
    }

    #[test]
    fn deadlock_reported_with_stuck_rank() {
        let (t, mut store) = table_and_store();
        let plan = ExecutablePlan {
            world: 2,
            per_rank: vec![
                RankProgram {
                    ops: vec![
                        PlanOp::Wait(0),
                        PlanOp::Issue(xfer(&t, 0, 0, 1, vec![], false)),
                    ],
                },
                RankProgram { ops: vec![] },
            ],
            num_signals: 1,
            reserved_comm_sms: 0,
        };
        let rt = runtime();
        let e = run(&plan, &t, &mut store, &rt).unwrap_err();
        assert!(e.to_string().contains("deadlock"), "{e}");
        assert!(e.to_string().contains("rank 0"), "{e}");
        // both parallel engines report it too, within the bounded wait
        for sync in [SyncStrategy::Atomic, SyncStrategy::Condvar] {
            let opts = ExecOptions {
                mode: ExecMode::Parallel,
                wait_timeout: Duration::from_millis(100),
                sync,
                ..ExecOptions::parallel()
            };
            let e = run_with(&plan, &t, &mut store, &rt, &opts).unwrap_err();
            assert!(e.to_string().contains("deadlock"), "{e}");
        }
    }

    #[test]
    fn world_mismatch_rejected() {
        let (t, mut store) = table_and_store();
        let plan = ExecutablePlan {
            world: 3,
            per_rank: vec![RankProgram::default(); 3],
            num_signals: 0,
            reserved_comm_sms: 0,
        };
        let rt = runtime();
        assert!(run(&plan, &t, &mut store, &rt).is_err());
    }

    #[test]
    fn traced_runs_match_untraced_stats_and_agree_across_engines() {
        // The same plan under both engines: identical ExecStats to the
        // untraced path, and identical timestamp-free event SETS (the
        // cross-engine identity the trace subsystem guarantees).
        let build_plan = |t: &TensorTable| ExecutablePlan {
            world: 2,
            per_rank: vec![
                RankProgram {
                    ops: vec![
                        PlanOp::Issue(xfer(t, 0, 0, 1, vec![], false)),
                        PlanOp::Compute(ComputeSeg::default()), // call-free: no event
                    ],
                },
                RankProgram { ops: vec![PlanOp::Wait(0)] },
            ],
            num_signals: 1,
            reserved_comm_sms: 0,
        };
        let rt = runtime();
        let mut keysets = Vec::new();
        for opts in both_modes() {
            let (t, mut store) = table_and_store();
            store.set(0, "x", &[3.0; 16]).unwrap();
            let plan = build_plan(&t);
            let (stats, trace) = run_with_traced(&plan, &t, &mut store, &rt, &opts).unwrap();
            assert_eq!(stats.transfers, 1);
            assert_eq!(stats.waits_hit, 1);
            assert_eq!(trace.world, 2);
            assert_eq!(trace.count("transfer"), 1);
            assert_eq!(trace.count("wait"), 1);
            assert_eq!(trace.count("compute"), 0, "call-free segs emit no event");
            for ev in &trace.events {
                assert!(ev.end_us >= ev.start_us);
            }
            keysets.push(trace.event_keys());
        }
        for k in &keysets[1..] {
            assert_eq!(&keysets[0], k, "engines must agree on the event set");
        }
    }

    #[test]
    fn arena_reuse_entry_point_matches_fresh_runs() {
        // the public reuse API: same plan, same arena, repeated runs — each
        // must match what a fresh parallel run produces
        let (t, store) = table_and_store();
        let plan = ExecutablePlan {
            world: 2,
            per_rank: vec![
                RankProgram { ops: vec![PlanOp::Issue(xfer(&t, 0, 0, 1, vec![], false))] },
                RankProgram { ops: vec![PlanOp::Wait(0)] },
            ],
            num_signals: 1,
            reserved_comm_sms: 0,
        };
        let rt = runtime();
        let prep = prepare(&plan, &t).unwrap();
        let mut arena = PlanArena::new(&prep);
        let opts = ExecOptions::parallel();
        for _ in 0..2 {
            let run_store = store.clone();
            run_store.set(0, "x", &[9.0; 16]).unwrap();
            let stats =
                super::run_prepared_reusing(&prep, &mut arena, &run_store, &rt, &opts).unwrap();
            assert_eq!(stats.transfers, 1);
            assert_eq!(&run_store.get(1, "x").unwrap()[..8], &[9.0; 8]);
        }
        // world-mismatched store is rejected before touching the engine
        let bad = BufferStore::new(3);
        assert!(super::run_prepared_reusing(&prep, &mut arena, &bad, &rt, &opts).is_err());
    }

    #[test]
    fn empty_compute_segments_ok() {
        for opts in both_modes() {
            let (t, mut store) = table_and_store();
            let plan = ExecutablePlan {
                world: 2,
                per_rank: vec![
                    RankProgram { ops: vec![PlanOp::Compute(ComputeSeg::default())] },
                    RankProgram::default(),
                ],
                num_signals: 0,
                reserved_comm_sms: 0,
            };
            let rt = runtime();
            let stats = run_with(&plan, &t, &mut store, &rt, &opts).unwrap();
            assert_eq!(stats.compute_calls, 0);
        }
    }
}
