//! Chunk↔tile dependence graph and minimal synchronization insertion
//! (paper §5.2, "Dependency Parsing").
//!
//! For each chunk we track its producer(s) and consumer(s): which comm op
//! materializes it on a rank, which tiles read it, and which tiles must
//! finish before an outgoing op may read its source region. From this the
//! compiler derives the *minimal* set of wait points — a tile consuming a
//! chunk cannot start before the chunk's transfer completes, and a transfer
//! reading kernel output cannot issue before its producing tiles finish —
//! and nothing more. The conservative alternative (barrier per wave /
//! kernel boundary) is also provided for the `ablation_sync` study.

use std::collections::HashMap;


use crate::error::{Error, Result};
use crate::kernel::grid::TileId;
use crate::kernel::scheduler::TileScheduler;
use crate::schedule::{CommSchedule, OpRef};
use crate::topo::Rank;

/// Chunk↔tile containment for one rank's view of a schedule.
///
/// Built by the operator layer (it knows how tensor regions map to grid
/// axes); consumed by sync planning, the scheduler swizzle and codegen.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ChunkTileMap {
    /// Comm op -> tiles (on the op's destination rank) that READ the chunk
    /// the op delivers.
    pub consumers: HashMap<OpRef, Vec<TileId>>,
    /// Comm op -> tiles (on the op's source rank) that WRITE the region the
    /// op sends. Empty = the source data pre-exists (weights, inputs).
    pub producers: HashMap<OpRef, Vec<TileId>>,
}

impl ChunkTileMap {
    /// Tiles feeding from any comm op, grouped per op — the chunk groups the
    /// scheduler swizzle consumes.
    ///
    /// Grouping is keyed on the op's position in the *arrival* list (see
    /// [`ChunkTileMap::arrival_order`]); the `rank` argument is currently
    /// informational (maps are already built per-rank) but kept for API
    /// stability with multi-rank maps.
    /// A tile fed by several ops (e.g. both the K and the V chunk of the
    /// same rows) is assigned to its LAST-arriving op's group — it cannot
    /// start earlier anyway. Group keys are compacted to `0..n` in arrival
    /// order, matching the `arrival` list expected by
    /// [`crate::kernel::scheduler::TileScheduler::chunk_major`].
    pub fn consumer_groups(&self, _rank: Rank) -> HashMap<usize, Vec<TileId>> {
        let order = self.arrival_order();
        // tile -> latest arrival index among its feeding ops, dense vectors
        // (this runs once per rank per compile; hashed maps dominated the
        // profile — perf pass, EXPERIMENTS §Perf)
        let max_tile = self
            .consumers
            .values()
            .flat_map(|ts| ts.iter().copied())
            .max()
            .map(|t| t + 1)
            .unwrap_or(0);
        let mut latest: Vec<Option<usize>> = vec![None; max_tile];
        for (k, op) in order.iter().enumerate() {
            if let Some(tiles) = self.consumers.get(op) {
                for &t in tiles {
                    latest[t] = Some(latest[t].map_or(k, |e| e.max(k)));
                }
            }
        }
        let mut by_arrival: Vec<Vec<TileId>> = vec![Vec::new(); order.len()];
        for (t, k) in latest.into_iter().enumerate() {
            if let Some(k) = k {
                by_arrival[k].push(t); // ascending t by construction
            }
        }
        let mut g = HashMap::new();
        let mut compact = 0usize;
        for tiles in by_arrival {
            if !tiles.is_empty() {
                g.insert(compact, tiles);
                compact += 1;
            }
        }
        g
    }

    /// Deterministic arrival order of consumed ops: ops sorted by
    /// (rank, index) — the issue order of the schedule. The simulator may
    /// refine this with measured completion times; for planning, issue order
    /// is the canonical estimate.
    pub fn arrival_order(&self) -> Vec<OpRef> {
        let mut ops: Vec<OpRef> = self.consumers.keys().copied().collect();
        ops.sort();
        ops
    }
}

/// A wait inserted before the tile at `before_pos` in the visiting order:
/// the tile must not start until `op`'s transfer signal is set.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Wait {
    pub before_pos: usize,
    pub op: OpRef,
}

/// An outgoing-op trigger: the rank's comm op at `op_index` may issue only
/// after the tile at `after_pos` completes (`None` = issue immediately).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Trigger {
    pub after_pos: Option<usize>,
    pub op_index: usize,
}

/// Synchronization plan for one rank: minimal waits + issue triggers.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct RankSync {
    pub waits: Vec<Wait>,
    pub triggers: Vec<Trigger>,
}

impl RankSync {
    /// Number of distinct wait points (the §Perf/ablation metric).
    pub fn num_waits(&self) -> usize {
        self.waits.len()
    }
}

/// Compute the minimal synchronization plan for `rank`.
///
/// * For every op delivering a chunk consumed by this rank's tiles, one wait
///   is placed before the *earliest* consuming tile in `order` — later
///   consumers are covered transitively (signals are sticky).
/// * For every op this rank issues whose source region is written by tiles,
///   a trigger is placed after the *latest* producing tile.
pub fn plan_rank_sync(
    rank: Rank,
    sched: &CommSchedule,
    order: &TileScheduler,
    map: &ChunkTileMap,
) -> Result<RankSync> {
    let pos = order.positions().map_err(|e| {
        // hand-edited / imported plans reach this path: name the subsystem
        Error::DepGraph(format!("rank {rank}: {e}"))
    })?;
    let n = order.order.len();
    let mut waits = Vec::new();
    for (op, tiles) in &map.consumers {
        // the wait belongs on the rank whose buffer receives the chunk
        let dst = sched.op(*op)?.dst_rank(op.rank);
        if dst != rank || tiles.is_empty() {
            continue;
        }
        let earliest = tiles
            .iter()
            .map(|&t| {
                if t >= n {
                    Err(Error::DepGraph(format!("consumer tile {t} out of range {n}")))
                } else {
                    Ok(pos[t])
                }
            })
            .collect::<Result<Vec<_>>>()?
            .into_iter()
            .min()
            .unwrap();
        waits.push(Wait { before_pos: earliest, op: *op });
    }
    waits.sort_by_key(|w| (w.before_pos, w.op));

    let mut triggers = Vec::new();
    for (op_index, _op) in sched.per_rank[rank].iter().enumerate() {
        let opref = OpRef { rank, index: op_index };
        let after_pos = match map.producers.get(&opref) {
            None => None,
            Some(tiles) if tiles.is_empty() => None,
            Some(tiles) => {
                let latest = tiles
                    .iter()
                    .map(|&t| {
                        if t >= n {
                            Err(Error::DepGraph(format!(
                                "producer tile {t} out of range {n}"
                            )))
                        } else {
                            Ok(pos[t])
                        }
                    })
                    .collect::<Result<Vec<_>>>()?
                    .into_iter()
                    .max()
                    .unwrap();
                Some(latest)
            }
        };
        triggers.push(Trigger { after_pos, op_index });
    }
    Ok(RankSync { waits, triggers })
}

/// Conservative baseline: wait for ALL incoming chunks before the first tile
/// that consumes anything, and issue producer-fed transfers only after the
/// LAST tile (the kernel-boundary sync of kernel-level overlap —
/// `total_tiles` is the rank's tile count).
pub fn plan_rank_sync_barrier(
    rank: Rank,
    sched: &CommSchedule,
    map: &ChunkTileMap,
    total_tiles: usize,
) -> Result<RankSync> {
    let mut waits = Vec::new();
    for (op, tiles) in &map.consumers {
        let dst = sched.op(*op)?.dst_rank(op.rank);
        if dst != rank || tiles.is_empty() {
            continue;
        }
        waits.push(Wait { before_pos: 0, op: *op });
    }
    waits.sort_by_key(|w| (w.before_pos, w.op));
    let triggers = (0..sched.per_rank[rank].len())
        .map(|op_index| {
            let opref = OpRef { rank, index: op_index };
            let fed_by_tiles =
                map.producers.get(&opref).map(|t| !t.is_empty()).unwrap_or(false);
            Trigger {
                after_pos: if fed_by_tiles && total_tiles > 0 {
                    Some(total_tiles - 1)
                } else {
                    None
                },
                op_index,
            }
        })
        .collect();
    Ok(RankSync { waits, triggers })
}

/// Exposure analysis used by ablations: with minimal sync, how many tiles
/// can run before the first wait (pipeline fill), vs zero under a barrier.
pub fn tiles_before_first_wait(sync: &RankSync, total_tiles: usize) -> usize {
    sync.waits.iter().map(|w| w.before_pos).min().unwrap_or(total_tiles)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chunk::{Chunk, DType, Region, TensorTable};
    use crate::kernel::grid::TileGrid;
    use crate::schedule::{CommOp, TransferKind};

    /// 2-rank schedule: rank1 pushes two chunks into rank0; rank0 pushes one
    /// chunk out whose region rank0's tiles produce.
    fn setup() -> (CommSchedule, TileGrid, ChunkTileMap) {
        let mut t = TensorTable::new();
        let x = t.declare("x", &[8, 16], DType::F32).unwrap();
        let mut s = CommSchedule::new(2, t);
        let c0 = Chunk::new(x, Region::rows(0, 2, 16));
        let c1 = Chunk::new(x, Region::rows(2, 2, 16));
        let c2 = Chunk::new(x, Region::rows(4, 2, 16));
        // rank 1 pushes c0 then c1 into rank 0
        for c in [&c0, &c1] {
            s.add_op(
                1,
                CommOp::P2p {
                    kind: TransferKind::Push,
                    peer: 0,
                    src: c.clone(),
                    dst: c.clone(),
                    reduce: false,
                    deps: vec![],
                },
            )
            .unwrap();
        }
        // rank 0 pushes c2 (produced by its tiles) to rank 1
        s.add_op(
            0,
            CommOp::P2p {
                kind: TransferKind::Push,
                peer: 1,
                src: c2.clone(),
                dst: c2,
                reduce: false,
                deps: vec![],
            },
        )
        .unwrap();

        let grid = TileGrid::gemm(8, 16, 2, 16).unwrap(); // 4 tiles (M rows)
        let mut map = ChunkTileMap::default();
        // tiles 0,1 consume the two incoming chunks
        map.consumers.insert(OpRef { rank: 1, index: 0 }, vec![0]);
        map.consumers.insert(OpRef { rank: 1, index: 1 }, vec![1]);
        // outgoing op reads region produced by tiles 2 and 3
        map.producers.insert(OpRef { rank: 0, index: 0 }, vec![2, 3]);
        (s, grid, map)
    }

    #[test]
    fn minimal_waits_at_earliest_consumer() {
        let (s, grid, map) = setup();
        let order = TileScheduler::row_major(&grid);
        let sync = plan_rank_sync(0, &s, &order, &map).unwrap();
        assert_eq!(sync.num_waits(), 2);
        assert_eq!(sync.waits[0], Wait { before_pos: 0, op: OpRef { rank: 1, index: 0 } });
        assert_eq!(sync.waits[1], Wait { before_pos: 1, op: OpRef { rank: 1, index: 1 } });
    }

    #[test]
    fn trigger_after_latest_producer() {
        let (s, grid, map) = setup();
        let order = TileScheduler::row_major(&grid);
        let sync = plan_rank_sync(0, &s, &order, &map).unwrap();
        assert_eq!(sync.triggers.len(), 1);
        assert_eq!(sync.triggers[0], Trigger { after_pos: Some(3), op_index: 0 });
    }

    #[test]
    fn waits_follow_swizzled_order() {
        let (s, grid, map) = setup();
        // reversed order: tile 1 now earlier than tile 0
        let order = TileScheduler { order: vec![3, 2, 1, 0] };
        assert!(order.is_permutation(grid.num_tiles()));
        let sync = plan_rank_sync(0, &s, &order, &map).unwrap();
        // op1's consumer (tile 1) now at pos 2; op0's (tile 0) at pos 3
        assert_eq!(sync.waits[0].before_pos, 2);
        assert_eq!(sync.waits[0].op, OpRef { rank: 1, index: 1 });
        assert_eq!(sync.waits[1].before_pos, 3);
        // producer tiles 2,3 now at positions 1,0 -> trigger after pos 1
        assert_eq!(sync.triggers[0].after_pos, Some(1));
    }

    #[test]
    fn rank1_sees_no_waits_but_gets_triggers() {
        let (s, grid, map) = setup();
        let order = TileScheduler::row_major(&grid);
        let sync = plan_rank_sync(1, &s, &order, &map).unwrap();
        // rank 1 receives c2 but no tile consumes it in the map -> no waits
        assert_eq!(sync.num_waits(), 0);
        // both of rank 1's ops trigger immediately (no producing tiles)
        assert_eq!(sync.triggers.len(), 2);
        assert!(sync.triggers.iter().all(|t| t.after_pos.is_none()));
    }

    #[test]
    fn barrier_plan_waits_everything_at_zero() {
        let (s, _grid, map) = setup();
        let sync = plan_rank_sync_barrier(0, &s, &map, 4).unwrap();
        // producer-fed op 0 waits for the last tile under a barrier
        assert_eq!(sync.triggers[0].after_pos, Some(3));
        assert_eq!(sync.num_waits(), 2);
        assert!(sync.waits.iter().all(|w| w.before_pos == 0));
        assert_eq!(tiles_before_first_wait(&sync, 4), 0);
    }

    #[test]
    fn pipeline_fill_metric() {
        let (s, grid, map) = setup();
        // order local tiles (2,3) first: waits move later -> bigger fill
        let order = TileScheduler { order: vec![2, 3, 0, 1] };
        let sync = plan_rank_sync(0, &s, &order, &map).unwrap();
        assert_eq!(tiles_before_first_wait(&sync, grid.num_tiles()), 2);
        let none = RankSync::default();
        assert_eq!(tiles_before_first_wait(&none, 4), 4);
    }

    #[test]
    fn malformed_order_rejected_not_panicking() {
        // regression (ISSUE 3): sync planning over a hand-edited plan with
        // a duplicated tile in the order used to panic in positions()
        let (s, _grid, map) = setup();
        let order = TileScheduler { order: vec![0, 1, 1, 3] };
        let e = plan_rank_sync(0, &s, &order, &map).unwrap_err();
        assert!(e.to_string().contains("permutation"), "{e}");
    }

    #[test]
    fn out_of_range_tiles_rejected() {
        let (s, grid, mut map) = setup();
        map.consumers.insert(OpRef { rank: 1, index: 0 }, vec![99]);
        let order = TileScheduler::row_major(&grid);
        assert!(plan_rank_sync(0, &s, &order, &map).is_err());
    }

    #[test]
    fn consumer_groups_and_arrival() {
        let (_s, _grid, map) = setup();
        let arrival = map.arrival_order();
        assert_eq!(arrival.len(), 2);
        assert!(arrival[0] < arrival[1]);
        let groups = map.consumer_groups(0);
        assert_eq!(groups.len(), 2);
        assert_eq!(groups[&0], vec![0]);
        assert_eq!(groups[&1], vec![1]);
    }
}
