//! Shared happens-before machinery (DESIGN.md §17.1).
//!
//! Both [`crate::schedule::validate`] (the admission gate) and
//! [`crate::analysis`] (the multi-rule analyzer) reason about the same two
//! relations over a schedule's ops, built here exactly once:
//!
//! * **Issue order** — per-rank program order ∪ dep edges. A cycle here is
//!   a static deadlock: some op can never have its wait satisfied.
//! * **Apply order** — dep edges ∪ edges from each *dep-free* op to every
//!   later op on its rank. Both exec engines issue transfers
//!   asynchronously (an op with unmet deps parks while later ops on the
//!   rank proceed), so same-rank program order only constrains the order
//!   writes *land* downstream of a dep-free op. Data-race questions must
//!   be asked of this relation, not issue order — apply order is a
//!   subgraph of the issue-order transitive closure, so any issue-order
//!   topological order is also topological for it.
//!
//! Node numbering is dense: op `(rank, index)` is node
//! `base[rank] + index` with `base` the prefix sums of per-rank op counts.
//! Reachability is materialized as one `u64`-word bitset per node, filled
//! in reverse topological order — O(n²/64) space/time, exact, and fast at
//! the plan sizes the serving path admits.

use crate::schedule::{CommSchedule, OpRef};

/// A dependence graph over a schedule's ops (see module docs for which
/// edges each constructor includes).
pub struct OpGraph {
    /// Prefix sums of per-rank op counts; `base[world]` is the node count.
    pub base: Vec<usize>,
    /// Node count.
    pub n: usize,
    /// Forward adjacency (`u -> v` means `u` happens before `v`).
    pub adj: Vec<Vec<usize>>,
}

fn bases(sched: &CommSchedule) -> Vec<usize> {
    let mut base = vec![0usize; sched.world + 1];
    for r in 0..sched.world {
        base[r + 1] = base[r] + sched.per_rank[r].len();
    }
    base
}

impl OpGraph {
    /// The issue-order graph: program order on each rank ∪ dep edges.
    pub fn issue_order(sched: &CommSchedule) -> OpGraph {
        let base = bases(sched);
        let n = base[sched.world];
        let mut adj: Vec<Vec<usize>> = vec![Vec::new(); n];
        for (rank, ops) in sched.per_rank.iter().enumerate() {
            for (index, op) in ops.iter().enumerate() {
                let me = base[rank] + index;
                if index > 0 {
                    // program order: ops on a rank *issue* in list order
                    adj[me - 1].push(me);
                }
                for d in op.deps() {
                    adj[base[d.rank] + d.index].push(me);
                }
            }
        }
        OpGraph { base, n, adj }
    }

    /// The apply-order graph: dep edges ∪ (dep-free op → every later op on
    /// its rank). See module docs for why program order alone is not an
    /// apply-order guarantee.
    pub fn apply_order(sched: &CommSchedule) -> OpGraph {
        let base = bases(sched);
        let n = base[sched.world];
        let mut adj: Vec<Vec<usize>> = vec![Vec::new(); n];
        for (rank, ops) in sched.per_rank.iter().enumerate() {
            for (index, op) in ops.iter().enumerate() {
                let me = base[rank] + index;
                for d in op.deps() {
                    adj[base[d.rank] + d.index].push(me);
                }
                if op.deps().is_empty() {
                    for later in index + 1..ops.len() {
                        adj[me].push(base[rank] + later);
                    }
                }
            }
        }
        OpGraph { base, n, adj }
    }

    /// Dense node id of an op.
    pub fn id(&self, op: OpRef) -> usize {
        self.base[op.rank] + op.index
    }

    /// Inverse of [`OpGraph::id`].
    pub fn op_ref(&self, u: usize) -> OpRef {
        // first rank whose base exceeds u, minus one
        let rank = self.base.partition_point(|&b| b <= u) - 1;
        OpRef { rank, index: u - self.base[rank] }
    }

    /// Kahn's algorithm. `Ok(order)` is a topological order of all nodes;
    /// `Err(cycle)` is one full cycle in forward-edge direction (each node
    /// has an edge to the next, and the last back to the first).
    pub fn topo(&self) -> std::result::Result<Vec<usize>, Vec<usize>> {
        let mut indeg = vec![0usize; self.n];
        for edges in &self.adj {
            for &v in edges {
                indeg[v] += 1;
            }
        }
        let mut queue: Vec<usize> = (0..self.n).filter(|&u| indeg[u] == 0).collect();
        let mut order = Vec::with_capacity(self.n);
        while let Some(u) = queue.pop() {
            order.push(u);
            for &v in &self.adj[u] {
                indeg[v] -= 1;
                if indeg[v] == 0 {
                    queue.push(v);
                }
            }
        }
        if order.len() == self.n {
            return Ok(order);
        }
        // Residual nodes (indeg still > 0) all lie on or downstream of a
        // cycle, and every residual node has at least one residual
        // predecessor (the edge that kept its indegree positive). Walking
        // predecessors inside the residual set must therefore revisit a
        // node; the revisited segment is a cycle.
        let residual: Vec<bool> = indeg.iter().map(|&d| d > 0).collect();
        let mut pred = vec![usize::MAX; self.n];
        for u in 0..self.n {
            if !residual[u] {
                continue;
            }
            for &v in &self.adj[u] {
                if residual[v] {
                    pred[v] = u;
                }
            }
        }
        let start = (0..self.n).find(|&u| residual[u]).expect("residual set is non-empty");
        let mut seen_at = vec![usize::MAX; self.n];
        let mut path = Vec::new();
        let mut cur = start;
        loop {
            if seen_at[cur] != usize::MAX {
                // path[seen_at[cur]..] walked backwards over edges; flip it
                let mut cycle: Vec<usize> = path[seen_at[cur]..].to_vec();
                cycle.reverse();
                return Err(cycle);
            }
            seen_at[cur] = path.len();
            path.push(cur);
            cur = pred[cur];
        }
    }

    /// Topological order as [`OpRef`]s (convenience for callers that do not
    /// hold node ids).
    pub fn topo_refs(&self) -> std::result::Result<Vec<OpRef>, Vec<OpRef>> {
        match self.topo() {
            Ok(order) => Ok(order.into_iter().map(|u| self.op_ref(u)).collect()),
            Err(cycle) => Err(cycle.into_iter().map(|u| self.op_ref(u)).collect()),
        }
    }
}

/// Forward-reachability closure of an [`OpGraph`] as per-node bitsets.
pub struct Reach {
    words: usize,
    desc: Vec<Vec<u64>>,
}

impl Reach {
    /// Build the closure. `order` must be topological for `g` (for the
    /// apply-order graph, an *issue-order* topological order qualifies —
    /// see the module docs).
    pub fn build(g: &OpGraph, order: &[usize]) -> Reach {
        let words = (g.n + 63) / 64;
        let mut desc = vec![vec![0u64; words]; g.n];
        for &u in order.iter().rev() {
            let mut acc = vec![0u64; words];
            for &v in &g.adj[u] {
                acc[v / 64] |= 1 << (v % 64);
                for (a, d) in acc.iter_mut().zip(&desc[v]) {
                    *a |= *d;
                }
            }
            desc[u] = acc;
        }
        Reach { words, desc }
    }

    /// Is there a non-empty path `a -> ... -> b`?
    pub fn reaches(&self, a: usize, b: usize) -> bool {
        debug_assert!(b / 64 < self.words);
        self.desc[a][b / 64] & (1 << (b % 64)) != 0
    }

    /// Are `a` and `b` ordered either way?
    pub fn ordered(&self, a: usize, b: usize) -> bool {
        self.reaches(a, b) || self.reaches(b, a)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chunk::{Chunk, DType, Region, TensorTable};
    use crate::schedule::{CommOp, Dep, TransferKind};

    fn sched2() -> (CommSchedule, Chunk) {
        let mut t = TensorTable::new();
        let x = t.declare("x", &[8, 16], DType::F32).unwrap();
        let c = Chunk::new(x, Region::rows(0, 4, 16));
        (CommSchedule::new(2, t), c)
    }

    fn push(peer: usize, c: &Chunk, deps: Vec<Dep>) -> CommOp {
        CommOp::P2p {
            kind: TransferKind::Push,
            peer,
            src: c.clone(),
            dst: c.clone(),
            reduce: false,
            deps,
        }
    }

    #[test]
    fn id_and_op_ref_are_inverse() {
        let (mut s, c) = sched2();
        s.add_op(0, push(1, &c, vec![])).unwrap();
        s.add_op(0, push(1, &c, vec![])).unwrap();
        s.add_op(1, push(0, &c, vec![])).unwrap();
        let g = OpGraph::issue_order(&s);
        for rank in 0..2 {
            for index in 0..s.per_rank[rank].len() {
                let r = OpRef { rank, index };
                assert_eq!(g.op_ref(g.id(r)), r);
            }
        }
    }

    #[test]
    fn issue_order_includes_program_edges_apply_does_not_chain_parked_ops() {
        // rank 0: op0 has a dep (parks), op1 is later in program order.
        // Issue order chains 0->1; apply order must NOT (op0 may land late).
        let (mut s, c) = sched2();
        s.add_op(1, push(0, &c, vec![])).unwrap();
        s.add_op(0, push(1, &c, vec![Dep::on(1, 0)])).unwrap();
        s.add_op(0, push(1, &c, vec![])).unwrap();
        let issue = OpGraph::issue_order(&s);
        let apply = OpGraph::apply_order(&s);
        let op0 = issue.id(OpRef { rank: 0, index: 0 });
        let op1 = issue.id(OpRef { rank: 0, index: 1 });
        assert!(issue.adj[op0].contains(&op1));
        assert!(!apply.adj[op0].contains(&op1));
        // ...but a dep-free op orders everything later on its rank
        let r1op0 = issue.id(OpRef { rank: 1, index: 0 });
        assert!(apply.adj[r1op0].contains(&op0), "dep edge kept");
    }

    #[test]
    fn topo_detects_cycle_and_returns_full_path() {
        let (mut s, c) = sched2();
        s.add_op(0, push(1, &c, vec![Dep::on(1, 0)])).unwrap();
        s.add_op(1, push(0, &c, vec![Dep::on(0, 0)])).unwrap();
        let g = OpGraph::issue_order(&s);
        let cycle = g.topo().unwrap_err();
        assert_eq!(cycle.len(), 2);
        // forward-edge direction: each node points at the next, wrapping
        for (i, &u) in cycle.iter().enumerate() {
            let v = cycle[(i + 1) % cycle.len()];
            assert!(g.adj[u].contains(&v), "cycle edge {u}->{v} missing");
        }
    }

    #[test]
    fn reach_closure_is_transitive() {
        let (mut s, c) = sched2();
        s.add_op(0, push(1, &c, vec![])).unwrap(); // (0,0) dep-free
        s.add_op(0, push(1, &c, vec![])).unwrap(); // (0,1)
        s.add_op(1, push(0, &c, vec![Dep::on(0, 1)])).unwrap(); // (1,0)
        let g = OpGraph::apply_order(&s);
        let order = g.topo().unwrap();
        let r = Reach::build(&g, &order);
        let id = |rk: usize, ix: usize| g.id(OpRef { rank: rk, index: ix });
        assert!(r.reaches(id(0, 0), id(0, 1)), "prog edge from dep-free op");
        assert!(r.reaches(id(0, 0), id(1, 0)), "transitive through (0,1)");
        assert!(!r.reaches(id(1, 0), id(0, 0)));
        assert!(r.ordered(id(0, 0), id(1, 0)));
        assert!(!r.reaches(id(0, 0), id(0, 0)), "reachability is strict");
    }
}
