//! Chunk-plan static analyzer (DESIGN.md §17).
//!
//! [`run`] executes a fixed catalog of rules over a [`CommSchedule`] and
//! returns every finding — it is a *reporting* pass, not a first-error
//! gate like [`crate::schedule::validate`]. Rules have stable IDs and one
//! of three severities:
//!
//! | rule       | severity | meaning                                        |
//! |------------|----------|------------------------------------------------|
//! | `SY-E001`  | error    | unordered read-write overlap (data race)       |
//! | `SY-E002`  | error    | unordered write-write overlap (data race)      |
//! | `SY-E003`  | error    | static deadlock: wait-for cycle, full path     |
//! | `SY-W101`  | warn     | redundant dep edge (transitive reduction)      |
//! | `SY-W201`  | warn     | whole-tensor single chunk (no overlap possible)|
//! | `SY-W202`  | warn     | barrier-like all-wait-all dependency pattern   |
//! | `SY-W203`  | warn     | straggler chain dominating the critical path   |
//! | `SY-I301`  | info     | unbalanced per-rank op counts                  |
//!
//! Race questions are asked of the **apply-order** happens-before relation
//! ([`hb`]); redundancy is defined against the same relation, which makes
//! [`reduce`] sound: every removed edge has an alternative apply-order
//! path, so the set of admissible write orders — and therefore the final
//! f32 state under both exec engines — is unchanged (§17.3 has the full
//! argument). Cyclic schedules skip all reachability-based rules and
//! report only the `SY-E003` certificate (plus syntactic lints).

pub mod hb;

use std::collections::BTreeMap;

use crate::chunk::{Region, TensorId};
use crate::error::{Error, Result};
use crate::schedule::{CommOp, CommSchedule, Dep, OpRef};
use crate::topo::Topology;
use crate::util::json_escape;

/// Stable rule IDs (never renumber; retired rules leave gaps).
pub const RULE_RW_RACE: &str = "SY-E001";
pub const RULE_WW_RACE: &str = "SY-E002";
pub const RULE_DEADLOCK: &str = "SY-E003";
pub const RULE_REDUNDANT_DEP: &str = "SY-W101";
pub const RULE_WHOLE_TENSOR: &str = "SY-W201";
pub const RULE_BARRIER: &str = "SY-W202";
pub const RULE_STRAGGLER: &str = "SY-W203";
pub const RULE_UNBALANCED: &str = "SY-I301";

/// Per-rule finding cap: a hostile or degenerate plan with O(n²) racing
/// pairs must not DoS the serving path with findings; the overflow is
/// counted in [`AnalysisReport::suppressed`].
const MAX_PER_RULE: usize = 64;

/// Finding severity. `Error` findings reject a plan on the serving path;
/// `Warn`/`Info` are advisory (counted into `obs`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Severity {
    Error,
    Warn,
    Info,
}

impl Severity {
    pub fn as_str(self) -> &'static str {
        match self {
            Severity::Error => "error",
            Severity::Warn => "warn",
            Severity::Info => "info",
        }
    }
}

/// One diagnostic: a rule violation anchored to the ops involved.
#[derive(Debug, Clone)]
pub struct Finding {
    pub rule: &'static str,
    pub severity: Severity,
    /// Ops involved, most significant first (e.g. race: the two racing
    /// ops; deadlock: the full cycle in wait order).
    pub ops: Vec<OpRef>,
    pub message: String,
}

/// Everything [`run`] learned about one schedule.
#[derive(Debug, Clone, Default)]
pub struct AnalysisReport {
    pub world: usize,
    pub num_ops: usize,
    pub findings: Vec<Finding>,
    /// Redundant dep edges, `(op, dep)` — the input to [`reduce`].
    pub removable_deps: Vec<(OpRef, Dep)>,
    /// Findings dropped by the per-rule cap.
    pub suppressed: usize,
    /// Simulated critical path of the schedule as-is ([`run_on`] only).
    pub critical_path_us: Option<f64>,
    /// Simulated critical path after [`reduce`] ([`run_on`] only, and only
    /// when there was something to remove).
    pub reduced_critical_path_us: Option<f64>,
}

impl AnalysisReport {
    pub fn count(&self, sev: Severity) -> usize {
        self.findings.iter().filter(|f| f.severity == sev).count()
    }

    pub fn has_errors(&self) -> bool {
        self.findings.iter().any(|f| f.severity == Severity::Error)
    }

    /// Render as `syncopate.analysis.v1` JSON (parses under the strict
    /// [`crate::trace::json`] reader; `source` names the analyzed artifact).
    pub fn to_json(&self, source: &str) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        let _ = writeln!(out, "{{");
        let _ = writeln!(out, "  \"schema\": \"syncopate.analysis.v1\",");
        let _ = writeln!(out, "  \"source\": \"{}\",", json_escape(source));
        let _ = writeln!(out, "  \"world\": {},", self.world);
        let _ = writeln!(out, "  \"ops\": {},", self.num_ops);
        let _ = writeln!(out, "  \"errors\": {},", self.count(Severity::Error));
        let _ = writeln!(out, "  \"warnings\": {},", self.count(Severity::Warn));
        let _ = writeln!(out, "  \"infos\": {},", self.count(Severity::Info));
        let _ = writeln!(out, "  \"suppressed\": {},", self.suppressed);
        let opt = |v: Option<f64>| match v {
            Some(x) if x.is_finite() => format!("{x}"),
            _ => "null".to_string(),
        };
        let _ = writeln!(out, "  \"critical_path_us\": {},", opt(self.critical_path_us));
        let _ = writeln!(
            out,
            "  \"reduced_critical_path_us\": {},",
            opt(self.reduced_critical_path_us)
        );
        let _ = writeln!(out, "  \"findings\": [");
        for (i, f) in self.findings.iter().enumerate() {
            let ops: Vec<String> =
                f.ops.iter().map(|o| format!("[{}, {}]", o.rank, o.index)).collect();
            let sep = if i + 1 == self.findings.len() { "" } else { "," };
            let _ = writeln!(
                out,
                "    {{\"rule\": \"{}\", \"severity\": \"{}\", \"ops\": [{}], \
                 \"message\": \"{}\"}}{sep}",
                f.rule,
                f.severity.as_str(),
                ops.join(", "),
                json_escape(&f.message)
            );
        }
        let _ = writeln!(out, "  ]");
        let _ = writeln!(out, "}}");
        out
    }

    /// Render as human-readable text, one finding per line.
    pub fn render_text(&self, source: &str) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        let _ = writeln!(out, "analyze {source}: world {}, {} ops", self.world, self.num_ops);
        for f in &self.findings {
            let ops: Vec<String> =
                f.ops.iter().map(|o| format!("({},{})", o.rank, o.index)).collect();
            let _ = writeln!(
                out,
                "  {:5} {} [{}] {}",
                f.severity.as_str(),
                f.rule,
                ops.join(" "),
                f.message
            );
        }
        if let (Some(a), Some(b)) = (self.critical_path_us, self.reduced_critical_path_us) {
            let delta = if a > 0.0 { (b - a) / a * 100.0 } else { 0.0 };
            let _ = writeln!(
                out,
                "  reduction impact: sim critical path {:.3}us -> {:.3}us ({delta:+.2}%)",
                a, b
            );
        }
        if self.suppressed > 0 {
            let _ = writeln!(out, "  ({} further findings suppressed)", self.suppressed);
        }
        let _ = writeln!(
            out,
            "summary: {} errors, {} warnings, {} infos",
            self.count(Severity::Error),
            self.count(Severity::Warn),
            self.count(Severity::Info)
        );
        out
    }
}

fn fmt_op(o: OpRef) -> String {
    format!("({},{})", o.rank, o.index)
}

fn region_str(name: &str, r: &Region) -> String {
    let dims: Vec<String> =
        r.offset.iter().zip(&r.sizes).map(|(o, s)| format!("{}:{}", o, o + s)).collect();
    format!("{name}[{}]", dims.join(", "))
}

fn intersection(a: &Region, b: &Region) -> Region {
    let mut offset = Vec::with_capacity(a.offset.len());
    let mut sizes = Vec::with_capacity(a.offset.len());
    for i in 0..a.offset.len().min(b.offset.len()) {
        let lo = a.offset[i].max(b.offset[i]);
        let hi = (a.offset[i] + a.sizes[i]).min(b.offset[i] + b.sizes[i]);
        offset.push(lo);
        sizes.push(hi.saturating_sub(lo));
    }
    Region { offset, sizes }
}

fn tensor_name(sched: &CommSchedule, id: TensorId) -> String {
    sched.tensors.get(id).map(|d| d.name.clone()).unwrap_or_else(|_| format!("{id:?}"))
}

/// Cheap structural sanity: analysis (unlike `validate`) accepts plans
/// that fail admission — that is its point — but node numbering needs
/// per-rank lists matching `world` and deps that resolve to real ops.
fn structural_precheck(sched: &CommSchedule) -> Result<()> {
    if sched.per_rank.len() != sched.world {
        return Err(Error::Analysis(format!(
            "per_rank has {} entries for world {}",
            sched.per_rank.len(),
            sched.world
        )));
    }
    for (rank, ops) in sched.per_rank.iter().enumerate() {
        for (index, op) in ops.iter().enumerate() {
            for d in op.deps() {
                if d.rank >= sched.world || d.index >= sched.per_rank[d.rank].len() {
                    return Err(Error::Analysis(format!(
                        "op ({rank},{index}): dep ({}, {}) references a missing op",
                        d.rank, d.index
                    )));
                }
            }
        }
    }
    Ok(())
}

/// One memory access for race analysis: which op touches which region of
/// which rank's buffer. Collectives are skipped (abstract until lowering;
/// [`crate::pipeline::fuse`] rejects them for the same reason).
struct Access<'a> {
    node: usize,
    op: OpRef,
    region: &'a Region,
    reduce: bool,
}

type AccessMap<'a> = BTreeMap<(usize, TensorId), Vec<Access<'a>>>;

fn collect_accesses<'a>(sched: &'a CommSchedule, g: &hb::OpGraph) -> (AccessMap<'a>, AccessMap<'a>) {
    let mut writes: AccessMap<'a> = BTreeMap::new();
    let mut reads: AccessMap<'a> = BTreeMap::new();
    for (rank, ops) in sched.per_rank.iter().enumerate() {
        for (index, op) in ops.iter().enumerate() {
            let opref = OpRef { rank, index };
            let node = g.id(opref);
            let reduce = match op {
                CommOp::P2p { reduce, .. } => *reduce,
                CommOp::LocalCopy { .. } => false,
                CommOp::Collective { .. } => continue,
            };
            writes
                .entry((op.dst_rank(rank), op.produced_chunk().tensor))
                .or_default()
                .push(Access { node, op: opref, region: &op.produced_chunk().region, reduce });
            reads
                .entry((op.src_rank(rank), op.consumed_chunk().tensor))
                .or_default()
                .push(Access { node, op: opref, region: &op.consumed_chunk().region, reduce: false });
        }
    }
    (writes, reads)
}

/// Run the full rule catalog (static rules only; see [`run_on`] for the
/// sim-measured reduction impact). Returns `Err` only when the schedule is
/// too malformed to number ops — every analyzable problem is a [`Finding`].
pub fn run(sched: &CommSchedule) -> Result<AnalysisReport> {
    structural_precheck(sched)?;
    let issue = hb::OpGraph::issue_order(sched);
    let mut rep = AnalysisReport {
        world: sched.world,
        num_ops: issue.n,
        ..AnalysisReport::default()
    };

    let order = match issue.topo() {
        Ok(order) => order,
        Err(cycle) => {
            let refs: Vec<OpRef> = cycle.iter().map(|&u| issue.op_ref(u)).collect();
            let path: Vec<String> = refs.iter().map(|o| fmt_op(*o)).collect();
            rep.findings.push(Finding {
                rule: RULE_DEADLOCK,
                severity: Severity::Error,
                ops: refs,
                message: format!(
                    "static deadlock: wait-for cycle {} -> (back to start); no execution \
                     can satisfy all of these waits — the runtime would only see this as \
                     a bounded-wait timeout",
                    path.join(" -> ")
                ),
            });
            // reachability-based rules are meaningless on a cyclic graph;
            // keep the syntactic lints so one pass still reports them
            lint_whole_tensor(sched, &mut rep);
            lint_unbalanced(sched, &mut rep);
            return Ok(rep);
        }
    };

    // positions in one concrete admissible interleaving (witness basis)
    let mut pos = vec![0usize; issue.n];
    for (i, &u) in order.iter().enumerate() {
        pos[u] = i;
    }
    let apply = hb::OpGraph::apply_order(sched);
    let reach = hb::Reach::build(&apply, &order);

    check_races(sched, &apply, &reach, &pos, &mut rep);
    let removable = redundant_in(sched, &apply, &reach);
    for (op, dep, why) in &removable {
        if push_capped(
            &mut rep,
            Finding {
                rule: RULE_REDUNDANT_DEP,
                severity: Severity::Warn,
                ops: vec![*op, OpRef { rank: dep.rank, index: dep.index }],
                message: format!(
                    "dep ({},{}) of op {} is redundant: {why}; removing it cannot change \
                     any admissible apply order (plan analyze --fix drops it)",
                    dep.rank,
                    dep.index,
                    fmt_op(*op)
                ),
            },
        ) {
            break;
        }
    }
    rep.removable_deps = removable.into_iter().map(|(op, dep, _)| (op, dep)).collect();

    lint_whole_tensor(sched, &mut rep);
    lint_barrier(sched, &mut rep);
    lint_straggler(sched, &apply, &order, &mut rep);
    lint_unbalanced(sched, &mut rep);
    Ok(rep)
}

/// [`run`], plus the sim-measured critical path of the schedule and (when
/// anything is removable) of its reduction, under the best backend the
/// restricted user-plan autotune finds on `topo`. Simulation failures
/// (abstract collectives, untunable plans) leave the fields `None` — the
/// impact numbers are advisory, never a gate.
pub fn run_on(sched: &CommSchedule, topo: &Topology) -> Result<AnalysisReport> {
    let mut rep = run(sched)?;
    if rep.has_errors() {
        return Ok(rep);
    }
    let Ok(tuned) = crate::autotune::tune_user_plan(sched, topo) else {
        return Ok(rep);
    };
    let params = crate::sim::SimParams::default();
    let Ok(plan) = crate::codegen::compile_comm_only(sched, tuned.real.clone(), topo) else {
        return Ok(rep);
    };
    if let Ok(sim) = crate::sim::engine::simulate(&plan, topo, params) {
        rep.critical_path_us = Some(sim.makespan_us);
    }
    if !rep.removable_deps.is_empty() {
        if let Ok((reduced, _)) = reduce(sched) {
            if let Ok(rplan) = crate::codegen::compile_comm_only(&reduced, tuned.real, topo) {
                if let Ok(rsim) = crate::sim::engine::simulate(&rplan, topo, params) {
                    rep.reduced_critical_path_us = Some(rsim.makespan_us);
                }
            }
        }
    }
    Ok(rep)
}

/// Push respecting the per-rule cap; returns `true` when the cap is hit
/// (callers should stop scanning that rule).
fn push_capped(rep: &mut AnalysisReport, f: Finding) -> bool {
    let n = rep.findings.iter().filter(|x| x.rule == f.rule).count();
    if n >= MAX_PER_RULE {
        rep.suppressed += 1;
        return true;
    }
    rep.findings.push(f);
    false
}

/// SY-E001 / SY-E002: read-write and write-write races under apply-order
/// happens-before. Reduce-reduce write pairs are exempt (commutative;
/// exec's plan_prep serializes them canonically for f32 bit-stability) —
/// a plain write or a read racing a reduce write is still an error.
fn check_races(
    sched: &CommSchedule,
    apply: &hb::OpGraph,
    reach: &hb::Reach,
    pos: &[usize],
    rep: &mut AnalysisReport,
) {
    let (writes, reads) = collect_accesses(sched, apply);
    let witness = |a: OpRef, an: usize, b: OpRef, bn: usize| {
        let (first, second) = if pos[an] <= pos[bn] { (a, b) } else { (b, a) };
        format!(
            "witness: the interleaving applying {} then {} is admissible, and with no \
             happens-before path between them so is the mirror applying {} first",
            fmt_op(first),
            fmt_op(second),
            fmt_op(second)
        )
    };
    for ((mem_rank, tensor), ws) in &writes {
        // write-write
        'ww: for (i, a) in ws.iter().enumerate() {
            for b in ws.iter().skip(i + 1) {
                if (a.reduce && b.reduce) || !a.region.intersects(b.region) {
                    continue;
                }
                if reach.ordered(a.node, b.node) {
                    continue;
                }
                let name = tensor_name(sched, *tensor);
                let overlap = region_str(&name, &intersection(a.region, b.region));
                if push_capped(
                    rep,
                    Finding {
                        rule: RULE_WW_RACE,
                        severity: Severity::Error,
                        ops: vec![a.op, b.op],
                        message: format!(
                            "unordered write-write race on `{name}` rank {mem_rank}: ops {} \
                             and {} both write {overlap} with no happens-before path \
                             between them; {}",
                            fmt_op(a.op),
                            fmt_op(b.op),
                            witness(a.op, a.node, b.op, b.node)
                        ),
                    },
                ) {
                    break 'ww;
                }
            }
        }
        // read-write against the same (rank, tensor) memory
        let Some(rs) = reads.get(&(*mem_rank, *tensor)) else { continue };
        'rw: for w in ws {
            for r in rs {
                if r.op == w.op || !r.region.intersects(w.region) {
                    continue;
                }
                if reach.ordered(r.node, w.node) {
                    continue;
                }
                let name = tensor_name(sched, *tensor);
                let overlap = region_str(&name, &intersection(r.region, w.region));
                if push_capped(
                    rep,
                    Finding {
                        rule: RULE_RW_RACE,
                        severity: Severity::Error,
                        ops: vec![r.op, w.op],
                        message: format!(
                            "unordered read-write race on `{name}` rank {mem_rank}: op {} \
                             reads {overlap} while op {} writes it, with no happens-before \
                             path between them; {}",
                            fmt_op(r.op),
                            fmt_op(w.op),
                            witness(r.op, r.node, w.op, w.node)
                        ),
                    },
                ) {
                    break 'rw;
                }
            }
        }
    }
}

/// SY-W101 core: every dep edge implied by the rest of the apply-order
/// graph. An edge `d -> v` is redundant iff some *other* in-edge of `v`
/// comes from `d` itself (a parallel program-order edge) or from a node
/// `d` reaches — i.e. there is an apply-order path `d -> ... -> v` that
/// survives the removal. All edges are judged against the ORIGINAL
/// closure; simultaneous removal stays sound (DESIGN.md §17.3).
fn redundant_in(
    sched: &CommSchedule,
    g: &hb::OpGraph,
    reach: &hb::Reach,
) -> Vec<(OpRef, Dep, String)> {
    let mut out = Vec::new();
    for (rank, ops) in sched.per_rank.iter().enumerate() {
        for (index, op) in ops.iter().enumerate() {
            let v = OpRef { rank, index };
            let deps = op.deps();
            // program-order in-edges: earlier dep-free ops on this rank
            let prog_in: Vec<usize> = (0..index)
                .filter(|&e| ops[e].deps().is_empty())
                .map(|e| g.id(OpRef { rank, index: e }))
                .collect();
            for (slot, d) in deps.iter().enumerate() {
                let dn = g.id(OpRef { rank: d.rank, index: d.index });
                // duplicate dep: keep the first occurrence only
                if deps[..slot].contains(d) {
                    out.push((v, *d, "it duplicates an earlier dep of the same op".into()));
                    continue;
                }
                let mut why: Option<String> = None;
                for (oslot, od) in deps.iter().enumerate() {
                    if oslot == slot || *od == *d {
                        continue;
                    }
                    let on = g.id(OpRef { rank: od.rank, index: od.index });
                    if reach.reaches(dn, on) {
                        why = Some(format!(
                            "already implied through dep ({},{})",
                            od.rank, od.index
                        ));
                        break;
                    }
                }
                if why.is_none() {
                    for &pn in &prog_in {
                        if pn == dn {
                            why = Some(
                                "the dep target is an earlier dep-free op on the same \
                                 rank, so program order already applies it first"
                                    .into(),
                            );
                            break;
                        }
                        if reach.reaches(dn, pn) {
                            let p = g.op_ref(pn);
                            why = Some(format!(
                                "already implied through the earlier dep-free op ({},{}) \
                                 on the same rank",
                                p.rank, p.index
                            ));
                            break;
                        }
                    }
                }
                if let Some(why) = why {
                    out.push((v, *d, why));
                }
            }
        }
    }
    out
}

/// Redundant dep edges of a schedule, `(op, dep)` pairs. Errors on cyclic
/// or structurally broken schedules (no reduction exists).
pub fn redundant_dep_edges(sched: &CommSchedule) -> Result<Vec<(OpRef, Dep)>> {
    structural_precheck(sched)?;
    let issue = hb::OpGraph::issue_order(sched);
    let order = issue
        .topo()
        .map_err(|_| Error::Analysis("cannot reduce a cyclic schedule".into()))?;
    let apply = hb::OpGraph::apply_order(sched);
    let reach = hb::Reach::build(&apply, &order);
    Ok(redundant_in(sched, &apply, &reach).into_iter().map(|(o, d, _)| (o, d)).collect())
}

/// Delete one dep *slot* per removed edge (duplicate deps count once each).
fn apply_removals(sched: &mut CommSchedule, removed: &[(OpRef, Dep)]) {
    for (rank, ops) in sched.per_rank.iter_mut().enumerate() {
        for (index, op) in ops.iter_mut().enumerate() {
            let me = OpRef { rank, index };
            let mut drop: Vec<&Dep> =
                removed.iter().filter(|(o, _)| *o == me).map(|(_, d)| d).collect();
            if drop.is_empty() {
                continue;
            }
            let deps = match op {
                CommOp::P2p { deps, .. }
                | CommOp::Collective { deps, .. }
                | CommOp::LocalCopy { deps, .. } => deps,
            };
            let mut kept = Vec::with_capacity(deps.len());
            for d in deps.iter() {
                if let Some(p) = drop.iter().position(|r| **r == *d) {
                    drop.remove(p); // each removed edge deletes ONE slot
                } else {
                    kept.push(*d);
                }
            }
            *deps = kept;
        }
    }
}

/// Transitive reduction: drop every redundant dep edge, iterated to a
/// fixpoint — removing a dep can leave an op dep-free, which *adds*
/// program-order apply edges and can expose further redundancy. Returns
/// the canonically reduced schedule plus all removed `(op, dep)` edges.
/// Every pass preserves apply-order reachability (each dropped edge keeps
/// an alternative path), so the admissible apply orders of the reduction
/// are a subset of the original's — exec bit-identity follows
/// (DESIGN.md §17.3).
pub fn reduce(sched: &CommSchedule) -> Result<(CommSchedule, Vec<(OpRef, Dep)>)> {
    let mut out = sched.clone();
    let mut all_removed = Vec::new();
    loop {
        let removed = redundant_dep_edges(&out)?;
        if removed.is_empty() {
            return Ok((out, all_removed));
        }
        apply_removals(&mut out, &removed);
        all_removed.extend(removed);
    }
}

/// SY-W201: an op moving an entire tensor as one chunk — splitting is the
/// whole point of chunk-centric overlap, so this op serializes with
/// everything touching the tensor.
fn lint_whole_tensor(sched: &CommSchedule, rep: &mut AnalysisReport) {
    for (rank, ops) in sched.per_rank.iter().enumerate() {
        for (index, op) in ops.iter().enumerate() {
            if matches!(op, CommOp::Collective { .. }) {
                continue;
            }
            let c = op.produced_chunk();
            let Ok(decl) = sched.tensors.get(c.tensor) else { continue };
            let full = c.region.offset.iter().all(|&o| o == 0) && c.region.sizes == decl.shape;
            if !full {
                continue;
            }
            if push_capped(
                rep,
                Finding {
                    rule: RULE_WHOLE_TENSOR,
                    severity: Severity::Warn,
                    ops: vec![OpRef { rank, index }],
                    message: format!(
                        "op ({rank},{index}) moves ALL of `{}` ({}) as a single chunk: \
                         no compute can overlap a transfer it depends on or that depends \
                         on it; split the tensor into chunks (split_p2p)",
                        decl.name,
                        region_str(&decl.name, &c.region)
                    ),
                },
            ) {
                return;
            }
        }
    }
}

/// SY-W202: barrier-like all-wait-all. At world ≥ 3, if EVERY rank has an
/// op whose deps span all other ranks, the plan contains a de-facto
/// global barrier — exactly the pattern fine-grained deps exist to avoid.
fn lint_barrier(sched: &CommSchedule, rep: &mut AnalysisReport) {
    if sched.world < 3 {
        return;
    }
    let mut waiters: Vec<OpRef> = Vec::with_capacity(sched.world);
    for (rank, ops) in sched.per_rank.iter().enumerate() {
        let found = ops.iter().enumerate().find(|(_, op)| {
            let mut seen = vec![false; sched.world];
            for d in op.deps() {
                if d.rank != rank {
                    seen[d.rank] = true;
                }
            }
            seen.iter().filter(|&&s| s).count() >= sched.world - 1
        });
        match found {
            Some((index, _)) => waiters.push(OpRef { rank, index }),
            None => return,
        }
    }
    let names: Vec<String> = waiters.iter().map(|o| fmt_op(*o)).collect();
    rep.findings.push(Finding {
        rule: RULE_BARRIER,
        severity: Severity::Warn,
        ops: waiters,
        message: format!(
            "barrier-like all-wait-all: every rank has an op waiting on ops from all \
             other ranks ({}); this is a global barrier in dep-edge clothing — overlap \
             across it is impossible, consider depending only on the chunks actually read",
            names.join(" ")
        ),
    });
}

/// SY-W203: straggler chain. The longest apply-order chain concentrated
/// on one rank (≥70% of its ops) whose cross-rank dep fan-in is more than
/// twice the mean — that rank serializes the plan while others idle.
fn lint_straggler(
    sched: &CommSchedule,
    apply: &hb::OpGraph,
    order: &[usize],
    rep: &mut AnalysisReport,
) {
    if apply.n < 4 || sched.world < 2 {
        return;
    }
    // longest path by op count, reconstructed deterministically
    let mut len = vec![1usize; apply.n];
    let mut next = vec![usize::MAX; apply.n];
    for &u in order.iter().rev() {
        for &v in &apply.adj[u] {
            if len[v] + 1 > len[u] || (len[v] + 1 == len[u] && v < next[u]) {
                len[u] = len[v] + 1;
                next[u] = v;
            }
        }
    }
    let Some(start) = (0..apply.n).max_by_key(|&u| (len[u], usize::MAX - u)) else { return };
    if len[start] < 4 {
        return;
    }
    let mut chain = Vec::with_capacity(len[start]);
    let mut cur = start;
    while cur != usize::MAX {
        chain.push(cur);
        cur = next[cur];
    }
    let mut per_rank = vec![0usize; sched.world];
    for &u in &chain {
        per_rank[apply.op_ref(u).rank] += 1;
    }
    let (mode_rank, &mode_count) =
        per_rank.iter().enumerate().max_by_key(|&(r, c)| (*c, usize::MAX - r)).unwrap();
    if (mode_count as f64) < 0.7 * chain.len() as f64 {
        return;
    }
    // cross-rank dep fan-in per rank
    let mut cross_in = vec![0usize; sched.world];
    for (rank, ops) in sched.per_rank.iter().enumerate() {
        for op in ops {
            cross_in[rank] += op.deps().iter().filter(|d| d.rank != rank).count();
        }
    }
    let total: usize = cross_in.iter().sum();
    let mean = total as f64 / sched.world as f64;
    if mean <= 0.0 || (cross_in[mode_rank] as f64) <= 2.0 * mean {
        return;
    }
    let head: Vec<String> =
        chain.iter().take(6).map(|&u| fmt_op(apply.op_ref(u))).collect();
    rep.findings.push(Finding {
        rule: RULE_STRAGGLER,
        severity: Severity::Warn,
        ops: chain.iter().map(|&u| apply.op_ref(u)).collect(),
        message: format!(
            "straggler chain: the longest apply-order chain ({} ops, {head}...) runs \
             {mode_count}/{} of its ops on rank {mode_rank}, whose cross-rank dep fan-in \
             ({}) is more than twice the mean ({mean:.1}); that rank serializes the \
             critical path while the others idle",
            chain.len(),
            chain.len(),
            cross_in[mode_rank],
            head = head.join(" -> ")
        ),
    });
}

/// SY-I301: per-rank op-count imbalance (max > 2x min, or idle ranks in a
/// non-empty plan).
fn lint_unbalanced(sched: &CommSchedule, rep: &mut AnalysisReport) {
    if sched.world < 2 {
        return;
    }
    let counts: Vec<usize> = sched.per_rank.iter().map(Vec::len).collect();
    let max = *counts.iter().max().unwrap_or(&0);
    let min = *counts.iter().min().unwrap_or(&0);
    if max == 0 || (min > 0 && max <= 2 * min) {
        return;
    }
    rep.findings.push(Finding {
        rule: RULE_UNBALANCED,
        severity: Severity::Info,
        ops: Vec::new(),
        message: format!(
            "unbalanced per-rank op counts {counts:?}: the busiest rank issues {max} ops \
             vs {min} on the idlest; heavily skewed plans under-use the idle ranks' links"
        ),
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chunk::{Chunk, DType, Region, TensorTable};
    use crate::schedule::{templates, TransferKind};

    fn push_op(peer: usize, src: &Chunk, dst: &Chunk, reduce: bool, deps: Vec<Dep>) -> CommOp {
        CommOp::P2p {
            kind: TransferKind::Push,
            peer,
            src: src.clone(),
            dst: dst.clone(),
            reduce,
            deps,
        }
    }

    fn rules_at_least_warn(rep: &AnalysisReport) -> Vec<&'static str> {
        let mut v: Vec<&'static str> = rep
            .findings
            .iter()
            .filter(|f| f.severity != Severity::Info)
            .map(|f| f.rule)
            .collect();
        v.dedup();
        v
    }

    #[test]
    fn clean_template_reports_nothing() {
        let mut t = TensorTable::new();
        let x = t.declare("x", &[8, 16], DType::F32).unwrap();
        let s = templates::all_gather_ring(&t, x, 0, 4).unwrap();
        let rep = run(&s).unwrap();
        assert!(!rep.has_errors(), "{:?}", rep.findings);
        assert_eq!(rules_at_least_warn(&rep), Vec::<&str>::new(), "{:?}", rep.findings);
    }

    #[test]
    fn rw_race_detected_with_witness() {
        // rank 0 writes x[0:2] into rank 1 while rank 1 reads it, unordered
        let mut t = TensorTable::new();
        let x = t.declare("x", &[4, 8], DType::F32).unwrap();
        let lo = Chunk::new(x, Region::rows(0, 2, 8));
        let hi = Chunk::new(x, Region::rows(2, 2, 8));
        let mut s = CommSchedule::new(2, t);
        s.add_op(0, push_op(1, &lo, &lo, false, vec![])).unwrap();
        s.add_op(1, push_op(0, &lo, &hi, false, vec![])).unwrap();
        let rep = run(&s).unwrap();
        assert_eq!(rules_at_least_warn(&rep), vec![RULE_RW_RACE], "{:?}", rep.findings);
        let f = &rep.findings[0];
        assert_eq!(f.ops, vec![OpRef { rank: 1, index: 0 }, OpRef { rank: 0, index: 0 }]);
        assert!(f.message.contains("witness"), "{}", f.message);
        assert!(f.message.contains("x[0:2, 0:8]"), "{}", f.message);
        // the dep-ordered version is clean
        let mut ok = CommSchedule::new(2, {
            let mut t = TensorTable::new();
            t.declare("x", &[4, 8], DType::F32).unwrap();
            t
        });
        ok.add_op(0, push_op(1, &lo, &lo, false, vec![])).unwrap();
        ok.add_op(1, push_op(0, &lo, &hi, false, vec![Dep::on(0, 0)])).unwrap();
        assert!(!run(&ok).unwrap().has_errors());
    }

    #[test]
    fn ww_race_detected_reduce_pair_exempt() {
        let mut t = TensorTable::new();
        let x = t.declare("x", &[4, 8], DType::F32).unwrap();
        let c = Chunk::new(x, Region::rows(0, 2, 8));
        let mut s = CommSchedule::new(3, t.clone());
        s.add_op(0, push_op(2, &c, &c, false, vec![])).unwrap();
        s.add_op(1, push_op(2, &c, &c, false, vec![])).unwrap();
        let rep = run(&s).unwrap();
        assert!(rep.findings.iter().any(|f| f.rule == RULE_WW_RACE), "{:?}", rep.findings);

        let mut r = CommSchedule::new(3, t);
        r.add_op(0, push_op(2, &c, &c, true, vec![])).unwrap();
        r.add_op(1, push_op(2, &c, &c, true, vec![])).unwrap();
        assert!(!run(&r).unwrap().has_errors(), "reduce-reduce pairs commute");
    }

    #[test]
    fn deadlock_certificate_prints_full_cycle() {
        let mut t = TensorTable::new();
        let x = t.declare("x", &[4, 8], DType::F32).unwrap();
        let a = Chunk::new(x, Region::rows(0, 2, 8));
        let b = Chunk::new(x, Region::rows(2, 2, 8));
        let mut s = CommSchedule::new(2, t);
        s.add_op(0, push_op(1, &a, &a, false, vec![Dep::on(1, 0)])).unwrap();
        s.add_op(1, push_op(0, &b, &b, false, vec![Dep::on(0, 0)])).unwrap();
        let rep = run(&s).unwrap();
        let f = rep.findings.iter().find(|f| f.rule == RULE_DEADLOCK).expect("E003");
        assert!(f.message.contains("(0,0)") && f.message.contains("(1,0)"), "{}", f.message);
        assert_eq!(f.ops.len(), 2);
        // cyclic plans skip reachability rules: no race/redundancy noise
        assert!(rep.findings.iter().all(|f| f.rule != RULE_RW_RACE && f.rule != RULE_WW_RACE));
    }

    #[test]
    fn redundant_dep_found_and_reduced() {
        // (1,1) deps on (0,0) and (1,0); (1,0) is an earlier dep-free op on
        // the same rank, so that dep is pure noise
        let mut t = TensorTable::new();
        let x = t.declare("x", &[4, 8], DType::F32).unwrap();
        let lo = Chunk::new(x, Region::rows(0, 2, 8));
        let hi = Chunk::new(x, Region::rows(2, 2, 8));
        let mut s = CommSchedule::new(2, t);
        s.add_op(0, push_op(1, &lo, &lo, false, vec![])).unwrap();
        s.add_op(1, push_op(0, &hi, &hi, false, vec![])).unwrap();
        s.add_op(1, push_op(0, &hi, &hi, false, vec![Dep::on(0, 0), Dep::on(1, 0)])).unwrap();
        let rep = run(&s).unwrap();
        assert_eq!(rep.removable_deps, vec![(OpRef { rank: 1, index: 1 }, Dep::on(1, 0))]);
        assert!(rep.findings.iter().any(|f| f.rule == RULE_REDUNDANT_DEP));
        let (reduced, removed) = reduce(&s).unwrap();
        assert_eq!(removed.len(), 1);
        assert_eq!(reduced.per_rank[1][1].deps(), &[Dep::on(0, 0)]);
        // reduction reaches a fixpoint: nothing left to remove
        assert!(redundant_dep_edges(&reduced).unwrap().is_empty());
        crate::schedule::validate::validate(&reduced).unwrap();
    }

    #[test]
    fn dep_implied_through_other_dep_is_redundant() {
        // (1,0) deps on both (0,1) and (0,0); (0,0) -> (0,1) in apply order
        // ((0,0) is dep-free), so the (0,0) dep is implied
        let mut t = TensorTable::new();
        let x = t.declare("x", &[4, 8], DType::F32).unwrap();
        let lo = Chunk::new(x, Region::rows(0, 2, 8));
        let mut s = CommSchedule::new(2, t);
        s.add_op(0, push_op(1, &lo, &lo, false, vec![])).unwrap();
        s.add_op(0, push_op(1, &lo, &lo, false, vec![])).unwrap();
        s.add_op(1, push_op(0, &lo, &lo, false, vec![Dep::on(0, 1), Dep::on(0, 0)])).unwrap();
        let removed = redundant_dep_edges(&s).unwrap();
        assert_eq!(removed, vec![(OpRef { rank: 1, index: 0 }, Dep::on(0, 0))]);
    }

    #[test]
    fn whole_tensor_chunk_flagged() {
        let mut t = TensorTable::new();
        let x = t.declare("x", &[4, 8], DType::F32).unwrap();
        let full = Chunk::new(x, Region::full(&[4, 8]));
        let mut s = CommSchedule::new(2, t);
        s.add_op(0, push_op(1, &full, &full, false, vec![])).unwrap();
        let rep = run(&s).unwrap();
        assert!(rep.findings.iter().any(|f| f.rule == RULE_WHOLE_TENSOR), "{:?}", rep.findings);
    }

    #[test]
    fn barrier_pattern_flagged_only_when_every_rank_waits() {
        let world = 4;
        let mut t = TensorTable::new();
        let x = t.declare("x", &[8, 8], DType::F32).unwrap();
        let shard = |r: usize| Chunk::new(x, Region::rows(2 * r, 2, 8));
        let mut s = CommSchedule::new(world, t);
        for r in 0..world {
            s.add_op(r, push_op((r + 1) % world, &shard(r), &shard(r), false, vec![])).unwrap();
        }
        for r in 0..world {
            let deps: Vec<Dep> =
                (0..world).filter(|&s2| s2 != r).map(|s2| Dep::on(s2, 0)).collect();
            s.add_op(r, push_op((r + 2) % world, &shard(r), &shard(r), false, deps)).unwrap();
        }
        let rep = run(&s).unwrap();
        assert!(rep.findings.iter().any(|f| f.rule == RULE_BARRIER), "{:?}", rep.findings);
        // drop one rank's all-wait op: no longer a global barrier
        let mut partial = s.clone();
        partial.per_rank[0].truncate(1);
        let rep2 = run(&partial).unwrap();
        assert!(rep2.findings.iter().all(|f| f.rule != RULE_BARRIER), "{:?}", rep2.findings);
    }

    #[test]
    fn unbalanced_op_counts_info() {
        let mut t = TensorTable::new();
        let x = t.declare("x", &[8, 8], DType::F32).unwrap();
        let c = |r0: usize| Chunk::new(x, Region::rows(r0, 2, 8));
        let mut s = CommSchedule::new(2, t);
        for i in 0..3 {
            s.add_op(0, push_op(1, &c(2 * i), &c(2 * i), false, vec![])).unwrap();
        }
        let rep = run(&s).unwrap();
        let f = rep.findings.iter().find(|f| f.rule == RULE_UNBALANCED).expect("I301");
        assert_eq!(f.severity, Severity::Info);
    }

    #[test]
    fn straggler_chain_flagged() {
        // rank 0 hosts a 4-op chain fed by cross-rank deps at every link;
        // ranks 1..3 each contribute one feeder op and no chain of their own
        let world = 4;
        let mut t = TensorTable::new();
        let x = t.declare("x", &[16, 8], DType::F32).unwrap();
        let c = |r0: usize| Chunk::new(x, Region::rows(r0, 2, 8));
        let mut s = CommSchedule::new(world, t);
        for r in 1..world {
            s.add_op(r, push_op(0, &c(2 * r), &c(2 * r), false, vec![])).unwrap();
        }
        s.add_op(0, push_op(1, &c(0), &c(0), false, vec![Dep::on(1, 0)])).unwrap();
        s.add_op(0, push_op(2, &c(8), &c(8), false, vec![Dep::on(2, 0), Dep::on(0, 0)]))
            .unwrap();
        s.add_op(0, push_op(3, &c(10), &c(10), false, vec![Dep::on(3, 0), Dep::on(0, 1)]))
            .unwrap();
        s.add_op(0, push_op(1, &c(12), &c(12), false, vec![Dep::on(0, 2)])).unwrap();
        let rep = run(&s).unwrap();
        assert!(rep.findings.iter().any(|f| f.rule == RULE_STRAGGLER), "{:?}", rep.findings);
    }

    #[test]
    fn json_and_text_render() {
        let mut t = TensorTable::new();
        let x = t.declare("x", &[4, 8], DType::F32).unwrap();
        let c = Chunk::new(x, Region::rows(0, 2, 8));
        let mut s = CommSchedule::new(3, t);
        s.add_op(0, push_op(2, &c, &c, false, vec![])).unwrap();
        s.add_op(1, push_op(2, &c, &c, false, vec![])).unwrap();
        let rep = run(&s).unwrap();
        let j = rep.to_json("test.sched");
        crate::trace::json::parse(&j).expect("analysis JSON must parse strictly");
        assert!(j.contains("\"schema\": \"syncopate.analysis.v1\""));
        assert!(j.contains(RULE_WW_RACE));
        let text = rep.render_text("test.sched");
        assert!(text.contains("summary:"), "{text}");
        assert!(text.contains(RULE_WW_RACE), "{text}");
    }

    #[test]
    fn structural_breakage_is_an_error_not_a_finding() {
        let mut t = TensorTable::new();
        let x = t.declare("x", &[4, 8], DType::F32).unwrap();
        let c = Chunk::new(x, Region::rows(0, 2, 8));
        let mut s = CommSchedule::new(2, t);
        s.add_op(0, push_op(1, &c, &c, false, vec![Dep::on(1, 9)])).unwrap();
        let e = run(&s).unwrap_err();
        assert_eq!(e.subsystem(), "analysis");
    }

    #[test]
    fn every_template_analyzes_without_errors() {
        use crate::schedule::templates as tp;
        for world in [2usize, 4, 8] {
            let mut t = TensorTable::new();
            let x = t.declare("x", &[world * world * 2, 16], DType::F32).unwrap();
            for s in [
                tp::all_gather_ring(&t, x, 0, world).unwrap(),
                tp::all_gather_swizzle(&t, x, 0, world).unwrap(),
                tp::all_gather_direct(&t, x, 0, world).unwrap(),
                tp::reduce_scatter_ring(&t, x, 0, world).unwrap(),
                tp::reduce_scatter_direct(&t, x, 0, world).unwrap(),
                tp::all_reduce_partition(&t, x, 0, world).unwrap(),
                tp::all_reduce_rs_ag(&t, x, 0, world).unwrap(),
                tp::all_to_all(&t, x, 0, world).unwrap(),
            ] {
                let rep = run(&s).unwrap();
                assert!(!rep.has_errors(), "world {world}: {:#?}", rep.findings);
                let rep2 = run(&s.split_p2p(0, 2).unwrap()).unwrap();
                assert!(!rep2.has_errors(), "world {world} split: {:#?}", rep2.findings);
            }
        }
    }
}
