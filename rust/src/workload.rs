//! Workload suite: model configurations and distributed-operator shapes
//! (paper §6.1).
//!
//! Operator shapes derive from the FFN and attention layers of open-source
//! Llama-3 and Qwen models, exactly as the evaluation does, across the
//! tensor-parallel / sequence-parallel patterns: AG-GEMM, GEMM-RS, GEMM-AR,
//! A2A-GEMM, head-parallel (HP) and sequence-parallel (SP) attention, and
//! RingAttention.

use crate::chunk::DType;

/// A model family member (decoder layer dimensions).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ModelCfg {
    pub name: &'static str,
    /// Hidden size (d_model).
    pub hidden: usize,
    /// FFN intermediate size.
    pub inter: usize,
    /// Attention heads.
    pub heads: usize,
    /// Per-head dimension.
    pub head_dim: usize,
}

/// Llama-3 8B.
pub const LLAMA3_8B: ModelCfg =
    ModelCfg { name: "llama3-8b", hidden: 4096, inter: 14336, heads: 32, head_dim: 128 };
/// Llama-3 70B.
pub const LLAMA3_70B: ModelCfg =
    ModelCfg { name: "llama3-70b", hidden: 8192, inter: 28672, heads: 64, head_dim: 128 };
/// Llama-3 405B.
pub const LLAMA3_405B: ModelCfg =
    ModelCfg { name: "llama3-405b", hidden: 16384, inter: 53248, heads: 128, head_dim: 128 };
/// Qwen2.5 7B.
pub const QWEN_7B: ModelCfg =
    ModelCfg { name: "qwen-7b", hidden: 3584, inter: 18944, heads: 28, head_dim: 128 };
/// Qwen2.5 72B.
pub const QWEN_72B: ModelCfg =
    ModelCfg { name: "qwen-72b", hidden: 8192, inter: 29568, heads: 64, head_dim: 128 };

/// The models swept in Fig. 8 / Fig. 9.
pub const MODELS: [ModelCfg; 5] = [LLAMA3_8B, LLAMA3_70B, LLAMA3_405B, QWEN_7B, QWEN_72B];

/// Distributed operator kinds under evaluation.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum OpKind {
    /// AllGather(X) then X @ W (tensor-parallel FFN up-projection).
    AgGemm,
    /// X @ W then ReduceScatter (sequence-parallel FFN down-projection).
    GemmRs,
    /// X @ W then AllReduce (tensor-parallel FFN down-projection).
    GemmAr,
    /// AllToAll(X) then X @ W (MoE dispatch + expert GEMM).
    A2aGemm,
    /// Head-parallel (DeepSpeed-Ulysses-style) attention.
    AttnHp,
    /// Sequence-parallel attention (blockwise, gathered KV).
    AttnSp,
    /// RingAttention (rotating KV shards, online softmax).
    RingAttn,
}

impl OpKind {
    pub const GEMM_OPS: [OpKind; 4] =
        [OpKind::AgGemm, OpKind::GemmRs, OpKind::GemmAr, OpKind::A2aGemm];
    pub const ATTN_OPS: [OpKind; 3] = [OpKind::AttnHp, OpKind::AttnSp, OpKind::RingAttn];

    pub fn name(&self) -> &'static str {
        match self {
            OpKind::AgGemm => "ag-gemm",
            OpKind::GemmRs => "gemm-rs",
            OpKind::GemmAr => "gemm-ar",
            OpKind::A2aGemm => "a2a-gemm",
            OpKind::AttnHp => "attn-hp",
            OpKind::AttnSp => "attn-sp",
            OpKind::RingAttn => "ring-attn",
        }
    }

    pub fn is_gemm(&self) -> bool {
        matches!(self, OpKind::AgGemm | OpKind::GemmRs | OpKind::GemmAr | OpKind::A2aGemm)
    }
}

/// A concrete distributed-operator instance (global problem, mesh size).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OperatorInstance {
    pub kind: OpKind,
    /// Global rows (tokens) for GEMM ops; global sequence length for attn.
    pub m: usize,
    /// Contraction dim (GEMM) or head_dim (attention).
    pub k: usize,
    /// Output columns (GEMM) or heads (attention).
    pub n: usize,
    pub world: usize,
    pub dtype: DType,
}

impl OperatorInstance {
    /// GEMM-family instance from a model config (FFN layer, `tokens` rows).
    pub fn gemm(kind: OpKind, model: &ModelCfg, tokens: usize, world: usize) -> Self {
        debug_assert!(kind.is_gemm());
        let (k, n) = match kind {
            // up-projection: [tokens, hidden] @ [hidden, inter/world]
            OpKind::AgGemm | OpKind::A2aGemm => (model.hidden, model.inter / world),
            // down-projection: [tokens, inter/world] @ [inter/world, hidden]
            OpKind::GemmRs | OpKind::GemmAr => (model.inter / world, model.hidden),
            _ => unreachable!(),
        };
        OperatorInstance { kind, m: tokens, k, n, world, dtype: DType::BF16 }
    }

    /// Attention instance: `seq` global sequence length.
    pub fn attention(kind: OpKind, model: &ModelCfg, seq: usize, world: usize) -> Self {
        debug_assert!(!kind.is_gemm());
        OperatorInstance { kind, m: seq, k: model.head_dim, n: model.heads, world, dtype: DType::BF16 }
    }

    /// Total FLOPs across the mesh.
    pub fn flops(&self) -> f64 {
        match self.kind {
            // each rank multiplies the (gathered) M rows by its weight shard
            OpKind::AgGemm | OpKind::A2aGemm => {
                2.0 * self.m as f64 * self.k as f64 * self.n as f64 * self.world as f64
            }
            // each rank multiplies its partial K shard into a full output
            OpKind::GemmRs | OpKind::GemmAr => {
                2.0 * self.m as f64 * self.k as f64 * self.n as f64 * self.world as f64
            }
            // attention fwd: QK^T and PV, over all heads
            OpKind::AttnHp | OpKind::AttnSp | OpKind::RingAttn => {
                4.0 * (self.m as f64) * (self.m as f64) * self.k as f64 * self.n as f64
            }
        }
    }

    /// Bytes crossing links (sum over the mesh), using standard collective
    /// cost models.
    pub fn comm_bytes(&self) -> usize {
        let e = self.dtype.size();
        let w = self.world;
        match self.kind {
            // AG of [m, k]: each rank receives (w-1)/w of the tensor
            OpKind::AgGemm => self.m * self.k * e * (w - 1),
            // RS of [m, n*w]... output per rank [m, n]: partials move (w-1)/w
            OpKind::GemmRs => self.m * self.n * e * (w - 1),
            // AR moves 2x RS
            OpKind::GemmAr => 2 * self.m * self.n * e * (w - 1),
            // A2A: (w-1)/w of the tokens leave each rank
            OpKind::A2aGemm => self.m * self.k * e * (w - 1) / w,
            // HP (Ulysses): two A2As over [seq, heads*head_dim]
            OpKind::AttnHp => 2 * self.m * self.n * self.k * e * (w - 1) / w,
            // SP: gather KV shards: each rank receives (w-1) shards
            OpKind::AttnSp => 2 * self.m * self.n * self.k * e * (w - 1),
            // Ring: KV rotates w-1 hops, each hop seq/w rows
            OpKind::RingAttn => 2 * self.m * self.n * self.k * e * (w - 1) / w * (w - 1) / w.max(1),
        }
    }

    /// Arithmetic intensity (FLOPs per communicated byte) — predicts which
    /// operators are communication-bound.
    pub fn intensity(&self) -> f64 {
        self.flops() / self.comm_bytes().max(1) as f64
    }

    pub fn label(&self) -> String {
        format!("{}-{}x{}x{}-w{}", self.kind.name(), self.m, self.k, self.n, self.world)
    }
}

/// The sequence lengths swept in Fig. 9.
pub const SEQ_SWEEP: [usize; 5] = [4096, 8192, 16384, 32768, 65536];

/// Default token count (batch x seq per microbatch) for GEMM benchmarks.
pub const DEFAULT_TOKENS: usize = 8192;

/// The full Fig. 8 GEMM suite: every model x {4, 8} GPUs x GEMM op kinds.
pub fn fig8_suite() -> Vec<OperatorInstance> {
    let mut v = Vec::new();
    for model in &MODELS {
        for &world in &[4usize, 8] {
            for kind in [OpKind::AgGemm, OpKind::GemmRs, OpKind::GemmAr] {
                v.push(OperatorInstance::gemm(kind, model, DEFAULT_TOKENS, world));
            }
        }
    }
    v
}

/// The Fig. 9 attention suite: Llama-3 8B/70B across sequence lengths.
pub fn fig9_suite() -> Vec<OperatorInstance> {
    let mut v = Vec::new();
    for model in &[LLAMA3_8B, LLAMA3_70B] {
        for &world in &[4usize, 8] {
            for &seq in &SEQ_SWEEP[..3] {
                for kind in OpKind::ATTN_OPS {
                    v.push(OperatorInstance::attention(kind, model, seq, world));
                }
            }
        }
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn model_configs_sane() {
        for m in &MODELS {
            assert!(m.hidden >= 1024 && m.inter > m.hidden);
            assert_eq!(m.heads * m.head_dim, m.hidden, "{}", m.name);
        }
    }

    #[test]
    fn gemm_shapes_divide_by_world() {
        for m in &MODELS {
            for w in [4usize, 8] {
                let op = OperatorInstance::gemm(OpKind::AgGemm, m, 8192, w);
                assert_eq!(op.n * w, m.inter);
                let op2 = OperatorInstance::gemm(OpKind::GemmRs, m, 8192, w);
                assert_eq!(op2.k * w, m.inter);
            }
        }
    }

    #[test]
    fn flops_scale_with_world_for_tp() {
        let a4 = OperatorInstance::gemm(OpKind::AgGemm, &LLAMA3_70B, 8192, 4);
        let a8 = OperatorInstance::gemm(OpKind::AgGemm, &LLAMA3_70B, 8192, 8);
        // total math is invariant: n shrinks as world grows
        assert_eq!(a4.flops(), a8.flops());
    }

    #[test]
    fn ar_moves_twice_rs() {
        let rs = OperatorInstance::gemm(OpKind::GemmRs, &LLAMA3_8B, 8192, 8);
        let ar = OperatorInstance::gemm(OpKind::GemmAr, &LLAMA3_8B, 8192, 8);
        assert_eq!(ar.comm_bytes(), 2 * rs.comm_bytes());
        assert!(ar.intensity() < rs.intensity());
    }

    #[test]
    fn attention_flops_quadratic_in_seq() {
        let a = OperatorInstance::attention(OpKind::RingAttn, &LLAMA3_8B, 4096, 8);
        let b = OperatorInstance::attention(OpKind::RingAttn, &LLAMA3_8B, 8192, 8);
        assert!((b.flops() / a.flops() - 4.0).abs() < 1e-9);
        // comm grows linearly -> intensity grows with seq (ring gets easier
        // to hide at long sequences, Fig. 9's trend)
        assert!(b.intensity() > a.intensity());
    }

    #[test]
    fn suites_nonempty_and_labeled() {
        let f8 = fig8_suite();
        assert_eq!(f8.len(), 5 * 2 * 3);
        let f9 = fig9_suite();
        assert_eq!(f9.len(), 2 * 2 * 3 * 3);
        for op in f8.iter().chain(&f9) {
            assert!(op.flops() > 0.0);
            assert!(op.comm_bytes() > 0);
            assert!(!op.label().is_empty());
        }
    }

    #[test]
    fn hp_cheaper_comm_than_sp() {
        let hp = OperatorInstance::attention(OpKind::AttnHp, &LLAMA3_8B, 16384, 8);
        let sp = OperatorInstance::attention(OpKind::AttnSp, &LLAMA3_8B, 16384, 8);
        assert!(hp.comm_bytes() < sp.comm_bytes());
    }
}
