#![deny(unsafe_op_in_unsafe_fn)]
//! # Syncopate
//!
//! Reproduction of *Syncopate: Efficient Multi-GPU AI Kernels via Automatic
//! Chunk-Centric Compute-Communication Overlap* as a three-layer
//! Rust + JAX + Pallas stack (see DESIGN.md).
//!
//! * **L3 (this crate)** — the paper's contribution: chunk abstraction,
//!   communication schedules, annotated-kernel frontend, dependence-graph
//!   sync insertion, backend selection, tile-scheduler swizzling, codegen
//!   to per-rank executable plans, a communication-centric autotuner, a
//!   calibrated multi-GPU discrete-event simulator, and a real-numerics
//!   multi-rank executor with two engines: a **parallel per-rank engine**
//!   (one worker thread per rank over a shared signal board — the
//!   production path) and the deterministic sequential interpreter kept as
//!   the reference semantics, cross-checked bit-for-bit (`exec::`).
//!   Request serving is a multi-worker [`coordinator`] pool sharing a plan
//!   cache. Chunk schedules are a first-class interchange artifact
//!   ([`plan_io`]): a textual `.sched` DSL with guaranteed round-trip,
//!   importers lifting stream-level plans from existing distributed
//!   runtimes, and a user-plan serving path (validate → restricted
//!   autotune → codegen → exec) cached by content hash. Consecutive
//!   operators compose through [`pipeline`]: their chunk schedules fuse
//!   into one barrier-free plan whose cross-stage ordering is carried by
//!   fine-grained dependency edges instead of a kernel-boundary sync.
//!   The hardware model is data, not code ([`hw`]): a queryable per-arch
//!   capability matrix + bandwidth-curve store, a `.topo` description
//!   format with a built-in catalog (`h100_node`, `a100_node`, `b200_node`,
//!   multinode and mixed-fabric shapes), and a topology fingerprint keying
//!   the tuning cache — every scenario runs on any described machine via
//!   `--topo`. The model is closed-loop ([`trace`]): both exec engines
//!   emit chunk-level event traces (Chrome `trace_event` export, overlap
//!   report, sim-vs-trace divergence), and `calibrate` fits measured
//!   bandwidth curves + compute rate back into a `.topo` keyed by the
//!   machine fingerprint. A standing telemetry layer ([`obs`]) watches
//!   all of it continuously: a lock-free metrics registry (counters,
//!   gauges, log₂ latency histograms) instruments the serving path, the
//!   plan/tune caches, and the parallel engine's run loop, exported as
//!   Prometheus text or `syncopate.stats.v1` JSON (`stats` CLI verbs).
//!   Plans are checked before they run ([`analysis`]): a multi-rule
//!   static analyzer over the happens-before relation reports read-write /
//!   write-write race certificates with witness interleavings, static
//!   deadlock cycles, redundant-dep reduction (with a `--fix` mode
//!   emitting the canonically reduced plan), and overlap-quality lints —
//!   wired into `plan analyze`/`plan lint` and the serving path.
//! * **L2/L1 (python/, build-time only)** — JAX per-rank compute graphs
//!   calling Pallas kernels, AOT-lowered to HLO text in `artifacts/`.
//!
//! Python never runs on the request path: the Rust binary executes kernels
//! through [`runtime::Runtime`] — the PJRT CPU client over the AOT HLO
//! artifacts when built with `--features xla`, or the dependency-free
//! host-reference backend otherwise — and is self-contained either way.

pub mod analysis;
pub mod autotune;
pub mod backend;
pub mod baselines;
pub mod chunk;
pub mod codegen;
pub mod coordinator;
pub mod depgraph;
pub mod error;
pub mod hw;
pub mod kernel;
pub mod lowering;
pub mod exec;
pub mod metrics;
pub mod obs;
pub mod perf;
pub mod pipeline;
pub mod plan_io;
pub mod reports;
pub mod runtime;
pub mod schedule;
pub mod sim;
#[doc(hidden)]
pub mod testutil;
pub mod topo;
pub mod trace;
pub mod util;
pub mod workload;

pub use error::{Error, Result};
