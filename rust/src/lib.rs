//! # Syncopate
//!
//! Reproduction of *Syncopate: Efficient Multi-GPU AI Kernels via Automatic
//! Chunk-Centric Compute-Communication Overlap* as a three-layer
//! Rust + JAX + Pallas stack (see DESIGN.md).
//!
//! * **L3 (this crate)** — the paper's contribution: chunk abstraction,
//!   communication schedules, annotated-kernel frontend, dependence-graph
//!   sync insertion, backend selection, tile-scheduler swizzling, codegen
//!   to per-rank executable plans, a communication-centric autotuner, a
//!   calibrated multi-GPU discrete-event simulator, and a real-numerics
//!   multi-rank executor backed by PJRT.
//! * **L2/L1 (python/, build-time only)** — JAX per-rank compute graphs
//!   calling Pallas kernels, AOT-lowered to HLO text in `artifacts/`.
//!
//! Python never runs on the request path: the Rust binary loads the HLO
//! artifacts through the `xla` crate's PJRT CPU client and is self-contained.

pub mod autotune;
pub mod backend;
pub mod baselines;
pub mod chunk;
pub mod codegen;
pub mod coordinator;
pub mod depgraph;
pub mod error;
pub mod kernel;
pub mod lowering;
pub mod exec;
pub mod metrics;
pub mod reports;
pub mod runtime;
pub mod schedule;
pub mod sim;
pub mod topo;
pub mod util;
pub mod workload;

pub use error::{Error, Result};
