//! Process-wide observability: lock-free metrics registry + hot-path
//! counters (DESIGN.md §16).
//!
//! Three primitives, all built on `Relaxed` atomics so recording never
//! takes a lock and never allocates:
//!
//! * [`Counter`] — monotone `AtomicU64`, `inc`/`add`.
//! * [`Gauge`] — an `AtomicU64` holding `f64` bits; `set`/`get` are single
//!   Relaxed ops, `add` is a CAS loop (gauges are cold — queue depths,
//!   divergence — so contention is irrelevant).
//! * [`Histogram`] — fixed log₂-scaled buckets (`NUM_BUCKETS` words,
//!   bucket *i* holds durations in `[2^(i-1), 2^i)` µs, bucket 0 is
//!   `< 1 µs`), plus Relaxed `sum`/`max` words. p50/p90/p99 derive from a
//!   bucket walk without allocation; a histogram's **count is defined as
//!   the sum of its buckets**, so a concurrent snapshot can never observe
//!   `count != Σ buckets` — the one cross-word invariant we promise.
//!
//! Metrics live behind the global [`registry()`], keyed by a namespaced
//! name plus sorted `(label, value)` pairs. Lookup takes a registry mutex
//! (cold path, serving-tier frequency); the returned handle is
//! `&'static`, so hot sites resolve once and record lock-free forever.
//! The run-loop counters the parallel engine touches per-operation never
//! even do that: they are const-constructed statics in [`hot`], gated by
//! a Relaxed runtime toggle and compiled to empty inline no-ops under the
//! `no-obs` cargo feature so the bit-identity and hotpath-bench baselines
//! are untouched.
//!
//! Snapshot consistency model: [`Registry::snapshot`] reads every word
//! with `Relaxed` loads while writers keep writing. Each individual value
//! is coherent (no torn reads — they are single words) and monotone
//! across snapshots for counters and histogram buckets; *cross*-metric
//! and bucket-vs-sum relationships are only eventually consistent. That
//! is exactly the Prometheus scrape contract, and all we need.
//!
//! Export lives in [`export`]: Prometheus text exposition, a
//! hand-rolled JSON snapshot (schema `syncopate.stats.v1`, parsed back
//! via the `trace::json` parser — the crate has zero dependencies), and
//! [`crate::metrics::Table`] renderings for the `stats show` CLI.

pub mod export;
pub mod flight;

use std::sync::atomic::{AtomicU64, Ordering::Relaxed};
use std::sync::Mutex;

/// Log₂ bucket count: bucket 39's upper bound is 2³⁹ µs ≈ 6.4 days —
/// nothing we time lives longer.
pub const NUM_BUCKETS: usize = 40;

/// Monotone event counter (Relaxed `AtomicU64`).
#[derive(Debug)]
pub struct Counter(AtomicU64);

impl Counter {
    pub const fn new() -> Self {
        Counter(AtomicU64::new(0))
    }

    #[inline]
    pub fn inc(&self) {
        self.0.fetch_add(1, Relaxed);
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Relaxed)
    }

    pub fn reset(&self) {
        self.0.store(0, Relaxed);
    }
}

impl Default for Counter {
    fn default() -> Self {
        Counter::new()
    }
}

/// Last-write-wins instantaneous value (`f64` bits in an `AtomicU64`).
#[derive(Debug)]
pub struct Gauge(AtomicU64);

impl Gauge {
    pub const fn new() -> Self {
        // 0u64 is the bit pattern of 0.0f64.
        Gauge(AtomicU64::new(0))
    }

    #[inline]
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Relaxed);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Relaxed))
    }

    /// Add a delta (CAS loop; gauges are cold, so contention is rare).
    pub fn add(&self, d: f64) {
        let mut cur = self.0.load(Relaxed);
        loop {
            let next = (f64::from_bits(cur) + d).to_bits();
            match self.0.compare_exchange_weak(cur, next, Relaxed, Relaxed) {
                Ok(_) => return,
                Err(seen) => cur = seen,
            }
        }
    }

    pub fn inc(&self) {
        self.add(1.0);
    }

    pub fn dec(&self) {
        self.add(-1.0);
    }

    pub fn reset(&self) {
        self.0.store(0f64.to_bits(), Relaxed);
    }
}

impl Default for Gauge {
    fn default() -> Self {
        Gauge::new()
    }
}

/// Fixed-bucket log₂ latency histogram (µs domain).
#[derive(Debug)]
pub struct Histogram {
    buckets: [AtomicU64; NUM_BUCKETS],
    /// Sum of recorded durations in **nanoseconds** (u64 so `fetch_add`
    /// works; ~584 years of accumulated latency before wrap).
    sum_ns: AtomicU64,
    max_ns: AtomicU64,
}

/// Upper bound (µs) of bucket `i`: `2^i` (bucket 0 holds `< 1 µs`).
pub fn bucket_upper_us(i: usize) -> f64 {
    (1u64 << i.min(63)) as f64
}

fn bucket_index(us: f64) -> usize {
    if us.is_nan() || us < 1.0 {
        // < 1 µs, zero, negative, NaN — all land in bucket 0.
        return 0;
    }
    let n = us as u64; // floor; us >= 1 so n >= 1
    (64 - n.leading_zeros() as usize).min(NUM_BUCKETS - 1)
}

impl Histogram {
    pub const fn new() -> Self {
        #[allow(clippy::declare_interior_mutable_const)]
        const ZERO: AtomicU64 = AtomicU64::new(0);
        Histogram {
            buckets: [ZERO; NUM_BUCKETS],
            sum_ns: AtomicU64::new(0),
            max_ns: AtomicU64::new(0),
        }
    }

    /// Record one duration in microseconds (negative/NaN clamp to 0).
    #[inline]
    pub fn record_us(&self, us: f64) {
        let us = if us.is_finite() && us > 0.0 { us } else { 0.0 };
        let ns = (us * 1000.0) as u64;
        self.sum_ns.fetch_add(ns, Relaxed);
        self.max_ns.fetch_max(ns, Relaxed);
        self.buckets[bucket_index(us)].fetch_add(1, Relaxed);
    }

    /// Consistent-enough read: each bucket is one Relaxed load; `count`
    /// is *defined* as their sum, so it can never disagree with them.
    pub fn snap(&self) -> HistogramSnapshot {
        let buckets: Vec<u64> = self.buckets.iter().map(|b| b.load(Relaxed)).collect();
        let count = buckets.iter().sum();
        HistogramSnapshot {
            buckets,
            count,
            sum_us: self.sum_ns.load(Relaxed) as f64 / 1000.0,
            max_us: self.max_ns.load(Relaxed) as f64 / 1000.0,
        }
    }

    pub fn reset(&self) {
        for b in &self.buckets {
            b.store(0, Relaxed);
        }
        self.sum_ns.store(0, Relaxed);
        self.max_ns.store(0, Relaxed);
    }
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram::new()
    }
}

/// One histogram read: bucket counts + derived aggregates.
#[derive(Debug, Clone, PartialEq)]
pub struct HistogramSnapshot {
    /// `NUM_BUCKETS` counts; bucket `i` covers `[2^(i-1), 2^i)` µs.
    pub buckets: Vec<u64>,
    /// Always `buckets.iter().sum()`.
    pub count: u64,
    pub sum_us: f64,
    pub max_us: f64,
}

impl HistogramSnapshot {
    pub fn empty() -> Self {
        HistogramSnapshot { buckets: vec![0; NUM_BUCKETS], count: 0, sum_us: 0.0, max_us: 0.0 }
    }

    /// Quantile estimate (`q` in `[0, 1]`): the upper bound of the bucket
    /// containing the q-th record, clamped to the observed max. NaN when
    /// empty.
    pub fn percentile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return f64::NAN;
        }
        let target = ((q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut cum = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            cum += b;
            if cum >= target {
                let ub = bucket_upper_us(i);
                return if self.max_us > 0.0 { ub.min(self.max_us) } else { ub };
            }
        }
        self.max_us
    }

    pub fn mean_us(&self) -> f64 {
        if self.count == 0 {
            f64::NAN
        } else {
            self.sum_us / self.count as f64
        }
    }
}

/// Namespaced metric identity: dotted name + sorted label pairs.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub struct Key {
    pub name: String,
    pub labels: Vec<(String, String)>,
}

impl Key {
    pub fn new(name: &str, labels: &[(&str, &str)]) -> Self {
        let mut labels: Vec<(String, String)> =
            labels.iter().map(|(k, v)| (k.to_string(), v.to_string())).collect();
        labels.sort();
        Key { name: name.to_string(), labels }
    }
}

impl std::fmt::Display for Key {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.name)?;
        if !self.labels.is_empty() {
            let pairs: Vec<String> =
                self.labels.iter().map(|(k, v)| format!("{k}={v}")).collect();
            write!(f, "{{{}}}", pairs.join(","))?;
        }
        Ok(())
    }
}

#[derive(Debug)]
enum Metric {
    Counter(&'static Counter),
    Gauge(&'static Gauge),
    Histogram(&'static Histogram),
}

impl Metric {
    fn read(&self) -> Value {
        match self {
            Metric::Counter(c) => Value::Counter(c.get()),
            Metric::Gauge(g) => Value::Gauge(g.get()),
            Metric::Histogram(h) => Value::Histogram(h.snap()),
        }
    }

    fn reset(&self) {
        match self {
            Metric::Counter(c) => c.reset(),
            Metric::Gauge(g) => g.reset(),
            Metric::Histogram(h) => h.reset(),
        }
    }
}

/// One snapshotted metric value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Counter(u64),
    Gauge(f64),
    Histogram(HistogramSnapshot),
}

impl Value {
    pub fn kind(&self) -> &'static str {
        match self {
            Value::Counter(_) => "counter",
            Value::Gauge(_) => "gauge",
            Value::Histogram(_) => "histogram",
        }
    }
}

/// A consistent-enough, writer-transparent read of every metric, sorted
/// by key. See the module doc for the consistency model.
#[derive(Debug, Clone, PartialEq)]
pub struct Snapshot {
    pub entries: Vec<(Key, Value)>,
}

impl Snapshot {
    pub fn get(&self, name: &str, labels: &[(&str, &str)]) -> Option<&Value> {
        let key = Key::new(name, labels);
        self.entries.iter().find(|(k, _)| *k == key).map(|(_, v)| v)
    }

    pub fn counter(&self, name: &str, labels: &[(&str, &str)]) -> Option<u64> {
        match self.get(name, labels) {
            Some(Value::Counter(n)) => Some(*n),
            _ => None,
        }
    }

    pub fn gauge(&self, name: &str, labels: &[(&str, &str)]) -> Option<f64> {
        match self.get(name, labels) {
            Some(Value::Gauge(v)) => Some(*v),
            _ => None,
        }
    }

    pub fn histogram(&self, name: &str, labels: &[(&str, &str)]) -> Option<&HistogramSnapshot> {
        match self.get(name, labels) {
            Some(Value::Histogram(h)) => Some(h),
            _ => None,
        }
    }
}

/// The process-wide metric store. Registration/lookup is mutexed (cold
/// path); recording through the returned `&'static` handles never locks.
pub struct Registry {
    inner: Mutex<Vec<(Key, Metric)>>,
}

static REGISTRY: Registry = Registry { inner: Mutex::new(Vec::new()) };

/// The global registry.
pub fn registry() -> &'static Registry {
    &REGISTRY
}

impl Registry {
    /// Read every metric (registry entries + the [`hot`] statics) without
    /// stopping writers.
    pub fn snapshot(&self) -> Snapshot {
        let mut entries: Vec<(Key, Value)> = {
            let inner = self.inner.lock().unwrap();
            inner.iter().map(|(k, m)| (k.clone(), m.read())).collect()
        };
        entries.extend(hot::entries());
        entries.extend(flight::entries());
        entries.sort_by(|a, b| a.0.cmp(&b.0));
        Snapshot { entries }
    }

    /// Zero every metric (keys stay registered; handles stay valid).
    pub fn reset(&self) {
        for (_, m) in self.inner.lock().unwrap().iter() {
            m.reset();
        }
        hot::reset_counters();
        flight::reset_counters();
    }

    fn counter_entry(&self, name: &str, labels: &[(&str, &str)]) -> &'static Counter {
        let key = Key::new(name, labels);
        let mut inner = self.inner.lock().unwrap();
        if let Some((_, m)) = inner.iter().find(|(k, _)| *k == key) {
            match m {
                // `*c` copies the inner `&'static` out of the guard borrow
                Metric::Counter(c) => return *c,
                other => panic!("obs: `{key}` already registered as a {}", other.read().kind()),
            }
        }
        let c: &'static Counter = Box::leak(Box::new(Counter::new()));
        inner.push((key, Metric::Counter(c)));
        c
    }

    fn gauge_entry(&self, name: &str, labels: &[(&str, &str)]) -> &'static Gauge {
        let key = Key::new(name, labels);
        let mut inner = self.inner.lock().unwrap();
        if let Some((_, m)) = inner.iter().find(|(k, _)| *k == key) {
            match m {
                Metric::Gauge(g) => return *g,
                other => panic!("obs: `{key}` already registered as a {}", other.read().kind()),
            }
        }
        let g: &'static Gauge = Box::leak(Box::new(Gauge::new()));
        inner.push((key, Metric::Gauge(g)));
        g
    }

    fn histogram_entry(&self, name: &str, labels: &[(&str, &str)]) -> &'static Histogram {
        let key = Key::new(name, labels);
        let mut inner = self.inner.lock().unwrap();
        if let Some((_, m)) = inner.iter().find(|(k, _)| *k == key) {
            match m {
                Metric::Histogram(h) => return *h,
                other => panic!("obs: `{key}` already registered as a {}", other.read().kind()),
            }
        }
        let h: &'static Histogram = Box::leak(Box::new(Histogram::new()));
        inner.push((key, Metric::Histogram(h)));
        h
    }
}

/// Resolve (registering on first use) a label-free counter.
pub fn counter(name: &str) -> &'static Counter {
    REGISTRY.counter_entry(name, &[])
}

/// Resolve a labeled counter.
pub fn counter_with(name: &str, labels: &[(&str, &str)]) -> &'static Counter {
    REGISTRY.counter_entry(name, labels)
}

/// Resolve a label-free gauge.
pub fn gauge(name: &str) -> &'static Gauge {
    REGISTRY.gauge_entry(name, &[])
}

/// Resolve a labeled gauge.
pub fn gauge_with(name: &str, labels: &[(&str, &str)]) -> &'static Gauge {
    REGISTRY.gauge_entry(name, labels)
}

/// Resolve a label-free histogram.
pub fn histogram(name: &str) -> &'static Histogram {
    REGISTRY.histogram_entry(name, &[])
}

/// Resolve a labeled histogram.
pub fn histogram_with(name: &str, labels: &[(&str, &str)]) -> &'static Histogram {
    REGISTRY.histogram_entry(name, labels)
}

/// Bump the process-wide `error_total{kind=...}` counter (deadlock
/// verdicts, serve rejections, ... — anything that returns an `Error` to
/// a caller who may swallow it).
pub fn error_total(kind: &str) {
    counter_with("error_total", &[("kind", kind)]).inc();
}

/// Elapsed microseconds since `t` (instrumentation helper).
pub fn us_since(t: std::time::Instant) -> f64 {
    t.elapsed().as_secs_f64() * 1e6
}

/// Hot-path counters: const-constructed statics the parallel engine
/// bumps per-operation. No registry lookup ever happens on the run loop —
/// these are resolved at link time and merged into snapshots explicitly.
///
/// Two off switches:
/// * the `no-obs` cargo feature compiles the record functions to empty
///   inline no-ops (the hard baseline for bit-identity / bench purity);
/// * [`set_enabled`] is a Relaxed runtime toggle, letting one bench
///   binary measure obs-on vs obs-off in the same run.
pub mod hot {
    use super::{Counter, Key, Value};
    use std::sync::atomic::{AtomicBool, Ordering::Relaxed};

    pub static PARKS: Counter = Counter::new();
    pub static UNPARKS: Counter = Counter::new();
    pub static QUEUE_DRAINED: Counter = Counter::new();
    pub static SEEN_SHORT_CIRCUITS: Counter = Counter::new();
    pub static ARENA_REUSES: Counter = Counter::new();

    static ENABLED: AtomicBool = AtomicBool::new(true);

    /// Runtime toggle for the hot counters (benchmark A/B switch).
    pub fn set_enabled(on: bool) {
        ENABLED.store(on, Relaxed);
    }

    pub fn enabled() -> bool {
        ENABLED.load(Relaxed)
    }

    #[cfg(not(feature = "no-obs"))]
    #[inline(always)]
    fn on() -> bool {
        ENABLED.load(Relaxed)
    }

    /// One `park_timeout` actually entered by a rank thread.
    #[cfg(not(feature = "no-obs"))]
    #[inline(always)]
    pub fn park() {
        if on() {
            PARKS.inc();
        }
    }

    /// One targeted `Thread::unpark` issued by a signal producer.
    #[cfg(not(feature = "no-obs"))]
    #[inline(always)]
    pub fn unpark() {
        if on() {
            UNPARKS.inc();
        }
    }

    /// `n` parked transfers drained from a rank-owned queue.
    #[cfg(not(feature = "no-obs"))]
    #[inline(always)]
    pub fn queue_drained(n: usize) {
        if n > 0 && on() {
            QUEUE_DRAINED.add(n as u64);
        }
    }

    /// One dep check answered by the thread-local `SeenSignals` cache
    /// without touching shared state.
    #[cfg(not(feature = "no-obs"))]
    #[inline(always)]
    pub fn seen_short_circuit() {
        if on() {
            SEEN_SHORT_CIRCUITS.inc();
        }
    }

    /// One warm `run_prepared_reusing` replay of an existing arena.
    #[cfg(not(feature = "no-obs"))]
    #[inline(always)]
    pub fn arena_reuse() {
        if on() {
            ARENA_REUSES.inc();
        }
    }

    #[cfg(feature = "no-obs")]
    #[inline(always)]
    pub fn park() {}

    #[cfg(feature = "no-obs")]
    #[inline(always)]
    pub fn unpark() {}

    #[cfg(feature = "no-obs")]
    #[inline(always)]
    pub fn queue_drained(_n: usize) {}

    #[cfg(feature = "no-obs")]
    #[inline(always)]
    pub fn seen_short_circuit() {}

    #[cfg(feature = "no-obs")]
    #[inline(always)]
    pub fn arena_reuse() {}

    pub(super) fn entries() -> Vec<(Key, Value)> {
        [
            ("hot.parks", &PARKS),
            ("hot.unparks", &UNPARKS),
            ("hot.queue_drained", &QUEUE_DRAINED),
            ("hot.seen_short_circuits", &SEEN_SHORT_CIRCUITS),
            ("hot.arena_reuses", &ARENA_REUSES),
        ]
        .into_iter()
        .map(|(name, c)| (Key::new(name, &[]), Value::Counter(c.get())))
        .collect()
    }

    pub(super) fn reset_counters() {
        for c in [&PARKS, &UNPARKS, &QUEUE_DRAINED, &SEEN_SHORT_CIRCUITS, &ARENA_REUSES] {
            c.reset();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicBool as TestBool, Ordering};

    #[test]
    fn counter_basics() {
        let c = Counter::new();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        c.reset();
        assert_eq!(c.get(), 0);
    }

    #[test]
    fn gauge_set_add_dec() {
        let g = Gauge::new();
        assert_eq!(g.get(), 0.0);
        g.set(2.5);
        assert_eq!(g.get(), 2.5);
        g.add(1.5);
        assert_eq!(g.get(), 4.0);
        g.dec();
        assert_eq!(g.get(), 3.0);
        g.reset();
        assert_eq!(g.get(), 0.0);
    }

    #[test]
    fn histogram_buckets_and_bounds() {
        assert_eq!(bucket_index(0.0), 0);
        assert_eq!(bucket_index(0.9), 0);
        assert_eq!(bucket_index(1.0), 1);
        assert_eq!(bucket_index(1.9), 1);
        assert_eq!(bucket_index(2.0), 2);
        assert_eq!(bucket_index(1000.0), 10);
        assert_eq!(bucket_index(f64::NAN), 0);
        assert_eq!(bucket_index(1e30), NUM_BUCKETS - 1);
        assert_eq!(bucket_upper_us(0), 1.0);
        assert_eq!(bucket_upper_us(10), 1024.0);
    }

    #[test]
    fn histogram_percentiles_clamped_to_max() {
        let h = Histogram::new();
        for _ in 0..100 {
            h.record_us(10.0); // bucket 4, upper bound 16
        }
        let s = h.snap();
        assert_eq!(s.count, 100);
        assert_eq!(s.buckets.iter().sum::<u64>(), 100);
        assert!((s.mean_us() - 10.0).abs() < 1e-9);
        assert_eq!(s.max_us, 10.0);
        // upper bound 16 clamps to the observed max
        assert_eq!(s.percentile(0.5), 10.0);
        assert_eq!(s.percentile(0.99), 10.0);
    }

    #[test]
    fn histogram_percentiles_spread() {
        let h = Histogram::new();
        // 90 fast records (~2µs, bucket 2) + 10 slow (~1000µs, bucket 10)
        for _ in 0..90 {
            h.record_us(2.0);
        }
        for _ in 0..10 {
            h.record_us(1000.0);
        }
        let s = h.snap();
        assert_eq!(s.count, 100);
        assert_eq!(s.percentile(0.5), 4.0); // bucket 2 upper bound
        assert_eq!(s.percentile(0.9), 4.0);
        assert_eq!(s.percentile(0.99), 1000.0); // bucket 10 ub 1024 -> max
        let empty = HistogramSnapshot::empty();
        assert!(empty.percentile(0.5).is_nan());
        assert!(empty.mean_us().is_nan());
    }

    #[test]
    fn registry_handles_are_singletons() {
        let a = counter_with("test.obs.single", &[("x", "1")]);
        let b = counter_with("test.obs.single", &[("x", "1")]);
        let c = counter_with("test.obs.single", &[("x", "2")]);
        assert!(std::ptr::eq(a, b));
        assert!(!std::ptr::eq(a, c));
        let g1 = gauge("test.obs.single_gauge");
        let g2 = gauge("test.obs.single_gauge");
        assert!(std::ptr::eq(g1, g2));
        let h1 = histogram("test.obs.single_hist");
        let h2 = histogram("test.obs.single_hist");
        assert!(std::ptr::eq(h1, h2));
    }

    #[test]
    fn snapshot_sees_registered_metrics() {
        // Unique names: unit tests share one process-wide registry.
        counter_with("test.obs.snap_counter", &[("k", "v")]).add(7);
        gauge("test.obs.snap_gauge").set(1.25);
        histogram("test.obs.snap_hist").record_us(3.0);
        let s = registry().snapshot();
        assert!(s.counter("test.obs.snap_counter", &[("k", "v")]).unwrap() >= 7);
        assert_eq!(s.gauge("test.obs.snap_gauge", &[]), Some(1.25));
        assert!(s.histogram("test.obs.snap_hist", &[]).unwrap().count >= 1);
        // hot statics are merged into every snapshot
        assert!(s.get("hot.parks", &[]).is_some());
        assert!(s.get("hot.arena_reuses", &[]).is_some());
        // sorted by key
        for w in s.entries.windows(2) {
            assert!(w[0].0 <= w[1].0, "{} vs {}", w[0].0, w[1].0);
        }
    }

    #[test]
    fn error_total_is_labeled() {
        error_total("test-kind");
        error_total("test-kind");
        let s = registry().snapshot();
        assert!(s.counter("error_total", &[("kind", "test-kind")]).unwrap() >= 2);
    }

    #[test]
    fn key_display_formats_labels() {
        assert_eq!(Key::new("a.b", &[]).to_string(), "a.b");
        let k = Key::new("a.b", &[("z", "1"), ("a", "2")]);
        // labels sort
        assert_eq!(k.to_string(), "a.b{a=2,z=1}");
    }

    #[test]
    fn concurrent_counter_totals_exact() {
        const THREADS: usize = 8;
        const PER: u64 = 10_000;
        let c = Counter::new();
        std::thread::scope(|s| {
            for _ in 0..THREADS {
                s.spawn(|| {
                    for _ in 0..PER {
                        c.inc();
                    }
                });
            }
        });
        assert_eq!(c.get(), THREADS as u64 * PER);
    }

    #[test]
    fn concurrent_histogram_snapshots_never_tear() {
        const WRITERS: usize = 4;
        const PER: usize = 5_000;
        let h = Histogram::new();
        let done = TestBool::new(false);
        std::thread::scope(|s| {
            for w in 0..WRITERS {
                let h = &h;
                s.spawn(move || {
                    for i in 0..PER {
                        // spread across buckets
                        h.record_us(((w * PER + i) % 4096) as f64);
                    }
                });
            }
            let reader = s.spawn(|| {
                let mut last_count = 0u64;
                let mut reads = 0usize;
                while !done.load(Ordering::Acquire) {
                    let snap = h.snap();
                    // count is defined as the bucket sum: no torn view
                    assert_eq!(snap.count, snap.buckets.iter().sum::<u64>());
                    assert!(snap.count >= last_count, "count went backwards");
                    assert!(snap.count <= (WRITERS * PER) as u64);
                    last_count = snap.count;
                    reads += 1;
                }
                reads
            });
            // writers finish when the unnamed spawns above are joined by
            // scope exit; signal the reader from a watcher thread that
            // observes the total reaching the target
            s.spawn(|| {
                while h.snap().count < (WRITERS * PER) as u64 {
                    std::hint::spin_loop();
                }
                done.store(true, Ordering::Release);
            });
            let reads = reader.join().unwrap();
            assert!(reads > 0);
        });
        let fin = h.snap();
        assert_eq!(fin.count, (WRITERS * PER) as u64);
        assert!(fin.max_us <= 4096.0);
        assert!(fin.percentile(0.5).is_finite());
    }

    #[test]
    fn reset_zeroes_registered_metrics() {
        // A PRIVATE registry: resetting the global one here would race the
        // delta-based assertions of every other test in this process.
        // (The global `registry().reset()` path — which also zeroes the
        // `hot` statics — is exercised by the `stats reset` CLI verb.)
        let reg = Registry { inner: Mutex::new(Vec::new()) };
        let c = reg.counter_entry("test.obs.reset_counter", &[]);
        let g = reg.gauge_entry("test.obs.reset_gauge", &[]);
        let h = reg.histogram_entry("test.obs.reset_hist", &[]);
        c.add(3);
        g.set(2.0);
        h.record_us(5.0);
        for (_, m) in reg.inner.lock().unwrap().iter() {
            m.reset();
        }
        assert_eq!(c.get(), 0);
        assert_eq!(g.get(), 0.0);
        assert_eq!(h.snap().count, 0);
    }

    #[test]
    fn hot_toggle_gates_recording() {
        // Delta-based: other tests (and engine tests) bump these too.
        hot::set_enabled(false);
        let before = hot::SEEN_SHORT_CIRCUITS.get();
        for _ in 0..100_000 {
            hot::seen_short_circuit();
        }
        let disabled_delta = hot::SEEN_SHORT_CIRCUITS.get().saturating_sub(before);
        hot::set_enabled(true);
        // anything recorded while disabled came from concurrent tests,
        // never from our 100k calls
        assert!(disabled_delta < 50_000, "toggle off still recorded {disabled_delta}");
        #[cfg(not(feature = "no-obs"))]
        {
            let before = hot::SEEN_SHORT_CIRCUITS.get();
            for _ in 0..100 {
                hot::seen_short_circuit();
            }
            assert!(hot::SEEN_SHORT_CIRCUITS.get() - before >= 100);
        }
        assert!(hot::enabled());
    }
}
