//! Snapshot export: Prometheus text exposition, JSON stats snapshots
//! (schema `syncopate.stats.v1`), and [`Table`] renderings for the
//! `stats show` CLI — all hand-rolled, same zero-dependency discipline as
//! `trace::json` (whose parser reads the JSON back).
//!
//! Exposition grammar (the subset of the Prometheus text format we
//! emit; see DESIGN.md §16):
//!
//! ```text
//! # TYPE syncopate_<name> counter|gauge|histogram
//! syncopate_<name>{label="value",...} <number>
//! ```
//!
//! Metric names sanitize `.`/`-` (and anything non-alphanumeric) to `_`
//! and carry a `syncopate_` prefix. Histograms expand to cumulative
//! `_bucket{le="2^i"}` samples (buckets up to the last non-empty one,
//! then `le="+Inf"`), `_sum`, and `_count`.

use crate::error::{Error, Result};
use crate::metrics::Table;
use crate::obs::{bucket_upper_us, HistogramSnapshot, Key, Snapshot, Value, NUM_BUCKETS};
use crate::trace::json::{parse as parse_json, Json};
use std::fmt::Write as _;

/// Schema tag stamped on (and required of) every JSON stats snapshot.
pub const STATS_SCHEMA: &str = "syncopate.stats.v1";

/// Prometheus-safe metric name: `syncopate_` prefix, every
/// non-alphanumeric byte mapped to `_`.
pub fn sanitize(name: &str) -> String {
    let mut out = String::with_capacity(name.len() + 10);
    out.push_str("syncopate_");
    for c in name.chars() {
        if c.is_ascii_alphanumeric() || c == '_' {
            out.push(c);
        } else {
            out.push('_');
        }
    }
    out
}

fn escape_label(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

/// Shortest-round-trip number, integers without a trailing `.0`.
fn fmt_num(v: f64) -> String {
    if v.is_finite() && v.fract() == 0.0 && v.abs() < 9.0e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

fn sample_name(base: &str, labels: &[(String, String)], extra: Option<(&str, &str)>) -> String {
    let mut pairs: Vec<String> =
        labels.iter().map(|(k, v)| format!("{k}=\"{}\"", escape_label(v))).collect();
    if let Some((k, v)) = extra {
        pairs.push(format!("{k}=\"{}\"", escape_label(v)));
    }
    if pairs.is_empty() {
        base.to_string()
    } else {
        format!("{base}{{{}}}", pairs.join(","))
    }
}

/// Flatten a snapshot into `(sample_name, value)` pairs — the exact
/// sample set [`to_prometheus`] renders, exposed so the golden test can
/// assert `parse(render(s)) == flatten(s)`.
pub fn flatten(snap: &Snapshot) -> Vec<(String, f64)> {
    let mut out = Vec::new();
    for (key, value) in &snap.entries {
        let base = sanitize(&key.name);
        match value {
            Value::Counter(n) => out.push((sample_name(&base, &key.labels, None), *n as f64)),
            Value::Gauge(v) => out.push((sample_name(&base, &key.labels, None), *v)),
            Value::Histogram(h) => {
                let bucket_base = format!("{base}_bucket");
                let last = h.buckets.iter().rposition(|&b| b > 0);
                let mut cum = 0u64;
                if let Some(last) = last {
                    for (i, b) in h.buckets.iter().enumerate().take(last + 1) {
                        cum += b;
                        let le = fmt_num(bucket_upper_us(i));
                        out.push((
                            sample_name(&bucket_base, &key.labels, Some(("le", &le))),
                            cum as f64,
                        ));
                    }
                }
                out.push((
                    sample_name(&bucket_base, &key.labels, Some(("le", "+Inf"))),
                    h.count as f64,
                ));
                out.push((sample_name(&format!("{base}_sum"), &key.labels, None), h.sum_us));
                out.push((
                    sample_name(&format!("{base}_count"), &key.labels, None),
                    h.count as f64,
                ));
            }
        }
    }
    out
}

/// Render a snapshot in Prometheus text-exposition format.
pub fn to_prometheus(snap: &Snapshot) -> String {
    let mut out = String::new();
    let mut typed: Vec<String> = Vec::new();
    // TYPE headers interleave with their samples; emit per metric name.
    for (key, value) in &snap.entries {
        let base = sanitize(&key.name);
        if !typed.contains(&base) {
            let _ = writeln!(out, "# TYPE {base} {}", value.kind());
            typed.push(base);
        }
        for (name, v) in flatten(&Snapshot { entries: vec![(key.clone(), value.clone())] }) {
            let _ = writeln!(out, "{name} {}", fmt_num(v));
        }
    }
    out
}

/// Parse the exposition format back into `(sample_name, value)` pairs
/// (comment lines skipped) — the golden-test inverse of
/// [`to_prometheus`].
pub fn parse_prometheus(text: &str) -> Result<Vec<(String, f64)>> {
    let mut out = Vec::new();
    for (ln, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let Some(space) = line.rfind(' ') else {
            return Err(Error::Io(format!("exposition line {}: no value: `{line}`", ln + 1)));
        };
        let (name, value) = line.split_at(space);
        let value: f64 = value
            .trim()
            .parse()
            .map_err(|_| Error::Io(format!("exposition line {}: bad number `{value}`", ln + 1)))?;
        out.push((name.to_string(), value));
    }
    Ok(out)
}

fn labels_json(labels: &[(String, String)]) -> String {
    let esc = crate::util::json_escape;
    let pairs: Vec<String> =
        labels.iter().map(|(k, v)| format!("\"{}\": \"{}\"", esc(k), esc(v))).collect();
    format!("{{{}}}", pairs.join(", "))
}

fn json_f64(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".to_string()
    }
}

/// Render a snapshot as a `syncopate.stats.v1` JSON document.
///
/// Histograms carry their non-empty buckets as `[upper_us, count]`
/// pairs plus derived p50/p90/p99 (informational — [`from_json`]
/// re-derives them from the buckets). Non-finite numbers render as
/// `null`, so the document is always valid JSON.
pub fn to_json(snap: &Snapshot) -> String {
    let esc = crate::util::json_escape;
    let mut out = String::new();
    let _ = writeln!(out, "{{");
    let _ = writeln!(out, "  \"schema\": \"{STATS_SCHEMA}\",");
    let _ = writeln!(out, "  \"metrics\": [");
    for (i, (key, value)) in snap.entries.iter().enumerate() {
        let sep = if i + 1 < snap.entries.len() { "," } else { "" };
        let head = format!(
            "\"name\": \"{}\", \"labels\": {}, \"kind\": \"{}\"",
            esc(&key.name),
            labels_json(&key.labels),
            value.kind()
        );
        match value {
            Value::Counter(n) => {
                let _ = writeln!(out, "    {{{head}, \"value\": {n}}}{sep}");
            }
            Value::Gauge(v) => {
                let _ = writeln!(out, "    {{{head}, \"value\": {}}}{sep}", json_f64(*v));
            }
            Value::Histogram(h) => {
                let buckets: Vec<String> = h
                    .buckets
                    .iter()
                    .enumerate()
                    .filter(|(_, &b)| b > 0)
                    .map(|(i, b)| format!("[{}, {b}]", fmt_num(bucket_upper_us(i))))
                    .collect();
                let _ = writeln!(
                    out,
                    "    {{{head}, \"count\": {}, \"sum_us\": {}, \"max_us\": {}, \
                     \"p50_us\": {}, \"p90_us\": {}, \"p99_us\": {}, \"buckets\": [{}]}}{sep}",
                    h.count,
                    json_f64(h.sum_us),
                    json_f64(h.max_us),
                    json_f64(h.percentile(0.5)),
                    json_f64(h.percentile(0.9)),
                    json_f64(h.percentile(0.99)),
                    buckets.join(", ")
                );
            }
        }
    }
    let _ = writeln!(out, "  ]");
    let _ = writeln!(out, "}}");
    out
}

fn key_from_json(m: &Json) -> Result<Key> {
    let name = m
        .get("name")
        .and_then(Json::as_str)
        .ok_or_else(|| Error::Io("stats metric missing `name`".into()))?;
    let mut labels: Vec<(String, String)> = Vec::new();
    if let Some(Json::Obj(pairs)) = m.get("labels") {
        for (k, v) in pairs {
            let v = v
                .as_str()
                .ok_or_else(|| Error::Io(format!("label `{k}` of `{name}` is not a string")))?;
            labels.push((k.clone(), v.to_string()));
        }
    }
    labels.sort();
    Ok(Key { name: name.to_string(), labels })
}

fn histogram_from_json(m: &Json, key: &Key) -> Result<HistogramSnapshot> {
    let count = m
        .get("count")
        .and_then(Json::as_usize)
        .ok_or_else(|| Error::Io(format!("histogram `{key}` missing `count`")))?
        as u64;
    let sum_us = m.get("sum_us").and_then(Json::as_f64).unwrap_or(f64::NAN);
    let max_us = m.get("max_us").and_then(Json::as_f64).unwrap_or(f64::NAN);
    let mut buckets = vec![0u64; NUM_BUCKETS];
    let pairs = m
        .get("buckets")
        .and_then(Json::as_arr)
        .ok_or_else(|| Error::Io(format!("histogram `{key}` missing `buckets`")))?;
    for pair in pairs {
        let pair = pair
            .as_arr()
            .filter(|p| p.len() == 2)
            .ok_or_else(|| Error::Io(format!("histogram `{key}`: bucket must be [le, count]")))?;
        let le = pair[0]
            .as_f64()
            .ok_or_else(|| Error::Io(format!("histogram `{key}`: bad bucket bound")))?;
        let n = pair[1]
            .as_usize()
            .ok_or_else(|| Error::Io(format!("histogram `{key}`: bad bucket count")))?;
        let idx = (0..NUM_BUCKETS)
            .find(|&i| bucket_upper_us(i) == le)
            .ok_or_else(|| Error::Io(format!("histogram `{key}`: `{le}` is not a bucket bound")))?;
        buckets[idx] = n as u64;
    }
    if buckets.iter().sum::<u64>() != count {
        return Err(Error::Io(format!("histogram `{key}`: count != sum of buckets")));
    }
    Ok(HistogramSnapshot { buckets, count, sum_us, max_us })
}

/// Parse a `syncopate.stats.v1` document back into a [`Snapshot`]
/// (schema-checked; the `stats show FILE` path).
pub fn from_json(text: &str) -> Result<Snapshot> {
    let doc = parse_json(text).map_err(|e| Error::Io(format!("stats snapshot: {e}")))?;
    match doc.get("schema").and_then(Json::as_str) {
        Some(STATS_SCHEMA) => {}
        Some(other) => {
            return Err(Error::Io(format!(
                "stats snapshot schema `{other}` (expected `{STATS_SCHEMA}`)"
            )))
        }
        None => return Err(Error::Io("stats snapshot missing `schema` tag".into())),
    }
    let metrics = doc
        .get("metrics")
        .and_then(Json::as_arr)
        .ok_or_else(|| Error::Io("stats snapshot missing `metrics` array".into()))?;
    let mut entries = Vec::with_capacity(metrics.len());
    for m in metrics {
        let key = key_from_json(m)?;
        let kind = m
            .get("kind")
            .and_then(Json::as_str)
            .ok_or_else(|| Error::Io(format!("metric `{key}` missing `kind`")))?;
        let value = match kind {
            "counter" => Value::Counter(
                m.get("value")
                    .and_then(Json::as_usize)
                    .ok_or_else(|| Error::Io(format!("counter `{key}` missing `value`")))?
                    as u64,
            ),
            "gauge" => Value::Gauge(match m.get("value") {
                Some(Json::Null) => f64::NAN,
                Some(v) => v
                    .as_f64()
                    .ok_or_else(|| Error::Io(format!("gauge `{key}` has a non-number value")))?,
                None => return Err(Error::Io(format!("gauge `{key}` missing `value`"))),
            }),
            "histogram" => Value::Histogram(histogram_from_json(m, &key)?),
            other => return Err(Error::Io(format!("metric `{key}`: unknown kind `{other}`"))),
        };
        entries.push((key, value));
    }
    entries.sort_by(|a, b| a.0.cmp(&b.0));
    Ok(Snapshot { entries })
}

/// Validate that `text` is a well-formed `syncopate.stats.v1` snapshot
/// (the CI schema check).
pub fn check_schema(text: &str) -> Result<()> {
    from_json(text).map(|_| ())
}

/// Render a snapshot as paper-style [`Table`]s: one for counters, one
/// for gauges, one for histograms (count/mean/p50/p90/p99/max).
/// Zero-valued counters and empty histograms are elided — the JSON
/// snapshot keeps everything; the tables are the human view.
pub fn tables(snap: &Snapshot) -> Vec<Table> {
    let mut counters = Table::new("stats: counters", &["value"], "count");
    let mut gauges = Table::new("stats: gauges", &["value"], "value");
    let mut hists = Table::new(
        "stats: latency histograms",
        &["count", "mean us", "p50 us", "p90 us", "p99 us", "max us"],
        "us",
    );
    for (key, value) in &snap.entries {
        let label = key.to_string();
        match value {
            Value::Counter(n) => {
                if *n > 0 {
                    counters.push_row(&label, vec![*n as f64]);
                }
            }
            Value::Gauge(v) => gauges.push_row(&label, vec![*v]),
            Value::Histogram(h) => {
                if h.count > 0 {
                    hists.push_row(
                        &label,
                        vec![
                            h.count as f64,
                            h.mean_us(),
                            h.percentile(0.5),
                            h.percentile(0.9),
                            h.percentile(0.99),
                            h.max_us,
                        ],
                    );
                }
            }
        }
    }
    [counters, gauges, hists].into_iter().filter(|t| !t.rows.is_empty()).collect()
}

/// Human rendering of a whole snapshot (the `stats show` output).
pub fn render(snap: &Snapshot) -> String {
    let ts = tables(snap);
    if ts.is_empty() {
        return "stats: no metrics recorded\n".to_string();
    }
    ts.iter().map(|t| t.render()).collect::<Vec<_>>().join("\n")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::Histogram;

    fn sample_snapshot() -> Snapshot {
        let h = Histogram::new();
        h.record_us(2.0);
        h.record_us(2.0);
        h.record_us(10.0);
        Snapshot {
            entries: vec![
                (Key::new("exec.iter_us", &[("case", "ag")]), Value::Histogram(h.snap())),
                (Key::new("queue.depth", &[]), Value::Gauge(2.0)),
                (Key::new("serve.requests", &[("kind", "op")]), Value::Counter(5)),
            ],
        }
    }

    #[test]
    fn prometheus_golden() {
        let text = to_prometheus(&sample_snapshot());
        let expected = "\
# TYPE syncopate_exec_iter_us histogram
syncopate_exec_iter_us_bucket{case=\"ag\",le=\"1\"} 0
syncopate_exec_iter_us_bucket{case=\"ag\",le=\"2\"} 0
syncopate_exec_iter_us_bucket{case=\"ag\",le=\"4\"} 2
syncopate_exec_iter_us_bucket{case=\"ag\",le=\"8\"} 2
syncopate_exec_iter_us_bucket{case=\"ag\",le=\"16\"} 3
syncopate_exec_iter_us_bucket{case=\"ag\",le=\"+Inf\"} 3
syncopate_exec_iter_us_sum{case=\"ag\"} 14
syncopate_exec_iter_us_count{case=\"ag\"} 3
# TYPE syncopate_queue_depth gauge
syncopate_queue_depth 2
# TYPE syncopate_serve_requests counter
syncopate_serve_requests{kind=\"op\"} 5
";
        assert_eq!(text, expected);
    }

    #[test]
    fn exposition_parse_render_round_trip() {
        let snap = sample_snapshot();
        let parsed = parse_prometheus(&to_prometheus(&snap)).unwrap();
        assert_eq!(parsed, flatten(&snap));
    }

    #[test]
    fn exposition_parser_rejects_garbage() {
        assert!(parse_prometheus("no_value_here").is_err());
        assert!(parse_prometheus("x notanumber").is_err());
        assert!(parse_prometheus("# comment only\n").unwrap().is_empty());
    }

    #[test]
    fn sanitize_maps_punctuation() {
        assert_eq!(sanitize("serve.phase_us"), "syncopate_serve_phase_us");
        assert_eq!(sanitize("a-b.c"), "syncopate_a_b_c");
    }

    #[test]
    fn json_round_trip_is_exact() {
        let snap = sample_snapshot();
        let text = to_json(&snap);
        let back = from_json(&text).unwrap();
        assert_eq!(back, snap);
        check_schema(&text).unwrap();
        // the document parses under the strict trace::json reader
        crate::trace::json::parse(&text).unwrap();
    }

    #[test]
    fn json_marks_non_finite_as_null() {
        let snap = Snapshot {
            entries: vec![(Key::new("g", &[]), Value::Gauge(f64::NAN))],
        };
        let text = to_json(&snap);
        assert!(text.contains("\"value\": null"), "{text}");
        crate::trace::json::parse(&text).unwrap();
        let back = from_json(&text).unwrap();
        match back.get("g", &[]) {
            Some(Value::Gauge(v)) => assert!(v.is_nan()),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn schema_check_rejects_malformed() {
        assert!(check_schema("{}").is_err());
        assert!(check_schema("{\"schema\": \"other.v9\", \"metrics\": []}").is_err());
        assert!(check_schema("{\"schema\": \"syncopate.stats.v1\"}").is_err());
        // bucket bound that is not a power of two
        let bad = "{\"schema\": \"syncopate.stats.v1\", \"metrics\": [\
                   {\"name\": \"h\", \"labels\": {}, \"kind\": \"histogram\", \
                   \"count\": 1, \"sum_us\": 1, \"max_us\": 1, \"buckets\": [[3, 1]]}]}";
        assert!(check_schema(bad).is_err());
        // count disagreeing with buckets
        let torn = "{\"schema\": \"syncopate.stats.v1\", \"metrics\": [\
                    {\"name\": \"h\", \"labels\": {}, \"kind\": \"histogram\", \
                    \"count\": 5, \"sum_us\": 1, \"max_us\": 1, \"buckets\": [[4, 1]]}]}";
        assert!(check_schema(torn).is_err());
        // unknown kind
        let odd = "{\"schema\": \"syncopate.stats.v1\", \"metrics\": [\
                   {\"name\": \"x\", \"labels\": {}, \"kind\": \"meter\", \"value\": 1}]}";
        assert!(check_schema(odd).is_err());
    }

    #[test]
    fn tables_elide_empty_series() {
        let mut snap = sample_snapshot();
        snap.entries.push((Key::new("zero.counter", &[]), Value::Counter(0)));
        snap.entries
            .push((Key::new("empty.hist", &[]), Value::Histogram(HistogramSnapshot::empty())));
        let ts = tables(&snap);
        let all: String = ts.iter().map(|t| t.render()).collect();
        assert!(all.contains("exec.iter_us{case=ag}"), "{all}");
        assert!(all.contains("serve.requests{kind=op}"), "{all}");
        assert!(!all.contains("zero.counter"), "{all}");
        assert!(!all.contains("empty.hist"), "{all}");
        let r = render(&snap);
        assert!(r.contains("stats: counters"), "{r}");
        assert!(r.contains("stats: latency histograms"), "{r}");
    }
}
