//! Flight recorder: always-on, per-rank lock-free event rings for
//! post-mortem debugging and request-scoped causal tracing (DESIGN.md §18).
//!
//! The metrics registry ([`super`]) answers "how much / how often"; the
//! [`crate::trace`] subsystem answers "exactly when", but only for runs
//! that opted into capture. This module fills the gap between them: a
//! cheap, *always-on* record of the last ~[`RING_CAPACITY`] causal events
//! per rank (op issue/apply, signal set/wait, park/unpark, queue drains,
//! request phases), so that when a run deadlocks or a served request
//! errors, the post-mortem question — *what was each rank doing just
//! before it stopped?* — has an answer without re-running under a tracer.
//!
//! Design:
//!
//! * **Rings** — one fixed power-of-two ring per rank lane (plus one
//!   control lane for coordinator threads). Events are two packed `u64`
//!   words in per-slot seqlocks. Writers claim a slot with one Relaxed
//!   `fetch_add` on the lane head and publish with one Release store;
//!   overwrite-oldest means recording never blocks and never allocates.
//! * **Snapshot** — a reader drains the published window `[head-cap, head)`
//!   and validates each slot's sequence word around the data reads
//!   (crossbeam-style seqlock: odd = write in progress). Slots caught
//!   mid-overwrite are skipped and counted, never torn.
//! * **Gating** — like [`super::hot`]: a Relaxed runtime toggle
//!   ([`set_enabled`]) plus the `no-obs` cargo feature compiling every
//!   record fn to an empty inline body.
//! * **Request scope** — coordinator workers stamp a monotonic request ID
//!   into a thread-local ([`set_request`]); every event records the ID of
//!   the request it happened under, so one ring holds interleaved events
//!   from many requests and a dump can still reconstruct each lifecycle.
//!
//! Dumps render as `syncopate.flight.v1` JSON ([`to_json`] /
//! [`from_json`], exact round trip) and as Chrome `trace_event` JSON
//! ([`to_chrome_json`], same viewer as `exec --trace` captures).

use std::cell::Cell;
use std::sync::atomic::{fence, AtomicBool, AtomicU64, Ordering::Acquire, Ordering::Relaxed,
    Ordering::Release};
use std::sync::Mutex;
use std::time::Instant;

use super::{Counter, Key, Value};
use crate::error::{Error, Result};

/// Events kept per lane (power of two; the seqlock mask depends on it).
pub const RING_CAPACITY: usize = 512;
const MASK: u64 = RING_CAPACITY as u64 - 1;

/// Rank lanes 0..16 plus one control lane for coordinator threads.
pub const LANES: usize = 17;

/// Sentinel rank for control-plane (coordinator worker) events.
pub const CTRL_RANK: u8 = 0xFF;

// --- event codes (u8 in the packed word) --------------------------------

pub const OP_ISSUE: u8 = 0;
pub const OP_APPLY: u8 = 1;
pub const SIGNAL_SET: u8 = 2;
pub const SIGNAL_WAIT: u8 = 3;
pub const PARK: u8 = 4;
pub const UNPARK: u8 = 5;
pub const QUEUE_DRAIN: u8 = 6;
pub const REQ_BEGIN: u8 = 7;
pub const REQ_END: u8 = 8;
pub const REQ_ERROR: u8 = 9;
pub const PHASE_BEGIN: u8 = 10;
pub const PHASE_END: u8 = 11;

/// `a` value meaning "no specific signal" for park/unpark events.
pub const ANY_SIGNAL: u32 = u32::MAX;

/// Stable wire name of an event code (`syncopate.flight.v1` `kind` field).
pub fn code_name(code: u8) -> &'static str {
    match code {
        OP_ISSUE => "op-issue",
        OP_APPLY => "op-apply",
        SIGNAL_SET => "sig-set",
        SIGNAL_WAIT => "sig-wait",
        PARK => "park",
        UNPARK => "unpark",
        QUEUE_DRAIN => "queue-drain",
        REQ_BEGIN => "req-begin",
        REQ_END => "req-end",
        REQ_ERROR => "req-error",
        PHASE_BEGIN => "phase-begin",
        PHASE_END => "phase-end",
        _ => "unknown",
    }
}

fn code_from_name(name: &str) -> Option<u8> {
    (0..=PHASE_END).find(|&c| code_name(c) == name)
}

// --- serving phases (the `a` arg of PHASE_* events) ---------------------

/// Serving-phase codes carried in `a` by `phase-begin`/`phase-end`.
pub fn phase_code(name: &str) -> u32 {
    match name {
        "parse" => 0,
        "validate" => 1,
        "analyze" => 2,
        "tune" => 3,
        "compile" => 4,
        "exec" => 5,
        _ => 6,
    }
}

pub fn phase_name(code: u32) -> &'static str {
    match code {
        0 => "parse",
        1 => "validate",
        2 => "analyze",
        3 => "tune",
        4 => "compile",
        5 => "exec",
        _ => "other",
    }
}

// --- the decoded event --------------------------------------------------

/// One decoded flight event. The packed form is two `u64` words:
/// `w0 = t_us | code<<32 | rank<<40 | b<<48`, `w1 = a | req<<32`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FlightEvent {
    /// Microseconds since the process flight epoch (wraps every ~71 min;
    /// ordering within a lane comes from the ring, not the clock).
    pub t_us: u32,
    /// Event code (`OP_ISSUE` ... `PHASE_END`).
    pub code: u8,
    /// Rank the event happened on (`CTRL_RANK` for coordinator threads).
    pub rank: u8,
    /// Secondary argument (signal for `op-apply`/`sig-wait`; saturated to
    /// 16 bits).
    pub b: u16,
    /// Primary argument (op index, signal id, drained count, phase code;
    /// `ANY_SIGNAL` for untargeted park/unpark).
    pub a: u32,
    /// Request ID the event happened under (0 = outside any request).
    pub req: u32,
}

impl FlightEvent {
    fn pack(&self) -> (u64, u64) {
        let w0 = self.t_us as u64
            | (self.code as u64) << 32
            | (self.rank as u64) << 40
            | (self.b as u64) << 48;
        let w1 = self.a as u64 | (self.req as u64) << 32;
        (w0, w1)
    }

    fn unpack(w0: u64, w1: u64) -> Self {
        FlightEvent {
            t_us: w0 as u32,
            code: (w0 >> 32) as u8,
            rank: (w0 >> 40) as u8,
            b: (w0 >> 48) as u16,
            a: w1 as u32,
            req: (w1 >> 32) as u32,
        }
    }

    /// Compact one-line rendering for verdict messages and `flight show`.
    pub fn brief(&self) -> String {
        let sig = |a: u32| {
            if a == ANY_SIGNAL { "any".to_string() } else { format!("sig{a}") }
        };
        let body = match self.code {
            OP_ISSUE => format!("op-issue op{}", self.a),
            OP_APPLY => format!("op-apply op{} sig{}", self.a, self.b),
            SIGNAL_SET => format!("sig-set sig{}", self.a),
            SIGNAL_WAIT => format!("sig-wait op{} sig{}", self.a, self.b),
            PARK => format!("park {}", sig(self.a)),
            UNPARK => format!("unpark {}", sig(self.a)),
            QUEUE_DRAIN => format!("queue-drain n{}", self.a),
            REQ_BEGIN => "req-begin".to_string(),
            REQ_END => "req-end".to_string(),
            REQ_ERROR => "req-error".to_string(),
            PHASE_BEGIN => format!("phase-begin {}", phase_name(self.a)),
            PHASE_END => format!("phase-end {}", phase_name(self.a)),
            other => format!("code{other} a{}", self.a),
        };
        if self.req != 0 {
            format!("{body} @{}us req{}", self.t_us, self.req)
        } else {
            format!("{body} @{}us", self.t_us)
        }
    }
}

// --- the rings ----------------------------------------------------------

/// One seqlocked slot: `seq` is `(claim << 1) | dirty`; a reader accepts
/// the slot for window index `i` only when `seq == (i + 1) << 1` both
/// before and after the data reads.
struct Slot {
    seq: AtomicU64,
    w0: AtomicU64,
    w1: AtomicU64,
}

struct Ring {
    /// Claim counter: each writer takes one index with a Relaxed
    /// `fetch_add`; index `i` maps to slot `i & MASK`.
    head: AtomicU64,
    slots: [Slot; RING_CAPACITY],
}

#[allow(clippy::declare_interior_mutable_const)]
const ZERO_SLOT: Slot = Slot {
    seq: AtomicU64::new(0),
    w0: AtomicU64::new(0),
    w1: AtomicU64::new(0),
};

#[allow(clippy::declare_interior_mutable_const)]
const EMPTY_RING: Ring = Ring { head: AtomicU64::new(0), slots: [ZERO_SLOT; RING_CAPACITY] };

static RINGS: [Ring; LANES] = [EMPTY_RING; LANES];

fn lane_of(rank: u8) -> usize {
    if rank == CTRL_RANK {
        LANES - 1
    } else {
        (rank & 0xF) as usize
    }
}

fn clamp_rank(rank: usize) -> u8 {
    rank.min(0xFE) as u8
}

// --- counters merged into registry snapshots ----------------------------

pub static EVENTS: Counter = Counter::new();
pub static SNAPSHOT_SKIPS: Counter = Counter::new();
pub static DUMPS: Counter = Counter::new();

pub(super) fn entries() -> Vec<(Key, Value)> {
    [
        ("flight.events_total", &EVENTS),
        ("flight.snapshot_skips_total", &SNAPSHOT_SKIPS),
        ("flight.dumps_total", &DUMPS),
    ]
    .into_iter()
    .map(|(name, c)| (Key::new(name, &[]), Value::Counter(c.get())))
    .collect()
}

pub(super) fn reset_counters() {
    for c in [&EVENTS, &SNAPSHOT_SKIPS, &DUMPS] {
        c.reset();
    }
}

// --- gating + thread context --------------------------------------------

static ENABLED: AtomicBool = AtomicBool::new(true);

/// Runtime toggle for the recorder (benchmark A/B switch, `no-obs`-free
/// opt-out).
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Relaxed);
}

pub fn enabled() -> bool {
    ENABLED.load(Relaxed)
}

#[cfg(not(feature = "no-obs"))]
#[inline(always)]
fn on() -> bool {
    ENABLED.load(Relaxed)
}

thread_local! {
    /// The rank whose events this thread records ([`enter_rank`]).
    static CUR_RANK: Cell<u8> = const { Cell::new(CTRL_RANK) };
    /// The request ID this thread's events belong to ([`set_request`]).
    static CUR_REQ: Cell<u32> = const { Cell::new(0) };
    /// Per-thread copy of the process flight epoch (first event on a
    /// thread pays one cold mutex lock; every later event is one TLS read
    /// plus `Instant::elapsed`).
    static TLS_EPOCH: Cell<Option<Instant>> = const { Cell::new(None) };
}

static EPOCH: Mutex<Option<Instant>> = Mutex::new(None);

fn now_us() -> u32 {
    let epoch = TLS_EPOCH.with(|c| match c.get() {
        Some(e) => e,
        None => {
            let e = *EPOCH.lock().unwrap().get_or_insert_with(Instant::now);
            c.set(Some(e));
            e
        }
    });
    epoch.elapsed().as_micros() as u32
}

/// Declare the rank whose events this thread records (rank threads call
/// it on entry; the sequential engine calls it per round-robin turn).
/// A TLS store — negligible, so not feature-gated.
pub fn enter_rank(rank: usize) {
    CUR_RANK.with(|c| c.set(clamp_rank(rank)));
}

/// Return this thread to the control lane (after a sequential run on a
/// worker thread, say).
pub fn exit_rank() {
    CUR_RANK.with(|c| c.set(CTRL_RANK));
}

/// Stamp the request ID subsequent events on this thread belong to
/// (0 clears it). IDs are truncated to 32 bits in the packed event.
pub fn set_request(id: u64) {
    CUR_REQ.with(|c| c.set(id as u32));
}

/// The request ID currently stamped on this thread (0 = none). Engines
/// read it before spawning rank threads so the scope inherits it.
pub fn current_request() -> u64 {
    CUR_REQ.with(|c| c.get()) as u64
}

// --- recording (the hot path) -------------------------------------------

#[cfg(not(feature = "no-obs"))]
#[inline]
fn record(code: u8, rank: u8, a: u32, b: u16) {
    let ev = FlightEvent {
        t_us: now_us(),
        code,
        rank,
        b,
        a,
        req: CUR_REQ.with(|c| c.get()),
    };
    let ring = &RINGS[lane_of(rank)];
    let i = ring.head.fetch_add(1, Relaxed);
    let slot = &ring.slots[(i & MASK) as usize];
    let (w0, w1) = ev.pack();
    // Seqlock write protocol (crossbeam discipline): mark dirty, fence,
    // write data Relaxed, publish with Release. A snapshot validating the
    // sequence word around its data reads can skip but never tear.
    slot.seq.store((i << 1) | 1, Relaxed);
    fence(Release);
    slot.w0.store(w0, Relaxed);
    slot.w1.store(w1, Relaxed);
    slot.seq.store((i + 1) << 1, Release);
    EVENTS.inc();
}

#[cfg(not(feature = "no-obs"))]
#[inline(always)]
fn rank_of_thread() -> u8 {
    CUR_RANK.with(|c| c.get())
}

fn sat16(v: usize) -> u16 {
    v.min(u16::MAX as usize) as u16
}

/// An `Issue` op examined by `rank` (applied immediately or parked).
#[cfg(not(feature = "no-obs"))]
#[inline(always)]
pub fn op_issue(rank: usize, op: usize) {
    if on() {
        record(OP_ISSUE, clamp_rank(rank), op as u32, 0);
    }
}

/// A transfer applied (immediately or drained), completing `signal`.
#[cfg(not(feature = "no-obs"))]
#[inline(always)]
pub fn op_apply(rank: usize, op: usize, signal: usize) {
    if on() {
        record(OP_APPLY, clamp_rank(rank), op as u32, sat16(signal));
    }
}

/// A signal published on the board (rank from thread context).
#[cfg(not(feature = "no-obs"))]
#[inline(always)]
pub fn signal_set(signal: usize) {
    if on() {
        record(SIGNAL_SET, rank_of_thread(), signal as u32, 0);
    }
}

/// A rank entering a `Wait` op on `signal`.
#[cfg(not(feature = "no-obs"))]
#[inline(always)]
pub fn signal_wait(rank: usize, op: usize, signal: usize) {
    if on() {
        record(SIGNAL_WAIT, clamp_rank(rank), op as u32, sat16(signal));
    }
}

/// A thread actually entering `park_timeout` (`None` = any-activity wait).
#[cfg(not(feature = "no-obs"))]
#[inline(always)]
pub fn park(signal: Option<usize>) {
    if on() {
        record(PARK, rank_of_thread(), signal.map_or(ANY_SIGNAL, |s| s as u32), 0);
    }
}

/// A producer issuing a targeted unpark (`None` = any-interest wake).
#[cfg(not(feature = "no-obs"))]
#[inline(always)]
pub fn unpark(signal: Option<usize>) {
    if on() {
        record(UNPARK, rank_of_thread(), signal.map_or(ANY_SIGNAL, |s| s as u32), 0);
    }
}

/// `n` parked transfers drained from `rank`'s queue.
#[cfg(not(feature = "no-obs"))]
#[inline(always)]
pub fn queue_drain(rank: usize, n: usize) {
    if n > 0 && on() {
        record(QUEUE_DRAIN, clamp_rank(rank), n as u32, 0);
    }
}

/// A coordinator request starting on this thread (control lane).
#[cfg(not(feature = "no-obs"))]
#[inline(always)]
pub fn req_begin() {
    if on() {
        record(REQ_BEGIN, rank_of_thread(), 0, 0);
    }
}

/// The request completing successfully.
#[cfg(not(feature = "no-obs"))]
#[inline(always)]
pub fn req_end() {
    if on() {
        record(REQ_END, rank_of_thread(), 0, 0);
    }
}

/// The request completing with an error.
#[cfg(not(feature = "no-obs"))]
#[inline(always)]
pub fn req_error() {
    if on() {
        record(REQ_ERROR, rank_of_thread(), 0, 0);
    }
}

/// A serving phase (`phase_code` name) starting under the current request.
#[cfg(not(feature = "no-obs"))]
#[inline(always)]
pub fn phase_begin(phase: &str) {
    if on() {
        record(PHASE_BEGIN, rank_of_thread(), phase_code(phase), 0);
    }
}

/// The serving phase ending.
#[cfg(not(feature = "no-obs"))]
#[inline(always)]
pub fn phase_end(phase: &str) {
    if on() {
        record(PHASE_END, rank_of_thread(), phase_code(phase), 0);
    }
}

// `no-obs`: every record fn is an empty inline body (same discipline as
// `super::hot`); the query/dump surface below stays available and simply
// sees empty rings.

#[cfg(feature = "no-obs")]
#[inline(always)]
pub fn op_issue(_rank: usize, _op: usize) {}

#[cfg(feature = "no-obs")]
#[inline(always)]
pub fn op_apply(_rank: usize, _op: usize, _signal: usize) {}

#[cfg(feature = "no-obs")]
#[inline(always)]
pub fn signal_set(_signal: usize) {}

#[cfg(feature = "no-obs")]
#[inline(always)]
pub fn signal_wait(_rank: usize, _op: usize, _signal: usize) {}

#[cfg(feature = "no-obs")]
#[inline(always)]
pub fn park(_signal: Option<usize>) {}

#[cfg(feature = "no-obs")]
#[inline(always)]
pub fn unpark(_signal: Option<usize>) {}

#[cfg(feature = "no-obs")]
#[inline(always)]
pub fn queue_drain(_rank: usize, _n: usize) {}

#[cfg(feature = "no-obs")]
#[inline(always)]
pub fn req_begin() {}

#[cfg(feature = "no-obs")]
#[inline(always)]
pub fn req_end() {}

#[cfg(feature = "no-obs")]
#[inline(always)]
pub fn req_error() {}

#[cfg(feature = "no-obs")]
#[inline(always)]
pub fn phase_begin(_phase: &str) {}

#[cfg(feature = "no-obs")]
#[inline(always)]
pub fn phase_end(_phase: &str) {}

// --- snapshots ----------------------------------------------------------

/// One consistent drain of every ring: the post-mortem artifact.
#[derive(Debug, Clone, PartialEq)]
pub struct FlightDump {
    /// Why the dump was taken (`"deadlock"`, `"served-error"`, `"cli"`).
    pub reason: String,
    /// World size of the run that recorded, from [`set_context`] (0 when
    /// the process never stamped one — e.g. a bare unit test).
    pub world: usize,
    /// [`crate::hw::fingerprint`] of the machine shape, from
    /// [`set_context`] (empty when unstamped).
    pub fingerprint: String,
    /// Registry-case provenance, from [`set_context`] (empty when the run
    /// was not a registry case).
    pub case: String,
    /// All published events, lane-major, oldest-first within each lane.
    pub events: Vec<FlightEvent>,
}

/// Drain one lane's published window (oldest-first). Slots caught
/// mid-write (in-flight claims, overwrites racing the read) are skipped
/// and counted in `flight.snapshot_skips_total` — a snapshot may be
/// incomplete, never torn.
fn drain_lane(lane: usize) -> Vec<FlightEvent> {
    let ring = &RINGS[lane];
    let head = ring.head.load(Acquire);
    let start = head.saturating_sub(RING_CAPACITY as u64);
    let mut out = Vec::with_capacity((head - start) as usize);
    for i in start..head {
        let slot = &ring.slots[(i & MASK) as usize];
        let want = (i + 1) << 1;
        let s1 = slot.seq.load(Acquire);
        if s1 != want {
            SNAPSHOT_SKIPS.inc();
            continue;
        }
        let w0 = slot.w0.load(Relaxed);
        let w1 = slot.w1.load(Relaxed);
        fence(Acquire);
        if slot.seq.load(Relaxed) != want {
            SNAPSHOT_SKIPS.inc();
            continue;
        }
        out.push(FlightEvent::unpack(w0, w1));
    }
    out
}

/// Snapshot every lane into a [`FlightDump`], stamped with the process
/// run context (see [`set_context`]).
pub fn snapshot(reason: &str) -> FlightDump {
    let mut events = Vec::new();
    for lane in 0..LANES {
        events.extend(drain_lane(lane));
    }
    let (world, fingerprint, case) = CONTEXT.lock().unwrap().clone();
    FlightDump { reason: reason.to_string(), world, fingerprint, case, events }
}

/// Run provenance stamped into every subsequent [`snapshot`]: the same
/// (world, fingerprint, case) triple the trace exporter carries in its
/// `syncopate` Chrome header, so a flight dump of a crashed run and the
/// trace of a good one are attributable to the same machine + workload.
/// The CLI stamps this once per `exec`/`serve-demo` invocation.
pub fn set_context(world: usize, fingerprint: &str, case: &str) {
    *CONTEXT.lock().unwrap() = (world, fingerprint.to_string(), case.to_string());
}

static CONTEXT: Mutex<(usize, String, String)> = Mutex::new((0, String::new(), String::new()));

/// The last `k` published events recorded *by* `rank` (oldest-first).
/// Other ranks sharing the lane modulo 16 are filtered out by the event's
/// own rank byte.
pub fn last_events(rank: usize, k: usize) -> Vec<FlightEvent> {
    let r = clamp_rank(rank);
    let evs = drain_lane(lane_of(r));
    let mut mine: Vec<FlightEvent> = evs.into_iter().filter(|e| e.rank == r).collect();
    if mine.len() > k {
        mine.drain(..mine.len() - k);
    }
    mine
}

/// Per-stuck-rank last-K context appended to deadlock verdicts: empty
/// when the recorder is off (or `no-obs`), else
/// `"; recent flight events: rank R [ev | ev | ...], ..."`.
pub fn verdict_context(ranks: &[usize], k: usize) -> String {
    let mut parts = Vec::new();
    for &r in ranks {
        let evs = last_events(r, k);
        if evs.is_empty() {
            continue;
        }
        let briefs: Vec<String> = evs.iter().map(FlightEvent::brief).collect();
        parts.push(format!("rank {r} [{}]", briefs.join(" | ")));
    }
    if parts.is_empty() {
        String::new()
    } else {
        format!("; recent flight events: {}", parts.join(", "))
    }
}

// --- post-mortem dump path ----------------------------------------------

static DUMP_PATH: Mutex<Option<String>> = Mutex::new(None);

/// Configure a file the process dumps flight JSON to on deadlock verdicts
/// and served errors (`--flight FILE` on `exec` / `serve-demo`). `None`
/// (the default) disables automatic dumps — no silent file writes.
pub fn set_dump_path(path: Option<&str>) {
    *DUMP_PATH.lock().unwrap() = path.map(str::to_string);
}

/// Snapshot all rings and write `syncopate.flight.v1` JSON to the
/// configured dump path, if any. Returns the path written. IO failures
/// are reported on stderr, never propagated into the failing run's error.
pub fn dump_to_configured(reason: &str) -> Option<String> {
    let path = DUMP_PATH.lock().unwrap().clone()?;
    let dump = snapshot(reason);
    match std::fs::write(&path, to_json(&dump)) {
        Ok(()) => {
            DUMPS.inc();
            Some(path)
        }
        Err(e) => {
            eprintln!("flight: could not write dump to {path}: {e}");
            None
        }
    }
}

// --- syncopate.flight.v1 JSON -------------------------------------------

/// Schema tag of the flight dump JSON.
pub const FLIGHT_SCHEMA: &str = "syncopate.flight.v1";

/// Render a dump as `syncopate.flight.v1` JSON. Exact round trip:
/// `from_json(to_json(d)) == d`.
pub fn to_json(dump: &FlightDump) -> String {
    use std::fmt::Write as _;
    let mut out = String::new();
    let _ = writeln!(out, "{{");
    let _ = writeln!(out, "  \"schema\": \"{FLIGHT_SCHEMA}\",");
    let _ = writeln!(out, "  \"reason\": \"{}\",", crate::util::json_escape(&dump.reason));
    let _ = writeln!(out, "  \"world\": {},", dump.world);
    let _ = writeln!(out, "  \"fingerprint\": \"{}\",", crate::util::json_escape(&dump.fingerprint));
    let _ = writeln!(out, "  \"case\": \"{}\",", crate::util::json_escape(&dump.case));
    let _ = writeln!(out, "  \"events\": [");
    for (i, e) in dump.events.iter().enumerate() {
        let _ = writeln!(
            out,
            "    {{\"t_us\": {}, \"kind\": \"{}\", \"rank\": {}, \"a\": {}, \"b\": {}, \
             \"req\": {}}}{}",
            e.t_us,
            code_name(e.code),
            e.rank,
            e.a,
            e.b,
            e.req,
            if i + 1 < dump.events.len() { "," } else { "" }
        );
    }
    let _ = writeln!(out, "  ]");
    let _ = writeln!(out, "}}");
    out
}

/// Parse `syncopate.flight.v1` JSON back into a [`FlightDump`].
pub fn from_json(text: &str) -> Result<FlightDump> {
    let bad = |msg: &str| Error::Io(format!("flight dump: {msg}"));
    let v = crate::trace::json::parse(text)?;
    match v.get("schema").and_then(|s| s.as_str()) {
        Some(s) if s == FLIGHT_SCHEMA => {}
        Some(s) => return Err(bad(&format!("schema `{s}`, expected `{FLIGHT_SCHEMA}`"))),
        None => return Err(bad("missing `schema` tag")),
    }
    let reason = v
        .get("reason")
        .and_then(|r| r.as_str())
        .ok_or_else(|| bad("missing `reason`"))?
        .to_string();
    // provenance fields are lenient: dumps written before they existed
    // must stay readable
    let world = v.get("world").and_then(|w| w.as_usize()).unwrap_or(0);
    let fingerprint =
        v.get("fingerprint").and_then(|f| f.as_str()).unwrap_or_default().to_string();
    let case = v.get("case").and_then(|c| c.as_str()).unwrap_or_default().to_string();
    let evs = v.get("events").and_then(|e| e.as_arr()).ok_or_else(|| bad("missing `events`"))?;
    let mut events = Vec::with_capacity(evs.len());
    for (i, e) in evs.iter().enumerate() {
        let num = |field: &str| {
            e.get(field)
                .and_then(|n| n.as_usize())
                .ok_or_else(|| bad(&format!("event {i}: missing numeric `{field}`")))
        };
        let kind = e
            .get("kind")
            .and_then(|k| k.as_str())
            .ok_or_else(|| bad(&format!("event {i}: missing `kind`")))?;
        let code = code_from_name(kind)
            .ok_or_else(|| bad(&format!("event {i}: unknown kind `{kind}`")))?;
        let rank = num("rank")?;
        if rank > 0xFF {
            return Err(bad(&format!("event {i}: rank {rank} out of range")));
        }
        let b = num("b")?;
        if b > u16::MAX as usize {
            return Err(bad(&format!("event {i}: b {b} out of range")));
        }
        let a = num("a")?;
        if a > u32::MAX as usize {
            return Err(bad(&format!("event {i}: a {a} out of range")));
        }
        let (t_us, req) = (num("t_us")?, num("req")?);
        if t_us > u32::MAX as usize || req > u32::MAX as usize {
            return Err(bad(&format!("event {i}: t_us/req out of range")));
        }
        events.push(FlightEvent {
            t_us: t_us as u32,
            code,
            rank: rank as u8,
            b: b as u16,
            a: a as u32,
            req: req as u32,
        });
    }
    Ok(FlightDump { reason, world, fingerprint, case, events })
}

/// Validate a flight dump document; returns its event count.
pub fn check_schema(text: &str) -> Result<usize> {
    from_json(text).map(|d| d.events.len())
}

// --- Chrome trace_event export ------------------------------------------

/// Render a dump in Chrome `trace_event` JSON (the same viewer surface as
/// `exec --trace` captures): one named thread per rank lane, phase
/// begin/end as `B`/`E` spans, everything else as instant events carrying
/// `a`/`b`/`req` args.
pub fn to_chrome_json(dump: &FlightDump) -> String {
    use std::fmt::Write as _;
    let mut out = String::new();
    let _ = writeln!(out, "{{");
    let _ = writeln!(out, "  \"displayTimeUnit\": \"ms\",");
    // the same header block as `exec --trace` Chrome exports (one shared
    // helper), so downstream tooling finds world/fingerprint/case in one
    // place regardless of which recorder wrote the file
    let mut meta = Vec::new();
    if !dump.case.is_empty() {
        meta.push(("registry-case".to_string(), dump.case.clone()));
    }
    let extra = [
        ("flight", "true".to_string()),
        ("reason", format!("\"{}\"", crate::util::json_escape(&dump.reason))),
    ];
    let _ = writeln!(
        out,
        "{},",
        crate::trace::syncopate_header(dump.world, &dump.fingerprint, &meta, &extra)
    );
    let _ = writeln!(out, "  \"traceEvents\": [");
    let mut lines = Vec::new();
    // thread-name metadata for every rank that appears
    let mut ranks: Vec<u8> = dump.events.iter().map(|e| e.rank).collect();
    ranks.sort_unstable();
    ranks.dedup();
    for r in &ranks {
        let name = if *r == CTRL_RANK { "coordinator".to_string() } else { format!("rank {r}") };
        lines.push(format!(
            "    {{\"ph\": \"M\", \"pid\": 0, \"tid\": {r}, \"name\": \"thread_name\", \
             \"args\": {{\"name\": \"{name}\"}}}}"
        ));
    }
    for e in &dump.events {
        let (ph, name) = match e.code {
            PHASE_BEGIN => ("B", phase_name(e.a).to_string()),
            PHASE_END => ("E", phase_name(e.a).to_string()),
            c => ("i", code_name(c).to_string()),
        };
        let scope = if ph == "i" { ", \"s\": \"t\"" } else { "" };
        lines.push(format!(
            "    {{\"ph\": \"{ph}\", \"pid\": 0, \"tid\": {}, \"name\": \"{name}\", \
             \"cat\": \"flight\", \"ts\": {}{scope}, \
             \"args\": {{\"a\": {}, \"b\": {}, \"req\": {}}}}}",
            e.rank, e.t_us, e.a, e.b, e.req
        ));
    }
    let _ = writeln!(out, "{}", lines.join(",\n"));
    let _ = writeln!(out, "  ]");
    let _ = writeln!(out, "}}");
    out
}

/// Text summary for `flight show`: per-rank event counts plus the tail.
pub fn render(dump: &FlightDump) -> String {
    use std::fmt::Write as _;
    let mut out = String::new();
    let _ = writeln!(out, "flight dump: reason `{}`, {} events", dump.reason, dump.events.len());
    let mut ranks: Vec<u8> = dump.events.iter().map(|e| e.rank).collect();
    ranks.sort_unstable();
    ranks.dedup();
    for r in ranks {
        let evs: Vec<&FlightEvent> = dump.events.iter().filter(|e| e.rank == r).collect();
        let label =
            if r == CTRL_RANK { "coordinator".to_string() } else { format!("rank {r}") };
        let tail: Vec<String> =
            evs.iter().rev().take(8).rev().map(|e| e.brief()).collect();
        let _ = writeln!(out, "  {label}: {} events; last: {}", evs.len(), tail.join(" | "));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pack_unpack_round_trips() {
        let e = FlightEvent {
            t_us: 123_456,
            code: SIGNAL_WAIT,
            rank: 7,
            b: 65_535,
            a: u32::MAX - 1,
            req: 42,
        };
        let (w0, w1) = e.pack();
        assert_eq!(FlightEvent::unpack(w0, w1), e);
        let z = FlightEvent { t_us: 0, code: 0, rank: 0, b: 0, a: 0, req: 0 };
        let (w0, w1) = z.pack();
        assert_eq!(FlightEvent::unpack(w0, w1), z);
    }

    #[test]
    fn code_names_round_trip() {
        for code in 0..=PHASE_END {
            assert_eq!(code_from_name(code_name(code)), Some(code), "code {code}");
        }
        assert_eq!(code_from_name("nope"), None);
    }

    #[test]
    fn phase_codes_cover_serving_phases() {
        for p in ["parse", "validate", "analyze", "tune", "compile", "exec"] {
            assert_eq!(phase_name(phase_code(p)), p);
        }
        assert_eq!(phase_name(phase_code("mystery")), "other");
    }

    #[cfg(not(feature = "no-obs"))]
    #[test]
    fn recorded_events_come_back_in_order() {
        // Rank 13: a lane no engine test touches (worlds stop at 8).
        let before = last_events(13, RING_CAPACITY).len();
        op_issue(13, 3);
        op_apply(13, 3, 9);
        signal_wait(13, 4, 9);
        let evs = last_events(13, RING_CAPACITY);
        assert!(evs.len() >= before + 3);
        let tail = &evs[evs.len() - 3..];
        assert_eq!(tail[0].code, OP_ISSUE);
        assert_eq!(tail[0].a, 3);
        assert_eq!(tail[1].code, OP_APPLY);
        assert_eq!((tail[1].a, tail[1].b), (3, 9));
        assert_eq!(tail[2].code, SIGNAL_WAIT);
        assert_eq!((tail[2].a, tail[2].b), (4, 9));
        // timestamps are monotone within one thread's writes
        assert!(tail[0].t_us <= tail[2].t_us);
    }

    #[cfg(not(feature = "no-obs"))]
    #[test]
    fn verdict_context_names_ranks_and_events() {
        op_issue(14, 1);
        signal_wait(14, 2, 5);
        let ctx = verdict_context(&[14], 4);
        assert!(ctx.contains("recent flight events"), "{ctx}");
        assert!(ctx.contains("rank 14"), "{ctx}");
        assert!(ctx.contains("sig-wait op2 sig5"), "{ctx}");
        // a rank with no events contributes nothing
        assert_eq!(verdict_context(&[11], 4), "");
    }

    #[test]
    fn json_round_trip_is_exact() {
        let dump = FlightDump {
            reason: "unit \"quoted\"".to_string(),
            world: 4,
            fingerprint: "deadbeefdeadbeef".to_string(),
            case: "tp-block".to_string(),
            events: vec![
                FlightEvent { t_us: 5, code: OP_ISSUE, rank: 0, b: 0, a: 7, req: 0 },
                FlightEvent { t_us: 9, code: PARK, rank: 3, b: 0, a: ANY_SIGNAL, req: 12 },
                FlightEvent {
                    t_us: u32::MAX,
                    code: PHASE_END,
                    rank: CTRL_RANK,
                    b: u16::MAX,
                    a: 5,
                    req: u32::MAX,
                },
            ],
        };
        let json = to_json(&dump);
        assert_eq!(check_schema(&json).unwrap(), 3);
        assert_eq!(from_json(&json).unwrap(), dump);
        // the document parses under the crate's own JSON reader
        crate::trace::json::parse(&json).unwrap();
        // dumps written before the provenance fields existed stay readable
        let legacy = "{\"schema\": \"syncopate.flight.v1\", \"reason\": \"old\", \
             \"events\": []}";
        let d = from_json(legacy).unwrap();
        assert_eq!((d.world, d.fingerprint.as_str(), d.case.as_str()), (0, "", ""));
    }

    #[test]
    fn schema_check_rejects_malformed() {
        assert!(from_json("{}").is_err());
        assert!(from_json("{\"schema\": \"syncopate.stats.v1\"}").is_err());
        let bad_kind = "{\"schema\": \"syncopate.flight.v1\", \"reason\": \"x\", \
             \"events\": [{\"t_us\": 1, \"kind\": \"nope\", \"rank\": 0, \"a\": 0, \
             \"b\": 0, \"req\": 0}]}";
        assert!(from_json(bad_kind).is_err());
        let bad_rank = "{\"schema\": \"syncopate.flight.v1\", \"reason\": \"x\", \
             \"events\": [{\"t_us\": 1, \"kind\": \"park\", \"rank\": 900, \"a\": 0, \
             \"b\": 0, \"req\": 0}]}";
        assert!(from_json(bad_rank).is_err());
    }

    #[test]
    fn chrome_export_is_valid_json_with_thread_names() {
        let dump = FlightDump {
            reason: "unit".to_string(),
            world: 2,
            fingerprint: "deadbeefdeadbeef".to_string(),
            case: "tp-block".to_string(),
            events: vec![
                FlightEvent { t_us: 1, code: PHASE_BEGIN, rank: CTRL_RANK, b: 0, a: 0, req: 3 },
                FlightEvent { t_us: 2, code: SIGNAL_SET, rank: 2, b: 0, a: 4, req: 3 },
                FlightEvent { t_us: 6, code: PHASE_END, rank: CTRL_RANK, b: 0, a: 0, req: 3 },
            ],
        };
        let chrome = to_chrome_json(&dump);
        let v = crate::trace::json::parse(&chrome).unwrap();
        let evs = v.get("traceEvents").and_then(|e| e.as_arr()).unwrap();
        // 2 thread-name metadata + 3 events
        assert_eq!(evs.len(), 5);
        // the shared syncopate header passes the trace exporter's own
        // header check and carries the stamped provenance
        let (world, fp) = crate::trace::check_chrome_header(&chrome).unwrap();
        assert_eq!((world, fp.as_str()), (2, "deadbeefdeadbeef"));
        assert!(chrome.contains("\"flight\": true"), "{chrome}");
        assert!(chrome.contains("\"registry-case\": \"tp-block\""), "{chrome}");
        assert!(chrome.contains("\"coordinator\""));
        assert!(chrome.contains("\"rank 2\""));
        assert!(chrome.contains("\"ph\": \"B\""));
        assert!(chrome.contains("\"ph\": \"E\""));
        assert!(chrome.contains("\"ph\": \"i\""));
    }

    #[test]
    fn render_summarizes_per_rank() {
        let dump = FlightDump {
            reason: "unit".to_string(),
            world: 0,
            fingerprint: String::new(),
            case: String::new(),
            events: vec![
                FlightEvent { t_us: 1, code: OP_ISSUE, rank: 1, b: 0, a: 0, req: 0 },
                FlightEvent { t_us: 2, code: OP_APPLY, rank: 1, b: 3, a: 0, req: 0 },
            ],
        };
        let text = render(&dump);
        assert!(text.contains("2 events"), "{text}");
        assert!(text.contains("rank 1"), "{text}");
        assert!(text.contains("op-apply op0 sig3"), "{text}");
    }

    #[test]
    fn dump_path_roundtrip_and_unset_is_silent() {
        // default: no configured path -> no write attempted
        set_dump_path(None);
        assert_eq!(dump_to_configured("unit"), None);
    }
}
