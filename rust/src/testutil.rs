//! Test-support constructors shared by unit and integration tests.
//!
//! `#[doc(hidden)]` — not part of the stable API; exists so the
//! hand-built-plan fixtures in `exec::engine`, `exec::parallel`,
//! `exec::plan_prep`, and `tests/integration_parallel.rs` stay in
//! lockstep when [`TransferDesc`] grows a field.

use crate::backend::BackendKind;
use crate::chunk::{Chunk, Region, TensorId};
use crate::codegen::TransferDesc;
use crate::schedule::OpRef;

/// A minimal [`TransferDesc`] between ranks over one region: copy-engine
/// for plain copies, ld/st for reduces; `bytes` derived from the region.
pub fn transfer_desc(
    tensor: TensorId,
    region: Region,
    signal: usize,
    src: usize,
    dst: usize,
    deps: Vec<usize>,
    reduce: bool,
) -> TransferDesc {
    let bytes = region.elems() * 4;
    let c = Chunk::new(tensor, region);
    let (backend, comm_sms) = if reduce {
        (BackendKind::LdStSpecialized, 16)
    } else {
        (BackendKind::CopyEngine, 0)
    };
    TransferDesc {
        signal,
        op: OpRef { rank: src, index: signal },
        src_rank: src,
        dst_rank: dst,
        src_chunk: c.clone(),
        dst_chunk: c,
        bytes,
        pieces: 1,
        backend,
        comm_sms,
        reduce,
        dep_signals: deps,
    }
}
