//! Calibrated multi-GPU performance model.
//!
//! The paper's testbed (8×H100 + NVLink) is not available here; this module
//! is the substitute substrate (DESIGN.md §1): a discrete-event simulator
//! that executes [`crate::codegen::ExecutablePlan`]s against per-device SM
//! pools, copy-engine queues, link contention, wave quantization, and the
//! per-backend transfer curves of [`crate::backend`].
//!
//! * [`waves`] — the SM-utilization / wave-quantization model (Fig. 2a).
//! * [`engine`] — the event-driven plan executor.
//! * [`timeline`] — span recording, utilization metrics, JSON export.

pub mod engine;
pub mod timeline;
pub mod waves;

pub use engine::{simulate, SimParams, SimResult};
pub use timeline::{Span, SpanKind, Timeline};
