//! Event-driven executor for [`ExecutablePlan`]s over the calibrated
//! hardware model.
//!
//! Modeled resources, per device: the compute SM pool (minus any statically
//! reserved communication SMs), `copy_engines_per_device` DMA queues, one
//! specialized-communication SM group, one co-located issue queue (whose SM
//! time is charged back to compute as "debt"), and directed links with
//! serialization per (src, dst) pair.
//!
//! Determinism: the event heap is ordered by (time, sequence number); equal
//! times resolve in creation order, so repeated runs are bit-identical.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap};

use crate::backend::BackendKind;
use crate::codegen::{ExecutablePlan, PlanOp, SignalId};
use crate::error::{Error, Result};
use crate::sim::timeline::{Span, SpanKind, Timeline};
use crate::sim::waves;
use crate::topo::Topology;

/// Simulation knobs beyond the plan itself.
#[derive(Debug, Clone, Copy)]
pub struct SimParams {
    /// Achieved fraction of per-SM peak for this operator's tile shape
    /// (from [`waves::mxu_efficiency`] of the tile config).
    pub mxu_eff: f64,
}

impl Default for SimParams {
    fn default() -> Self {
        SimParams { mxu_eff: 0.85 }
    }
}

/// Result of one simulated run.
#[derive(Debug, Clone)]
pub struct SimResult {
    pub makespan_us: f64,
    pub rank_end_us: Vec<f64>,
    pub total_flops: f64,
    pub exposed_wait_us: f64,
    pub timeline: Timeline,
}

impl SimResult {
    /// Aggregate achieved TFLOP/s across the whole mesh.
    pub fn tflops(&self) -> f64 {
        if self.makespan_us <= 0.0 {
            return 0.0;
        }
        self.total_flops / (self.makespan_us * 1e6)
    }
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum Event {
    Resume { rank: usize },
    TryIssue { tid: usize },
}

#[derive(Debug, Clone, Copy)]
struct Key {
    t: f64,
    seq: u64,
}

impl PartialEq for Key {
    fn eq(&self, o: &Self) -> bool {
        self.t == o.t && self.seq == o.seq
    }
}
impl Eq for Key {}
impl PartialOrd for Key {
    fn partial_cmp(&self, o: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(o))
    }
}
impl Ord for Key {
    fn cmp(&self, o: &Self) -> std::cmp::Ordering {
        self.t.total_cmp(&o.t).then(self.seq.cmp(&o.seq))
    }
}

struct Engine<'a> {
    plan: &'a ExecutablePlan,
    topo: &'a Topology,
    params: SimParams,
    heap: BinaryHeap<Reverse<(Key, usize)>>,
    events: Vec<Event>,
    seq: u64,
    // rank state
    pc: Vec<usize>,
    debt_sm_us: Vec<f64>,
    done: Vec<bool>,
    rank_end: Vec<f64>,
    exposed_wait: f64,
    // transfers & signals
    xfers: Vec<Xfer>,
    signal_time: Vec<Option<f64>>,
    blocked_xfers: HashMap<SignalId, Vec<usize>>,
    waiting_ranks: HashMap<SignalId, Vec<(usize, f64)>>,
    // resources
    ce_free: Vec<Vec<f64>>,
    commsm_free: Vec<f64>,
    coloc_free: Vec<f64>,
    link_free: HashMap<(usize, usize), f64>,
    timeline: Timeline,
}

struct Xfer {
    /// Index into per_rank program: (rank, op position) for provenance only.
    owner: usize,
    desc: crate::codegen::TransferDesc,
    created_at: f64,
    scheduled: bool,
}

/// Simulate one plan on one topology.
pub fn simulate(plan: &ExecutablePlan, topo: &Topology, params: SimParams) -> Result<SimResult> {
    if plan.world != topo.world {
        return Err(Error::Sim(format!(
            "plan world {} != topology world {}",
            plan.world, topo.world
        )));
    }
    plan.validate().map_err(|e| Error::Sim(format!("invalid plan: {e}")))?;
    let compute_sms = topo
        .sms_per_device
        .checked_sub(plan.reserved_comm_sms)
        .filter(|&s| s > 0)
        .ok_or_else(|| {
            Error::Sim(format!(
                "reserved comm SMs {} leave no compute SMs (device has {})",
                plan.reserved_comm_sms, topo.sms_per_device
            ))
        })?;
    let _ = compute_sms;

    let mut eng = Engine {
        plan,
        topo,
        params,
        heap: BinaryHeap::new(),
        events: Vec::new(),
        seq: 0,
        pc: vec![0; plan.world],
        debt_sm_us: vec![0.0; plan.world],
        done: vec![false; plan.world],
        rank_end: vec![0.0; plan.world],
        exposed_wait: 0.0,
        xfers: Vec::new(),
        signal_time: vec![None; plan.num_signals],
        blocked_xfers: HashMap::new(),
        waiting_ranks: HashMap::new(),
        ce_free: vec![vec![0.0; topo.copy_engines_per_device.max(1)]; plan.world],
        commsm_free: vec![0.0; plan.world],
        coloc_free: vec![0.0; plan.world],
        link_free: HashMap::new(),
        timeline: Timeline::default(),
    };
    for r in 0..plan.world {
        eng.push(0.0, Event::Resume { rank: r });
    }
    eng.run()?;

    // an operator is not complete until its last transfer lands (e.g. the
    // tail reductions of GEMM-RS finish after the producing rank's program)
    let makespan = eng
        .rank_end
        .iter()
        .copied()
        .fold(0.0, f64::max)
        .max(eng.timeline.makespan_us());
    Ok(SimResult {
        makespan_us: makespan,
        rank_end_us: eng.rank_end,
        total_flops: plan.total_flops(),
        exposed_wait_us: eng.exposed_wait,
        timeline: eng.timeline,
    })
}

impl<'a> Engine<'a> {
    fn push(&mut self, t: f64, ev: Event) {
        let id = self.events.len();
        self.events.push(ev);
        self.heap.push(Reverse((Key { t, seq: self.seq }, id)));
        self.seq += 1;
    }

    fn run(&mut self) -> Result<()> {
        while let Some(Reverse((key, id))) = self.heap.pop() {
            match self.events[id] {
                Event::Resume { rank } => self.resume(rank, key.t)?,
                Event::TryIssue { tid } => self.try_issue(tid, key.t)?,
            }
        }
        // deadlock check
        for r in 0..self.plan.world {
            if !self.done[r] {
                let op = self
                    .plan
                    .per_rank[r]
                    .ops
                    .get(self.pc[r])
                    .map(|o| format!("{o:?}"))
                    .unwrap_or_else(|| "<end>".into());
                return Err(Error::Sim(format!(
                    "deadlock: rank {r} stuck at op {} ({op})",
                    self.pc[r]
                )));
            }
        }
        Ok(())
    }

    fn compute_sms(&self) -> usize {
        self.topo.sms_per_device - self.plan.reserved_comm_sms
    }

    fn resume(&mut self, rank: usize, mut t: f64) -> Result<()> {
        let prog = &self.plan.per_rank[rank];
        while self.pc[rank] < prog.ops.len() {
            let pc = self.pc[rank];
            match &prog.ops[pc] {
                PlanOp::Overhead { us, label } => {
                    self.timeline.push(Span {
                        rank,
                        kind: SpanKind::Overhead,
                        start_us: t,
                        end_us: t + us,
                        label: (*label).into(),
                    });
                    t += us;
                    self.pc[rank] += 1;
                }
                PlanOp::Compute(seg) => {
                    let n = seg.tiles.len();
                    let sms = self.compute_sms();
                    let mean_flops = if n == 0 { 0.0 } else { seg.total_flops() / n as f64 };
                    let tile_us =
                        mean_flops / (self.topo.sm_tflops * 1e6 * self.params.mxu_eff.max(1e-3));
                    let dur = if seg.quantized {
                        waves::segment_duration_us(n, tile_us, sms, self.debt_sm_us[rank])
                    } else {
                        waves::streaming_duration_us(n, tile_us, sms, self.debt_sm_us[rank])
                    };
                    self.debt_sm_us[rank] = 0.0;
                    if dur > 0.0 {
                        self.timeline.push(Span {
                            rank,
                            kind: SpanKind::Compute,
                            start_us: t,
                            end_us: t + dur,
                            label: format!("{n} tiles"),
                        });
                    }
                    t += dur;
                    self.pc[rank] += 1;
                }
                PlanOp::Issue(desc) => {
                    let tid = self.xfers.len();
                    self.xfers.push(Xfer {
                        owner: rank,
                        desc: desc.clone(),
                        created_at: t,
                        scheduled: false,
                    });
                    self.pc[rank] += 1;
                    // Issue inline (not via the heap) so co-located SM debt
                    // lands before this rank's next compute segment — the
                    // issuing SMs are borrowed from exactly that segment.
                    self.try_issue(tid, t)?;
                }
                PlanOp::Wait(sig) => {
                    let sig = *sig;
                    self.pc[rank] += 1;
                    match self.signal_time[sig] {
                        Some(ts) if ts <= t => {} // already landed, fall through
                        Some(ts) => {
                            self.stall(rank, t, ts, sig);
                            self.push(ts, Event::Resume { rank });
                            return Ok(());
                        }
                        None => {
                            self.waiting_ranks.entry(sig).or_default().push((rank, t));
                            return Ok(());
                        }
                    }
                }
            }
        }
        self.done[rank] = true;
        self.rank_end[rank] = self.rank_end[rank].max(t);
        Ok(())
    }

    fn stall(&mut self, rank: usize, from: f64, to: f64, sig: SignalId) {
        if to > from {
            self.exposed_wait += to - from;
            self.timeline.push(Span {
                rank,
                kind: SpanKind::WaitStall,
                start_us: from,
                end_us: to,
                label: format!("sig{sig}"),
            });
        }
    }

    fn try_issue(&mut self, tid: usize, t: f64) -> Result<()> {
        if self.xfers[tid].scheduled {
            return Ok(());
        }
        // resolve deps: all signal times must be known
        let mut ready = self.xfers[tid].created_at.max(t);
        for &d in &self.xfers[tid].desc.dep_signals.clone() {
            match self.signal_time[d] {
                Some(ts) => ready = ready.max(ts),
                None => {
                    self.blocked_xfers.entry(d).or_default().push(tid);
                    return Ok(());
                }
            }
        }
        let (owner, desc) = (self.xfers[tid].owner, self.xfers[tid].desc.clone());
        let link = self.topo.link(desc.src_rank, desc.dst_rank)?;
        // per-transfer cost through the topology's own backend matrix —
        // curves differ per machine generation (hw::Arch), not per build
        let dur = self.topo.arch.transfer_time_us(
            desc.backend,
            desc.bytes,
            desc.pieces,
            desc.comm_sms,
            link,
        );
        // engine queue on the issuing device
        let queue_free = match desc.backend {
            BackendKind::CopyEngine => {
                let q = self.ce_free[desc.src_rank]
                    .iter()
                    .enumerate()
                    .min_by(|a, b| a.1.total_cmp(b.1))
                    .map(|(i, _)| i)
                    .unwrap();
                self.ce_free[desc.src_rank][q]
            }
            BackendKind::TmaSpecialized | BackendKind::LdStSpecialized | BackendKind::NcclBulk => {
                self.commsm_free[owner]
            }
            BackendKind::TmaColocated | BackendKind::LdStColocated => self.coloc_free[owner],
        };
        let lf = *self.link_free.entry((desc.src_rank, desc.dst_rank)).or_insert(0.0);
        let start = ready.max(queue_free).max(lf);
        let done = start + dur;
        // commit resources
        match desc.backend {
            BackendKind::CopyEngine => {
                let q = self.ce_free[desc.src_rank]
                    .iter()
                    .enumerate()
                    .min_by(|a, b| a.1.total_cmp(b.1))
                    .map(|(i, _)| i)
                    .unwrap();
                self.ce_free[desc.src_rank][q] = done;
            }
            BackendKind::TmaSpecialized | BackendKind::LdStSpecialized | BackendKind::NcclBulk => {
                self.commsm_free[owner] = done;
            }
            BackendKind::TmaColocated | BackendKind::LdStColocated => {
                self.coloc_free[owner] = done;
                // borrowed SM time charged back to this rank's compute
                self.debt_sm_us[owner] += dur * desc.comm_sms as f64;
            }
        }
        self.link_free.insert((desc.src_rank, desc.dst_rank), done);
        self.signal_time[desc.signal] = Some(done);
        self.xfers[tid].scheduled = true;
        self.timeline.push(Span {
            rank: owner,
            kind: SpanKind::Transfer,
            start_us: start,
            end_us: done,
            label: format!(
                "{}->{} {}B {}",
                desc.src_rank,
                desc.dst_rank,
                desc.bytes,
                desc.backend.name()
            ),
        });
        // wake blocked transfers and waiting ranks
        if let Some(blocked) = self.blocked_xfers.remove(&desc.signal) {
            for b in blocked {
                self.push(t, Event::TryIssue { tid: b });
            }
        }
        if let Some(waiters) = self.waiting_ranks.remove(&desc.signal) {
            for (rank, floor) in waiters {
                let resume_at = done.max(floor);
                self.stall(rank, floor, resume_at, desc.signal);
                self.push(resume_at, Event::Resume { rank });
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codegen::{ComputeSeg, PlanOp, RankProgram, TransferDesc};
    use crate::chunk::{Chunk, Region, TensorId};
    use crate::schedule::OpRef;

    fn chunk() -> Chunk {
        Chunk::new(TensorId(0), Region::rows(0, 4, 16))
    }

    fn topo(w: usize) -> Topology {
        crate::hw::catalog::topology("h100_node", w).unwrap()
    }

    fn xfer(signal: usize, src: usize, dst: usize, bytes: usize, deps: Vec<usize>) -> TransferDesc {
        TransferDesc {
            signal,
            op: OpRef { rank: src, index: signal },
            src_rank: src,
            dst_rank: dst,
            src_chunk: chunk(),
            dst_chunk: chunk(),
            bytes,
            pieces: 1,
            backend: BackendKind::CopyEngine,
            comm_sms: 0,
            reduce: false,
            dep_signals: deps,
        }
    }

    fn seg(tiles: usize, flops_per_tile: f64) -> ComputeSeg {
        ComputeSeg {
            tiles: (0..tiles).collect(),
            flops: vec![flops_per_tile; tiles],
            calls: vec![],
            quantized: true, // unit tests check the wave model directly
        }
    }

    fn plan(world: usize, progs: Vec<Vec<PlanOp>>, signals: usize) -> ExecutablePlan {
        ExecutablePlan {
            world,
            per_rank: progs.into_iter().map(|ops| RankProgram { ops }).collect(),
            num_signals: signals,
            reserved_comm_sms: 0,
        }
    }

    #[test]
    fn compute_only_plan_times_by_waves() {
        let topo = topo(1);
        // 264 tiles of 2*128^3 flops on 132 SMs = 2 waves
        let p = plan(1, vec![vec![PlanOp::Compute(seg(264, 2.0 * 128.0_f64.powi(3)))]], 0);
        let r = simulate(&p, &topo, SimParams { mxu_eff: 1.0 }).unwrap();
        let tile_us = 2.0 * 128.0_f64.powi(3) / (7.5 * 1e6);
        assert!((r.makespan_us - 2.0 * tile_us).abs() < 1e-9);
        assert!(r.tflops() > 0.0);
    }

    #[test]
    fn transfer_then_wait_exposes_comm() {
        let topo = topo(2);
        // rank1 issues a big transfer; rank0 waits for it with no compute.
        let p = plan(
            2,
            vec![
                vec![PlanOp::Wait(0)],
                vec![PlanOp::Issue(xfer(0, 1, 0, 64 << 20, vec![]))],
            ],
            1,
        );
        let r = simulate(&p, &topo, SimParams::default()).unwrap();
        assert!(r.makespan_us > 100.0, "64MiB over ~400GB/s ≈ 170µs: {}", r.makespan_us);
        assert!(r.exposed_wait_us > 100.0);
    }

    #[test]
    fn overlap_hides_comm_behind_compute() {
        let topo = topo(2);
        // 100 waves of 128^3 tiles ≈ 66µs compute vs ~52µs transfer
        let big_seg = seg(264 * 50, 2.0 * 128.0_f64.powi(3));
        let t = xfer(0, 1, 0, 16 << 20, vec![]);
        // rank0: compute, then wait (transfer long done) -> no stall
        let p = plan(
            2,
            vec![
                vec![PlanOp::Compute(big_seg.clone()), PlanOp::Wait(0)],
                vec![PlanOp::Issue(t), PlanOp::Compute(big_seg)],
            ],
            1,
        );
        let r = simulate(&p, &topo, SimParams::default()).unwrap();
        assert!(r.exposed_wait_us < 1.0, "exposed {}", r.exposed_wait_us);
    }

    #[test]
    fn dep_signals_serialize_transfers() {
        let topo = topo(3);
        let bytes = 8 << 20;
        // t1 (rank1->0) deps on t0 (rank2->1): must start after t0 completes.
        let p = plan(
            3,
            vec![
                vec![PlanOp::Wait(1)],
                vec![PlanOp::Issue(xfer(1, 1, 0, bytes, vec![0]))],
                vec![PlanOp::Issue(xfer(0, 2, 1, bytes, vec![]))],
            ],
            2,
        );
        let r = simulate(&p, &topo, SimParams::default()).unwrap();
        let single = {
            let p1 = plan(
                2,
                vec![
                    vec![PlanOp::Wait(0)],
                    vec![PlanOp::Issue(xfer(0, 1, 0, bytes, vec![]))],
                ],
                1,
            );
            simulate(&p1, &crate::hw::catalog::topology("h100_node", 2).unwrap(), SimParams::default())
                .unwrap()
                .makespan_us
        };
        // chained: roughly 2x one transfer
        assert!(r.makespan_us > 1.8 * single, "{} vs {single}", r.makespan_us);
    }

    #[test]
    fn link_contention_serializes_same_pair() {
        let topo = topo(2);
        let bytes = 32 << 20;
        // two transfers on the same (1 -> 0) link, independent
        let p = plan(
            2,
            vec![
                vec![PlanOp::Wait(0), PlanOp::Wait(1)],
                vec![
                    PlanOp::Issue(xfer(0, 1, 0, bytes, vec![])),
                    PlanOp::Issue(xfer(1, 1, 0, bytes, vec![])),
                ],
            ],
            2,
        );
        let two = simulate(&p, &topo, SimParams::default()).unwrap().makespan_us;
        let p1 = plan(
            2,
            vec![
                vec![PlanOp::Wait(0)],
                vec![PlanOp::Issue(xfer(0, 1, 0, bytes, vec![]))],
            ],
            1,
        );
        let one = simulate(&p1, &topo, SimParams::default()).unwrap().makespan_us;
        assert!(two > 1.8 * one, "{two} vs {one}");
    }

    #[test]
    fn colocated_charges_debt_to_compute() {
        let topo = topo(2);
        let mut t = xfer(0, 1, 0, 32 << 20, vec![]);
        t.backend = BackendKind::LdStColocated;
        t.comm_sms = 32;
        let cseg = seg(264, 2.0 * 128.0_f64.powi(3));
        let p_coloc = plan(
            2,
            vec![
                vec![PlanOp::Wait(0)],
                vec![PlanOp::Issue(t), PlanOp::Compute(cseg.clone())],
            ],
            1,
        );
        let r_coloc = simulate(&p_coloc, &topo, SimParams::default()).unwrap();
        let mut t2 = xfer(0, 1, 0, 32 << 20, vec![]);
        t2.backend = BackendKind::CopyEngine;
        let p_ce = plan(
            2,
            vec![
                vec![PlanOp::Wait(0)],
                vec![PlanOp::Issue(t2), PlanOp::Compute(cseg)],
            ],
            1,
        );
        let r_ce = simulate(&p_ce, &topo, SimParams::default()).unwrap();
        // rank1 compute is slower under co-located issue (debt)
        assert!(r_coloc.rank_end_us[1] > r_ce.rank_end_us[1]);
    }

    #[test]
    fn deadlock_detected() {
        let topo = topo(1);
        // wait on a signal nobody sets
        let p = plan(1, vec![vec![PlanOp::Wait(0)]], 1);
        let e = simulate(&p, &topo, SimParams::default()).unwrap_err();
        assert!(e.to_string().contains("deadlock"), "{e}");
    }

    #[test]
    fn world_mismatch_rejected() {
        let topo = topo(2);
        let p = plan(1, vec![vec![]], 0);
        assert!(simulate(&p, &topo, SimParams::default()).is_err());
    }

    #[test]
    fn reserved_sms_slow_compute() {
        let topo = topo(1);
        let mk = |reserved| {
            let mut p = plan(1, vec![vec![PlanOp::Compute(seg(264, 2.0 * 128.0_f64.powi(3)))]], 0);
            p.reserved_comm_sms = reserved;
            simulate(&p, &topo, SimParams::default()).unwrap().makespan_us
        };
        assert!(mk(66) > mk(0)); // half the SMs -> more waves
        // all SMs reserved is invalid
        let mut p = plan(1, vec![vec![]], 0);
        p.reserved_comm_sms = 132;
        assert!(simulate(&p, &topo, SimParams::default()).is_err());
    }

    #[test]
    fn overhead_spans_accumulate() {
        let topo = topo(1);
        let p = plan(
            1,
            vec![vec![
                PlanOp::Overhead { us: 5.0, label: "launch" },
                PlanOp::Overhead { us: 3.0, label: "sync" },
            ]],
            0,
        );
        let r = simulate(&p, &topo, SimParams::default()).unwrap();
        assert!((r.makespan_us - 8.0).abs() < 1e-12);
        assert_eq!(r.timeline.spans.len(), 2);
    }

    #[test]
    fn determinism() {
        let topo = topo(2);
        let p = plan(
            2,
            vec![
                vec![PlanOp::Compute(seg(100, 1e6)), PlanOp::Wait(0)],
                vec![PlanOp::Issue(xfer(0, 1, 0, 4 << 20, vec![])), PlanOp::Compute(seg(50, 1e6))],
            ],
            1,
        );
        let a = simulate(&p, &topo, SimParams::default()).unwrap();
        let b = simulate(&p, &topo, SimParams::default()).unwrap();
        assert_eq!(a.makespan_us, b.makespan_us);
        assert_eq!(a.timeline.spans.len(), b.timeline.spans.len());
    }
}
