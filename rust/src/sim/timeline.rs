//! Execution timelines: spans, utilization metrics, JSON export.
//!
//! Every simulated run records what each resource did and when; the report
//! binaries and EXPERIMENTS.md numbers are derived from these spans.

use std::fmt::Write as _;

use crate::topo::Rank;

/// What a span represents.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpanKind {
    /// Tiles running on a rank's compute SMs.
    Compute,
    /// A chunk transfer on a link.
    Transfer,
    /// A rank blocked waiting on a signal (exposed communication).
    WaitStall,
    /// Fixed overhead (kernel launch, reorder pass).
    Overhead,
}

impl SpanKind {
    pub fn name(self) -> &'static str {
        match self {
            SpanKind::Compute => "compute",
            SpanKind::Transfer => "transfer",
            SpanKind::WaitStall => "wait",
            SpanKind::Overhead => "overhead",
        }
    }
}

/// One timed interval on a rank.
#[derive(Debug, Clone, PartialEq)]
pub struct Span {
    pub rank: Rank,
    pub kind: SpanKind,
    pub start_us: f64,
    pub end_us: f64,
    /// Free-form detail: tile count, backend name, signal id...
    pub label: String,
}

impl Span {
    pub fn dur_us(&self) -> f64 {
        self.end_us - self.start_us
    }
}

/// A complete run timeline.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Timeline {
    pub spans: Vec<Span>,
}

impl Timeline {
    pub fn push(&mut self, span: Span) {
        debug_assert!(span.end_us >= span.start_us - 1e-9, "negative span {span:?}");
        self.spans.push(span);
    }

    /// Latest end time across all spans.
    pub fn makespan_us(&self) -> f64 {
        self.spans.iter().map(|s| s.end_us).fold(0.0, f64::max)
    }

    /// Total duration of spans of `kind` on `rank`.
    pub fn total_us(&self, rank: Rank, kind: SpanKind) -> f64 {
        self.spans
            .iter()
            .filter(|s| s.rank == rank && s.kind == kind)
            .map(|s| s.dur_us())
            .sum()
    }

    /// Fraction of the makespan `rank` spent computing.
    pub fn compute_fraction(&self, rank: Rank) -> f64 {
        let m = self.makespan_us();
        if m <= 0.0 {
            return 0.0;
        }
        self.total_us(rank, SpanKind::Compute) / m
    }

    /// Communication time not hidden behind compute, across all ranks.
    pub fn exposed_comm_us(&self, world: usize) -> f64 {
        (0..world).map(|r| self.total_us(r, SpanKind::WaitStall)).sum()
    }

    /// Hand-rolled JSON export (the vendored build has no serde_json).
    /// Schema: `[{"rank":0,"kind":"compute","start":0.0,"end":1.0,"label":".."}]`
    pub fn to_json(&self) -> String {
        let mut out = String::from("[");
        for (i, s) in self.spans.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            let _ = write!(
                out,
                "{{\"rank\":{},\"kind\":\"{}\",\"start\":{:.3},\"end\":{:.3},\"label\":\"{}\"}}",
                s.rank,
                s.kind.name(),
                s.start_us,
                s.end_us,
                s.label.replace('"', "'"),
            );
        }
        out.push(']');
        out
    }

    /// Chrome `trace_event` JSON export: the SIMULATED timeline in the
    /// same viewer format the exec engines' measured traces use
    /// ([`crate::trace::export`]), so a prediction and its measurement can
    /// be compared side by side in `chrome://tracing`. Tracks mirror the
    /// measured layout: rank `r`'s compute/wait/overhead on tid `2r`,
    /// transfers on tid `2r + 1`.
    pub fn to_chrome_json(&self, world: usize) -> String {
        let esc = crate::util::json_escape;
        let mut lines = Vec::new();
        for r in 0..world {
            for (lane, label) in
                [(2 * r, format!("rank {r} (sim)")), (2 * r + 1, format!("rank {r} comm (sim)"))]
            {
                lines.push(format!(
                    "    {{\"ph\": \"M\", \"pid\": 0, \"tid\": {lane}, \
                     \"name\": \"thread_name\", \"args\": {{\"name\": \"{label}\"}}}}"
                ));
            }
        }
        for s in &self.spans {
            let tid = match s.kind {
                SpanKind::Transfer => 2 * s.rank + 1,
                _ => 2 * s.rank,
            };
            lines.push(format!(
                "    {{\"ph\": \"X\", \"pid\": 0, \"tid\": {tid}, \"name\": \"{}\", \
                 \"cat\": \"sim-{}\", \"ts\": {}, \"dur\": {}, \"args\": {{}}}}",
                esc(&s.label),
                s.kind.name(),
                s.start_us,
                s.dur_us().max(0.0)
            ));
        }
        // simulated timelines carry no machine fingerprint (nothing ran);
        // the shared header keeps the file discoverable by the same
        // tooling as measured exports, with `sim: true` marking the origin
        let header = crate::trace::syncopate_header(
            world.max(1),
            "",
            &[],
            &[("sim", "true".to_string())],
        );
        format!(
            "{{\n  \"displayTimeUnit\": \"ms\",\n{header},\n  \"traceEvents\": [\n{}\n  ]\n}}\n",
            lines.join(",\n")
        )
    }

    /// Compact per-rank ASCII rendering for CLI debugging.
    pub fn ascii(&self, world: usize, width: usize) -> String {
        let m = self.makespan_us().max(1e-9);
        let mut out = String::new();
        for r in 0..world {
            let mut row = vec!['.'; width];
            for s in self.spans.iter().filter(|s| s.rank == r) {
                let a = ((s.start_us / m) * width as f64) as usize;
                let b = (((s.end_us / m) * width as f64).ceil() as usize).min(width);
                let ch = match s.kind {
                    SpanKind::Compute => '#',
                    SpanKind::Transfer => '~',
                    SpanKind::WaitStall => 'w',
                    SpanKind::Overhead => 'o',
                };
                for c in row.iter_mut().take(b).skip(a.min(width)) {
                    // compute wins rendering conflicts
                    if *c == '.' || ch == '#' {
                        *c = ch;
                    }
                }
            }
            let _ = writeln!(out, "r{r}: {}", row.iter().collect::<String>());
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tl() -> Timeline {
        let mut t = Timeline::default();
        t.push(Span { rank: 0, kind: SpanKind::Compute, start_us: 0.0, end_us: 10.0, label: "a".into() });
        t.push(Span { rank: 0, kind: SpanKind::WaitStall, start_us: 10.0, end_us: 12.0, label: "w".into() });
        t.push(Span { rank: 1, kind: SpanKind::Transfer, start_us: 2.0, end_us: 8.0, label: "x".into() });
        t
    }

    #[test]
    fn makespan_and_totals() {
        let t = tl();
        assert_eq!(t.makespan_us(), 12.0);
        assert_eq!(t.total_us(0, SpanKind::Compute), 10.0);
        assert_eq!(t.total_us(0, SpanKind::WaitStall), 2.0);
        assert_eq!(t.total_us(1, SpanKind::Transfer), 6.0);
        assert_eq!(t.exposed_comm_us(2), 2.0);
    }

    #[test]
    fn compute_fraction() {
        let t = tl();
        assert!((t.compute_fraction(0) - 10.0 / 12.0).abs() < 1e-12);
        assert_eq!(Timeline::default().compute_fraction(0), 0.0);
    }

    #[test]
    fn json_schema() {
        let j = tl().to_json();
        assert!(j.starts_with('[') && j.ends_with(']'));
        assert!(j.contains("\"kind\":\"compute\""));
        assert!(j.contains("\"start\":0.000"));
        // quotes in labels are sanitized
        let mut t = Timeline::default();
        t.push(Span { rank: 0, kind: SpanKind::Compute, start_us: 0.0, end_us: 1.0, label: "a\"b".into() });
        assert!(t.to_json().contains("a'b"));
    }

    #[test]
    fn chrome_export_has_tracks_and_spans() {
        let j = tl().to_chrome_json(2);
        assert!(j.contains("\"traceEvents\""), "{j}");
        // shared syncopate header, marked as simulated
        let (w, fp) = crate::trace::check_chrome_header(&j).unwrap();
        assert_eq!((w, fp.as_str()), (2, ""));
        assert!(j.contains("\"sim\": true"), "{j}");
        assert!(j.contains("rank 0 (sim)"));
        assert!(j.contains("\"cat\": \"sim-compute\""));
        // transfers land on the comm track (tid 2r+1 = 3 for rank 1)
        assert!(j.contains("\"tid\": 3"));
    }

    #[test]
    fn ascii_renders_rows() {
        let s = tl().ascii(2, 24);
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 2);
        assert!(lines[0].contains('#'));
        assert!(lines[1].contains('~'));
    }
}
