//! Wave quantization and SM utilization (paper Fig. 2a, Insight 1).
//!
//! A kernel launch with `T` tiles on `S` SMs executes in `ceil(T/S)` waves;
//! the partially-filled last wave idles `waves*S - T` SM-slots. Large GEMMs
//! amortize this; partitioning a GEMM into many small launches (kernel-level
//! overlap) pushes every launch into the low-utilization regime.

use crate::util::ceil_div;

/// Number of tile waves for `tiles` tiles on `sms` SMs.
pub fn wave_count(tiles: usize, sms: usize) -> usize {
    if tiles == 0 {
        return 0;
    }
    ceil_div(tiles, sms.max(1))
}

/// SM utilization of a launch: occupied SM-slots / total SM-slots.
pub fn sm_utilization(tiles: usize, sms: usize) -> f64 {
    if tiles == 0 {
        return 0.0;
    }
    let waves = wave_count(tiles, sms);
    tiles as f64 / (waves * sms.max(1)) as f64
}

/// Duration of a compute segment: `waves * mean tile time`, plus any
/// borrowed-SM debt (co-located communication) spread across the pool.
pub fn segment_duration_us(
    tiles: usize,
    mean_tile_us: f64,
    sms: usize,
    debt_sm_us: f64,
) -> f64 {
    let base = wave_count(tiles, sms) as f64 * mean_tile_us;
    base + debt_sm_us / sms.max(1) as f64
}

/// Duration of a segment of a *persistent fused kernel*: tiles stream
/// continuously across wait boundaries, so segments are modeled at
/// throughput granularity (`n·τ/S`) with no per-segment wave
/// re-quantization — consecutive segments pipeline into each other's idle
/// SMs. This is exactly the advantage the streamed kernel of Fig. 2(b) has
/// over kernel-partitioned launches, which pay [`segment_duration_us`]'s
/// full wave quantization on every launch.
pub fn streaming_duration_us(
    tiles: usize,
    mean_tile_us: f64,
    sms: usize,
    debt_sm_us: f64,
) -> f64 {
    (tiles as f64 * mean_tile_us + debt_sm_us) / sms.max(1) as f64
}

/// Time for one GEMM tile of `bm x bn x k` on one SM, microseconds.
///
/// `sm_tflops` is the per-SM dense throughput; `eff` the achieved fraction
/// (MXU/tensor-core occupancy for this tile shape, see
/// [`mxu_efficiency`]).
pub fn gemm_tile_time_us(bm: usize, bn: usize, k: usize, sm_tflops: f64, eff: f64) -> f64 {
    let flops = 2.0 * bm as f64 * bn as f64 * k as f64;
    flops / (sm_tflops * 1e6 * eff.max(1e-3))
}

/// Fraction of peak the tensor pipeline achieves for a tile shape — small
/// tiles under-fill the MXU/tensor cores (mirrors the L1 kernel's
/// `mxu_utilization_estimate`).
pub fn mxu_efficiency(bm: usize, bn: usize, bk: usize) -> f64 {
    let fill = (bm.min(128) as f64 / 128.0) * (bn.min(128) as f64 / 128.0);
    let ramp = bk.min(128) as f64 / 128.0;
    fill * (0.5 + 0.5 * ramp)
}

/// End-to-end utilization of an M×N GEMM with given tile config on `sms`
/// SMs — the quantity plotted in Fig. 2(a).
pub fn gemm_sm_utilization(m: usize, n: usize, bm: usize, bn: usize, sms: usize) -> f64 {
    let tiles = ceil_div(m, bm) * ceil_div(n, bn);
    sm_utilization(tiles, sms)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wave_count_basics() {
        assert_eq!(wave_count(0, 132), 0);
        assert_eq!(wave_count(1, 132), 1);
        assert_eq!(wave_count(132, 132), 1);
        assert_eq!(wave_count(133, 132), 2);
        assert_eq!(wave_count(10, 0), 10); // degenerate SM count clamped to 1
    }

    #[test]
    fn utilization_full_and_partial() {
        assert_eq!(sm_utilization(264, 132), 1.0);
        assert!((sm_utilization(133, 132) - 133.0 / 264.0).abs() < 1e-12);
        assert_eq!(sm_utilization(0, 132), 0.0);
    }

    #[test]
    fn fig2a_large_gemm_saturates_small_does_not() {
        // 16384^2 with 128-tiles: 16k tiles >> 132 SMs -> ~1.0
        let big = gemm_sm_utilization(16384, 16384, 128, 128, 132);
        assert!(big > 0.95, "{big}");
        // 512^2 with 256-tiles: 4 tiles on 132 SMs -> tiny
        let small = gemm_sm_utilization(512, 512, 256, 256, 132);
        assert!(small < 0.05, "{small}");
        // utilization decreases as GEMM shrinks (fixed tile size)
        let mut prev = 1.1;
        for m in [16384usize, 4096, 1024, 512] {
            let u = gemm_sm_utilization(m, m, 128, 128, 132);
            assert!(u <= prev + 1e-9, "m={m}: {u} > {prev}");
            prev = u;
        }
    }

    #[test]
    fn partition_hurts_utilization() {
        // Insight 1: splitting one launch into 8 sub-launches lowers
        // aggregate utilization via extra partial waves.
        let m = 4096;
        let whole = gemm_sm_utilization(m, 3072, 128, 128, 132);
        let split = gemm_sm_utilization(m / 8, 3072, 128, 128, 132);
        assert!(split < whole, "split={split} whole={whole}");
    }

    #[test]
    fn segment_duration_waves_and_debt() {
        let d0 = segment_duration_us(132, 10.0, 132, 0.0);
        assert!((d0 - 10.0).abs() < 1e-9);
        let d1 = segment_duration_us(133, 10.0, 132, 0.0);
        assert!((d1 - 20.0).abs() < 1e-9);
        // 132 SM-µs of debt on 132 SMs adds 1 µs
        let d2 = segment_duration_us(132, 10.0, 132, 132.0);
        assert!((d2 - 11.0).abs() < 1e-9);
    }

    #[test]
    fn tile_time_scale() {
        // 128^3 tile at 7.5 TFLOP/s/SM, eff 1: 2*128^3 / 7.5e6 ≈ 0.56 µs
        let t = gemm_tile_time_us(128, 128, 128, 7.5, 1.0);
        assert!((t - 0.559).abs() < 0.01, "{t}");
        // lower efficiency -> longer
        assert!(gemm_tile_time_us(128, 128, 128, 7.5, 0.5) > t * 1.9);
    }

    #[test]
    fn mxu_efficiency_shape() {
        assert_eq!(mxu_efficiency(128, 128, 128), 1.0);
        assert!(mxu_efficiency(64, 128, 128) < 1.0);
        assert!(mxu_efficiency(8, 8, 8) < 0.01);
        assert!(mxu_efficiency(256, 256, 256) <= 1.0);
    }
}
