//! Syncopate CLI: the leader entrypoint.
//!
//! Subcommands (hand-rolled parser — the offline build carries no clap):
//!
//! ```text
//! syncopate report <table2|fig2|fig8|fig9|fig10|fig11|ported|pipeline|
//!                   arch-sweep|headline|all> [--full] [--csv]
//! syncopate simulate --op <kind> [--model <name>] [--world N] [--tokens N|--seq N]
//!                    [--split K] [--backend <name>] [--sms N] [--timeline]
//!                    [--topo <name|FILE.topo>]
//! syncopate tune --op <kind> [--model <name>] [--world N] [--full]
//!                [--topo <name|FILE.topo>] [--cache FILE]
//! syncopate exec --case <NAME|list> [--world N] [--split K] [--nodes N]
//!                [--topo <name|FILE.topo>]
//!                [--exec-mode <parallel|sequential>] [--timeout-ms N]
//!                (--nodes splits SINGLE-node --topo descriptions for the
//!                 hierarchical case; a multinode description's own node
//!                 structure wins)
//! syncopate plan import --from <SOURCE> [--world N] [--out FILE.sched]
//! syncopate plan show <FILE.sched>
//! syncopate plan lint <FILE.sched>...
//! syncopate plan run <FILE.sched> [--workers N] [--exec-mode M] [--timeout-ms N]
//!                    [--topo <name|FILE.topo>]
//! syncopate plan --op <kind> [--world N] [--split K]      (operator plan stats)
//! syncopate topo list
//! syncopate topo show <name|FILE.topo>
//! syncopate topo lint <FILE.topo>...
//! syncopate serve-demo [--workers N] [--topo <name|FILE.topo>]
//! ```
//!
//! Every `--topo` accepts a built-in catalog name (`syncopate topo list`)
//! or a path to a `.topo` description file (DESIGN.md §13).

use std::collections::HashMap;

use syncopate::autotune::{self, Budget};
use syncopate::backend::BackendKind;
use syncopate::codegen::Realization;
use syncopate::coordinator::execases::{self, run_and_verify_with, CaseParams};
use syncopate::coordinator::operators::compile_operator;
use syncopate::coordinator::service::{opkind_by_name, Coordinator};
use syncopate::coordinator::TuneConfig;
use syncopate::error::{Error, Result};
use syncopate::exec::{ExecMode, ExecOptions};
use syncopate::hw;
use syncopate::plan_io;
use syncopate::reports;
use syncopate::runtime::Runtime;
use syncopate::sim::engine::simulate;
use syncopate::topo::Topology;
use syncopate::workload::{ModelCfg, OperatorInstance, DEFAULT_TOKENS, MODELS};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

/// Parse `--key value` pairs and bare flags after the subcommand.
fn parse_flags(args: &[String]) -> (HashMap<String, String>, Vec<String>) {
    let mut flags = HashMap::new();
    let mut bare = Vec::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(key) = args[i].strip_prefix("--") {
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                flags.insert(key.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                flags.insert(key.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            bare.push(args[i].clone());
            i += 1;
        }
    }
    (flags, bare)
}

fn get_usize(flags: &HashMap<String, String>, key: &str, default: usize) -> Result<usize> {
    match flags.get(key) {
        None => Ok(default),
        Some(v) => v
            .parse()
            .map_err(|_| Error::Coordinator(format!("--{key} expects an integer, got `{v}`"))),
    }
}

fn model_by_name(name: &str) -> Result<ModelCfg> {
    MODELS
        .iter()
        .find(|m| m.name == name)
        .copied()
        .ok_or_else(|| {
            Error::Coordinator(format!(
                "unknown model `{name}` (known: {})",
                MODELS.map(|m| m.name).join(", ")
            ))
        })
}

fn backend_by_name(name: &str) -> Result<BackendKind> {
    BackendKind::TUNABLE
        .into_iter()
        .chain([BackendKind::NcclBulk])
        .find(|b| b.name() == name)
        .ok_or_else(|| Error::Coordinator(format!("unknown backend `{name}`")))
}

fn build_op(flags: &HashMap<String, String>) -> Result<OperatorInstance> {
    let kind = opkind_by_name(flags.get("op").map(String::as_str).unwrap_or("ag-gemm"))?;
    let model = model_by_name(flags.get("model").map(String::as_str).unwrap_or("llama3-8b"))?;
    let world = get_usize(flags, "world", 8)?;
    Ok(if kind.is_gemm() {
        OperatorInstance::gemm(kind, &model, get_usize(flags, "tokens", DEFAULT_TOKENS)?, world)
    } else {
        OperatorInstance::attention(kind, &model, get_usize(flags, "seq", 16384)?, world)
    })
}

/// Resolve the `--topo` flag (catalog name or `.topo` file path; defaults
/// to the paper's `h100_node`) at `world` ranks.
fn resolve_topo(flags: &HashMap<String, String>, world: usize) -> Result<Topology> {
    let spec = flags.get("topo").map(String::as_str).unwrap_or(hw::catalog::DEFAULT);
    Ok(hw::catalog::resolve(spec, world)?.1)
}

fn build_cfg(flags: &HashMap<String, String>, topo: &Topology) -> Result<TuneConfig> {
    let mut cfg = TuneConfig::default();
    cfg.split = get_usize(flags, "split", cfg.split)?;
    if let Some(b) = flags.get("backend") {
        let backend = backend_by_name(b)?;
        // --sms default follows the TARGET arch's curve, not the H100
        // reference: a .topo may flip a mechanism's SM-drivenness
        let sms = get_usize(
            flags,
            "sms",
            if topo.arch.curve(backend).sms_for_peak == 0 { 0 } else { 16 },
        )?;
        cfg.real = Realization::new(backend, sms);
    }
    Ok(cfg)
}

fn dispatch(args: &[String]) -> Result<()> {
    let Some(cmd) = args.first() else {
        print_usage();
        return Ok(());
    };
    let (flags, bare) = parse_flags(&args[1..]);
    match cmd.as_str() {
        "report" => report(&bare, &flags),
        "simulate" => {
            let op = build_op(&flags)?;
            let topo = resolve_topo(&flags, op.world)?;
            let cfg = build_cfg(&flags, &topo)?;
            let (plan, params) = compile_operator(&op, &cfg, &topo)?;
            let r = simulate(&plan, &topo, params)?;
            println!("operator : {}", op.label());
            println!("topology : {} (fingerprint {})", topo.arch.name(), hw::fingerprint(&topo));
            println!("config   : {}", cfg.label());
            println!("makespan : {}", syncopate::util::fmt_us(r.makespan_us));
            println!("tflops   : {:.1}", r.tflops());
            println!("exposed  : {}", syncopate::util::fmt_us(r.exposed_wait_us));
            if flags.contains_key("timeline") {
                println!("{}", r.timeline.ascii(op.world, 100));
            }
            if let Some(path) = flags.get("timeline-json") {
                std::fs::write(path, r.timeline.to_json())?;
                println!("timeline JSON -> {path}");
            }
            Ok(())
        }
        "tune" => {
            let op = build_op(&flags)?;
            let topo = resolve_topo(&flags, op.world)?;
            let budget = if flags.contains_key("full") { Budget::Full } else { Budget::Quick };
            // tune-once persistence: `--cache FILE` reuses prior results —
            // keyed by (operator, topology fingerprint), so a cache file
            // from another machine shape never serves stale knobs here
            if let Some(path) = flags.get("cache") {
                let p = std::path::Path::new(path);
                if p.exists() {
                    let cache = autotune::TuneCache::load(p)?;
                    if let Some((cfg, m, t)) = cache.get(&op, &topo) {
                        println!("operator : {} (cached)", op.label());
                        println!("topology : {} (fingerprint {})",
                            topo.arch.name(), hw::fingerprint(&topo));
                        println!("best     : {cfg}");
                        println!("makespan : {}", syncopate::util::fmt_us(m));
                        println!("tflops   : {t:.1}");
                        return Ok(());
                    }
                }
            }
            let r = autotune::tune(&op, &topo, budget)?;
            println!("operator : {}", op.label());
            println!("topology : {} (fingerprint {})", topo.arch.name(), hw::fingerprint(&topo));
            println!("best     : {}", r.cfg.label());
            println!("makespan : {}", syncopate::util::fmt_us(r.makespan_us));
            println!("tflops   : {:.1}", r.tflops);
            println!("evaluated: {} (pruned {})", r.evaluated, r.pruned);
            if let Some(path) = flags.get("cache") {
                let p = std::path::Path::new(path);
                let mut cache = if p.exists() {
                    autotune::TuneCache::load(p)?
                } else {
                    autotune::TuneCache::default()
                };
                cache.insert(&op, &topo, &r)?;
                cache.save(p)?;
                println!("cached   : {path} ({} entries)", cache.len());
            }
            Ok(())
        }
        "exec" => {
            let case_name =
                flags.get("case").cloned().unwrap_or_else(|| "ag-gemm".to_string());
            if case_name == "list" {
                println!("registered exec cases:");
                for spec in execases::CASES {
                    println!("  {:14} {}", spec.name, spec.about);
                }
                return Ok(());
            }
            let params = CaseParams {
                world: get_usize(&flags, "world", 4)?,
                split: get_usize(&flags, "split", 1)?,
                seed: get_usize(&flags, "seed", 42)? as u64,
                nodes: get_usize(&flags, "nodes", 2)?,
                topo: flags
                    .get("topo")
                    .cloned()
                    .unwrap_or_else(|| hw::catalog::DEFAULT.to_string()),
            };
            let case = execases::build_case(&case_name, &params)?;
            let name = case.name.clone();
            let mode: ExecMode = flags
                .get("exec-mode")
                .map(String::as_str)
                .unwrap_or("parallel")
                .parse()?;
            // clamp: a zero bound would verdict "deadlock" on any wait
            let timeout_ms = get_usize(&flags, "timeout-ms", 10_000)?.max(1) as u64;
            let opts = ExecOptions {
                mode,
                wait_timeout: std::time::Duration::from_millis(timeout_ms),
            };
            let rt = Runtime::open_default()?;
            let backend = rt.backend_name();
            let stats = run_and_verify_with(case, &rt, &opts)?;
            println!(
                "{name}: VERIFIED on {} [{mode:?}/{backend}] ({} transfers, {} moved, \
                 {} kernel calls)",
                params.topo,
                stats.transfers,
                syncopate::util::fmt_bytes(stats.bytes_moved as u64),
                stats.compute_calls
            );
            Ok(())
        }
        "plan" => match bare.first().map(String::as_str) {
            Some("import") => plan_import(&flags),
            Some("show") => plan_show(&bare[1..]),
            Some("lint") => plan_lint(&bare[1..]),
            Some("run") => plan_run(&bare[1..], &flags),
            Some(other) => Err(Error::Coordinator(format!(
                "unknown plan verb `{other}` (import|show|lint|run, or `plan --op ...` \
                 for operator plan stats)"
            ))),
            None => {
                let op = build_op(&flags)?;
                let topo = resolve_topo(&flags, op.world)?;
                let cfg = build_cfg(&flags, &topo)?;
                let (plan, _) = compile_operator(&op, &cfg, &topo)?;
                println!("operator  : {}", op.label());
                println!("transfers : {}", plan.total_transfers());
                println!("signals   : {}", plan.num_signals);
                println!("flops     : {:.3e}", plan.total_flops());
                for (r, prog) in plan.per_rank.iter().enumerate() {
                    println!(
                        "rank {r}: {} ops ({} tiles, {} transfers, {} waits)",
                        prog.ops.len(),
                        prog.num_tiles(),
                        prog.num_transfers(),
                        prog.num_waits()
                    );
                }
                Ok(())
            }
        },
        "serve-demo" => {
            let world = get_usize(&flags, "world", 8)?;
            let workers = get_usize(&flags, "workers", 2)?;
            let coord = Coordinator::spawn_pool(resolve_topo(&flags, world)?, workers);
            println!(
                "coordinator up (world {world}, {} workers); submitting demo batch...",
                coord.workers()
            );
            for m in &MODELS[..2] {
                let op = OperatorInstance::gemm(
                    syncopate::workload::OpKind::AgGemm,
                    m,
                    DEFAULT_TOKENS,
                    world,
                );
                let r = coord.run(op, TuneConfig::default())?;
                println!(
                    "  {:50} {:>10} {:>8.1} TFLOPS (cache {})",
                    r.label,
                    syncopate::util::fmt_us(r.makespan_us),
                    r.tflops,
                    r.cache_hit
                );
            }
            Ok(())
        }
        "topo" => topo_cmd(&bare),
        other => {
            print_usage();
            Err(Error::Coordinator(format!("unknown subcommand `{other}`")))
        }
    }
}

/// `topo list|show|lint`: the hardware-description counterpart of the
/// `plan` verbs (DESIGN.md §13).
fn topo_cmd(bare: &[String]) -> Result<()> {
    match bare.first().map(String::as_str) {
        Some("list") => {
            println!("topology catalog (use with --topo NAME, or point --topo at a .topo file):");
            for e in hw::catalog::CATALOG {
                let d = hw::catalog::desc(e.name)?;
                println!(
                    "  {:16} {:>2} node(s)  {:>4} SMs  {:>6.0} GB/s intra   {}",
                    e.name, d.nodes, d.sms_per_device, d.intra.bw_gbps, e.about
                );
            }
            Ok(())
        }
        Some("show") => {
            let Some(spec) = bare.get(1) else {
                return Err(Error::Coordinator(
                    "topo show needs a catalog name or .topo file".into(),
                ));
            };
            let d = hw::catalog::load_desc(spec)?;
            let canonical = hw::print_desc(&d);
            println!("# {spec}");
            println!(
                "# {} node(s), {} backends, fingerprint@world{} {}",
                d.nodes,
                d.arch.available_kinds().len(),
                2 * d.nodes,
                hw::fingerprint(&d.instantiate(2 * d.nodes)?),
            );
            print!("{canonical}");
            Ok(())
        }
        Some("lint") => {
            if bare.len() < 2 {
                return Err(Error::Coordinator(
                    "topo lint needs at least one .topo file".into(),
                ));
            }
            for path in &bare[1..] {
                let text = std::fs::read_to_string(path)
                    .map_err(|e| Error::Io(format!("{path}: {e}")))?;
                let d = hw::parse_desc(&text).map_err(|e| Error::Hw(format!("{path}: {e}")))?;
                let canonical = hw::print_desc(&d);
                let reparsed = hw::parse_desc(&canonical)?;
                if reparsed != d {
                    return Err(Error::Hw(format!(
                        "{path}: print->parse round-trip changed the description \
                         (printer bug?)"
                    )));
                }
                // instantiation smoke: the description must produce a
                // usable mesh at its smallest even filling
                let world = 2 * d.nodes;
                let t = d.instantiate(world)?;
                println!(
                    "OK {path}: {} ({} node(s), {} backends), fingerprint@world{world} {}",
                    d.name,
                    d.nodes,
                    d.arch.available_kinds().len(),
                    hw::fingerprint(&t)
                );
            }
            Ok(())
        }
        other => Err(Error::Coordinator(format!(
            "unknown topo verb `{}` (list|show|lint)",
            other.unwrap_or("<none>")
        ))),
    }
}

/// `plan import --from SOURCE [--world N] [--out FILE]`: instantiate a
/// registered plan source (template or baseline importer) and emit it in
/// the `.sched` DSL.
fn plan_import(flags: &HashMap<String, String>) -> Result<()> {
    let Some(from) = flags.get("from") else {
        return Err(Error::Coordinator(format!(
            "plan import needs --from <source> (known: {})",
            plan_io::registry::names().join(", ")
        )));
    };
    let world = get_usize(flags, "world", 4)?;
    let sched = plan_io::registry::build(from, world)?;
    let text = plan_io::print_schedule(&sched)?;
    let hash = plan_io::content_hash(&text);
    match flags.get("out") {
        Some(path) => {
            std::fs::write(path, &text)?;
            println!(
                "{from} @ world {world}: {} ops, hash {hash} -> {path}",
                sched.num_ops()
            );
        }
        None => print!("{text}"),
    }
    Ok(())
}

/// `plan show FILE`: parse, validate, summarize, and re-print canonically.
fn plan_show(files: &[String]) -> Result<()> {
    let Some(path) = files.first() else {
        return Err(Error::Coordinator("plan show needs a .sched file".into()));
    };
    let text = std::fs::read_to_string(path)?;
    let sched = plan_io::parse_schedule(&text)?;
    syncopate::schedule::validate::validate(&sched)?;
    let canonical = plan_io::print_schedule(&sched)?;
    println!("# {path}");
    println!("# world {}, {} tensors, {} ops, {} over links, hash {}",
        sched.world,
        sched.tensors.len(),
        sched.num_ops(),
        syncopate::util::fmt_bytes(sched.total_link_bytes()? as u64),
        plan_io::content_hash(&canonical),
    );
    print!("{canonical}");
    Ok(())
}

/// `plan lint FILE...`: parse + validate + round-trip-check each file;
/// exits non-zero on the first violation (CI guards the shipped corpus
/// with this).
fn plan_lint(files: &[String]) -> Result<()> {
    if files.is_empty() {
        return Err(Error::Coordinator("plan lint needs at least one .sched file".into()));
    }
    for path in files {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::Io(format!("{path}: {e}")))?;
        let sched = plan_io::parse_schedule(&text)
            .map_err(|e| Error::PlanIo(format!("{path}: {e}")))?;
        syncopate::schedule::validate::validate(&sched)
            .map_err(|e| Error::Schedule(format!("{path}: {e}")))?;
        let canonical = plan_io::print_schedule(&sched)?;
        let reparsed = plan_io::parse_schedule(&canonical)?;
        if reparsed != sched {
            return Err(Error::PlanIo(format!(
                "{path}: print->parse round-trip changed the schedule (printer bug?)"
            )));
        }
        println!(
            "OK {path}: world {}, {} ops, hash {}",
            sched.world,
            sched.num_ops(),
            plan_io::content_hash(&canonical)
        );
    }
    Ok(())
}

/// `plan run FILE [--workers N] [--exec-mode M] [--timeout-ms N]`: serve a
/// user-authored schedule through the coordinator's cached path —
/// validate → restricted autotune → codegen → exec. Submitted twice to
/// show the plan-cache hit on re-serving.
fn plan_run(files: &[String], flags: &HashMap<String, String>) -> Result<()> {
    let Some(path) = files.first() else {
        return Err(Error::Coordinator("plan run needs a .sched file".into()));
    };
    let text = std::fs::read_to_string(path)?;
    let sched = plan_io::parse_schedule(&text)?;
    let workers = get_usize(flags, "workers", 2)?;
    let mode: ExecMode = flags
        .get("exec-mode")
        .map(String::as_str)
        .unwrap_or("parallel")
        .parse()?;
    let timeout_ms = get_usize(flags, "timeout-ms", 10_000)?.max(1) as u64;
    let opts = ExecOptions {
        mode,
        wait_timeout: std::time::Duration::from_millis(timeout_ms),
    };
    let coord = Coordinator::spawn_pool(resolve_topo(flags, sched.world)?, workers);
    for attempt in ["cold", "warm"] {
        let r = coord.run_user_plan(&text, opts.clone())?;
        println!(
            "{path} [{attempt}]: world {}, {} ops, backend {}, sim {}, \
             {} transfers / {} moved [{mode:?}] (cache {})",
            r.world,
            r.ops,
            r.backend_label,
            syncopate::util::fmt_us(r.sim_makespan_us),
            r.stats.transfers,
            syncopate::util::fmt_bytes(r.stats.bytes_moved as u64),
            r.cache_hit
        );
    }
    Ok(())
}

fn report(bare: &[String], flags: &HashMap<String, String>) -> Result<()> {
    let which = bare.first().map(String::as_str).unwrap_or("all");
    let budget = if flags.contains_key("full") { Budget::Full } else { Budget::Quick };
    let csv = flags.contains_key("csv");
    let emit = |t: &syncopate::metrics::Table| {
        if csv {
            println!("{}", t.to_csv());
        } else {
            println!("{}", t.render());
        }
    };
    match which {
        "table2" => emit(&reports::table2()),
        "fig2" => {
            emit(&reports::fig2a());
            emit(&reports::fig2b()?);
            emit(&reports::fig2c());
            emit(&reports::fig2d());
        }
        "fig8" => {
            let t = reports::fig8(budget)?;
            emit(&t);
            print_ratios(&t);
        }
        "fig9" => {
            let t = reports::fig9(budget)?;
            emit(&t);
            print_ratios(&t);
        }
        "fig10" => emit(&reports::fig10(budget)?),
        "ported" => emit(&reports::ported()?),
        "pipeline" => emit(&reports::pipeline()?),
        "scale" => emit(&reports::scalability(budget)?),
        "fig11" => {
            emit(&reports::fig11a()?);
            emit(&reports::fig11b()?);
            emit(&reports::fig11c()?);
            emit(&reports::fig11d()?);
        }
        "arch-sweep" => {
            let t = reports::arch_sweep()?;
            emit(&t);
            print_arch_ranking(&t);
        }
        "headline" => {
            let (avg, max) = reports::headline(budget)?;
            println!("headline: avg {avg:.2}x, up to {max:.2}x over automatic baselines\n");
        }
        "all" => {
            for w in [
                "table2", "fig2", "fig8", "fig9", "fig10", "fig11", "ported", "pipeline",
                "scale", "arch-sweep", "headline",
            ] {
                report(&[w.to_string()], flags)?;
            }
        }
        other => return Err(Error::Coordinator(format!("unknown report `{other}`"))),
    }
    Ok(())
}

/// Per-case topology ranking for `report arch-sweep` (fastest first).
fn print_arch_ranking(t: &syncopate::metrics::Table) {
    for (label, row) in &t.rows {
        let mut idx: Vec<usize> = (0..row.len()).filter(|&i| row[i].is_finite()).collect();
        idx.sort_by(|&a, &b| row[a].total_cmp(&row[b]));
        let order: Vec<&str> = idx.iter().map(|&i| t.columns[i].as_str()).collect();
        println!("  {label:14} fastest -> slowest: {}", order.join(" > "));
    }
    println!();
}

fn print_ratios(t: &syncopate::metrics::Table) {
    for base in ["triton+nccl", "kernel-level", "flux", "triton-dist"] {
        if let (Some(avg), Some(max)) =
            (t.geomean_ratio("syncopate", base), t.max_ratio("syncopate", base))
        {
            println!("  vs {base:14} avg {avg:.2}x  max {max:.2}x");
        }
    }
    println!();
}

fn print_usage() {
    println!(
        "syncopate — chunk-centric compute/communication overlap (paper reproduction)\n\
         usage: syncopate <report|simulate|tune|exec|plan|topo|serve-demo> [flags]\n\
         plan verbs: plan import --from <src>, plan show|lint|run <file.sched>\n\
         topo verbs: topo list, topo show|lint <name|file.topo>\n\
         exec cases: syncopate exec --case list\n\
         hardware  : every sim/tune/exec/plan-run takes --topo <name|file.topo>\n\
         see rust/src/main.rs header for the full flag list"
    );
}
