//! Syncopate CLI: the leader entrypoint.
//!
//! Subcommands (hand-rolled parser — the offline build carries no clap):
//!
//! ```text
//! syncopate report <table2|fig2|fig8|fig9|fig10|fig11|ported|pipeline|
//!                   arch-sweep|headline|all> [--full] [--csv] [--json]
//! syncopate simulate --op <kind> [--model <name>] [--world N] [--tokens N|--seq N]
//!                    [--split K] [--backend <name>] [--sms N] [--timeline]
//!                    [--chrome FILE.json] [--topo <name|FILE.topo>]
//! syncopate tune --op <kind> [--model <name>] [--world N] [--full]
//!                [--topo <name|FILE.topo>] [--cache FILE]
//! syncopate exec --case <NAME|list> [--world N] [--split K] [--nodes N]
//!                [--topo <name|FILE.topo>] [--trace FILE.json] [--cache FILE]
//!                [--exec-mode <parallel|sequential>] [--timeout-ms N]
//!                [--sync <atomic|condvar>] [--pin-ranks] [--pin-from FILE.json]
//!                [--repeat N] [--stats FILE.json] [--flight FILE.json]
//!                (--nodes splits SINGLE-node --topo descriptions for the
//!                 hierarchical case; a multinode description's own node
//!                 structure wins; --trace captures a Chrome trace and
//!                 --cache additionally records the measured time;
//!                 --sync picks the parallel engine's synchronization core,
//!                 --pin-ranks pins rank threads round-robin over cores, and
//!                 --pin-from derives the pin layout from a prior traced
//!                 run's per-rank slack — stragglers get dedicated cores;
//!                 --repeat N warm-replays the prepared plan N times on the
//!                 atomic engine, feeding per-iteration makespans into the
//!                 exec.iter_us histogram and the exec.repeat.* gauges;
//!                 --bench [FILE] appends the repeat percentiles as a row to
//!                 the BENCH_results.json trajectory; --stats dumps the
//!                 process telemetry snapshot as syncopate.stats.v1 JSON on
//!                 exit; --flight arms the post-mortem dump path: a deadlock
//!                 verdict snapshots the flight rings to the file)
//! syncopate trace show <FILE.json>
//! syncopate trace overlap <FILE.json>
//! syncopate trace diff <A.json> <B.json>
//! syncopate flight dump [--deadlock-demo] [--world N] [--sync <atomic|condvar>]
//!                       [--timeout-ms N] [--out FILE.json] [--chrome FILE.json]
//! syncopate flight show <FILE.json>
//!                    (the flight recorder's post-mortem surface, DESIGN.md
//!                     §18: dump snapshots this process's per-rank event
//!                     rings as syncopate.flight.v1 JSON — with
//!                     --deadlock-demo after running a known-deadlocking
//!                     plan whose verdict carries the stuck ranks' recent
//!                     events; show summarizes a previously written dump)
//! syncopate stats show [FILE.json] [--prom]
//! syncopate stats check <FILE.json>
//! syncopate stats watch <FILE.json> [--interval-ms N] [--count N]
//! syncopate stats reset
//! syncopate calibrate --from <FILE.json> --topo <name|FILE.topo> [-o FILE.topo]
//! syncopate calibrate sweep --topo <name|FILE.topo> [--backend <name>] [--world N]
//!                           [--repeat N] [-o FILE.topo]
//!                    (microbench a size x SM grid of single transfers so the
//!                     fitted curve's half_size becomes identifiable — the
//!                     one parameter `calibrate --from` must keep from the
//!                     prior; emits the updated .topo like calibrate does)
//! syncopate perf critical <FILE.json> [--json] [--chrome FILE.json]
//!                         [--what-if <name|FILE.topo>] [--what-if-comm-x F]
//! syncopate perf record [--out FILE] [--cases a,b|all] [--world N] [--split K]
//!                       [--nodes N] [--topo <name|FILE.topo>] [--repeat N]
//!                       [--bench FILE]
//! syncopate perf diff <A.json> <B.json> [--max-regress PCT]
//! syncopate perf gate --baseline <FILE> [--max-regress PCT] [--repeat N]
//!                     [--cases a,b|all] [--world N] [--topo <name|FILE.topo>]
//!                    (the critical-path profiler + continuous perf tracking,
//!                     DESIGN.md §19: `critical` reconstructs the dependency
//!                     DAG of a captured trace, extracts the longest
//!                     model-weighted path, and blames every microsecond of
//!                     the wall makespan on compute / a comm backend / an
//!                     exposed wait / scheduling gaps — --chrome re-exports
//!                     the trace with critical spans painted red, --what-if
//!                     bounds the speedup of a hypothetical comm curve;
//!                     `record` times registry cases on the arena hot path
//!                     and writes a noise-aware median+MAD baseline keyed by
//!                     machine fingerprint; `diff`/`gate` flag significant
//!                     regressions and exit non-zero when they find any)
//! syncopate plan import --from <SOURCE> [--world N] [--out FILE.sched]
//! syncopate plan show <FILE.sched>
//! syncopate plan lint <FILE.sched>...
//! syncopate plan analyze <FILE.sched>... [--json] [--strict] [--topo <name|FILE.topo>]
//! syncopate plan analyze --fix <FILE.sched> -o FILE.sched
//!                    (static analysis, DESIGN.md §17: race certificates with
//!                     witness interleavings, deadlock cycle paths, redundant-dep
//!                     reduction with sim-measured critical-path impact, overlap
//!                     lints; error findings exit non-zero, --strict promotes
//!                     warnings, --fix writes the canonically reduced plan)
//! syncopate plan run <FILE.sched> [--workers N] [--exec-mode M] [--timeout-ms N]
//!                    [--sync <atomic|condvar>] [--topo <name|FILE.topo>]
//! syncopate plan --op <kind> [--world N] [--split K]      (operator plan stats)
//! syncopate topo list
//! syncopate topo show <name|FILE.topo>
//! syncopate topo lint <FILE.topo>...
//! syncopate serve-demo [--workers N] [--topo <name|FILE.topo>] [--stats FILE.json]
//!                      [--flight FILE.json] [--trace-sample N] [--requests N]
//!                    (--trace-sample N serves a batch of user-plan requests
//!                     with every Nth routed through the traced path; each
//!                     sample feeds sim.divergence and the trace.sample.*
//!                     gauges — production-shaped sampled live tracing)
//! ```
//!
//! Every `--topo` accepts a built-in catalog name (`syncopate topo list`)
//! or a path to a `.topo` description file (DESIGN.md §13). Tracing and
//! calibration (the sim↔execution loop) are DESIGN.md §14: `exec --trace`
//! captures, `trace overlap` analyzes, `calibrate` fits measured curves
//! into a new `.topo`.

use std::collections::HashMap;

use syncopate::autotune::{self, Budget};
use syncopate::backend::BackendKind;
use syncopate::codegen::Realization;
use syncopate::coordinator::execases::{self, run_and_verify_with, CaseParams};
use syncopate::coordinator::operators::compile_operator;
use syncopate::coordinator::service::{opkind_by_name, Coordinator};
use syncopate::coordinator::TuneConfig;
use syncopate::error::{Error, Result};
use syncopate::exec::{ExecMode, ExecOptions, SyncStrategy};
use syncopate::hw;
use syncopate::plan_io;
use syncopate::reports;
use syncopate::runtime::Runtime;
use syncopate::sim::engine::simulate;
use syncopate::topo::Topology;
use syncopate::workload::{ModelCfg, OperatorInstance, DEFAULT_TOKENS, MODELS};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

/// Parse `--key value` pairs (and short `-k value` flags, e.g.
/// `calibrate -o FILE`) plus bare words after the subcommand.
fn parse_flags(args: &[String]) -> (HashMap<String, String>, Vec<String>) {
    let mut flags = HashMap::new();
    let mut bare = Vec::new();
    let mut i = 0;
    while i < args.len() {
        let key = args[i]
            .strip_prefix("--")
            .or_else(|| args[i].strip_prefix('-').filter(|k| !k.is_empty()));
        if let Some(key) = key {
            if i + 1 < args.len() && !args[i + 1].starts_with('-') {
                flags.insert(key.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                flags.insert(key.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            bare.push(args[i].clone());
            i += 1;
        }
    }
    (flags, bare)
}

fn get_usize(flags: &HashMap<String, String>, key: &str, default: usize) -> Result<usize> {
    match flags.get(key) {
        None => Ok(default),
        Some(v) => v
            .parse()
            .map_err(|_| Error::Coordinator(format!("--{key} expects an integer, got `{v}`"))),
    }
}

/// Parse `--sync <atomic|condvar>` (default atomic).
fn get_sync(flags: &HashMap<String, String>) -> Result<SyncStrategy> {
    flags.get("sync").map(String::as_str).unwrap_or("atomic").parse()
}

/// Resolve `--pin-ranks` / `--pin-from FILE.json` into a rank→core layout
/// for [`ExecOptions::pin_cores`]. `--pin-from` orders ranks by measured
/// per-rank slack from a chunk trace (stragglers get the low cores);
/// `--pin-ranks` alone is the identity `rank % cores` spread.
fn get_pin_layout(flags: &HashMap<String, String>, world: usize) -> Result<Option<Vec<usize>>> {
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    if let Some(path) = flags.get("pin-from") {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::Io(format!("{path}: {e}")))?;
        let trace = syncopate::trace::from_chrome_json(&text)?;
        let report = syncopate::trace::analyze(&trace);
        if report.per_rank.len() != world {
            return Err(Error::Exec(format!(
                "--pin-from {path}: trace has {} ranks but the case runs {world}",
                report.per_rank.len()
            )));
        }
        let slack: Vec<f64> = report
            .per_rank
            .iter()
            .map(|u| (report.wall_makespan_us - u.end_us).max(0.0))
            .collect();
        return Ok(Some(syncopate::exec::pin::layout_from_slack(&slack, cores)));
    }
    if flags.contains_key("pin-ranks") {
        return Ok(Some(syncopate::exec::pin::identity_layout(world, cores)));
    }
    Ok(None)
}

fn model_by_name(name: &str) -> Result<ModelCfg> {
    MODELS
        .iter()
        .find(|m| m.name == name)
        .copied()
        .ok_or_else(|| {
            Error::Coordinator(format!(
                "unknown model `{name}` (known: {})",
                MODELS.map(|m| m.name).join(", ")
            ))
        })
}

fn backend_by_name(name: &str) -> Result<BackendKind> {
    BackendKind::TUNABLE
        .into_iter()
        .chain([BackendKind::NcclBulk])
        .find(|b| b.name() == name)
        .ok_or_else(|| Error::Coordinator(format!("unknown backend `{name}`")))
}

fn build_op(flags: &HashMap<String, String>) -> Result<OperatorInstance> {
    let kind = opkind_by_name(flags.get("op").map(String::as_str).unwrap_or("ag-gemm"))?;
    let model = model_by_name(flags.get("model").map(String::as_str).unwrap_or("llama3-8b"))?;
    let world = get_usize(flags, "world", 8)?;
    Ok(if kind.is_gemm() {
        OperatorInstance::gemm(kind, &model, get_usize(flags, "tokens", DEFAULT_TOKENS)?, world)
    } else {
        OperatorInstance::attention(kind, &model, get_usize(flags, "seq", 16384)?, world)
    })
}

/// Resolve the `--topo` flag (catalog name or `.topo` file path; defaults
/// to the paper's `h100_node`) at `world` ranks.
fn resolve_topo(flags: &HashMap<String, String>, world: usize) -> Result<Topology> {
    let spec = flags.get("topo").map(String::as_str).unwrap_or(hw::catalog::DEFAULT);
    Ok(hw::catalog::resolve(spec, world)?.1)
}

fn build_cfg(flags: &HashMap<String, String>, topo: &Topology) -> Result<TuneConfig> {
    let mut cfg = TuneConfig::default();
    cfg.split = get_usize(flags, "split", cfg.split)?;
    if let Some(b) = flags.get("backend") {
        let backend = backend_by_name(b)?;
        // --sms default follows the TARGET arch's curve, not the H100
        // reference: a .topo may flip a mechanism's SM-drivenness
        let sms = get_usize(
            flags,
            "sms",
            if topo.arch.curve(backend).sms_for_peak == 0 { 0 } else { 16 },
        )?;
        cfg.real = Realization::new(backend, sms);
    }
    Ok(cfg)
}

fn dispatch(args: &[String]) -> Result<()> {
    let Some(cmd) = args.first() else {
        print_usage();
        return Ok(());
    };
    let (flags, bare) = parse_flags(&args[1..]);
    match cmd.as_str() {
        "report" => report(&bare, &flags),
        "simulate" => {
            let op = build_op(&flags)?;
            let topo = resolve_topo(&flags, op.world)?;
            let cfg = build_cfg(&flags, &topo)?;
            let (plan, params) = compile_operator(&op, &cfg, &topo)?;
            let r = simulate(&plan, &topo, params)?;
            println!("operator : {}", op.label());
            println!("topology : {} (fingerprint {})", topo.arch.name(), hw::fingerprint(&topo));
            println!("config   : {}", cfg.label());
            println!("makespan : {}", syncopate::util::fmt_us(r.makespan_us));
            println!("tflops   : {:.1}", r.tflops());
            println!("exposed  : {}", syncopate::util::fmt_us(r.exposed_wait_us));
            if flags.contains_key("timeline") {
                println!("{}", r.timeline.ascii(op.world, 100));
            }
            if let Some(path) = flags.get("timeline-json") {
                std::fs::write(path, r.timeline.to_json())?;
                println!("timeline JSON -> {path}");
            }
            if let Some(path) = flags.get("chrome") {
                // predicted timeline, same viewer format as `exec --trace`
                std::fs::write(path, r.timeline.to_chrome_json(op.world))?;
                println!("chrome trace (simulated) -> {path}");
            }
            Ok(())
        }
        "tune" => {
            let op = build_op(&flags)?;
            let topo = resolve_topo(&flags, op.world)?;
            let budget = if flags.contains_key("full") { Budget::Full } else { Budget::Quick };
            // tune-once persistence: `--cache FILE` reuses prior results —
            // keyed by (operator, topology fingerprint), so a cache file
            // from another machine shape never serves stale knobs here
            if let Some(path) = flags.get("cache") {
                let p = std::path::Path::new(path);
                if p.exists() {
                    let cache = autotune::TuneCache::load(p)?;
                    if let Some((cfg, m, t)) = cache.get(&op, &topo) {
                        println!("operator : {} (cached)", op.label());
                        println!("topology : {} (fingerprint {})",
                            topo.arch.name(), hw::fingerprint(&topo));
                        println!("best     : {cfg}");
                        println!("makespan : {}", syncopate::util::fmt_us(m));
                        println!("tflops   : {t:.1}");
                        return Ok(());
                    }
                }
            }
            let r = autotune::tune(&op, &topo, budget)?;
            println!("operator : {}", op.label());
            println!("topology : {} (fingerprint {})", topo.arch.name(), hw::fingerprint(&topo));
            println!("best     : {}", r.cfg.label());
            println!("makespan : {}", syncopate::util::fmt_us(r.makespan_us));
            println!("tflops   : {:.1}", r.tflops);
            println!("evaluated: {} (pruned {})", r.evaluated, r.pruned);
            if let Some(path) = flags.get("cache") {
                let p = std::path::Path::new(path);
                let mut cache = if p.exists() {
                    autotune::TuneCache::load(p)?
                } else {
                    autotune::TuneCache::default()
                };
                cache.insert(&op, &topo, &r)?;
                cache.save(p)?;
                println!("cached   : {path} ({} entries)", cache.len());
            }
            Ok(())
        }
        "exec" => {
            let case_name =
                flags.get("case").cloned().unwrap_or_else(|| "ag-gemm".to_string());
            if case_name == "list" {
                println!("registered exec cases:");
                for spec in execases::CASES {
                    println!("  {:14} {}", spec.name, spec.about);
                }
                return Ok(());
            }
            let params = CaseParams {
                world: get_usize(&flags, "world", 4)?,
                split: get_usize(&flags, "split", 1)?,
                seed: get_usize(&flags, "seed", 42)? as u64,
                nodes: get_usize(&flags, "nodes", 2)?,
                topo: flags
                    .get("topo")
                    .cloned()
                    .unwrap_or_else(|| hw::catalog::DEFAULT.to_string()),
            };
            let case = execases::build_case(&case_name, &params)?;
            let name = case.name.clone();
            let plan_flops = case.plan.total_flops();
            let mode: ExecMode = flags
                .get("exec-mode")
                .map(String::as_str)
                .unwrap_or("parallel")
                .parse()?;
            // clamp: a zero bound would verdict "deadlock" on any wait
            let timeout_ms = get_usize(&flags, "timeout-ms", 10_000)?.max(1) as u64;
            let opts = ExecOptions {
                mode,
                wait_timeout: std::time::Duration::from_millis(timeout_ms),
                sync: get_sync(&flags)?,
                pin_cores: get_pin_layout(&flags, params.world)?,
            };
            if let Some(path) = flags.get("flight") {
                // post-mortem capture: a runtime deadlock verdict snapshots
                // the flight rings to this file (DESIGN.md §18)
                syncopate::obs::flight::set_dump_path(Some(path));
            }
            // stamp run provenance into the flight recorder so a post-mortem
            // dump names the same (world, fingerprint, case) as a trace would
            syncopate::obs::flight::set_context(
                params.world,
                &hw::fingerprint(&case.topo),
                &case_name,
            );
            let rt = Runtime::open_default()?;
            let backend = rt.backend_name();
            let stats = match flags.get("trace") {
                None => run_and_verify_with(case, &rt, &opts)?,
                Some(trace_path) => {
                    let (stats, mut trace) =
                        execases::run_and_verify_traced(case, &rt, &opts)?;
                    // full provenance so `trace overlap` / `calibrate` can
                    // rebuild and re-simulate exactly this run
                    trace.set_meta("registry-case", &case_name);
                    trace.set_meta("split", &params.split.to_string());
                    trace.set_meta("seed", &params.seed.to_string());
                    trace.set_meta("nodes", &params.nodes.to_string());
                    trace.set_meta("topo", &params.topo);
                    std::fs::write(trace_path, syncopate::trace::to_chrome_json(&trace))?;
                    let report = syncopate::trace::analyze(&trace);
                    println!("trace -> {trace_path} ({})", report.summary_line());
                    if let Some(cache_path) = flags.get("cache") {
                        // the MEASURED time lands in the tuning cache,
                        // keyed like everything else by the machine
                        // fingerprint; measured entries outrank modeled
                        let p = std::path::Path::new(cache_path);
                        let mut cache = if p.exists() {
                            autotune::TuneCache::load(p)?
                        } else {
                            autotune::TuneCache::default()
                        };
                        cache.insert_measured_raw(
                            &format!("exec:{name}"),
                            &trace.fingerprint,
                            &format!("{mode:?}"),
                            report.busy_makespan_us,
                            syncopate::metrics::tflops(plan_flops, report.busy_makespan_us),
                        )?;
                        cache.save(p)?;
                        println!(
                            "measured : busy {} -> {cache_path} ({} entries)",
                            syncopate::util::fmt_us(report.busy_makespan_us),
                            cache.len()
                        );
                    }
                    stats
                }
            };
            println!(
                "{name}: VERIFIED on {} [{mode:?}/{backend}] ({} transfers, {} moved, \
                 {} kernel calls)",
                params.topo,
                stats.transfers,
                syncopate::util::fmt_bytes(stats.bytes_moved as u64),
                stats.compute_calls
            );
            // --repeat N: warm-replay the prepared plan through the atomic
            // engine's arena-reusing entry point (regardless of --exec-mode:
            // replay is about the serving-tier hot path), so exec.iter_us
            // accumulates real per-iteration makespans
            let repeat = get_usize(&flags, "repeat", 1)?.max(1);
            if repeat > 1 {
                let rcase = execases::build_case(&case_name, &params)?;
                let prep = syncopate::exec::prepare(&rcase.plan, &rcase.sched.tensors)?;
                let mut arena = syncopate::exec::PlanArena::new(&prep);
                let hist =
                    syncopate::obs::histogram_with("exec.iter_us", &[("case", name.as_str())]);
                for _ in 0..repeat {
                    let store = rcase.store.clone();
                    let t0 = std::time::Instant::now();
                    syncopate::exec::run_prepared_reusing(&prep, &mut arena, &store, &rt, &opts)?;
                    hist.record_us(syncopate::obs::us_since(t0));
                }
                let s = hist.snap();
                let (p50, p90, p99) =
                    (s.percentile(0.50), s.percentile(0.90), s.percentile(0.99));
                println!(
                    "repeat {repeat}x [atomic, arena-reused]: p50 {} p90 {} p99 {} max {} \
                     (n={})",
                    syncopate::util::fmt_us(p50),
                    syncopate::util::fmt_us(p90),
                    syncopate::util::fmt_us(p99),
                    syncopate::util::fmt_us(s.max_us),
                    s.count
                );
                // the percentile row is data, not just console text: gauges
                // land in the --stats snapshot, --bench in the trajectory
                let labels = [("case", name.as_str())];
                for (g, v) in [
                    ("exec.repeat.p50_us", p50),
                    ("exec.repeat.p90_us", p90),
                    ("exec.repeat.p99_us", p99),
                    ("exec.repeat.max_us", s.max_us),
                    ("exec.repeat.count", s.count as f64),
                ] {
                    syncopate::obs::gauge_with(g, &labels).set(v);
                }
                if let Some(v) = flags.get("bench") {
                    let path = if v == "true" { "BENCH_results.json" } else { v.as_str() };
                    let row = syncopate::perf::bench_row(
                        "exec-repeat",
                        &[
                            ("case", name.as_str()),
                            ("topo", params.topo.as_str()),
                            ("world", &params.world.to_string()),
                        ],
                        &[
                            ("repeat", repeat as f64),
                            ("p50_us", p50),
                            ("p90_us", p90),
                            ("p99_us", p99),
                            ("max_us", s.max_us),
                        ],
                    );
                    syncopate::perf::append_bench_row(path, &row)?;
                    println!("bench row -> {path}");
                }
            }
            if let Some(path) = flags.get("stats") {
                let snap = syncopate::obs::registry().snapshot();
                std::fs::write(path, syncopate::obs::export::to_json(&snap))?;
                println!("stats -> {path} ({} metrics)", snap.entries.len());
            }
            Ok(())
        }
        "trace" => trace_cmd(&bare),
        "flight" => flight_cmd(&bare, &flags),
        "stats" => stats_cmd(&bare, &flags),
        "calibrate" => calibrate_cmd(&bare, &flags),
        "perf" => perf_cmd(&bare, &flags),
        "plan" => match bare.first().map(String::as_str) {
            Some("import") => plan_import(&flags),
            Some("show") => plan_show(&bare[1..]),
            Some("lint") => plan_lint(&bare[1..]),
            Some("analyze") => plan_analyze(&bare[1..], &flags),
            Some("run") => plan_run(&bare[1..], &flags),
            Some(other) => Err(Error::Coordinator(format!(
                "unknown plan verb `{other}` (import|show|lint|analyze|run, or \
                 `plan --op ...` for operator plan stats)"
            ))),
            None => {
                let op = build_op(&flags)?;
                let topo = resolve_topo(&flags, op.world)?;
                let cfg = build_cfg(&flags, &topo)?;
                let (plan, _) = compile_operator(&op, &cfg, &topo)?;
                println!("operator  : {}", op.label());
                println!("transfers : {}", plan.total_transfers());
                println!("signals   : {}", plan.num_signals);
                println!("flops     : {:.3e}", plan.total_flops());
                for (r, prog) in plan.per_rank.iter().enumerate() {
                    println!(
                        "rank {r}: {} ops ({} tiles, {} transfers, {} waits)",
                        prog.ops.len(),
                        prog.num_tiles(),
                        prog.num_transfers(),
                        prog.num_waits()
                    );
                }
                Ok(())
            }
        },
        "serve-demo" => {
            let world = get_usize(&flags, "world", 8)?;
            let workers = get_usize(&flags, "workers", 2)?;
            if let Some(path) = flags.get("flight") {
                // any served error (or deadlock verdict) snapshots the
                // flight rings to this file for post-mortem inspection
                syncopate::obs::flight::set_dump_path(Some(path));
            }
            let topo = resolve_topo(&flags, world)?;
            syncopate::obs::flight::set_context(world, &hw::fingerprint(&topo), "serve-demo");
            let coord = Coordinator::spawn_pool(topo, workers);
            println!(
                "coordinator up (world {world}, {} workers); submitting demo batch...",
                coord.workers()
            );
            for m in &MODELS[..2] {
                let op = OperatorInstance::gemm(
                    syncopate::workload::OpKind::AgGemm,
                    m,
                    DEFAULT_TOKENS,
                    world,
                );
                let r = coord.run(op, TuneConfig::default())?;
                println!(
                    "  {:50} {:>10} {:>8.1} TFLOPS (cache {})",
                    r.label,
                    syncopate::util::fmt_us(r.makespan_us),
                    r.tflops,
                    r.cache_hit
                );
            }
            // user-plan requests served WITH per-request tracing: every
            // response carries its measured overlap stats (DESIGN.md §14)
            let sched = plan_io::registry::build("ag-swizzle", world)?;
            let text = plan_io::print_schedule(&sched)?;
            for attempt in ["cold", "warm"] {
                let r = coord.run_user_plan_traced(&text, ExecOptions::parallel())?;
                let t = r.trace.as_ref().expect("traced request carries stats");
                let hidden = if t.hidden_frac.is_nan() {
                    "-".to_string()
                } else {
                    format!("{:.0}%", t.hidden_frac * 100.0)
                };
                println!(
                    "  plan ag-swizzle [{attempt:4}] {:>10} busy, {} events, comm {} \
                     ({hidden} hidden), {} transfers (cache {})",
                    syncopate::util::fmt_us(t.busy_makespan_us),
                    t.events,
                    syncopate::util::fmt_us(t.comm_us),
                    r.stats.transfers,
                    r.cache_hit
                );
            }
            // --trace-sample N: serve a batch of user-plan requests with
            // every Nth routed through the traced path — production-shaped
            // sampled live tracing. Each sample feeds sim.divergence and
            // the trace.sample.* gauges (inspect with `stats show`).
            if let Some(v) = flags.get("trace-sample") {
                let n: usize = v.parse().map_err(|_| {
                    Error::Coordinator(format!("--trace-sample expects an integer, got `{v}`"))
                })?;
                let n = n.max(1);
                let batch = get_usize(&flags, "requests", 8)?.max(1);
                let mut sampled = 0usize;
                for i in 0..batch {
                    if (i + 1) % n == 0 {
                        let r = coord.run_user_plan_traced(&text, ExecOptions::parallel())?;
                        let t = r.trace.as_ref().expect("traced request carries stats");
                        sampled += 1;
                        syncopate::obs::counter("trace.sampled_total").inc();
                        syncopate::obs::gauge("trace.sample.events").set(t.events as f64);
                        syncopate::obs::gauge("trace.sample.comm_us").set(t.comm_us);
                        syncopate::obs::gauge("trace.sample.wait_us").set(t.wait_us);
                        syncopate::obs::gauge("trace.sample.busy_makespan_us")
                            .set(t.busy_makespan_us);
                        if !t.hidden_frac.is_nan() {
                            syncopate::obs::gauge("trace.sample.hidden_frac").set(t.hidden_frac);
                        }
                    } else {
                        coord.run_user_plan(&text, ExecOptions::parallel())?;
                    }
                }
                println!(
                    "  sampled {sampled}/{batch} user-plan requests (1 in {n}) through the \
                     traced path"
                );
            }
            // live telemetry on exit: everything the demo batch recorded
            // (per-phase serving latencies, cache traffic, the divergence
            // gauge the traced requests fed)
            let snap = syncopate::obs::registry().snapshot();
            println!("\n{}", syncopate::obs::export::render(&snap));
            if let Some(path) = flags.get("stats") {
                std::fs::write(path, syncopate::obs::export::to_json(&snap))?;
                println!("stats -> {path} ({} metrics)", snap.entries.len());
            }
            Ok(())
        }
        "topo" => topo_cmd(&bare),
        other => {
            print_usage();
            Err(Error::Coordinator(format!("unknown subcommand `{other}`")))
        }
    }
}

/// `flight dump|show`: the flight recorder's post-mortem surface
/// (DESIGN.md §18). `dump` snapshots this process's rings; with
/// `--deadlock-demo` it first runs a known-deadlocking plan so the whole
/// capture path can be exercised without authoring a broken `.sched`.
/// `show FILE` re-reads a previously written dump and summarizes it.
fn flight_cmd(bare: &[String], flags: &HashMap<String, String>) -> Result<()> {
    match bare.first().map(String::as_str) {
        Some("dump") => {
            let demo = flags.contains_key("deadlock-demo");
            if demo {
                let world = get_usize(flags, "world", 2)?;
                // short bound: the verdict is the point, not the wait
                let timeout_ms = get_usize(flags, "timeout-ms", 250)?.max(1) as u64;
                let case = execases::deadlock_demo(world)?;
                let opts = ExecOptions {
                    wait_timeout: std::time::Duration::from_millis(timeout_ms),
                    sync: get_sync(flags)?,
                    ..ExecOptions::parallel()
                };
                let rt = Runtime::open_default()?;
                match syncopate::exec::run_with(
                    &case.plan,
                    &case.sched.tensors,
                    &case.store,
                    &rt,
                    &opts,
                ) {
                    Ok(_) => {
                        return Err(Error::Coordinator(
                            "deadlock demo unexpectedly ran to completion".into(),
                        ))
                    }
                    Err(e) => println!("verdict: {e}"),
                }
            }
            let dump =
                syncopate::obs::flight::snapshot(if demo { "deadlock-demo" } else { "manual" });
            let out = flags.get("out").map(String::as_str).unwrap_or("flight.json");
            std::fs::write(out, syncopate::obs::flight::to_json(&dump))?;
            println!("flight dump -> {out} ({} events)", dump.events.len());
            if let Some(path) = flags.get("chrome") {
                std::fs::write(path, syncopate::obs::flight::to_chrome_json(&dump))?;
                println!("chrome trace -> {path}");
            }
            Ok(())
        }
        Some("show") => {
            let Some(path) = bare.get(1) else {
                return Err(Error::Coordinator(
                    "flight show needs a flight dump file (write one with `flight dump`)".into(),
                ));
            };
            let dump = syncopate::obs::flight::from_json(&std::fs::read_to_string(path)?)?;
            println!("{}", syncopate::obs::flight::render(&dump));
            Ok(())
        }
        other => Err(Error::Coordinator(format!(
            "unknown flight verb `{}` (dump|show)",
            other.unwrap_or("")
        ))),
    }
}

/// `topo list|show|lint`: the hardware-description counterpart of the
/// `plan` verbs (DESIGN.md §13).
fn topo_cmd(bare: &[String]) -> Result<()> {
    match bare.first().map(String::as_str) {
        Some("list") => {
            println!("topology catalog (use with --topo NAME, or point --topo at a .topo file):");
            for e in hw::catalog::CATALOG {
                let d = hw::catalog::desc(e.name)?;
                println!(
                    "  {:16} {:>2} node(s)  {:>4} SMs  {:>6.0} GB/s intra   {}",
                    e.name, d.nodes, d.sms_per_device, d.intra.bw_gbps, e.about
                );
            }
            Ok(())
        }
        Some("show") => {
            let Some(spec) = bare.get(1) else {
                return Err(Error::Coordinator(
                    "topo show needs a catalog name or .topo file".into(),
                ));
            };
            let d = hw::catalog::load_desc(spec)?;
            let canonical = hw::print_desc(&d);
            println!("# {spec}");
            println!(
                "# {} node(s), {} backends, fingerprint@world{} {}",
                d.nodes,
                d.arch.available_kinds().len(),
                2 * d.nodes,
                hw::fingerprint(&d.instantiate(2 * d.nodes)?),
            );
            print!("{canonical}");
            Ok(())
        }
        Some("lint") => {
            if bare.len() < 2 {
                return Err(Error::Coordinator(
                    "topo lint needs at least one .topo file".into(),
                ));
            }
            for path in &bare[1..] {
                let text = std::fs::read_to_string(path)
                    .map_err(|e| Error::Io(format!("{path}: {e}")))?;
                let d = hw::parse_desc(&text).map_err(|e| Error::Hw(format!("{path}: {e}")))?;
                let canonical = hw::print_desc(&d);
                let reparsed = hw::parse_desc(&canonical)?;
                if reparsed != d {
                    return Err(Error::Hw(format!(
                        "{path}: print->parse round-trip changed the description \
                         (printer bug?)"
                    )));
                }
                // instantiation smoke: the description must produce a
                // usable mesh at its smallest even filling
                let world = 2 * d.nodes;
                let t = d.instantiate(world)?;
                println!(
                    "OK {path}: {} ({} node(s), {} backends), fingerprint@world{world} {}",
                    d.name,
                    d.nodes,
                    d.arch.available_kinds().len(),
                    hw::fingerprint(&t)
                );
            }
            Ok(())
        }
        other => Err(Error::Coordinator(format!(
            "unknown topo verb `{}` (list|show|lint)",
            other.unwrap_or("<none>")
        ))),
    }
}

/// Read + schema-check + parse an exported Chrome trace file.
fn load_trace(path: &str) -> Result<syncopate::trace::Trace> {
    let text = std::fs::read_to_string(path).map_err(|e| Error::Io(format!("{path}: {e}")))?;
    syncopate::trace::from_chrome_json(&text)
        .map_err(|e| Error::Trace(format!("{path}: {e}")))
}

/// Rebuild the exec case a trace was captured from (when its provenance
/// metadata names one) and return its compiled plan + the topology it
/// executed on — `trace overlap` and `calibrate` simulate it to score
/// sim-vs-trace divergence. The case's OWN topology matters: the
/// hierarchical case splits single-node `--topo` descriptions across
/// `--nodes`, so re-resolving the topo spec naively would simulate a
/// different machine shape than the trace's fingerprint names. `Ok(None)`
/// when the trace carries no case provenance (e.g. a coordinator trace).
/// (Rebuilding also re-derives the case's host oracles — wasted for this
/// read-only path, but it keeps one source of truth for case shapes.)
fn traced_case_plan(
    trace: &syncopate::trace::Trace,
) -> Result<Option<(syncopate::codegen::ExecutablePlan, Topology)>> {
    let (Some(case), Some(split), Some(seed), Some(nodes), Some(tspec)) = (
        trace.meta("registry-case"),
        trace.meta("split"),
        trace.meta("seed"),
        trace.meta("nodes"),
        trace.meta("topo"),
    ) else {
        return Ok(None);
    };
    let num = |what: &str, v: &str| -> Result<usize> {
        v.parse()
            .map_err(|_| Error::Trace(format!("trace meta `{what}` is not an integer: `{v}`")))
    };
    let params = CaseParams {
        world: trace.world,
        split: num("split", split)?,
        seed: num("seed", seed)? as u64,
        nodes: num("nodes", nodes)?,
        topo: tspec.to_string(),
    };
    let built = execases::build_case(case, &params)?;
    Ok(Some((built.plan, built.topo)))
}

/// `trace show|overlap|diff`: inspect captured execution traces
/// (DESIGN.md §14).
fn trace_cmd(bare: &[String]) -> Result<()> {
    if bare.first().map(String::as_str) == Some("diff") {
        return trace_diff(bare.get(1), bare.get(2));
    }
    let (verb, path) = match (bare.first().map(String::as_str), bare.get(1)) {
        (Some(v @ ("show" | "overlap")), Some(p)) => (v, p),
        (Some("show" | "overlap"), None) => {
            return Err(Error::Coordinator("trace show|overlap needs a trace file".into()))
        }
        (other, _) => {
            return Err(Error::Coordinator(format!(
                "unknown trace verb `{}` (show|overlap|diff)",
                other.unwrap_or("<none>")
            )))
        }
    };
    let trace = load_trace(path)?;
    println!("# {path}");
    println!(
        "# world {}, fingerprint {}, {} events ({} transfers, {} waits, {} kernel calls, \
         {} segments)",
        trace.world,
        if trace.fingerprint.is_empty() { "<none>" } else { trace.fingerprint.as_str() },
        trace.events.len(),
        trace.count("transfer"),
        trace.count("wait"),
        trace.count("kernel"),
        trace.count("compute"),
    );
    for (k, v) in &trace.meta {
        println!("# {k}: {v}");
    }
    let report = syncopate::trace::analyze(&trace);
    match verb {
        "show" => println!("{}", report.summary_line()),
        _ => {
            println!("{}", report.table().render());
            println!("{}\n", report.summary_line());
            // divergence against the model, when the trace names its case
            if let Some((plan, topo)) = traced_case_plan(&trace)? {
                let sim = simulate(&plan, &topo, syncopate::sim::SimParams::default())?;
                let case = trace.meta("registry-case").expect("provenance checked");
                println!("{}", report.divergence_table(case, sim.makespan_us).render());
            }
        }
    }
    Ok(())
}

/// `trace diff A.json B.json`: compare two traced runs of the same plan —
/// per-rank busy deltas, makespan/hidden-fraction deltas, and (when the
/// traces name their registry case) the sim-vs-trace divergence shift.
/// Refuses traces that describe different worlds, machine shapes, or
/// cases: a diff across those is noise, not a comparison.
fn trace_diff(a: Option<&String>, b: Option<&String>) -> Result<()> {
    let (Some(pa), Some(pb)) = (a, b) else {
        return Err(Error::Coordinator("trace diff needs two trace files: A.json B.json".into()));
    };
    let ta = load_trace(pa)?;
    let tb = load_trace(pb)?;
    if ta.world != tb.world {
        return Err(Error::Trace(format!(
            "world mismatch: {pa} is world {}, {pb} is world {}",
            ta.world, tb.world
        )));
    }
    if !ta.fingerprint.is_empty()
        && !tb.fingerprint.is_empty()
        && ta.fingerprint != tb.fingerprint
    {
        return Err(Error::Trace(format!(
            "fingerprint mismatch: the traces ran on different machine shapes \
             ({} vs {})",
            ta.fingerprint, tb.fingerprint
        )));
    }
    if let (Some(ca), Some(cb)) = (ta.meta("registry-case"), tb.meta("registry-case")) {
        if ca != cb {
            return Err(Error::Trace(format!(
                "case mismatch: {pa} traced `{ca}`, {pb} traced `{cb}`"
            )));
        }
    }
    let ra = syncopate::trace::analyze(&ta);
    let rb = syncopate::trace::analyze(&tb);
    println!("# A: {pa} ({})", ra.summary_line());
    println!("# B: {pb} ({})", rb.summary_line());
    println!("{}", syncopate::trace::OverlapReport::diff_table(&ra, &rb).render());
    if let Some((plan, topo)) = traced_case_plan(&ta)? {
        let sim = simulate(&plan, &topo, syncopate::sim::SimParams::default())?;
        println!(
            "sim-vs-trace divergence: A {:.3} -> B {:.3} (sim {})",
            ra.divergence(sim.makespan_us),
            rb.divergence(sim.makespan_us),
            syncopate::util::fmt_us(sim.makespan_us)
        );
    }
    Ok(())
}

/// `stats show|check|watch|reset`: the live-telemetry verb family. `show`
/// renders a `syncopate.stats.v1` snapshot file (or, with no file, this
/// process's own registry — useful mostly after `exec --repeat` in the
/// same invocation); `--prom` switches to Prometheus text exposition.
fn stats_cmd(bare: &[String], flags: &HashMap<String, String>) -> Result<()> {
    let load_snap = |path: &String| -> Result<syncopate::obs::Snapshot> {
        let text =
            std::fs::read_to_string(path).map_err(|e| Error::Io(format!("{path}: {e}")))?;
        syncopate::obs::export::from_json(&text).map_err(|e| Error::Io(format!("{path}: {e}")))
    };
    match bare.first().map(String::as_str) {
        Some("show") => {
            let snap = match bare.get(1) {
                Some(path) => load_snap(path)?,
                None => syncopate::obs::registry().snapshot(),
            };
            if flags.contains_key("prom") {
                print!("{}", syncopate::obs::export::to_prometheus(&snap));
            } else {
                print!("{}", syncopate::obs::export::render(&snap));
            }
            Ok(())
        }
        Some("check") => {
            let Some(path) = bare.get(1) else {
                return Err(Error::Coordinator("stats check needs a stats.json file".into()));
            };
            let snap = load_snap(path)?;
            println!(
                "OK {path}: valid {} snapshot ({} metrics)",
                syncopate::obs::export::STATS_SCHEMA,
                snap.entries.len()
            );
            Ok(())
        }
        Some("watch") => {
            let Some(path) = bare.get(1) else {
                return Err(Error::Coordinator("stats watch needs a stats.json file".into()));
            };
            let interval = get_usize(flags, "interval-ms", 1000)?.max(10) as u64;
            // --count bounds the watch (0 = forever); CI smoke uses 1
            let count = get_usize(flags, "count", 0)?;
            let mut seen = String::new();
            let mut shown = 0usize;
            loop {
                // a watched file may not exist yet (or be mid-write):
                // unreadable snapshots just mean "poll again"
                if let Ok(text) = std::fs::read_to_string(path) {
                    if text != seen {
                        let snap = syncopate::obs::export::from_json(&text)
                            .map_err(|e| Error::Io(format!("{path}: {e}")))?;
                        println!("-- {path} --");
                        print!("{}", syncopate::obs::export::render(&snap));
                        seen = text;
                        shown += 1;
                        if count > 0 && shown >= count {
                            return Ok(());
                        }
                    }
                }
                std::thread::sleep(std::time::Duration::from_millis(interval));
            }
        }
        Some("reset") => {
            let n = syncopate::obs::registry().snapshot().entries.len();
            syncopate::obs::registry().reset();
            println!("stats: registry reset ({n} metrics zeroed)");
            Ok(())
        }
        other => Err(Error::Coordinator(format!(
            "unknown stats verb `{}` (show|check|watch|reset)",
            other.unwrap_or("<none>")
        ))),
    }
}

/// `calibrate --from TRACE --topo NAME -o FILE.topo`: fit measured curve
/// rows from a trace into an updated `.topo` description (DESIGN.md §14).
/// `calibrate sweep` instead runs a dedicated size x SM microbenchmark so
/// the curve's `half_size` becomes identifiable (see
/// [`syncopate::trace::fit_curve_sweep`]).
fn calibrate_cmd(bare: &[String], flags: &HashMap<String, String>) -> Result<()> {
    if bare.first().map(String::as_str) == Some("sweep") {
        return calibrate_sweep(flags);
    }
    let Some(from) = flags.get("from") else {
        return Err(Error::Coordinator(
            "calibrate needs --from <trace.json> (captured by `exec --trace`)".into(),
        ));
    };
    let Some(spec) = flags.get("topo") else {
        return Err(Error::Coordinator(
            "calibrate needs --topo <name|file.topo> (the shape the trace ran on)".into(),
        ));
    };
    let trace = load_trace(from)?;
    let mut desc = hw::catalog::load_desc(spec)?;
    // The hierarchical exec case splits single-node descriptions across
    // `--nodes`; when the trace's fingerprint says THAT is the shape it
    // ran on, follow the same resolution — otherwise a hier trace naming
    // its own topo would be refused as a foreign machine.
    if let Some(nodes) = trace.meta("nodes").and_then(|v| v.parse::<usize>().ok()) {
        if desc.nodes == 1 && nodes > 1 && trace.world % nodes == 0 {
            let split = desc.clone().with_nodes(nodes)?;
            if hw::fingerprint(&split.instantiate(trace.world)?) == trace.fingerprint {
                desc = split;
            }
        }
    }
    let cal = syncopate::trace::calibrate(&trace, &desc)?;
    println!("{}", cal.table().render());
    for (tag, before, after) in &cal.link_floors {
        println!("link {tag}: bandwidth floor raised {before:.1} -> {after:.1} GB/s");
    }
    // when the trace names its case, show how much closer the calibrated
    // model predicts the measured run
    if let Some((plan, _)) = traced_case_plan(&trace)? {
        let report = syncopate::trace::analyze(&trace);
        let params = syncopate::sim::SimParams::default();
        let before =
            simulate(&plan, &desc.instantiate(trace.world)?, params)?.makespan_us;
        let after =
            simulate(&plan, &cal.desc.instantiate(trace.world)?, params)?.makespan_us;
        println!(
            "sim-vs-trace divergence: {:.3} (uncalibrated) -> {:.3} (calibrated)",
            report.divergence(before),
            report.divergence(after)
        );
    }
    let text = hw::print_desc(&cal.desc);
    match flags.get("o").or_else(|| flags.get("out")) {
        Some(path) => {
            std::fs::write(path, &text)?;
            println!("calibrated topology `{}` -> {path}", cal.desc.name);
        }
        None => print!("{text}"),
    }
    Ok(())
}

/// `calibrate sweep --topo SPEC [--backend B] [--world N] [--repeat N]
/// [-o FILE]`: drive single-transfer microbenchmarks over a
/// (bytes x comm-SMs) grid and fit the FULL bandwidth curve. A normal
/// `calibrate --from` run keeps `half_size` from the prior — within one
/// trace the ramp constant is confounded with issue overhead — but a grid
/// that varies both transfer size and the SM allotment makes all three
/// curve constants separately identifiable (`trace::fit_curve_sweep`).
fn calibrate_sweep(flags: &HashMap<String, String>) -> Result<()> {
    use syncopate::chunk::{DType, Region, TensorTable};
    use syncopate::codegen::{ExecutablePlan, PlanOp, RankProgram};
    use syncopate::exec::BufferStore;
    use syncopate::trace::{SweepSample, TraceKind};

    let Some(spec) = flags.get("topo") else {
        return Err(Error::Coordinator(
            "calibrate sweep needs --topo <name|file.topo> (the shape to calibrate)".into(),
        ));
    };
    let world = get_usize(flags, "world", 2)?;
    if world < 2 {
        return Err(Error::Coordinator("calibrate sweep needs --world >= 2".into()));
    }
    let repeat = get_usize(flags, "repeat", 5)?.max(1);
    let backend = match flags.get("backend") {
        Some(name) => backend_by_name(name)?,
        None => BackendKind::TmaSpecialized,
    };
    let mut desc = hw::catalog::load_desc(spec)?;
    let topo = desc.instantiate(world)?;
    let prior = desc.arch.curve(backend);
    let caps = desc.arch.caps(backend);
    if caps.host_launched {
        println!(
            "note: {} is host-launched — per-launch cost and ramp are confounded, \
             the sweep keeps half_size at its prior",
            backend.name()
        );
    }
    // measure rank 0 -> rank 1 (the link the fitted latency must match)
    let lat_us = topo.link(0, 1)?.lat_us;

    let rt = Runtime::open_default()?;
    let opts = ExecOptions {
        mode: ExecMode::Parallel,
        wait_timeout: std::time::Duration::from_millis(
            get_usize(flags, "timeout-ms", 10_000)?.max(1) as u64,
        ),
        sync: get_sync(flags)?,
        pin_cores: None,
    };

    // grid: transfer sizes 64 KiB .. 4 MiB (rows of a [2048, 1024] f32
    // tensor) x SM allotments up to the prior's saturation point
    const COLS: usize = 1024;
    const ROWS: usize = 2048;
    let sizes = [16usize, 64, 256, 1024];
    let mut sms_grid = if prior.sms_for_peak == 0 {
        vec![0]
    } else {
        vec![
            (prior.sms_for_peak / 4).max(1),
            (prior.sms_for_peak / 2).max(1),
            prior.sms_for_peak,
        ]
    };
    sms_grid.dedup();

    let mut samples = Vec::new();
    for &rows in &sizes {
        for &sms in &sms_grid {
            // minimal two-rank plan: rank 0 issues the transfer, rank 1
            // waits on its completion signal
            let mut table = TensorTable::new();
            let x = table.declare("x", &[ROWS, COLS], DType::F32)?;
            let mut desc_op =
                syncopate::testutil::transfer_desc(x, Region::rows(0, rows, COLS), 0, 0, 1, vec![], false);
            desc_op.backend = backend;
            desc_op.comm_sms = sms;
            let bytes = desc_op.bytes;
            let pieces = desc_op.pieces;
            let mut per_rank = vec![RankProgram::default(); world];
            per_rank[0].ops = vec![PlanOp::Issue(desc_op)];
            per_rank[1].ops = vec![PlanOp::Wait(0)];
            let plan = ExecutablePlan {
                world,
                per_rank,
                num_signals: 1,
                reserved_comm_sms: if caps.dedicated_sms { sms } else { 0 },
            };
            let mut store = BufferStore::new(world);
            store.declare("x", &[ROWS, COLS])?;

            let mut durs = Vec::with_capacity(repeat);
            for i in 0..=repeat {
                let (_, trace) =
                    syncopate::exec::run_with_traced(&plan, &table, &store, &rt, &opts)?;
                let dur = trace
                    .events
                    .iter()
                    .find(|e| matches!(e.kind, TraceKind::Transfer { .. }))
                    .map(syncopate::trace::TraceEvent::dur_us)
                    .ok_or_else(|| {
                        Error::Trace("sweep run produced no transfer event".into())
                    })?;
                if i > 0 {
                    // run 0 is warm-up: first-touch page faults and thread
                    // spin-up would otherwise skew the smallest sizes
                    durs.push(dur);
                }
            }
            let (median, _) = syncopate::perf::median_mad(&durs);
            samples.push(SweepSample { bytes, pieces, comm_sms: sms, dur_us: median });
        }
    }

    let (fitted, sse) = syncopate::trace::fit_curve_sweep(prior, caps, lat_us, &samples)?;
    let mut t = syncopate::metrics::Table::new(
        &format!("sweep calibration: {} ({} samples)", backend.name(), samples.len()),
        &["peak GB/s", "half KiB", "issue us", "SMs@peak"],
        "",
    );
    for (label, c) in [("prior", prior), ("fitted", fitted)] {
        t.push_row(
            label,
            vec![c.peak_gbps, c.half_size / 1024.0, c.issue_us, c.sms_for_peak as f64],
        );
    }
    println!("{}", t.render());
    println!("fit residual: {sse:.3e} (sum of squared us over {} grid points)", samples.len());

    desc.arch.set(backend, caps, fitted);
    if !desc.name.ends_with("-cal") {
        desc.name.push_str("-cal");
    }
    let text = hw::print_desc(&desc);
    match flags.get("o").or_else(|| flags.get("out")) {
        Some(path) => {
            std::fs::write(path, &text)?;
            println!("swept topology `{}` -> {path}", desc.name);
        }
        None => print!("{text}"),
    }
    Ok(())
}

/// `perf <critical|record|diff|gate>`: the critical-path profiler and the
/// continuous perf-regression harness (DESIGN.md §19).
fn perf_cmd(bare: &[String], flags: &HashMap<String, String>) -> Result<()> {
    match bare.first().map(String::as_str) {
        Some("critical") => perf_critical(&bare[1..], flags),
        Some("record") => perf_record(flags),
        Some("diff") => perf_diff(&bare[1..], flags),
        Some("gate") => perf_gate(flags),
        _ => Err(Error::Coordinator(
            "perf needs a verb: critical <trace.json> | record | diff <a> <b> | gate \
             --baseline <file> (see --help)"
                .into(),
        )),
    }
}

fn get_f64(flags: &HashMap<String, String>, key: &str, default: f64) -> Result<f64> {
    match flags.get(key) {
        None => Ok(default),
        Some(v) => v
            .parse::<f64>()
            .map_err(|_| Error::Coordinator(format!("--{key} needs a number, got `{v}`"))),
    }
}

/// `perf critical <trace.json>`: reconstruct the trace's dependency DAG,
/// extract the model-weighted longest path, blame the wall makespan.
fn perf_critical(bare: &[String], flags: &HashMap<String, String>) -> Result<()> {
    let Some(path) = bare.first() else {
        return Err(Error::Coordinator(
            "perf critical needs a trace file (captured by `exec --trace`)".into(),
        ));
    };
    let trace = load_trace(path)?;
    let cp = syncopate::perf::critical_path(&trace)?;
    if flags.contains_key("json") {
        println!("{}", cp.to_json());
    } else {
        println!("{}", cp.table().render());
        println!(
            "path: {} ops, model-weighted length {} (wall {})",
            cp.nodes.len(),
            syncopate::util::fmt_us(cp.model_path_us),
            syncopate::util::fmt_us(cp.wall_makespan_us)
        );
    }
    if let Some(out) = flags.get("chrome") {
        // re-export the trace with the critical spans painted for
        // chrome://tracing (the `critical: true` arg + color override)
        std::fs::write(out, syncopate::trace::to_chrome_json_overlay(&trace, &cp.keys()))?;
        println!("critical-path overlay -> {out} ({} highlighted spans)", cp.nodes.len());
    }
    if let Some(spec) = flags.get("what-if") {
        let (_, topo) = hw::catalog::resolve(spec, trace.world)?;
        let w = cp.what_if_topo(&trace, &topo)?;
        println!(
            "what-if [{spec}]: critical comm repriced saves {}, makespan bound {} \
             (speedup <= {:.3}x)",
            syncopate::util::fmt_us(w.saved_us),
            syncopate::util::fmt_us(w.bound_us),
            w.speedup_bound
        );
    }
    if let Some(v) = flags.get("what-if-comm-x") {
        let scale = get_f64(flags, "what-if-comm-x", 1.0)?;
        if scale < 0.0 {
            return Err(Error::Coordinator(format!(
                "--what-if-comm-x needs a scale >= 0, got `{v}`"
            )));
        }
        let w = cp.what_if_scale(scale);
        println!(
            "what-if [comm x{scale}]: saves {}, makespan bound {} (speedup <= {:.3}x)",
            syncopate::util::fmt_us(w.saved_us),
            syncopate::util::fmt_us(w.bound_us),
            w.speedup_bound
        );
    }
    Ok(())
}

/// Time registry cases on the arena-reusing hot path and summarize each as
/// a noise-aware baseline cell. Shared by `perf record` and `perf gate`.
fn perf_measure(flags: &HashMap<String, String>) -> Result<syncopate::perf::Baseline> {
    let repeat = get_usize(flags, "repeat", 7)?.max(2);
    let cases_flag = flags.get("cases").map(String::as_str);
    // an explicit case list fails loudly; the default "all" sweep skips
    // cases the requested (world, topo) cannot build
    let explicit = matches!(cases_flag, Some(v) if v != "all" && v != "true");
    let names: Vec<String> = match cases_flag {
        Some(v) if explicit => {
            v.split(',').map(|s| s.trim().to_string()).filter(|s| !s.is_empty()).collect()
        }
        _ => execases::CASES.iter().map(|s| s.name.to_string()).collect(),
    };
    let params = CaseParams {
        world: get_usize(flags, "world", 4)?,
        split: get_usize(flags, "split", 1)?,
        seed: get_usize(flags, "seed", 42)? as u64,
        nodes: get_usize(flags, "nodes", 2)?,
        topo: flags.get("topo").cloned().unwrap_or_else(|| hw::catalog::DEFAULT.to_string()),
    };
    let opts = ExecOptions {
        mode: ExecMode::Parallel,
        wait_timeout: std::time::Duration::from_millis(
            get_usize(flags, "timeout-ms", 10_000)?.max(1) as u64,
        ),
        sync: get_sync(flags)?,
        pin_cores: None,
    };
    let rt = Runtime::open_default()?;
    let mut base = syncopate::perf::Baseline::default();
    for name in &names {
        let case = match execases::build_case(name, &params) {
            Ok(c) => c,
            Err(e) if !explicit => {
                println!("skip {name}: {e}");
                continue;
            }
            Err(e) => return Err(e),
        };
        let fingerprint = hw::fingerprint(&case.topo);
        let prep = syncopate::exec::prepare(&case.plan, &case.sched.tensors)?;
        let mut arena = syncopate::exec::PlanArena::new(&prep);
        let mut durs = Vec::with_capacity(repeat);
        for i in 0..=repeat {
            // fresh data every iteration (runs mutate the buffers); run 0
            // is warm-up so first-touch costs stay out of the median
            let store = case.store.clone();
            let t0 = std::time::Instant::now();
            syncopate::exec::run_prepared_reusing(&prep, &mut arena, &store, &rt, &opts)?;
            if i > 0 {
                durs.push(syncopate::obs::us_since(t0));
            }
        }
        let (median_us, mad_us) = syncopate::perf::median_mad(&durs);
        base.insert(syncopate::perf::PerfCase {
            case: name.clone(),
            world: params.world,
            engine: "parallel".into(),
            fingerprint,
            samples: durs.len(),
            median_us,
            mad_us,
        });
    }
    if base.cases.is_empty() {
        return Err(Error::Coordinator(
            "perf: no case could be built for the requested world/topo".into(),
        ));
    }
    Ok(base)
}

fn perf_baseline_table(base: &syncopate::perf::Baseline) -> syncopate::metrics::Table {
    let mut t = syncopate::metrics::Table::new(
        "Perf baseline (median over N hot-path iterations)",
        &["median us", "MAD us", "samples"],
        "us",
    );
    for c in &base.cases {
        t.push_row(
            &format!("{} w{} [{}]", c.case, c.world, c.engine),
            vec![c.median_us, c.mad_us, c.samples as f64],
        );
    }
    t
}

/// `perf record`: measure a fresh baseline, write it, and append one
/// trajectory row per cell to `BENCH_results.json`.
fn perf_record(flags: &HashMap<String, String>) -> Result<()> {
    let base = perf_measure(flags)?;
    println!("{}", perf_baseline_table(&base).render());
    let out = flags.get("out").map(String::as_str).unwrap_or("BENCH_baseline.json");
    std::fs::write(out, base.to_json())?;
    println!("baseline ({} cells) -> {out}", base.cases.len());
    let bench = match flags.get("bench").map(String::as_str) {
        Some("true") | None => "BENCH_results.json",
        Some(p) => p,
    };
    for c in &base.cases {
        let row = syncopate::perf::bench_row(
            "perf-record",
            &[
                ("case", c.case.as_str()),
                ("engine", c.engine.as_str()),
                ("fingerprint", c.fingerprint.as_str()),
            ],
            &[
                ("world", c.world as f64),
                ("median_us", c.median_us),
                ("mad_us", c.mad_us),
                ("samples", c.samples as f64),
            ],
        );
        syncopate::perf::append_bench_row(bench, &row)?;
    }
    println!("{} trajectory rows -> {bench}", base.cases.len());
    Ok(())
}

/// `perf diff <A> <B>`: compare two recorded baselines; exit non-zero when
/// any cell regresses significantly.
fn perf_diff(bare: &[String], flags: &HashMap<String, String>) -> Result<()> {
    let (Some(a), Some(b)) = (bare.first(), bare.get(1)) else {
        return Err(Error::Coordinator(
            "perf diff needs two baseline files: perf diff <base.json> <new.json>".into(),
        ));
    };
    let base = syncopate::perf::Baseline::from_json(&std::fs::read_to_string(a)?)?;
    let fresh = syncopate::perf::Baseline::from_json(&std::fs::read_to_string(b)?)?;
    let max = get_f64(flags, "max-regress", 5.0)?;
    perf_judge(&base, &fresh, max)
}

/// `perf gate --baseline <FILE>`: re-measure now and compare against a
/// recorded baseline — the CI entry point.
fn perf_gate(flags: &HashMap<String, String>) -> Result<()> {
    let Some(path) = flags.get("baseline") else {
        return Err(Error::Coordinator(
            "perf gate needs --baseline <file> (written by `perf record`)".into(),
        ));
    };
    let base = syncopate::perf::Baseline::from_json(&std::fs::read_to_string(path)?)?;
    let fresh = perf_measure(flags)?;
    let max = get_f64(flags, "max-regress", 5.0)?;
    perf_judge(&base, &fresh, max)
}

fn perf_judge(
    base: &syncopate::perf::Baseline,
    fresh: &syncopate::perf::Baseline,
    max_regress_pct: f64,
) -> Result<()> {
    let rows = syncopate::perf::diff(base, fresh, max_regress_pct);
    if rows.is_empty() {
        println!("perf: no overlapping (case, world, engine) cells to compare");
        return Ok(());
    }
    println!("{}", syncopate::perf::diff_table(&rows).render());
    if rows.iter().any(|r| !r.fingerprint_match) {
        println!("note: some cells ran on a different machine fingerprint (never flagged)");
    }
    let n = syncopate::perf::regressions(&rows);
    if n > 0 {
        return Err(Error::Coordinator(format!(
            "{n} significant perf regression(s) beyond {max_regress_pct}% \
             (delta > noise band 3*(MAD_a + MAD_b))"
        )));
    }
    println!("perf: no significant regressions (threshold {max_regress_pct}%)");
    Ok(())
}

/// `plan import --from SOURCE [--world N] [--out FILE]`: instantiate a
/// registered plan source (template or baseline importer) and emit it in
/// the `.sched` DSL.
fn plan_import(flags: &HashMap<String, String>) -> Result<()> {
    let Some(from) = flags.get("from") else {
        return Err(Error::Coordinator(format!(
            "plan import needs --from <source> (known: {})",
            plan_io::registry::names().join(", ")
        )));
    };
    let world = get_usize(flags, "world", 4)?;
    let sched = plan_io::registry::build(from, world)?;
    let text = plan_io::print_schedule(&sched)?;
    let hash = plan_io::content_hash(&text);
    match flags.get("out") {
        Some(path) => {
            std::fs::write(path, &text)?;
            println!(
                "{from} @ world {world}: {} ops, hash {hash} -> {path}",
                sched.num_ops()
            );
        }
        None => print!("{text}"),
    }
    Ok(())
}

/// `plan show FILE`: parse, validate, summarize, and re-print canonically.
fn plan_show(files: &[String]) -> Result<()> {
    let Some(path) = files.first() else {
        return Err(Error::Coordinator("plan show needs a .sched file".into()));
    };
    let text = std::fs::read_to_string(path)?;
    let sched = plan_io::parse_schedule(&text)?;
    syncopate::schedule::validate::validate(&sched)?;
    let canonical = plan_io::print_schedule(&sched)?;
    println!("# {path}");
    println!("# world {}, {} tensors, {} ops, {} over links, hash {}",
        sched.world,
        sched.tensors.len(),
        sched.num_ops(),
        syncopate::util::fmt_bytes(sched.total_link_bytes()? as u64),
        plan_io::content_hash(&canonical),
    );
    print!("{canonical}");
    Ok(())
}

/// `plan lint FILE...`: parse + validate + round-trip-check each file,
/// then run the analyzer's error-severity rules (race certificates,
/// deadlock cycles); exits non-zero on the first violation (CI guards the
/// shipped corpus with this). Warnings are counted, not fatal — `plan
/// analyze --strict` is the gate for those.
fn plan_lint(files: &[String]) -> Result<()> {
    if files.is_empty() {
        return Err(Error::Coordinator("plan lint needs at least one .sched file".into()));
    }
    for path in files {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::Io(format!("{path}: {e}")))?;
        let sched = plan_io::parse_schedule(&text)
            .map_err(|e| Error::PlanIo(format!("{path}: {e}")))?;
        syncopate::schedule::validate::validate(&sched)
            .map_err(|e| Error::Schedule(format!("{path}: {e}")))?;
        let rep = syncopate::analysis::run(&sched)
            .map_err(|e| Error::Analysis(format!("{path}: {e}")))?;
        if let Some(f) = rep
            .findings
            .iter()
            .find(|f| f.severity == syncopate::analysis::Severity::Error)
        {
            return Err(Error::Analysis(format!("{path}: {} {}", f.rule, f.message)));
        }
        let canonical = plan_io::print_schedule(&sched)?;
        let reparsed = plan_io::parse_schedule(&canonical)?;
        if reparsed != sched {
            return Err(Error::PlanIo(format!(
                "{path}: print->parse round-trip changed the schedule (printer bug?)"
            )));
        }
        println!(
            "OK {path}: world {}, {} ops, {} warning(s), hash {}",
            sched.world,
            sched.num_ops(),
            rep.count(syncopate::analysis::Severity::Warn),
            plan_io::content_hash(&canonical)
        );
    }
    Ok(())
}

/// `plan analyze FILE... [--json] [--strict]`: run the full static-analysis
/// rule catalog (DESIGN.md §17) over each plan and report every finding —
/// unlike `plan lint`, bad plans are *described*, not just rejected: race
/// certificates name both ops, the overlapping region, and a witness
/// interleaving; deadlocks print the full wait-for cycle. With `--topo`
/// the report includes the sim-measured critical-path impact of removing
/// redundant deps. Exits non-zero when any plan has error findings
/// (`--strict`: or warnings).
///
/// `plan analyze --fix FILE -o OUT` writes the canonically reduced plan
/// (all redundant dep edges dropped); both exec engines run it
/// bit-identically to the original.
fn plan_analyze(files: &[String], flags: &HashMap<String, String>) -> Result<()> {
    // `--fix FILE` puts the file in the flag value (hand-rolled parser);
    // accept it there or as a bare arg.
    if let Some(fix) = flags.get("fix") {
        let target = if fix != "true" { Some(fix) } else { files.first() };
        let Some(path) = target else {
            return Err(Error::Coordinator("plan analyze --fix needs a .sched file".into()));
        };
        let Some(out) = flags.get("o").or_else(|| flags.get("out")) else {
            return Err(Error::Coordinator("plan analyze --fix needs -o FILE.sched".into()));
        };
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::Io(format!("{path}: {e}")))?;
        let sched = plan_io::parse_schedule(&text)
            .map_err(|e| Error::PlanIo(format!("{path}: {e}")))?;
        // only valid plans are worth canonicalizing — a racy or deadlocked
        // plan needs fixing by hand, not dep-thinning
        syncopate::schedule::validate::validate(&sched)
            .map_err(|e| Error::Schedule(format!("{path}: {e}")))?;
        let (reduced, removed) = syncopate::analysis::reduce(&sched)
            .map_err(|e| Error::Analysis(format!("{path}: {e}")))?;
        syncopate::schedule::validate::validate(&reduced)?;
        let canonical = plan_io::print_schedule(&reduced)?;
        std::fs::write(out, &canonical)?;
        println!(
            "{path}: removed {} redundant dep edge(s) -> {out} ({} ops, hash {})",
            removed.len(),
            reduced.num_ops(),
            plan_io::content_hash(&canonical)
        );
        return Ok(());
    }
    if files.is_empty() {
        return Err(Error::Coordinator("plan analyze needs at least one .sched file".into()));
    }
    let json = flags.contains_key("json");
    let strict = flags.contains_key("strict");
    let mut failed = 0usize;
    for path in files {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::Io(format!("{path}: {e}")))?;
        let sched = plan_io::parse_schedule(&text)
            .map_err(|e| Error::PlanIo(format!("{path}: {e}")))?;
        let topo = resolve_topo(flags, sched.world)?;
        let rep = syncopate::analysis::run_on(&sched, &topo)
            .map_err(|e| Error::Analysis(format!("{path}: {e}")))?;
        if json {
            print!("{}", rep.to_json(path));
        } else {
            print!("{}", rep.render_text(path));
        }
        use syncopate::analysis::Severity;
        if rep.has_errors() || (strict && rep.count(Severity::Warn) > 0) {
            failed += 1;
        }
    }
    if failed > 0 {
        return Err(Error::Analysis(format!(
            "{failed} of {} plan(s) failed analysis{}",
            files.len(),
            if strict { " (--strict: warnings are fatal)" } else { "" }
        )));
    }
    Ok(())
}

/// `plan run FILE [--workers N] [--exec-mode M] [--timeout-ms N]`: serve a
/// user-authored schedule through the coordinator's cached path —
/// validate → restricted autotune → codegen → exec. Submitted twice to
/// show the plan-cache hit on re-serving.
fn plan_run(files: &[String], flags: &HashMap<String, String>) -> Result<()> {
    let Some(path) = files.first() else {
        return Err(Error::Coordinator("plan run needs a .sched file".into()));
    };
    let text = std::fs::read_to_string(path)?;
    let sched = plan_io::parse_schedule(&text)?;
    let workers = get_usize(flags, "workers", 2)?;
    let mode: ExecMode = flags
        .get("exec-mode")
        .map(String::as_str)
        .unwrap_or("parallel")
        .parse()?;
    let timeout_ms = get_usize(flags, "timeout-ms", 10_000)?.max(1) as u64;
    let opts = ExecOptions {
        mode,
        wait_timeout: std::time::Duration::from_millis(timeout_ms),
        sync: get_sync(flags)?,
        pin_cores: None,
    };
    let coord = Coordinator::spawn_pool(resolve_topo(flags, sched.world)?, workers);
    for attempt in ["cold", "warm"] {
        let r = coord.run_user_plan(&text, opts.clone())?;
        println!(
            "{path} [{attempt}]: world {}, {} ops, backend {}, sim {}, \
             {} transfers / {} moved [{mode:?}] (cache {})",
            r.world,
            r.ops,
            r.backend_label,
            syncopate::util::fmt_us(r.sim_makespan_us),
            r.stats.transfers,
            syncopate::util::fmt_bytes(r.stats.bytes_moved as u64),
            r.cache_hit
        );
    }
    Ok(())
}

fn report(bare: &[String], flags: &HashMap<String, String>) -> Result<()> {
    let which = bare.first().map(String::as_str).unwrap_or("all");
    let budget = if flags.contains_key("full") { Budget::Full } else { Budget::Quick };
    let csv = flags.contains_key("csv");
    // --json: the BENCH_results.json discipline for report tables (NaN
    // cells -> null); ranking/ratio footers are suppressed so the output
    // pipes straight into jq
    let json = flags.contains_key("json");
    let emit = |t: &syncopate::metrics::Table| {
        if json {
            println!("{}", t.to_json());
        } else if csv {
            println!("{}", t.to_csv());
        } else {
            println!("{}", t.render());
        }
    };
    match which {
        "table2" => emit(&reports::table2()),
        "fig2" => {
            emit(&reports::fig2a());
            emit(&reports::fig2b()?);
            emit(&reports::fig2c());
            emit(&reports::fig2d());
        }
        "fig8" => {
            let t = reports::fig8(budget)?;
            emit(&t);
            if !json {
                print_ratios(&t);
            }
        }
        "fig9" => {
            let t = reports::fig9(budget)?;
            emit(&t);
            if !json {
                print_ratios(&t);
            }
        }
        "fig10" => emit(&reports::fig10(budget)?),
        "ported" => emit(&reports::ported()?),
        "pipeline" => emit(&reports::pipeline()?),
        "scale" => emit(&reports::scalability(budget)?),
        "fig11" => {
            emit(&reports::fig11a()?);
            emit(&reports::fig11b()?);
            emit(&reports::fig11c()?);
            emit(&reports::fig11d()?);
        }
        "arch-sweep" => {
            let t = reports::arch_sweep()?;
            emit(&t);
            if !json {
                print_arch_ranking(&t);
            }
        }
        "headline" => {
            let (avg, max) = reports::headline(budget)?;
            println!("headline: avg {avg:.2}x, up to {max:.2}x over automatic baselines\n");
        }
        "all" => {
            for w in [
                "table2", "fig2", "fig8", "fig9", "fig10", "fig11", "ported", "pipeline",
                "scale", "arch-sweep", "headline",
            ] {
                report(&[w.to_string()], flags)?;
            }
        }
        other => return Err(Error::Coordinator(format!("unknown report `{other}`"))),
    }
    Ok(())
}

/// Per-case topology ranking for `report arch-sweep` (fastest first).
fn print_arch_ranking(t: &syncopate::metrics::Table) {
    for (label, row) in &t.rows {
        let mut idx: Vec<usize> = (0..row.len()).filter(|&i| row[i].is_finite()).collect();
        idx.sort_by(|&a, &b| row[a].total_cmp(&row[b]));
        let order: Vec<&str> = idx.iter().map(|&i| t.columns[i].as_str()).collect();
        println!("  {label:14} fastest -> slowest: {}", order.join(" > "));
    }
    println!();
}

fn print_ratios(t: &syncopate::metrics::Table) {
    for base in ["triton+nccl", "kernel-level", "flux", "triton-dist"] {
        if let (Some(avg), Some(max)) =
            (t.geomean_ratio("syncopate", base), t.max_ratio("syncopate", base))
        {
            println!("  vs {base:14} avg {avg:.2}x  max {max:.2}x");
        }
    }
    println!();
}

fn print_usage() {
    println!(
        "syncopate — chunk-centric compute/communication overlap (paper reproduction)\n\
         usage: syncopate <report|simulate|tune|exec|trace|flight|stats|calibrate|perf|plan|\
         topo|serve-demo> [flags]\n\
         plan verbs: plan import --from <src>, plan show|lint|run <file.sched>\n\
         topo verbs: topo list, topo show|lint <name|file.topo>\n\
         exec cases: syncopate exec --case list   (add --trace FILE to capture, \
         --repeat N --stats FILE for telemetry)\n\
         tracing   : trace show|overlap <file.json>, trace diff <a.json> <b.json>; \
         calibrate --from <file.json> --topo <name> -o <file.topo>; \
         calibrate sweep --topo <name> (microbench grid, fits half_size)\n\
         perf      : perf critical <trace.json> [--chrome out.json] [--what-if topo], \
         perf record [--out file], perf diff <a> <b>, perf gate --baseline <file> \
         [--max-regress PCT]\n\
         telemetry : stats show [file.json] [--prom], stats check|watch <file.json>, \
         stats reset\n\
         post-mortem: flight dump [--deadlock-demo] [--out file.json] [--chrome file.json], \
         flight show <file.json>; exec/serve-demo take --flight FILE\n\
         hardware  : every sim/tune/exec/plan-run takes --topo <name|file.topo>\n\
         see rust/src/main.rs header for the full flag list"
    );
}
